"""Spectral (truncated-SVD) parameterization: init, dense conversion, checks.

A spectral parameter is the triple ``(U, s, V)`` with ``U: (m, k)``,
``s: (k,)``, ``V: (n, k)``, representing — but never materializing —
``W = U diag(s) V^T`` (paper Eq. 1). Storage: ``k(m+n+1)`` vs ``m*n``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import ref


def orthonormal(key: jax.Array, rows: int, cols: int, dtype=jnp.float32) -> jax.Array:
    """Random (rows, cols) matrix with orthonormal columns: QR of a Gaussian,
    sign-fixed so the distribution is Haar on the Stiefel manifold."""
    g = jax.random.normal(key, (rows, cols), dtype=jnp.float32)
    q = ref.qr_retract_cgs(g)  # graph-safe; see kernels.ref
    return q.astype(dtype)


def init_spectral(key: jax.Array, m: int, n: int, k: int, dtype=jnp.float32) -> dict:
    """From-scratch init for a spectral layer.

    U, V are Haar-orthonormal; ``s`` is constant and chosen so the implied
    dense matrix matches Glorot variance:  dense Glorot has
    E||W||_F^2 = m*n*sigma^2 with sigma^2 = 2/(m+n); since U, V are
    orthonormal, ||W||_F^2 = sum s_i^2, so  s_i = sigma * sqrt(m*n / k).
    This keeps activation scales rank-independent at init, which is what
    makes the paper's cross-rank loss comparisons meaningful.
    """
    ku, kv = jax.random.split(key)
    sigma = jnp.sqrt(2.0 / (m + n))
    s0 = sigma * jnp.sqrt(m * n / k)
    return {
        "u": orthonormal(ku, m, k, dtype),
        "s": jnp.full((k,), s0, dtype),
        "v": orthonormal(kv, n, k, dtype),
    }


def from_dense(w: jax.Array, k: int) -> dict:
    """Truncated SVD of a dense ``(m, n)`` matrix -> rank-k spectral triple.

    This is the paper's conversion path (§4.2: pretrained MLP weights are
    converted via truncated SVD; §4.4: at an energy threshold). If
    ``k > rank(w)`` the extra singular values are zero and U, V are completed
    to orthonormal bases, so the representation is exact.
    """
    u, s, vt = jnp.linalg.svd(w, full_matrices=False)
    kk = min(k, s.shape[0])
    tri = {"u": u[:, :kk], "s": s[:kk], "v": vt[:kk, :].T}
    if kk < k:
        tri = pad_rank(tri, k, jax.random.PRNGKey(0))
    return tri


def energy_rank(s: jax.Array, energy: float) -> int:
    """Smallest k with sum(s[:k]^2) >= energy * sum(s^2) (§4.4's 95%)."""
    e = jnp.cumsum(s**2) / jnp.sum(s**2)
    return int(jnp.searchsorted(e, energy) + 1)


def pad_rank(tri: dict, k: int, key: jax.Array) -> dict:
    """Zero-pad a rank-r triple to rank k > r without changing W.

    s gets zeros; U, V get orthonormal completions of their column spaces
    (project a Gaussian block off the existing basis, CGS-style, then
    retract). W = U diag(s) V^T is unchanged because the new directions are
    multiplied by zero — this is how the rust finetune driver feeds an
    energy-rank conversion into a fixed-k artifact.
    """
    u, s, v = tri["u"], tri["s"], tri["v"]
    r = s.shape[0]
    if r >= k:
        return tri
    ku, kv = jax.random.split(key)

    def complete(q, key, rows):
        extra = k - q.shape[1]
        g = jax.random.normal(key, (rows, extra), dtype=q.dtype)
        g = g - q @ (q.T @ g)
        g = g - q @ (q.T @ g)  # twice, CGS2
        return jnp.concatenate([q, ref.qr_retract(g)], axis=1)

    return {
        "u": complete(u, ku, u.shape[0]),
        "s": jnp.concatenate([s, jnp.zeros((k - r,), s.dtype)]),
        "v": complete(v, kv, v.shape[0]),
    }


def to_dense(tri: dict) -> jax.Array:
    """Materialize W — FOR TESTS ONLY. The training path never calls this."""
    return tri["u"] @ jnp.diag(tri["s"]) @ tri["v"].T


def ortho_error(tri: dict) -> jax.Array:
    """max of the two factor orthonormality errors (paper reports < 2e-6)."""
    return jnp.maximum(ref.ortho_error(tri["u"]), ref.ortho_error(tri["v"]))


def spectral_size(m: int, n: int, k: int) -> int:
    """Parameter count k(m+n+1) — paper §3 Memory analysis."""
    return k * (m + n + 1)
