"""AOT exporter: lower every step function to HLO *text* + a manifest.

This is the only bridge between the Python build layer and the rust runtime.
``python -m compile.aot`` runs once (``make artifacts``); afterwards the rust
binary is self-contained.

Interchange format gotcha (see /opt/xla-example/README.md): we emit HLO
**text**, not a serialized HloModuleProto — jax >= 0.5 writes protos with
64-bit instruction ids that the runtime's XLA (xla_extension 0.5.1) rejects;
the text parser reassigns ids and round-trips cleanly. Lowered with
``return_tuple=True``; the rust session unwraps the single tuple output.

Manifest contract (artifacts/manifest.json)
-------------------------------------------
For each preset: the model config, the ordered flat tensor specs of every
artifact's inputs and outputs (name/dtype/shape in jax tree-flatten order),
and the state layout. For state-carrying artifacts (``train_step``) the
first ``n_state`` inputs and outputs are the same tensors in the same order,
so the rust hot loop feeds step outputs straight back as next-step inputs
without any host round-trip.
"""

from __future__ import annotations

import argparse
import json
import os

import jax

# The runtime's XLA (xla_extension 0.5.1) predates jax's typed-FFI custom
# calls. The default threefry PRNG lowers to one; 'rbg' lowers to the native
# HLO RngBitGenerator op instead. Must be set before any tracing happens.
jax.config.update("jax_default_prng_impl", "rbg")

import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import configs, train
from .optim import path_str

# --------------------------------------------------------------------------


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dtype_name(dt) -> str:
    return jnp.dtype(dt).name  # 'float32', 'int32', ...


def flat_specs(tree, prefix: str) -> list[dict]:
    """Ordered (name, dtype, shape) for every leaf, in tree-flatten order —
    the exact order XLA parameters / tuple outputs appear in."""
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [
        {
            "name": f"{prefix}/{path_str(path)}" if path else prefix,
            "dtype": _dtype_name(leaf.dtype),
            "shape": [int(d) for d in leaf.shape],
        }
        for path, leaf in leaves
    ]


def lower_artifact(fn, example_args, arg_prefixes, out_dir, name):
    """Lower ``fn(*example_args)`` to HLO text; return its manifest entry."""
    # keep_unused=True: the manifest contract is positional over the FULL
    # input tree; without it XLA drops unused parameters (e.g. ortho_check
    # reads only the 2·3·L factor matrices) and the buffer counts diverge.
    lowered = jax.jit(fn, keep_unused=True).lower(*example_args)
    text = to_hlo_text(lowered)
    fname = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)

    inputs = []
    for prefix, arg in zip(arg_prefixes, example_args):
        inputs.extend(flat_specs(arg, prefix))
    out_shape = jax.eval_shape(fn, *example_args)
    outputs = flat_specs(out_shape, "out")
    return {
        "file": fname,
        "inputs": inputs,
        "outputs": outputs,
        "bytes": len(text),
    }


# --------------------------------------------------------------------------


def export_preset(cfg: configs.ModelConfig, root: str, chunk_k: int = 10) -> dict:
    out_dir = os.path.join(root, cfg.name)
    os.makedirs(out_dir, exist_ok=True)
    params, opt, tokens, scalar, seed = train.example_inputs(cfg)

    n_params = len(jax.tree_util.tree_leaves(params))
    n_opt = len(jax.tree_util.tree_leaves(opt))

    arts = {}
    arts["init"] = lower_artifact(
        train.make_init(cfg), (seed,), ("seed",), out_dir, "init"
    )
    # Pallas interpret-mode kernels have no registered VJP, so the pallas
    # integration preset exports only the inference-side artifacts (its
    # training math is identical to the ref path — proven by pytest).
    if not cfg.use_pallas:
        arts["train_step"] = lower_artifact(
            train.make_train_step(cfg),
            (params, opt, tokens, scalar, scalar),
            ("params", "opt", "tokens", "lr_dense", "lr_spectral"),
            out_dir,
            "train_step",
        )
        chunk_tokens = jax.ShapeDtypeStruct(
            (chunk_k, cfg.batch, cfg.seq_len + 1), jnp.int32
        )
        arts["train_chunk"] = lower_artifact(
            train.make_train_chunk(cfg, chunk_k),
            (params, opt, chunk_tokens, scalar, scalar),
            ("params", "opt", "tokens", "lr_dense", "lr_spectral"),
            out_dir,
            "train_chunk",
        )
    arts["eval_step"] = lower_artifact(
        train.make_eval_step(cfg), (params, tokens), ("params", "tokens"), out_dir, "eval_step"
    )
    # forward takes input tokens (B, T) — no next-token column.
    fwd_tokens = jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len), jnp.int32)
    arts["forward"] = lower_artifact(
        train.make_forward(cfg), (params, fwd_tokens), ("params", "tokens"), out_dir, "forward"
    )
    arts["retract"] = lower_artifact(
        train.make_retract(cfg), (params,), ("params",), out_dir, "retract"
    )
    arts["ortho_check"] = lower_artifact(
        train.make_ortho_check(cfg), (params,), ("params",), out_dir, "ortho_check"
    )

    return {
        "model": cfg.to_json_dict(),
        "param_count": cfg.param_count(),
        "n_state": n_params + n_opt,  # state prefix length of train_step I/O
        "n_params": n_params,
        # Canonical state layout: names/dtypes/shapes of every state tensor
        # (params then optimizer) in flatten order — what `init` returns and
        # what the state-prefix of `train_step` I/O means. The rust session
        # and checkpoint format key off these names.
        "state": flat_specs(params, "params") + flat_specs(opt, "opt"),
        "artifacts": arts,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact root dir")
    ap.add_argument(
        "--presets",
        default="",
        help="comma-separated preset names (default: all in configs.PRESETS)",
    )
    args = ap.parse_args()

    names = [n for n in args.presets.split(",") if n] or sorted(configs.PRESETS)
    os.makedirs(args.out, exist_ok=True)

    manifest = {"format": 1, "presets": {}}
    for name in names:
        cfg = configs.get(name)
        print(f"[aot] lowering preset {name} "
              f"({cfg.param_count():,} params, rank={cfg.rank})", flush=True)
        manifest["presets"][name] = export_preset(cfg, args.out)

    path = os.path.join(args.out, "manifest.json")
    with open(path, "w") as f:
        json.dump(manifest, f, indent=1)
    total = sum(
        a["bytes"]
        for p in manifest["presets"].values()
        for a in p["artifacts"].values()
    )
    print(f"[aot] wrote {path} ({len(names)} presets, {total/1e6:.1f} MB of HLO)")


if __name__ == "__main__":
    main()
