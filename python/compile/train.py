"""L2 — the exported step functions (paper Alg. 1 as one HLO module each).

Everything the rust coordinator executes at runtime is defined here as a
pure function and lowered once by `aot.py`:

* ``init``        seed                                  -> (params, opt)
* ``train_step``  (params, opt, tokens, lr_d, lr_s)     -> (params, opt, loss)
* ``eval_step``   (params, tokens)                      -> loss
* ``forward``     (params, tokens)                      -> logits
* ``retract``     params                                -> params
* ``ortho_check`` params                                -> max ||Q^TQ - I||

``train_step`` is the whole of Algorithm 1 — forward, backward, AdamW,
Stiefel QR retraction — fused into a single XLA computation, so the rust
hot loop makes exactly one PJRT call per step and no dense (m, n) tensor
ever exists on any path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import model, optim
from .configs import ModelConfig


def make_init(cfg: ModelConfig):
    def init(seed: jax.Array):
        params = model.init_params(jax.random.PRNGKey(seed), cfg)
        return params, optim.init_opt_state(params)

    return init


def make_train_step(cfg: ModelConfig, *, weight_decay: float = 0.0, retract_every: int = 1):
    """Alg. 1. ``retract_every`` is an ablation knob (DESIGN.md): the paper
    retracts after every step; the coordinator can also run the exported
    ``retract`` artifact on its own cadence when this is 0."""

    def train_step(params, opt, tokens, lr_dense, lr_spectral):
        loss, grads = jax.value_and_grad(model.loss_fn)(params, tokens, cfg)
        params, opt = optim.adamw_update(
            params, grads, opt, lr_dense, lr_spectral, weight_decay=weight_decay
        )
        if retract_every:
            params = optim.retract_params(params, use_pallas=cfg.use_pallas)
        return params, opt, loss

    return train_step


def make_train_chunk(cfg: ModelConfig, k: int, *, weight_decay: float = 0.0):
    """K training steps fused into one HLO via lax.scan.

    The PJRT shim returns step outputs as a single host tuple, so a chunked
    step amortizes the host<->device state round-trip and dispatch overhead
    by K — the rust driver's default hot path (EXPERIMENTS.md §Perf).
    Semantics are identical to K calls of `train_step` (retraction after
    every optimizer step, per the paper's Algorithm 1).

    tokens: (k, batch, seq+1) i32; returns (params, opt, losses[k]).
    """
    step = make_train_step(cfg, weight_decay=weight_decay)

    def train_chunk(params, opt, tokens, lr_dense, lr_spectral):
        def body(carry, tok):
            params, opt = carry
            params, opt, loss = step(params, opt, tok, lr_dense, lr_spectral)
            return (params, opt), loss

        (params, opt), losses = jax.lax.scan(body, (params, opt), tokens)
        return params, opt, losses

    return train_chunk


def make_eval_step(cfg: ModelConfig):
    def eval_step(params, tokens):
        return model.loss_fn(params, tokens, cfg)

    return eval_step


def make_forward(cfg: ModelConfig):
    def forward(params, tokens):
        return model.forward(params, tokens, cfg)

    return forward


def make_retract(cfg: ModelConfig):
    def retract(params):
        return optim.retract_params(params, use_pallas=cfg.use_pallas)

    return retract


def make_ortho_check(cfg: ModelConfig):
    def ortho_check(params):
        return model.ortho_error_all(params)

    return ortho_check


def example_inputs(cfg: ModelConfig):
    """ShapeDtypeStructs used to lower each artifact (and recorded in the
    manifest so the rust session wires buffers positionally)."""
    params = jax.eval_shape(lambda: model.init_params(jax.random.PRNGKey(0), cfg))
    opt = jax.eval_shape(optim.init_opt_state, params)
    tokens = jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len + 1), jnp.int32)
    scalar = jax.ShapeDtypeStruct((), jnp.float32)
    seed = jax.ShapeDtypeStruct((), jnp.int32)
    return params, opt, tokens, scalar, seed
