"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here, written in
the most obvious jnp form. pytest (``python/tests/``) asserts allclose
between kernel and oracle across a hypothesis sweep of shapes/dtypes; the
oracles are also what the L2 model uses when ``use_pallas=False`` (the
default for the big AOT artifacts, since interpret-mode Pallas inside a
multi-layer training graph would be pointlessly slow on CPU — the math is
identical, which is exactly what the kernel-vs-ref tests prove).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def spectral_matmul(x: jax.Array, u: jax.Array, s: jax.Array, v: jax.Array) -> jax.Array:
    """Paper Eq. (2)-(4): ``y = ((x @ U) * s) @ V^T``.

    x: (..., m), u: (m, k), s: (k,), v: (n, k)  ->  (..., n).

    The dense matrix ``W = U diag(s) V^T`` is never formed; cost is
    O(b*k*(m+n)) instead of O(b*m*n).
    """
    h = x @ u  # (..., k)
    hs = h * s  # (..., k)
    return hs @ v.T  # (..., n)


def silu(x: jax.Array) -> jax.Array:
    return x * jax.nn.sigmoid(x)


def spectral_swiglu(x, gate, up, down):
    """SCT SwiGLU MLP: ``down(silu(gate(x)) * up(x))`` with all three
    projections in spectral form (the paper converts gate_proj, up_proj and
    down_proj of every MLP block).

    x: (..., d); ``gate``/``up`` are (U, s, V) factor triples mapping d -> f,
    ``down`` maps f -> d.
    """
    g = spectral_matmul(x, *gate)
    u_ = spectral_matmul(x, *up)
    h = silu(g) * u_
    return spectral_matmul(h, *down)


def qr_retract(a: jax.Array) -> jax.Array:
    """Paper Eq. (5): Stiefel retraction ``Q, R = qr(A); Q * sign(diag(R))``.

    The sign correction makes diag(R) positive, which selects the unique QR
    factorization with positive diagonal — keeping the retraction continuous
    across steps (Householder QR is only defined up to column signs).
    """
    q, r = jnp.linalg.qr(a, mode="reduced")
    d = jnp.sign(jnp.diagonal(r))
    d = jnp.where(d == 0, jnp.ones_like(d), d).astype(a.dtype)
    return q * d[None, :]


def qr_retract_cgs(a: jax.Array, eps: float = 1e-30) -> jax.Array:
    """Graph-safe Stiefel retraction: CGS2 (classical Gram-Schmidt, twice)
    in pure jnp.

    Numerically equivalent to :func:`qr_retract` (CGS2's R has a positive
    diagonal by construction, so the sign fix is built in), but lowers to
    native HLO (while-loop + dots). ``jnp.linalg.qr`` lowers to a LAPACK
    custom-call on CPU that the runtime's XLA 0.5.1 cannot compile, so every
    *exported* graph (train_step, retract, init) uses this path; the
    LAPACK version remains the pytest oracle.
    """
    m, k = a.shape
    a32 = a.astype(jnp.float32)

    def body(j, q):
        v = jax.lax.dynamic_slice(a32, (0, j), (m, 1))
        v = v - q @ (q.T @ v)
        v = v - q @ (q.T @ v)  # reorthogonalize: "twice is enough"
        r_jj = jnp.sqrt(jnp.sum(v * v))
        qj = v / jnp.maximum(r_jj, eps)
        return jax.lax.dynamic_update_slice(q, qj, (0, j))

    q = jax.lax.fori_loop(0, k, body, jnp.zeros_like(a32))
    return q.astype(a.dtype)


def ortho_error(q: jax.Array) -> jax.Array:
    """``max |Q^T Q - I|`` — the paper reports < 2e-6 after retraction."""
    k = q.shape[-1]
    return jnp.max(jnp.abs(q.T @ q - jnp.eye(k, dtype=q.dtype)))
