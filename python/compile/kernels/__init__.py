"""L1 — Pallas kernels for SCT (interpret=True on CPU; see each module's
docstring for the real-TPU mapping) plus their pure-jnp oracles."""

from . import ref  # noqa: F401
from .qr_retract import qr_retract  # noqa: F401
from .spectral_matmul import spectral_matmul  # noqa: F401
from .spectral_swiglu import spectral_swiglu  # noqa: F401
