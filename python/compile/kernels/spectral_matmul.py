"""Pallas kernel for the SCT spectral linear hot path.

Computes ``y = ((x @ U) * s) @ V^T`` — paper Eq. (2)-(4) — without ever
materializing ``W = U diag(s) V^T``.

TPU mapping (DESIGN.md §Hardware-Adaptation)
--------------------------------------------
The paper's CUDA view ("three small GEMMs") becomes, on TPU:

* The **factors are VMEM-resident**: ``k(m+n+1)`` floats is tiny (70B MLP at
  k=32: 4.7 MB), so ``U``, ``s``, ``V`` use BlockSpecs whose index_map is
  constant — Pallas keeps one copy in VMEM for the whole grid instead of
  re-streaming from HBM per tile. This is the kernel-level expression of the
  paper's core memory argument.
* The **rows stream**: the grid walks (row-tiles, n-tiles); each program
  computes a ``(bm, k)`` intermediate on the MXU, applies the ``* s`` scaling
  as a register-level epilogue (no third op), then the second MXU pass
  against the ``(bn, k)`` V-tile.
* ``h = x @ U`` depends only on the row tile, so it is computed in the
  ``j == 0`` program of each row and cached in a VMEM scratch buffer for the
  remaining n-tiles (the standard Pallas revisiting-grid idiom); the
  alternative (recompute per n-tile) costs an extra (#n-tiles - 1) MXU passes
  over U.

On CPU this runs under ``interpret=True`` (Mosaic custom-calls cannot execute
on the CPU PJRT plugin); correctness vs ``ref.spectral_matmul`` is the
pytest/hypothesis signal.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, u_ref, s_ref, v_ref, o_ref, h_ref, *, n_tiles: int):
    """One (row-tile, n-tile) program.

    x_ref: (bm, m)   row tile (full reduction dim)
    u_ref: (m, k)    whole U, VMEM-resident
    s_ref: (k,)      whole s
    v_ref: (bn, k)   V tile for this n-tile
    o_ref: (bm, bn)  output tile
    h_ref: (bm, k)   VMEM scratch: cached (x@U)*s for the current row tile
    """
    j = pl.program_id(1)

    # First n-tile of each row tile computes the shared rank-space projection
    # once; later n-tiles reuse it from scratch (grid iterates j fastest).
    @pl.when(j == 0)
    def _compute_h():
        h = jnp.dot(x_ref[...], u_ref[...], preferred_element_type=jnp.float32)
        h_ref[...] = (h * s_ref[...][None, :]).astype(h_ref.dtype)

    # Second MXU pass: (bm, k) x (k, bn).
    o_ref[...] = jnp.dot(
        h_ref[...], v_ref[...].T, preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)
    del n_tiles  # structural constant, kept for cost documentation


def _pick_block(dim: int, target: int) -> int:
    """Largest divisor of ``dim`` that is <= target (prefer exact tiling —
    interpret mode zero-pads partial blocks, and on real TPU ragged edges
    waste MXU lanes)."""
    b = min(dim, target)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("block_rows", "block_n"))
def spectral_matmul(
    x: jax.Array,
    u: jax.Array,
    s: jax.Array,
    v: jax.Array,
    *,
    block_rows: int = 128,
    block_n: int = 256,
) -> jax.Array:
    """``y = ((x @ U) * s) @ V^T`` as a Pallas call.

    x: (..., m), u: (m, k), s: (k,), v: (n, k) -> (..., n).
    Leading dims of ``x`` are flattened into a row dimension.
    """
    m, k = u.shape
    n = v.shape[0]
    lead = x.shape[:-1]
    rows = 1
    for d in lead:
        rows *= d
    x2 = x.reshape(rows, m)

    bm = _pick_block(rows, block_rows)
    bn = _pick_block(n, block_n)
    grid = (rows // bm, n // bn)

    out = pl.pallas_call(
        functools.partial(_kernel, n_tiles=grid[1]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, m), lambda i, j: (i, 0)),  # x: row tile
            pl.BlockSpec((m, k), lambda i, j: (0, 0)),  # U: pinned
            pl.BlockSpec((k,), lambda i, j: (0,)),  # s: pinned
            pl.BlockSpec((bn, k), lambda i, j: (j, 0)),  # V: n tile
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rows, n), x.dtype),
        # VMEM scratch on real TPU (pltpu.VMEM); memory-space-agnostic here so
        # the interpret path stays backend-neutral.
        scratch_shapes=[pl.MemorySpace.ANY((bm, k), jnp.float32)],
        interpret=True,
    )(x2, u, s, v)
    return out.reshape(*lead, n)


def vmem_bytes(m: int, n: int, k: int, bm: int = 128, bn: int = 256, itemsize: int = 4) -> int:
    """Estimated VMEM working set of one program — used by the perf notes in
    EXPERIMENTS.md §Perf (interpret-mode wallclock is not a TPU proxy, the
    footprint is what we can reason about)."""
    x_tile = bm * m
    factors = m * k + k + bn * k
    h = bm * k
    o = bm * bn
    return (x_tile + factors + h + o) * itemsize
