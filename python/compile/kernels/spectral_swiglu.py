"""Fused Pallas kernel for the SCT SwiGLU MLP block.

The paper converts the three MLP projections of every transformer layer
(gate_proj, up_proj, down_proj) to spectral form. Done naively that is nine
skinny GEMMs with two (rows x ffn) intermediates round-tripping through HBM.
This kernel fuses the whole block per row-tile:

    y = spectral_down( silu(spectral_gate(x)) * spectral_up(x) )

TPU mapping (DESIGN.md §Hardware-Adaptation)
--------------------------------------------
* All six factor matrices + three singular-value vectors are VMEM-pinned
  (constant index_map): total ``k(2d + 4f + 3)`` floats — for the paper's
  70B MLP at k=32 that is ~14 MB of factors *replacing* 235M dense weights.
* Grid walks row tiles only. The (bm, f) SwiGLU intermediate lives in the
  program's registers/VMEM and never reaches HBM — this is the fusion the
  paper's CUDA implementation gets from torch.compile, expressed with
  BlockSpecs.
* Six MXU passes per tile: x@Ug, *@Vg^T, x@Uu, *@Vu^T, h@Ud, *@Vd^T, with
  the diag(s) scalings folded into epilogues.

Runs under interpret=True on CPU; oracle: ``ref.spectral_swiglu``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _silu(x):
    return x * (1.0 / (1.0 + jnp.exp(-x)))


def _kernel(
    x_ref,
    ug_ref, sg_ref, vg_ref,
    uu_ref, su_ref, vu_ref,
    ud_ref, sd_ref, vd_ref,
    o_ref,
):
    x = x_ref[...]
    f32 = jnp.float32

    def spec(xv, u_ref, s_ref, v_ref):
        h = jnp.dot(xv, u_ref[...], preferred_element_type=f32)
        h = h * s_ref[...][None, :]
        return jnp.dot(h, v_ref[...].T, preferred_element_type=f32)

    g = spec(x, ug_ref, sg_ref, vg_ref)  # (bm, f)
    u = spec(x, uu_ref, su_ref, vu_ref)  # (bm, f)
    h = _silu(g) * u                     # fused SwiGLU intermediate, VMEM-only
    y = spec(h, ud_ref, sd_ref, vd_ref)  # (bm, d)
    o_ref[...] = y.astype(o_ref.dtype)


def _pick_block(dim: int, target: int) -> int:
    b = min(dim, target)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("block_rows",))
def spectral_swiglu(
    x: jax.Array,
    gate: tuple[jax.Array, jax.Array, jax.Array],
    up: tuple[jax.Array, jax.Array, jax.Array],
    down: tuple[jax.Array, jax.Array, jax.Array],
    *,
    block_rows: int = 128,
) -> jax.Array:
    """Fused SCT SwiGLU MLP. x: (..., d) -> (..., d).

    ``gate``/``up``: (U: (d,k), s: (k,), V: (f,k)); ``down``: (U: (f,k),
    s: (k,), V: (d,k)).
    """
    d = x.shape[-1]
    lead = x.shape[:-1]
    rows = 1
    for dd in lead:
        rows *= dd
    x2 = x.reshape(rows, d)
    bm = _pick_block(rows, block_rows)

    pinned = lambda *shape: pl.BlockSpec(shape, lambda i: tuple(0 for _ in shape))
    specs = [pl.BlockSpec((bm, d), lambda i: (i, 0))]
    for (u, s, v) in (gate, up, down):
        specs += [pinned(*u.shape), pinned(*s.shape), pinned(*v.shape)]

    out = pl.pallas_call(
        _kernel,
        grid=(rows // bm,),
        in_specs=specs,
        out_specs=pl.BlockSpec((bm, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=True,
    )(x2, *gate, *up, *down)
    return out.reshape(*lead, d)


def vmem_bytes(d: int, f: int, k: int, bm: int = 128, itemsize: int = 4) -> int:
    """VMEM working-set estimate per program (perf notes, EXPERIMENTS.md)."""
    factors = 2 * (d * k + k + f * k) + (f * k + k + d * k)
    tiles = bm * d * 2 + bm * f * 2  # x & y tiles + g/u intermediates
    return (factors + tiles) * itemsize
