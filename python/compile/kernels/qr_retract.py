"""Pallas kernel for the Stiefel QR retraction (paper Eq. 5, Alg. 1 l.5-7).

After every AdamW step the factors U (m,k) and V (n,k) are retracted back to
the Stiefel manifold:  ``Q, R = qr(A);  A <- Q * sign(diag(R))``.

The paper's implementation calls cuSOLVER. TPUs have no QR unit, so we
re-derive the retraction for the MXU (DESIGN.md §Hardware-Adaptation):

* k is small (32-256): the k x k Gram/projection matrices fit trivially in
  VMEM, and the m dimension streams.
* We use **CGS2** — classical Gram-Schmidt applied twice — which is rich in
  (m,k)x(k,k) GEMMs (MXU-friendly) and whose "twice is enough" reorthogonal-
  ization drives ||Q^T Q - I|| to machine epsilon, comfortably below the
  paper's 2e-6 threshold.
* CGS2 produces R with a *positive* diagonal by construction (r_jj = ||v||),
  so the paper's sign(diag(R)) correction is the identity here — the kernel
  output equals Householder-QR-plus-sign-fix exactly in exact arithmetic,
  which is what the hypothesis tests assert numerically.

The column loop runs inside one program (grid=()) over VMEM-resident values;
for the 70B factor shapes (8192x32 = 1 MB) the whole matrix fits in VMEM.
Oracle: ``ref.qr_retract`` (jnp.linalg.qr + sign fix).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, o_ref, *, k: int, eps: float):
    a = a_ref[...].astype(jnp.float32)  # (m, k)
    m = a.shape[0]

    def body(j, q):
        v = jax.lax.dynamic_slice(a, (0, j), (m, 1))  # (m, 1)
        # CGS2: project out the already-built columns twice. Columns >= j of
        # q are still zero, so the masked full-width GEMM is exact.
        c1 = q.T @ v  # (k, 1)
        v = v - q @ c1
        c2 = q.T @ v
        v = v - q @ c2
        r_jj = jnp.sqrt(jnp.sum(v * v))
        # Rank-deficiency guard: a zero residual column becomes a zero column
        # (caller re-completes the basis); eps keeps the division finite.
        qj = v / jnp.maximum(r_jj, eps)
        return jax.lax.dynamic_update_slice(q, qj, (0, j))

    q = jax.lax.fori_loop(0, k, body, jnp.zeros_like(a))
    o_ref[...] = q.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps",))
def qr_retract(a: jax.Array, *, eps: float = 1e-30) -> jax.Array:
    """Retract ``a`` (m, k), m >= k, onto the Stiefel manifold via CGS2 QR.

    Returns Q with orthonormal columns and span(Q) = span(a), matching
    ``ref.qr_retract`` (QR with positive-diagonal R).
    """
    m, k = a.shape
    return pl.pallas_call(
        functools.partial(_kernel, k=k, eps=eps),
        out_shape=jax.ShapeDtypeStruct((m, k), a.dtype),
        interpret=True,
    )(a)
