"""Model/run presets for the AOT exporter.

The paper's testbeds (SmolLM2-1.7B on an A100, a 70B-class architecture on a
Steam Deck) are hardware-gated here, so artifacts are exported at CPU-scale
presets with the *same architecture family* (RoPE attention, RMSNorm, SwiGLU
MLP with spectral gate/up/down). The paper's exact layer shapes
(SmolLM2-135M/360M/1.7B, LLaMA-7B, Qwen-27B, LLaMA-70B) live in the rust
analytic memory model (`rust/src/memmodel/presets.rs`), which regenerates
Tables 1-2 / Figure 1 at the true dimensions.

Rank mapping for the scaled rank sweep (Table 3): the paper sweeps
k in {32, 64, 128, 256} on d=2048/ffn=8192; at the `sweep` preset
(d=128/ffn=384) the ranks {8, 16, 32, 64} occupy the same relative band
(k/min(m,n) from ~6% to ~50%), so the qualitative claims — every rank hits
the same loss floor, dense sits below, memory and step time fall with k —
are probed at matched compression ratios.
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture + batch geometry for one exported artifact set."""

    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ffn: int
    seq_len: int
    batch: int
    # None -> dense MLP baseline; otherwise the spectral rank k for the MLP
    # projections (attention/embeddings/norms stay dense, as in the paper).
    rank: Optional[int] = None
    # route the MLP through the Pallas kernels instead of the jnp oracle
    # (interpret mode: correct everywhere, fast nowhere — used for the
    # kernel-path integration artifact).
    use_pallas: bool = False
    tie_embeddings: bool = True

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Exact trainable-parameter count (matches model.init_params)."""
        d, f, v = self.d_model, self.d_ffn, self.vocab
        attn = 4 * d * d
        if self.rank is None:
            mlp = 3 * d * f
        else:
            k = self.rank
            mlp = 2 * (d * k + k + f * k) + (f * k + k + d * k)
        per_layer = attn + mlp + 2 * d  # + two RMSNorm gains
        head = 0 if self.tie_embeddings else d * v
        return v * d + self.n_layers * per_layer + d + head

    def to_json_dict(self) -> dict:
        return dataclasses.asdict(self)


def _base(name, vocab, d, layers, heads, ffn, seq, batch, **kw) -> ModelConfig:
    return ModelConfig(
        name=name, vocab=vocab, d_model=d, n_layers=layers, n_heads=heads,
        d_ffn=ffn, seq_len=seq, batch=batch, **kw,
    )


#: Presets exported by `python -m compile.aot`. Keys are artifact-set names.
PRESETS: dict[str, ModelConfig] = {}


def _register(cfg: ModelConfig) -> ModelConfig:
    PRESETS[cfg.name] = cfg
    return cfg


# --- tiny: unit/integration tests, finetune-integrity experiment (Table 4) --
for k in (4, 8, 16, 32):
    _register(_base(f"tiny_r{k}", 256, 64, 2, 4, 192, 64, 4, rank=k))
_register(_base("tiny_dense", 256, 64, 2, 4, 192, 64, 4, rank=None))
# Pallas-kernel-path variant: proves the interpret-lowered kernel HLO runs
# end-to-end from rust (forward only; see aot.py).
_register(_base("tiny_r8_pallas", 256, 64, 2, 4, 192, 64, 4, rank=8, use_pallas=True))

# --- sweep: the scaled Table 3 / Fig 2 / Fig 3 rank sweep ------------------
for k in (8, 16, 32, 64):
    _register(_base(f"sweep_r{k}", 512, 128, 4, 4, 384, 128, 4, rank=k))
_register(_base("sweep_dense", 512, 128, 4, 4, 384, 128, 4, rank=None))

# --- e2e: the end-to-end pretraining driver (examples/pretrain_e2e.rs) -----
# ~28M params — the "100M-class" driver scaled to what XLA-CPU trains in
# minutes; same structure as the paper's SmolLM2 testbed.
_register(_base("e2e_r64", 8192, 384, 6, 6, 1024, 128, 4, rank=64))
_register(_base("e2e_dense", 8192, 384, 6, 6, 1024, 128, 4, rank=None))


def get(name: str) -> ModelConfig:
    try:
        return PRESETS[name]
    except KeyError:
        raise KeyError(f"unknown preset {name!r}; have {sorted(PRESETS)}") from None
