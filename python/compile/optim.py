"""AdamW with per-component learning-rate groups + Stiefel QR retraction.

Paper Alg. 1: AdamW on (U, s, V) followed by QR retraction of U and V. The
paper's §4.3/§5 analysis blames its dense-vs-SCT gap on using one global LR
for both the spectral factors and the (much larger) dense attention stack;
its stated "clear next step" is per-component scheduling. We implement that
here: every leaf is classified as *spectral* or *dense* and the train step
takes two LR scalars — the rust coordinator drives both schedules and can
tie them together to reproduce the paper's single-LR configuration exactly.

LRs enter the HLO as runtime scalars (not baked constants), so one artifact
serves any schedule.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .kernels import ref

Tree = Any

# --------------------------------------------------------------------------
# leaf classification
# --------------------------------------------------------------------------


def path_str(path) -> str:
    """'params/layers/0/mlp/gate/u'-style name for a tree_util key path."""
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def is_spectral_leaf(path) -> bool:
    """Spectral factors are exactly the u/s/v leaves under an mlp block."""
    s = path_str(path)
    return "/mlp/" in s and s.rsplit("/", 1)[-1] in ("u", "s", "v")


def is_factor_leaf(path) -> bool:
    """U/V factors (retracted); excludes the singular values s."""
    s = path_str(path)
    return "/mlp/" in s and s.rsplit("/", 1)[-1] in ("u", "v")


def _no_decay(path, leaf) -> bool:
    # Norm gains, singular values and embeddings are exempt from weight
    # decay (decaying s shrinks the whole operator norm; decaying U/V is
    # meaningless under retraction).
    s = path_str(path)
    return leaf.ndim <= 1 or s.endswith("embed") or ("/mlp/" in s and s.endswith(("u", "v")))


# --------------------------------------------------------------------------
# AdamW
# --------------------------------------------------------------------------


def init_opt_state(params: Tree) -> dict:
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {
        "m": zeros,
        "v": jax.tree_util.tree_map(jnp.zeros_like, params),
        "t": jnp.zeros((), jnp.int32),
    }


def adamw_update(
    params: Tree,
    grads: Tree,
    opt: dict,
    lr_dense: jax.Array,
    lr_spectral: jax.Array,
    *,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
):
    """One decoupled-weight-decay Adam step with two LR groups.

    Returns (new_params, new_opt). Pure; lowered into the train_step HLO.
    """
    t = opt["t"] + 1
    tf = t.astype(jnp.float32)
    bc1 = 1.0 - b1**tf
    bc2 = 1.0 - b2**tf

    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(params)
    grads_flat = treedef.flatten_up_to(grads)
    m_flat = treedef.flatten_up_to(opt["m"])
    v_flat = treedef.flatten_up_to(opt["v"])

    new_p, new_m, new_v = [], [], []
    for (path, p), g, m, v in zip(paths_leaves, grads_flat, m_flat, v_flat):
        lr = lr_spectral if is_spectral_leaf(path) else lr_dense
        m = b1 * m + (1.0 - b1) * g
        v = b2 * v + (1.0 - b2) * (g * g)
        update = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        if weight_decay and not _no_decay(path, p):
            update = update + weight_decay * p
        new_p.append(p - lr * update)
        new_m.append(m)
        new_v.append(v)

    return (
        jax.tree_util.tree_unflatten(treedef, new_p),
        {
            "m": jax.tree_util.tree_unflatten(treedef, new_m),
            "v": jax.tree_util.tree_unflatten(treedef, new_v),
            "t": t,
        },
    )


# --------------------------------------------------------------------------
# retraction
# --------------------------------------------------------------------------


def retract_params(params: Tree, use_pallas: bool = False) -> Tree:
    """Alg. 1 lines 5-7: QR-retract every U and V factor onto the Stiefel
    manifold (positive-diagonal QR; see kernels.qr_retract)."""
    if use_pallas:
        from .kernels.qr_retract import qr_retract as retract
    else:
        # Graph-safe CGS2 — NOT jnp.linalg.qr, which lowers to a LAPACK
        # custom-call the runtime XLA cannot compile (see kernels.ref).
        retract = ref.qr_retract_cgs

    def fix(path, leaf):
        return retract(leaf) if is_factor_leaf(path) else leaf

    return jax.tree_util.tree_map_with_path(fix, params)
