"""Build-time Python for SCT: L1 Pallas kernels, L2 JAX model/optimizer, and
the AOT exporter. Never imported at runtime — the rust binary only consumes
artifacts/*.hlo.txt + manifest.json produced by `python -m compile.aot`."""
