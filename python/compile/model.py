"""L2 — the transformer LM whose MLP layers are SpectralLinear.

Architecture mirrors the paper's SmolLM2/LLaMA testbed family: RMSNorm,
rotary-position attention, SwiGLU MLP. Exactly as in the paper (§4.2), only
the MLP projections (gate/up/down) are spectral; attention projections,
embeddings and norms remain dense.

The module is functional: parameters are a nested-dict pytree and every
entry point is a pure function of (params, inputs), so `aot.py` can lower
whole training steps to single HLO modules.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import spectral
from .configs import ModelConfig
from .kernels import ref
from .kernels.spectral_matmul import spectral_matmul as pallas_spectral_matmul
from .kernels.spectral_swiglu import spectral_swiglu as pallas_spectral_swiglu

# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------


def init_params(key: jax.Array, cfg: ModelConfig) -> dict:
    """Initialize the full parameter pytree for ``cfg``.

    Dense inits are Glorot-normal; spectral triples use
    :func:`spectral.init_spectral` (orthonormal factors, variance-matched
    singular values) so dense and spectral runs start at the same activation
    scale.
    """
    d, f, v = cfg.d_model, cfg.d_ffn, cfg.vocab
    keys = iter(jax.random.split(key, 8 + 16 * cfg.n_layers))

    def glorot(m, n):
        sigma = jnp.sqrt(2.0 / (m + n))
        return sigma * jax.random.normal(next(keys), (m, n), jnp.float32)

    def mlp_params() -> dict:
        if cfg.rank is None:
            return {"gate": glorot(d, f), "up": glorot(d, f), "down": glorot(f, d)}
        k = cfg.rank
        return {
            "gate": spectral.init_spectral(next(keys), d, f, k),
            "up": spectral.init_spectral(next(keys), d, f, k),
            "down": spectral.init_spectral(next(keys), f, d, k),
        }

    layers = []
    for _ in range(cfg.n_layers):
        layers.append(
            {
                "attn": {
                    "wq": glorot(d, d),
                    "wk": glorot(d, d),
                    "wv": glorot(d, d),
                    "wo": glorot(d, d),
                },
                "mlp": mlp_params(),
                "ln1": jnp.ones((d,), jnp.float32),
                "ln2": jnp.ones((d,), jnp.float32),
            }
        )
    params = {
        "embed": 0.02 * jax.random.normal(next(keys), (v, d), jnp.float32),
        "layers": layers,
        "ln_f": jnp.ones((d,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["head"] = glorot(d, v)
    return params


# --------------------------------------------------------------------------
# building blocks
# --------------------------------------------------------------------------


def rmsnorm(x: jax.Array, gain: jax.Array, eps: float = 1e-6) -> jax.Array:
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * gain


def _rope_tables(seq: int, head_dim: int):
    half = head_dim // 2
    inv = 1.0 / (10000.0 ** (jnp.arange(half, dtype=jnp.float32) / half))
    t = jnp.arange(seq, dtype=jnp.float32)
    ang = jnp.outer(t, inv)  # (seq, half)
    return jnp.cos(ang), jnp.sin(ang)


def _apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, H, T, hd); rotate pairs (x1, x2) by position-dependent angles."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = cos[None, None, :, :]
    s = sin[None, None, :, :]
    return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)


def attention(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Standard causal multi-head attention with RoPE. Dense projections —
    the paper leaves attention dense (§4.2); extending SCT to q/k/v/o is its
    §5 future work and is exercised separately in the ablation configs."""
    b, t, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim

    def proj(w):
        return (x @ w).reshape(b, t, h, hd).transpose(0, 2, 1, 3)

    q, k, v = proj(p["wq"]), proj(p["wk"]), proj(p["wv"])
    cos, sin = _rope_tables(t, hd)
    q, k = _apply_rope(q, cos, sin), _apply_rope(k, cos, sin)

    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(hd))
    mask = jnp.tril(jnp.ones((t, t), bool))
    scores = jnp.where(mask[None, None], scores, jnp.finfo(scores.dtype).min)
    att = jax.nn.softmax(scores, axis=-1)
    y = jnp.einsum("bhqk,bhkd->bhqd", att, v)
    y = y.transpose(0, 2, 1, 3).reshape(b, t, d)
    return y @ p["wo"]


def mlp(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """SwiGLU MLP — spectral (SCT) or dense depending on the config."""
    if cfg.rank is None:
        return (ref.silu(x @ p["gate"]) * (x @ p["up"])) @ p["down"]
    tri = lambda q: (q["u"], q["s"], q["v"])
    if cfg.use_pallas:
        return pallas_spectral_swiglu(x, tri(p["gate"]), tri(p["up"]), tri(p["down"]))
    return ref.spectral_swiglu(x, tri(p["gate"]), tri(p["up"]), tri(p["down"]))


def spectral_linear(p: dict, x: jax.Array, use_pallas: bool = False) -> jax.Array:
    """Single spectral projection (exported standalone for kernel tests)."""
    fn = pallas_spectral_matmul if use_pallas else ref.spectral_matmul
    return fn(x, p["u"], p["s"], p["v"])


# --------------------------------------------------------------------------
# model
# --------------------------------------------------------------------------


def forward(params: dict, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    """tokens: (B, T) int32 -> logits (B, T, vocab)."""
    x = params["embed"][tokens]
    for layer in params["layers"]:
        x = x + attention(layer["attn"], rmsnorm(x, layer["ln1"]), cfg)
        x = x + mlp(layer["mlp"], rmsnorm(x, layer["ln2"]), cfg)
    x = rmsnorm(x, params["ln_f"])
    head = params["head"] if "head" in params else params["embed"].T
    return x @ head


def loss_fn(params: dict, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Causal LM cross-entropy. tokens: (B, T+1); inputs are tokens[:, :-1],
    targets tokens[:, 1:] — the batch is a single i32 tensor on the wire so
    the rust data pipeline feeds one buffer per step."""
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    logits = forward(params, inp, cfg)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


def ortho_error_all(params: dict) -> jax.Array:
    """Max orthonormality error over every spectral factor in the model —
    the paper's Table 2 'Ortho. Error' metric (< 2e-6)."""
    errs = [jnp.asarray(0.0, jnp.float32)]
    for layer in params["layers"]:
        for name in ("gate", "up", "down"):
            p = layer["mlp"][name]
            if isinstance(p, dict):
                errs.append(ref.ortho_error(p["u"]))
                errs.append(ref.ortho_error(p["v"]))
    return jnp.stack(errs).max()
