"""L2 model tests: shapes, loss behaviour, spectral==dense at full rank."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, model, optim, spectral, train

TINY = configs.get("tiny_r8")
TINY_DENSE = configs.get("tiny_dense")


def toks(cfg, seed=0, plus_one=True):
    rng = np.random.default_rng(seed)
    t = cfg.seq_len + (1 if plus_one else 0)
    return jnp.asarray(rng.integers(0, cfg.vocab, (cfg.batch, t)), jnp.int32)


@pytest.mark.parametrize("cfg", [TINY, TINY_DENSE], ids=["spectral", "dense"])
def test_forward_shapes(cfg):
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    logits = model.forward(params, toks(cfg, plus_one=False), cfg)
    assert logits.shape == (cfg.batch, cfg.seq_len, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("cfg", [TINY, TINY_DENSE], ids=["spectral", "dense"])
def test_param_count_matches_config(cfg):
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    n = sum(int(np.prod(p.shape)) for p in jax.tree_util.tree_leaves(params))
    assert n == cfg.param_count()


def test_initial_loss_near_uniform():
    """Fresh model should score ~log(vocab) — catches init-scale bugs."""
    params = model.init_params(jax.random.PRNGKey(1), TINY)
    loss = float(model.loss_fn(params, toks(TINY), TINY))
    expect = float(jnp.log(TINY.vocab))
    assert abs(loss - expect) < 1.0, f"{loss} vs log(vocab)={expect}"


def test_causality():
    """Changing future tokens must not change past logits."""
    cfg = TINY
    params = model.init_params(jax.random.PRNGKey(2), cfg)
    t = toks(cfg, plus_one=False)
    t2 = t.at[:, -1].set((t[:, -1] + 1) % cfg.vocab)
    l1 = model.forward(params, t, cfg)
    l2 = model.forward(params, t2, cfg)
    # All positions except the last must be identical.
    assert float(jnp.max(jnp.abs(l1[:, :-1] - l2[:, :-1]))) == 0.0
    assert float(jnp.max(jnp.abs(l1[:, -1] - l2[:, -1]))) > 0.0


def test_loss_decreases_under_training():
    cfg = TINY
    step = jax.jit(train.make_train_step(cfg))
    params, opt = jax.jit(train.make_init(cfg))(jnp.int32(0))
    batch = toks(cfg, 3)
    losses = []
    for _ in range(12):
        params, opt, loss = step(params, opt, batch, jnp.float32(1e-3), jnp.float32(5e-3))
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.2


def test_training_keeps_factors_orthonormal():
    cfg = TINY
    step = jax.jit(train.make_train_step(cfg))
    params, opt = jax.jit(train.make_init(cfg))(jnp.int32(1))
    for i in range(5):
        params, opt, _ = step(params, opt, toks(cfg, i), jnp.float32(1e-3), jnp.float32(5e-3))
    err = float(model.ortho_error_all(params))
    assert err < 2e-6, f"paper threshold violated: {err}"


def test_without_retraction_factors_drift():
    """Ablation: skipping retraction lets U drift off the manifold — the
    reason Alg. 1 retracts every step."""
    cfg = TINY
    step = jax.jit(train.make_train_step(cfg, retract_every=0))
    params, opt = jax.jit(train.make_init(cfg))(jnp.int32(1))
    for i in range(5):
        params, opt, _ = step(params, opt, toks(cfg, i), jnp.float32(1e-3), jnp.float32(5e-2))
    err = float(model.ortho_error_all(params))
    assert err > 2e-6, f"expected drift without retraction, got {err}"


def test_full_rank_spectral_matches_dense_forward():
    """At k=min(m,n), a spectral layer converted from dense weights computes
    the same function as the dense layer."""
    cfg_d = TINY_DENSE
    cfg_s = TINY.with_(rank=64)  # d_model=64, f=192 -> full rank = 64
    params = model.init_params(jax.random.PRNGKey(4), cfg_d)
    # convert each MLP to spectral at full rank
    sp = jax.tree_util.tree_map(lambda x: x, params)
    for layer in sp["layers"]:
        m_ = layer["mlp"]
        layer["mlp"] = {
            "gate": spectral.from_dense(m_["gate"], 64),
            "up": spectral.from_dense(m_["up"], 64),
            "down": spectral.from_dense(m_["down"], 64),
        }
    t = toks(cfg_d, plus_one=False)
    ld = model.forward(params, t, cfg_d)
    ls = model.forward(sp, t, cfg_s)
    rel = float(jnp.max(jnp.abs(ld - ls))) / (float(jnp.max(jnp.abs(ld))) + 1e-6)
    assert rel < 1e-3


def test_train_chunk_equals_step_loop():
    cfg = TINY
    k = 3
    chunk = jax.jit(train.make_train_chunk(cfg, k))
    step = jax.jit(train.make_train_step(cfg))
    p0, o0 = jax.jit(train.make_init(cfg))(jnp.int32(5))
    batches = jnp.stack([toks(cfg, i) for i in range(k)])
    lr_d, lr_s = jnp.float32(1e-3), jnp.float32(5e-3)

    pc, oc, losses_c = chunk(p0, o0, batches, lr_d, lr_s)
    p, o = p0, o0
    losses_l = []
    for i in range(k):
        p, o, l = step(p, o, batches[i], lr_d, lr_s)
        losses_l.append(float(l))
    np.testing.assert_allclose(np.asarray(losses_c), np.asarray(losses_l), rtol=1e-5)
    # final params identical too
    diff = jax.tree_util.tree_map(lambda a, b: float(jnp.max(jnp.abs(a - b))), pc, p)
    assert max(jax.tree_util.tree_leaves(diff)) < 1e-5


def test_pallas_config_matches_ref_forward():
    """use_pallas=True routes the MLP through the interpret-mode kernels —
    same numbers as the jnp oracle path."""
    cfg_ref = TINY
    cfg_pal = configs.get("tiny_r8_pallas")
    params = model.init_params(jax.random.PRNGKey(6), cfg_ref)
    t = toks(cfg_ref, plus_one=False)
    lr = model.forward(params, t, cfg_ref)
    lp = model.forward(params, t, cfg_pal)
    rel = float(jnp.max(jnp.abs(lr - lp))) / (float(jnp.max(jnp.abs(lr))) + 1e-6)
    assert rel < 1e-4
