"""Optimizer tests: AdamW mechanics, LR groups, retraction wiring."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import configs, model, optim

CFG = configs.get("tiny_r8")


def make_state(seed=0):
    params = model.init_params(jax.random.PRNGKey(seed), CFG)
    return params, optim.init_opt_state(params)


def uniform_grads(params, value=0.01):
    return jax.tree_util.tree_map(lambda p: jnp.full_like(p, value), params)


def test_opt_state_shapes_mirror_params():
    params, opt = make_state()
    jax.tree_util.tree_map(
        lambda p, m: (_ for _ in ()).throw(AssertionError) if p.shape != m.shape else None,
        params,
        opt["m"],
    )
    assert int(opt["t"]) == 0


def test_step_counter_increments():
    params, opt = make_state()
    g = uniform_grads(params)
    _, opt = optim.adamw_update(params, g, opt, jnp.float32(1e-3), jnp.float32(1e-3))
    assert int(opt["t"]) == 1
    _, opt = optim.adamw_update(params, g, opt, jnp.float32(1e-3), jnp.float32(1e-3))
    assert int(opt["t"]) == 2


def test_first_step_is_signed_lr():
    """With bias correction, step 1 update is ~lr * sign(g)."""
    params, opt = make_state()
    g = uniform_grads(params, 0.5)
    lr = 1e-3
    new_params, _ = optim.adamw_update(params, g, opt, jnp.float32(lr), jnp.float32(lr))
    diff = jax.tree_util.tree_map(lambda a, b: np.asarray(a - b), new_params, params)
    for leaf in jax.tree_util.tree_leaves(diff):
        np.testing.assert_allclose(-leaf, lr, rtol=1e-3)


def test_lr_groups_route_correctly():
    """Spectral leaves move with lr_spectral, dense leaves with lr_dense."""
    params, opt = make_state()
    g = uniform_grads(params, 1.0)
    new_params, _ = optim.adamw_update(params, g, opt, jnp.float32(0.0), jnp.float32(1e-2))
    # dense leaf unchanged
    d0 = np.asarray(new_params["layers"][0]["attn"]["wq"] - params["layers"][0]["attn"]["wq"])
    assert np.abs(d0).max() == 0.0
    # spectral leaf moved by ~1e-2
    s0 = np.asarray(
        new_params["layers"][0]["mlp"]["gate"]["u"] - params["layers"][0]["mlp"]["gate"]["u"]
    )
    np.testing.assert_allclose(-s0, 1e-2, rtol=1e-3)


def test_single_lr_reproduces_paper_config():
    """lr_dense == lr_spectral must equal a single-group AdamW step (the
    paper's configuration)."""
    params, opt = make_state(1)
    g = jax.tree_util.tree_map(
        lambda p: 0.01 * jnp.ones_like(p) * (1 + jnp.arange(p.size).reshape(p.shape) % 3),
        params,
    )
    lr = jnp.float32(3e-3)
    a, _ = optim.adamw_update(params, g, opt, lr, lr)
    # re-run with groups swapped: same because both lrs equal
    b, _ = optim.adamw_update(params, g, opt, lr, lr)
    for la, lb in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        assert float(jnp.max(jnp.abs(la - lb))) == 0.0


def test_weight_decay_only_on_decayable():
    params, opt = make_state(2)
    g = uniform_grads(params, 0.0)  # zero grads isolate the decay term
    new_params, _ = optim.adamw_update(
        params, g, opt, jnp.float32(1e-2), jnp.float32(1e-2), weight_decay=0.1
    )
    # attention matrix decays
    w0 = np.asarray(params["layers"][0]["attn"]["wq"])
    w1 = np.asarray(new_params["layers"][0]["attn"]["wq"])
    np.testing.assert_allclose(w1, w0 * (1 - 1e-2 * 0.1), rtol=1e-5)
    # norm gain, embeddings, U/V factors must NOT decay
    for name, (a, b) in {
        "ln1": (params["layers"][0]["ln1"], new_params["layers"][0]["ln1"]),
        "embed": (params["embed"], new_params["embed"]),
        "u": (params["layers"][0]["mlp"]["gate"]["u"], new_params["layers"][0]["mlp"]["gate"]["u"]),
    }.items():
        assert float(jnp.max(jnp.abs(a - b))) == 0.0, f"{name} should not decay"


def test_retract_params_only_touches_factors():
    params, _ = make_state(3)
    # perturb a factor off the manifold
    params["layers"][0]["mlp"]["gate"]["u"] = params["layers"][0]["mlp"]["gate"]["u"] * 1.7
    before_embed = np.asarray(params["embed"]).copy()
    before_s = np.asarray(params["layers"][0]["mlp"]["gate"]["s"]).copy()
    out = optim.retract_params(params)
    # factor re-orthonormalized
    u = out["layers"][0]["mlp"]["gate"]["u"]
    k = u.shape[1]
    err = float(jnp.max(jnp.abs(u.T @ u - jnp.eye(k))))
    assert err < 2e-6
    # everything else untouched
    assert np.array_equal(before_embed, np.asarray(out["embed"]))
    assert np.array_equal(before_s, np.asarray(out["layers"][0]["mlp"]["gate"]["s"]))


def test_leaf_classification():
    params, _ = make_state(4)
    leaves = jax.tree_util.tree_flatten_with_path(params)[0]
    spectral = [optim.path_str(p) for p, _ in leaves if optim.is_spectral_leaf(p)]
    factors = [optim.path_str(p) for p, _ in leaves if optim.is_factor_leaf(p)]
    # 2 layers x 3 triples x 3 tensors
    assert len(spectral) == 18
    # 2 layers x 3 triples x 2 factors
    assert len(factors) == 12
    assert all(s.endswith(("u", "s", "v")) for s in spectral)
    assert not any(s.endswith("/s") for s in factors)
