"""Spectral parameterization: init statistics, dense conversion, rank math."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import spectral
from compile.kernels import ref

settings.register_profile("ci", deadline=None, max_examples=20)
settings.load_profile("ci")


@given(m=st.integers(4, 64), n=st.integers(4, 64), k=st.integers(1, 16), seed=st.integers(0, 10_000))
def test_init_spectral_orthonormal(m, n, k, seed):
    k = min(k, m, n)
    tri = spectral.init_spectral(jax.random.PRNGKey(seed), m, n, k)
    assert tri["u"].shape == (m, k)
    assert tri["v"].shape == (n, k)
    assert tri["s"].shape == (k,)
    assert float(spectral.ortho_error(tri)) < 2e-6


def test_init_spectral_variance_matches_glorot():
    """||W||_F^2 of the implied dense matrix ~ Glorot's m*n*2/(m+n),
    independent of rank — the property that makes cross-rank loss curves
    comparable (paper §4.2 uses one LR across ranks)."""
    m, n = 96, 160
    target = m * n * 2.0 / (m + n)
    for k in (2, 8, 32):
        tri = spectral.init_spectral(jax.random.PRNGKey(k), m, n, k)
        w = spectral.to_dense(tri)
        fro2 = float(jnp.sum(w * w))
        assert abs(fro2 - target) / target < 1e-4, f"k={k}: {fro2} vs {target}"


@given(m=st.integers(6, 48), n=st.integers(6, 48), seed=st.integers(0, 10_000))
def test_from_dense_full_rank_exact(m, n, seed):
    """k = min(m,n) reconstructs W exactly (up to f32 SVD error)."""
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(m, n)), jnp.float32)
    tri = spectral.from_dense(w, min(m, n))
    w2 = spectral.to_dense(tri)
    assert float(jnp.max(jnp.abs(w - w2))) < 1e-4


def test_from_dense_truncation_is_best_approx():
    """Eckart-Young sanity: rank-k SVD error <= error of any cruder rank."""
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(32, 48)), jnp.float32)
    errs = []
    for k in (2, 4, 8, 16, 32):
        tri = spectral.from_dense(w, k)
        errs.append(float(jnp.linalg.norm(w - spectral.to_dense(tri))))
    assert errs == sorted(errs, reverse=True)


def test_from_dense_pads_beyond_rank():
    """Requesting k > rank(W) zero-pads without changing W."""
    rng = np.random.default_rng(1)
    low = jnp.asarray(rng.normal(size=(20, 3)), jnp.float32) @ jnp.asarray(
        rng.normal(size=(3, 24)), jnp.float32
    )
    tri = spectral.from_dense(low, 10)
    assert tri["s"].shape == (10,)
    assert float(jnp.max(jnp.abs(spectral.to_dense(tri) - low))) < 1e-3
    assert float(spectral.ortho_error(tri)) < 5e-6
    # padded singular values are ~0
    assert float(jnp.max(jnp.abs(tri["s"][3:]))) < 1e-3


@given(seed=st.integers(0, 10_000))
def test_energy_rank_monotone(seed):
    rng = np.random.default_rng(seed)
    s = jnp.sort(jnp.abs(jnp.asarray(rng.normal(size=(32,)), jnp.float32)))[::-1]
    r50 = spectral.energy_rank(s, 0.50)
    r95 = spectral.energy_rank(s, 0.95)
    r999 = spectral.energy_rank(s, 0.999)
    assert 1 <= r50 <= r95 <= r999 <= 32


def test_energy_rank_exact_cases():
    s = jnp.asarray([2.0, 1.0, 0.0, 0.0])
    # energies: 4/5, 5/5 -> 80% needs 1, 95% needs 2
    assert spectral.energy_rank(s, 0.79) == 1
    assert spectral.energy_rank(s, 0.95) == 2


def test_pad_rank_preserves_dense_and_ortho():
    tri = spectral.init_spectral(jax.random.PRNGKey(0), 24, 36, 4)
    w = spectral.to_dense(tri)
    padded = spectral.pad_rank(tri, 12, jax.random.PRNGKey(1))
    assert padded["s"].shape == (12,)
    assert float(jnp.max(jnp.abs(spectral.to_dense(padded) - w))) < 1e-5
    assert float(spectral.ortho_error(padded)) < 5e-6


def test_spectral_size_formula():
    # Paper §3: LLaMA-70B MLP at k=32 -> 1.18M vs 234.9M params.
    assert spectral.spectral_size(8192, 28672, 32) == 32 * (8192 + 28672 + 1)
    ratio = (8192 * 28672) / spectral.spectral_size(8192, 28672, 32)
    assert 198 < ratio < 200  # the paper's 199x


@pytest.mark.parametrize("m,n,k", [(16, 16, 4), (64, 32, 8)])
def test_forward_through_triple_matches_dense(m, n, k):
    """x @ W == spectral_matmul(x, U, s, V) when W = U diag(s) V^T."""
    rng = np.random.default_rng(2)
    tri = spectral.init_spectral(jax.random.PRNGKey(3), m, n, k)
    x = jnp.asarray(rng.normal(size=(8, m)), jnp.float32)
    dense = x @ spectral.to_dense(tri)
    fact = ref.spectral_matmul(x, tri["u"], tri["s"], tri["v"])
    assert float(jnp.max(jnp.abs(dense - fact))) < 1e-4
