"""AOT exporter tests: manifest structure, HLO text invariants.

These run the lowering in-process on the tiny preset (seconds) — they do not
require `make artifacts` to have been run.
"""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, configs, train


@pytest.fixture(scope="module")
def exported(tmp_path_factory):
    root = tmp_path_factory.mktemp("artifacts")
    entry = aot.export_preset(configs.get("tiny_r4"), str(root), chunk_k=2)
    return root, entry


def test_manifest_entry_structure(exported):
    _, entry = exported
    assert entry["n_state"] == entry["n_params"] * 3 + 1  # m, v mirrors + t
    assert len(entry["state"]) == entry["n_state"]
    for name in ("init", "train_step", "train_chunk", "eval_step", "forward",
                 "retract", "ortho_check"):
        assert name in entry["artifacts"], name


def test_hlo_files_exist_and_are_text(exported):
    root, entry = exported
    for art in entry["artifacts"].values():
        path = os.path.join(root, "tiny_r4", art["file"])
        with open(path) as f:
            head = f.read(200)
        assert "HloModule" in head, f"{path} does not look like HLO text"


def test_no_unsupported_custom_calls(exported):
    """The runtime XLA (0.5.1) rejects typed-FFI custom calls (LAPACK QR,
    threefry). Exported HLO must contain none."""
    root, entry = exported
    for name, art in entry["artifacts"].items():
        path = os.path.join(root, "tiny_r4", art["file"])
        text = open(path).read()
        assert "custom-call" not in text, f"{name} contains a custom call"


def test_train_step_io_contract(exported):
    """First n_state inputs and outputs are the same tensors in the same
    order; the final output is the scalar loss."""
    _, entry = exported
    ts = entry["artifacts"]["train_step"]
    n = entry["n_state"]
    in_state = [(t["dtype"], tuple(t["shape"])) for t in ts["inputs"][:n]]
    out_state = [(t["dtype"], tuple(t["shape"])) for t in ts["outputs"][:n]]
    assert in_state == out_state
    assert ts["inputs"][n]["name"] == "tokens"
    assert ts["outputs"][-1]["shape"] == []
    # state list matches the train_step prefix
    st = [(t["dtype"], tuple(t["shape"])) for t in entry["state"]]
    assert st == in_state


def test_init_outputs_match_state(exported):
    _, entry = exported
    init = entry["artifacts"]["init"]
    assert [tuple(t["shape"]) for t in init["outputs"]] == [
        tuple(t["shape"]) for t in entry["state"]
    ]


def test_forward_takes_inputs_without_target_column(exported):
    _, entry = exported
    cfg = configs.get("tiny_r4")
    fwd = entry["artifacts"]["forward"]
    tok = [t for t in fwd["inputs"] if t["name"] == "tokens"][0]
    assert tok["shape"] == [cfg.batch, cfg.seq_len]
    assert fwd["outputs"][0]["shape"] == [cfg.batch, cfg.seq_len, cfg.vocab]


def test_example_inputs_consistency():
    cfg = configs.get("tiny_r4")
    params, opt, tokens, scalar, seed = train.example_inputs(cfg)
    n_params = len(jax.tree_util.tree_leaves(params))
    n_opt = len(jax.tree_util.tree_leaves(opt))
    assert tokens.shape == (cfg.batch, cfg.seq_len + 1)
    assert tokens.dtype == jnp.int32
    assert n_opt == 2 * n_params + 1


def test_pallas_preset_skips_grad_artifacts(tmp_path):
    entry = aot.export_preset(configs.get("tiny_r8_pallas"), str(tmp_path), chunk_k=2)
    assert "train_step" not in entry["artifacts"]
    assert "forward" in entry["artifacts"]
    assert "retract" in entry["artifacts"]


def test_manifest_json_roundtrips(exported):
    _, entry = exported
    text = json.dumps({"format": 1, "presets": {"tiny_r4": entry}})
    back = json.loads(text)
    assert back["presets"]["tiny_r4"]["n_state"] == entry["n_state"]
