"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes (including ragged / non-power-of-two dims) and value
scales; every kernel must match its oracle to tight tolerance. This is THE
correctness signal for the kernel layer — the AOT model graphs use the same
math via ref.py, so kernel==ref ties all three layers together.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.qr_retract import qr_retract
from compile.kernels.spectral_matmul import spectral_matmul, vmem_bytes
from compile.kernels.spectral_swiglu import spectral_swiglu

settings.register_profile("ci", deadline=None, max_examples=25)
settings.load_profile("ci")


def rand(rng, *shape, scale=1.0):
    return jnp.asarray(rng.normal(size=shape) * scale, jnp.float32)


def rel_err(a, b):
    denom = float(jnp.max(jnp.abs(b))) + 1e-6
    return float(jnp.max(jnp.abs(a - b))) / denom


# ---------------------------------------------------------------------------
# spectral_matmul
# ---------------------------------------------------------------------------


@given(
    rows=st.integers(1, 33),
    m=st.integers(1, 96),
    n=st.integers(1, 96),
    k=st.integers(1, 32),
    seed=st.integers(0, 2**31 - 1),
)
def test_spectral_matmul_matches_ref(rows, m, n, k, seed):
    rng = np.random.default_rng(seed)
    x, u = rand(rng, rows, m), rand(rng, m, k)
    s, v = rand(rng, k), rand(rng, n, k)
    got = spectral_matmul(x, u, s, v)
    want = ref.spectral_matmul(x, u, s, v)
    assert got.shape == (rows, n)
    assert rel_err(got, want) < 1e-5


@given(
    b=st.integers(1, 4),
    t=st.integers(1, 9),
    m=st.integers(1, 48),
    seed=st.integers(0, 2**31 - 1),
)
def test_spectral_matmul_leading_dims(b, t, m, seed):
    """3-D inputs (batch, seq, features) flatten and reshape correctly."""
    rng = np.random.default_rng(seed)
    k, n = 4, 24
    x, u = rand(rng, b, t, m), rand(rng, m, k)
    s, v = rand(rng, k), rand(rng, n, k)
    got = spectral_matmul(x, u, s, v)
    want = ref.spectral_matmul(x, u, s, v)
    assert got.shape == (b, t, n)
    assert rel_err(got, want) < 1e-5


@pytest.mark.parametrize("block_rows,block_n", [(1, 1), (2, 7), (128, 256), (8, 16)])
def test_spectral_matmul_block_shape_invariance(block_rows, block_n):
    """The result must not depend on the tiling — pure schedule change."""
    rng = np.random.default_rng(0)
    x, u = rand(rng, 16, 32), rand(rng, 32, 8)
    s, v = rand(rng, 8), rand(rng, 56, 8)
    base = ref.spectral_matmul(x, u, s, v)
    got = spectral_matmul(x, u, s, v, block_rows=block_rows, block_n=block_n)
    assert rel_err(got, base) < 1e-5


def test_spectral_matmul_large_values():
    """No catastrophic cancellation with big magnitudes (f32 accumulate)."""
    rng = np.random.default_rng(1)
    x, u = rand(rng, 8, 64, scale=100.0), rand(rng, 64, 16)
    s, v = rand(rng, 16, scale=10.0), rand(rng, 48, 16)
    assert rel_err(spectral_matmul(x, u, s, v), ref.spectral_matmul(x, u, s, v)) < 1e-4


def test_vmem_estimate_is_positive_and_monotonic():
    a = vmem_bytes(512, 512, 32)
    b = vmem_bytes(1024, 1024, 32)
    assert 0 < a < b


# ---------------------------------------------------------------------------
# spectral_swiglu
# ---------------------------------------------------------------------------


def make_mlp_factors(rng, d, f, k):
    gate = (rand(rng, d, k), jnp.abs(rand(rng, k)), rand(rng, f, k))
    up = (rand(rng, d, k), jnp.abs(rand(rng, k)), rand(rng, f, k))
    down = (rand(rng, f, k), jnp.abs(rand(rng, k)), rand(rng, d, k))
    return gate, up, down


@given(
    rows=st.integers(1, 17),
    d=st.integers(4, 48),
    f=st.integers(4, 64),
    k=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_spectral_swiglu_matches_ref(rows, d, f, k, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, rows, d)
    gate, up, down = make_mlp_factors(rng, d, f, k)
    got = spectral_swiglu(x, gate, up, down)
    want = ref.spectral_swiglu(x, gate, up, down)
    assert got.shape == (rows, d)
    assert rel_err(got, want) < 2e-5


def test_spectral_swiglu_3d_input():
    rng = np.random.default_rng(2)
    x = rand(rng, 2, 5, 32)
    gate, up, down = make_mlp_factors(rng, 32, 96, 8)
    got = spectral_swiglu(x, gate, up, down)
    want = ref.spectral_swiglu(x, gate, up, down)
    assert got.shape == (2, 5, 32)
    assert rel_err(got, want) < 2e-5


def test_swiglu_equals_composed_spectral_matmuls():
    """The fused kernel == three separate kernel calls + elementwise glue."""
    rng = np.random.default_rng(3)
    d, f, k = 24, 72, 6
    x = rand(rng, 8, d)
    gate, up, down = make_mlp_factors(rng, d, f, k)
    fused = spectral_swiglu(x, gate, up, down)
    g = spectral_matmul(x, *gate)
    u = spectral_matmul(x, *up)
    composed = spectral_matmul(ref.silu(g) * u, *down)
    assert rel_err(fused, composed) < 2e-5


# ---------------------------------------------------------------------------
# qr_retract
# ---------------------------------------------------------------------------


@given(
    m=st.integers(2, 96),
    k=st.integers(1, 24),
    seed=st.integers(0, 2**31 - 1),
)
def test_qr_retract_matches_lapack_oracle(m, k, seed):
    if k > m:
        k = m
    rng = np.random.default_rng(seed)
    a = rand(rng, m, k)
    got = qr_retract(a)
    want = ref.qr_retract(a)
    assert rel_err(got, want) < 5e-4  # sign-fixed QR is unique; CGS2 vs Householder


@given(m=st.integers(2, 128), k=st.integers(1, 32), seed=st.integers(0, 2**31 - 1))
def test_qr_retract_orthonormality(m, k, seed):
    """Paper Table 2: ortho error < 2e-6."""
    if k > m:
        k = m
    rng = np.random.default_rng(seed)
    q = qr_retract(rand(rng, m, k))
    assert float(ref.ortho_error(q)) < 2e-6


@given(m=st.integers(4, 64), k=st.integers(1, 16), seed=st.integers(0, 2**31 - 1))
def test_qr_retract_preserves_span(m, k, seed):
    """span(Q) == span(A): A must be exactly representable as Q (Q^T A)."""
    if k > m:
        k = m
    rng = np.random.default_rng(seed)
    a = rand(rng, m, k)
    q = qr_retract(a)
    recon = q @ (q.T @ a)
    assert rel_err(recon, a) < 1e-4


def test_qr_retract_identity_on_orthonormal():
    """Retraction of an already-orthonormal matrix is the identity."""
    rng = np.random.default_rng(4)
    q0 = ref.qr_retract(rand(rng, 40, 8))
    q1 = qr_retract(q0)
    assert rel_err(q1, q0) < 1e-5


def test_qr_retract_positive_diagonal():
    """R = Q^T A must have a positive diagonal (the paper's sign fix)."""
    rng = np.random.default_rng(5)
    a = rand(rng, 32, 8)
    q = qr_retract(a)
    r = q.T @ a
    assert bool(jnp.all(jnp.diagonal(r) > 0))


def test_graph_safe_cgs_matches_oracle():
    """ref.qr_retract_cgs (used inside every exported graph) == LAPACK path."""
    rng = np.random.default_rng(6)
    for m, k in [(16, 4), (64, 16), (128, 32), (7, 7)]:
        a = rand(rng, m, k)
        assert rel_err(ref.qr_retract_cgs(a), ref.qr_retract(a)) < 5e-4
        assert float(ref.ortho_error(ref.qr_retract_cgs(a))) < 2e-6
