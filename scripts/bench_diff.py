#!/usr/bin/env python3
"""Compare two bench JSON documents (base vs PR) field by field.

Usage: bench_diff.py BASE.json PR.json

Flattens every numeric leaf to a dotted path (array entries keyed by their
"rank"/"mode" fields when present, else by index) and prints a base/PR/delta
table. Advisory output only — it never fails the build; the point is a
readable perf trajectory in the CI log instead of archive-only artifacts.
"""

import json
import sys


def key_for(item, idx):
    if isinstance(item, dict):
        parts = [str(item[k]) for k in ("rank", "mode") if k in item]
        if parts:
            return "/".join(parts)
    return str(idx)


def flatten(node, prefix=""):
    out = {}
    if isinstance(node, bool):
        return out
    if isinstance(node, (int, float)):
        out[prefix.rstrip(".")] = float(node)
    elif isinstance(node, dict):
        for k, v in node.items():
            out.update(flatten(v, f"{prefix}{k}."))
    elif isinstance(node, list):
        for i, v in enumerate(node):
            out.update(flatten(v, f"{prefix}[{key_for(v, i)}]."))
    return out


def main():
    if len(sys.argv) != 3:
        print(__doc__, file=sys.stderr)
        return 2
    with open(sys.argv[1]) as f:
        base = flatten(json.load(f))
    with open(sys.argv[2]) as f:
        pr = flatten(json.load(f))

    keys = sorted(set(base) | set(pr))
    width = max((len(k) for k in keys), default=10)
    print(f"{'metric':<{width}}  {'base':>12}  {'pr':>12}  {'delta':>8}")
    for k in keys:
        b, p = base.get(k), pr.get(k)
        if b is None or p is None:
            print(f"{k:<{width}}  {b if b is not None else '-':>12}  "
                  f"{p if p is not None else '-':>12}  {'new' if b is None else 'gone':>8}")
            continue
        delta = f"{(p - b) / b * 100.0:+7.1f}%" if b else "    n/a"
        print(f"{k:<{width}}  {b:>12.3f}  {p:>12.3f}  {delta:>8}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
