#!/usr/bin/env python3
"""Compare two bench JSON documents (base vs PR) field by field.

Usage: bench_diff.py BASE.json PR.json [--gate SUBSTR:PCT ...]

Flattens every numeric leaf to a dotted path (array entries keyed by their
"rank"/"mode" fields when present, else by index) and prints a base/PR/delta
table. The table itself is advisory — a readable perf trajectory in the CI
log instead of archive-only artifacts.

Each `--gate SUBSTR:PCT` turns one slice of the diff into a hard regression
gate: every flattened key containing SUBSTR that exists in BOTH documents
must not drop by more than PCT percent (higher-is-better metrics, e.g.
GFLOP/s). Exit code 1 if any gated metric regresses past the threshold.
Keys present only in the PR doc are skipped with an advisory note, so the
PR that introduces a metric cannot fail its own gate.
"""

import json
import sys


def key_for(item, idx):
    if isinstance(item, dict):
        parts = [str(item[k]) for k in ("rank", "mode") if k in item]
        if parts:
            return "/".join(parts)
    return str(idx)


def flatten(node, prefix=""):
    out = {}
    if isinstance(node, bool):
        return out
    if isinstance(node, (int, float)):
        out[prefix.rstrip(".")] = float(node)
    elif isinstance(node, dict):
        for k, v in node.items():
            out.update(flatten(v, f"{prefix}{k}."))
    elif isinstance(node, list):
        for i, v in enumerate(node):
            out.update(flatten(v, f"{prefix}[{key_for(v, i)}]."))
    return out


def parse_gates(args):
    """['SUBSTR:PCT', ...] -> [(substr, pct), ...]; exits 2 on malformed."""
    gates = []
    for spec in args:
        substr, sep, pct = spec.rpartition(":")
        if not sep or not substr:
            print(f"bench_diff: bad --gate spec '{spec}' (want SUBSTR:PCT)",
                  file=sys.stderr)
            sys.exit(2)
        try:
            gates.append((substr, float(pct)))
        except ValueError:
            print(f"bench_diff: bad --gate threshold in '{spec}'",
                  file=sys.stderr)
            sys.exit(2)
    return gates


def apply_gates(gates, base, pr):
    """Returns the number of gate failures; prints a verdict per gated key."""
    failures = 0
    for substr, pct in gates:
        hits = sorted(k for k in pr if substr in k)
        if not hits:
            # Gate keyed on a metric the PR doc doesn't emit: that IS a
            # regression (the bench row was dropped), fail loudly.
            print(f"gate '{substr}': no matching metric in PR doc — FAIL")
            failures += 1
            continue
        for k in hits:
            if k not in base:
                print(f"gate '{substr}': {k} absent on base branch; "
                      "skipping (new metric)")
                continue
            b, p = base[k], pr[k]
            if b <= 0.0:
                print(f"gate '{substr}': {k} base value {b} not positive; "
                      "skipping")
                continue
            delta = (p - b) / b * 100.0
            verdict = "FAIL" if delta < -pct else "ok"
            print(f"gate '{substr}': {k} {b:.3f} -> {p:.3f} "
                  f"({delta:+.1f}%, floor -{pct:.0f}%) {verdict}")
            if delta < -pct:
                failures += 1
    return failures


def main():
    argv = sys.argv[1:]
    gate_specs = []
    while "--gate" in argv:
        i = argv.index("--gate")
        if i + 1 >= len(argv):
            print("bench_diff: --gate needs an argument", file=sys.stderr)
            return 2
        gate_specs.append(argv[i + 1])
        del argv[i:i + 2]
    if len(argv) != 2:
        print(__doc__, file=sys.stderr)
        return 2
    gates = parse_gates(gate_specs)
    with open(argv[0]) as f:
        base = flatten(json.load(f))
    with open(argv[1]) as f:
        pr = flatten(json.load(f))

    keys = sorted(set(base) | set(pr))
    width = max((len(k) for k in keys), default=10)
    print(f"{'metric':<{width}}  {'base':>12}  {'pr':>12}  {'delta':>8}")
    for k in keys:
        b, p = base.get(k), pr.get(k)
        if b is None or p is None:
            print(f"{k:<{width}}  {b if b is not None else '-':>12}  "
                  f"{p if p is not None else '-':>12}  {'new' if b is None else 'gone':>8}")
            continue
        delta = f"{(p - b) / b * 100.0:+7.1f}%" if b else "    n/a"
        print(f"{k:<{width}}  {b:>12.3f}  {p:>12.3f}  {delta:>8}")

    if gates:
        print()
        failures = apply_gates(gates, base, pr)
        if failures:
            print(f"bench_diff: {failures} gated metric(s) regressed")
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
