#!/usr/bin/env bash
# Compare the PR's bench JSONs against the base branch, in CI.
#
# Builds the base ref in a temporary git worktree (sharing the PR's cargo
# target dir so only changed crates rebuild), runs the same smoke benches
# there, and prints a field-by-field diff via scripts/bench_diff.py.
#
# Most diffs are advisory (a bench missing on the base branch is reported
# and skipped, not an error), with one hard gate: the kernels diff fails
# this script — and CI — if the single-thread rank-128 matmul GFLOP/s rows
# in BENCH_kernels.json regress more than 15% against the base branch
# (`bench_diff.py --gate`). The gate keys on the `].gflops` leaves only, so
# a wall-time improvement (ms dropping) can never trip it, and it skips
# metrics the base branch doesn't emit yet.
#
# Usage: scripts/bench_compare.sh [base-ref]   (default: origin/main)

set -euo pipefail
repo_root="$(cd "$(dirname "$0")/.." && pwd)"
base_ref="${1:-origin/main}"
cd "$repo_root"

git fetch origin "${base_ref#origin/}" --depth 1 2>/dev/null || true
if ! git rev-parse --verify --quiet "$base_ref" >/dev/null; then
    echo "bench_compare: base ref '$base_ref' not found; skipping comparison"
    exit 0
fi

worktree="$(mktemp -d)/base"
git worktree add --detach "$worktree" "$base_ref"
trap 'git worktree remove --force "$worktree" 2>/dev/null || true' EXIT

export CARGO_TARGET_DIR="$repo_root/rust/target"
# Same thread count as the PR-side tier1.sh bench run so the diff compares
# like with like (results are bit-identical; wall time is what's measured).
export SCT_THREADS="${SCT_THREADS:-2}"
for pair in serve_throughput:serve train_step:train rank_transition:rank kernel_scaling:kernels; do
    bench="${pair%%:*}"
    name="${pair##*:}"
    if (cd "$worktree/rust" && cargo bench --bench "$bench" -- --smoke \
            --json "$worktree/BENCH_$name.json"); then
        :
    else
        echo "bench_compare: bench '$bench' absent or failing on $base_ref; skipping"
    fi
done

gate_failed=0
for name in serve train rank kernels; do
    base_json="$worktree/BENCH_$name.json"
    pr_json="$repo_root/BENCH_$name.json"
    if [[ -f "$base_json" && -f "$pr_json" ]]; then
        echo
        echo "== BENCH_$name.json: $base_ref vs PR =="
        if [[ "$name" == kernels ]]; then
            # Hard gate: rank-128 single-thread GFLOP/s must not drop >15%.
            python3 "$repo_root/scripts/bench_diff.py" "$base_json" "$pr_json" \
                --gate "matmul_gflops@r128].gflops:15" || gate_failed=1
        else
            python3 "$repo_root/scripts/bench_diff.py" "$base_json" "$pr_json"
        fi
    else
        echo "bench_compare: BENCH_$name.json missing on one side; skipping"
    fi
done

if [[ "$gate_failed" -ne 0 ]]; then
    echo "bench_compare: kernel-regression gate failed"
    exit 1
fi
