#!/usr/bin/env bash
# Tier-1 gate: format check, lints (default AND pjrt feature surfaces),
# offline build + tests, and an optional serving bench smoke run.
#
# The default feature set is the pure-Rust stack (no PJRT); `--features pjrt`
# links the vendored xla stub. The pjrt surface is compile-checked AND
# clippy-linted (`--all-targets --features pjrt -- -D warnings`) so the
# stub-gated code stays warning-clean even though it is off by default.
# rustfmt/clippy stages are skipped with a notice when the components are
# not installed (minimal CI images); the build+test stage is mandatory.
#
# Usage: scripts/tier1.sh [all|lint|build|test|bench]
#   all    (default) lint + build + test
#   lint   rustfmt --check, clippy (default features), clippy (pjrt feature)
#   build  cargo build --release, cargo check --features pjrt
#   test   cargo test -q
#   bench  serve_throughput + train_step + rank_transition + kernel_scaling
#          in smoke mode, writing BENCH_serve.json, BENCH_train.json,
#          BENCH_rank.json and BENCH_kernels.json at the repo root (CI
#          uploads them and diffs them against the base branch via
#          scripts/bench_compare.sh). The serve bench also scrapes the
#          observability layer: BENCH_metrics.prom (GET /metrics dump,
#          checked for the mandatory serve/pool/http/spectral/health
#          series) and traces.jsonl (one span per request), then runs the
#          spectral-health smoke: a short native train with --spectra-out
#          (spectra.jsonl, uploaded by CI), `sct doctor` over the produced
#          checkpoint, and an injected-NaN watchdog run that must halt
#          with a non-zero exit and a counted anomaly. The kernel bench's
#          matmul_gflops rows (single-thread blocked-kernel GFLOP/s at
#          ranks 32 and 128, run even in smoke mode) feed the
#          kernel-regression gate in scripts/bench_compare.sh; this stage
#          checks both kernel JSONs record the detected SIMD feature set
#          ("simd" field) and echoes it so perf numbers are attributable
#          to the runner's ISA. Runs with SCT_THREADS=2 unless the caller
#          overrides it, so the parallel kernel paths are exercised in CI
#          (results are bit-identical at any thread count).

set -euo pipefail
repo_root="$(cd "$(dirname "$0")/.." && pwd)"
cd "$repo_root/rust"

stage="${1:-all}"

if ! command -v cargo >/dev/null 2>&1; then
    echo "tier1: cargo not found on PATH" >&2
    exit 127
fi

run_lint() {
    echo "== tier1: rustfmt =="
    if cargo fmt --version >/dev/null 2>&1; then
        cargo fmt --all -- --check
    else
        echo "tier1: rustfmt not installed, skipping format check"
    fi

    if cargo clippy --version >/dev/null 2>&1; then
        echo "== tier1: clippy (default features, -D warnings) =="
        cargo clippy --all-targets -- -D warnings
        echo "== tier1: clippy (pjrt feature, -D warnings) =="
        cargo clippy --all-targets --features pjrt -- -D warnings
    else
        echo "tier1: clippy not installed, skipping lints"
    fi
}

run_build() {
    echo "== tier1: build (release) =="
    cargo build --release

    echo "== tier1: compile check with pjrt feature (xla stub) =="
    cargo check --features pjrt
}

run_test() {
    echo "== tier1: tests =="
    cargo test -q
}

run_bench() {
    # Exercise the parallel kernel layer in CI (bit-identical results; only
    # wall time depends on this). Callers may override.
    export SCT_THREADS="${SCT_THREADS:-2}"
    echo "== tier1: bench smoke with SCT_THREADS=$SCT_THREADS =="

    echo "== tier1: serve bench smoke (BENCH_serve.json, gateway --workers 2) =="
    rm -f "$repo_root/traces.jsonl" # the trace sink appends; start clean
    cargo bench --bench serve_throughput -- --smoke \
        --workers 2 \
        --json "$repo_root/BENCH_serve.json" \
        --metrics-dump "$repo_root/BENCH_metrics.prom" \
        --trace-out "$repo_root/traces.jsonl"
    echo "tier1: wrote $repo_root/BENCH_serve.json"

    echo "== tier1: metrics scrape check (BENCH_metrics.prom) =="
    for series in \
        sct_serve_requests_total \
        sct_serve_completions_total \
        sct_serve_tokens_out_total \
        sct_serve_queue_depth \
        sct_serve_active_slots \
        sct_serve_queue_wait_ms \
        sct_serve_ttft_ms_bucket \
        sct_serve_decode_step_ms \
        sct_pool_fanouts_total \
        sct_pool_tasks_total \
        sct_http_requests_total \
        sct_spectral_energy \
        sct_spectral_tail_share \
        sct_spectral_effective_rank \
        sct_health_anomalies_total \
        sct_health_skipped_steps_total; do
        if ! grep -q "^$series" "$repo_root/BENCH_metrics.prom"; then
            echo "tier1: mandatory series $series missing from BENCH_metrics.prom" >&2
            exit 1
        fi
    done
    # Sharded serving: every per-worker scheduler labels its series, and the
    # --workers 2 run above must have registered both label sets.
    for series in \
        'sct_serve_requests_total{worker="0"}' \
        'sct_serve_requests_total{worker="1"}' \
        'sct_serve_tokens_out_total{worker="0"}' \
        'sct_serve_tokens_out_total{worker="1"}'; do
        if ! grep -qF "$series" "$repo_root/BENCH_metrics.prom"; then
            echo "tier1: mandatory worker-labeled series $series missing from BENCH_metrics.prom" >&2
            exit 1
        fi
    done
    if ! [ -s "$repo_root/traces.jsonl" ]; then
        echo "tier1: traces.jsonl missing or empty after serve bench" >&2
        exit 1
    fi
    echo "tier1: metrics + traces scrape OK"

    echo "== tier1: spectral-health smoke (spectra.jsonl + sct doctor + watchdog halt) =="
    smoke_dir="$repo_root/tier1_health_smoke"
    rm -rf "$smoke_dir" "$repo_root/spectra.jsonl"
    mkdir -p "$smoke_dir"
    # Short native train streaming spectral diagnostics; watchdog disarmed,
    # so this run also covers the zero-overhead-when-disabled path.
    cargo run -q --release --bin sct -- train --backend native \
        --steps 30 --batch 2 --seq-len 16 \
        --d-model 16 --layers 2 --heads 2 --ffn 24 --rank 4 --max-seq 32 \
        --out "$smoke_dir" --ckpt-dir "$smoke_dir/ckpt" --ckpt-every 10 \
        --spectra-out "$repo_root/spectra.jsonl" --spectra-every 10 \
        --log-level warn
    if ! [ -s "$repo_root/spectra.jsonl" ]; then
        echo "tier1: spectra.jsonl missing or empty after --spectra-out train" >&2
        exit 1
    fi
    for key in tail_share effective_rank condition ortho_u drift_u; do
        if ! grep -q "\"$key\"" "$repo_root/spectra.jsonl"; then
            echo "tier1: spectra.jsonl rows carry no $key field" >&2
            exit 1
        fi
    done
    ckpt="$(ls "$smoke_dir"/ckpt/step_*.sct | sort | tail -1)"
    cargo run -q --release --bin sct -- doctor "$ckpt" \
        --json "$smoke_dir/doctor.json" --log-level warn
    if ! grep -q '"tail_share"' "$smoke_dir/doctor.json"; then
        echo "tier1: sct doctor wrote no tail_share diagnostics" >&2
        exit 1
    fi
    # Injected-NaN watchdog run: MUST exit non-zero (halt policy) and flush
    # a final metrics record carrying the anomaly counter.
    if cargo run -q --release --bin sct -- train --backend native \
        --steps 20 --batch 2 --seq-len 16 \
        --d-model 16 --layers 2 --heads 2 --ffn 24 --rank 4 --max-seq 32 \
        --out "$smoke_dir/halt" \
        --metrics-out "$smoke_dir/metrics.jsonl" --metrics-every 100 \
        --watchdog halt --watchdog-inject-nan 7 --log-level error; then
        echo "tier1: watchdog halt run exited 0 (must be non-zero)" >&2
        exit 1
    fi
    if ! grep -q 'sct_health_anomalies_total' "$smoke_dir/metrics.jsonl"; then
        echo "tier1: anomaly counter missing from the halt run's metrics flush" >&2
        exit 1
    fi
    echo "tier1: spectral-health smoke OK"

    echo "== tier1: train bench smoke (BENCH_train.json) =="
    cargo bench --bench train_step -- --smoke --json "$repo_root/BENCH_train.json"
    echo "tier1: wrote $repo_root/BENCH_train.json"

    echo "== tier1: rank-transition bench smoke (BENCH_rank.json) =="
    cargo bench --bench rank_transition -- --smoke --json "$repo_root/BENCH_rank.json"
    echo "tier1: wrote $repo_root/BENCH_rank.json"

    echo "== tier1: kernel-scaling bench smoke (BENCH_kernels.json + BENCH_profile.json) =="
    cargo bench --bench kernel_scaling -- --smoke \
        --json "$repo_root/BENCH_kernels.json" \
        --profile-json "$repo_root/BENCH_profile.json"
    echo "tier1: wrote $repo_root/BENCH_kernels.json"

    # Both kernel JSONs must record the detected SIMD feature set so a
    # GFLOP/s delta in the regression gate is attributable to the runner.
    for bj in BENCH_kernels.json BENCH_profile.json; do
        if ! grep -q '"simd"' "$repo_root/$bj"; then
            echo "tier1: SIMD feature set missing from $bj" >&2
            exit 1
        fi
    done
    simd_label="$(grep -o '"simd": *"[^"]*"' "$repo_root/BENCH_kernels.json" | head -1)"
    echo "tier1: kernel bench SIMD feature set: ${simd_label:-unknown}"
    if ! grep -q 'matmul_gflops@r128' "$repo_root/BENCH_kernels.json"; then
        echo "tier1: rank-128 matmul_gflops rows missing from BENCH_kernels.json" >&2
        exit 1
    fi

    echo "== tier1: profiler roofline check (BENCH_profile.json) =="
    # The roofline pass must attribute work to every mandatory kernel; a
    # missing name means its instrumentation was dropped.
    for kernel in matmul attention_fwd attention_bwd adamw qr_retract; do
        if ! grep -q "\"kernel\": *\"$kernel\"" "$repo_root/BENCH_profile.json"; then
            echo "tier1: mandatory kernel $kernel missing from BENCH_profile.json" >&2
            exit 1
        fi
    done
    if ! [ -s "$repo_root/BENCH_profile.folded" ]; then
        echo "tier1: BENCH_profile.folded missing or empty after profile pass" >&2
        exit 1
    fi
    echo "tier1: profiler roofline OK"
}

case "$stage" in
    all)
        run_lint
        run_build
        run_test
        ;;
    lint) run_lint ;;
    build) run_build ;;
    test) run_test ;;
    bench) run_bench ;;
    *)
        echo "tier1: unknown stage '$stage' (use all|lint|build|test|bench)" >&2
        exit 2
        ;;
esac

echo "tier1 OK ($stage)"
