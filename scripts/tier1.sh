#!/usr/bin/env bash
# Tier-1 gate: format check, lints, offline build + tests.
#
# The default feature set is the pure-Rust stack (no PJRT); `--features pjrt`
# links the vendored xla stub and is compile-checked only (the stub errors at
# runtime by design). rustfmt/clippy stages are skipped with a notice when
# the components are not installed (minimal CI images); the build+test stage
# is mandatory.
#
# Usage: scripts/tier1.sh

set -euo pipefail
cd "$(dirname "$0")/../rust"

if ! command -v cargo >/dev/null 2>&1; then
    echo "tier1: cargo not found on PATH" >&2
    exit 127
fi

echo "== tier1: rustfmt =="
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --all -- --check
else
    echo "tier1: rustfmt not installed, skipping format check"
fi

echo "== tier1: clippy (-D warnings) =="
if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy --all-targets -- -D warnings
else
    echo "tier1: clippy not installed, skipping lints"
fi

echo "== tier1: build (release) =="
cargo build --release

echo "== tier1: compile check with pjrt feature (xla stub) =="
cargo check --features pjrt

echo "== tier1: tests =="
cargo test -q

echo "tier1 OK"
