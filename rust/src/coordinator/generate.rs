//! Text generation through the spectral model — the inference side of the
//! paper's claim that the dense matrix is never materialized "during
//! training or inference".
//!
//! Uses the AOT `forward` artifact (fixed (B, T) shape): the generator keeps
//! a rolling context window, runs the batch-row-0 forward, reads the logits
//! at the last real position, samples, and appends. When the context fills,
//! the window slides by half (positions are absolute RoPE, so a slide is a
//! re-encode, not a KV shift — the artifact has no KV cache; this is the
//! simple-and-correct baseline decoder).
//!
//! For KV-cached incremental decoding and batched serving, see
//! [`crate::serve`] — the sampler ([`SampleOpts`], [`sample_logits`]) is
//! shared with that engine so both paths sample identically.

use anyhow::{Context, Result};

use crate::data::Tokenizer;
#[cfg(feature = "pjrt")]
use crate::runtime::Session;
#[cfg(feature = "pjrt")]
use crate::util::rng::Rng;

pub use crate::serve::engine::{sample_logits, SampleOpts};

/// Native text generation — `sct generate --backend native`. Points the
/// CLI at the serving engine: prompt ids feed the per-sequence KV cache
/// ([`crate::serve::Engine::generate_kv`], the same incremental path the
/// HTTP server decodes on) and the shared sampler draws each token, so a
/// checkpoint trained by the native engine samples text with no PJRT and
/// no artifacts. The KV cache holds `max_seq` absolute positions and
/// `generate_kv` stops when it fills, so the prompt is clipped to its
/// trailing `max_seq - n_tokens` ids up front — the full `n_tokens` are
/// always produced (for `n_tokens >= max_seq` the prompt is clipped to one
/// token and the output is capped at what the window holds).
pub fn generate_text_native(
    engine: &crate::serve::Engine,
    tokenizer: &Tokenizer,
    prompt: &str,
    n_tokens: usize,
    opts: SampleOpts,
) -> Result<String> {
    let vocab = engine.cfg().vocab as i32;
    let mut ids: Vec<i32> =
        tokenizer.encode(prompt).into_iter().map(|t| t % vocab.max(1)).collect();
    let max_seq = engine.cfg().max_seq;
    let budget = max_seq.saturating_sub(n_tokens).max(1);
    if ids.len() > budget {
        ids = ids[ids.len() - budget..].to_vec();
    }
    if ids.is_empty() {
        ids.push(0); // generate_kv needs a seed token; 0 is the byte-level NUL
    }
    let mut kv = engine.new_kv(1);
    let slot = kv.alloc().context("fresh KV arena must have a free slot")?;
    let out = engine.generate_kv(&ids, n_tokens, &opts, &mut kv, slot);
    Ok(tokenizer.decode(&out))
}

#[cfg(feature = "pjrt")]
pub struct Generator<'s> {
    session: &'s mut Session,
    batch: usize,
    seq: usize,
    vocab: usize,
    rng: Rng,
    opts: SampleOpts,
}

#[cfg(feature = "pjrt")]
impl<'s> Generator<'s> {
    pub fn new(session: &'s mut Session, opts: SampleOpts) -> Result<Generator<'s>> {
        let fwd = session.preset.artifact("forward")?;
        let ti = fwd.input_index("tokens")?;
        let shape = fwd.inputs[ti].shape.clone();
        let vocab = session.preset.model.vocab;
        Ok(Generator {
            session,
            batch: shape[0],
            seq: shape[1],
            vocab,
            rng: Rng::new(opts.seed),
            opts,
        })
    }

    /// Generate `n_tokens` continuations of `prompt` token ids.
    pub fn generate(&mut self, prompt: &[i32], n_tokens: usize) -> Result<Vec<i32>> {
        let mut context: Vec<i32> = prompt.to_vec();
        anyhow::ensure!(!context.is_empty(), "prompt must not be empty");
        let mut out = Vec::with_capacity(n_tokens);
        for _ in 0..n_tokens {
            // window: last `seq` tokens, but if full, keep the back half so
            // consecutive steps reuse positions (cheap slide policy).
            if context.len() > self.seq {
                let keep = self.seq / 2;
                context = context[context.len() - keep..].to_vec();
            }
            let len = context.len().min(self.seq);
            // batch row 0 = context (padded to T with 0s); rows 1.. zeros.
            let mut tokens = vec![0i32; self.batch * self.seq];
            tokens[..len].copy_from_slice(&context[..len]);
            let (shape, logits) = self.session.forward(&tokens)?;
            debug_assert_eq!(shape, vec![self.batch, self.seq, self.vocab]);
            let row = &logits[(len - 1) * self.vocab..len * self.vocab];
            let next = sample_logits(row, self.opts.temperature, self.opts.top_k, &mut self.rng);
            out.push(next);
            context.push(next);
        }
        Ok(out)
    }
}

/// End-to-end convenience: tokenize a text prompt with the standard corpus
/// tokenizer, generate, decode.
#[cfg(feature = "pjrt")]
pub fn generate_text(
    session: &mut Session,
    tokenizer: &Tokenizer,
    prompt: &str,
    n_tokens: usize,
    opts: SampleOpts,
) -> Result<String> {
    let vocab = session.preset.model.vocab;
    let ids: Vec<i32> = tokenizer
        .encode(prompt)
        .into_iter()
        .map(|t| t % vocab as i32)
        .collect();
    let mut g = Generator::new(session, opts)?;
    let out = g.generate(&ids, n_tokens).context("generation failed")?;
    Ok(tokenizer.decode(&out))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::{Engine, EngineConfig, SpectralModel};

    fn tiny_engine() -> Engine {
        let cfg = EngineConfig {
            vocab: 256,
            d_model: 32,
            n_layers: 2,
            n_heads: 4,
            d_ffn: 48,
            rank: 4,
            max_seq: 32,
            tied: true,
        };
        Engine::new(SpectralModel::init(cfg, 3))
    }

    #[test]
    fn native_generation_is_deterministic_at_t0() {
        let engine = tiny_engine();
        let tok = Tokenizer::byte_level();
        let opts = SampleOpts { temperature: 0.0, top_k: 0, seed: 0 };
        let a = generate_text_native(&engine, &tok, "hello", 8, opts.clone()).unwrap();
        let b = generate_text_native(&engine, &tok, "hello", 8, opts).unwrap();
        assert_eq!(a, b, "temperature-0 native generation must be deterministic");
    }

    #[test]
    fn native_generation_handles_empty_and_long_prompts() {
        let engine = tiny_engine();
        let tok = Tokenizer::byte_level();
        let opts = SampleOpts { temperature: 0.0, top_k: 0, seed: 0 };
        // empty prompt: seeded with a NUL token instead of panicking
        generate_text_native(&engine, &tok, "", 4, opts.clone()).unwrap();
        // prompt longer than the KV window: clipped, still generates
        let long = "x".repeat(100);
        generate_text_native(&engine, &tok, &long, 4, opts).unwrap();
    }

    #[test]
    fn near_full_prompt_still_yields_all_requested_tokens() {
        // max_seq = 32; a 28-byte prompt with 8 requested tokens would
        // overflow the KV window unless the prompt is clipped up front —
        // the clip must leave room so the FULL request is produced.
        let engine = tiny_engine();
        let max_seq = engine.cfg().max_seq;
        let prompt: Vec<i32> = (0..max_seq as i32 - 4).map(|i| i % 50).collect();
        let budget = max_seq - 8;
        let clipped = &prompt[prompt.len() - budget..];
        let opts = SampleOpts { temperature: 0.0, top_k: 0, seed: 0 };
        let mut kv = engine.new_kv(1);
        let slot = kv.alloc().unwrap();
        let out = engine.generate_kv(clipped, 8, &opts, &mut kv, slot);
        assert_eq!(out.len(), 8, "clipped prompt must leave room for every requested token");
        // and the text-level wrapper applies exactly that clip
        let text: String = prompt.iter().map(|&t| (t as u8 + 65) as char).collect();
        let s =
            generate_text_native(&engine, &Tokenizer::byte_level(), &text, 8, opts).unwrap();
        assert!(!s.is_empty());
    }
}
