//! Text generation through the spectral model — the inference side of the
//! paper's claim that the dense matrix is never materialized "during
//! training or inference".
//!
//! Uses the AOT `forward` artifact (fixed (B, T) shape): the generator keeps
//! a rolling context window, runs the batch-row-0 forward, reads the logits
//! at the last real position, samples, and appends. When the context fills,
//! the window slides by half (positions are absolute RoPE, so a slide is a
//! re-encode, not a KV shift — the artifact has no KV cache; this is the
//! simple-and-correct baseline decoder).

use anyhow::{Context, Result};

use crate::data::Tokenizer;
use crate::runtime::Session;
use crate::util::rng::Rng;

/// Sampling configuration.
#[derive(Debug, Clone)]
pub struct SampleOpts {
    /// 0.0 => greedy argmax.
    pub temperature: f32,
    /// keep only the top-k logits before sampling (0 = all).
    pub top_k: usize,
    pub seed: u64,
}

impl Default for SampleOpts {
    fn default() -> SampleOpts {
        SampleOpts { temperature: 0.8, top_k: 40, seed: 0 }
    }
}

pub struct Generator<'s> {
    session: &'s mut Session,
    batch: usize,
    seq: usize,
    vocab: usize,
    rng: Rng,
    opts: SampleOpts,
}

impl<'s> Generator<'s> {
    pub fn new(session: &'s mut Session, opts: SampleOpts) -> Result<Generator<'s>> {
        let fwd = session.preset.artifact("forward")?;
        let ti = fwd.input_index("tokens")?;
        let shape = fwd.inputs[ti].shape.clone();
        let vocab = session.preset.model.vocab;
        Ok(Generator {
            session,
            batch: shape[0],
            seq: shape[1],
            vocab,
            rng: Rng::new(opts.seed),
            opts,
        })
    }

    /// Generate `n_tokens` continuations of `prompt` token ids.
    pub fn generate(&mut self, prompt: &[i32], n_tokens: usize) -> Result<Vec<i32>> {
        let mut context: Vec<i32> = prompt.to_vec();
        anyhow::ensure!(!context.is_empty(), "prompt must not be empty");
        let mut out = Vec::with_capacity(n_tokens);
        for _ in 0..n_tokens {
            // window: last `seq` tokens, but if full, keep the back half so
            // consecutive steps reuse positions (cheap slide policy).
            if context.len() > self.seq {
                let keep = self.seq / 2;
                context = context[context.len() - keep..].to_vec();
            }
            let len = context.len().min(self.seq);
            // batch row 0 = context (padded to T with 0s); rows 1.. zeros.
            let mut tokens = vec![0i32; self.batch * self.seq];
            tokens[..len].copy_from_slice(&context[..len]);
            let (shape, logits) = self.session.forward(&tokens)?;
            debug_assert_eq!(shape, vec![self.batch, self.seq, self.vocab]);
            let row = &logits[(len - 1) * self.vocab..len * self.vocab];
            let next = self.sample(row);
            out.push(next);
            context.push(next);
        }
        Ok(out)
    }

    fn sample(&mut self, logits: &[f32]) -> i32 {
        if self.opts.temperature <= 0.0 {
            return argmax(logits) as i32;
        }
        // top-k filter
        let k = if self.opts.top_k == 0 { logits.len() } else { self.opts.top_k.min(logits.len()) };
        let mut idx: Vec<usize> = (0..logits.len()).collect();
        idx.sort_unstable_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap());
        let kept = &idx[..k];
        // softmax over kept at temperature
        let t = self.opts.temperature;
        let mx = logits[kept[0]];
        let weights: Vec<f64> =
            kept.iter().map(|&i| (((logits[i] - mx) / t) as f64).exp()).collect();
        let total: f64 = weights.iter().sum();
        let mut u = self.rng.f64() * total;
        for (w, &i) in weights.iter().zip(kept) {
            u -= w;
            if u <= 0.0 {
                return i as i32;
            }
        }
        kept[k - 1] as i32
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// End-to-end convenience: tokenize a text prompt with the standard corpus
/// tokenizer, generate, decode.
pub fn generate_text(
    session: &mut Session,
    tokenizer: &Tokenizer,
    prompt: &str,
    n_tokens: usize,
    opts: SampleOpts,
) -> Result<String> {
    let vocab = session.preset.model.vocab;
    let ids: Vec<i32> = tokenizer
        .encode(prompt)
        .into_iter()
        .map(|t| t % vocab as i32)
        .collect();
    let mut g = Generator::new(session, opts)?;
    let out = g.generate(&ids, n_tokens).context("generation failed")?;
    Ok(tokenizer.decode(&out))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basics() {
        assert_eq!(argmax(&[1.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[-5.0, -1.0]), 1);
    }

    #[test]
    fn sampling_math_is_deterministic_per_seed() {
        // Pure sampler test without a session: emulate via direct calls.
        let logits: Vec<f32> = (0..16).map(|i| (i as f32 * 0.3).sin()).collect();
        let sample_once = |seed: u64| -> Vec<usize> {
            let mut rng = Rng::new(seed);
            let t = 0.8f32;
            let mut idx: Vec<usize> = (0..logits.len()).collect();
            idx.sort_unstable_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap());
            let kept = &idx[..8];
            let mx = logits[kept[0]];
            let weights: Vec<f64> =
                kept.iter().map(|&i| (((logits[i] - mx) / t) as f64).exp()).collect();
            let total: f64 = weights.iter().sum();
            (0..20)
                .map(|_| {
                    let mut u = rng.f64() * total;
                    for (w, &i) in weights.iter().zip(kept) {
                        u -= w;
                        if u <= 0.0 {
                            return i;
                        }
                    }
                    kept[7]
                })
                .collect()
        };
        assert_eq!(sample_once(5), sample_once(5));
        assert_ne!(sample_once(5), sample_once(6));
    }
}
