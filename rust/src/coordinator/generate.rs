//! Text generation through the spectral model — the inference side of the
//! paper's claim that the dense matrix is never materialized "during
//! training or inference".
//!
//! Uses the AOT `forward` artifact (fixed (B, T) shape): the generator keeps
//! a rolling context window, runs the batch-row-0 forward, reads the logits
//! at the last real position, samples, and appends. When the context fills,
//! the window slides by half (positions are absolute RoPE, so a slide is a
//! re-encode, not a KV shift — the artifact has no KV cache; this is the
//! simple-and-correct baseline decoder).
//!
//! For KV-cached incremental decoding and batched serving, see
//! [`crate::serve`] — the sampler ([`SampleOpts`], [`sample_logits`]) is
//! shared with that engine so both paths sample identically.

use anyhow::{Context, Result};

use crate::data::Tokenizer;
use crate::runtime::Session;
use crate::util::rng::Rng;

pub use crate::serve::engine::{sample_logits, SampleOpts};

pub struct Generator<'s> {
    session: &'s mut Session,
    batch: usize,
    seq: usize,
    vocab: usize,
    rng: Rng,
    opts: SampleOpts,
}

impl<'s> Generator<'s> {
    pub fn new(session: &'s mut Session, opts: SampleOpts) -> Result<Generator<'s>> {
        let fwd = session.preset.artifact("forward")?;
        let ti = fwd.input_index("tokens")?;
        let shape = fwd.inputs[ti].shape.clone();
        let vocab = session.preset.model.vocab;
        Ok(Generator {
            session,
            batch: shape[0],
            seq: shape[1],
            vocab,
            rng: Rng::new(opts.seed),
            opts,
        })
    }

    /// Generate `n_tokens` continuations of `prompt` token ids.
    pub fn generate(&mut self, prompt: &[i32], n_tokens: usize) -> Result<Vec<i32>> {
        let mut context: Vec<i32> = prompt.to_vec();
        anyhow::ensure!(!context.is_empty(), "prompt must not be empty");
        let mut out = Vec::with_capacity(n_tokens);
        for _ in 0..n_tokens {
            // window: last `seq` tokens, but if full, keep the back half so
            // consecutive steps reuse positions (cheap slide policy).
            if context.len() > self.seq {
                let keep = self.seq / 2;
                context = context[context.len() - keep..].to_vec();
            }
            let len = context.len().min(self.seq);
            // batch row 0 = context (padded to T with 0s); rows 1.. zeros.
            let mut tokens = vec![0i32; self.batch * self.seq];
            tokens[..len].copy_from_slice(&context[..len]);
            let (shape, logits) = self.session.forward(&tokens)?;
            debug_assert_eq!(shape, vec![self.batch, self.seq, self.vocab]);
            let row = &logits[(len - 1) * self.vocab..len * self.vocab];
            let next = sample_logits(row, self.opts.temperature, self.opts.top_k, &mut self.rng);
            out.push(next);
            context.push(next);
        }
        Ok(out)
    }
}

/// End-to-end convenience: tokenize a text prompt with the standard corpus
/// tokenizer, generate, decode.
pub fn generate_text(
    session: &mut Session,
    tokenizer: &Tokenizer,
    prompt: &str,
    n_tokens: usize,
    opts: SampleOpts,
) -> Result<String> {
    let vocab = session.preset.model.vocab;
    let ids: Vec<i32> = tokenizer
        .encode(prompt)
        .into_iter()
        .map(|t| t % vocab as i32)
        .collect();
    let mut g = Generator::new(session, opts)?;
    let out = g.generate(&ids, n_tokens).context("generation failed")?;
    Ok(tokenizer.decode(&out))
}
