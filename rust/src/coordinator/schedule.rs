//! Learning-rate schedules, including the paper's per-component split.
//!
//! The paper trains everything at one LR (dense baseline 2e-5, SCT 5e-4) and
//! §4.3/§5 attributes its convergence gap to exactly that: the 77%-of-model
//! attention stack shares the 25x-hot spectral LR. The "clear next step" it
//! names — per-component scheduling — is implemented here as a pair of
//! schedules evaluated per step and fed to the two LR inputs of the
//! train_step artifact.

/// A scalar schedule.
#[derive(Debug, Clone)]
pub enum Schedule {
    Constant(f32),
    /// Linear warmup to `peak` over `warmup` steps, then cosine decay to
    /// `floor` at `total` steps.
    WarmupCosine { peak: f32, floor: f32, warmup: usize, total: usize },
}

impl Schedule {
    pub fn at(&self, step: usize) -> f32 {
        match *self {
            Schedule::Constant(v) => v,
            Schedule::WarmupCosine { peak, floor, warmup, total } => {
                if warmup > 0 && step < warmup {
                    return peak * (step as f32 + 1.0) / warmup as f32;
                }
                let t = (step.saturating_sub(warmup)) as f32
                    / (total.saturating_sub(warmup)).max(1) as f32;
                let t = t.clamp(0.0, 1.0);
                floor + 0.5 * (peak - floor) * (1.0 + (std::f32::consts::PI * t).cos())
            }
        }
    }
}

/// The pair of schedules the coordinator drives.
#[derive(Debug, Clone)]
pub struct LrPlan {
    pub dense: Schedule,
    pub spectral: Schedule,
}

impl LrPlan {
    /// The paper's SCT configuration: one constant 5e-4 for everything.
    pub fn paper_sct() -> LrPlan {
        LrPlan { dense: Schedule::Constant(5e-4), spectral: Schedule::Constant(5e-4) }
    }

    /// The paper's dense baseline: constant 2e-5.
    pub fn paper_dense() -> LrPlan {
        LrPlan { dense: Schedule::Constant(2e-5), spectral: Schedule::Constant(2e-5) }
    }

    /// The paper's §5 proposal: dense-calibrated LR for attention/embeddings,
    /// hotter LR for the spectral factors.
    pub fn split(dense: f32, spectral: f32) -> LrPlan {
        LrPlan { dense: Schedule::Constant(dense), spectral: Schedule::Constant(spectral) }
    }

    pub fn at(&self, step: usize) -> (f32, f32) {
        (self.dense.at(step), self.spectral.at(step))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_is_constant() {
        let s = Schedule::Constant(3e-4);
        assert_eq!(s.at(0), 3e-4);
        assert_eq!(s.at(10_000), 3e-4);
    }

    #[test]
    fn warmup_rises_linearly() {
        let s = Schedule::WarmupCosine { peak: 1.0, floor: 0.0, warmup: 10, total: 100 };
        assert!((s.at(0) - 0.1).abs() < 1e-6);
        assert!((s.at(4) - 0.5).abs() < 1e-6);
        assert!((s.at(9) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn cosine_decays_to_floor() {
        let s = Schedule::WarmupCosine { peak: 1.0, floor: 0.1, warmup: 0, total: 100 };
        assert!((s.at(0) - 1.0).abs() < 1e-6);
        assert!((s.at(50) - 0.55).abs() < 1e-3);
        assert!((s.at(100) - 0.1).abs() < 1e-6);
        assert!((s.at(1000) - 0.1).abs() < 1e-6, "clamps past total");
    }

    #[test]
    fn monotone_decreasing_after_warmup() {
        let s = Schedule::WarmupCosine { peak: 5e-4, floor: 5e-5, warmup: 5, total: 200 };
        let mut prev = f32::INFINITY;
        for step in 5..200 {
            let v = s.at(step);
            assert!(v <= prev + 1e-9);
            prev = v;
        }
    }

    #[test]
    fn paper_plans() {
        assert_eq!(LrPlan::paper_sct().at(123), (5e-4, 5e-4));
        assert_eq!(LrPlan::paper_dense().at(0), (2e-5, 2e-5));
        let split = LrPlan::split(2e-5, 5e-4);
        assert_eq!(split.at(7), (2e-5, 5e-4));
    }
}
