//! L3 coordinator — the training orchestration layer.
//!
//! This is where the paper's experiments live as code: the config system
//! ([`config`]), LR schedules incl. the per-component split the paper names
//! as future work ([`schedule`]), the training loop ([`trainer`]), and one
//! driver per experiment ([`sweep`] = Table 3 / Figs 2-3, [`finetune`] =
//! Table 4, [`validate70b`] = Table 2 / Fig 1). The [`cli`] exposes each as
//! a subcommand of the `sct` launcher.
//!
//! Drivers that execute AOT artifacts (the pjrt `Trainer`,
//! [`sweep::run_sweep`], [`finetune`], the AOT [`generate::Generator`])
//! require the `pjrt` feature; [`config`], [`schedule`], [`validate70b`],
//! the native-backend [`trainer::run_native`] loop with its adaptive-rank
//! policies ([`crate::rank`]), the native sweep
//! ([`sweep::run_sweep_native`]), native generation
//! ([`generate::generate_text_native`]) and the CLI shell are always
//! built.

pub mod cli;
pub mod config;
#[cfg(feature = "pjrt")]
pub mod finetune;
pub mod generate;
pub mod schedule;
pub mod sweep;
pub mod trainer;
pub mod validate70b;

pub use config::RunConfig;
pub use schedule::{LrPlan, Schedule};
pub use trainer::{run_native, RunSummary};
#[cfg(feature = "pjrt")]
pub use trainer::Trainer;
