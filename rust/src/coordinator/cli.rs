//! The `sct` launcher CLI.
//!
//! Subcommands map onto the paper's experiments (DESIGN.md §3):
//! * `train`        — one training run (any preset, any LR plan; the
//!   `--backend native` pure-Rust engine needs no PJRT, supports live rank
//!   transitions via `--rank-schedule` / the `[rank]` TOML section, and its
//!   checkpoints serve directly via `sct serve --ckpt`)
//! * `sweep`        — Table 3 + Figures 2/3 (rank sweep, dense baseline;
//!   `--backend native` reruns the rank sweep through the pure-Rust engine)
//! * `validate-70b` — Table 2 + Figure 1 (70B step, true factor shapes)
//! * `finetune`     — Table 4 (dense -> 95%-energy spectral conversion)
//! * `generate`     — sample text (`--backend native` decodes a trained
//!   `.sct` checkpoint through the serving engine, no PJRT)
//! * `mem-report`   — Table 1 / Figure 1 analytic memory model
//!   (`--rank-schedule` reports peak memory across milestone ranks)
//! * `serve`        — pure-Rust spectral inference server (KV cache +
//!   continuous batching + chunked prefill + SSE streaming; no PJRT needed)
//! * `doctor`       — offline spectral-health report over a `.sct`
//!   checkpoint: the same per-layer diagnostics `sct train --spectra-out`
//!   streams live (spectrum, tail energy, effective rank, condition,
//!   factor orthogonality), plus a NaN/Inf parameter scan
//! * `info`         — list presets in the artifact manifest
//!
//! PJRT-backed paths (finetune, and train/sweep/generate with the default
//! pjrt backend) need the `pjrt` feature; without it they exit with a
//! pointer to the feature flag and to the `--backend native` twins, which
//! run entirely in Rust.

use anyhow::{bail, Result};

use super::config::RunConfig;
use super::schedule::LrPlan;
use super::sweep;
use super::trainer::RunSummary;
use super::validate70b;
#[cfg(feature = "pjrt")]
use super::finetune;
use crate::memmodel::report;
use crate::metrics::{export, Tracker};
use crate::obs::{log as obs_log, trace};
use crate::rank::RankPolicyConfig;
use crate::runtime::Manifest;
use crate::sct_info;
use crate::serve;
use crate::util::args::{Args, Command};

pub fn run() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some((sub, rest)) = argv.split_first() else {
        print_usage();
        return Ok(());
    };
    let rest = rest.to_vec();
    match sub.as_str() {
        "train" => cmd_train(&rest),
        "sweep" => cmd_sweep(&rest),
        "validate-70b" => cmd_validate_70b(&rest),
        "finetune" => cmd_finetune(&rest),
        "generate" => cmd_generate(&rest),
        "serve" => cmd_serve(&rest),
        "doctor" => cmd_doctor(&rest),
        "mem-report" => cmd_mem_report(&rest),
        "info" => cmd_info(&rest),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown subcommand {other:?}\n\nrun `sct help`"),
    }
}

fn print_usage() {
    println!(
        "sct — Spectral Compact Training (paper reproduction)\n\n\
         subcommands:\n\
         \x20 train         one training run (PJRT artifacts, or --backend native: pure Rust,\n\
         \x20               with live rank transitions via --rank-schedule / [rank] TOML)\n\
         \x20 sweep         rank sweep: Table 3 + Figures 2/3 (--backend native: no PJRT)\n\
         \x20 validate-70b  70B-step validation: Table 2 + Figure 1\n\
         \x20 finetune      gradient-integrity fine-tune: Table 4\n\
         \x20 generate      sample text from a (trained) spectral model (--backend native)\n\
         \x20 serve         spectral inference server (batching + chunked prefill + SSE streaming)\n\
         \x20 doctor        offline spectral-health report over a .sct checkpoint\n\
         \x20 mem-report    analytic memory model: Table 1 / Figure 1 (--rank-schedule: peak)\n\
         \x20 info          list presets in the manifest\n\n\
         `sct <subcommand> --help` for options"
    );
}

#[cfg(not(feature = "pjrt"))]
fn needs_pjrt(cmd: &str) -> Result<()> {
    bail!(
        "`sct {cmd}` executes AOT artifacts through PJRT, which this binary \
         was built without; rebuild with `cargo build --features pjrt`, or \
         use the pure-Rust twins: `sct train|sweep|generate --backend native` \
         (other pure-Rust subcommands: serve, validate-70b, mem-report, info)"
    )
}

fn base_config(args: &Args) -> Result<RunConfig> {
    let mut cfg = RunConfig::default();
    if let Some(path) = args.get("config") {
        cfg.load_file(std::path::Path::new(path))?;
    }
    if let Some(p) = args.get("preset") {
        cfg.preset = p.to_string();
    }
    if let Some(b) = args.get("backend") {
        cfg.backend = b.to_string();
    }
    cfg.steps = args.parse_num("steps", cfg.steps)?;
    cfg.seed = args.parse_num("seed", cfg.seed)?;
    if let Some(r) = args.get("artifacts") {
        cfg.artifacts_root = r.to_string();
    }
    if let Some(o) = args.get("out") {
        cfg.out_dir = o.to_string();
    }
    if args.flag("no-chunk") {
        cfg.chunked = false;
    }
    let lr_d: Option<f32> = args.get("lr-dense").map(|s| s.parse()).transpose()?;
    let lr_s: Option<f32> = args.get("lr-spectral").map(|s| s.parse()).transpose()?;
    if lr_d.is_some() || lr_s.is_some() {
        let d = lr_d.unwrap_or(5e-4);
        cfg.lr_plan = LrPlan::split(d, lr_s.unwrap_or(d));
    }
    if let Some(dir) = args.get("ckpt-dir") {
        cfg.ckpt_dir = Some(dir.to_string());
        cfg.ckpt_every = args.parse_num("ckpt-every", 100)?;
    }
    // native-backend knobs (layered: RunConfig defaults < TOML < flags)
    cfg.grad_clip = args.parse_num("grad-clip", cfg.grad_clip)?;
    cfg.weight_decay = args.parse_num("weight-decay", cfg.weight_decay)?;
    cfg.retract_every = args.parse_num("retract-every", cfg.retract_every)?;
    cfg.batch = args.parse_num("batch", cfg.batch)?;
    cfg.seq_len = args.parse_num("seq-len", cfg.seq_len)?;
    let nm = &mut cfg.native_model;
    nm.vocab = args.parse_num("vocab", nm.vocab)?;
    nm.d_model = args.parse_num("d-model", nm.d_model)?;
    nm.n_layers = args.parse_num("layers", nm.n_layers)?;
    nm.n_heads = args.parse_num("heads", nm.n_heads)?;
    nm.d_ffn = args.parse_num("ffn", nm.d_ffn)?;
    nm.rank = args.parse_num("rank", nm.rank)?;
    nm.max_seq = args.parse_num("max-seq", nm.max_seq)?;
    if args.flag("untied") {
        nm.tied = false;
    }
    // rank-transition policy (native backend): the flag declares scheduled
    // milestones; the [rank] TOML section configures any policy.
    if let Some(s) = args.get("rank-schedule") {
        cfg.rank_policy = RankPolicyConfig::Schedule(RankPolicyConfig::parse_schedule(s)?);
    }
    // worker-pool size for the parallel kernels: flag > [runtime] TOML >
    // SCT_THREADS env > all cores (the pool resolves the last two itself)
    cfg.threads = args.parse_num("threads", cfg.threads)?;
    if cfg.threads > 0 {
        crate::util::pool::set_threads(cfg.threads);
    }
    // matmul fan-out threshold: [runtime] TOML > SCT_PAR_THRESHOLD env >
    // pool default (the pool resolves the last two itself)
    if cfg.par_threshold > 0 {
        crate::util::pool::set_par_threshold(cfg.par_threshold);
    }
    // observability knobs: flag > [obs] TOML > SCT_LOG env
    if let Some(l) = args.get("log-level") {
        anyhow::ensure!(
            obs_log::parse_level(l).is_some(),
            "--log-level {l:?} unknown (expected quiet|error|warn|info|debug)"
        );
        cfg.obs.log_level = Some(l.to_string());
    }
    if let Some(p) = args.get("metrics-out") {
        cfg.obs.metrics_out = Some(p.to_string());
    }
    cfg.obs.metrics_every = args.parse_num("metrics-every", cfg.obs.metrics_every)?.max(1);
    if let Some(p) = args.get("profile-out") {
        cfg.obs.profile_out = Some(p.to_string());
    }
    // spectral-health telemetry + training watchdog (native backend)
    if let Some(p) = args.get("spectra-out") {
        cfg.obs.spectra_out = Some(p.to_string());
    }
    cfg.obs.spectra_every = args.parse_num("spectra-every", cfg.obs.spectra_every)?.max(1);
    if let Some(w) = args.get("watchdog") {
        w.parse::<crate::obs::health::Policy>()
            .map_err(|e| anyhow::anyhow!("--watchdog: {e}"))?;
        cfg.obs.watchdog = Some(w.to_string());
    }
    cfg.obs.watchdog_spike_factor =
        args.parse_num("watchdog-spike-factor", cfg.obs.watchdog_spike_factor)?;
    cfg.obs.watchdog_grad_max = args.parse_num("watchdog-grad-max", cfg.obs.watchdog_grad_max)?;
    if let Some(s) = args.get("watchdog-inject-nan") {
        let step: u64 = s
            .parse()
            .map_err(|e| anyhow::anyhow!("--watchdog-inject-nan {s:?}: {e}"))?;
        cfg.obs.watchdog_inject_nan = Some(step);
    }
    cfg.obs.apply_log_level();
    Ok(cfg)
}

/// Turn the profiler on when `--profile-out` / `[obs] profile_out` asked for
/// a report. Call before the run starts; pair with [`write_profile`].
fn start_profile(obs: &super::config::ObsConfig) {
    if obs.profile_out.is_some() {
        crate::obs::prof::enable();
    }
}

/// Persist the profiler report (JSON + sibling `.folded` collapsed stacks)
/// and log the top of the phase/kernel table. No-op without `profile_out`.
fn write_profile(obs: &super::config::ObsConfig) -> Result<()> {
    if let Some(path) = &obs.profile_out {
        let report = crate::obs::prof::write_report(std::path::Path::new(path))?;
        sct_info!(
            "profile: wrote {path} (+ {}):\n{}",
            std::path::Path::new(path).with_extension("folded").display(),
            report.render_table(8)
        );
    }
    Ok(())
}

fn train_cmd_spec() -> Command {
    Command::new("sct train", "run one training job (pjrt artifacts or the native engine)")
        .opt("config", "TOML config file ([train]/[model]/[lr] sections)")
        .opt("backend", "training backend: pjrt | native [default: pjrt]")
        .opt("preset", "artifact preset name, pjrt backend (see `sct info`)")
        .opt("steps", "training steps")
        .opt("seed", "RNG seed (init + data)")
        .opt("lr-dense", "LR for dense params (attention/embeddings)")
        .opt("lr-spectral", "LR for spectral factors (U, s, V)")
        .opt("artifacts", "artifact root, pjrt backend [default: artifacts]")
        .opt("out", "output dir for CSV/JSONL [default: runs]")
        .opt("ckpt-dir", "checkpoint directory (enables checkpointing)")
        .opt("ckpt-every", "checkpoint cadence in steps")
        .opt("grad-clip", "global gradient-norm clip, native backend (0 = off) [default: 1]")
        .opt(
            "weight-decay",
            "decoupled weight decay on attention/head tensors, native backend [default: 0]",
        )
        .opt("retract-every", "QR-retract U/V every N steps, native backend [default: 1]")
        .opt("batch", "batch size, native backend [default: 8]")
        .opt("seq-len", "input sequence length, native backend [default: 64]")
        .opt("vocab", "vocab size, native backend [default: 256]")
        .opt("d-model", "model width, native backend [default: 64]")
        .opt("layers", "decoder layers, native backend [default: 2]")
        .opt("heads", "attention heads, native backend [default: 4]")
        .opt("ffn", "FFN width, native backend [default: 192]")
        .opt("rank", "spectral rank k, native backend [default: 8]")
        .opt("max-seq", "max sequence length / RoPE table, native backend [default: 128]")
        .opt(
            "rank-schedule",
            "\"step:rank,step:rank\" milestones — grow/shrink the spectral \
             factors live at those steps, native backend (TOML: [[rank.schedule]]; \
             adaptive tail-energy policy via the [rank] section)",
        )
        .opt(
            "threads",
            "worker-pool threads for the parallel kernels (0 = auto; also \
             [runtime] threads in TOML or the SCT_THREADS env var; results \
             are bit-identical at any setting)",
        )
        .opt(
            "log-level",
            "logger verbosity: quiet|error|warn|info|debug (also [obs] \
             log_level in TOML or SCT_LOG; quiet leaves stdout machine-clean)",
        )
        .opt(
            "metrics-out",
            "append metric-registry JSONL snapshots to this path during the \
             run (TOML: [obs] metrics_out)",
        )
        .opt(
            "metrics-every",
            "snapshot cadence in optimizer steps, with --metrics-out \
             (TOML: [obs] metrics_every) [default: 10]",
        )
        .opt(
            "profile-out",
            "enable the phase/kernel profiler and write its report here as \
             JSON, plus collapsed flamegraph stacks at the sibling .folded \
             path (TOML: [obs] profile_out)",
        )
        .opt(
            "spectra-out",
            "append per-layer spectral-health JSONL samples (full spectrum, \
             tail-energy curve, effective rank, condition, ortho error, \
             subspace drift) to this path, native backend (TOML: [obs] \
             spectra_out)",
        )
        .opt(
            "spectra-every",
            "spectral-health sampling cadence in optimizer steps, with \
             --spectra-out (TOML: [obs] spectra_every) [default: 25]",
        )
        .opt(
            "watchdog",
            "arm the training watchdog, native backend: warn|skip|halt on \
             NaN/Inf loss/grads/params, loss spikes, gradient explosions and \
             dead spectra (TOML: [obs] watchdog)",
        )
        .opt(
            "watchdog-spike-factor",
            "loss counts as a spike above this multiple of the rolling-window \
             mean (TOML: [obs] watchdog_spike_factor) [default: 3]",
        )
        .opt(
            "watchdog-grad-max",
            "global gradient norm above this is an explosion anomaly \
             (TOML: [obs] watchdog_grad_max) [default: 1000]",
        )
        .opt(
            "watchdog-inject-nan",
            "test hook: inject a NaN loss into the watchdog at this step \
             (CI smoke for the halt path; needs --watchdog)",
        )
        .flag("untied", "untied LM head, native backend (default tied)")
        .flag("no-chunk", "dispatch per-step instead of fused K-step chunks (pjrt)")
        .flag("resume", "resume from newest checkpoint if present")
}

/// Shared tail of both train backends: banner line, loss CSV, runs.jsonl.
/// Progress lines go through the logger (stderr), so `--log-level quiet`
/// leaves stdout machine-clean.
fn report_run(
    summary: &RunSummary,
    tracker: &Tracker,
    mlp_compression: f64,
    out_dir: &std::path::Path,
) -> Result<()> {
    sct_info!(
        "run {}: {} steps, loss {:.3} (ppl {:.1}), {:.0} ms/step, state {:.1} MB{}",
        summary.label,
        summary.steps,
        summary.final_loss_smoothed,
        summary.ppl,
        summary.mean_step_s * 1e3,
        summary.state_bytes as f64 / 1e6,
        summary
            .ortho_error
            .map(|o| format!(", ortho {o:.1e}"))
            .unwrap_or_default()
    );
    let csv = out_dir.join(format!("{}_loss.csv", summary.label));
    export::write_loss_csv(tracker, &csv)?;
    let row = export::summary_json(
        &summary.label,
        summary.params,
        mlp_compression,
        tracker,
        summary.state_bytes,
    );
    export::append_jsonl(&out_dir.join("runs.jsonl"), &row)?;
    sct_info!("wrote {}", csv.display());
    // rank transitions applied by the adaptive-rank policy, one JSON row
    // per event — the metrics surface of the `rank` subsystem
    if !summary.rank_events.is_empty() {
        let path = out_dir.join("rank_events.jsonl");
        for ev in &summary.rank_events {
            export::append_jsonl(&path, &ev.to_json())?;
        }
        sct_info!(
            "{} rank transitions (final per-layer ranks {:?}) -> {}",
            summary.rank_events.len(),
            summary.layer_ranks,
            path.display()
        );
    }
    Ok(())
}

fn cmd_train(argv: &[String]) -> Result<()> {
    let spec = train_cmd_spec();
    let args = spec.parse(argv)?;
    let cfg = base_config(&args)?;
    start_profile(&cfg.obs);
    let obs = cfg.obs.clone();
    match cfg.backend.as_str() {
        "native" => cmd_train_native(cfg, args.flag("resume"))?,
        "pjrt" => cmd_train_pjrt(cfg, args.flag("resume"))?,
        other => bail!("unknown train backend {other:?} (expected \"pjrt\" or \"native\")"),
    }
    write_profile(&obs)
}

/// `sct train --backend native` — the pure-Rust training engine: shared
/// decoder forward, full backward into the compact factors, AdamW + QR
/// retraction. Needs no PJRT, no artifacts; checkpoints serve directly.
fn cmd_train_native(cfg: RunConfig, resume: bool) -> Result<()> {
    let out_dir = std::path::PathBuf::from(&cfg.out_dir);
    std::fs::create_dir_all(&out_dir)?;
    let (summary, tracker) = super::trainer::run_native(&cfg, resume)?;
    report_run(
        &summary,
        &tracker,
        crate::train::mlp_compression(&cfg.native_model),
        &out_dir,
    )
}

#[cfg(feature = "pjrt")]
fn cmd_train_pjrt(cfg: RunConfig, resume: bool) -> Result<()> {
    let out_dir = std::path::PathBuf::from(&cfg.out_dir);
    std::fs::create_dir_all(&out_dir)?;
    let mut trainer = super::Trainer::new(cfg)?;
    if resume {
        if let Some(step) = trainer.try_resume()? {
            sct_info!("resumed from step {step}");
        }
    }
    let summary = trainer.run()?;
    let compression = trainer.mlp_compression();
    report_run(&summary, &trainer.tracker, compression, &out_dir)
}

#[cfg(not(feature = "pjrt"))]
fn cmd_train_pjrt(_cfg: RunConfig, _resume: bool) -> Result<()> {
    needs_pjrt("train")
}

fn cmd_sweep(argv: &[String]) -> Result<()> {
    let spec = Command::new(
        "sct sweep",
        "rank sweep (Table 3, Figures 2-3); --backend native reruns it \
         through the pure-Rust engine with no PJRT",
    )
        .opt("config", "TOML config file")
        .opt("backend", "sweep backend: pjrt | native [default: pjrt]")
        .opt_default("steps", "steps per run", "200")
        .opt("seed", "RNG seed")
        .opt("artifacts", "artifact root, pjrt backend")
        .opt("out", "output dir")
        .opt_default("ranks", "comma-separated spectral ranks, native backend", "4,8,16,32")
        .opt("threads", "worker-pool threads for the parallel kernels (0 = auto)")
        .opt(
            "profile-out",
            "enable the phase/kernel profiler across the whole sweep and \
             write its report here (JSON + sibling .folded)",
        )
        .flag("split-lr", "per-component LRs, pjrt backend (the paper's §5 proposal)")
        .flag("quick", "small steps count for smoke runs");
    let args = spec.parse(argv)?;
    let mut cfg = base_config(&args)?;
    if args.flag("quick") {
        cfg.steps = 40;
    }
    start_profile(&cfg.obs);
    let obs = cfg.obs.clone();
    match cfg.backend.as_str() {
        "native" => {
            // opt_default guarantees the value exists; req avoids a second
            // copy of the default literal drifting from the help text
            let ranks = args
                .req("ranks")?
                .split(',')
                .map(|s| {
                    s.trim()
                        .parse::<usize>()
                        .map_err(|e| anyhow::anyhow!("--ranks entry {s:?}: {e}"))
                })
                .collect::<Result<Vec<usize>>>()?;
            let result = sweep::run_sweep_native(&cfg, &ranks)?;
            report_sweep(&result, &cfg)?
        }
        "pjrt" => cmd_sweep_pjrt(cfg, args.flag("split-lr"))?,
        other => bail!("unknown sweep backend {other:?} (expected \"pjrt\" or \"native\")"),
    }
    write_profile(&obs)
}

/// Shared tail of both sweep backends: tables, figures, observation
/// checks, and one CSV per curve.
fn report_sweep(result: &sweep::SweepResult, cfg: &RunConfig) -> Result<()> {
    println!("{}", sweep::render_table3(&result.rows));
    println!("{}", sweep::render_fig2(&result.curves));
    println!("{}", sweep::render_fig3(&result.rows));
    for (what, ok) in sweep::check_observations(&result.rows) {
        println!("[{}] {what}", if ok { "OK " } else { "FAIL" });
    }
    // persist curves for EXPERIMENTS.md
    let out_dir = std::path::PathBuf::from(&cfg.out_dir);
    std::fs::create_dir_all(&out_dir)?;
    for (label, ys) in &result.curves {
        let mut t = crate::metrics::Tracker::new(1);
        for &y in ys {
            t.record(y, 0.0);
        }
        let path = out_dir.join(format!("sweep_{}.csv", label.replace([' ', '='], "_")));
        export::write_loss_csv(&t, &path)?;
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_sweep_pjrt(cfg: RunConfig, split_lr: bool) -> Result<()> {
    let presets = sweep::paper_presets(split_lr);
    let result = sweep::run_sweep(&cfg, &presets)?;
    report_sweep(&result, &cfg)
}

#[cfg(not(feature = "pjrt"))]
fn cmd_sweep_pjrt(_cfg: RunConfig, _split_lr: bool) -> Result<()> {
    needs_pjrt("sweep")
}

fn cmd_validate_70b(argv: &[String]) -> Result<()> {
    let spec = Command::new("sct validate-70b", "70B-step validation (Table 2, Figure 1)")
        .opt_default("rank", "spectral rank k", "32")
        .opt_default("batch", "token rows through the measured layers", "4")
        .opt_default("layers", "layers to measure directly (of 80)", "2");
    let args = spec.parse(argv)?;
    let k: usize = args.parse_num("rank", 32)?;
    let batch: usize = args.parse_num("batch", 4)?;
    let layers: usize = args.parse_num("layers", 2)?;
    let phases = validate70b::measure_70b_phases(k, batch, layers)?;
    println!("{}", validate70b::render_table2(k, &phases));
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_finetune(argv: &[String]) -> Result<()> {
    let spec = Command::new("sct finetune", "gradient-integrity fine-tune (Table 4)")
        .opt_default("pretrain-steps", "dense pre-training steps", "150")
        .opt_default("finetune-steps", "fine-tune steps per method", "100")
        .opt_default("energy", "SVD energy retention", "0.95")
        .opt_default("seed", "RNG seed", "0")
        .opt("artifacts", "artifact root");
    let args = spec.parse(argv)?;
    let mut opts = finetune::FinetuneOpts::default();
    opts.pretrain_steps = args.parse_num("pretrain-steps", opts.pretrain_steps)?;
    opts.finetune_steps = args.parse_num("finetune-steps", opts.finetune_steps)?;
    opts.energy = args.parse_num("energy", opts.energy)?;
    opts.seed = args.parse_num("seed", opts.seed)?;
    if let Some(a) = args.get("artifacts") {
        opts.artifacts_root = a.to_string();
    }
    let result = finetune::run_finetune(&opts)?;
    println!("{}", finetune::render_table4(&result));
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_finetune(_argv: &[String]) -> Result<()> {
    needs_pjrt("finetune")
}

fn cmd_generate(argv: &[String]) -> Result<()> {
    let spec = Command::new(
        "sct generate",
        "sample text from a spectral model; --backend native decodes a \
         `.sct` checkpoint through the serving engine with no PJRT",
    )
        .opt("backend", "generation backend: pjrt | native [default: pjrt]")
        .opt_default("preset", "artifact preset, pjrt backend", "tiny_r8")
        .opt_default("prompt", "prompt text", "### Instruction: describe the rank of matrices")
        .opt_default("tokens", "tokens to generate", "48")
        .opt_default("temperature", "sampling temperature (0 = greedy)", "0.8")
        .opt_default("train-steps", "steps to train before sampling", "100")
        .opt_default("seed", "seed", "0")
        .opt("artifacts", "artifact root, pjrt backend")
        .opt("ckpt", "checkpoint file to restore instead of training (.sct)")
        .opt("log-level", "logger verbosity: quiet|error|warn|info|debug (also SCT_LOG)");
    let args = spec.parse(argv)?;
    if let Some(l) = args.get("log-level") {
        let level = obs_log::parse_level(l)
            .ok_or_else(|| anyhow::anyhow!("--log-level {l:?} unknown"))?;
        obs_log::set_level(level);
    }
    match args.get_or("backend", "pjrt") {
        "native" => cmd_generate_native(&args),
        "pjrt" => cmd_generate_pjrt(&args),
        other => bail!("unknown generate backend {other:?} (expected \"pjrt\" or \"native\")"),
    }
}

/// `sct generate --backend native` — closes the ROADMAP "generate without
/// PJRT" item: a checkpoint trained by the native engine (any per-layer
/// rank mix) samples text straight from the CLI through `serve::Engine`'s
/// KV-cached decode and the shared sampler.
fn cmd_generate_native(args: &Args) -> Result<()> {
    let seed: u64 = args.parse_num("seed", 0)?;
    let model = if let Some(ckpt) = args.get("ckpt") {
        let m = serve::SpectralModel::load(std::path::Path::new(ckpt))?;
        sct_info!("restored {ckpt} (per-layer ranks {:?})", m.layer_ranks());
        m
    } else {
        let steps: usize = args.parse_num("train-steps", 100)?;
        let tcfg = crate::train::NativeTrainConfig::default();
        let mut trainer = crate::train::NativeTrainer::new(tcfg, seed);
        if steps > 0 {
            sct_info!("training {steps} native steps so samples aren't pure noise...");
            let (_tok, mut ds) = crate::data::build_dataset(
                tcfg.model.vocab,
                tcfg.batch,
                tcfg.seq_len + 1,
                1 << 20,
                seed,
            );
            for _ in 0..steps {
                trainer.train_step(&ds.next_batch(), 1e-3, 3e-3);
            }
        }
        trainer.model
    };
    let tokenizer = crate::data::tokenizer_for(model.cfg.vocab, seed);
    let engine = serve::Engine::new(model);
    let opts = serve::SampleOpts {
        temperature: args.parse_num("temperature", 0.8)?,
        top_k: 40,
        seed,
    };
    let prompt = args.get_or("prompt", "### Instruction:");
    let n: usize = args.parse_num("tokens", 48)?;
    let out = super::generate::generate_text_native(&engine, &tokenizer, prompt, n, opts)?;
    println!("\nprompt: {prompt}\ncompletion: {out}");
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_generate_pjrt(args: &Args) -> Result<()> {
    let root = args.get_or("artifacts", "artifacts").to_string();
    let preset = args.get_or("preset", "tiny_r8");
    let seed: u64 = args.parse_num("seed", 0)?;
    let mut session = crate::runtime::Session::open(&root, preset)?;
    session.init(seed as i32)?;

    // tokenizer must match the training corpus: tokenizer_for trains on the
    // same deterministic CorpusGen(seed) stream `text` regenerates below
    let text = crate::data::CorpusGen::new(seed).generate(1 << 20);
    let tokenizer = crate::data::tokenizer_for(session.preset.model.vocab, seed);

    if let Some(ckpt) = args.get("ckpt") {
        let mgr = crate::checkpoint::CheckpointManager::new(
            std::path::Path::new(ckpt).parent().unwrap_or(std::path::Path::new(".")),
            3,
        )?;
        mgr.restore(&mut session, std::path::Path::new(ckpt))?;
        sct_info!("restored {ckpt}");
    } else {
        let steps: usize = args.parse_num("train-steps", 100)?;
        if steps > 0 {
            sct_info!("training {steps} steps so samples aren't pure noise...");
            let ts = session.preset.tokens_spec()?.clone();
            let (_tok2, ds) = (
                (),
                crate::data::Dataset::new(
                    {
                        let mut ids = tokenizer.encode(&text);
                        let cap = session.preset.model.vocab as i32;
                        for t in &mut ids { if *t >= cap { *t %= cap; } }
                        ids
                    },
                    ts.shape[0], ts.shape[1], seed,
                ),
            );
            let mut ds = ds;
            let chunk = session.chunk_len().unwrap_or(1);
            let mut done = 0;
            while done < steps {
                if chunk > 1 {
                    let t = ds.next_chunk(chunk);
                    session.train_chunk(&t, 1e-3, 3e-3)?;
                    done += chunk;
                } else {
                    let t = ds.next_batch();
                    session.train_step(&t, 1e-3, 3e-3)?;
                    done += 1;
                }
            }
        }
    }

    let opts = super::generate::SampleOpts {
        temperature: args.parse_num("temperature", 0.8)?,
        top_k: 40,
        seed,
    };
    let prompt = args.get_or("prompt", "### Instruction:");
    let n: usize = args.parse_num("tokens", 48)?;
    let out = super::generate::generate_text(&mut session, &tokenizer, prompt, n, opts)?;
    println!("\nprompt: {prompt}\ncompletion: {out}");
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_generate_pjrt(_args: &Args) -> Result<()> {
    needs_pjrt("generate")
}

/// `sct serve` — the pure-Rust spectral inference server. Runs without PJRT:
/// the engine computes `x → (xU)⊙s → (·)Vᵀ` natively, so a random-init or
/// checkpointed model serves on any machine the crate builds on.
fn cmd_serve(argv: &[String]) -> Result<()> {
    // Server-sizing options deliberately carry no parser-level default:
    // `opt_default` would pre-populate the value and silently override the
    // `[serve]` TOML section. Layering is ServeConfig::default < TOML < flag.
    let spec = Command::new(
        "sct serve",
        "spectral inference server (KV cache + continuous batching + chunked \
         prefill; --workers N shards requests across N engine clones behind a \
         least-loaded gateway; POST /v1/generate with \"stream\": true answers \
         Server-Sent Events, one data: frame per token over a keep-alive \
         connection)",
    )
        .opt("config", "TOML config file ([serve] section)")
        .opt("addr", "listen address [default: 127.0.0.1:8077]")
        .opt(
            "workers",
            "independent worker schedulers behind the gateway, one engine \
             clone + KV arena each; requests go to the least-loaded worker \
             (also [serve] workers in TOML or SCT_WORKERS) [default: 1]",
        )
        .opt("slots", "concurrent decode slots (KV cache arena size) per worker [default: 8]")
        .opt("queue-depth", "bounded admission queue depth per worker [default: 32]")
        .opt("max-new", "default tokens per request [default: 48]")
        .opt(
            "prefill-chunk",
            "prompt tokens prefilled per scheduler step — the fairness budget \
             that keeps long-prompt admission from stalling active decodes \
             (0 = unchunked) [default: 64]",
        )
        .opt(
            "keep-alive-ms",
            "connection read deadline / keep-alive idle window, ms \
             (0 = no deadline) [default: 15000]",
        )
        .opt(
            "threads",
            "worker-pool threads for the parallel decode kernels (0 = auto; \
             also [runtime] threads in TOML or SCT_THREADS)",
        )
        .opt(
            "ckpt",
            ".sct checkpoint (SpectralModel::save or `sct train --backend \
             native`; TOML: [serve] ckpt)",
        )
        .opt(
            "log-level",
            "logger verbosity: quiet|error|warn|info|debug (also [obs] \
             log_level in TOML or SCT_LOG)",
        )
        .opt(
            "trace-out",
            "append one JSON span record per request to this path \
             (TOML: [obs] trace_out)",
        )
        .opt(
            "profile-out",
            "enable the phase/kernel profiler (live snapshots at GET \
             /v1/profile) and write the final report here on shutdown \
             (JSON + sibling .folded; TOML: [obs] profile_out)",
        )
        .opt_default("seed", "weight-init / tokenizer seed", "0")
        .opt_default("vocab", "vocab size (random-init model)", "256")
        .opt_default("d-model", "model width (random-init model)", "64")
        .opt_default("layers", "transformer layers (random-init model)", "2")
        .opt_default("heads", "attention heads (random-init model)", "4")
        .opt_default("ffn", "FFN width (random-init model)", "192")
        .opt_default("rank", "spectral rank k (random-init model)", "8")
        .opt_default("max-seq", "max sequence length (KV capacity)", "128");
    let args = spec.parse(argv)?;

    let mut serve_cfg = serve::ServeConfig::default();
    let mut threads = 0usize;
    let mut par_threshold = 0usize;
    let mut obs_cfg = super::config::ObsConfig::default();
    if let Some(path) = args.get("config") {
        let text = std::fs::read_to_string(path)?;
        let doc = super::config::parse_toml(&text)?;
        serve_cfg.apply_toml(&doc)?;
        threads = super::config::runtime_threads(&doc)?;
        par_threshold = super::config::runtime_par_threshold(&doc)?;
        obs_cfg.apply_toml(&doc)?;
    }
    threads = args.parse_num("threads", threads)?;
    if threads > 0 {
        crate::util::pool::set_threads(threads);
    }
    if par_threshold > 0 {
        crate::util::pool::set_par_threshold(par_threshold);
    }
    // observability: flags > [obs] TOML > SCT_LOG env
    if let Some(l) = args.get("log-level") {
        anyhow::ensure!(
            obs_log::parse_level(l).is_some(),
            "--log-level {l:?} unknown (expected quiet|error|warn|info|debug)"
        );
        obs_cfg.log_level = Some(l.to_string());
    }
    obs_cfg.apply_log_level();
    if let Some(path) = args.get("trace-out") {
        obs_cfg.trace_out = Some(path.to_string());
    }
    if let Some(path) = &obs_cfg.trace_out {
        trace::install_file(std::path::Path::new(path))?;
        sct_info!("tracing request spans to {path}");
    }
    if let Some(path) = args.get("profile-out") {
        obs_cfg.profile_out = Some(path.to_string());
    }
    start_profile(&obs_cfg);
    if let Some(path) = &obs_cfg.profile_out {
        sct_info!("profiling enabled; report goes to {path} on shutdown (live: GET /v1/profile)");
    }
    if let Some(a) = args.get("addr") {
        serve_cfg.addr = a.to_string();
    }
    serve_cfg.workers = args.parse_num("workers", serve_cfg.workers)?;
    serve_cfg.slots = args.parse_num("slots", serve_cfg.slots)?;
    serve_cfg.queue_depth = args.parse_num("queue-depth", serve_cfg.queue_depth)?;
    serve_cfg.max_new_default = args.parse_num("max-new", serve_cfg.max_new_default)?;
    serve_cfg.prefill_chunk = args.parse_num("prefill-chunk", serve_cfg.prefill_chunk)?;
    serve_cfg.keep_alive_ms = args.parse_num("keep-alive-ms", serve_cfg.keep_alive_ms)?;
    anyhow::ensure!(serve_cfg.workers > 0, "--workers must be at least 1");
    anyhow::ensure!(serve_cfg.slots > 0, "--slots must be at least 1");

    let seed: u64 = args.parse_num("seed", 0)?;
    if let Some(c) = args.get("ckpt") {
        serve_cfg.ckpt = Some(c.to_string());
    }
    let model = if let Some(ckpt) = serve_cfg.ckpt.clone() {
        let m = serve::SpectralModel::load(std::path::Path::new(&ckpt))?;
        sct_info!("restored serve checkpoint {ckpt}");
        m
    } else {
        let cfg = serve::EngineConfig {
            vocab: args.parse_num("vocab", 256)?,
            d_model: args.parse_num("d-model", 64)?,
            n_layers: args.parse_num("layers", 2)?,
            n_heads: args.parse_num("heads", 4)?,
            d_ffn: args.parse_num("ffn", 192)?,
            rank: args.parse_num("rank", 8)?,
            max_seq: args.parse_num("max-seq", 128)?,
            tied: true,
        };
        serve::SpectralModel::init(cfg, seed)
    };
    let m = &model.cfg;
    sct_info!(
        "model: d={} layers={} heads={} ffn={} vocab={} rank={} max_seq={} ({} params, no dense W)",
        m.d_model, m.n_layers, m.n_heads, m.d_ffn, m.vocab, m.rank, m.max_seq,
        model.param_count(),
    );

    let tokenizer = crate::data::tokenizer_for(m.vocab, seed);

    let server = serve::Server::start(&serve_cfg, serve::Engine::new(model), tokenizer)?;
    sct_info!(
        "serving on http://{}  (workers={}, slots={}/worker, queue={}/worker, \
         prefill_chunk={}, keep_alive_ms={})\n\
         routes: POST /v1/generate (\"stream\": true => SSE, one data: frame per \
         token), GET /healthz, GET /v1/health, GET /v1/stats, GET /metrics, \
         GET /v1/profile, GET /v1/version",
        server.addr,
        serve_cfg.workers,
        serve_cfg.slots,
        serve_cfg.queue_depth,
        serve_cfg.prefill_chunk,
        serve_cfg.keep_alive_ms,
    );
    server.join();
    write_profile(&obs_cfg)
}

/// `sct doctor <ckpt.sct>` — the offline twin of `sct train --spectra-out`:
/// load a checkpoint, run the same per-layer spectral diagnostics the live
/// sampler streams (identical code path, so the numbers are comparable to
/// the digit), print a per-layer table, and exit non-zero if any parameter
/// tensor holds a non-finite value.
fn cmd_doctor(argv: &[String]) -> Result<()> {
    let spec = Command::new(
        "sct doctor <ckpt.sct>",
        "offline spectral-health report over a checkpoint: per-triple \
         spectrum diagnostics (energy, tail share, effective rank, condition \
         number, factor orthogonality) plus a NaN/Inf parameter scan; the \
         same code path as `sct train --spectra-out`, so live and post-hoc \
         numbers agree exactly",
    )
        .opt_default("tail-frac", "tail fraction for the tail-energy share", "0.25")
        .opt("json", "also write the full record (all singular spectra) to this path")
        .opt("log-level", "logger verbosity: quiet|error|warn|info|debug (also SCT_LOG)");
    let args = spec.parse(argv)?;
    if let Some(l) = args.get("log-level") {
        let level = obs_log::parse_level(l)
            .ok_or_else(|| anyhow::anyhow!("--log-level {l:?} unknown"))?;
        obs_log::set_level(level);
    }
    let [ckpt] = args.positional.as_slice() else {
        bail!("usage: sct doctor <ckpt.sct> [--tail-frac f] [--json path]\n\n{}", spec.usage());
    };
    let tail_frac: f32 = args.parse_num("tail-frac", 0.25)?;
    let model = serve::SpectralModel::load(std::path::Path::new(ckpt))?;
    let spectra = crate::rank::model_spectra(&model, tail_frac);

    println!(
        "{ckpt}: {} params, d={} layers={} ranks {:?}",
        model.param_count(),
        model.cfg.d_model,
        model.cfg.n_layers,
        model.layer_ranks(),
    );
    println!(
        "{:<5} {:<6} {:>4} {:>12} {:>11} {:>9} {:>10} {:>9} {:>9}",
        "layer", "triple", "rank", "energy", "tail_share", "eff_rank", "cond", "ortho_u", "ortho_v"
    );
    for l in &spectra {
        for t in &l.triples {
            println!(
                "{:<5} {:<6} {:>4} {:>12.4} {:>11.3e} {:>9.2} {:>10.3e} {:>9.1e} {:>9.1e}",
                l.layer,
                t.name,
                t.rank,
                t.energy,
                t.tail_share,
                t.effective_rank,
                t.condition,
                t.ortho_u,
                t.ortho_v,
            );
        }
    }

    if let Some(path) = args.get("json") {
        use crate::util::json::Json;
        let mut report = crate::rank::spectra_json(0, &spectra);
        if let Json::Obj(fields) = &mut report {
            fields.retain(|(k, _)| k != "step");
            fields.insert(0, ("params".to_string(), Json::Num(model.param_count() as f64)));
            fields.insert(0, ("checkpoint".to_string(), Json::Str(ckpt.to_string())));
        }
        std::fs::write(path, report.to_string() + "\n")?;
        sct_info!("wrote {path}");
    }

    // The health verdict is the exit status: a poisoned checkpoint must not
    // pass silently through scripts that chain on `sct doctor && ...`.
    if let Some(detail) = super::trainer::non_finite_param(&model) {
        bail!("{ckpt}: {detail}");
    }
    sct_info!("{ckpt}: all parameter tensors finite");
    Ok(())
}

fn cmd_mem_report(argv: &[String]) -> Result<()> {
    let spec = Command::new("sct mem-report", "analytic memory model (Table 1, Figure 1)")
        .opt_default("rank", "spectral rank k", "32")
        .opt(
            "rank-schedule",
            "\"step:rank,...\" milestones — report the training-memory \
             footprint per milestone and the peak across the schedule \
             (what a rank-scheduled run must provision for)",
        )
        .flag("table1", "print Table 1 only")
        .flag("fig1", "print Figure 1 only")
        .flag("baselines", "include GaLore/LoRA accounting rows");
    let args = spec.parse(argv)?;
    if let Some(sched) = args.get("rank-schedule") {
        let milestones = RankPolicyConfig::parse_schedule(sched)?;
        // The run spends steps at --rank before the first milestone fires,
        // so the starting rank is part of the peak — unless a step-0
        // milestone overrides it.
        let mut ranks: Vec<usize> = Vec::with_capacity(milestones.len() + 1);
        if !matches!(milestones.first(), Some(&(0, _))) {
            ranks.push(args.parse_num("rank", 32)?);
        }
        ranks.extend(milestones.iter().map(|&(_, r)| r));
        println!("{}", report::render_schedule(&ranks));
        return Ok(());
    }
    let k: usize = args.parse_num("rank", 32)?;
    let all = !args.flag("table1") && !args.flag("fig1");
    if args.flag("table1") || all {
        println!("{}", report::render_table1(k));
    }
    if args.flag("fig1") || all {
        println!("{}", report::render_fig1(k));
    }
    if args.flag("baselines") {
        println!("70B MLP-stack training memory by method (GB):");
        for (name, gb) in report::baseline_rows(k) {
            println!("  {name:<12} {gb:>10.1}");
        }
    }
    Ok(())
}

fn cmd_info(argv: &[String]) -> Result<()> {
    let spec = Command::new("sct info", "list presets in the manifest")
        .opt_default("artifacts", "artifact root", "artifacts");
    let args = spec.parse(argv)?;
    let manifest = Manifest::load(args.get_or("artifacts", "artifacts"))?;
    println!(
        "{:<16} {:>10} {:>6} {:>8} {:>8} {:>9}  artifacts",
        "preset", "params", "rank", "d_model", "layers", "state MB"
    );
    for (name, p) in &manifest.presets {
        println!(
            "{:<16} {:>10} {:>6} {:>8} {:>8} {:>9.1}  {}",
            name,
            p.model.param_count,
            p.model.rank.map(|r| r.to_string()).unwrap_or_else(|| "-".into()),
            p.model.d_model,
            p.model.n_layers,
            p.state_bytes() as f64 / 1e6,
            p.artifacts.keys().cloned().collect::<Vec<_>>().join(",")
        );
    }
    Ok(())
}
