//! Fine-tuning gradient-integrity experiment — Table 4 (§4.4, scaled).
//!
//! Paper protocol: take a dense pre-trained model, convert its MLP weights
//! to spectral form at 95% energy retention, fine-tune converted and dense
//! models on the same data / seed / LR, and compare final loss and PPL. The
//! point is gradient integrity through the factored parameterization, not
//! compression (their 135M testbed compresses barely; so does our tiny one).
//!
//! Scaled protocol here:
//! 1. "pre-train" the dense tiny preset on corpus A (rust-driven, real
//!    training through the dense artifact);
//! 2. read the dense MLP weights back, truncated-SVD them at 95% energy
//!    (rust Jacobi SVD), pad to the artifact rank (orthonormal completion),
//!    and write them into a spectral session *initialized with the same
//!    non-MLP weights*;
//! 3. fine-tune both on corpus B, same seed/LR/steps;
//! 4. report Table 4: final loss, PPL, trainable params, PPL ratio.

use anyhow::{bail, Context, Result};

use crate::data::{build_dataset, Prefetcher};
use crate::metrics::Tracker;
use crate::runtime::Session;
use crate::spectral::{svd, Matrix};

#[derive(Debug)]
pub struct FinetuneRow {
    pub label: String,
    pub final_loss: f32,
    pub ppl: f32,
    pub trainable_params: usize,
    pub initial_loss: f32,
}

#[derive(Debug)]
pub struct FinetuneResult {
    pub dense: FinetuneRow,
    pub sct: FinetuneRow,
    pub energy_ranks: Vec<usize>,
    pub artifact_rank: usize,
}

pub struct FinetuneOpts {
    pub artifacts_root: String,
    pub dense_preset: String,
    pub spectral_preset: String,
    pub pretrain_steps: usize,
    pub finetune_steps: usize,
    pub energy: f32,
    pub seed: u64,
    pub lr: f32,
}

impl Default for FinetuneOpts {
    fn default() -> FinetuneOpts {
        FinetuneOpts {
            artifacts_root: "artifacts".into(),
            dense_preset: "tiny_dense".into(),
            spectral_preset: "tiny_r32".into(),
            pretrain_steps: 150,
            finetune_steps: 100,
            energy: 0.95,
            seed: 0,
            lr: 1e-3,
        }
    }
}

pub fn run_finetune(opts: &FinetuneOpts) -> Result<FinetuneResult> {
    // ---- phase 1: dense pre-training -------------------------------------
    let mut dense = Session::open(&opts.artifacts_root, &opts.dense_preset)?;
    dense.init(opts.seed as i32)?;
    let model = dense.preset.model.clone();
    let spec = dense.preset.tokens_spec()?.clone();
    let (_t, ds) = build_dataset(
        model.vocab,
        spec.shape[0],
        spec.shape[1],
        1 << 20,
        opts.seed,
    );
    let pf = Prefetcher::spawn(ds, dense.chunk_len().unwrap_or(1), 4);
    eprintln!("[finetune] pre-training dense for {} steps", opts.pretrain_steps);
    let chunk = dense.chunk_len().unwrap_or(1);
    let mut done = 0;
    while done < opts.pretrain_steps {
        let tokens = pf.next();
        if chunk > 1 {
            dense.train_chunk(&tokens, opts.lr, opts.lr)?;
            done += chunk;
        } else {
            dense.train_step(&tokens, opts.lr, opts.lr)?;
            done += 1;
        }
    }
    drop(pf);

    // ---- phase 2: spectral conversion at 95% energy ----------------------
    let mut sct = Session::open(&opts.artifacts_root, &opts.spectral_preset)?;
    sct.init(opts.seed as i32)?;
    let k_art = sct.preset.model.rank.context("spectral preset must have a rank")?;
    if sct.preset.model.d_model != model.d_model || sct.preset.model.n_layers != model.n_layers {
        bail!("dense and spectral presets must share the architecture");
    }

    // copy every non-MLP parameter verbatim (embed, attention, norms)
    let mut energy_ranks = Vec::new();
    for spec_t in sct.state_specs().to_vec() {
        let name = spec_t.name.clone();
        if !name.starts_with("params/") {
            continue; // leave optimizer state fresh
        }
        if name.contains("/mlp/") {
            continue; // handled below
        }
        let (shape, data) = dense.tensor_f32(&name)?;
        sct.set_tensor(&name, &shape, &data)?;
    }

    // convert each MLP matrix: truncated SVD @ energy, pad to k_art
    let mut rng = crate::util::rng::Rng::new(opts.seed ^ 0x9e37);
    for layer in 0..model.n_layers {
        for mat in ["gate", "up", "down"] {
            let dense_name = format!("params/layers/{layer}/mlp/{mat}");
            let (shape, data) = dense.tensor_f32(&dense_name)?;
            let w = Matrix::from_vec(shape[0], shape[1], data);
            let full = svd(&w);
            let r95 = full.energy_rank(opts.energy);
            energy_ranks.push(r95);
            let k_eff = r95.min(k_art);
            let padded = full.truncate(k_eff).pad_to(k_art, &mut rng);
            // v is stored as (n, k); our Svd.v already is (n, k)
            sct.set_tensor(&format!("{dense_name}/u"), &[shape[0], k_art], &padded.u.data)?;
            sct.set_tensor(&format!("{dense_name}/s"), &[k_art], &padded.s)?;
            sct.set_tensor(&format!("{dense_name}/v"), &[shape[1], k_art], &padded.v.data)?;
        }
    }
    // factors came from SVD: orthonormal, but retract once for hygiene
    sct.retract()?;
    let ortho = sct.ortho_check()?;
    if ortho > 2e-6 {
        bail!("conversion produced non-orthonormal factors: {ortho}");
    }

    // ---- phase 3: fine-tune both on corpus B, same seed ------------------
    let ft = |session: &mut Session, label: &str| -> Result<FinetuneRow> {
        let (_t, ds) = build_dataset(
            model.vocab,
            spec.shape[0],
            spec.shape[1],
            1 << 20,
            opts.seed + 1000, // corpus B
        );
        let pf = Prefetcher::spawn(ds, session.chunk_len().unwrap_or(1), 4);
        let chunk = session.chunk_len().unwrap_or(1);
        let mut tracker = Tracker::paper();
        let mut initial = None;
        let mut done = 0;
        while done < opts.finetune_steps {
            let tokens = pf.next();
            if chunk > 1 {
                let losses = session.train_chunk(&tokens, opts.lr, opts.lr)?;
                if initial.is_none() {
                    initial = losses.first().copied();
                }
                tracker.record_losses(&losses, 0.0);
                done += chunk;
            } else {
                let loss = session.train_step(&tokens, opts.lr, opts.lr)?;
                if initial.is_none() {
                    initial = Some(loss);
                }
                tracker.record(loss, 0.0);
                done += 1;
            }
        }
        Ok(FinetuneRow {
            label: label.to_string(),
            final_loss: tracker.smoothed_loss(),
            ppl: tracker.ppl(),
            trainable_params: session.preset.model.param_count,
            initial_loss: initial.unwrap_or(f32::NAN),
        })
    };

    eprintln!("[finetune] fine-tuning dense ({} steps)", opts.finetune_steps);
    let dense_row = ft(&mut dense, "Dense + AdamW")?;
    eprintln!("[finetune] fine-tuning SCT @ {:.0}% energy", opts.energy * 100.0);
    let sct_row = ft(&mut sct, "SCT (95% energy)")?;

    Ok(FinetuneResult { dense: dense_row, sct: sct_row, energy_ranks, artifact_rank: k_art })
}

pub fn render_table4(r: &FinetuneResult) -> String {
    let ratio = r.sct.ppl / r.dense.ppl;
    let mut out = String::new();
    out.push_str("Table 4 — fine-tuning gradient-integrity test (scaled)\n");
    out.push_str("| Method | Final Loss | Final PPL | Trainable Params | PPL Ratio |\n");
    out.push_str("|---|---|---|---|---|\n");
    out.push_str(&format!(
        "| {} | {:.3} | {:.1} | {} | 1.0x |\n",
        r.dense.label, r.dense.final_loss, r.dense.ppl, r.dense.trainable_params
    ));
    out.push_str(&format!(
        "| {} | {:.3} | {:.1} | {} | {:.2}x |\n",
        r.sct.label, r.sct.final_loss, r.sct.ppl, r.sct.trainable_params, ratio
    ));
    let mean_rank =
        r.energy_ranks.iter().sum::<usize>() as f64 / r.energy_ranks.len().max(1) as f64;
    out.push_str(&format!(
        "(95% energy rank: mean {mean_rank:.1} over {} matrices, artifact rank {}; \
         initial losses dense {:.2} / sct {:.2})\n",
        r.energy_ranks.len(),
        r.artifact_rank,
        r.dense.initial_loss,
        r.sct.initial_loss
    ));
    out
}
