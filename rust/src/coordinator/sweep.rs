//! The rank-sweep driver — Table 3, Figure 2 and Figure 3 (scaled).
//!
//! Paper protocol (§4.2): dense baseline vs SCT at four ranks, same data,
//! same steps, dense LR 2e-5, SCT LR 5e-4, loss/PPL smoothed over 50 steps.
//! Here the SmolLM2-1.7B testbed is scaled to the `sweep_*` presets (same
//! architecture family; ranks 8..64 occupy the same relative band as the
//! paper's 32..256 — DESIGN.md §4) and "GPU memory" becomes the training
//! state footprint (weights+grads+moments), the device-agnostic part of the
//! paper's VRAM column.

use anyhow::Result;

use super::config::RunConfig;
use super::schedule::LrPlan;
use super::trainer::RunSummary;
#[cfg(feature = "pjrt")]
use super::trainer::Trainer;
use crate::metrics::plot;

/// One Table 3 row.
#[derive(Debug)]
pub struct SweepRow {
    pub label: String,
    pub params_m: f64,
    pub mlp_compression: f64,
    pub loss: f32,
    pub ppl: f32,
    pub state_mb: f64,
    pub step_ms: f64,
    pub ortho: Option<f32>,
}

pub struct SweepResult {
    pub rows: Vec<SweepRow>,
    pub curves: Vec<(String, Vec<f32>)>,
}

/// Run the full sweep. `presets` are (label, preset, lr_plan) triples.
#[cfg(feature = "pjrt")]
pub fn run_sweep(
    base: &RunConfig,
    presets: &[(String, String, LrPlan)],
) -> Result<SweepResult> {
    let mut rows = Vec::new();
    let mut curves = Vec::new();
    for (label, preset, plan) in presets {
        let mut cfg = base.clone();
        cfg.preset = preset.clone();
        cfg.lr_plan = plan.clone();
        eprintln!("[sweep] {label}: preset={preset} steps={}", cfg.steps);
        let mut trainer = Trainer::new(cfg)?;
        let summary = trainer.run()?;
        let compression = trainer.mlp_compression();
        rows.push(to_row(label, compression, &summary));
        let mut t = crate::metrics::Tracker::paper();
        t.record_losses(&summary.losses, 0.0);
        curves.push((label.clone(), t.smoothed_series()));
    }
    Ok(SweepResult { rows, curves })
}

/// The native twin of [`run_sweep`]: rerun the paper's rank-sweep protocol
/// through the pure-Rust training engine — one `run_native` per rank, same
/// steps, same data stream — with no PJRT and no AOT artifacts anywhere.
/// On this path a different rank is just a different matrix width, so the
/// sweep needs no per-rank compiled preset.
pub fn run_sweep_native(base: &RunConfig, ranks: &[usize]) -> Result<SweepResult> {
    let mut rows = Vec::new();
    let mut curves = Vec::new();
    for &k in ranks {
        let mut cfg = base.clone();
        cfg.backend = "native".into();
        cfg.native_model.rank = k;
        cfg.ckpt_dir = None; // sweep runs are throwaway measurements
        // A [rank] policy inherited from a shared config would mutate ranks
        // mid-run and silently falsify the per-rank rows — the sweep's whole
        // point is holding k fixed per run.
        cfg.rank_policy = crate::rank::RankPolicyConfig::Fixed;
        let cap = cfg.native_model.d_model.min(cfg.native_model.d_ffn);
        anyhow::ensure!(
            k >= 1 && k <= cap,
            "sweep rank {k} out of range for ({}, {})",
            cfg.native_model.d_model,
            cfg.native_model.d_ffn
        );
        let label = format!("SCT r={k}");
        eprintln!("[sweep] {label}: native backend, steps={}", cfg.steps);
        let (summary, _tracker) = super::trainer::run_native(&cfg, false)?;
        rows.push(to_row(&label, crate::train::mlp_compression(&cfg.native_model), &summary));
        let mut t = crate::metrics::Tracker::paper();
        t.record_losses(&summary.losses, 0.0);
        curves.push((label, t.smoothed_series()));
    }
    Ok(SweepResult { rows, curves })
}

fn to_row(label: &str, compression: f64, s: &RunSummary) -> SweepRow {
    SweepRow {
        label: label.to_string(),
        params_m: s.params as f64 / 1e6,
        mlp_compression: compression,
        loss: s.final_loss_smoothed,
        ppl: s.ppl,
        state_mb: s.state_bytes as f64 / 1e6,
        step_ms: s.mean_step_s * 1e3,
        ortho: s.ortho_error,
    }
}

/// The default sweep: dense + four ranks.
///
/// LR calibration note (DESIGN.md §4): the paper's literal pairing
/// (dense 2e-5 vs SCT 5e-4) is tied to *fine-tuning a pretrained 1.7B* —
/// at 2e-5 a from-scratch model barely moves in 2000 steps. Our scaled runs
/// train from scratch, so the dense baseline gets a from-scratch-calibrated
/// 3e-4 while SCT keeps the paper's hotter 5e-4; the paper's qualitative
/// picture (dense floor below SCT; all SCT ranks at one floor) is what is
/// being reproduced. Use [`LrPlan::paper_dense`] directly to run the
/// paper's literal configuration.
pub fn paper_presets(split_lr: bool) -> Vec<(String, String, LrPlan)> {
    let mut v = vec![(
        "Dense".to_string(),
        "sweep_dense".to_string(),
        // LR parity with SCT: from scratch, capacity ordering (dense below
        // every rank) is only meaningful at a matched learning rate.
        LrPlan::split(5e-4, 5e-4),
    )];
    for k in [64usize, 32, 16, 8] {
        let plan = if split_lr {
            // §5's per-component proposal: dense-calibrated LR for
            // attention/embeddings, hot LR for the spectral factors.
            LrPlan::split(3e-4, 5e-4)
        } else {
            LrPlan::paper_sct()
        };
        v.push((format!("SCT r={k}"), format!("sweep_r{k}"), plan));
    }
    v
}

/// Render Table 3 in the paper's column order.
pub fn render_table3(rows: &[SweepRow]) -> String {
    let mut out = String::new();
    out.push_str("Table 3 — rank sweep (scaled testbed; see DESIGN.md §4)\n");
    out.push_str("| Method | Params | MLP Comp. | Loss | PPL | State Mem. | Step Time | Ortho |\n");
    out.push_str("|---|---|---|---|---|---|---|---|\n");
    for r in rows {
        out.push_str(&format!(
            "| {} | {:.1}M | {:.1}x | {:.2} | {:.1} | {:.1} MB | {:.0} ms | {} |\n",
            r.label,
            r.params_m,
            r.mlp_compression,
            r.loss,
            r.ppl,
            r.state_mb,
            r.step_ms,
            r.ortho.map(|o| format!("{o:.1e}")).unwrap_or_else(|| "-".into()),
        ));
    }
    out
}

/// Figure 2: smoothed loss curves, all runs on one grid.
pub fn render_fig2(curves: &[(String, Vec<f32>)]) -> String {
    format!(
        "Figure 2 — loss convergence (smoothed, window=50)\n{}",
        plot::line_plot(curves, 18, 72)
    )
}

/// Figure 3: compression-vs-PPL Pareto + state-memory bars.
pub fn render_fig3(rows: &[SweepRow]) -> String {
    let pts: Vec<(String, f64, f64)> = rows
        .iter()
        .map(|r| (r.label.clone(), r.mlp_compression, r.ppl as f64))
        .collect();
    let mut out = format!(
        "Figure 3 (left) — compression vs quality Pareto\n{}",
        plot::scatter_plot(&pts, 14, 60)
    );
    out.push_str("\nFigure 3 (right) — training-state memory by method\n");
    let max_mb = rows.iter().map(|r| r.state_mb).fold(0.0, f64::max).max(1e-9);
    for r in rows {
        let chars = (r.state_mb / max_mb * 50.0).round() as usize;
        out.push_str(&format!(
            "{:<10} {:>8.1} MB |{}\n",
            r.label,
            r.state_mb,
            "#".repeat(chars.max(1))
        ));
    }
    out
}

/// The §4.3 observations, computed from our rows (printed with the tables so
/// the qualitative claims are machine-checked, not eyeballed).
pub fn check_observations(rows: &[SweepRow]) -> Vec<(String, bool)> {
    let dense = rows.iter().find(|r| r.label.starts_with("Dense"));
    let scts: Vec<&SweepRow> = rows.iter().filter(|r| r.label.starts_with("SCT")).collect();
    let mut checks = Vec::new();
    if let Some(d) = dense {
        let best_sct = scts.iter().map(|r| r.loss).fold(f32::INFINITY, f32::min);
        checks.push((
            "dense converges below every SCT rank (paper Fig 2)".to_string(),
            d.loss < best_sct,
        ));
        let fastest = scts.iter().map(|r| r.step_ms).fold(f64::INFINITY, f64::min);
        checks.push((
            "SCT steps are faster than dense (paper: 2.1x at r=32)".to_string(),
            fastest < d.step_ms,
        ));
        let min_mem = scts.iter().map(|r| r.state_mb).fold(f64::INFINITY, f64::min);
        checks.push((
            "SCT state memory below dense (paper: 46% reduction)".to_string(),
            min_mem < d.state_mb,
        ));
    }
    if scts.len() >= 2 {
        let max = scts.iter().map(|r| r.loss).fold(f32::NEG_INFINITY, f32::max);
        let min = scts.iter().map(|r| r.loss).fold(f32::INFINITY, f32::min);
        checks.push((
            format!("all ranks converge to the same loss floor (spread {:.2})", max - min),
            max - min < 0.5,
        ));
    }
    checks
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::EngineConfig;

    #[test]
    fn native_sweep_produces_rows_curves_and_checks() {
        let base = RunConfig {
            steps: 4,
            eval_every: 0,
            ortho_every: 2,
            corpus_bytes: 60_000,
            batch: 2,
            seq_len: 12,
            native_model: EngineConfig {
                vocab: 256,
                d_model: 16,
                n_layers: 1,
                n_heads: 2,
                d_ffn: 24,
                rank: 3,
                max_seq: 16,
                tied: true,
            },
            ..RunConfig::default()
        };
        let res = run_sweep_native(&base, &[2, 4]).unwrap();
        assert_eq!(res.rows.len(), 2);
        assert!(res.rows[0].label.contains("r=2"));
        assert!(res.rows.iter().all(|r| r.loss.is_finite() && r.step_ms >= 0.0));
        // rank 4 triples hold more parameters than rank 2
        assert!(res.rows[1].params_m > res.rows[0].params_m);
        assert_eq!(res.curves.len(), 2);
        assert_eq!(res.curves[0].1.len(), 4);
        let table = render_table3(&res.rows);
        assert!(table.contains("SCT r=4"), "{table}");
        let checks = check_observations(&res.rows);
        assert!(!checks.is_empty(), "same-floor observation must be computed");
        // out-of-range rank is a clean error, not a panic
        assert!(run_sweep_native(&base, &[17]).is_err());
    }
}
