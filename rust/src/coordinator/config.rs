//! Run configuration: defaults, TOML-subset file loading, CLI overrides.
//!
//! The config system is layered exactly like the big training frameworks:
//! built-in defaults < config file (`--config run.toml`) < CLI flags. The
//! offline image has no `toml` crate, so [`parse_toml`] implements the
//! subset the configs use: `[section]` tables, `key = value` with strings,
//! integers, floats, booleans and flat arrays, plus `#` comments.
//!
//! The `[runtime]` section holds execution knobs shared by every
//! subcommand: `threads` — the worker-pool size for the parallel kernels
//! (`util::pool`), resolved as `--threads` flag > `[runtime] threads` >
//! `SCT_THREADS` env > all cores — and `par_threshold` — the matmul
//! fan-out threshold in inner-loop MACs, resolved as `[runtime]
//! par_threshold` > `SCT_PAR_THRESHOLD` env > the pool default calibrated
//! for the blocked SIMD microkernels. Results are bit-identical at any
//! setting of either knob (the pool's determinism contract), so both only
//! move throughput.
//!
//! The `[serve]` section sizes the inference server
//! ([`crate::serve::ServeConfig`]): `addr`, `workers` — worker schedulers
//! behind the gateway, one engine clone + KV arena each (`--workers` flag >
//! `[serve] workers` > `SCT_WORKERS` env > 1; like `threads`, the setting
//! never changes T=0 output, only throughput), `slots` and `queue_depth`
//! (both per worker), `max_new`, `prefill_chunk`, `keep_alive_ms`.
//!
//! The `[obs]` section configures the observability layer ([`crate::obs`]),
//! shared by `sct train` and `sct serve` (flags win over the file):
//! `log_level` — `quiet|error|warn|info|debug`, the `--log-level` default
//! (overrides `SCT_LOG`); `metrics_out` — path for registry JSONL snapshots
//! during training (`--metrics-out`); `metrics_every` — snapshot cadence in
//! optimizer steps (`--metrics-every`, default 10); `trace_out` — path for
//! per-request span records during serving (`--trace-out`); `profile_out` —
//! path for the profiler report (`--profile-out`; enables
//! [`crate::obs::prof`] for the run and writes JSON plus a sibling `.folded`
//! flamegraph file at the end); `spectra_out` / `spectra_every` — per-layer
//! spectral-health JSONL snapshots during native training
//! (`--spectra-out`, cadence default 25); `watchdog` — arm the training
//! watchdog with policy `warn|skip|halt`, tuned by `watchdog_spike_factor`
//! (loss spike vs rolling-window mean, default 3.0) and `watchdog_grad_max`
//! (gradient-norm explosion threshold, default 1e3).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::schedule::{LrPlan, Schedule};
use crate::rank::RankPolicyConfig;
use crate::serve::EngineConfig;

/// A parsed TOML-subset document: section -> key -> raw value.
pub type TomlDoc = BTreeMap<String, BTreeMap<String, TomlValue>>;

#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_f32(&self) -> Result<f32> {
        match self {
            TomlValue::Float(f) => Ok(*f as f32),
            TomlValue::Int(i) => Ok(*i as f32),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        match self {
            TomlValue::Int(i) if *i >= 0 => Ok(*i as usize),
            _ => bail!("expected non-negative integer, got {self:?}"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            TomlValue::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            TomlValue::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }
}

/// Parse the TOML subset. Unknown syntax is an error, not a silent skip.
///
/// Array-of-tables headers (`[[name]]`) are supported by storing each
/// occurrence as a section keyed `name#<index>`; read them back with
/// [`array_sections`].
pub fn parse_toml(text: &str) -> Result<TomlDoc> {
    let mut doc = TomlDoc::new();
    let mut section = String::new();
    doc.insert(String::new(), BTreeMap::new());
    let mut array_counts: BTreeMap<String, usize> = BTreeMap::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim().to_string();
        if line.is_empty() {
            continue;
        }
        // `[[name]]` must be checked before `[name]` (the single-bracket
        // pattern would otherwise swallow one bracket pair).
        if let Some(name) = line.strip_prefix("[[").and_then(|s| s.strip_suffix("]]")) {
            let name = name.trim();
            let idx = array_counts.entry(name.to_string()).or_insert(0);
            section = format!("{name}#{idx}");
            *idx += 1;
            doc.entry(section.clone()).or_default();
            continue;
        }
        if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
            section = name.trim().to_string();
            doc.entry(section.clone()).or_default();
            continue;
        }
        let Some((key, value)) = line.split_once('=') else {
            bail!("line {}: expected `key = value`, got {raw:?}", lineno + 1);
        };
        let value = parse_value(value.trim())
            .with_context(|| format!("line {}: bad value {:?}", lineno + 1, value.trim()))?;
        doc.get_mut(&section).unwrap().insert(key.trim().to_string(), value);
    }
    Ok(doc)
}

/// Read `[runtime] threads` from a parsed document (0 = absent/auto) — the
/// single home of that key, shared by [`RunConfig::apply_toml`] and the
/// serve CLI path (which carries no `RunConfig`).
pub fn runtime_threads(doc: &TomlDoc) -> Result<usize> {
    match doc.get("runtime").and_then(|r| r.get("threads")) {
        Some(v) => v.as_usize(),
        None => Ok(0),
    }
}

/// Read `[runtime] par_threshold` (0 = absent/auto) — the matmul fan-out
/// threshold for `util::pool::set_par_threshold`, shared by
/// [`RunConfig::apply_toml`] and the serve CLI path like
/// [`runtime_threads`].
pub fn runtime_par_threshold(doc: &TomlDoc) -> Result<usize> {
    match doc.get("runtime").and_then(|r| r.get("par_threshold")) {
        Some(v) => v.as_usize(),
        None => Ok(0),
    }
}

/// The tables of a `[[name]]` array, in declaration order.
pub fn array_sections<'a>(doc: &'a TomlDoc, name: &str) -> Vec<&'a BTreeMap<String, TomlValue>> {
    let prefix = format!("{name}#");
    let mut found: Vec<(usize, &BTreeMap<String, TomlValue>)> = doc
        .iter()
        .filter_map(|(k, table)| {
            k.strip_prefix(&prefix)
                .and_then(|i| i.parse::<usize>().ok())
                .map(|i| (i, table))
        })
        .collect();
    found.sort_by_key(|&(i, _)| i);
    found.into_iter().map(|(_, t)| t).collect()
}

fn strip_comment(line: &str) -> &str {
    // '#' outside a string starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(v: &str) -> Result<TomlValue> {
    if let Some(inner) = v.strip_prefix('"').and_then(|s| s.strip_suffix('"')) {
        return Ok(TomlValue::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if v == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if v == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = v.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(TomlValue::Array(Vec::new()));
        }
        let items = inner
            .split(',')
            .map(|s| parse_value(s.trim()))
            .collect::<Result<Vec<_>>>()?;
        return Ok(TomlValue::Array(items));
    }
    if let Ok(i) = v.replace('_', "").parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = v.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    bail!("unparseable value")
}

/// Observability knobs — the `[obs]` TOML section (see the module docs),
/// mirrored by CLI flags which take precedence.
#[derive(Debug, Clone, PartialEq)]
pub struct ObsConfig {
    /// Log level name (`quiet|error|warn|info|debug`); `None` = leave the
    /// `SCT_LOG` / default-`info` resolution alone.
    pub log_level: Option<String>,
    /// Path for metric-registry JSONL snapshots during training.
    pub metrics_out: Option<String>,
    /// Snapshot cadence in optimizer steps (with `metrics_out`).
    pub metrics_every: usize,
    /// Path for per-request span records (JSONL) during serving.
    pub trace_out: Option<String>,
    /// Path for the profiler report: enables `obs::prof` for the run and
    /// writes JSON there (plus `<path>.folded` collapsed stacks) at the end.
    pub profile_out: Option<String>,
    /// Path for per-layer spectral-health JSONL snapshots during native
    /// training (`rank::spectra`: spectrum, tail curve, effective rank,
    /// condition, ortho error, subspace drift).
    pub spectra_out: Option<String>,
    /// Spectra sampling cadence in optimizer steps (with `spectra_out`).
    pub spectra_every: usize,
    /// Training-watchdog policy (`warn|skip|halt`); `None` = watchdog off.
    pub watchdog: Option<String>,
    /// Loss counts as a spike above `factor x` the rolling-window mean.
    pub watchdog_spike_factor: f32,
    /// Gradient global norm above this is an explosion anomaly.
    pub watchdog_grad_max: f64,
    /// Test hook: feed the watchdog a synthetic NaN loss at this step (the
    /// CI watchdog smoke; CLI-only, not a TOML key).
    pub watchdog_inject_nan: Option<u64>,
}

impl Default for ObsConfig {
    fn default() -> ObsConfig {
        ObsConfig {
            log_level: None,
            metrics_out: None,
            metrics_every: 10,
            trace_out: None,
            profile_out: None,
            spectra_out: None,
            spectra_every: 25,
            watchdog: None,
            watchdog_spike_factor: 3.0,
            watchdog_grad_max: 1e3,
            watchdog_inject_nan: None,
        }
    }
}

impl ObsConfig {
    /// Apply an `[obs]` section. Standalone (not only via
    /// [`RunConfig::apply_toml`]) because `sct serve` reads config files
    /// without carrying a `RunConfig`.
    pub fn apply_toml(&mut self, doc: &TomlDoc) -> Result<()> {
        let Some(o) = doc.get("obs") else { return Ok(()) };
        if let Some(v) = o.get("log_level") {
            let name = v.as_str()?;
            if crate::obs::log::parse_level(name).is_none() {
                bail!("[obs] log_level {name:?} unknown (expected quiet|error|warn|info|debug)");
            }
            self.log_level = Some(name.to_string());
        }
        if let Some(v) = o.get("metrics_out") {
            self.metrics_out = Some(v.as_str()?.to_string());
        }
        if let Some(v) = o.get("metrics_every") {
            self.metrics_every = v.as_usize()?.max(1);
        }
        if let Some(v) = o.get("trace_out") {
            self.trace_out = Some(v.as_str()?.to_string());
        }
        if let Some(v) = o.get("profile_out") {
            self.profile_out = Some(v.as_str()?.to_string());
        }
        if let Some(v) = o.get("spectra_out") {
            self.spectra_out = Some(v.as_str()?.to_string());
        }
        if let Some(v) = o.get("spectra_every") {
            self.spectra_every = v.as_usize()?.max(1);
        }
        if let Some(v) = o.get("watchdog") {
            let policy = v.as_str()?;
            if policy.parse::<crate::obs::health::Policy>().is_err() {
                bail!("[obs] watchdog {policy:?} unknown (expected warn|skip|halt)");
            }
            self.watchdog = Some(policy.to_string());
        }
        if let Some(v) = o.get("watchdog_spike_factor") {
            self.watchdog_spike_factor = v.as_f32()?;
        }
        if let Some(v) = o.get("watchdog_grad_max") {
            self.watchdog_grad_max = v.as_f32()? as f64;
        }
        Ok(())
    }

    /// The armed watchdog configuration, or `None` when the watchdog is off.
    /// Policy strings are validated at parse time, so this never fails on a
    /// config that passed `apply_toml` / CLI validation.
    pub fn watchdog_config(&self) -> Option<crate::obs::health::WatchdogConfig> {
        let policy = self.watchdog.as_deref()?.parse().ok()?;
        Some(crate::obs::health::WatchdogConfig {
            policy,
            spike_factor: self.watchdog_spike_factor,
            grad_max: self.watchdog_grad_max,
            ..Default::default()
        })
    }

    /// Apply the configured level to the global logger (call after flags
    /// have overridden `log_level`).
    pub fn apply_log_level(&self) {
        if let Some(name) = &self.log_level {
            if let Some(l) = crate::obs::log::parse_level(name) {
                crate::obs::log::set_level(l);
            }
        }
    }
}

/// Everything a training run needs.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub preset: String,
    /// Execution backend: `"pjrt"` dispatches the AOT artifacts (needs the
    /// `pjrt` feature), `"native"` runs the pure-Rust training engine.
    pub backend: String,
    pub steps: usize,
    pub seed: u64,
    pub lr_plan: LrPlan,
    /// Use the fused K-step train_chunk artifact when available.
    pub chunked: bool,
    pub eval_every: usize,
    pub ortho_every: usize,
    pub corpus_bytes: usize,
    pub ckpt_dir: Option<String>,
    pub ckpt_every: usize,
    pub artifacts_root: String,
    pub out_dir: String,
    /// Global gradient-norm clip (native backend; 0 disables).
    pub grad_clip: f32,
    /// Decoupled weight decay on decay-eligible tensors — attention
    /// matrices and an untied head; never embeddings, norms or the
    /// spectral factors (native backend).
    pub weight_decay: f32,
    /// QR-retract every N steps (native backend; paper default 1).
    pub retract_every: usize,
    /// Batch size (native backend; the pjrt path reads it from the artifact).
    pub batch: usize,
    /// Input sequence length T (native backend).
    pub seq_len: usize,
    /// Model geometry for the native backend (`[model]` TOML section /
    /// `sct train` shape flags; the pjrt path gets geometry from its preset).
    pub native_model: EngineConfig,
    /// Rank-transition policy for the native backend (`[rank]` TOML section
    /// + `[[rank.schedule]]` milestones, or `sct train --rank-schedule`).
    pub rank_policy: RankPolicyConfig,
    /// Worker-pool threads for the parallel kernels (`[runtime] threads` /
    /// `--threads`; 0 = auto: `SCT_THREADS` env, else all cores). Purely a
    /// throughput knob — results are bit-identical at any setting.
    pub threads: usize,
    /// Matmul fan-out threshold in inner-loop MACs (`[runtime]
    /// par_threshold`; 0 = auto: `SCT_PAR_THRESHOLD` env, else the pool's
    /// default calibrated for the blocked SIMD kernels). Like `threads`,
    /// purely a throughput knob.
    pub par_threshold: usize,
    /// Observability knobs (`[obs]` section / `--log-level`,
    /// `--metrics-out`, `--metrics-every` flags).
    pub obs: ObsConfig,
}

impl Default for RunConfig {
    fn default() -> RunConfig {
        RunConfig {
            preset: "sweep_r16".into(),
            backend: "pjrt".into(),
            steps: 200,
            seed: 0,
            lr_plan: LrPlan::paper_sct(),
            chunked: true,
            eval_every: 50,
            ortho_every: 100,
            corpus_bytes: 1 << 20,
            ckpt_dir: None,
            ckpt_every: 0,
            artifacts_root: "artifacts".into(),
            out_dir: "runs".into(),
            grad_clip: 1.0,
            weight_decay: 0.0,
            retract_every: 1,
            batch: 8,
            seq_len: 64,
            native_model: EngineConfig::default(),
            rank_policy: RankPolicyConfig::Fixed,
            threads: 0,
            par_threshold: 0,
            obs: ObsConfig::default(),
        }
    }
}

impl RunConfig {
    /// Apply a `[train]` section from a TOML file.
    pub fn apply_toml(&mut self, doc: &TomlDoc) -> Result<()> {
        let empty = BTreeMap::new();
        let t = doc.get("train").unwrap_or(&empty);
        if let Some(v) = t.get("preset") {
            self.preset = v.as_str()?.to_string();
        }
        if let Some(v) = t.get("backend") {
            self.backend = v.as_str()?.to_string();
        }
        if let Some(v) = t.get("grad_clip") {
            self.grad_clip = v.as_f32()?;
        }
        if let Some(v) = t.get("weight_decay") {
            self.weight_decay = v.as_f32()?;
        }
        if let Some(v) = t.get("retract_every") {
            self.retract_every = v.as_usize()?;
        }
        if let Some(v) = t.get("batch") {
            self.batch = v.as_usize()?;
        }
        if let Some(v) = t.get("seq_len") {
            self.seq_len = v.as_usize()?;
        }
        if let Some(v) = t.get("steps") {
            self.steps = v.as_usize()?;
        }
        if let Some(v) = t.get("seed") {
            self.seed = v.as_usize()? as u64;
        }
        if let Some(v) = t.get("chunked") {
            self.chunked = v.as_bool()?;
        }
        if let Some(v) = t.get("eval_every") {
            self.eval_every = v.as_usize()?;
        }
        if let Some(v) = t.get("corpus_bytes") {
            self.corpus_bytes = v.as_usize()?;
        }
        if let Some(v) = t.get("ckpt_every") {
            self.ckpt_every = v.as_usize()?;
        }
        if let Some(v) = t.get("ckpt_dir") {
            self.ckpt_dir = Some(v.as_str()?.to_string());
        }
        if let Some(v) = t.get("artifacts") {
            self.artifacts_root = v.as_str()?.to_string();
        }
        if let Some(v) = t.get("out_dir") {
            self.out_dir = v.as_str()?.to_string();
        }
        // [runtime] section: execution knobs shared by every subcommand.
        let rt_threads = runtime_threads(doc)?;
        if rt_threads > 0 {
            self.threads = rt_threads;
        }
        let rt_par = runtime_par_threshold(doc)?;
        if rt_par > 0 {
            self.par_threshold = rt_par;
        }
        // [obs] section: logging / metrics / tracing knobs.
        self.obs.apply_toml(doc)?;
        // [model] section: native-backend model geometry.
        if let Some(m) = doc.get("model") {
            let mm = &mut self.native_model;
            for (key, field) in [
                ("vocab", &mut mm.vocab as &mut usize),
                ("d_model", &mut mm.d_model),
                ("n_layers", &mut mm.n_layers),
                ("n_heads", &mut mm.n_heads),
                ("d_ffn", &mut mm.d_ffn),
                ("rank", &mut mm.rank),
                ("max_seq", &mut mm.max_seq),
            ] {
                if let Some(v) = m.get(key) {
                    *field = v.as_usize()?;
                }
            }
            if let Some(v) = m.get("tied") {
                mm.tied = v.as_bool()?;
            }
        }
        // [rank] section + [[rank.schedule]] milestones: the adaptive-rank
        // policy for the native backend.
        //
        // ```toml
        // [rank]
        // policy = "tail-energy"   # or "fixed" / "schedule"
        // tail_frac = 0.25         # tail = ceil(tail_frac * k) smallest |s|
        // grow_above = 0.12        # grow when tail share exceeds this
        // shrink_below = 0.01      # shrink when tail share is below this
        // min_rank = 2
        // max_rank = 64
        // check_every = 50         # decision cadence in steps
        // step_frac = 0.25         # resize by ceil(step_frac * k) columns
        //
        // [[rank.schedule]]        # policy = "schedule" milestones
        // step = 200
        // rank = 16
        // [[rank.schedule]]
        // step = 800
        // rank = 32
        // ```
        let milestones = {
            let tables = array_sections(doc, "rank.schedule");
            let mut ms = Vec::with_capacity(tables.len());
            for t in tables {
                let step = t
                    .get("step")
                    .with_context(|| "[[rank.schedule]] entry missing `step`")?
                    .as_usize()? as u64;
                let rank = t
                    .get("rank")
                    .with_context(|| "[[rank.schedule]] entry missing `rank`")?
                    .as_usize()?;
                if rank == 0 {
                    bail!("[[rank.schedule]] rank must be >= 1");
                }
                ms.push((step, rank));
            }
            ms.sort_by_key(|&(s, _)| s);
            ms
        };
        if let Some(r) = doc.get("rank") {
            let policy = r.get("policy").map(|v| v.as_str()).transpose()?.unwrap_or(
                if milestones.is_empty() { "fixed" } else { "schedule" },
            );
            // Declared milestones under a non-schedule policy would be
            // silently dead config — same philosophy as the parser itself:
            // an error, not a silent skip.
            if !milestones.is_empty() && !matches!(policy, "schedule") {
                bail!(
                    "[[rank.schedule]] milestones conflict with [rank] policy = {policy:?}; \
                     use policy = \"schedule\" or remove the milestones"
                );
            }
            self.rank_policy = match policy {
                "fixed" => RankPolicyConfig::Fixed,
                "schedule" => {
                    if milestones.is_empty() {
                        bail!("[rank] policy = \"schedule\" needs [[rank.schedule]] milestones");
                    }
                    RankPolicyConfig::Schedule(milestones.clone())
                }
                "tail-energy" | "tail_energy" => {
                    // max_rank default = usize::MAX sentinel, resolved to
                    // the REAL min(d_model, d_ffn) by validated() at run
                    // time — geometry here may still change under CLI
                    // shape flags applied after this TOML pass.
                    let mut cfg = RankPolicyConfig::tail_energy_defaults(1, usize::MAX);
                    if let RankPolicyConfig::TailEnergy {
                        tail_frac,
                        grow_above,
                        shrink_below,
                        min_rank,
                        max_rank,
                        check_every,
                        step_frac,
                    } = &mut cfg
                    {
                        if let Some(v) = r.get("tail_frac") {
                            *tail_frac = v.as_f32()?;
                        }
                        if let Some(v) = r.get("grow_above") {
                            *grow_above = v.as_f32()?;
                        }
                        if let Some(v) = r.get("shrink_below") {
                            *shrink_below = v.as_f32()?;
                        }
                        if let Some(v) = r.get("min_rank") {
                            *min_rank = v.as_usize()?;
                        }
                        if let Some(v) = r.get("max_rank") {
                            *max_rank = v.as_usize()?;
                        }
                        if let Some(v) = r.get("check_every") {
                            *check_every = v.as_usize()? as u64;
                        }
                        if let Some(v) = r.get("step_frac") {
                            *step_frac = v.as_f32()?;
                        }
                    }
                    cfg
                }
                other => bail!(
                    "[rank] policy {other:?} unknown (expected \"fixed\", \"schedule\" \
                     or \"tail-energy\")"
                ),
            };
        } else if !milestones.is_empty() {
            self.rank_policy = RankPolicyConfig::Schedule(milestones);
        }
        // [lr] section: dense / spectral constants or cosine fields.
        if let Some(lr) = doc.get("lr") {
            let dense = lr.get("dense").map(|v| v.as_f32()).transpose()?;
            let spectral = lr.get("spectral").map(|v| v.as_f32()).transpose()?;
            let d = dense.unwrap_or(5e-4);
            let s = spectral.unwrap_or(d);
            if let (Some(warmup), Some(total)) = (lr.get("warmup"), lr.get("total")) {
                let (w, t_) = (warmup.as_usize()?, total.as_usize()?);
                let floor = lr.get("floor").map(|v| v.as_f32()).transpose()?.unwrap_or(0.0);
                self.lr_plan = LrPlan {
                    dense: Schedule::WarmupCosine { peak: d, floor, warmup: w, total: t_ },
                    spectral: Schedule::WarmupCosine { peak: s, floor, warmup: w, total: t_ },
                };
            } else {
                self.lr_plan = LrPlan::split(d, s);
            }
        }
        Ok(())
    }

    pub fn load_file(&mut self, path: &Path) -> Result<()> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        let doc = parse_toml(&text)?;
        self.apply_toml(&doc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# run config
[train]
preset = "sweep_r32"   # the rank-32 preset
steps = 2_000
seed = 7
chunked = false
ckpt_dir = "ckpts/sweep"

[lr]
dense = 2e-5
spectral = 5e-4
"#;

    #[test]
    fn parses_sample() {
        let doc = parse_toml(SAMPLE).unwrap();
        assert_eq!(doc["train"]["preset"], TomlValue::Str("sweep_r32".into()));
        assert_eq!(doc["train"]["steps"], TomlValue::Int(2000));
        assert_eq!(doc["lr"]["dense"], TomlValue::Float(2e-5));
    }

    #[test]
    fn applies_to_config() {
        let mut cfg = RunConfig::default();
        cfg.apply_toml(&parse_toml(SAMPLE).unwrap()).unwrap();
        assert_eq!(cfg.preset, "sweep_r32");
        assert_eq!(cfg.steps, 2000);
        assert_eq!(cfg.seed, 7);
        assert!(!cfg.chunked);
        assert_eq!(cfg.ckpt_dir.as_deref(), Some("ckpts/sweep"));
        assert_eq!(cfg.lr_plan.at(0), (2e-5, 5e-4));
    }

    #[test]
    fn native_backend_and_model_sections() {
        let text = r#"
[train]
backend = "native"
grad_clip = 0.5
weight_decay = 0.01
retract_every = 4
batch = 2
seq_len = 24

[model]
d_model = 48
rank = 6
tied = false
"#;
        let mut cfg = RunConfig::default();
        cfg.apply_toml(&parse_toml(text).unwrap()).unwrap();
        assert_eq!(cfg.backend, "native");
        assert_eq!(cfg.grad_clip, 0.5);
        assert!((cfg.weight_decay - 0.01).abs() < 1e-9);
        assert_eq!(cfg.retract_every, 4);
        assert_eq!(cfg.batch, 2);
        assert_eq!(cfg.seq_len, 24);
        assert_eq!(cfg.native_model.d_model, 48);
        assert_eq!(cfg.native_model.rank, 6);
        assert!(!cfg.native_model.tied);
        // untouched geometry keeps its default
        assert_eq!(cfg.native_model.vocab, 256);
    }

    #[test]
    fn array_of_tables_parses_in_order() {
        let text = r#"
[[rank.schedule]]
step = 200
rank = 16
[[rank.schedule]]
step = 800
rank = 32
"#;
        let doc = parse_toml(text).unwrap();
        let tables = array_sections(&doc, "rank.schedule");
        assert_eq!(tables.len(), 2);
        assert_eq!(tables[0]["step"], TomlValue::Int(200));
        assert_eq!(tables[1]["rank"], TomlValue::Int(32));
        assert!(array_sections(&doc, "nope").is_empty());
    }

    #[test]
    fn rank_schedule_section_applies() {
        let text = r#"
[train]
backend = "native"

[[rank.schedule]]
step = 800
rank = 32
[[rank.schedule]]
step = 200
rank = 16
"#;
        let mut cfg = RunConfig::default();
        cfg.apply_toml(&parse_toml(text).unwrap()).unwrap();
        // milestones sorted by step regardless of declaration order
        assert_eq!(cfg.rank_policy, RankPolicyConfig::Schedule(vec![(200, 16), (800, 32)]));
    }

    #[test]
    fn rank_tail_energy_section_applies() {
        let text = r#"
[rank]
policy = "tail-energy"
grow_above = 0.2
min_rank = 4
max_rank = 48
check_every = 25
"#;
        let mut cfg = RunConfig::default();
        cfg.apply_toml(&parse_toml(text).unwrap()).unwrap();
        match &cfg.rank_policy {
            RankPolicyConfig::TailEnergy { grow_above, min_rank, max_rank, check_every, tail_frac, .. } => {
                assert!((grow_above - 0.2).abs() < 1e-6);
                assert_eq!((*min_rank, *max_rank), (4, 48));
                assert_eq!(*check_every, 25);
                assert!((tail_frac - 0.25).abs() < 1e-6, "untouched knob keeps its default");
            }
            other => panic!("expected TailEnergy, got {other:?}"),
        }
    }

    #[test]
    fn rank_section_rejects_bad_input() {
        let mut cfg = RunConfig::default();
        // schedule policy without milestones
        let doc = parse_toml("[rank]\npolicy = \"schedule\"\n").unwrap();
        assert!(cfg.apply_toml(&doc).is_err());
        // unknown policy name
        let doc = parse_toml("[rank]\npolicy = \"magic\"\n").unwrap();
        assert!(cfg.apply_toml(&doc).is_err());
        // milestone missing a key
        let doc = parse_toml("[[rank.schedule]]\nstep = 5\n").unwrap();
        assert!(cfg.apply_toml(&doc).is_err());
        // milestones under a non-schedule policy are dead config -> error
        let doc = parse_toml(
            "[rank]\npolicy = \"tail-energy\"\n\n[[rank.schedule]]\nstep = 5\nrank = 8\n",
        )
        .unwrap();
        assert!(cfg.apply_toml(&doc).is_err());
        // rank 0 milestone
        let doc = parse_toml("[[rank.schedule]]\nstep = 5\nrank = 0\n").unwrap();
        assert!(cfg.apply_toml(&doc).is_err());
        // defaults stay Fixed when no [rank] config is present
        let mut fresh = RunConfig::default();
        fresh.apply_toml(&parse_toml(SAMPLE).unwrap()).unwrap();
        assert_eq!(fresh.rank_policy, RankPolicyConfig::Fixed);
    }

    #[test]
    fn runtime_threads_section_applies() {
        let mut cfg = RunConfig::default();
        assert_eq!(cfg.threads, 0, "default is auto");
        cfg.apply_toml(&parse_toml("[runtime]\nthreads = 3\n").unwrap()).unwrap();
        assert_eq!(cfg.threads, 3);
        // bad value is an error, not a silent skip
        let doc = parse_toml("[runtime]\nthreads = \"many\"\n").unwrap();
        assert!(cfg.apply_toml(&doc).is_err());
    }

    #[test]
    fn runtime_par_threshold_section_applies() {
        let mut cfg = RunConfig::default();
        assert_eq!(cfg.par_threshold, 0, "default is auto");
        let doc = parse_toml("[runtime]\nthreads = 2\npar_threshold = 65536\n").unwrap();
        cfg.apply_toml(&doc).unwrap();
        assert_eq!(cfg.par_threshold, 65536);
        assert_eq!(cfg.threads, 2, "both runtime keys coexist");
        let bad = parse_toml("[runtime]\npar_threshold = \"lots\"\n").unwrap();
        assert!(cfg.apply_toml(&bad).is_err());
        // the standalone reader used by the serve path
        assert_eq!(runtime_par_threshold(&doc).unwrap(), 65536);
        assert_eq!(runtime_par_threshold(&parse_toml("").unwrap()).unwrap(), 0);
    }

    #[test]
    fn obs_section_applies() {
        let text = r#"
[obs]
log_level = "debug"
metrics_out = "runs/metrics.jsonl"
metrics_every = 5
trace_out = "traces.jsonl"
profile_out = "profile.json"
spectra_out = "spectra.jsonl"
spectra_every = 7
watchdog = "skip"
watchdog_spike_factor = 4.5
watchdog_grad_max = 250.0
"#;
        let mut cfg = RunConfig::default();
        assert_eq!(cfg.obs, ObsConfig::default());
        assert_eq!(cfg.obs.metrics_every, 10, "default cadence");
        assert_eq!(cfg.obs.profile_out, None, "profiling is off by default");
        assert_eq!(cfg.obs.spectra_every, 25, "default spectra cadence");
        assert!(cfg.obs.watchdog.is_none(), "watchdog is off by default");
        assert!(cfg.obs.watchdog_config().is_none());
        cfg.apply_toml(&parse_toml(text).unwrap()).unwrap();
        assert_eq!(cfg.obs.log_level.as_deref(), Some("debug"));
        assert_eq!(cfg.obs.metrics_out.as_deref(), Some("runs/metrics.jsonl"));
        assert_eq!(cfg.obs.metrics_every, 5);
        assert_eq!(cfg.obs.trace_out.as_deref(), Some("traces.jsonl"));
        assert_eq!(cfg.obs.profile_out.as_deref(), Some("profile.json"));
        assert_eq!(cfg.obs.spectra_out.as_deref(), Some("spectra.jsonl"));
        assert_eq!(cfg.obs.spectra_every, 7);
        assert_eq!(cfg.obs.watchdog.as_deref(), Some("skip"));
        let wd = cfg.obs.watchdog_config().expect("watchdog armed");
        assert_eq!(wd.policy, crate::obs::health::Policy::Skip);
        assert!((wd.spike_factor - 4.5).abs() < 1e-6);
        assert!((wd.grad_max - 250.0).abs() < 1e-6);
        // unknown level name is an error, not a silent skip
        let doc = parse_toml("[obs]\nlog_level = \"loud\"\n").unwrap();
        assert!(cfg.apply_toml(&doc).is_err());
        // unknown watchdog policy is an error, not a silent skip
        let doc = parse_toml("[obs]\nwatchdog = \"loud\"\n").unwrap();
        assert!(cfg.apply_toml(&doc).is_err());
    }

    #[test]
    fn cosine_section() {
        let text = "[lr]\ndense = 1e-3\nwarmup = 10\ntotal = 100\nfloor = 1e-5\n";
        let mut cfg = RunConfig::default();
        cfg.apply_toml(&parse_toml(text).unwrap()).unwrap();
        let (d0, _) = cfg.lr_plan.at(0);
        let (d100, _) = cfg.lr_plan.at(100);
        assert!(d0 < 1e-3 && d100 <= 1.1e-5);
    }

    #[test]
    fn arrays_bools_strings() {
        let doc = parse_toml("x = [1, 2, 3]\nflag = true\ns = \"a # not comment\"").unwrap();
        assert_eq!(
            doc[""]["x"],
            TomlValue::Array(vec![TomlValue::Int(1), TomlValue::Int(2), TomlValue::Int(3)])
        );
        assert_eq!(doc[""]["flag"], TomlValue::Bool(true));
        assert_eq!(doc[""]["s"], TomlValue::Str("a # not comment".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_toml("key value no equals").is_err());
        assert!(parse_toml("k = @nope").is_err());
    }
}
