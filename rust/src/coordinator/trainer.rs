//! The training loop: data pipeline + schedules + metrics + checkpoints,
//! wired the way the paper's Algorithm 1 runs — over either backend.
//!
//! Two backends share the [`RunSummary`] contract:
//! * **pjrt** ([`Trainer`], `pjrt` feature): one PJRT dispatch per chunk
//!   (`train_chunk`, K fused steps) with batches prefetched on a producer
//!   thread; falls back to per-step dispatch when `chunked` is off or the
//!   artifact is missing (the pallas integration preset).
//! * **native** ([`run_native`], always built): the pure-Rust engine in
//!   [`crate::train`] — full backward through the shared decoder, AdamW on
//!   the compact factors, QR retraction — driven by the same
//!   warmup+cosine [`super::schedule::LrPlan`], eval/ortho cadences and
//!   rotating checkpoint manager, with no PJRT anywhere.

use std::time::Instant;

use anyhow::Result;

#[cfg(feature = "pjrt")]
use anyhow::Context;

use super::config::RunConfig;
use crate::checkpoint::CheckpointManager;
use crate::data::build_dataset;
use crate::metrics::{export, Tracker};
use crate::obs::{self, health};
use crate::rank::{
    model_energy, model_spectra, publish_energy, publish_ortho_error, spectra_json, DriftTracker,
    RankEvent,
};
use crate::serve::SpectralModel;
use crate::train::{NativeTrainConfig, NativeTrainer};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::{sct_error, sct_info, sct_warn};

#[cfg(feature = "pjrt")]
use crate::data::Prefetcher;
#[cfg(feature = "pjrt")]
use crate::runtime::Session;

/// Result of a training run — everything Table 3 needs for one row.
#[derive(Debug)]
pub struct RunSummary {
    pub label: String,
    pub params: usize,
    pub steps: usize,
    pub final_loss_smoothed: f32,
    pub ppl: f32,
    pub mean_step_s: f64,
    pub state_bytes: usize,
    pub eval_loss: Option<f32>,
    pub ortho_error: Option<f32>,
    pub losses: Vec<f32>,
    /// Rank transitions applied by the configured policy (native backend;
    /// empty under `Fixed` or on the pjrt path).
    pub rank_events: Vec<RankEvent>,
    /// Final per-layer MLP ranks (native backend; empty on the pjrt path).
    pub layer_ranks: Vec<usize>,
}

// ---------------------------------------------------------------------------
// native backend
// ---------------------------------------------------------------------------

/// Run `cfg.steps` native training steps on the bundled synthetic corpus:
/// the no-PJRT twin of [`Trainer::run`]. Honors the LR plan, eval/ortho
/// cadences, gradient clipping, retraction cadence, and (when `ckpt_dir` +
/// `ckpt_every` are set) rotating `.sct` checkpoints in the
/// `params/layers/...` layout — which `serve::SpectralModel::load` reads
/// directly. With `resume`, the newest checkpoint in `ckpt_dir` (if any)
/// restores model + optimizer moments before training continues.
pub fn run_native(cfg: &RunConfig, resume: bool) -> Result<(RunSummary, Tracker)> {
    let tcfg = NativeTrainConfig {
        model: cfg.native_model,
        batch: cfg.batch,
        seq_len: cfg.seq_len,
        grad_clip: cfg.grad_clip,
        retract_every: cfg.retract_every.max(1),
        weight_decay: cfg.weight_decay,
    };
    let mgr = match &cfg.ckpt_dir {
        Some(dir) if cfg.ckpt_every > 0 => Some(CheckpointManager::new(dir, 3)?),
        _ => None,
    };
    let mut trainer = match &mgr {
        Some(m) if resume => match m.latest()? {
            Some((step, path)) => {
                let t = NativeTrainer::load(&path, tcfg)?;
                sct_info!("resumed native run from step {step} ({})", path.display());
                t
            }
            None => NativeTrainer::new(tcfg, cfg.seed),
        },
        _ => NativeTrainer::new(tcfg, cfg.seed),
    };
    let m = trainer.cfg.model;

    let seq_plus1 = trainer.cfg.seq_len + 1;
    let (_tok, mut dataset) =
        build_dataset(m.vocab, trainer.cfg.batch, seq_plus1, cfg.corpus_bytes, cfg.seed);
    let eval_batch = dataset.eval_batch();
    // The dataset is a deterministic stream from the seed: on resume, skip
    // the batches the checkpointed steps already consumed so the continued
    // run sees the same data an uninterrupted run would have seen.
    for _ in 0..trainer.step {
        let _ = dataset.next_batch();
    }

    let mut tracker = Tracker::paper();
    let mut step = trainer.step as usize;
    let mut last_eval = None;
    let mut last_ortho = None;

    // Rank-transition policy: consulted at every step boundary BEFORE the
    // step runs. Deterministic in (seed, step), and schedule targets are a
    // pure function of the step, so a checkpoint-resumed run applies the
    // same transitions an uninterrupted run would. Validated against the
    // restored model's real capacity up front — an impossible milestone
    // fails here, not thousands of steps in.
    let rank_cap = m.d_model.min(m.d_ffn);
    let rank_policy_cfg = cfg.rank_policy.validated(rank_cap)?;
    let mut rank_policy = rank_policy_cfg.build();
    let tail_frac = rank_policy_cfg.tail_frac();
    let mut rank_rng = Rng::new(cfg.seed ^ 0x72616e6b); // "rank"
    let mut rank_events: Vec<RankEvent> = Vec::new();

    // `--metrics-out`: append one flat registry snapshot per cadence step,
    // keyed by the optimizer step — the offline twin of `GET /metrics`. The
    // closure dedups by step so the unconditional final flush (loop exit,
    // watchdog halt, resume-already-done) never writes the same step twice.
    let metrics_out = cfg.obs.metrics_out.as_ref().map(std::path::PathBuf::from);
    let metrics_every = cfg.obs.metrics_every.max(1);
    let mut last_metrics_step: Option<usize> = None;
    let mut flush_metrics = move |step: usize| -> Result<()> {
        if let Some(path) = &metrics_out {
            if last_metrics_step != Some(step) {
                last_metrics_step = Some(step);
                let row = Json::Obj(vec![
                    ("step".to_string(), Json::Num(step as f64)),
                    ("metrics".to_string(), obs::registry().render_json()),
                ]);
                export::append_jsonl(path, &row)?;
            }
        }
        Ok(())
    };

    // `--spectra-out`: per-layer spectral-health snapshots on their own
    // cadence, sharing the rank policy's tail fraction so tail energies in
    // spectra.jsonl agree with the monitor/policy numbers exactly. The
    // watchdog (if armed) runs its deep parameter scan on the same cadence.
    let spectra_out = cfg.obs.spectra_out.as_ref().map(std::path::PathBuf::from);
    let spectra_every = cfg.obs.spectra_every.max(1);
    let mut drift = DriftTracker::new();
    if let Some(wd) = cfg.obs.watchdog_config() {
        sct_info!("[watchdog] armed with policy {}", wd.policy.as_str());
        health::configure(wd);
        trainer.watchdog = true;
    }

    while step < cfg.steps {
        if rank_policy.wants_stats(step as u64) {
            // Schedule-style policies decide on (step, rank) alone — give
            // them rank-only stats and keep the per-step boundary free of
            // the singular-value sort the energy policy needs.
            let stats = if rank_policy.needs_energy() {
                model_energy(&trainer.model, tail_frac)
            } else {
                trainer
                    .layer_ranks()
                    .into_iter()
                    .enumerate()
                    .map(|(layer, rank)| crate::rank::LayerEnergy {
                        layer,
                        rank,
                        energy: 0.0,
                        tail_share: 0.0,
                    })
                    .collect()
            };
            publish_energy(&stats);
            for st in stats {
                if let Some(target) = rank_policy.target(step as u64, &st) {
                    if target != st.rank {
                        trainer.set_layer_rank(st.layer, target, &mut rank_rng)?;
                        sct_info!(
                            "[rank] step {step}: layer {} {} -> {} ({}, tail {:.3})",
                            st.layer,
                            st.rank,
                            target,
                            rank_policy.name(),
                            st.tail_share,
                        );
                        let ev = RankEvent {
                            step: step as u64,
                            layer: st.layer,
                            from: st.rank,
                            to: target,
                            tail_share: st.tail_share,
                            policy: rank_policy.name(),
                        };
                        ev.publish();
                        rank_events.push(ev);
                    }
                }
            }
        }
        let (ld, ls) = cfg.lr_plan.at(step);
        let tokens = dataset.next_batch();
        let t0 = Instant::now();
        let (loss, _phases) = trainer.train_step(&tokens, ld, ls);
        tracker.record(loss, t0.elapsed().as_secs_f64());
        step += 1;

        // Watchdog: fold in the step's verdict from train_step, the deep
        // parameter scan (spectra cadence), and the CI smoke's synthetic
        // NaN injection.
        let mut verdict = trainer.last_verdict;
        if cfg.obs.watchdog_inject_nan == Some(step as u64) {
            sct_warn!("[watchdog] injecting synthetic NaN loss at step {step} (test hook)");
            verdict = verdict.max(health::check_loss(step as u64, f32::NAN));
        }
        if trainer.watchdog && (step % spectra_every == 0 || step == cfg.steps) {
            verdict =
                verdict.max(health::check_params(step as u64, || non_finite_param(&trainer.model)));
        }
        let halted = verdict.halts();

        if let Some(path) = &spectra_out {
            if step % spectra_every == 0 || step == cfg.steps || halted {
                let mut spectra = model_spectra(&trainer.model, tail_frac);
                drift.observe(&trainer.model, &mut spectra);
                crate::rank::spectra::publish(&spectra);
                export::append_jsonl(path, &spectra_json(step as u64, &spectra))?;
            }
        }

        if halted {
            // Diagnostic dump, then a non-zero exit. The checkpoint cadence
            // below is never reached, so no checkpoint is written from the
            // anomalous state (and skip semantics already kept the model at
            // its pre-step values).
            flush_metrics(step)?;
            let report = health::report_json();
            sct_error!("[watchdog] halting at step {step}: {}", report.to_string());
            let detail = health::last_anomaly()
                .map(|a| format!("{} ({})", a.kind.name(), a.detail))
                .unwrap_or_else(|| "anomaly".to_string());
            anyhow::bail!("watchdog halted training at step {step}: {detail}");
        }

        if cfg.eval_every > 0 && step % cfg.eval_every == 0 {
            last_eval = Some(trainer.eval_loss(&eval_batch));
        }
        if cfg.ortho_every > 0 && step % cfg.ortho_every == 0 {
            let err = trainer.ortho_error();
            last_ortho = Some(err);
            publish_ortho_error(err);
            // The paper's own acceptance threshold (Table 2).
            if err > 2e-6 {
                sct_warn!("ortho error {err} > 2e-6 at step {step}");
            }
        }
        if let Some(mgr) = &mgr {
            if step % cfg.ckpt_every == 0 {
                mgr.save_tensors(trainer.step, &trainer.checkpoint_tensors())?;
            }
        }
        if step % metrics_every == 0 {
            flush_metrics(step)?;
        }
    }
    // Final flush even when the step count is not a multiple of the cadence
    // (and when a resumed run was already done, so the loop never ran).
    flush_metrics(step)?;
    let final_err = trainer.ortho_error();
    publish_ortho_error(final_err);
    last_ortho = Some(final_err);

    let params = trainer.model.param_count();
    let summary = RunSummary {
        // trainer.cfg.model.rank tracks the max layer rank through live
        // transitions — label the run by where it ENDED, not where it began
        label: format!("native_d{}_r{}", m.d_model, trainer.cfg.model.rank),
        params,
        steps: step,
        final_loss_smoothed: tracker.smoothed_loss(),
        ppl: tracker.ppl(),
        mean_step_s: tracker.mean_step_s(),
        // params + AdamW m/v moments, f32 — the paper's "four copies" story
        // minus the transient gradient.
        state_bytes: params * 4 * 3,
        eval_loss: last_eval,
        ortho_error: last_ortho,
        losses: tracker.losses.clone(),
        rank_events,
        layer_ranks: trainer.layer_ranks(),
    };
    Ok((summary, tracker))
}

/// Scan every parameter tensor for a non-finite value — the watchdog's deep
/// check, run on the spectra cadence (the per-step check inside
/// `train_step` covers only the O(rank) `s` vectors). `sct doctor` runs the
/// same scan offline over a loaded checkpoint.
pub(crate) fn non_finite_param(model: &SpectralModel) -> Option<String> {
    fn scan(name: String, data: &[f32]) -> Option<String> {
        if data.iter().any(|v| !v.is_finite()) {
            Some(format!("non-finite value in {name}"))
        } else {
            None
        }
    }
    if let Some(d) = scan("embed".into(), &model.embed.data) {
        return Some(d);
    }
    for (i, l) in model.layers.iter().enumerate() {
        for (nm, w) in [("wq", &l.wq), ("wk", &l.wk), ("wv", &l.wv), ("wo", &l.wo)] {
            if let Some(d) = scan(format!("layers/{i}/attn/{nm}"), &w.data) {
                return Some(d);
            }
        }
        for (nm, v) in [("ln1", &l.ln1), ("ln2", &l.ln2)] {
            if let Some(d) = scan(format!("layers/{i}/{nm}"), v) {
                return Some(d);
            }
        }
        for (nm, sl) in [("gate", &l.gate), ("up", &l.up), ("down", &l.down)] {
            for (f, data) in [("u", &sl.u.data), ("s", &sl.s), ("v", &sl.v.data)] {
                if let Some(d) = scan(format!("layers/{i}/mlp/{nm}/{f}"), data) {
                    return Some(d);
                }
            }
        }
    }
    if let Some(d) = scan("ln_f".into(), &model.ln_f) {
        return Some(d);
    }
    if let Some(h) = &model.head {
        if let Some(d) = scan("head".into(), &h.data) {
            return Some(d);
        }
    }
    None
}

// ---------------------------------------------------------------------------
// pjrt backend
// ---------------------------------------------------------------------------

#[cfg(feature = "pjrt")]
pub struct Trainer {
    pub cfg: RunConfig,
    pub session: Session,
    pub tracker: Tracker,
    ckpt: Option<CheckpointManager>,
}

#[cfg(feature = "pjrt")]
impl Trainer {
    /// Open the session, init from seed, build the checkpoint manager.
    pub fn new(cfg: RunConfig) -> Result<Trainer> {
        let mut session = Session::open(&cfg.artifacts_root, &cfg.preset)
            .with_context(|| format!("opening preset {}", cfg.preset))?;
        session.init(cfg.seed as i32)?;
        let ckpt = match &cfg.ckpt_dir {
            Some(dir) if cfg.ckpt_every > 0 => Some(CheckpointManager::new(dir, 3)?),
            _ => None,
        };
        Ok(Trainer { cfg, session, tracker: Tracker::paper(), ckpt })
    }

    /// Resume from the newest checkpoint if one exists. Returns the step.
    pub fn try_resume(&mut self) -> Result<Option<u64>> {
        if let Some(mgr) = &self.ckpt {
            if !mgr.list()?.is_empty() {
                let step = mgr.restore_latest(&mut self.session)?;
                return Ok(Some(step));
            }
        }
        Ok(None)
    }

    /// Run `cfg.steps` training steps. Returns the summary row.
    pub fn run(&mut self) -> Result<RunSummary> {
        let spec = self
            .session
            .preset
            .artifacts
            .get("train_step")
            .context("preset has no train_step artifact (pallas presets are inference-only)")?;
        let tok_idx = spec.input_index("tokens")?;
        let seq_plus1 = spec.inputs[tok_idx].shape[1];
        let batch = spec.inputs[tok_idx].shape[0];
        let vocab = self.session.preset.model.vocab;

        let chunk_k = if self.cfg.chunked { self.session.chunk_len().unwrap_or(1) } else { 1 };
        let (_tok, dataset) =
            build_dataset(vocab, batch, seq_plus1, self.cfg.corpus_bytes, self.cfg.seed);
        let eval_batch = dataset.eval_batch();
        let prefetch = Prefetcher::spawn(dataset, chunk_k, 4);

        // Compile up front so step timing excludes XLA compilation.
        self.session.warmup(&["train_step", "train_chunk", "eval_step", "ortho_check"])?;

        let mut step = self.session.steps_done as usize;
        let target = self.cfg.steps;
        let mut last_eval = None;
        let mut last_ortho = None;

        while step < target {
            let (ld, ls) = self.cfg.lr_plan.at(step);
            let t0 = Instant::now();
            if chunk_k > 1 && step + chunk_k <= target {
                let tokens = prefetch.next();
                let losses = self.session.train_chunk(&tokens, ld, ls)?;
                self.tracker.record_losses(&losses, t0.elapsed().as_secs_f64());
                step += chunk_k;
            } else {
                let tokens = if chunk_k > 1 {
                    // tail: take the first batch of a chunk item
                    prefetch.next()[..batch * seq_plus1].to_vec()
                } else {
                    prefetch.next()
                };
                let loss = self.session.train_step(&tokens, ld, ls)?;
                self.tracker.record(loss, t0.elapsed().as_secs_f64());
                step += 1;
            }

            if self.cfg.eval_every > 0 && step % self.cfg.eval_every < chunk_k.max(1) {
                last_eval = Some(self.session.eval_step(&eval_batch)?);
            }
            if self.cfg.ortho_every > 0
                && self.session.preset.model.rank.is_some()
                && step % self.cfg.ortho_every < chunk_k.max(1)
            {
                let err = self.session.ortho_check()?;
                last_ortho = Some(err);
                publish_ortho_error(err);
                // The paper's own acceptance threshold (Table 2).
                if err > 2e-6 {
                    sct_warn!("ortho error {err} > 2e-6 at step {step}");
                }
            }
            if let Some(mgr) = &self.ckpt {
                if self.cfg.ckpt_every > 0 && step % self.cfg.ckpt_every < chunk_k.max(1) {
                    mgr.save(&self.session)?;
                }
            }
        }

        if self.cfg.ortho_every > 0 && self.session.preset.model.rank.is_some() {
            last_ortho = Some(self.session.ortho_check()?);
        }

        Ok(RunSummary {
            label: self.cfg.preset.clone(),
            params: self.session.preset.model.param_count,
            steps: step,
            final_loss_smoothed: self.tracker.smoothed_loss(),
            ppl: self.tracker.ppl(),
            mean_step_s: self.tracker.mean_step_s(),
            state_bytes: self.session.preset.state_bytes(),
            eval_loss: last_eval,
            ortho_error: last_ortho,
            losses: self.tracker.losses.clone(),
            rank_events: Vec::new(),
            layer_ranks: Vec::new(),
        })
    }

    /// MLP compression factor vs the dense preset geometry (Table 3 col 3).
    pub fn mlp_compression(&self) -> f64 {
        let m = &self.session.preset.model;
        match m.rank {
            None => 1.0,
            Some(k) => {
                let dense: f64 = (3 * m.d_model * m.d_ffn) as f64;
                let spectral =
                    (2 * k * (m.d_model + m.d_ffn + 1) + k * (m.d_ffn + m.d_model + 1)) as f64;
                dense / spectral
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rank::RankPolicyConfig;
    use crate::serve::EngineConfig;

    #[test]
    fn run_native_applies_a_rank_schedule() {
        let cfg = RunConfig {
            backend: "native".into(),
            steps: 6,
            eval_every: 0,
            ortho_every: 0,
            corpus_bytes: 60_000,
            batch: 2,
            seq_len: 12,
            native_model: EngineConfig {
                vocab: 256,
                d_model: 16,
                n_layers: 2,
                n_heads: 2,
                d_ffn: 24,
                rank: 3,
                max_seq: 16,
                tied: true,
            },
            rank_policy: RankPolicyConfig::Schedule(vec![(2, 5)]),
            ..RunConfig::default()
        };
        let (summary, _) = run_native(&cfg, false).unwrap();
        assert_eq!(summary.steps, 6);
        assert_eq!(summary.layer_ranks, vec![5, 5], "milestone must have applied to every layer");
        assert_eq!(summary.rank_events.len(), 2, "one event per layer");
        for (i, ev) in summary.rank_events.iter().enumerate() {
            assert_eq!((ev.step, ev.layer, ev.from, ev.to), (2, i, 3, 5));
            assert_eq!(ev.policy, "schedule");
        }
        assert!(summary.final_loss_smoothed.is_finite());
    }

    #[test]
    fn run_native_trains_and_checkpoints() {
        let dir = std::env::temp_dir().join(format!("sct_run_native_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let cfg = RunConfig {
            backend: "native".into(),
            steps: 6,
            eval_every: 3,
            ortho_every: 3,
            corpus_bytes: 60_000,
            ckpt_dir: Some(dir.to_string_lossy().into_owned()),
            ckpt_every: 3,
            batch: 2,
            seq_len: 12,
            native_model: EngineConfig {
                vocab: 256,
                d_model: 16,
                n_layers: 1,
                n_heads: 2,
                d_ffn: 24,
                rank: 3,
                max_seq: 16,
                tied: true,
            },
            ..RunConfig::default()
        };
        let (summary, tracker) = run_native(&cfg, false).unwrap();
        assert_eq!(summary.steps, 6);
        assert_eq!(tracker.steps(), 6);
        assert!(summary.final_loss_smoothed.is_finite());
        assert!(summary.eval_loss.is_some());
        assert!(summary.ortho_error.unwrap() <= 2e-6);
        // checkpoints landed and resume picks the newest up
        let mgr = CheckpointManager::new(&dir, 3).unwrap();
        let (latest, _) = mgr.latest().unwrap().expect("ckpt_every=3 must have saved");
        assert_eq!(latest, 6);
        // resuming with the same step target does no additional work
        let (resumed, _) = run_native(&cfg, true).unwrap();
        assert_eq!(resumed.steps, 6);
        std::fs::remove_dir_all(&dir).ok();
    }

    fn tiny_run_cfg(dir: &std::path::Path) -> RunConfig {
        RunConfig {
            backend: "native".into(),
            steps: 7,
            eval_every: 0,
            ortho_every: 0,
            corpus_bytes: 60_000,
            ckpt_dir: Some(dir.join("ckpt").to_string_lossy().into_owned()),
            ckpt_every: 3,
            batch: 2,
            seq_len: 12,
            native_model: EngineConfig {
                vocab: 256,
                d_model: 16,
                n_layers: 2,
                n_heads: 2,
                d_ffn: 24,
                rank: 3,
                max_seq: 16,
                tied: true,
            },
            ..RunConfig::default()
        }
    }

    #[test]
    fn run_native_streams_spectra_matching_the_checkpointed_model() {
        let dir = std::env::temp_dir().join(format!("sct_spectra_run_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let spectra_path = dir.join("spectra.jsonl");
        let mut cfg = tiny_run_cfg(&dir);
        cfg.steps = 6; // multiple of ckpt_every: final ckpt == final sample state
        cfg.obs.spectra_out = Some(spectra_path.to_string_lossy().into_owned());
        cfg.obs.spectra_every = 2;
        let (summary, _) = run_native(&cfg, false).unwrap();
        assert_eq!(summary.steps, 6);

        let text = std::fs::read_to_string(&spectra_path).unwrap();
        let rows: Vec<Json> =
            text.lines().map(|l| Json::parse(l).expect("each line parses")).collect();
        assert_eq!(rows.len(), 3, "cadence 2 over 6 steps -> samples at 2, 4, 6");
        let last = rows.last().unwrap();
        assert_eq!(last.get("step").unwrap(), &Json::Num(6.0));
        let layers = match last.get("layers").unwrap() {
            Json::Arr(a) => a,
            other => panic!("layers not an array: {other:?}"),
        };
        assert_eq!(layers.len(), 2);
        // Drift is measured from the second sample on.
        let t0 = match rows[1].get("layers").unwrap() {
            Json::Arr(a) => a[0].get("triples").unwrap().clone(),
            other => panic!("layers not an array: {other:?}"),
        };
        if let Json::Arr(ts) = &t0 {
            assert!(ts[0].get("drift_u").unwrap().as_f64().unwrap() >= 0.0);
        } else {
            panic!("triples not an array");
        }

        // The acceptance contract: tail energies in spectra.jsonl match the
        // rank monitor's values on the checkpointed model (saved at step 6,
        // the same state the final sample observed).
        let mgr = CheckpointManager::new(dir.join("ckpt"), 3).unwrap();
        let (ckpt_step, path) = mgr.latest().unwrap().expect("ckpt at step 6");
        assert_eq!(ckpt_step, 6);
        let model = SpectralModel::load(&path).unwrap();
        let energy = model_energy(&model, 0.25);
        for (l, e) in layers.iter().zip(&energy) {
            let tail = l.get("tail_share").unwrap().as_f64().unwrap();
            assert!(
                (tail - e.tail_share as f64).abs() < 1e-6,
                "spectra tail {tail} vs monitor {}",
                e.tail_share
            );
            let en = l.get("energy").unwrap().as_f64().unwrap();
            assert!((en - e.energy as f64).abs() <= 1e-6 * e.energy as f64);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn run_native_flushes_final_metrics_off_cadence() {
        let dir = std::env::temp_dir().join(format!("sct_metrics_flush_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let metrics_path = dir.join("metrics.jsonl");
        let mut cfg = tiny_run_cfg(&dir);
        cfg.steps = 7; // NOT a multiple of the cadence
        cfg.obs.metrics_out = Some(metrics_path.to_string_lossy().into_owned());
        cfg.obs.metrics_every = 5;
        let (summary, _) = run_native(&cfg, false).unwrap();
        assert_eq!(summary.steps, 7);
        let text = std::fs::read_to_string(&metrics_path).unwrap();
        let steps: Vec<f64> = text
            .lines()
            .map(|l| Json::parse(l).unwrap().get("step").unwrap().as_f64().unwrap())
            .collect();
        assert_eq!(steps, vec![5.0, 7.0], "cadence row + final partial-window row");

        // A resumed run that is already done still appends its final record
        // (the loop body never runs).
        let (resumed, _) = run_native(&cfg, true).unwrap();
        assert_eq!(resumed.steps, 7);
        let text = std::fs::read_to_string(&metrics_path).unwrap();
        assert_eq!(text.lines().count(), 3, "resume-done run flushes exactly one record");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn run_native_watchdog_halts_without_poisoning_the_checkpoint() {
        let _g = health::test_guard();
        let dir = std::env::temp_dir().join(format!("sct_watchdog_halt_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let metrics_path = dir.join("metrics.jsonl");
        let mut cfg = tiny_run_cfg(&dir);
        cfg.steps = 7;
        cfg.obs.metrics_out = Some(metrics_path.to_string_lossy().into_owned());
        cfg.obs.metrics_every = 100;
        cfg.obs.watchdog = Some("halt".into());
        cfg.obs.watchdog_inject_nan = Some(4);
        let before = health::anomaly_total();
        let err = run_native(&cfg, false).expect_err("halt policy must error out");
        assert!(err.to_string().contains("watchdog halted training at step 4"), "{err}");
        assert!(health::anomaly_total() > before, "anomaly counter must increment");

        // ckpt_every = 3: the step-3 checkpoint landed, the halt at step 4
        // prevented any later save — the checkpoint predates the anomaly.
        let mgr = CheckpointManager::new(dir.join("ckpt"), 3).unwrap();
        let (ckpt_step, path) = mgr.latest().unwrap().expect("pre-halt ckpt");
        assert_eq!(ckpt_step, 3);
        let model = SpectralModel::load(&path).unwrap();
        assert!(super::non_finite_param(&model).is_none(), "checkpoint must be clean");

        // The halt path still flushed a final metrics record (cadence 100
        // never fired on its own).
        let text = std::fs::read_to_string(&metrics_path).unwrap();
        let steps: Vec<f64> = text
            .lines()
            .map(|l| Json::parse(l).unwrap().get("step").unwrap().as_f64().unwrap())
            .collect();
        assert_eq!(steps, vec![4.0], "halt flushes the partial window");
        health::disable();
        std::fs::remove_dir_all(&dir).ok();
    }
}
