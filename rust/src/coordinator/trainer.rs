//! The training loop: session + data pipeline + schedules + metrics +
//! checkpoints, wired the way the paper's Algorithm 1 runs.
//!
//! The hot path is one PJRT dispatch per chunk (`train_chunk`, K fused
//! steps) with batches prefetched on a producer thread; falls back to
//! per-step dispatch when `chunked` is off or the artifact is missing (the
//! pallas integration preset).

use std::time::Instant;

use anyhow::{Context, Result};

use super::config::RunConfig;
use crate::checkpoint::CheckpointManager;
use crate::data::{build_dataset, Prefetcher};
use crate::metrics::Tracker;
use crate::runtime::Session;

/// Result of a training run — everything Table 3 needs for one row.
#[derive(Debug)]
pub struct RunSummary {
    pub label: String,
    pub params: usize,
    pub steps: usize,
    pub final_loss_smoothed: f32,
    pub ppl: f32,
    pub mean_step_s: f64,
    pub state_bytes: usize,
    pub eval_loss: Option<f32>,
    pub ortho_error: Option<f32>,
    pub losses: Vec<f32>,
}

pub struct Trainer {
    pub cfg: RunConfig,
    pub session: Session,
    pub tracker: Tracker,
    ckpt: Option<CheckpointManager>,
}

impl Trainer {
    /// Open the session, init from seed, build the checkpoint manager.
    pub fn new(cfg: RunConfig) -> Result<Trainer> {
        let mut session = Session::open(&cfg.artifacts_root, &cfg.preset)
            .with_context(|| format!("opening preset {}", cfg.preset))?;
        session.init(cfg.seed as i32)?;
        let ckpt = match &cfg.ckpt_dir {
            Some(dir) if cfg.ckpt_every > 0 => Some(CheckpointManager::new(dir, 3)?),
            _ => None,
        };
        Ok(Trainer { cfg, session, tracker: Tracker::paper(), ckpt })
    }

    /// Resume from the newest checkpoint if one exists. Returns the step.
    pub fn try_resume(&mut self) -> Result<Option<u64>> {
        if let Some(mgr) = &self.ckpt {
            if !mgr.list()?.is_empty() {
                let step = mgr.restore_latest(&mut self.session)?;
                return Ok(Some(step));
            }
        }
        Ok(None)
    }

    /// Run `cfg.steps` training steps. Returns the summary row.
    pub fn run(&mut self) -> Result<RunSummary> {
        let spec = self
            .session
            .preset
            .artifacts
            .get("train_step")
            .context("preset has no train_step artifact (pallas presets are inference-only)")?;
        let tok_idx = spec.input_index("tokens")?;
        let seq_plus1 = spec.inputs[tok_idx].shape[1];
        let batch = spec.inputs[tok_idx].shape[0];
        let vocab = self.session.preset.model.vocab;

        let chunk_k = if self.cfg.chunked { self.session.chunk_len().unwrap_or(1) } else { 1 };
        let (_tok, dataset) =
            build_dataset(vocab, batch, seq_plus1, self.cfg.corpus_bytes, self.cfg.seed);
        let eval_batch = dataset.eval_batch();
        let prefetch = Prefetcher::spawn(dataset, chunk_k, 4);

        // Compile up front so step timing excludes XLA compilation.
        self.session.warmup(&["train_step", "train_chunk", "eval_step", "ortho_check"])?;

        let mut step = self.session.steps_done as usize;
        let target = self.cfg.steps;
        let mut last_eval = None;
        let mut last_ortho = None;

        while step < target {
            let (ld, ls) = self.cfg.lr_plan.at(step);
            let t0 = Instant::now();
            if chunk_k > 1 && step + chunk_k <= target {
                let tokens = prefetch.next();
                let losses = self.session.train_chunk(&tokens, ld, ls)?;
                self.tracker.record_losses(&losses, t0.elapsed().as_secs_f64());
                step += chunk_k;
            } else {
                let tokens = if chunk_k > 1 {
                    // tail: take the first batch of a chunk item
                    prefetch.next()[..batch * seq_plus1].to_vec()
                } else {
                    prefetch.next()
                };
                let loss = self.session.train_step(&tokens, ld, ls)?;
                self.tracker.record(loss, t0.elapsed().as_secs_f64());
                step += 1;
            }

            if self.cfg.eval_every > 0 && step % self.cfg.eval_every < chunk_k.max(1) {
                last_eval = Some(self.session.eval_step(&eval_batch)?);
            }
            if self.cfg.ortho_every > 0
                && self.session.preset.model.rank.is_some()
                && step % self.cfg.ortho_every < chunk_k.max(1)
            {
                let err = self.session.ortho_check()?;
                last_ortho = Some(err);
                // The paper's own acceptance threshold (Table 2).
                if err > 2e-6 {
                    eprintln!("[trainer] WARNING ortho error {err} > 2e-6 at step {step}");
                }
            }
            if let Some(mgr) = &self.ckpt {
                if self.cfg.ckpt_every > 0 && step % self.cfg.ckpt_every < chunk_k.max(1) {
                    mgr.save(&self.session)?;
                }
            }
        }

        if self.cfg.ortho_every > 0 && self.session.preset.model.rank.is_some() {
            last_ortho = Some(self.session.ortho_check()?);
        }

        Ok(RunSummary {
            label: self.cfg.preset.clone(),
            params: self.session.preset.model.param_count,
            steps: step,
            final_loss_smoothed: self.tracker.smoothed_loss(),
            ppl: self.tracker.ppl(),
            mean_step_s: self.tracker.mean_step_s(),
            state_bytes: self.session.preset.state_bytes(),
            eval_loss: last_eval,
            ortho_error: last_ortho,
            losses: self.tracker.losses.clone(),
        })
    }

    /// MLP compression factor vs the dense preset geometry (Table 3 col 3).
    pub fn mlp_compression(&self) -> f64 {
        let m = &self.session.preset.model;
        match m.rank {
            None => 1.0,
            Some(k) => {
                let dense: f64 = (3 * m.d_model * m.d_ffn) as f64;
                let spectral =
                    (2 * k * (m.d_model + m.d_ffn + 1) + k * (m.d_ffn + m.d_model + 1)) as f64;
                dense / spectral
            }
        }
    }
}
