//! The training loop: data pipeline + schedules + metrics + checkpoints,
//! wired the way the paper's Algorithm 1 runs — over either backend.
//!
//! Two backends share the [`RunSummary`] contract:
//! * **pjrt** ([`Trainer`], `pjrt` feature): one PJRT dispatch per chunk
//!   (`train_chunk`, K fused steps) with batches prefetched on a producer
//!   thread; falls back to per-step dispatch when `chunked` is off or the
//!   artifact is missing (the pallas integration preset).
//! * **native** ([`run_native`], always built): the pure-Rust engine in
//!   [`crate::train`] — full backward through the shared decoder, AdamW on
//!   the compact factors, QR retraction — driven by the same
//!   warmup+cosine [`super::schedule::LrPlan`], eval/ortho cadences and
//!   rotating checkpoint manager, with no PJRT anywhere.

use std::time::Instant;

use anyhow::Result;

#[cfg(feature = "pjrt")]
use anyhow::Context;

use super::config::RunConfig;
use crate::checkpoint::CheckpointManager;
use crate::data::build_dataset;
use crate::metrics::{export, Tracker};
use crate::obs;
use crate::rank::{model_energy, publish_energy, publish_ortho_error, RankEvent};
use crate::train::{NativeTrainConfig, NativeTrainer};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::{sct_info, sct_warn};

#[cfg(feature = "pjrt")]
use crate::data::Prefetcher;
#[cfg(feature = "pjrt")]
use crate::runtime::Session;

/// Result of a training run — everything Table 3 needs for one row.
#[derive(Debug)]
pub struct RunSummary {
    pub label: String,
    pub params: usize,
    pub steps: usize,
    pub final_loss_smoothed: f32,
    pub ppl: f32,
    pub mean_step_s: f64,
    pub state_bytes: usize,
    pub eval_loss: Option<f32>,
    pub ortho_error: Option<f32>,
    pub losses: Vec<f32>,
    /// Rank transitions applied by the configured policy (native backend;
    /// empty under `Fixed` or on the pjrt path).
    pub rank_events: Vec<RankEvent>,
    /// Final per-layer MLP ranks (native backend; empty on the pjrt path).
    pub layer_ranks: Vec<usize>,
}

// ---------------------------------------------------------------------------
// native backend
// ---------------------------------------------------------------------------

/// Run `cfg.steps` native training steps on the bundled synthetic corpus:
/// the no-PJRT twin of [`Trainer::run`]. Honors the LR plan, eval/ortho
/// cadences, gradient clipping, retraction cadence, and (when `ckpt_dir` +
/// `ckpt_every` are set) rotating `.sct` checkpoints in the
/// `params/layers/...` layout — which `serve::SpectralModel::load` reads
/// directly. With `resume`, the newest checkpoint in `ckpt_dir` (if any)
/// restores model + optimizer moments before training continues.
pub fn run_native(cfg: &RunConfig, resume: bool) -> Result<(RunSummary, Tracker)> {
    let tcfg = NativeTrainConfig {
        model: cfg.native_model,
        batch: cfg.batch,
        seq_len: cfg.seq_len,
        grad_clip: cfg.grad_clip,
        retract_every: cfg.retract_every.max(1),
        weight_decay: cfg.weight_decay,
    };
    let mgr = match &cfg.ckpt_dir {
        Some(dir) if cfg.ckpt_every > 0 => Some(CheckpointManager::new(dir, 3)?),
        _ => None,
    };
    let mut trainer = match &mgr {
        Some(m) if resume => match m.latest()? {
            Some((step, path)) => {
                let t = NativeTrainer::load(&path, tcfg)?;
                sct_info!("resumed native run from step {step} ({})", path.display());
                t
            }
            None => NativeTrainer::new(tcfg, cfg.seed),
        },
        _ => NativeTrainer::new(tcfg, cfg.seed),
    };
    let m = trainer.cfg.model;

    let seq_plus1 = trainer.cfg.seq_len + 1;
    let (_tok, mut dataset) =
        build_dataset(m.vocab, trainer.cfg.batch, seq_plus1, cfg.corpus_bytes, cfg.seed);
    let eval_batch = dataset.eval_batch();
    // The dataset is a deterministic stream from the seed: on resume, skip
    // the batches the checkpointed steps already consumed so the continued
    // run sees the same data an uninterrupted run would have seen.
    for _ in 0..trainer.step {
        let _ = dataset.next_batch();
    }

    let mut tracker = Tracker::paper();
    let mut step = trainer.step as usize;
    let mut last_eval = None;
    let mut last_ortho = None;

    // Rank-transition policy: consulted at every step boundary BEFORE the
    // step runs. Deterministic in (seed, step), and schedule targets are a
    // pure function of the step, so a checkpoint-resumed run applies the
    // same transitions an uninterrupted run would. Validated against the
    // restored model's real capacity up front — an impossible milestone
    // fails here, not thousands of steps in.
    let rank_cap = m.d_model.min(m.d_ffn);
    let rank_policy_cfg = cfg.rank_policy.validated(rank_cap)?;
    let mut rank_policy = rank_policy_cfg.build();
    let tail_frac = rank_policy_cfg.tail_frac();
    let mut rank_rng = Rng::new(cfg.seed ^ 0x72616e6b); // "rank"
    let mut rank_events: Vec<RankEvent> = Vec::new();

    // `--metrics-out`: append one flat registry snapshot per cadence step,
    // keyed by the optimizer step — the offline twin of `GET /metrics`.
    let metrics_out = cfg.obs.metrics_out.as_ref().map(std::path::PathBuf::from);
    let metrics_every = cfg.obs.metrics_every.max(1);

    while step < cfg.steps {
        if rank_policy.wants_stats(step as u64) {
            // Schedule-style policies decide on (step, rank) alone — give
            // them rank-only stats and keep the per-step boundary free of
            // the singular-value sort the energy policy needs.
            let stats = if rank_policy.needs_energy() {
                model_energy(&trainer.model, tail_frac)
            } else {
                trainer
                    .layer_ranks()
                    .into_iter()
                    .enumerate()
                    .map(|(layer, rank)| crate::rank::LayerEnergy {
                        layer,
                        rank,
                        energy: 0.0,
                        tail_share: 0.0,
                    })
                    .collect()
            };
            publish_energy(&stats);
            for st in stats {
                if let Some(target) = rank_policy.target(step as u64, &st) {
                    if target != st.rank {
                        trainer.set_layer_rank(st.layer, target, &mut rank_rng)?;
                        sct_info!(
                            "[rank] step {step}: layer {} {} -> {} ({}, tail {:.3})",
                            st.layer,
                            st.rank,
                            target,
                            rank_policy.name(),
                            st.tail_share,
                        );
                        let ev = RankEvent {
                            step: step as u64,
                            layer: st.layer,
                            from: st.rank,
                            to: target,
                            tail_share: st.tail_share,
                            policy: rank_policy.name(),
                        };
                        ev.publish();
                        rank_events.push(ev);
                    }
                }
            }
        }
        let (ld, ls) = cfg.lr_plan.at(step);
        let tokens = dataset.next_batch();
        let t0 = Instant::now();
        let (loss, _phases) = trainer.train_step(&tokens, ld, ls);
        tracker.record(loss, t0.elapsed().as_secs_f64());
        step += 1;

        if cfg.eval_every > 0 && step % cfg.eval_every == 0 {
            last_eval = Some(trainer.eval_loss(&eval_batch));
        }
        if cfg.ortho_every > 0 && step % cfg.ortho_every == 0 {
            let err = trainer.ortho_error();
            last_ortho = Some(err);
            publish_ortho_error(err);
            // The paper's own acceptance threshold (Table 2).
            if err > 2e-6 {
                sct_warn!("ortho error {err} > 2e-6 at step {step}");
            }
        }
        if let Some(mgr) = &mgr {
            if step % cfg.ckpt_every == 0 {
                mgr.save_tensors(trainer.step, &trainer.checkpoint_tensors())?;
            }
        }
        if let Some(path) = &metrics_out {
            if step % metrics_every == 0 || step == cfg.steps {
                let row = Json::Obj(vec![
                    ("step".to_string(), Json::Num(step as f64)),
                    ("metrics".to_string(), obs::registry().render_json()),
                ]);
                export::append_jsonl(path, &row)?;
            }
        }
    }
    let final_err = trainer.ortho_error();
    publish_ortho_error(final_err);
    last_ortho = Some(final_err);

    let params = trainer.model.param_count();
    let summary = RunSummary {
        // trainer.cfg.model.rank tracks the max layer rank through live
        // transitions — label the run by where it ENDED, not where it began
        label: format!("native_d{}_r{}", m.d_model, trainer.cfg.model.rank),
        params,
        steps: step,
        final_loss_smoothed: tracker.smoothed_loss(),
        ppl: tracker.ppl(),
        mean_step_s: tracker.mean_step_s(),
        // params + AdamW m/v moments, f32 — the paper's "four copies" story
        // minus the transient gradient.
        state_bytes: params * 4 * 3,
        eval_loss: last_eval,
        ortho_error: last_ortho,
        losses: tracker.losses.clone(),
        rank_events,
        layer_ranks: trainer.layer_ranks(),
    };
    Ok((summary, tracker))
}

// ---------------------------------------------------------------------------
// pjrt backend
// ---------------------------------------------------------------------------

#[cfg(feature = "pjrt")]
pub struct Trainer {
    pub cfg: RunConfig,
    pub session: Session,
    pub tracker: Tracker,
    ckpt: Option<CheckpointManager>,
}

#[cfg(feature = "pjrt")]
impl Trainer {
    /// Open the session, init from seed, build the checkpoint manager.
    pub fn new(cfg: RunConfig) -> Result<Trainer> {
        let mut session = Session::open(&cfg.artifacts_root, &cfg.preset)
            .with_context(|| format!("opening preset {}", cfg.preset))?;
        session.init(cfg.seed as i32)?;
        let ckpt = match &cfg.ckpt_dir {
            Some(dir) if cfg.ckpt_every > 0 => Some(CheckpointManager::new(dir, 3)?),
            _ => None,
        };
        Ok(Trainer { cfg, session, tracker: Tracker::paper(), ckpt })
    }

    /// Resume from the newest checkpoint if one exists. Returns the step.
    pub fn try_resume(&mut self) -> Result<Option<u64>> {
        if let Some(mgr) = &self.ckpt {
            if !mgr.list()?.is_empty() {
                let step = mgr.restore_latest(&mut self.session)?;
                return Ok(Some(step));
            }
        }
        Ok(None)
    }

    /// Run `cfg.steps` training steps. Returns the summary row.
    pub fn run(&mut self) -> Result<RunSummary> {
        let spec = self
            .session
            .preset
            .artifacts
            .get("train_step")
            .context("preset has no train_step artifact (pallas presets are inference-only)")?;
        let tok_idx = spec.input_index("tokens")?;
        let seq_plus1 = spec.inputs[tok_idx].shape[1];
        let batch = spec.inputs[tok_idx].shape[0];
        let vocab = self.session.preset.model.vocab;

        let chunk_k = if self.cfg.chunked { self.session.chunk_len().unwrap_or(1) } else { 1 };
        let (_tok, dataset) =
            build_dataset(vocab, batch, seq_plus1, self.cfg.corpus_bytes, self.cfg.seed);
        let eval_batch = dataset.eval_batch();
        let prefetch = Prefetcher::spawn(dataset, chunk_k, 4);

        // Compile up front so step timing excludes XLA compilation.
        self.session.warmup(&["train_step", "train_chunk", "eval_step", "ortho_check"])?;

        let mut step = self.session.steps_done as usize;
        let target = self.cfg.steps;
        let mut last_eval = None;
        let mut last_ortho = None;

        while step < target {
            let (ld, ls) = self.cfg.lr_plan.at(step);
            let t0 = Instant::now();
            if chunk_k > 1 && step + chunk_k <= target {
                let tokens = prefetch.next();
                let losses = self.session.train_chunk(&tokens, ld, ls)?;
                self.tracker.record_losses(&losses, t0.elapsed().as_secs_f64());
                step += chunk_k;
            } else {
                let tokens = if chunk_k > 1 {
                    // tail: take the first batch of a chunk item
                    prefetch.next()[..batch * seq_plus1].to_vec()
                } else {
                    prefetch.next()
                };
                let loss = self.session.train_step(&tokens, ld, ls)?;
                self.tracker.record(loss, t0.elapsed().as_secs_f64());
                step += 1;
            }

            if self.cfg.eval_every > 0 && step % self.cfg.eval_every < chunk_k.max(1) {
                last_eval = Some(self.session.eval_step(&eval_batch)?);
            }
            if self.cfg.ortho_every > 0
                && self.session.preset.model.rank.is_some()
                && step % self.cfg.ortho_every < chunk_k.max(1)
            {
                let err = self.session.ortho_check()?;
                last_ortho = Some(err);
                publish_ortho_error(err);
                // The paper's own acceptance threshold (Table 2).
                if err > 2e-6 {
                    sct_warn!("ortho error {err} > 2e-6 at step {step}");
                }
            }
            if let Some(mgr) = &self.ckpt {
                if self.cfg.ckpt_every > 0 && step % self.cfg.ckpt_every < chunk_k.max(1) {
                    mgr.save(&self.session)?;
                }
            }
        }

        if self.cfg.ortho_every > 0 && self.session.preset.model.rank.is_some() {
            last_ortho = Some(self.session.ortho_check()?);
        }

        Ok(RunSummary {
            label: self.cfg.preset.clone(),
            params: self.session.preset.model.param_count,
            steps: step,
            final_loss_smoothed: self.tracker.smoothed_loss(),
            ppl: self.tracker.ppl(),
            mean_step_s: self.tracker.mean_step_s(),
            state_bytes: self.session.preset.state_bytes(),
            eval_loss: last_eval,
            ortho_error: last_ortho,
            losses: self.tracker.losses.clone(),
            rank_events: Vec::new(),
            layer_ranks: Vec::new(),
        })
    }

    /// MLP compression factor vs the dense preset geometry (Table 3 col 3).
    pub fn mlp_compression(&self) -> f64 {
        let m = &self.session.preset.model;
        match m.rank {
            None => 1.0,
            Some(k) => {
                let dense: f64 = (3 * m.d_model * m.d_ffn) as f64;
                let spectral =
                    (2 * k * (m.d_model + m.d_ffn + 1) + k * (m.d_ffn + m.d_model + 1)) as f64;
                dense / spectral
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rank::RankPolicyConfig;
    use crate::serve::EngineConfig;

    #[test]
    fn run_native_applies_a_rank_schedule() {
        let cfg = RunConfig {
            backend: "native".into(),
            steps: 6,
            eval_every: 0,
            ortho_every: 0,
            corpus_bytes: 60_000,
            batch: 2,
            seq_len: 12,
            native_model: EngineConfig {
                vocab: 256,
                d_model: 16,
                n_layers: 2,
                n_heads: 2,
                d_ffn: 24,
                rank: 3,
                max_seq: 16,
                tied: true,
            },
            rank_policy: RankPolicyConfig::Schedule(vec![(2, 5)]),
            ..RunConfig::default()
        };
        let (summary, _) = run_native(&cfg, false).unwrap();
        assert_eq!(summary.steps, 6);
        assert_eq!(summary.layer_ranks, vec![5, 5], "milestone must have applied to every layer");
        assert_eq!(summary.rank_events.len(), 2, "one event per layer");
        for (i, ev) in summary.rank_events.iter().enumerate() {
            assert_eq!((ev.step, ev.layer, ev.from, ev.to), (2, i, 3, 5));
            assert_eq!(ev.policy, "schedule");
        }
        assert!(summary.final_loss_smoothed.is_finite());
    }

    #[test]
    fn run_native_trains_and_checkpoints() {
        let dir = std::env::temp_dir().join(format!("sct_run_native_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let cfg = RunConfig {
            backend: "native".into(),
            steps: 6,
            eval_every: 3,
            ortho_every: 3,
            corpus_bytes: 60_000,
            ckpt_dir: Some(dir.to_string_lossy().into_owned()),
            ckpt_every: 3,
            batch: 2,
            seq_len: 12,
            native_model: EngineConfig {
                vocab: 256,
                d_model: 16,
                n_layers: 1,
                n_heads: 2,
                d_ffn: 24,
                rank: 3,
                max_seq: 16,
                tied: true,
            },
            ..RunConfig::default()
        };
        let (summary, tracker) = run_native(&cfg, false).unwrap();
        assert_eq!(summary.steps, 6);
        assert_eq!(tracker.steps(), 6);
        assert!(summary.final_loss_smoothed.is_finite());
        assert!(summary.eval_loss.is_some());
        assert!(summary.ortho_error.unwrap() <= 2e-6);
        // checkpoints landed and resume picks the newest up
        let mgr = CheckpointManager::new(&dir, 3).unwrap();
        let (latest, _) = mgr.latest().unwrap().expect("ckpt_every=3 must have saved");
        assert_eq!(latest, 6);
        // resuming with the same step target does no additional work
        let (resumed, _) = run_native(&cfg, true).unwrap();
        assert_eq!(resumed.steps, 6);
        std::fs::remove_dir_all(&dir).ok();
    }
}
