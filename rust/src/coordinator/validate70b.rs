//! 70B architecture validation — Table 2 + Figure 1 (§4.1).
//!
//! The paper runs ONE full training step of a 70B-shape spectral transformer
//! on consumer hardware and reports peak memory + per-phase time. Our
//! substitution (DESIGN.md §4):
//! * **memory** is analytic — identical arithmetic to the paper's (the
//!   paper's dense 1,245 GB figure is itself analytic);
//! * **phase times** are measured for real at the TRUE factor shapes: the
//!   native rust SpectralLinear runs forward/backward/AdamW/QR-retraction on
//!   an 8192x28672 @ k=32 layer (feasible on any machine — that is the
//!   paper's whole point) and we scale by the layer count;
//! * the scaled end-to-end artifact step (sweep preset) cross-checks that
//!   the runtime path has the same phase structure.

use anyhow::Result;

use crate::memmodel::layer::gb;
use crate::memmodel::model::{ModelMemory, SpectralScope};
use crate::memmodel::presets::validation_70b;
use crate::memmodel::report::render_fig1;
use crate::memmodel::TrainRegime;
use crate::spectral::{LayerTrainer, Matrix, SpectralLinear};
use crate::util::rng::Rng;

#[derive(Debug)]
pub struct Phase70b {
    pub fwd_s: f64,
    pub bwd_s: f64,
    pub opt_s: f64,
    pub retract_s: f64,
    pub ortho_error: f32,
    /// layers measured directly (the rest is linear extrapolation)
    pub layers_measured: usize,
    pub layers_total: usize,
}

impl Phase70b {
    pub fn total_s(&self) -> f64 {
        self.fwd_s + self.bwd_s + self.opt_s + self.retract_s
    }

    pub fn retract_fraction(&self) -> f64 {
        self.retract_s / self.total_s().max(1e-12)
    }
}

/// Run `layers_measured` real layer-steps at the 70B MLP shape and
/// extrapolate to the full 80-layer architecture.
pub fn measure_70b_phases(k: usize, batch: usize, layers_measured: usize) -> Result<Phase70b> {
    let shape = validation_70b();
    let (d, f) = (shape.d_model, shape.d_ffn);
    let mut rng = Rng::new(42);

    // One MLP = three spectral matrices; measure one (d,f) and one (f,d)
    // projection and weight accordingly: per layer = 2 * (d->f) + 1 * (f->d).
    let mut acc = [0.0f64; 4];
    let mut ortho = 0.0f32;
    for _ in 0..layers_measured {
        for (m, n, copies) in [(d, f, 2usize), (f, d, 1)] {
            let layer = SpectralLinear::init(&mut rng, m, n, k);
            let mut tr = LayerTrainer::new(layer, 5e-4);
            let x = Matrix::randn(&mut rng, batch, m, 1.0);
            let t = Matrix::randn(&mut rng, batch, n, 0.5);
            let (_, phases) = tr.step(&x, &t);
            for (a, p) in acc.iter_mut().zip(phases) {
                *a += p * copies as f64;
            }
            ortho = ortho.max(tr.layer.ortho_error());
        }
    }
    let scale = shape.n_layers as f64 / layers_measured as f64;
    Ok(Phase70b {
        fwd_s: acc[0] * scale,
        bwd_s: acc[1] * scale,
        opt_s: acc[2] * scale,
        retract_s: acc[3] * scale,
        ortho_error: ortho,
        layers_measured,
        layers_total: shape.n_layers,
    })
}

pub fn render_table2(k: usize, phases: &Phase70b) -> String {
    let shape = validation_70b();
    let sct = ModelMemory::sct(&shape, k, SpectralScope::AllLinear, TrainRegime::AdamW);
    let dense = ModelMemory::dense(&shape, TrainRegime::AdamW);
    let mut out = String::new();
    out.push_str(&format!(
        "Table 2 — 70B architecture validation (this machine; {} of {} layers measured,\n\
         linearly extrapolated; paper measured Apple M4 Pro / Steam Deck)\n",
        phases.layers_measured, phases.layers_total
    ));
    out.push_str("| Metric | This machine (MLP stack) | Paper (Steam Deck) |\n|---|---|---|\n");
    out.push_str(&format!(
        "| Peak training state | {:.2} GB (analytic, all-linear k={k}) | 7.24 GB |\n",
        sct.gb()
    ));
    out.push_str(&format!("| Forward pass | {:.2} s | 0.43 s |\n", phases.fwd_s));
    out.push_str(&format!("| Backward pass | {:.2} s | 0.92 s |\n", phases.bwd_s));
    out.push_str(&format!("| Optimizer step | {:.2} s | 2.35 s |\n", phases.opt_s));
    out.push_str(&format!("| QR retraction | {:.2} s | 2.58 s |\n", phases.retract_s));
    out.push_str(&format!("| Total step | {:.2} s | 6.28 s |\n", phases.total_s()));
    out.push_str(&format!(
        "| Ortho. error | {:.1e} | < 2e-6 |\n",
        phases.ortho_error
    ));
    out.push_str(&format!(
        "| Retraction share of step | {:.0}% | 41% (paper: \"40-50%\") |\n",
        100.0 * phases.retract_fraction()
    ));
    out.push_str(&format!(
        "(dense FP32+Adam would need {:.0} GB — {:.0}x more; Figure 1)\n",
        gb(dense.total_bytes),
        dense.total_bytes as f64 / sct.total_bytes as f64,
    ));
    out.push('\n');
    out.push_str(&render_fig1(k));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phases_are_positive_and_scale() {
        // k tiny + 1 layer so the test is fast; structure is what matters.
        let p = measure_70b_phases(4, 1, 1).unwrap();
        assert!(p.fwd_s > 0.0 && p.bwd_s > 0.0 && p.opt_s > 0.0 && p.retract_s > 0.0);
        assert_eq!(p.layers_total, 80);
        assert!(p.ortho_error < 2e-6, "retraction must hold the manifold");
        let total = p.total_s();
        assert!((p.fwd_s + p.bwd_s + p.opt_s + p.retract_s - total).abs() < 1e-12);
    }
}
