//! The paper's true model shapes (Table 1 + the 70B validation).
//!
//! These are NOT the CPU-scale presets the artifacts are exported at — they
//! are the real SmolLM2 / LLaMA / Qwen dimensions the paper's memory claims
//! are computed over, reproduced so the analytic model regenerates the
//! paper's tables at the paper's own scales. MLP shapes (m x n) per row come
//! straight from Table 1; layer counts / vocabs from the public configs.

use super::model::ModelShape;

/// One named architecture from the paper.
#[derive(Debug, Clone)]
pub struct PaperModel {
    pub name: &'static str,
    pub shape: ModelShape,
    /// Table 1's compression factor at k=32 (cross-check target).
    pub table1_compression: f64,
}

/// All six Table 1 rows.
pub fn paper_models() -> Vec<PaperModel> {
    vec![
        PaperModel {
            name: "SmolLM2-135M",
            shape: ModelShape::new(49152, 576, 30, 1536),
            table1_compression: 13.0,
        },
        PaperModel {
            name: "SmolLM2-360M",
            shape: ModelShape::new(49152, 1024, 32, 4096),
            table1_compression: 26.0,
        },
        PaperModel {
            name: "SmolLM2-1.7B",
            shape: ModelShape::new(49152, 2048, 24, 8192),
            table1_compression: 51.0,
        },
        PaperModel {
            name: "LLaMA-7B",
            shape: ModelShape::new(32000, 4096, 32, 11008),
            table1_compression: 93.0,
        },
        PaperModel {
            name: "Qwen-27B",
            shape: ModelShape::new(152064, 4096, 60, 17408),
            table1_compression: 104.0,
        },
        PaperModel {
            name: "LLaMA-70B",
            shape: ModelShape::new(128256, 8192, 80, 28672),
            table1_compression: 199.0,
        },
    ]
}

/// The 70B-validation architecture (§4.1): 80 layers, d=8192, ffn=28672.
/// The paper counts transformer-block parameters only (its "77.8B dense /
/// 452M spectral" figures exclude embeddings) and spectralizes EVERY weight
/// matrix including attention — see `ModelMemory` tests.
pub fn validation_70b() -> ModelShape {
    ModelShape::new(128256, 8192, 80, 28672)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn six_rows_match_table1_mlp_shapes() {
        let models = paper_models();
        assert_eq!(models.len(), 6);
        let shapes: Vec<(usize, usize)> =
            models.iter().map(|m| (m.shape.d_model, m.shape.d_ffn)).collect();
        assert_eq!(
            shapes,
            vec![
                (576, 1536),
                (1024, 4096),
                (2048, 8192),
                (4096, 11008),
                (4096, 17408),
                (8192, 28672)
            ]
        );
    }
}
