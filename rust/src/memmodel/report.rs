//! Table/figure renderers for the memory model — shared by the CLI
//! (`sct mem-report`), the benches and the examples so every consumer prints
//! the same rows the paper does.

use super::layer::{mb, LayerMemory, TrainRegime};
use super::model::{ModelMemory, SpectralScope};
use super::presets::{paper_models, validation_70b};

/// Paper Table 1: per-MLP-layer training memory at rank 32 across scales.
/// Returns (name, m, n, dense_mb, sct_mb, compression) rows.
pub fn table1(k: usize) -> Vec<(String, usize, usize, f64, f64, f64)> {
    paper_models()
        .into_iter()
        .map(|pm| {
            let l = LayerMemory::fp32(pm.shape.d_model, pm.shape.d_ffn);
            (
                pm.name.to_string(),
                l.m,
                l.n,
                mb(l.dense_bytes(TrainRegime::AdamW)),
                mb(l.spectral_bytes(k, TrainRegime::AdamW)),
                l.compression(k),
            )
        })
        .collect()
}

pub fn render_table1(k: usize) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "Table 1 — per-MLP-layer training memory (weights+grads+Adam) at rank {k}\n"
    ));
    out.push_str("| Model | Layer (m x n) | Dense+Adam | SCT | Compression |\n");
    out.push_str("|---|---|---|---|---|\n");
    for (name, m, n, dense, sct, comp) in table1(k) {
        out.push_str(&format!(
            "| {name} | {m}x{n} | {dense:.1} MB | {sct:.1} MB | {comp:.0}x |\n"
        ));
    }
    out
}

/// Figure 1: 70B training memory, dense vs SCT (all-linear, the §4.1 run).
pub fn fig1(k: usize) -> (ModelMemory, ModelMemory) {
    let shape = validation_70b();
    (
        ModelMemory::dense(&shape, TrainRegime::AdamW),
        ModelMemory::sct(&shape, k, SpectralScope::AllLinear, TrainRegime::AdamW),
    )
}

pub fn render_fig1(k: usize) -> String {
    let (dense, sct) = fig1(k);
    let shape = validation_70b();
    let ratio = sct.compression_vs_dense(&shape, TrainRegime::AdamW);
    let mut out = String::new();
    out.push_str("Figure 1 — training memory at 70B scale (log-scale bars)\n");
    let bar = |label: &str, gb: f64| -> String {
        // log bar: 1 char per factor of ~1.26 (10 chars per decade)
        let chars = (gb.log10() * 10.0).max(1.0) as usize;
        format!("{label:<14} {:>9.1} GB |{}\n", gb, "#".repeat(chars))
    };
    out.push_str(&bar("dense FP32", dense.gb()));
    out.push_str(&bar(&format!("SCT (k={k})"), sct.gb()));
    out.push_str(&format!("SCT requires {ratio:.0}x less memory than dense training\n"));
    out
}

/// The memory side of Table 2 (peak-memory row).
pub fn table2_memory(k: usize) -> ModelMemory {
    let shape = validation_70b();
    ModelMemory::sct(&shape, k, SpectralScope::AllLinear, TrainRegime::AdamW)
}

/// Training memory per milestone of a rank schedule, at the 70B validation
/// geometry: one `(rank, memory)` row per milestone rank, in schedule
/// order. With the `rank` subsystem a run no longer has ONE footprint — it
/// has one per milestone, and provisioning must cover the max.
pub fn schedule_memory(ranks: &[usize]) -> Vec<(usize, ModelMemory)> {
    let shape = validation_70b();
    ranks
        .iter()
        .map(|&k| (k, ModelMemory::sct(&shape, k, SpectralScope::AllLinear, TrainRegime::AdamW)))
        .collect()
}

/// Render the rank-schedule-aware memory report: per-milestone footprints
/// and the peak (the provisioning number), vs the dense bar.
pub fn render_schedule(ranks: &[usize]) -> String {
    let rows = schedule_memory(ranks);
    let shape = validation_70b();
    let dense = ModelMemory::dense(&shape, TrainRegime::AdamW);
    let mut out = String::new();
    out.push_str("Rank-schedule training memory at the 70B validation geometry\n");
    out.push_str("| Milestone | Rank | Spectral params | Train state |\n");
    out.push_str("|---|---|---|---|\n");
    for (i, (k, m)) in rows.iter().enumerate() {
        out.push_str(&format!(
            "| {} | {} | {:.0}M | {:.2} GB |\n",
            i,
            k,
            m.trainable_params as f64 / 1e6,
            m.gb()
        ));
    }
    let (peak_k, peak) = rows
        .iter()
        .max_by(|a, b| a.1.total_bytes.cmp(&b.1.total_bytes))
        .map(|(k, m)| (*k, m.gb()))
        .unwrap_or((0, 0.0));
    out.push_str(&format!(
        "peak over the schedule: {peak:.2} GB at rank {peak_k} \
         ({:.0}x below dense {:.0} GB) — provision for the peak, not the start\n",
        dense.gb() / peak.max(1e-9),
        dense.gb(),
    ));
    out
}

/// Baseline comparison rows used by the extended figure (not in the paper's
/// tables but cited in its Related Work): GaLore- and LoRA-style accounting
/// on the 70B MLP stack.
pub fn baseline_rows(k: usize) -> Vec<(String, f64)> {
    let shape = validation_70b();
    let per_layer = LayerMemory::fp32(shape.d_model, shape.d_ffn);
    let layers = shape.n_layers * 3; // gate/up/down
    vec![
        (
            "dense+Adam".into(),
            mb(per_layer.dense_bytes(TrainRegime::AdamW) * layers) / 1e3,
        ),
        ("GaLore".into(), mb(per_layer.galore_bytes(k) * layers) / 1e3),
        ("LoRA".into(), mb(per_layer.lora_bytes(k) * layers) / 1e3),
        (
            format!("SCT k={k}"),
            mb(per_layer.spectral_bytes(k, TrainRegime::AdamW) * layers) / 1e3,
        ),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_renders_all_rows() {
        let s = render_table1(32);
        for name in ["SmolLM2-135M", "LLaMA-70B", "199x"] {
            assert!(s.contains(name), "missing {name} in:\n{s}");
        }
    }

    #[test]
    fn fig1_matches_paper_numbers() {
        let (dense, sct) = fig1(32);
        assert!((dense.gb() - 1245.0).abs() < 5.0);
        assert!((sct.gb() - 7.2).abs() < 0.1);
        let shape = validation_70b();
        let ratio = sct.compression_vs_dense(&shape, TrainRegime::AdamW);
        assert!((ratio - 172.0).abs() < 2.0, "paper: 172x, got {ratio:.1}");
        let s = render_fig1(32);
        assert!(s.contains("less memory than dense training"), "{s}");
    }

    #[test]
    fn schedule_peak_is_the_max_milestone() {
        let rows = schedule_memory(&[32, 64, 128]);
        assert_eq!(rows.len(), 3);
        // memory grows monotonically with rank, so the peak is the last row
        assert!(rows[0].1.total_bytes < rows[1].1.total_bytes);
        assert!(rows[1].1.total_bytes < rows[2].1.total_bytes);
        let s = render_schedule(&[32, 64, 128]);
        assert!(s.contains("at rank 128"), "{s}");
        assert!(s.contains("provision for the peak"), "{s}");
        // rank 32 milestone matches the static Table 2 number
        let static32 = table2_memory(32);
        assert_eq!(rows[0].1.total_bytes, static32.total_bytes);
    }

    #[test]
    fn baselines_ordered_sct_smallest() {
        let rows = baseline_rows(32);
        let sct = rows.last().unwrap().1;
        for (name, gb) in &rows[..3] {
            assert!(*gb > sct, "{name} should exceed SCT");
        }
    }
}
