//! Per-layer training-memory accounting (paper §3 "Memory analysis",
//! Table 1).
//!
//! For one weight matrix (m x n) trained with Adam the paper counts four
//! copies — weights, gradients, first moment, second moment:
//!   dense: 4 * m * n * 4 bytes;   SCT: 4 * k(m+n+1) * 4 bytes.

/// What is stored per trainable tensor under a given training regime.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TrainRegime {
    /// weights + grads + Adam m + Adam v (the paper's accounting).
    AdamW,
    /// weights + grads only (SGD, for ablation tables).
    Sgd,
    /// weights only (frozen, e.g. the dense W under LoRA).
    Frozen,
}

impl TrainRegime {
    /// Number of same-sized copies stored.
    pub fn copies(&self) -> usize {
        match self {
            TrainRegime::AdamW => 4,
            TrainRegime::Sgd => 2,
            TrainRegime::Frozen => 1,
        }
    }
}

/// Memory accounting for one (m x n) weight matrix.
#[derive(Debug, Clone, Copy)]
pub struct LayerMemory {
    pub m: usize,
    pub n: usize,
    pub bytes_per_el: usize,
}

impl LayerMemory {
    pub fn fp32(m: usize, n: usize) -> LayerMemory {
        LayerMemory { m, n, bytes_per_el: 4 }
    }

    /// Dense parameter count m*n.
    pub fn dense_params(&self) -> usize {
        self.m * self.n
    }

    /// Spectral parameter count k(m+n+1) — paper Eq. 1.
    pub fn spectral_params(&self, k: usize) -> usize {
        k * (self.m + self.n + 1)
    }

    pub fn dense_bytes(&self, regime: TrainRegime) -> usize {
        self.dense_params() * self.bytes_per_el * regime.copies()
    }

    pub fn spectral_bytes(&self, k: usize, regime: TrainRegime) -> usize {
        self.spectral_params(k) * self.bytes_per_el * regime.copies()
    }

    /// Table 1's "Compression" column: dense+Adam over SCT+Adam.
    pub fn compression(&self, k: usize) -> f64 {
        self.dense_bytes(TrainRegime::AdamW) as f64
            / self.spectral_bytes(k, TrainRegime::AdamW) as f64
    }

    /// GaLore-style accounting: full weights + grads, but optimizer moments
    /// in a rank-k projected space (2 * k(m+n) instead of 2 * mn).
    pub fn galore_bytes(&self, k: usize) -> usize {
        let weights_grads = 2 * self.dense_params();
        let moments = 2 * k * (self.m + self.n);
        (weights_grads + moments) * self.bytes_per_el
    }

    /// LoRA-style accounting: frozen dense W + trainable rank-k adapters
    /// (A: m x k, B: k x n) with Adam.
    pub fn lora_bytes(&self, k: usize) -> usize {
        let frozen = self.dense_params();
        let adapters = k * (self.m + self.n) * TrainRegime::AdamW.copies();
        (frozen + adapters) * self.bytes_per_el
    }
}

pub fn mb(bytes: usize) -> f64 {
    bytes as f64 / 1.0e6
}

pub fn gb(bytes: usize) -> f64 {
    bytes as f64 / 1.0e9
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Paper Table 1, row LLaMA-70B: 8192x28672 @ k=32 ->
    /// dense+Adam 3,758 MB, SCT 18.9 MB, 199x.
    #[test]
    fn table1_llama70b_row() {
        let l = LayerMemory::fp32(8192, 28672);
        assert_eq!(l.dense_params(), 234_881_024);
        assert_eq!(l.spectral_params(32), 32 * (8192 + 28672 + 1));
        let dense_mb = mb(l.dense_bytes(TrainRegime::AdamW));
        let sct_mb = mb(l.spectral_bytes(32, TrainRegime::AdamW));
        assert!((dense_mb - 3758.1).abs() < 1.0, "dense {dense_mb} MB");
        assert!((sct_mb - 18.9).abs() < 0.1, "sct {sct_mb} MB");
        let c = l.compression(32);
        assert!((c - 199.0).abs() < 1.0, "compression {c}");
    }

    /// All six Table 1 rows: compression factors 13/26/51/93/104/199.
    #[test]
    fn table1_all_rows() {
        let rows: [(usize, usize, f64); 6] = [
            (576, 1536, 13.0),
            (1024, 4096, 26.0),
            (2048, 8192, 51.0),
            (4096, 11008, 93.0),
            (4096, 17408, 104.0),
            (8192, 28672, 199.0),
        ];
        for (m, n, expect) in rows {
            let c = LayerMemory::fp32(m, n).compression(32);
            assert!(
                (c - expect).abs() / expect < 0.03,
                "{m}x{n}: got {c:.1}, paper says {expect}"
            );
        }
    }

    #[test]
    fn regime_copies() {
        let l = LayerMemory::fp32(100, 200);
        assert_eq!(l.dense_bytes(TrainRegime::AdamW), 4 * 100 * 200 * 4);
        assert_eq!(l.dense_bytes(TrainRegime::Sgd), 2 * 100 * 200 * 4);
        assert_eq!(l.dense_bytes(TrainRegime::Frozen), 100 * 200 * 4);
    }

    #[test]
    fn baselines_ordering() {
        // For small k: SCT < GaLore < dense; LoRA sits above frozen dense.
        let l = LayerMemory::fp32(4096, 11008);
        let k = 32;
        let sct = l.spectral_bytes(k, TrainRegime::AdamW);
        let galore = l.galore_bytes(k);
        let dense = l.dense_bytes(TrainRegime::AdamW);
        let lora = l.lora_bytes(k);
        assert!(sct < galore && galore < dense);
        assert!(lora > l.dense_bytes(TrainRegime::Frozen));
        assert!(lora < dense);
    }

    #[test]
    fn compression_monotone_in_k() {
        let l = LayerMemory::fp32(2048, 8192);
        let mut prev = f64::INFINITY;
        for k in [16, 32, 64, 128, 256] {
            let c = l.compression(k);
            assert!(c < prev);
            prev = c;
        }
    }
}
