//! Analytic training-memory model — regenerates the paper's memory claims.
//!
//! The paper's headline numbers (Table 1 per-layer compression, Table 2's
//! 7.2 GB 70B step, Figure 1's 1,245 GB dense baseline) are arithmetic over
//! tensor inventories: weights + gradients + two Adam moments, FP32. This
//! module reproduces that arithmetic exactly at the paper's true model
//! shapes, and extends it with activation/baseline accounting used by the
//! comparison figures (LoRA- and GaLore-style baselines).

pub mod layer;
pub mod model;
pub mod presets;
pub mod report;

pub use layer::{LayerMemory, TrainRegime};
pub use model::{ModelMemory, ModelShape};
pub use presets::{paper_models, PaperModel};
