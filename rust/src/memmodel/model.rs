//! Whole-model training-memory accounting (Table 2, Figure 1).
//!
//! Reproduces the paper's arithmetic:
//! * dense FP32 + Adam 70B training = 1,245 GB (Figure 1) — four copies of
//!   the 77.8B transformer-block parameters (the paper's dense-equivalent
//!   count excludes embeddings; see tests, which recover 77.8B and 452M
//!   exactly);
//! * SCT @ k=32 = 452M spectral parameters -> 7.2 GB for a full training
//!   step (Table 2) — in the §4.1 validation EVERY matrix (attention
//!   included) is spectral;
//! * the rank-sweep accounting (Table 3's GPU-memory column) where only the
//!   MLP is spectral and attention/embeddings stay dense.

use super::layer::{LayerMemory, TrainRegime};

/// Which matrices are stored in spectral form.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpectralScope {
    /// gate/up/down only (the paper's §4.2 rank-sweep configuration).
    MlpOnly,
    /// every linear incl. attention q/k/v/o (the paper's §4.1 validation).
    AllLinear,
}

/// Transformer architecture geometry (decoder-only, SwiGLU MLP).
#[derive(Debug, Clone)]
pub struct ModelShape {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub d_ffn: usize,
    pub tie_embeddings: bool,
}

impl ModelShape {
    pub fn new(vocab: usize, d_model: usize, n_layers: usize, d_ffn: usize) -> ModelShape {
        ModelShape { vocab, d_model, n_layers, d_ffn, tie_embeddings: true }
    }

    /// The four attention projections, per layer.
    pub fn attn_matrices(&self) -> [LayerMemory; 4] {
        let d = self.d_model;
        [LayerMemory::fp32(d, d); 4]
    }

    /// gate, up, down — per layer.
    pub fn mlp_matrices(&self) -> [LayerMemory; 3] {
        let (d, f) = (self.d_model, self.d_ffn);
        [LayerMemory::fp32(d, f), LayerMemory::fp32(d, f), LayerMemory::fp32(f, d)]
    }

    /// Transformer-block parameters (the paper's dense-equivalent count —
    /// no embeddings, no norms; norms are O(d) noise at these scales).
    pub fn block_dense_params(&self) -> usize {
        let per_layer: usize = self
            .attn_matrices()
            .iter()
            .chain(self.mlp_matrices().iter())
            .map(|l| l.dense_params())
            .sum();
        per_layer * self.n_layers
    }

    /// Embedding (+ untied head) parameters.
    pub fn embed_params(&self) -> usize {
        let e = self.vocab * self.d_model;
        if self.tie_embeddings {
            e
        } else {
            2 * e
        }
    }

    /// Spectral parameter count at rank k under `scope`; non-spectral
    /// matrices keep their dense size. Embeddings excluded (paper's count).
    pub fn block_spectral_params(&self, k: usize, scope: SpectralScope) -> usize {
        let attn: usize = self
            .attn_matrices()
            .iter()
            .map(|l| match scope {
                SpectralScope::AllLinear => l.spectral_params(k),
                SpectralScope::MlpOnly => l.dense_params(),
            })
            .sum();
        let mlp: usize = self.mlp_matrices().iter().map(|l| l.spectral_params(k)).sum();
        (attn + mlp) * self.n_layers
    }
}

/// Result of a memory computation, in bytes.
#[derive(Debug, Clone)]
pub struct ModelMemory {
    pub label: String,
    pub trainable_params: usize,
    pub total_bytes: usize,
}

impl ModelMemory {
    pub fn gb(&self) -> f64 {
        self.total_bytes as f64 / 1.0e9
    }

    pub fn mb(&self) -> f64 {
        self.total_bytes as f64 / 1.0e6
    }

    /// Dense FP32 + Adam training memory of the transformer blocks — the
    /// paper's Figure 1 dense bar.
    pub fn dense(shape: &ModelShape, regime: TrainRegime) -> ModelMemory {
        let params = shape.block_dense_params();
        ModelMemory {
            label: "dense".into(),
            trainable_params: params,
            total_bytes: params * 4 * regime.copies(),
        }
    }

    /// SCT training memory at rank k — the paper's Table 2 / Figure 1 bar.
    pub fn sct(shape: &ModelShape, k: usize, scope: SpectralScope, regime: TrainRegime) -> ModelMemory {
        let params = shape.block_spectral_params(k, scope);
        ModelMemory {
            label: format!("sct_r{k}"),
            trainable_params: params,
            total_bytes: params * 4 * regime.copies(),
        }
    }

    /// Compression vs the dense bar.
    pub fn compression_vs_dense(&self, shape: &ModelShape, regime: TrainRegime) -> f64 {
        ModelMemory::dense(shape, regime).total_bytes as f64 / self.total_bytes as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memmodel::presets::validation_70b;

    /// §4.1: "452M spectral parameters correspond to a 77.8B-parameter
    /// dense architecture."
    #[test]
    fn validation_70b_param_counts() {
        let shape = validation_70b();
        let dense = shape.block_dense_params() as f64;
        assert!(
            (dense / 1e9 - 77.8).abs() < 0.2,
            "dense-equivalent params {:.1}B, paper 77.8B",
            dense / 1e9
        );
        let spectral = shape.block_spectral_params(32, SpectralScope::AllLinear) as f64;
        assert!(
            (spectral / 1e6 - 452.0).abs() < 3.0,
            "spectral params {:.0}M, paper 452M",
            spectral / 1e6
        );
    }

    /// Figure 1 / Table 2: dense 1,245 GB vs SCT 7.2 GB (172x).
    #[test]
    fn validation_70b_memory() {
        let shape = validation_70b();
        let dense = ModelMemory::dense(&shape, TrainRegime::AdamW);
        assert!((dense.gb() - 1245.0).abs() < 5.0, "dense {:.0} GB", dense.gb());
        let sct = ModelMemory::sct(&shape, 32, SpectralScope::AllLinear, TrainRegime::AdamW);
        assert!((sct.gb() - 7.23).abs() < 0.1, "sct {:.2} GB", sct.gb());
        let ratio = sct.compression_vs_dense(&shape, TrainRegime::AdamW);
        assert!((ratio - 172.0).abs() < 3.0, "ratio {ratio:.0}, paper 172x");
    }

    /// Table 3's parameter column shape: MLP-only spectral at the SmolLM2-
    /// 1.7B geometry. The paper reports 527M total at r=32 with "MLP
    /// spectral parameters only 18M of 527M" and attention 403M.
    #[test]
    fn sweep_1p7b_param_structure() {
        let shape = ModelShape::new(49152, 2048, 24, 8192);
        let spectral_mlp: usize = shape
            .mlp_matrices()
            .iter()
            .map(|l| l.spectral_params(32))
            .sum::<usize>()
            * shape.n_layers;
        assert!(
            (spectral_mlp as f64 / 1e6 - 18.0).abs() < 7.0,
            "MLP spectral params {:.0}M, paper ~18M",
            spectral_mlp as f64 / 1e6
        );
        let attn: usize = shape
            .attn_matrices()
            .iter()
            .map(|l| l.dense_params())
            .sum::<usize>()
            * shape.n_layers;
        assert!(
            (attn as f64 / 1e6 - 403.0).abs() < 10.0,
            "attention params {:.0}M, paper 403M",
            attn as f64 / 1e6
        );
    }

    #[test]
    fn mlp_only_beats_nothing_all_linear_beats_mlp_only() {
        let shape = validation_70b();
        let dense = ModelMemory::dense(&shape, TrainRegime::AdamW).total_bytes;
        let mlp = ModelMemory::sct(&shape, 32, SpectralScope::MlpOnly, TrainRegime::AdamW)
            .total_bytes;
        let all = ModelMemory::sct(&shape, 32, SpectralScope::AllLinear, TrainRegime::AdamW)
            .total_bytes;
        assert!(all < mlp && mlp < dense);
    }

    #[test]
    fn embeddings_accounting() {
        let mut shape = ModelShape::new(1000, 64, 2, 192);
        assert_eq!(shape.embed_params(), 64_000);
        shape.tie_embeddings = false;
        assert_eq!(shape.embed_params(), 128_000);
    }
}
