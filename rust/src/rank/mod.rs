//! `rank` — the adaptive-rank subsystem: live grow/shrink of spectral
//! factors during native training, under scheduled or energy-driven
//! policies.
//!
//! The paper's rank sweep (§4.2: ranks 32–256 all reaching the same loss
//! floor) makes static rank choice look uninteresting — the useful axis is
//! *changing* rank during training. On the native Rust path a rank change
//! is a plain matrix resize (no recompiled PJRT artifact), so transitions
//! are cheap enough to apply at step boundaries.
//!
//! Pieces:
//! * [`resize`] — the mechanics: loss-continuous **grow** (append
//!   orthonormal-complement columns to U/V — the CGS2 construction of the
//!   QR retraction, restricted to new columns — with zero-initialized
//!   singular values, so the forward is bit-identical across the
//!   transition) and **shrink** (drop the smallest-|s| columns, truncated-
//!   SVD semantics). [`resize::RankResize`] reports the kept-column set so
//!   `AdamW::{grow_cols, select_cols}` can resize the optimizer moments in
//!   lockstep.
//! * [`policy`] — the [`policy::RankPolicy`] trait and its three
//!   implementations: [`policy::Fixed`] (static), [`policy::StepSchedule`]
//!   (`[[rank.schedule]]` TOML milestones / `--rank-schedule`), and
//!   [`policy::TailEnergy`] (per-layer adaptive: grow when the smallest
//!   singular values carry more than a threshold share of spectral energy,
//!   shrink when they are dead weight).
//! * [`monitor`] — per-layer spectral/tail-energy stats
//!   ([`monitor::LayerEnergy`]) that feed the adaptive policy, and
//!   [`monitor::RankEvent`] records surfaced through the metrics layer
//!   (`rank_events.jsonl` next to the loss CSVs).
//! * [`spectra`] — the full spectral-health diagnostics built on the same
//!   tail-energy math: per-triple spectrum + tail curve, effective rank
//!   (spectral entropy), condition number, factor ortho error, and
//!   principal-angle subspace drift between samples. Feeds
//!   `spectra.jsonl` (`--spectra-out`), the `sct_spectral_*` gauges, and
//!   the offline `sct doctor` report.
//!
//! Wiring: `train::NativeTrainer::set_layer_rank` applies a transition to
//! one layer (all three MLP triples + Adam moments); the
//! `coordinator::trainer::run_native` loop consults the configured policy
//! at every step boundary; heterogeneous per-layer ranks round-trip
//! through the `.sct` `model/meta` tensor so checkpoints written
//! mid-schedule train on, and serve, unchanged.

pub mod monitor;
pub mod policy;
pub mod resize;
pub mod spectra;

pub use monitor::{
    layer_energy, model_energy, publish_energy, publish_ortho_error, LayerEnergy, RankEvent,
};
pub use spectra::{
    max_principal_angle, model_spectra, principal_angles, spectra_json, DriftTracker,
    LayerSpectrum, TripleSpectrum,
};
pub use policy::{Fixed, RankPolicy, RankPolicyConfig, StepSchedule, TailEnergy};
pub use resize::{grow_triple, resize_triple, shrink_triple, RankResize};
