//! Per-layer spectral-energy monitoring — the measurement side of adaptive
//! rank. The energy-driven policy ([`super::policy::TailEnergy`]) and the
//! metrics surface both read [`LayerEnergy`] rows produced here.
//!
//! For a triple `W = U diag(s) Vᵀ` with orthonormal factors, `sum(s_i^2)`
//! is exactly `||W||_F^2` — the spectral energy — and the share carried by
//! the smallest entries (the *tail*) tells whether the layer is using its
//! full rank budget: a heavy tail means every direction carries signal
//! (the layer is rank-starved, grow), a near-zero tail means the last
//! directions are dead weight (shrink). This is the same energy criterion
//! the paper uses for its 95%-retention dense→spectral conversion, turned
//! into a live training signal.

use crate::json_obj;
use crate::obs;
use crate::serve::engine::{LayerWeights, SpectralModel};
use crate::util::json::Json;

/// Spectral-energy snapshot of one decoder layer (its gate/up/down triples
/// share one rank by construction).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LayerEnergy {
    pub layer: usize,
    /// Current rank k of the layer's MLP triples.
    pub rank: usize,
    /// Total spectral energy `sum s_i^2` across the three triples.
    pub energy: f32,
    /// Fraction of energy carried by the tail (the `ceil(tail_frac * k)`
    /// smallest-|s| entries), maximized over the three triples — the
    /// grow/shrink signal. In `[0, 1]`.
    pub tail_share: f32,
}

/// Tail share of one triple: `(total energy, energy fraction of the
/// `tail_count` smallest-|s| entries)`. Public so [`super::spectra`]
/// reports byte-identical tail energies (the doctor/monitor agreement
/// contract).
pub fn triple_tail_share(s: &[f32], tail_frac: f32) -> (f32, f32) {
    let k = s.len();
    let mut e: Vec<f64> = s.iter().map(|&x| (x as f64) * (x as f64)).collect();
    let total: f64 = e.iter().sum();
    if total <= 0.0 {
        return (0.0, 0.0);
    }
    e.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let tail_count = ((tail_frac as f64 * k as f64).ceil() as usize).clamp(1, k);
    let tail: f64 = e[..tail_count].iter().sum();
    (total as f32, (tail / total) as f32)
}

/// Energy stats for one layer at the given tail fraction.
pub fn layer_energy(idx: usize, layer: &LayerWeights, tail_frac: f32) -> LayerEnergy {
    let mut energy = 0.0f32;
    let mut tail_share = 0.0f32;
    for sl in [&layer.gate, &layer.up, &layer.down] {
        let (e, t) = triple_tail_share(&sl.s, tail_frac);
        energy += e;
        tail_share = tail_share.max(t);
    }
    LayerEnergy { layer: idx, rank: layer.gate.k(), energy, tail_share }
}

/// Energy stats for every layer of the model.
pub fn model_energy(model: &SpectralModel, tail_frac: f32) -> Vec<LayerEnergy> {
    model
        .layers
        .iter()
        .enumerate()
        .map(|(i, l)| layer_energy(i, l, tail_frac))
        .collect()
}

/// One applied rank transition — what the training loop records every time
/// a policy changes a layer's rank.
#[derive(Debug, Clone, PartialEq)]
pub struct RankEvent {
    /// Optimizer step at whose boundary the transition was applied.
    pub step: u64,
    pub layer: usize,
    pub from: usize,
    pub to: usize,
    /// The layer's tail share when the decision was made.
    pub tail_share: f32,
    /// Name of the policy that requested the change.
    pub policy: &'static str,
}

impl RankEvent {
    /// Count this transition on the global registry as
    /// `sct_rank_events_total{dir="grow"|"shrink"}`.
    pub fn publish(&self) {
        let dir = if self.to >= self.from { "grow" } else { "shrink" };
        obs::registry()
            .counter_with(
                "sct_rank_events_total",
                &[("dir", dir)],
                "Applied rank transitions, by direction",
            )
            .inc();
    }

    /// JSON row for `rank_events.jsonl` (written next to the loss CSVs by
    /// the CLI, one object per transition — the metrics surface).
    pub fn to_json(&self) -> Json {
        json_obj![
            ("step", self.step as usize),
            ("layer", self.layer),
            ("from", self.from),
            ("to", self.to),
            ("tail_share", self.tail_share as f64),
            ("policy", self.policy),
        ]
    }
}

/// Publish an energy snapshot as per-layer `sct_rank_layer_rank{layer=i}` /
/// `sct_rank_tail_energy{layer=i}` gauges on the global [`crate::obs`]
/// registry. Runs at the policy-check cadence (not per step), so the
/// registration mutex here is off every hot path.
pub fn publish_energy(stats: &[LayerEnergy]) {
    let r = obs::registry();
    for e in stats {
        let layer = e.layer.to_string();
        r.gauge_with(
            "sct_rank_layer_rank",
            &[("layer", &layer)],
            "Current rank k of the layer's MLP triples",
        )
        .set(e.rank as f64);
        r.gauge_with(
            "sct_rank_tail_energy",
            &[("layer", &layer)],
            "Tail energy share of the layer's spectrum (the grow/shrink signal)",
        )
        .set(e.tail_share as f64);
    }
}

/// Publish the model-wide factor orthonormality error gauge
/// (`sct_rank_ortho_error`, the max `||QᵀQ - I||` across factors).
pub fn publish_ortho_error(err: f32) {
    obs::registry()
        .gauge("sct_rank_ortho_error", "Max factor orthonormality error across the model")
        .set(err as f64);
}

/// One energy snapshot as a JSON row (step + per-layer rank/energy/tail).
pub fn energy_json(step: u64, stats: &[LayerEnergy]) -> Json {
    let layers: Vec<Json> = stats
        .iter()
        .map(|e| {
            json_obj![
                ("layer", e.layer),
                ("rank", e.rank),
                ("energy", e.energy as f64),
                ("tail_share", e.tail_share as f64),
            ]
        })
        .collect();
    json_obj![("step", step as usize), ("layers", layers)]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::engine::EngineConfig;

    #[test]
    fn tail_share_math() {
        // 4 entries, energies 100, 1, 1, 1 -> tail_frac 0.25 keeps 1 entry
        let s = [10.0f32, 1.0, 1.0, 1.0];
        let (e, t) = triple_tail_share(&s, 0.25);
        assert!((e - 103.0).abs() < 1e-4);
        assert!((t - 1.0 / 103.0).abs() < 1e-6);
        // tail_frac 0.5 -> 2 entries
        let (_, t2) = triple_tail_share(&s, 0.5);
        assert!((t2 - 2.0 / 103.0).abs() < 1e-6);
        // all-zero spectrum is defined as zero share
        assert_eq!(triple_tail_share(&[0.0, 0.0], 0.5), (0.0, 0.0));
        // rank 1: the tail is the whole spectrum
        let (_, t3) = triple_tail_share(&[2.0], 0.25);
        assert!((t3 - 1.0).abs() < 1e-6);
    }

    #[test]
    fn tail_share_matches_the_analytic_fixture() {
        // s = [4, 3, 2, 1]: energies 16, 9, 4, 1, total 30. Every value
        // below is exact in f64, so 1e-6 is a real agreement bound — the
        // same bound the spectra.jsonl/doctor acceptance contract uses.
        let s = [4.0f32, 3.0, 2.0, 1.0];
        for (frac, expect) in [
            (0.25, 1.0 / 30.0),  // 1 smallest entry
            (0.5, 5.0 / 30.0),   // 1 + 4
            (0.75, 14.0 / 30.0), // 1 + 4 + 9
            (1.0, 1.0),          // the whole spectrum
        ] {
            let (e, t) = triple_tail_share(&s, frac);
            assert!((e - 30.0).abs() < 1e-6, "energy at frac {frac}: {e}");
            assert!((t - expect as f32).abs() < 1e-6, "tail at frac {frac}: {t} vs {expect}");
        }
        // order-invariant: the tail is defined on sorted energies
        let shuffled = [1.0f32, 4.0, 3.0, 2.0];
        assert_eq!(triple_tail_share(&s, 0.5), triple_tail_share(&shuffled, 0.5));
        // negative entries contribute their square (s may hold signed
        // values mid-transition)
        let signed = [-4.0f32, 3.0, -2.0, 1.0];
        assert_eq!(triple_tail_share(&s, 0.25), triple_tail_share(&signed, 0.25));
    }

    #[test]
    fn model_energy_covers_every_layer() {
        let cfg = EngineConfig {
            vocab: 32,
            d_model: 16,
            n_layers: 3,
            n_heads: 2,
            d_ffn: 24,
            rank: 4,
            max_seq: 16,
            tied: true,
        };
        let model = SpectralModel::init(cfg, 0);
        let stats = model_energy(&model, 0.25);
        assert_eq!(stats.len(), 3);
        for (i, e) in stats.iter().enumerate() {
            assert_eq!(e.layer, i);
            assert_eq!(e.rank, 4);
            assert!(e.energy > 0.0);
            // flat init spectrum: tail of 1-of-4 equal entries carries 1/4
            // of one triple's energy
            assert!((e.tail_share - 0.25).abs() < 1e-3, "flat spectrum share {}", e.tail_share);
        }
    }

    #[test]
    fn publish_surfaces_rank_series_on_the_registry() {
        publish_energy(&[LayerEnergy { layer: 0, rank: 4, energy: 1.0, tail_share: 0.5 }]);
        publish_ortho_error(1e-6);
        RankEvent { step: 1, layer: 0, from: 4, to: 8, tail_share: 0.5, policy: "t" }.publish();
        let text = obs::registry().render_prometheus();
        assert!(text.contains("sct_rank_layer_rank{layer=\"0\"}"));
        assert!(text.contains("sct_rank_tail_energy{layer=\"0\"}"));
        assert!(text.contains("sct_rank_ortho_error"));
        assert!(text.contains("sct_rank_events_total{dir=\"grow\"}"));
    }

    #[test]
    fn event_json_round_trips() {
        let ev = RankEvent { step: 40, layer: 1, from: 8, to: 16, tail_share: 0.2, policy: "tail-energy" };
        let j = ev.to_json().to_string();
        let parsed = Json::parse(&j).unwrap();
        assert_eq!(parsed.get("from").unwrap(), &Json::Num(8.0));
        assert_eq!(parsed.get("policy").unwrap(), &Json::Str("tail-energy".into()));
        let snap = energy_json(3, &[LayerEnergy { layer: 0, rank: 4, energy: 1.0, tail_share: 0.5 }]);
        assert!(snap.to_string().contains("tail_share"));
    }
}
