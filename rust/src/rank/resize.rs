//! Loss-continuous resizing of a spectral factor triple `W = U diag(s) Vᵀ`.
//!
//! On the native path a rank change is a plain matrix resize — no recompiled
//! artifact, no new graph — which is what makes live rank adaptation cheap
//! enough to run at step boundaries:
//!
//! * **Grow** `k → k'`: append `k' - k` orthonormal-complement columns to
//!   `U` and `V` (random draws, CGS2-projected against the existing basis —
//!   the same classical-Gram-Schmidt-twice construction as the Stiefel QR
//!   retraction in [`crate::spectral::qr`], restricted to the new columns)
//!   and append **zero** singular values. Because every new `s` entry is
//!   exactly `0.0`, the new columns contribute exactly-zero terms to
//!   `x → (xU) ⊙ s → (·)Vᵀ`: the forward pass, and therefore the loss, is
//!   bit-identical to the pre-grow factor (the *exact-continuation*
//!   property, asserted in the tests and in `tests/rank_integration.rs`).
//! * **Shrink** `k → k'`: keep the `k'` columns with the largest `|s|`
//!   (truncated-SVD semantics: drop the least-energetic directions first),
//!   preserving their original order so the surviving Adam moments stay
//!   aligned with their parameters. Dropping columns of an orthonormal
//!   matrix leaves the rest orthonormal, so no re-retraction is needed in
//!   exact arithmetic; callers still verify the 2e-6 budget and retract if
//!   a degenerate draw ever exceeds it.
//!
//! The returned [`RankResize`] records what happened — in particular the
//! kept-column set of a shrink — so the optimizer can resize its moment
//! tensors in lockstep (see `AdamW::{grow_cols, select_cols}`).

use crate::spectral::{Matrix, SpectralLinear};
use crate::util::rng::Rng;

/// Outcome of a [`resize_triple`] call, carrying what the optimizer needs
/// to resize its per-tensor state the same way.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RankResize {
    /// Nothing to do — the triple already has the requested rank.
    Unchanged,
    /// Columns appended; moments for the new columns start at zero.
    Grown { from: usize, to: usize },
    /// Columns dropped; `kept` holds the surviving column indices of the
    /// OLD factor, ascending — the moment tensors keep exactly these.
    Shrunk { from: usize, to: usize, kept: Vec<usize> },
}

/// f64-accumulated dot product (accuracy over speed — resize happens at
/// step boundaries, not on the hot path).
fn dot64(a: &[f32], b: &[f32]) -> f64 {
    a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum()
}

/// Append `extra` orthonormal-complement columns to `mat` (m x k, columns
/// assumed orthonormal to the 2e-6 budget). Each new column is a Gaussian
/// draw CGS2-projected against every earlier column (existing + already
/// appended), so the result satisfies the same orthonormality budget as a
/// full QR retraction without perturbing the existing columns at all.
pub fn append_orthonormal_cols(mat: &mut Matrix, extra: usize, rng: &mut Rng) {
    if extra == 0 {
        return;
    }
    let (m, k) = (mat.rows, mat.cols);
    assert!(
        m >= k + extra,
        "cannot extend a {m} x {k} factor by {extra} orthonormal columns"
    );
    // col_into: one fill per existing column, capacity reserved up front
    // (no per-call Vec churn inside the CGS2 loop below).
    let mut cols: Vec<Vec<f32>> = Vec::with_capacity(k + extra);
    for j in 0..k {
        let mut c = Vec::with_capacity(m);
        mat.col_into(j, &mut c);
        cols.push(c);
    }
    for _ in 0..extra {
        // Resample on degenerate draws (norm collapses under projection);
        // with Gaussian draws and m > k this is astronomically rare.
        let mut accepted = None;
        for _attempt in 0..8 {
            let mut v: Vec<f32> = (0..m).map(|_| rng.normal() as f32).collect();
            for _pass in 0..2 {
                for q in &cols {
                    let c = dot64(q, &v) as f32;
                    for (vi, qi) in v.iter_mut().zip(q) {
                        *vi -= c * qi;
                    }
                }
            }
            let norm = dot64(&v, &v).sqrt();
            if norm > 1e-6 {
                let inv = (1.0 / norm) as f32;
                for vi in v.iter_mut() {
                    *vi *= inv;
                }
                accepted = Some(v);
                break;
            }
        }
        cols.push(accepted.expect("orthonormal-complement draw degenerate 8 times"));
    }
    let mut out = Matrix::zeros(m, k + extra);
    for (j, col) in cols.iter().enumerate() {
        for (r, &val) in col.iter().enumerate() {
            out[(r, j)] = val;
        }
    }
    *mat = out;
}

/// Keep only the columns in `kept` (ascending indices into the old factor).
fn select_matrix_cols(mat: &Matrix, kept: &[usize]) -> Matrix {
    let mut out = Matrix::zeros(mat.rows, kept.len());
    for r in 0..mat.rows {
        let row = mat.row(r);
        for (jo, &ji) in kept.iter().enumerate() {
            out[(r, jo)] = row[ji];
        }
    }
    out
}

/// Grow the triple to `new_k` (exact continuation: forward is unchanged).
pub fn grow_triple(sl: &mut SpectralLinear, new_k: usize, rng: &mut Rng) {
    let k = sl.k();
    assert!(new_k >= k, "grow_triple called with new_k {new_k} < k {k}");
    let extra = new_k - k;
    append_orthonormal_cols(&mut sl.u, extra, rng);
    append_orthonormal_cols(&mut sl.v, extra, rng);
    sl.s.resize(new_k, 0.0);
}

/// Shrink the triple to `new_k`, dropping the smallest-|s| columns.
/// Returns the kept column indices (ascending).
pub fn shrink_triple(sl: &mut SpectralLinear, new_k: usize) -> Vec<usize> {
    let k = sl.k();
    assert!(new_k <= k, "shrink_triple called with new_k {new_k} > k {k}");
    assert!(new_k >= 1, "cannot shrink a spectral triple below rank 1");
    let mut order: Vec<usize> = (0..k).collect();
    // Largest |s| first; ties broken by index so the selection (and thus a
    // resumed run) is deterministic.
    order.sort_by(|&a, &b| {
        sl.s[b]
            .abs()
            .partial_cmp(&sl.s[a].abs())
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    let mut kept = order[..new_k].to_vec();
    kept.sort_unstable();
    sl.u = select_matrix_cols(&sl.u, &kept);
    sl.v = select_matrix_cols(&sl.v, &kept);
    sl.s = kept.iter().map(|&j| sl.s[j]).collect();
    kept
}

/// Resize the triple to `new_k` in whichever direction is needed. The
/// target must satisfy `1 <= new_k <= min(m, n)`.
pub fn resize_triple(sl: &mut SpectralLinear, new_k: usize, rng: &mut Rng) -> RankResize {
    let k = sl.k();
    assert!(
        (1..=sl.m().min(sl.n())).contains(&new_k),
        "rank {new_k} out of range for a {} x {} factor",
        sl.m(),
        sl.n()
    );
    match new_k.cmp(&k) {
        std::cmp::Ordering::Equal => RankResize::Unchanged,
        std::cmp::Ordering::Greater => {
            grow_triple(sl, new_k, rng);
            RankResize::Grown { from: k, to: new_k }
        }
        std::cmp::Ordering::Less => {
            let kept = shrink_triple(sl, new_k);
            RankResize::Shrunk { from: k, to: new_k, kept }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn triple(m: usize, n: usize, k: usize, seed: u64) -> SpectralLinear {
        let mut rng = Rng::new(seed);
        let mut sl = SpectralLinear::init(&mut rng, m, n, k);
        // de-degenerate the spectrum so shrink has a real ordering to find
        for (i, s) in sl.s.iter_mut().enumerate() {
            *s *= 1.0 + 0.3 * i as f32;
        }
        sl
    }

    #[test]
    fn grow_is_an_exact_continuation() {
        let mut rng = Rng::new(1);
        let sl0 = triple(24, 18, 4, 7);
        let x = Matrix::randn(&mut rng, 5, 24, 1.0);
        let (y0, _) = sl0.forward(&x);
        let mut sl = sl0.clone();
        grow_triple(&mut sl, 9, &mut rng);
        assert_eq!(sl.k(), 9);
        assert_eq!((sl.u.rows, sl.u.cols), (24, 9));
        assert_eq!((sl.v.rows, sl.v.cols), (18, 9));
        let (y1, _) = sl.forward(&x);
        // zero singular values on the new columns => bit-identical output
        assert_eq!(y0.data, y1.data, "grow must not change the forward at all");
    }

    #[test]
    fn grow_keeps_the_orthonormality_budget() {
        let mut rng = Rng::new(2);
        for &(m, n, k, k2) in &[(16usize, 12usize, 2usize, 8usize), (64, 48, 8, 32), (33, 20, 1, 19)] {
            let mut sl = triple(m, n, k, 3);
            grow_triple(&mut sl, k2, &mut rng);
            assert!(
                sl.ortho_error() <= 2e-6,
                "({m},{n}) {k}->{k2}: ortho {}",
                sl.ortho_error()
            );
        }
    }

    #[test]
    fn shrink_drops_the_smallest_singular_values() {
        let mut sl = triple(20, 14, 6, 11);
        sl.s = vec![0.9, 0.1, 0.5, 0.05, 0.7, 0.3];
        let u0 = sl.u.clone();
        let kept = shrink_triple(&mut sl, 3);
        assert_eq!(kept, vec![0, 2, 4], "largest |s| at 0/2/4, original order kept");
        assert_eq!(sl.s, vec![0.9, 0.5, 0.7]);
        assert_eq!(sl.k(), 3);
        assert!(sl.ortho_error() <= 2e-6, "subset of an orthonormal basis stays orthonormal");
        for (jo, &ji) in kept.iter().enumerate() {
            for r in 0..sl.u.rows {
                assert_eq!(sl.u[(r, jo)], u0[(r, ji)]);
            }
        }
    }

    #[test]
    fn shrink_matches_best_rank_k_of_the_factored_operator() {
        // Keeping the top-|s| columns IS the truncated SVD of W when the
        // factors are orthonormal: check the dense reconstruction agrees.
        let mut sl = triple(16, 10, 5, 13);
        sl.s = vec![1.0, 0.01, 0.6, 0.02, 0.3];
        let dense_before = sl.to_dense();
        let mut truncated = sl.clone();
        // zero out the dropped directions in the full factor (oracle)
        truncated.s[1] = 0.0;
        truncated.s[3] = 0.0;
        let oracle = truncated.to_dense();
        shrink_triple(&mut sl, 3);
        let dense_after = sl.to_dense();
        assert!(dense_after.max_abs_diff(&oracle) < 1e-6);
        // and the dropped energy is exactly the small tail
        let mut diff2 = 0.0f64;
        for (a, b) in dense_before.data.iter().zip(&dense_after.data) {
            diff2 += ((a - b) as f64).powi(2);
        }
        let tail2 = (0.01f64).powi(2) + (0.02f64).powi(2);
        assert!((diff2 - tail2).abs() < 1e-5, "dropped energy {diff2} vs tail {tail2}");
    }

    #[test]
    fn resized_gradients_match_finite_differences() {
        // After a grow AND after a shrink the backward through the resized
        // triple must still match central differences — including the s
        // entries of freshly appended (zero-s) columns, which is where the
        // optimizer first puts the new capacity to work.
        let mut rng = Rng::new(5);
        let mut grown = triple(12, 10, 3, 17);
        grow_triple(&mut grown, 6, &mut rng);
        let mut shrunk = triple(12, 10, 6, 19);
        shrink_triple(&mut shrunk, 3);

        for (tag, layer) in [("grown", &grown), ("shrunk", &shrunk)] {
            let x = Matrix::randn(&mut rng, 4, 12, 1.0);
            let dy = Matrix::randn(&mut rng, 4, 10, 1.0);
            let (_, cache) = layer.forward(&x);
            let (_dx, grads) = layer.backward(&x, &dy, &cache);
            let eval = |l: &SpectralLinear| -> f32 {
                let (y, _) = l.forward(&x);
                y.data.iter().zip(&dy.data).map(|(a, b)| a * b).sum()
            };
            // y is LINEAR in each factor separately, so the central
            // difference is exact for any eps — a large eps just lifts the
            // difference above f32 rounding noise.
            let eps = 1e-2f32;
            // probe every s entry plus a U and a V entry in old + new columns
            for j in 0..layer.k() {
                let mut lp = layer.clone();
                lp.s[j] += eps;
                let mut lm = layer.clone();
                lm.s[j] -= eps;
                let fd = (eval(&lp) - eval(&lm)) / (2.0 * eps);
                let denom = grads.ds[j].abs().max(fd.abs()).max(1e-2);
                assert!(
                    (fd - grads.ds[j]).abs() / denom < 3e-2,
                    "{tag} ds[{j}]: fd {fd} vs analytic {}",
                    grads.ds[j]
                );
            }
            for &(r, c) in &[(0usize, 0usize), (1, layer.k() - 1)] {
                let mut lp = layer.clone();
                lp.u[(r, c)] += eps;
                let mut lm = layer.clone();
                lm.u[(r, c)] -= eps;
                let fd = (eval(&lp) - eval(&lm)) / (2.0 * eps);
                let an = grads.du[(r, c)];
                let denom = an.abs().max(fd.abs()).max(1e-2);
                assert!((fd - an).abs() / denom < 3e-2, "{tag} du[{r},{c}]: fd {fd} vs {an}");
                let mut lp = layer.clone();
                lp.v[(r, c)] += eps;
                let mut lm = layer.clone();
                lm.v[(r, c)] -= eps;
                let fd = (eval(&lp) - eval(&lm)) / (2.0 * eps);
                let an = grads.dv[(r, c)];
                let denom = an.abs().max(fd.abs()).max(1e-2);
                assert!((fd - an).abs() / denom < 3e-2, "{tag} dv[{r},{c}]: fd {fd} vs {an}");
            }
        }
    }

    #[test]
    fn resize_triple_dispatches_and_reports() {
        let mut rng = Rng::new(6);
        let mut sl = triple(10, 8, 4, 23);
        assert_eq!(resize_triple(&mut sl, 4, &mut rng), RankResize::Unchanged);
        assert_eq!(resize_triple(&mut sl, 7, &mut rng), RankResize::Grown { from: 4, to: 7 });
        match resize_triple(&mut sl, 2, &mut rng) {
            RankResize::Shrunk { from: 7, to: 2, kept } => {
                assert_eq!(kept.len(), 2);
                assert!(kept.windows(2).all(|w| w[0] < w[1]), "kept must be ascending");
            }
            other => panic!("expected Shrunk, got {other:?}"),
        }
        assert_eq!(sl.k(), 2);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn resize_rejects_rank_above_min_dim() {
        let mut rng = Rng::new(7);
        let mut sl = triple(10, 8, 4, 29);
        resize_triple(&mut sl, 9, &mut rng);
    }
}
