//! Per-layer spectral-health diagnostics — the measurement half of the
//! spectral-health subsystem (`obs::health` is the reaction half).
//!
//! Because every SCT weight is stored *as* its factorization
//! `W = U diag(s) Vᵀ` with orthonormal `U`, `V`, the singular spectrum is
//! just the `s` vector: no SVD is needed to observe it live. This module
//! turns the raw factors into the quantities the paper's analysis (and the
//! TailEnergy-calibration roadmap item) needs:
//!
//! * the full spectrum (sorted descending) and its **tail-energy curve**
//!   (suffix energy shares) — tail shares are computed by
//!   [`super::monitor::triple_tail_share`], so `spectra.jsonl`, the live
//!   rank policy and `sct doctor` report *identical* numbers;
//! * **effective rank** — `exp(H)` for the spectral entropy
//!   `H = -Σ pᵢ ln pᵢ`, `pᵢ = sᵢ²/Σs²` (k for a flat spectrum, 1 for a
//!   rank-1 one);
//! * **condition number** `s_max/s_min` and the per-factor orthonormality
//!   error `max|QᵀQ − I|`;
//! * **subspace drift**: principal angles between the current `U` (resp.
//!   `V`) and the factor at the previous sample — for orthonormal bases the
//!   cosines are the singular values of `U_prevᵀ U_now`, so a k×k Jacobi
//!   SVD per factor measures how fast training rotates the subspace.
//!
//! Snapshots stream to `spectra.jsonl` ([`spectra_json`]), to
//! `sct_spectral_*` gauges ([`publish`]), and to the offline
//! `sct doctor <ckpt.sct>` report.

use crate::json_obj;
use crate::obs;
use crate::rank::monitor;
use crate::serve::engine::{LayerWeights, SpectralModel};
use crate::spectral::{svd, Matrix, SpectralLinear};
use crate::util::json::Json;

/// Names of the three spectral triples of a decoder layer, in report order.
pub const TRIPLE_NAMES: [&str; 3] = ["gate", "up", "down"];

/// Diagnostics for one spectral triple `W = U diag(s) Vᵀ`.
#[derive(Debug, Clone)]
pub struct TripleSpectrum {
    /// `"gate"`, `"up"` or `"down"`.
    pub name: &'static str,
    pub rank: usize,
    /// Singular values sorted descending.
    pub spectrum: Vec<f32>,
    /// Total spectral energy `Σ sᵢ²` (== `||W||_F²` for orthonormal factors).
    pub energy: f32,
    /// Tail-energy share at the monitor's tail fraction — bit-identical to
    /// [`monitor::triple_tail_share`].
    pub tail_share: f32,
    /// `tail_curve[i]` = share of energy in `spectrum[i..]`; `[0]` is 1.
    pub tail_curve: Vec<f32>,
    /// `exp` of the spectral entropy of `s²` — k if flat, 1 if rank-1.
    pub effective_rank: f32,
    /// `s_max / s_min` (infinite when the smallest singular value is 0).
    pub condition: f32,
    /// `max|UᵀU − I|`.
    pub ortho_u: f32,
    /// `max|VᵀV − I|`.
    pub ortho_v: f32,
    /// Largest principal angle (radians) between the current U and the
    /// previous sample's U; `None` on the first sample.
    pub drift_u: Option<f32>,
    /// Same for V.
    pub drift_v: Option<f32>,
}

/// Diagnostics for one decoder layer. The layer-level `energy`/`tail_share`
/// are exactly [`monitor::layer_energy`]'s values (the acceptance contract
/// between `spectra.jsonl` and the rank monitor).
#[derive(Debug, Clone)]
pub struct LayerSpectrum {
    pub layer: usize,
    pub rank: usize,
    pub energy: f32,
    pub tail_share: f32,
    pub triples: Vec<TripleSpectrum>,
}

/// Principal angles (radians, ascending) between the column spaces of two
/// orthonormal-column matrices: `cos θᵢ` are the singular values of `AᵀB`.
/// Defined for differing ranks (`min(k_a, k_b)` angles), which is what a
/// drift sample straddling a rank transition produces.
pub fn principal_angles(a: &Matrix, b: &Matrix) -> Vec<f32> {
    let gram = a.t_matmul(b); // k_a x k_b
    let mut angles: Vec<f32> =
        svd::svd(&gram).s.iter().map(|c| c.clamp(-1.0, 1.0).acos()).collect();
    angles.sort_by(|x, y| x.partial_cmp(y).unwrap_or(std::cmp::Ordering::Equal));
    angles
}

/// Largest principal angle — the scalar "how far did the subspace move"
/// drift signal. 0 for identical spans, π/2 for orthogonal ones.
pub fn max_principal_angle(a: &Matrix, b: &Matrix) -> f32 {
    principal_angles(a, b).last().copied().unwrap_or(0.0)
}

/// Diagnostics for one triple (drift left unset — see [`DriftTracker`]).
pub fn triple_spectrum(name: &'static str, sl: &SpectralLinear, tail_frac: f32) -> TripleSpectrum {
    let mut spectrum = sl.s.clone();
    spectrum.sort_by(|a, b| b.abs().partial_cmp(&a.abs()).unwrap_or(std::cmp::Ordering::Equal));
    let (energy, tail_share) = monitor::triple_tail_share(&sl.s, tail_frac);

    // Suffix energy shares over the descending spectrum, accumulated in f64
    // from the small end so the tiny tail entries are not absorbed.
    let e2: Vec<f64> = spectrum.iter().map(|&x| (x as f64) * (x as f64)).collect();
    let total: f64 = e2.iter().sum();
    let mut tail_curve = vec![0.0f32; spectrum.len()];
    if total > 0.0 {
        let mut acc = 0.0f64;
        for i in (0..e2.len()).rev() {
            acc += e2[i];
            tail_curve[i] = (acc / total) as f32;
        }
    }

    // Spectral entropy -> effective rank.
    let effective_rank = if total > 0.0 {
        let mut h = 0.0f64;
        for &e in &e2 {
            let p = e / total;
            if p > 0.0 {
                h -= p * p.ln();
            }
        }
        h.exp() as f32
    } else {
        0.0
    };

    let s_max = spectrum.first().map(|s| s.abs()).unwrap_or(0.0);
    let s_min = spectrum.last().map(|s| s.abs()).unwrap_or(0.0);
    let condition = if s_min > 0.0 { s_max / s_min } else { f32::INFINITY };

    TripleSpectrum {
        name,
        rank: sl.k(),
        spectrum,
        energy,
        tail_share,
        tail_curve,
        effective_rank,
        condition,
        ortho_u: sl.u.ortho_error(),
        ortho_v: sl.v.ortho_error(),
        drift_u: None,
        drift_v: None,
    }
}

/// Full diagnostics for one decoder layer.
pub fn layer_spectrum(idx: usize, layer: &LayerWeights, tail_frac: f32) -> LayerSpectrum {
    let le = monitor::layer_energy(idx, layer, tail_frac);
    let triples = vec![
        triple_spectrum("gate", &layer.gate, tail_frac),
        triple_spectrum("up", &layer.up, tail_frac),
        triple_spectrum("down", &layer.down, tail_frac),
    ];
    LayerSpectrum { layer: idx, rank: le.rank, energy: le.energy, tail_share: le.tail_share, triples }
}

/// Diagnostics for every layer of a model (training snapshot or a
/// checkpoint loaded by `sct doctor`).
pub fn model_spectra(model: &SpectralModel, tail_frac: f32) -> Vec<LayerSpectrum> {
    model.layers.iter().enumerate().map(|(i, l)| layer_spectrum(i, l, tail_frac)).collect()
}

/// Remembers the last-sampled U/V factors and fills in principal-angle
/// drift on each new snapshot. One tracker per training run; memory cost is
/// one factor copy per triple (k(m+n) floats — the compact factors, never a
/// dense matrix).
#[derive(Default)]
pub struct DriftTracker {
    /// `prev[layer][triple] = (U, V)` at the previous sample.
    prev: Vec<Vec<Option<(Matrix, Matrix)>>>,
}

impl DriftTracker {
    pub fn new() -> DriftTracker {
        DriftTracker::default()
    }

    /// Fill `drift_u`/`drift_v` on `spectra` against the previous sample of
    /// `model`, then remember the current factors for the next call.
    pub fn observe(&mut self, model: &SpectralModel, spectra: &mut [LayerSpectrum]) {
        self.prev.resize_with(model.layers.len(), Vec::new);
        for (li, layer) in model.layers.iter().enumerate() {
            let slot = &mut self.prev[li];
            slot.resize_with(TRIPLE_NAMES.len(), || None);
            let triples = [&layer.gate, &layer.up, &layer.down];
            for (ti, sl) in triples.iter().enumerate() {
                if let Some(ts) = spectra.get_mut(li).and_then(|l| l.triples.get_mut(ti)) {
                    if let Some((pu, pv)) = &slot[ti] {
                        ts.drift_u = Some(max_principal_angle(pu, &sl.u));
                        ts.drift_v = Some(max_principal_angle(pv, &sl.v));
                    }
                }
                slot[ti] = Some((sl.u.clone(), sl.v.clone()));
            }
        }
    }
}

fn finite_num(v: f32) -> Json {
    if v.is_finite() {
        Json::Num(v as f64)
    } else {
        Json::Null
    }
}

fn opt_num(v: Option<f32>) -> Json {
    match v {
        Some(x) => finite_num(x),
        None => Json::Null,
    }
}

/// One snapshot as a `spectra.jsonl` row.
pub fn spectra_json(step: u64, spectra: &[LayerSpectrum]) -> Json {
    let layers: Vec<Json> = spectra
        .iter()
        .map(|l| {
            let triples: Vec<Json> = l
                .triples
                .iter()
                .map(|t| {
                    json_obj![
                        ("name", t.name),
                        ("rank", t.rank),
                        (
                            "spectrum",
                            Json::Arr(t.spectrum.iter().map(|&s| Json::Num(s as f64)).collect())
                        ),
                        ("energy", t.energy as f64),
                        ("tail_share", t.tail_share as f64),
                        (
                            "tail_curve",
                            Json::Arr(t.tail_curve.iter().map(|&s| Json::Num(s as f64)).collect())
                        ),
                        ("effective_rank", t.effective_rank as f64),
                        ("condition", finite_num(t.condition)),
                        ("ortho_u", t.ortho_u as f64),
                        ("ortho_v", t.ortho_v as f64),
                        ("drift_u", opt_num(t.drift_u)),
                        ("drift_v", opt_num(t.drift_v)),
                    ]
                })
                .collect();
            json_obj![
                ("layer", l.layer),
                ("rank", l.rank),
                ("energy", l.energy as f64),
                ("tail_share", l.tail_share as f64),
                ("triples", triples),
            ]
        })
        .collect();
    json_obj![("step", step as usize), ("layers", layers)]
}

/// Publish a snapshot as per-layer `sct_spectral_*` gauges on the global
/// registry. Runs at the spectra cadence (and once at serve startup), so
/// the registration mutex is off every hot path.
pub fn publish(spectra: &[LayerSpectrum]) {
    let r = obs::registry();
    for l in spectra {
        let layer = l.layer.to_string();
        let layer_s: &str = &layer;
        let lbl: &[(&str, &str)] = &[("layer", layer_s)];
        r.gauge_with("sct_spectral_energy", lbl, "Total spectral energy of the layer's MLP triples")
            .set(l.energy as f64);
        r.gauge_with(
            "sct_spectral_tail_share",
            lbl,
            "Tail energy share of the layer's spectrum (matches sct_rank_tail_energy)",
        )
        .set(l.tail_share as f64);
        let mut eff = 0.0f64;
        let mut cond = 0.0f64;
        let mut ortho = 0.0f64;
        let mut drift = None::<f64>;
        for t in &l.triples {
            eff += t.effective_rank as f64 / l.triples.len() as f64;
            if t.condition.is_finite() {
                cond = cond.max(t.condition as f64);
            }
            ortho = ortho.max(t.ortho_u.max(t.ortho_v) as f64);
            if let Some(d) = t.drift_u.into_iter().chain(t.drift_v).reduce(f32::max) {
                drift = Some(drift.unwrap_or(0.0).max(d as f64));
            }
        }
        r.gauge_with(
            "sct_spectral_effective_rank",
            lbl,
            "exp(spectral entropy), averaged over the layer's triples",
        )
        .set(eff);
        r.gauge_with(
            "sct_spectral_condition",
            lbl,
            "Worst finite condition number s_max/s_min across the layer's triples",
        )
        .set(cond);
        r.gauge_with(
            "sct_spectral_ortho_error",
            lbl,
            "Worst factor orthonormality error max|QtQ-I| across the layer's triples",
        )
        .set(ortho);
        if let Some(d) = drift {
            r.gauge_with(
                "sct_spectral_drift",
                lbl,
                "Largest principal angle (radians) vs the previous sample's subspaces",
            )
            .set(d);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::engine::EngineConfig;
    use crate::util::rng::Rng;

    fn tiny_model() -> SpectralModel {
        let cfg = EngineConfig {
            vocab: 32,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ffn: 24,
            rank: 4,
            max_seq: 16,
            tied: true,
        };
        SpectralModel::init(cfg, 7)
    }

    #[test]
    fn principal_angles_identical_and_orthogonal() {
        // Identical spans -> every angle 0.
        let mut rng = Rng::new(3);
        let q = crate::spectral::qr_retract(&Matrix::randn(&mut rng, 8, 3, 1.0));
        for a in principal_angles(&q, &q) {
            assert!(a.abs() < 1e-3, "identical factors should have zero drift, got {a}");
        }
        assert!(max_principal_angle(&q, &q) < 1e-3);

        // span{e1,e2} vs span{e3,e4} in R^4 -> both angles are pi/2.
        let mut a = Matrix::zeros(4, 2);
        a[(0, 0)] = 1.0;
        a[(1, 1)] = 1.0;
        let mut b = Matrix::zeros(4, 2);
        b[(2, 0)] = 1.0;
        b[(3, 1)] = 1.0;
        let angles = principal_angles(&a, &b);
        assert_eq!(angles.len(), 2);
        for ang in angles {
            assert!((ang - std::f32::consts::FRAC_PI_2).abs() < 1e-6, "got {ang}");
        }
    }

    #[test]
    fn analytic_spectrum_diagnostics() {
        // Known spectrum s = [4,3,2,1]: energy 30, tail(0.25) = 1/30,
        // condition 4, and the tail curve is the exact suffix shares.
        let mut rng = Rng::new(1);
        let mut sl = SpectralLinear::init(&mut rng, 8, 6, 4);
        sl.s = vec![4.0, 3.0, 2.0, 1.0];
        let t = triple_spectrum("gate", &sl, 0.25);
        assert_eq!(t.spectrum, vec![4.0, 3.0, 2.0, 1.0]);
        assert!((t.energy - 30.0).abs() < 1e-4);
        assert!((t.tail_share - 1.0 / 30.0).abs() < 1e-6);
        let expect_curve = [30.0 / 30.0, 14.0 / 30.0, 5.0 / 30.0, 1.0 / 30.0];
        for (got, want) in t.tail_curve.iter().zip(expect_curve) {
            assert!((got - want).abs() < 1e-6, "curve {got} vs {want}");
        }
        assert!((t.condition - 4.0).abs() < 1e-5);
        // Entropy of p = [16,9,4,1]/30 -> effective rank exp(H).
        let p = [16.0f64 / 30.0, 9.0 / 30.0, 4.0 / 30.0, 1.0 / 30.0];
        let h: f64 = -p.iter().map(|x| x * x.ln()).sum::<f64>();
        assert!((t.effective_rank as f64 - h.exp()).abs() < 1e-4);
        // Factors from init are orthonormal.
        assert!(t.ortho_u < 2e-6 && t.ortho_v < 2e-6);

        // Flat spectrum: effective rank == k, condition == 1.
        sl.s = vec![2.0; 4];
        let flat = triple_spectrum("up", &sl, 0.25);
        assert!((flat.effective_rank - 4.0).abs() < 1e-4);
        assert!((flat.condition - 1.0).abs() < 1e-6);

        // Dead spectrum: condition is infinite -> rendered as JSON null.
        sl.s = vec![1.0, 0.0, 0.0, 0.0];
        let dead = triple_spectrum("down", &sl, 0.25);
        assert!(dead.condition.is_infinite());
        assert!((dead.effective_rank - 1.0).abs() < 1e-4);
    }

    #[test]
    fn layer_values_match_rank_monitor_exactly() {
        let model = tiny_model();
        let spectra = model_spectra(&model, 0.25);
        let energy = monitor::model_energy(&model, 0.25);
        assert_eq!(spectra.len(), energy.len());
        for (s, e) in spectra.iter().zip(&energy) {
            assert_eq!(s.layer, e.layer);
            assert_eq!(s.rank, e.rank);
            // Same code path, so bit-identical — the 1e-6 acceptance bound
            // holds trivially.
            assert_eq!(s.energy, e.energy);
            assert_eq!(s.tail_share, e.tail_share);
        }
    }

    #[test]
    fn drift_tracker_zero_on_unchanged_model() {
        let model = tiny_model();
        let mut tracker = DriftTracker::new();
        let mut first = model_spectra(&model, 0.25);
        tracker.observe(&model, &mut first);
        for t in first.iter().flat_map(|l| &l.triples) {
            assert!(t.drift_u.is_none() && t.drift_v.is_none(), "no drift on first sample");
        }
        let mut second = model_spectra(&model, 0.25);
        tracker.observe(&model, &mut second);
        for t in second.iter().flat_map(|l| &l.triples) {
            assert!(t.drift_u.unwrap() < 1e-3, "unchanged U drifted {:?}", t.drift_u);
            assert!(t.drift_v.unwrap() < 1e-3, "unchanged V drifted {:?}", t.drift_v);
        }
    }

    #[test]
    fn spectra_json_round_trips_and_publishes() {
        let model = tiny_model();
        let mut tracker = DriftTracker::new();
        let mut spectra = model_spectra(&model, 0.25);
        tracker.observe(&model, &mut spectra);
        let row = spectra_json(12, &spectra);
        let parsed = Json::parse(&row.to_string()).unwrap();
        assert_eq!(parsed.get("step").unwrap(), &Json::Num(12.0));
        let layers = match parsed.get("layers").unwrap() {
            Json::Arr(a) => a,
            other => panic!("layers not an array: {other:?}"),
        };
        assert_eq!(layers.len(), 2);
        let triple0 = match layers[0].get("triples").unwrap() {
            Json::Arr(a) => &a[0],
            other => panic!("triples not an array: {other:?}"),
        };
        assert_eq!(triple0.get("name").unwrap(), &Json::Str("gate".into()));
        assert_eq!(triple0.get("drift_u").unwrap(), &Json::Null);

        publish(&spectra);
        let text = obs::registry().render_prometheus();
        assert!(text.contains("sct_spectral_tail_share{layer=\"0\"}"));
        assert!(text.contains("sct_spectral_effective_rank{layer=\"1\"}"));
        assert!(text.contains("sct_spectral_condition{layer=\"0\"}"));
        assert!(text.contains("sct_spectral_ortho_error{layer=\"0\"}"));
    }
}
