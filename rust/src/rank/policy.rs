//! Rank-transition policies: who decides a layer's rank, and when.
//!
//! A [`RankPolicy`] is consulted by the native training loop at every step
//! boundary with the layer's current [`LayerEnergy`] stats; returning
//! `Some(k)` asks the trainer to resize that layer to rank `k` before the
//! step runs. Three implementations:
//!
//! * [`Fixed`] — never changes anything (the paper's static-rank protocol).
//! * [`StepSchedule`] — TOML-declared milestones (`[[rank.schedule]]` with
//!   `step`/`rank` keys, or `--rank-schedule "100:16,400:32"`). The target
//!   at step `t` is the latest milestone with `step <= t`, which makes the
//!   policy a pure function of the step — a resumed run lands on the same
//!   rank an uninterrupted run would have.
//! * [`TailEnergy`] — per-layer adaptive (the Swift-SVD / AdaSVD
//!   direction): every `check_every` steps, grow a layer whose smallest
//!   singular values still carry more than `grow_above` of its spectral
//!   energy (every direction is loaded — the layer is rank-starved), and
//!   shrink one whose tail carries less than `shrink_below` (capacity is
//!   sitting idle). Targets are clamped to `[min_rank, max_rank]` and move
//!   by `ceil(step_frac * k)` columns at a time so one noisy snapshot
//!   cannot whiplash the factor sizes.
//!
//! [`RankPolicyConfig`] is the serializable description the config layer
//! produces (TOML / CLI) and [`RankPolicyConfig::build`] turns into a live
//! policy for the run.

use anyhow::{bail, Context, Result};

use super::monitor::LayerEnergy;

/// A rank-transition decision maker. Implementations must be deterministic
/// in `(step, stats)` so checkpoint-resumed runs behave identically.
pub trait RankPolicy {
    fn name(&self) -> &'static str;

    /// Target rank for the layer described by `stats` at the boundary
    /// before `step` executes; `None` means keep the current rank.
    fn target(&mut self, step: u64, stats: &LayerEnergy) -> Option<usize>;

    /// Whether this policy needs energy stats at `step` — lets the trainer
    /// skip the per-layer spectrum scan on steps with no decision.
    fn wants_stats(&self, step: u64) -> bool {
        let _ = step;
        true
    }

    /// Whether decisions read `energy`/`tail_share` at all. Schedule-style
    /// policies only compare ranks, so the trainer can hand them cheap
    /// rank-only stats instead of sorting every singular-value vector at
    /// every post-milestone step boundary.
    fn needs_energy(&self) -> bool {
        true
    }
}

/// Static rank — the identity policy.
#[derive(Debug, Clone, Default)]
pub struct Fixed;

impl RankPolicy for Fixed {
    fn name(&self) -> &'static str {
        "fixed"
    }

    fn target(&mut self, _step: u64, _stats: &LayerEnergy) -> Option<usize> {
        None
    }

    fn wants_stats(&self, _step: u64) -> bool {
        false
    }

    fn needs_energy(&self) -> bool {
        false
    }
}

/// Scheduled transitions: `(step, rank)` milestones, sorted by step.
#[derive(Debug, Clone)]
pub struct StepSchedule {
    milestones: Vec<(u64, usize)>,
}

impl StepSchedule {
    pub fn new(mut milestones: Vec<(u64, usize)>) -> StepSchedule {
        milestones.sort_by_key(|&(s, _)| s);
        StepSchedule { milestones }
    }
}

impl RankPolicy for StepSchedule {
    fn name(&self) -> &'static str {
        "schedule"
    }

    fn target(&mut self, step: u64, stats: &LayerEnergy) -> Option<usize> {
        self.milestones
            .iter()
            .rev()
            .find(|&&(s, _)| s <= step)
            .map(|&(_, k)| k)
            .filter(|&k| k != stats.rank)
    }

    fn wants_stats(&self, step: u64) -> bool {
        // stats are only needed to compare against the current rank; the
        // trainer's scan is cheap but skippable before the first milestone
        self.milestones.first().is_some_and(|&(s, _)| step >= s)
    }

    /// Schedule targets depend only on the step and current rank — no
    /// spectrum scan needed.
    fn needs_energy(&self) -> bool {
        false
    }
}

/// Per-layer adaptive policy driven by tail spectral energy.
#[derive(Debug, Clone)]
pub struct TailEnergy {
    pub tail_frac: f32,
    pub grow_above: f32,
    pub shrink_below: f32,
    pub min_rank: usize,
    pub max_rank: usize,
    pub check_every: u64,
    pub step_frac: f32,
}

impl TailEnergy {
    fn delta(&self, k: usize) -> usize {
        ((self.step_frac * k as f32).ceil() as usize).max(1)
    }
}

impl RankPolicy for TailEnergy {
    fn name(&self) -> &'static str {
        "tail-energy"
    }

    fn target(&mut self, step: u64, stats: &LayerEnergy) -> Option<usize> {
        if !self.wants_stats(step) {
            return None;
        }
        let k = stats.rank;
        if stats.tail_share > self.grow_above && k < self.max_rank {
            return Some((k + self.delta(k)).min(self.max_rank));
        }
        if stats.tail_share < self.shrink_below && k > self.min_rank {
            return Some(k.saturating_sub(self.delta(k)).max(self.min_rank));
        }
        None
    }

    fn wants_stats(&self, step: u64) -> bool {
        // step 0 is the random init (its spectrum is flat by construction,
        // not informative); decide only on trained spectra.
        step > 0 && step % self.check_every == 0
    }
}

/// Serializable policy description — what `[rank]` TOML / CLI flags parse
/// into and `RunConfig` carries.
#[derive(Debug, Clone, PartialEq, Default)]
pub enum RankPolicyConfig {
    #[default]
    Fixed,
    Schedule(Vec<(u64, usize)>),
    TailEnergy {
        tail_frac: f32,
        grow_above: f32,
        shrink_below: f32,
        min_rank: usize,
        max_rank: usize,
        check_every: u64,
        step_frac: f32,
    },
}

impl RankPolicyConfig {
    /// The default adaptive knobs (`[rank] policy = "tail-energy"` with no
    /// overrides): check every 50 steps, quarter-spectrum tail, grow above
    /// 12% tail share, shrink below 1%, quarter-rank increments. Pass
    /// `usize::MAX` as `max_rank` to mean "up to the model's capacity" —
    /// [`RankPolicyConfig::validated`] clamps it to the real
    /// `min(d_model, d_ffn)` at run time, AFTER every geometry flag has
    /// been applied.
    pub fn tail_energy_defaults(min_rank: usize, max_rank: usize) -> RankPolicyConfig {
        RankPolicyConfig::TailEnergy {
            tail_frac: 0.25,
            grow_above: 0.12,
            shrink_below: 0.01,
            min_rank,
            max_rank,
            check_every: 50,
            step_frac: 0.25,
        }
    }

    /// Parse a `"step:rank,step:rank"` schedule string (the
    /// `--rank-schedule` flag wire format).
    pub fn parse_schedule(text: &str) -> Result<Vec<(u64, usize)>> {
        let mut out = Vec::new();
        for part in text.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (step, rank) = part
                .split_once(':')
                .with_context(|| format!("milestone {part:?}: expected \"step:rank\""))?;
            let step: u64 = step.trim().parse().with_context(|| format!("bad step in {part:?}"))?;
            let rank: usize =
                rank.trim().parse().with_context(|| format!("bad rank in {part:?}"))?;
            if rank == 0 {
                bail!("milestone {part:?}: rank must be >= 1");
            }
            out.push((step, rank));
        }
        if out.is_empty() {
            bail!("empty rank schedule");
        }
        out.sort_by_key(|&(s, _)| s);
        Ok(out)
    }

    /// Check this policy against the model's rank capacity
    /// `cap = min(d_model, d_ffn)` and return the run-ready config —
    /// called by the training loop BEFORE the first step, so a milestone
    /// that could never apply fails fast instead of aborting the run
    /// thousands of steps in at the milestone boundary.
    ///
    /// Schedule milestones out of `1..=cap` are an error. A `TailEnergy`
    /// `max_rank` above `cap` is clamped rather than rejected: the default
    /// knobs bake in the geometry known at config-parse time, which CLI
    /// shape flags may later shrink.
    pub fn validated(&self, cap: usize) -> Result<RankPolicyConfig> {
        match self {
            RankPolicyConfig::Fixed => Ok(RankPolicyConfig::Fixed),
            RankPolicyConfig::Schedule(ms) => {
                for &(step, rank) in ms {
                    if !(1..=cap).contains(&rank) {
                        bail!(
                            "rank schedule milestone {step}:{rank} out of range for this \
                             model (min(d_model, d_ffn) = {cap})"
                        );
                    }
                }
                Ok(RankPolicyConfig::Schedule(ms.clone()))
            }
            RankPolicyConfig::TailEnergy {
                tail_frac,
                grow_above,
                shrink_below,
                min_rank,
                max_rank,
                check_every,
                step_frac,
            } => {
                let min_rank = (*min_rank).max(1);
                let max_rank = (*max_rank).min(cap);
                if min_rank > max_rank {
                    bail!(
                        "[rank] min_rank {min_rank} exceeds max_rank {max_rank} \
                         (capacity min(d_model, d_ffn) = {cap})"
                    );
                }
                Ok(RankPolicyConfig::TailEnergy {
                    tail_frac: *tail_frac,
                    grow_above: *grow_above,
                    shrink_below: *shrink_below,
                    min_rank,
                    max_rank,
                    check_every: *check_every,
                    step_frac: *step_frac,
                })
            }
        }
    }

    /// The tail fraction the monitor should use when computing stats for
    /// this policy (policies without an energy criterion use the default).
    pub fn tail_frac(&self) -> f32 {
        match self {
            RankPolicyConfig::TailEnergy { tail_frac, .. } => *tail_frac,
            _ => 0.25,
        }
    }

    /// Instantiate the live policy.
    pub fn build(&self) -> Box<dyn RankPolicy> {
        match self {
            RankPolicyConfig::Fixed => Box::new(Fixed),
            RankPolicyConfig::Schedule(m) => Box::new(StepSchedule::new(m.clone())),
            RankPolicyConfig::TailEnergy {
                tail_frac,
                grow_above,
                shrink_below,
                min_rank,
                max_rank,
                check_every,
                step_frac,
            } => Box::new(TailEnergy {
                tail_frac: *tail_frac,
                grow_above: *grow_above,
                shrink_below: *shrink_below,
                min_rank: (*min_rank).max(1),
                max_rank: *max_rank,
                check_every: (*check_every).max(1),
                step_frac: *step_frac,
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(rank: usize, tail_share: f32) -> LayerEnergy {
        LayerEnergy { layer: 0, rank, energy: 1.0, tail_share }
    }

    #[test]
    fn fixed_never_moves() {
        let mut p = Fixed;
        assert_eq!(p.target(100, &stats(8, 0.9)), None);
        assert!(!p.wants_stats(100));
    }

    #[test]
    fn schedule_applies_latest_milestone_at_or_before_step() {
        let mut p = StepSchedule::new(vec![(200, 32), (50, 16)]);
        assert_eq!(p.target(0, &stats(8, 0.0)), None, "before the first milestone");
        assert!(!p.wants_stats(0));
        assert_eq!(p.target(50, &stats(8, 0.0)), Some(16));
        assert_eq!(p.target(120, &stats(16, 0.0)), None, "already at target");
        assert_eq!(p.target(200, &stats(16, 0.0)), Some(32));
        // resume semantics: a run restarted at step 500 jumps straight to 32
        assert_eq!(p.target(500, &stats(8, 0.0)), Some(32));
    }

    #[test]
    fn tail_energy_grows_and_shrinks_with_clamps() {
        let mut p = TailEnergy {
            tail_frac: 0.25,
            grow_above: 0.12,
            shrink_below: 0.01,
            min_rank: 4,
            max_rank: 32,
            check_every: 10,
            step_frac: 0.25,
        };
        // off-cadence steps decide nothing
        assert_eq!(p.target(7, &stats(8, 0.9)), None);
        assert_eq!(p.target(0, &stats(8, 0.9)), None, "step 0 is the flat init");
        // heavy tail -> grow by ceil(0.25 * 8) = 2
        assert_eq!(p.target(10, &stats(8, 0.5)), Some(10));
        // dead tail -> shrink by 2
        assert_eq!(p.target(10, &stats(8, 0.001)), Some(6));
        // in the comfort band -> keep
        assert_eq!(p.target(10, &stats(8, 0.05)), None);
        // clamped at both ends
        assert_eq!(p.target(10, &stats(31, 0.5)), Some(32));
        assert_eq!(p.target(10, &stats(32, 0.5)), None);
        assert_eq!(p.target(10, &stats(5, 0.001)), Some(4));
        assert_eq!(p.target(10, &stats(4, 0.001)), None);
    }

    #[test]
    fn parse_schedule_forms() {
        assert_eq!(
            RankPolicyConfig::parse_schedule("100:16, 400:32").unwrap(),
            vec![(100, 16), (400, 32)]
        );
        // unsorted input is sorted
        assert_eq!(
            RankPolicyConfig::parse_schedule("400:32,100:16").unwrap(),
            vec![(100, 16), (400, 32)]
        );
        assert!(RankPolicyConfig::parse_schedule("").is_err());
        assert!(RankPolicyConfig::parse_schedule("100").is_err());
        assert!(RankPolicyConfig::parse_schedule("100:0").is_err());
        assert!(RankPolicyConfig::parse_schedule("x:8").is_err());
    }

    #[test]
    fn validated_rejects_impossible_schedules_and_clamps_tail_energy() {
        // fail-fast: a milestone above min(d_model, d_ffn) errors before
        // the run starts, not at the milestone step
        let sched = RankPolicyConfig::Schedule(vec![(10, 8), (5000, 64)]);
        assert!(sched.validated(16).is_err());
        assert!(sched.validated(64).is_ok());
        assert_eq!(RankPolicyConfig::Fixed.validated(1).unwrap(), RankPolicyConfig::Fixed);
        // tail-energy defaults bake in parse-time geometry; validated()
        // clamps max_rank to the real capacity instead of erroring
        match RankPolicyConfig::tail_energy_defaults(2, 64).validated(16).unwrap() {
            RankPolicyConfig::TailEnergy { min_rank, max_rank, .. } => {
                assert_eq!((min_rank, max_rank), (2, 16));
            }
            other => panic!("expected TailEnergy, got {other:?}"),
        }
        // but an explicit min above the capacity is a real error
        assert!(RankPolicyConfig::tail_energy_defaults(32, 64).validated(16).is_err());
    }

    #[test]
    fn config_builds_the_right_policy() {
        assert_eq!(RankPolicyConfig::Fixed.build().name(), "fixed");
        assert_eq!(RankPolicyConfig::Schedule(vec![(1, 2)]).build().name(), "schedule");
        assert_eq!(RankPolicyConfig::tail_energy_defaults(2, 64).build().name(), "tail-energy");
    }
}
