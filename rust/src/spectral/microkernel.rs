//! Cache-blocked SIMD matmul microkernels — the canonical accumulation
//! orders behind every hot kernel in `spectral::matrix`.
//!
//! # The two canonical primitives
//!
//! Every f32 value this module produces is defined by one of two fixed
//! accumulation recipes, stated here once and implemented twice (an
//! AVX2+FMA path and a portable fused-scalar path) with **bit-identical**
//! results:
//!
//! * **Broadcast-FMA fold** (`matmul`, `t_matmul`, [`axpy`]): each output
//!   element is a fold of IEEE-754 fused multiply-adds over the shared
//!   dimension in ascending order — `acc = fma(a_ik, b_kj, acc)` for
//!   `k = 0, 1, …`. Register tiling ([`MR`]×[`NR`] output tiles in
//!   [`gebp_tile`]) and k-panel packing change only *which* elements are
//!   computed together and *where* their operands are read from, never the
//!   per-element fold — so any row/column tiling, any `par_rows` shard
//!   decomposition, and the unpacked thin-output stream kernel all produce
//!   the same bits.
//! * **8-lane fused dot** ([`dot`], [`dot8_rows`]): lane `l` accumulates
//!   elements `8i + l` with fused multiply-adds, the eight lanes reduce in
//!   the fixed tree `((l0+l1)+(l2+l3)) + ((l4+l5)+(l6+l7))`, and the ragged
//!   tail folds in sequentially (fused). The structure depends only on the
//!   slice *length*, which is what makes `matmul_t_prefix`'s rank-grow
//!   invariant hold: a prefix dot of length `k_eff` is bit-identical to a
//!   full dot over a `k_eff`-column matrix.
//!
//! # Why the two paths can't diverge
//!
//! IEEE-754 `fusedMultiplyAdd` is exactly specified (one rounding), so
//! `_mm256_fmadd_ps` lane ops and scalar [`f32::mul_add`] agree bit-for-bit
//! on every input — including `mul_add`'s soft-float fallback on targets
//! without a hardware FMA unit. The SIMD path is therefore a pure speed
//! dispatch ([`fma_available`], cached `is_x86_feature_detected!`), not a
//! numerics fork: results are identical across thread counts, shard shapes,
//! and ISAs. The determinism contract in `util::pool` builds on exactly
//! this property.
//!
//! # Blocking scheme
//!
//! [`gebp_tile`] computes an `mr×nr` output tile (`mr ≤ 8`, `nr ≤ 8`) with
//! `mr` independent 8-lane FMA accumulator chains — enough in-flight chains
//! to saturate two FMA ports at 4-5 cycle latency. Both operands are packed
//! k-major into contiguous panels ([`pack_b_panels`] interleaves NR
//! columns; [`pack_a_rows`]/[`pack_a_cols`] interleave MR rows), so the
//! inner loop issues two sequential streams regardless of the source
//! matrices' strides. Packing happens once per matmul *before* the pool
//! dispatch; worker shards read the shared panels.

#[cfg(target_arch = "x86_64")]
use std::sync::OnceLock;

/// Output-tile width in columns — one AVX2 register of f32 lanes.
pub const NR: usize = 8;

/// Output-tile height in rows — 8 independent FMA accumulator chains.
pub const MR: usize = 8;

/// Below this many output rows the packed GEBP path can't amortize the
/// panel pack (the decode hot path runs 1-row matmuls where packing would
/// double the memory traffic); `spectral::matrix` uses the unpacked stream
/// kernel instead. Pure data-movement switch: both kernels realize the
/// identical broadcast-FMA fold, so results do not depend on this choice —
/// pinned by `tests/parallel_determinism.rs`'s fused-vs-per-position
/// prefill check.
pub const MIN_PACK_ROWS: usize = 4;

/// Runtime dispatch gate for the AVX2+FMA paths, detected once per process.
#[cfg(target_arch = "x86_64")]
pub fn fma_available() -> bool {
    static AVAIL: OnceLock<bool> = OnceLock::new();
    *AVAIL.get_or_init(|| {
        std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma")
    })
}

/// Runtime dispatch gate for the AVX2+FMA paths (always false off x86-64;
/// the portable fused-scalar kernels autovectorize on targets with a
/// baseline FMA unit, e.g. NEON `fmla` on aarch64).
#[cfg(not(target_arch = "x86_64"))]
pub fn fma_available() -> bool {
    false
}

/// Detected SIMD feature set, recorded by the kernel bench next to its
/// roofline numbers (`BENCH_kernels.json` / `BENCH_profile.json` `"simd"`
/// fields, surfaced by the tier1 bench stage).
#[cfg(target_arch = "x86_64")]
pub fn detected_features() -> String {
    let mut feats: Vec<&str> = Vec::new();
    if std::arch::is_x86_feature_detected!("sse4.2") {
        feats.push("sse4.2");
    }
    if std::arch::is_x86_feature_detected!("avx") {
        feats.push("avx");
    }
    if std::arch::is_x86_feature_detected!("avx2") {
        feats.push("avx2");
    }
    if std::arch::is_x86_feature_detected!("fma") {
        feats.push("fma");
    }
    if std::arch::is_x86_feature_detected!("avx512f") {
        feats.push("avx512f");
    }
    if feats.is_empty() {
        "x86_64-baseline".to_string()
    } else {
        format!("x86_64+{}", feats.join("+"))
    }
}

/// Detected SIMD feature set (non-x86: the architecture name; the portable
/// fused kernels are the only path).
#[cfg(not(target_arch = "x86_64"))]
pub fn detected_features() -> String {
    format!("{}-portable-fused", std::env::consts::ARCH)
}

/// The dispatch actually taken by the kernels in this process.
pub fn simd_kernel_label() -> &'static str {
    if fma_available() {
        "avx2+fma"
    } else {
        "scalar-fused"
    }
}

// ---------------------------------------------------------------------------
// canonical dot / axpy
// ---------------------------------------------------------------------------

/// Canonical 8-lane fused dot product (see module docs for the exact
/// recipe). Structure depends only on `a.len()`.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    #[cfg(target_arch = "x86_64")]
    if fma_available() {
        return unsafe { dot_avx(a, b) };
    }
    dot_scalar(a, b)
}

/// Portable realization of the canonical dot: lane accumulators via
/// `mul_add`, fixed reduction tree, fused sequential tail. Bit-identical to
/// [`dot_avx`] by IEEE-754 fma exactness.
fn dot_scalar(a: &[f32], b: &[f32]) -> f32 {
    let chunks = a.len() / 8;
    let mut acc = [0.0f32; 8];
    for i in 0..chunks {
        for l in 0..8 {
            acc[l] = a[i * 8 + l].mul_add(b[i * 8 + l], acc[l]);
        }
    }
    let mut s = ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
    for i in chunks * 8..a.len() {
        s = a[i].mul_add(b[i], s);
    }
    s
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn dot_avx(a: &[f32], b: &[f32]) -> f32 {
    use std::arch::x86_64::*;
    let n = a.len();
    let chunks = n / 8;
    let (ap, bp) = (a.as_ptr(), b.as_ptr());
    let mut accv = _mm256_setzero_ps();
    for i in 0..chunks {
        let av = _mm256_loadu_ps(ap.add(i * 8));
        let bv = _mm256_loadu_ps(bp.add(i * 8));
        accv = _mm256_fmadd_ps(av, bv, accv);
    }
    let mut acc = [0.0f32; 8];
    _mm256_storeu_ps(acc.as_mut_ptr(), accv);
    let mut s = ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
    for i in chunks * 8..n {
        s = (*ap.add(i)).mul_add(*bp.add(i), s);
    }
    s
}

/// Canonical fused `y += alpha * x`: each element is one fma, so lane
/// grouping is irrelevant and the SIMD/scalar paths agree trivially.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    #[cfg(target_arch = "x86_64")]
    if fma_available() {
        unsafe { axpy_avx(alpha, x, y) };
        return;
    }
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi = alpha.mul_add(xi, *yi);
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn axpy_avx(alpha: f32, x: &[f32], y: &mut [f32]) {
    use std::arch::x86_64::*;
    let n = x.len();
    let chunks = n / 8;
    let (xp, yp) = (x.as_ptr(), y.as_mut_ptr());
    let av = _mm256_set1_ps(alpha);
    for i in 0..chunks {
        let yv = _mm256_loadu_ps(yp.add(i * 8));
        let xv = _mm256_loadu_ps(xp.add(i * 8));
        _mm256_storeu_ps(yp.add(i * 8), _mm256_fmadd_ps(av, xv, yv));
    }
    for i in chunks * 8..n {
        *yp.add(i) = alpha.mul_add(*xp.add(i), *yp.add(i));
    }
}

/// Eight canonical dots sharing one left operand: `out[jj] = dot(a, row
/// j0+jj of the row-major `(rows × bstride)` buffer `bdata`, truncated to
/// `a.len()`)`. The `matmul_t` inner kernel — eight independent FMA chains
/// vectorized along k, each bit-identical to a standalone [`dot`].
pub fn dot8_rows(a: &[f32], bdata: &[f32], bstride: usize, j0: usize, out: &mut [f32]) {
    let k_eff = a.len();
    debug_assert!(bstride >= k_eff && out.len() >= NR);
    debug_assert!((j0 + NR) * bstride <= bdata.len() || bstride == 0);
    #[cfg(target_arch = "x86_64")]
    if fma_available() {
        unsafe { dot8_rows_avx(a, bdata.as_ptr().add(j0 * bstride), bstride, out.as_mut_ptr()) };
        return;
    }
    for jj in 0..NR {
        let base = (j0 + jj) * bstride;
        out[jj] = dot_scalar(a, &bdata[base..base + k_eff]);
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn dot8_rows_avx(a: &[f32], b: *const f32, bstride: usize, out: *mut f32) {
    use std::arch::x86_64::*;
    let k = a.len();
    let chunks = k / 8;
    let ap = a.as_ptr();
    let mut acc = [_mm256_setzero_ps(); NR];
    for i in 0..chunks {
        let av = _mm256_loadu_ps(ap.add(i * 8));
        for (jj, accjj) in acc.iter_mut().enumerate() {
            let bv = _mm256_loadu_ps(b.add(jj * bstride + i * 8));
            *accjj = _mm256_fmadd_ps(av, bv, *accjj);
        }
    }
    for (jj, accjj) in acc.iter().enumerate() {
        let mut lanes = [0.0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), *accjj);
        let mut s = ((lanes[0] + lanes[1]) + (lanes[2] + lanes[3]))
            + ((lanes[4] + lanes[5]) + (lanes[6] + lanes[7]));
        let brow = b.add(jj * bstride);
        for i in chunks * 8..k {
            s = (*ap.add(i)).mul_add(*brow.add(i), s);
        }
        *out.add(jj) = s;
    }
}

// ---------------------------------------------------------------------------
// panel packing
// ---------------------------------------------------------------------------

/// Pack a row-major `(kdim × n)` B operand into k-major [`NR`]-column
/// panels: panel `p` holds columns `p*NR..`, laid out
/// `buf[p*kdim*NR + k*NR + jj] = b[k][p*NR + jj]`, with the ragged last
/// panel zero-padded (the padded lanes feed `fma(·, 0, acc)` no-ops whose
/// results are never stored). One sequential read pass over `b`.
pub fn pack_b_panels(b: &[f32], kdim: usize, n: usize) -> Vec<f32> {
    let n_panels = n.div_ceil(NR);
    let mut buf = vec![0.0f32; n_panels * kdim * NR];
    for k in 0..kdim {
        let row = &b[k * n..(k + 1) * n];
        for (p, chunk) in row.chunks(NR).enumerate() {
            let dst = p * kdim * NR + k * NR;
            buf[dst..dst + chunk.len()].copy_from_slice(chunk);
        }
    }
    buf
}

/// Pack `mr` consecutive rows `r0..r0+mr` of a row-major `(rows × cols)`
/// buffer into a k-major interleaved A panel: `buf[k*mr + ii] =
/// a[r0+ii][k]` — the matmul-side left-operand pack (reused across every
/// column panel of the same row tile).
pub fn pack_a_rows(a: &[f32], cols: usize, r0: usize, mr: usize, buf: &mut Vec<f32>) {
    buf.clear();
    buf.resize(cols * mr, 0.0);
    for ii in 0..mr {
        let row = &a[(r0 + ii) * cols..(r0 + ii + 1) * cols];
        for (k, &v) in row.iter().enumerate() {
            buf[k * mr + ii] = v;
        }
    }
}

/// Pack `mr` consecutive *columns* `i0..i0+mr` of a row-major
/// `(rows × stride)` buffer into an r-major interleaved A panel:
/// `buf[r*mr + ii] = a[r][i0+ii]` — the `t_matmul`-side pack (contiguous
/// `mr`-wide slivers per row, so the strided column walk happens once).
pub fn pack_a_cols(a: &[f32], stride: usize, rows: usize, i0: usize, mr: usize, buf: &mut Vec<f32>) {
    buf.clear();
    buf.resize(rows * mr, 0.0);
    for r in 0..rows {
        let src = &a[r * stride + i0..r * stride + i0 + mr];
        buf[r * mr..(r + 1) * mr].copy_from_slice(src);
    }
}

// ---------------------------------------------------------------------------
// GEBP register tile
// ---------------------------------------------------------------------------

/// Compute an `mr×nr` output tile (`1 ≤ mr ≤ MR`, `1 ≤ nr ≤ NR`) from
/// packed panels: `out[ii*row_stride + jj] = fold over k of
/// fma(apanel[k*mr + ii], bpanel[k*NR + jj], acc)`. `out` is the tile
/// origin (a sub-slice of the output block); rows are `row_stride` apart.
/// The per-element fold is the broadcast-FMA canonical order — identical
/// across the AVX2 and scalar realizations and across every `mr`/`nr`
/// split, which is what lets `par_rows` shards tile independently.
pub fn gebp_tile(
    apanel: &[f32],
    mr: usize,
    bpanel: &[f32],
    kdim: usize,
    nr: usize,
    out: &mut [f32],
    row_stride: usize,
) {
    debug_assert!((1..=MR).contains(&mr) && (1..=NR).contains(&nr));
    debug_assert!(apanel.len() >= kdim * mr && bpanel.len() >= kdim * NR);
    debug_assert!(out.len() >= (mr - 1) * row_stride + nr);
    #[cfg(target_arch = "x86_64")]
    if fma_available() {
        unsafe {
            let (a, b, o) = (apanel.as_ptr(), bpanel.as_ptr(), out.as_mut_ptr());
            match mr {
                8 => gebp_avx_8(a, b, kdim, nr, o, row_stride),
                7 => gebp_avx_7(a, b, kdim, nr, o, row_stride),
                6 => gebp_avx_6(a, b, kdim, nr, o, row_stride),
                5 => gebp_avx_5(a, b, kdim, nr, o, row_stride),
                4 => gebp_avx_4(a, b, kdim, nr, o, row_stride),
                3 => gebp_avx_3(a, b, kdim, nr, o, row_stride),
                2 => gebp_avx_2(a, b, kdim, nr, o, row_stride),
                _ => gebp_avx_1(a, b, kdim, nr, o, row_stride),
            }
        }
        return;
    }
    gebp_scalar(apanel, mr, bpanel, kdim, nr, out, row_stride);
}

/// Portable GEBP tile: same fold, `mul_add` lane ops (autovectorizes on
/// targets with baseline FMA; exact soft-float fma elsewhere).
fn gebp_scalar(
    apanel: &[f32],
    mr: usize,
    bpanel: &[f32],
    kdim: usize,
    nr: usize,
    out: &mut [f32],
    row_stride: usize,
) {
    let mut acc = [[0.0f32; NR]; MR];
    for k in 0..kdim {
        let bk = &bpanel[k * NR..k * NR + NR];
        let ak = &apanel[k * mr..k * mr + mr];
        for (ii, &a) in ak.iter().enumerate() {
            let row = &mut acc[ii];
            for (rj, &bj) in row.iter_mut().zip(bk) {
                *rj = a.mul_add(bj, *rj);
            }
        }
    }
    for (ii, row) in acc.iter().take(mr).enumerate() {
        out[ii * row_stride..ii * row_stride + nr].copy_from_slice(&row[..nr]);
    }
}

/// Monomorphic AVX2+FMA tile kernels, one per row count so the accumulator
/// array lives entirely in ymm registers (a runtime-`mr` loop would spill).
/// Generated by macro rather than const generics: `#[target_feature]` on
/// non-generic fns is the conservative, every-toolchain-supported shape.
#[cfg(target_arch = "x86_64")]
macro_rules! gen_gebp_avx {
    ($name:ident, $mr:expr) => {
        #[target_feature(enable = "avx2,fma")]
        unsafe fn $name(
            ap: *const f32,
            bp: *const f32,
            kdim: usize,
            nr: usize,
            out: *mut f32,
            row_stride: usize,
        ) {
            use std::arch::x86_64::*;
            let mut acc = [_mm256_setzero_ps(); $mr];
            for k in 0..kdim {
                let bv = _mm256_loadu_ps(bp.add(k * NR));
                let abase = ap.add(k * $mr);
                for (ii, accii) in acc.iter_mut().enumerate() {
                    let av = _mm256_set1_ps(*abase.add(ii));
                    *accii = _mm256_fmadd_ps(av, bv, *accii);
                }
            }
            for (ii, accii) in acc.iter().enumerate() {
                let mut lanes = [0.0f32; NR];
                _mm256_storeu_ps(lanes.as_mut_ptr(), *accii);
                let orow = out.add(ii * row_stride);
                for (jj, &l) in lanes.iter().take(nr).enumerate() {
                    *orow.add(jj) = l;
                }
            }
        }
    };
}

#[cfg(target_arch = "x86_64")]
mod avx_tiles {
    use super::NR;
    gen_gebp_avx!(gebp_avx_1_impl, 1);
    gen_gebp_avx!(gebp_avx_2_impl, 2);
    gen_gebp_avx!(gebp_avx_3_impl, 3);
    gen_gebp_avx!(gebp_avx_4_impl, 4);
    gen_gebp_avx!(gebp_avx_5_impl, 5);
    gen_gebp_avx!(gebp_avx_6_impl, 6);
    gen_gebp_avx!(gebp_avx_7_impl, 7);
    gen_gebp_avx!(gebp_avx_8_impl, 8);
    pub(super) use gebp_avx_1_impl as gebp_avx_1;
    pub(super) use gebp_avx_2_impl as gebp_avx_2;
    pub(super) use gebp_avx_3_impl as gebp_avx_3;
    pub(super) use gebp_avx_4_impl as gebp_avx_4;
    pub(super) use gebp_avx_5_impl as gebp_avx_5;
    pub(super) use gebp_avx_6_impl as gebp_avx_6;
    pub(super) use gebp_avx_7_impl as gebp_avx_7;
    pub(super) use gebp_avx_8_impl as gebp_avx_8;
}

#[cfg(target_arch = "x86_64")]
use avx_tiles::{
    gebp_avx_1, gebp_avx_2, gebp_avx_3, gebp_avx_4, gebp_avx_5, gebp_avx_6, gebp_avx_7, gebp_avx_8,
};

#[cfg(test)]
mod tests {
    use super::*;

    fn seq(n: usize, scale: f32, shift: f32) -> Vec<f32> {
        (0..n).map(|i| ((i as f32 * 0.37 + shift).sin()) * scale).collect()
    }

    /// The AVX2 dispatch must reproduce the portable fused-scalar recipe
    /// bit-for-bit (on machines without AVX2+FMA both sides run the same
    /// code and the test is vacuous but still green).
    #[test]
    fn dot_dispatch_matches_scalar_reference_bitwise() {
        for len in [0usize, 1, 3, 7, 8, 9, 15, 16, 31, 32, 37, 64, 127, 200] {
            let a = seq(len, 1.3, 0.1);
            let b = seq(len, 0.7, 2.9);
            assert_eq!(
                dot(&a, &b).to_bits(),
                dot_scalar(&a, &b).to_bits(),
                "dot diverged from canonical recipe at len {len}"
            );
        }
    }

    #[test]
    fn axpy_dispatch_matches_fused_scalar_bitwise() {
        for len in [0usize, 1, 7, 8, 13, 32, 50] {
            let x = seq(len, 1.1, 0.4);
            let mut y = seq(len, 0.9, 1.7);
            let mut y_ref = y.clone();
            axpy(0.731, &x, &mut y);
            for (yr, &xi) in y_ref.iter_mut().zip(&x) {
                *yr = 0.731f32.mul_add(xi, *yr);
            }
            assert_eq!(
                y.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                y_ref.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "axpy diverged at len {len}"
            );
        }
    }

    #[test]
    fn dot8_rows_matches_eight_single_dots_bitwise() {
        for k_eff in [1usize, 5, 8, 19, 64, 100] {
            let stride = k_eff + 3; // rows longer than the dotted prefix
            let rows = 11;
            let b = seq(rows * stride, 1.0, 0.2);
            let a = seq(k_eff, 1.0, 3.3);
            let mut out = [0.0f32; NR];
            dot8_rows(&a, &b, stride, 2, &mut out);
            for (jj, &o) in out.iter().enumerate() {
                let base = (2 + jj) * stride;
                let single = dot(&a, &b[base..base + k_eff]);
                assert_eq!(o.to_bits(), single.to_bits(), "row {jj} k_eff {k_eff}");
            }
        }
    }

    #[test]
    fn gebp_tile_matches_scalar_reference_bitwise() {
        for &(mr, nr, kdim) in
            &[(1usize, 1usize, 1usize), (3, 5, 7), (8, 8, 16), (8, 3, 31), (5, 8, 40), (8, 8, 1)]
        {
            let ap = seq(kdim * mr, 1.0, 0.5);
            let bp = seq(kdim * NR, 1.0, 1.5);
            let stride = nr + 2;
            let mut out = vec![0.0f32; mr * stride];
            let mut out_ref = out.clone();
            gebp_tile(&ap, mr, &bp, kdim, nr, &mut out, stride);
            gebp_scalar(&ap, mr, &bp, kdim, nr, &mut out_ref, stride);
            assert_eq!(
                out.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                out_ref.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "gebp {mr}x{nr} k={kdim} diverged"
            );
        }
    }

    #[test]
    fn gebp_tile_equals_broadcast_fma_fold() {
        // The documented per-element recipe, written out naively.
        let (mr, nr, kdim) = (6usize, 7usize, 23usize);
        let ap = seq(kdim * mr, 0.8, 0.3);
        let bp = seq(kdim * NR, 1.2, 2.2);
        let mut out = vec![0.0f32; mr * NR];
        gebp_tile(&ap, mr, &bp, kdim, nr, &mut out, NR);
        for ii in 0..mr {
            for jj in 0..nr {
                let mut acc = 0.0f32;
                for k in 0..kdim {
                    acc = ap[k * mr + ii].mul_add(bp[k * NR + jj], acc);
                }
                assert_eq!(out[ii * NR + jj].to_bits(), acc.to_bits(), "({ii},{jj})");
            }
        }
    }

    #[test]
    fn pack_roundtrips() {
        let (kdim, n) = (5usize, 11usize);
        let b = seq(kdim * n, 1.0, 0.0);
        let panels = pack_b_panels(&b, kdim, n);
        assert_eq!(panels.len(), n.div_ceil(NR) * kdim * NR);
        for k in 0..kdim {
            for j in 0..n {
                let (p, jj) = (j / NR, j % NR);
                assert_eq!(panels[p * kdim * NR + k * NR + jj], b[k * n + j]);
            }
        }
        // ragged lanes zero-padded
        for k in 0..kdim {
            for jj in n % NR..NR {
                assert_eq!(panels[(n / NR) * kdim * NR + k * NR + jj], 0.0);
            }
        }

        let a = seq(6 * 9, 1.0, 1.0); // 6 rows x 9 cols
        let mut buf = Vec::new();
        pack_a_rows(&a, 9, 2, 3, &mut buf);
        for ii in 0..3 {
            for k in 0..9 {
                assert_eq!(buf[k * 3 + ii], a[(2 + ii) * 9 + k]);
            }
        }
        pack_a_cols(&a, 9, 6, 4, 2, &mut buf);
        for r in 0..6 {
            for ii in 0..2 {
                assert_eq!(buf[r * 2 + ii], a[r * 9 + 4 + ii]);
            }
        }
    }

    #[test]
    fn feature_labels_are_nonempty() {
        assert!(!detected_features().is_empty());
        assert!(!simd_kernel_label().is_empty());
    }
}
