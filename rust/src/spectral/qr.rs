//! Stiefel QR retraction (paper Eq. 5) — native Rust implementation.
//!
//! Used for (a) the true-shape 70B retraction benchmark (Table 2's phase
//! timing, where the factor shapes 8192x32 / 28672x32 are run for real on
//! this machine), (b) the dense->spectral conversion in the fine-tune
//! driver, and (c) property tests that cross-check the Pallas/JAX kernels'
//! algorithm (same CGS2 construction).
//!
//! CGS2 (classical Gram-Schmidt, applied twice) matches the exported-graph
//! and Pallas kernels exactly in structure: R has a positive diagonal by
//! construction, so `Q * sign(diag(R))` is the identity fix — the retraction
//! is the unique positive-diagonal QR of the input.

use super::matrix::{axpy, dot, Matrix};
use crate::obs::prof;
use crate::util::pool;

/// Accuracy-preserving fast dot: plain f32 accumulation over m ~ 3e4 rows
/// injects ~1e-5 of error (above the paper's 2e-6 orthonormality budget),
/// while a straight f64 accumulation blocks SIMD vectorization (measured
/// ~2 GFLOP/s — EXPERIMENTS.md §Perf). Blocked summation gets both: f32
/// 8-lane dots within 128-element chunks (vectorizable), f64 across chunks
/// (error grows with #chunks, not m — ~244x fewer terms at 70B shapes).
#[inline]
fn dot64(a: &[f32], b: &[f32]) -> f64 {
    const CHUNK: usize = 128;
    let mut total = 0.0f64;
    let mut i = 0;
    while i + CHUNK <= a.len() {
        let mut acc = [0.0f32; 8];
        for j in (i..i + CHUNK).step_by(8) {
            for l in 0..8 {
                acc[l] += a[j + l] * b[j + l];
            }
        }
        total += acc.iter().sum::<f32>() as f64;
        i += CHUNK;
    }
    // ragged tail in f64 (short, cost-free)
    for j in i..a.len() {
        total += a[j] as f64 * b[j] as f64;
    }
    total
}

/// Retract `a` (m x k, m >= k) onto the Stiefel manifold.
/// Returns Q with orthonormal columns spanning col(a).
///
/// §Perf outcome (EXPERIMENTS.md): after the blocked-summation `dot64`
/// (f32 SIMD within 128-element chunks, f64 across chunks) the serial CGS2
/// beats the row-sharded parallel variant at every paper shape — the column
/// dependency chain plus per-panel thread fan-out costs more than it saves.
/// Serial is therefore the default; the parallel and polar variants remain
/// for the `retraction_ablation` bench. Factor-level parallelism (U ∥ V,
/// see `SpectralLinear::retract`) is where threads actually pay off.
pub fn qr_retract(a: &Matrix) -> Matrix {
    // CGS2 work model: two projection passes, each ~k^2/2 dots + axpys of
    // length m (2 FLOPs per MAC each) => ~4*m*k^2 FLOPs; the panel is
    // re-read once per prior column per pass => ~4*m*k^2 bytes streamed.
    let (m, k) = (a.rows as f64, a.cols as f64);
    let _prof = prof::kernel("qr_retract", || (4.0 * m * k * k, 4.0 * m * k * k));
    qr_retract_serial(a)
}

/// Serial CGS2 — the reference implementation (and the faster one for small
/// factors, where thread fan-out costs more than it saves).
pub fn qr_retract_serial(a: &Matrix) -> Matrix {
    let (m, k) = (a.rows, a.cols);
    assert!(m >= k, "retraction needs m >= k, got {m} x {k}");
    // Column-major scratch: columns are the unit of work here. One working
    // buffer refilled per column (col_into), not one allocation per column.
    let mut q_cols: Vec<Vec<f32>> = Vec::with_capacity(k);
    let mut v: Vec<f32> = Vec::with_capacity(m);
    for j in 0..k {
        a.col_into(j, &mut v);
        // Two projection passes ("twice is enough"), f64 coefficients.
        for _pass in 0..2 {
            for q in &q_cols {
                let c = dot64(q, &v) as f32;
                // fused v -= c*q through the SIMD microkernel axpy
                axpy(-c, q, &mut v);
            }
        }
        let norm = dot64(&v, &v).sqrt();
        let inv = if norm > 1e-30 { (1.0 / norm) as f32 } else { 0.0 };
        q_cols.push(v.iter().map(|x| x * inv).collect());
    }
    let mut q = Matrix::zeros(m, k);
    for (j, qc) in q_cols.iter().enumerate() {
        for (r, &val) in qc.iter().enumerate() {
            q[(r, j)] = val;
        }
    }
    q
}

/// Blocked-parallel CGS2 (§Perf optimization of the paper's named
/// bottleneck — retraction is 40-50% of its 70B step time).
///
/// Two structural changes over the serial version:
/// * **panel blocking**: columns are orthogonalized against the finished
///   prefix in panels of `PANEL`, so the projection against earlier columns
///   becomes two (k_done x PANEL)-shaped GEMM-like passes instead of
///   column-at-a-time sweeps — far better cache reuse on the m-major data;
/// * **row-sharded threads**: each projection pass partitions the m rows
///   across `std::thread::scope` workers (partial dot products reduced in
///   f64, then the update applied shard-local) — the factor matrices at 70B
///   shapes (28672 x 32) are ~3.7 MB, well worth the fan-out.
pub fn qr_retract_parallel(a: &Matrix) -> Matrix {
    const PANEL: usize = 8;
    let (m, k) = (a.rows, a.cols);
    assert!(m >= k, "retraction needs m >= k, got {m} x {k}");
    let threads = pool::threads().min(16);
    // Column-major working set (col_into: one fill per column, capacity
    // reserved up front).
    let mut cols: Vec<Vec<f32>> = Vec::with_capacity(k);
    for j in 0..k {
        let mut c = Vec::with_capacity(m);
        a.col_into(j, &mut c);
        cols.push(c);
    }

    let mut done = 0usize;
    while done < k {
        let panel_end = (done + PANEL).min(k);
        // 1) project the panel against all finished columns, twice (CGS2).
        if done > 0 {
            for _pass in 0..2 {
                let (fin, panel) = cols.split_at_mut(done);
                let fin: &[Vec<f32>] = fin;
                let panel_cols = &mut panel[..panel_end - done];
                // coefficients c[j][p] = fin[j] . panel[p], f64-accumulated,
                // rows sharded across threads then reduced.
                let chunk = m.div_ceil(threads);
                let mut coeffs = vec![vec![0.0f64; panel_cols.len()]; done];
                // pass A: coefficients c[j][p] = fin[j] . panel[p]
                std::thread::scope(|s| {
                    let mut handles = Vec::new();
                    for t in 0..threads {
                        let lo = t * chunk;
                        let hi = ((t + 1) * chunk).min(m);
                        if lo >= hi {
                            break;
                        }
                        let fin_ref = fin;
                        let panel_ref: Vec<&[f32]> =
                            panel_cols.iter().map(|c| &c[lo..hi]).collect();
                        handles.push(s.spawn(move || {
                            let mut part = vec![vec![0.0f64; panel_ref.len()]; fin_ref.len()];
                            for (j, fcol) in fin_ref.iter().enumerate() {
                                let fseg = &fcol[lo..hi];
                                for (p, pseg) in panel_ref.iter().enumerate() {
                                    part[j][p] = dot64(fseg, pseg);
                                }
                            }
                            part
                        }));
                    }
                    for h in handles {
                        let part = h.join().unwrap();
                        for j in 0..done {
                            for p in 0..part[j].len() {
                                coeffs[j][p] += part[j][p];
                            }
                        }
                    }
                });
                // pass B: panel[p] -= sum_j c[j][p] * fin[j], row-sharded.
                // Threads own disjoint row ranges of each panel column
                // (raw-pointer shim because the ranges are provably disjoint).
                let panel_ptrs: Vec<SendPtr> =
                    panel_cols.iter_mut().map(|c| SendPtr(c.as_mut_ptr())).collect();
                std::thread::scope(|s| {
                    let coeffs_ref = &coeffs;
                    let mut handles = Vec::new();
                    let panel_ptrs = &panel_ptrs;
                    for t in 0..threads {
                        let lo = t * chunk;
                        let hi = ((t + 1) * chunk).min(m);
                        if lo >= hi {
                            break;
                        }
                        let fin_ref = fin;
                        handles.push(s.spawn(move || {
                            for (p, ptr) in panel_ptrs.iter().enumerate() {
                                let seg = unsafe {
                                    std::slice::from_raw_parts_mut(ptr.0.add(lo), hi - lo)
                                };
                                for (j, fcol) in fin_ref.iter().enumerate() {
                                    let c = coeffs_ref[j][p] as f32;
                                    if c != 0.0 {
                                        axpy(-c, &fcol[lo..hi], seg);
                                    }
                                }
                            }
                        }));
                    }
                    for h in handles {
                        h.join().unwrap();
                    }
                });
            }
        }
        // 2) serial CGS2 within the small panel.
        for j in done..panel_end {
            for _pass in 0..2 {
                for prev in done..j {
                    let (a_, b_) = cols.split_at_mut(j);
                    let c = dot64(&a_[prev], &b_[0]) as f32;
                    axpy(-c, &a_[prev], &mut b_[0]);
                }
            }
            let norm = dot64(&cols[j], &cols[j]).sqrt();
            let inv = if norm > 1e-30 { (1.0 / norm) as f32 } else { 0.0 };
            for v in cols[j].iter_mut() {
                *v *= inv;
            }
        }
        done = panel_end;
    }

    let mut q = Matrix::zeros(m, k);
    for (j, qc) in cols.iter().enumerate() {
        for (r, &val) in qc.iter().enumerate() {
            q[(r, j)] = val;
        }
    }
    q
}

/// Raw-pointer Send shim for disjoint row-range writes (each thread touches
/// a distinct `lo..hi` slice of each column).
struct SendPtr(*mut f32);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// Polar retraction via Newton-Schulz iteration — the lower-cost
/// alternative the paper's §5 asks for (it names Cayley; polar has the same
/// matmul-only structure and maps perfectly to an MXU).
///
/// After an AdamW step with a sane LR, U is a small perturbation of an
/// orthonormal matrix, so NS converges quadratically: 2-4 iterations of
/// `Q <- 1.5 Q - 0.5 Q (Q^T Q)` reach < 2e-6. This is a *different*
/// retraction (to the polar factor, not the positive-diagonal QR Q), but
/// equally valid Stiefel-manifold-wise; the ablation bench compares cost
/// and the orthonormality it achieves.
pub fn polar_retract(a: &Matrix, iters: usize) -> Matrix {
    // Scale so sigma_max <= 1 (NS requires sigma in (0, sqrt(3))): a tight,
    // cheap bound is sigma_max^2 <= ||A^T A||_inf. Near the manifold the
    // Gram matrix is ~I, so the scale is ~1 and convergence is quadratic.
    let g0 = a.t_matmul(a);
    let mut bound: f32 = 0.0;
    for i in 0..g0.rows {
        let row_sum: f32 = g0.row(i).iter().map(|x| x.abs()).sum();
        bound = bound.max(row_sum);
    }
    let scale = bound.sqrt().max(1e-30);
    let mut q = a.clone();
    for v in q.data.iter_mut() {
        *v /= scale;
    }
    for _ in 0..iters {
        // Gram in f64: an f32 accumulation over m ~ 3e4 rows floors the
        // achievable orthonormality at ~2e-6 — exactly the threshold being
        // targeted. (Found empirically; see EXPERIMENTS.md §Perf.)
        let g = gram64(&q); // k x k
        // q <- 1.5 q - 0.5 q g
        let qg = q.matmul(&g);
        for (qi, qgi) in q.data.iter_mut().zip(&qg.data) {
            *qi = 1.5 * *qi - 0.5 * qgi;
        }
    }
    q
}

/// Q^T Q with f64 accumulation, result in f32.
fn gram64(q: &Matrix) -> Matrix {
    let k = q.cols;
    let mut g = Matrix::zeros(k, k);
    for i in 0..k {
        for j in i..k {
            let mut acc = 0.0f64;
            for r in 0..q.rows {
                acc += q[(r, i)] as f64 * q[(r, j)] as f64;
            }
            g[(i, j)] = acc as f32;
            g[(j, i)] = acc as f32;
        }
    }
    g
}

/// Householder QR returning (Q, R) with the paper's sign fix applied
/// (diag(R) > 0). Slower than [`qr_retract`] but exposes R — used by the SVD
/// and by tests as an independent oracle for the CGS2 path.
pub fn qr_householder(a: &Matrix) -> (Matrix, Matrix) {
    let (m, k) = (a.rows, a.cols);
    assert!(m >= k);
    let mut r = a.clone();
    // Accumulate Q by applying the reflectors to an m x k identity block.
    let mut q = Matrix::zeros(m, k);
    for i in 0..k {
        q[(i, i)] = 1.0;
    }
    let mut vs: Vec<Vec<f32>> = Vec::with_capacity(k);
    for j in 0..k {
        // Build the Householder vector for column j.
        let mut v = vec![0.0f32; m - j];
        for i in j..m {
            v[i - j] = r[(i, j)];
        }
        let alpha = -v[0].signum() * dot(&v, &v).sqrt();
        v[0] -= alpha;
        let vnorm2 = dot(&v, &v);
        if vnorm2 > 1e-30 {
            // Apply (I - 2 v v^T / v^T v) to the trailing columns of R.
            for c in j..k {
                let mut s = 0.0;
                for i in j..m {
                    s += v[i - j] * r[(i, c)];
                }
                let f = 2.0 * s / vnorm2;
                for i in j..m {
                    r[(i, c)] -= f * v[i - j];
                }
            }
        }
        vs.push(v);
    }
    // Q = H_0 H_1 ... H_{k-1} I  (apply in reverse to the identity block).
    for j in (0..k).rev() {
        let v = &vs[j];
        let vnorm2 = dot(v, v);
        if vnorm2 <= 1e-30 {
            continue;
        }
        for c in 0..k {
            let mut s = 0.0;
            for i in j..m {
                s += v[i - j] * q[(i, c)];
            }
            let f = 2.0 * s / vnorm2;
            for i in j..m {
                q[(i, c)] -= f * v[i - j];
            }
        }
    }
    // Sign fix: make diag(R) positive (paper Eq. 5's sign(diag(R))).
    let mut r_out = Matrix::zeros(k, k);
    for i in 0..k {
        for c in 0..k {
            r_out[(i, c)] = r[(i, c)];
        }
    }
    for j in 0..k {
        if r_out[(j, j)] < 0.0 {
            for c in 0..k {
                r_out[(j, c)] = -r_out[(j, c)];
            }
            q.scale_col(j, -1.0);
        }
    }
    (q, r_out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn parallel_matches_serial() {
        let mut rng = Rng::new(10);
        for &(m, k) in &[(64usize, 16usize), (300, 24), (1000, 33), (2048, 8)] {
            let a = Matrix::randn(&mut rng, m, k, 1.0);
            let qs = qr_retract_serial(&a);
            let qp = qr_retract_parallel(&a);
            let diff = qs.max_abs_diff(&qp);
            assert!(diff < 1e-4, "{m}x{k}: serial vs parallel diff {diff}");
            assert!(qp.ortho_error() < 2e-6, "{m}x{k}: parallel ortho");
        }
    }

    #[test]
    fn parallel_handles_degenerate_shapes() {
        let mut rng = Rng::new(11);
        for &(m, k) in &[(1usize, 1usize), (5, 5), (7, 1)] {
            let a = Matrix::randn(&mut rng, m, k, 1.0);
            let q = qr_retract_parallel(&a);
            assert!(q.ortho_error() < 2e-6);
        }
    }

    #[test]
    fn polar_retract_near_manifold_is_accurate() {
        let mut rng = Rng::new(12);
        // Perturb an orthonormal matrix like one AdamW step would.
        let q0 = qr_retract(&Matrix::randn(&mut rng, 512, 32, 1.0));
        let mut a = q0.clone();
        for v in a.data.iter_mut() {
            *v += 1e-3 * rng.normal() as f32;
        }
        let q = polar_retract(&a, 3);
        assert!(q.ortho_error() < 2e-6, "NS ortho {}", q.ortho_error());
        // stays close to the input (it's a retraction, not a projection to
        // something far away)
        assert!(q.max_abs_diff(&a) < 0.01);
    }

    #[test]
    fn polar_retract_far_from_manifold_still_converges() {
        let mut rng = Rng::new(13);
        let a = Matrix::randn(&mut rng, 64, 8, 3.0);
        let q = polar_retract(&a, 30);
        assert!(q.ortho_error() < 1e-4, "NS from cold start: {}", q.ortho_error());
    }

    #[test]
    fn cgs2_orthonormal_and_span_preserving() {
        let mut rng = Rng::new(0);
        for &(m, k) in &[(8, 3), (64, 16), (100, 1), (33, 33)] {
            let a = Matrix::randn(&mut rng, m, k, 1.0);
            let q = qr_retract(&a);
            assert!(q.ortho_error() < 2e-6, "ortho {} for {m}x{k}", q.ortho_error());
            // A = Q (Q^T A) exactly when span is preserved.
            let recon = q.matmul(&q.t_matmul(&a));
            assert!(recon.max_abs_diff(&a) < 1e-4 * (m as f32).sqrt());
        }
    }

    #[test]
    fn cgs2_matches_householder_oracle() {
        let mut rng = Rng::new(1);
        for &(m, k) in &[(16, 4), (48, 12)] {
            let a = Matrix::randn(&mut rng, m, k, 1.0);
            let q1 = qr_retract(&a);
            let (q2, _r) = qr_householder(&a);
            assert!(
                q1.max_abs_diff(&q2) < 1e-4,
                "CGS2 vs Householder diff {}",
                q1.max_abs_diff(&q2)
            );
        }
    }

    #[test]
    fn householder_reconstructs_a() {
        let mut rng = Rng::new(2);
        let a = Matrix::randn(&mut rng, 20, 6, 1.0);
        let (q, r) = qr_householder(&a);
        let recon = q.matmul(&r);
        assert!(recon.max_abs_diff(&a) < 1e-4);
        // R upper-triangular with positive diagonal
        for i in 0..r.rows {
            assert!(r[(i, i)] > 0.0);
            for j in 0..i {
                assert!(r[(i, j)].abs() < 1e-5);
            }
        }
    }

    #[test]
    fn retraction_is_identity_on_orthonormal() {
        let mut rng = Rng::new(3);
        let q0 = qr_retract(&Matrix::randn(&mut rng, 32, 8, 1.0));
        let q1 = qr_retract(&q0);
        assert!(q1.max_abs_diff(&q0) < 1e-5);
    }

    #[test]
    fn retraction_scale_invariant_up_to_column_scale() {
        // Q(A D) == Q(A) for positive diagonal D — retraction kills scale.
        let mut rng = Rng::new(4);
        let a = Matrix::randn(&mut rng, 24, 5, 1.0);
        let mut scaled = a.clone();
        for j in 0..5 {
            scaled.scale_col(j, (j + 1) as f32 * 0.7);
        }
        let q1 = qr_retract(&a);
        let q2 = qr_retract(&scaled);
        assert!(q1.max_abs_diff(&q2) < 1e-4);
    }
}
