//! Native SpectralLinear layer: forward + manual backward through the
//! compact factors, plus a full SCT "training phase" driver at true shapes.
//!
//! This is the rust-side twin of the L1/L2 math. Its jobs:
//! * property-test the factored gradients against finite differences —
//!   independent confirmation that "gradients flow through the compact
//!   spectral factors via standard backprop" (paper §3) with no (m, n)
//!   tensor anywhere;
//! * run the paper's Table 2 phase benchmark (forward / backward /
//!   optimizer / retraction) at the REAL 70B factor shapes (8192x28672 @
//!   k=32), which fits trivially in RAM precisely because of SCT;
//! * provide the dense baseline for the same phases at small shapes.

use super::adamw::AdamW;
use super::matrix::Matrix;
use super::qr::qr_retract;
use crate::util::rng::Rng;

/// Spectral parameter triple: W = U diag(s) V^T, never materialized.
#[derive(Debug, Clone)]
pub struct SpectralLinear {
    pub u: Matrix,     // m x k
    pub s: Vec<f32>,   // k
    pub v: Matrix,     // n x k
}

/// Gradients w.r.t. the triple — shapes (m,k), (k), (n,k): the whole point
/// is that no (m,n) gradient exists.
#[derive(Debug, Clone)]
pub struct SpectralGrads {
    pub du: Matrix,
    pub ds: Vec<f32>,
    pub dv: Matrix,
}

/// Cached activations from forward needed by backward.
pub struct SpectralCache {
    h: Matrix,  // x U        (b x k)
    hs: Matrix, // h * s      (b x k)
}

impl SpectralLinear {
    /// Variance-matched init (mirrors python `spectral.init_spectral`):
    /// Haar-orthonormal U, V; s_i = sqrt(2/(m+n)) * sqrt(mn/k).
    pub fn init(rng: &mut Rng, m: usize, n: usize, k: usize) -> SpectralLinear {
        let u = qr_retract(&Matrix::randn(rng, m, k, 1.0));
        let v = qr_retract(&Matrix::randn(rng, n, k, 1.0));
        let sigma = (2.0 / (m + n) as f32).sqrt();
        let s0 = sigma * ((m * n) as f32 / k as f32).sqrt();
        SpectralLinear { u, s: vec![s0; k], v }
    }

    pub fn m(&self) -> usize {
        self.u.rows
    }

    pub fn n(&self) -> usize {
        self.v.rows
    }

    pub fn k(&self) -> usize {
        self.s.len()
    }

    /// Parameter count k(m+n+1) — paper Eq. 1 storage analysis.
    pub fn param_count(&self) -> usize {
        self.k() * (self.m() + self.n() + 1)
    }

    /// y = ((x U) * s) V^T. x: (b x m) -> y: (b x n).
    pub fn forward(&self, x: &Matrix) -> (Matrix, SpectralCache) {
        let h = x.matmul(&self.u); // b x k
        let mut hs = h.clone();
        for j in 0..self.k() {
            hs.scale_col(j, self.s[j]);
        }
        // Sparse-aware V projection: freshly grown columns carry exactly-zero
        // singular values until the optimizer moves them (rank subsystem), so
        // the trailing columns of hs are exactly zero — skip them instead of
        // branching per FLOP in the dense kernel. This also makes the
        // grow-is-exact-continuation property structural: the prefix product
        // IS the pre-grow product. The cache keeps the full-width h/hs (the
        // s-gradients of the new columns are how they come alive).
        let k_eff = self.k() - self.s.iter().rev().take_while(|s| **s == 0.0).count();
        let y = hs.matmul_t_prefix(&self.v, k_eff); // b x n
        (y, SpectralCache { h, hs })
    }

    /// Backward: given dL/dy, produce (dL/dx, grads). Derivation:
    ///   dhs = dy V;  dV = dy^T hs;  ds = sum_b(dhs * h);
    ///   dh = dhs * s;  dU = x^T dh;  dx = dh U^T.
    pub fn backward(
        &self,
        x: &Matrix,
        dy: &Matrix,
        cache: &SpectralCache,
    ) -> (Matrix, SpectralGrads) {
        let k = self.k();
        let dhs = dy.matmul(&self.v); // b x k
        let dv = dy.t_matmul(&cache.hs); // n x k
        let mut ds = vec![0.0f32; k];
        for b in 0..dhs.rows {
            for j in 0..k {
                ds[j] += dhs[(b, j)] * cache.h[(b, j)];
            }
        }
        let mut dh = dhs;
        for j in 0..k {
            dh.scale_col(j, self.s[j]);
        }
        let du = x.t_matmul(&dh); // m x k
        let dx = dh.matmul_t(&self.u); // b x m  (dh @ U^T)
        (dx, SpectralGrads { du, ds, dv })
    }

    /// Materialize W — FOR TESTS ONLY (the training path never does this).
    pub fn to_dense(&self) -> Matrix {
        let mut us = self.u.clone();
        for j in 0..self.k() {
            us.scale_col(j, self.s[j]);
        }
        us.matmul_t(&self.v)
    }

    /// Retract both factors (paper Alg. 1 lines 5-7). U and V are
    /// independent, so they retract on two threads — the §Perf fix that
    /// moved the 70B retraction phase (see EXPERIMENTS.md §Perf; the paper's
    /// sequential per-factor loop is 40-50% of its step time). Respects a
    /// `--threads 1` pool (runs serial); either way each factor's CGS2 is
    /// the same serial kernel, so results never depend on the pool size.
    /// `NativeTrainer` fans the same per-factor work across ALL layers'
    /// triples at once instead of calling this per-triple.
    pub fn retract(&mut self) {
        if crate::util::pool::threads() <= 1 {
            self.u = qr_retract(&self.u);
            self.v = qr_retract(&self.v);
            return;
        }
        let (u, v) = std::thread::scope(|s| {
            let hu = s.spawn(|| qr_retract(&self.u));
            let hv = s.spawn(|| qr_retract(&self.v));
            (hu.join().unwrap(), hv.join().unwrap())
        });
        self.u = u;
        self.v = v;
    }

    /// max of the two factor orthonormality errors.
    pub fn ortho_error(&self) -> f32 {
        self.u.ortho_error().max(self.v.ortho_error())
    }
}

/// One full SCT training step on a single layer with MSE-to-target loss:
/// forward, backward, AdamW on (U, s, V), QR retraction. Returns per-phase
/// seconds (fwd, bwd, opt, retract) — the Table 2 decomposition.
pub struct LayerTrainer {
    pub layer: SpectralLinear,
    opt_u: AdamW,
    opt_s: AdamW,
    opt_v: AdamW,
}

impl LayerTrainer {
    pub fn new(layer: SpectralLinear, lr: f32) -> LayerTrainer {
        let (mu, k, nv) = (layer.m() * layer.k(), layer.k(), layer.n() * layer.k());
        LayerTrainer {
            layer,
            opt_u: AdamW::new(mu, lr),
            opt_s: AdamW::new(k, lr),
            opt_v: AdamW::new(nv, lr),
        }
    }

    /// Returns (loss, [fwd_s, bwd_s, opt_s, retract_s]).
    pub fn step(&mut self, x: &Matrix, target: &Matrix) -> (f32, [f64; 4]) {
        use std::time::Instant;
        let t0 = Instant::now();
        let (y, cache) = self.layer.forward(x);
        let t_fwd = t0.elapsed().as_secs_f64();

        // MSE loss and its gradient.
        let bn = (y.rows * y.cols) as f32;
        let mut dy = Matrix::zeros(y.rows, y.cols);
        let mut loss = 0.0f32;
        for i in 0..y.data.len() {
            let d = y.data[i] - target.data[i];
            loss += d * d;
            dy.data[i] = 2.0 * d / bn;
        }
        loss /= bn;

        let t1 = Instant::now();
        let (_dx, grads) = self.layer.backward(x, &dy, &cache);
        let t_bwd = t1.elapsed().as_secs_f64();

        let t2 = Instant::now();
        self.opt_u.step(&mut self.layer.u.data, &grads.du.data);
        self.opt_s.step(&mut self.layer.s, &grads.ds);
        self.opt_v.step(&mut self.layer.v.data, &grads.dv.data);
        let t_opt = t2.elapsed().as_secs_f64();

        let t3 = Instant::now();
        self.layer.retract();
        let t_retract = t3.elapsed().as_secs_f64();

        (loss, [t_fwd, t_bwd, t_opt, t_retract])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn finite_diff_check(
        layer: &SpectralLinear,
        x: &Matrix,
        dy: &Matrix,
        grads: &SpectralGrads,
    ) -> f32 {
        // Check a handful of entries of each gradient by central differences
        // of L = sum(y * dy) (linear functional so dL/dtheta is exact).
        let eval = |l: &SpectralLinear| -> f32 {
            let (y, _) = l.forward(x);
            y.data.iter().zip(&dy.data).map(|(a, b)| a * b).sum()
        };
        let eps = 1e-3f32;
        let mut max_rel = 0.0f32;
        let probes = [(0usize, 0usize), (1, 0), (0, 1)];
        for &(r, c) in &probes {
            // dU
            let mut lp = layer.clone();
            lp.u[(r, c)] += eps;
            let mut lm = layer.clone();
            lm.u[(r, c)] -= eps;
            let fd = (eval(&lp) - eval(&lm)) / (2.0 * eps);
            let an = grads.du[(r, c)];
            max_rel = max_rel.max((fd - an).abs() / (an.abs().max(1e-3)));
            // dV
            let mut lp = layer.clone();
            lp.v[(r, c)] += eps;
            let mut lm = layer.clone();
            lm.v[(r, c)] -= eps;
            let fd = (eval(&lp) - eval(&lm)) / (2.0 * eps);
            let an = grads.dv[(r, c)];
            max_rel = max_rel.max((fd - an).abs() / (an.abs().max(1e-3)));
        }
        // ds[0]
        let mut lp = layer.clone();
        lp.s[0] += eps;
        let mut lm = layer.clone();
        lm.s[0] -= eps;
        let fd = (eval(&lp) - eval(&lm)) / (2.0 * eps);
        max_rel.max((fd - grads.ds[0]).abs() / grads.ds[0].abs().max(1e-3))
    }

    #[test]
    fn forward_matches_dense() {
        let mut rng = Rng::new(0);
        let layer = SpectralLinear::init(&mut rng, 24, 16, 6);
        let x = Matrix::randn(&mut rng, 5, 24, 1.0);
        let (y, _) = layer.forward(&x);
        let y_dense = x.matmul(&layer.to_dense());
        assert!(y.max_abs_diff(&y_dense) < 1e-4);
    }

    #[test]
    fn gradients_match_finite_differences() {
        let mut rng = Rng::new(1);
        let layer = SpectralLinear::init(&mut rng, 12, 10, 4);
        let x = Matrix::randn(&mut rng, 3, 12, 1.0);
        let dy = Matrix::randn(&mut rng, 3, 10, 1.0);
        let (_, cache) = layer.forward(&x);
        let (_dx, grads) = layer.backward(&x, &dy, &cache);
        let rel = finite_diff_check(&layer, &x, &dy, &grads);
        assert!(rel < 2e-2, "finite-diff rel err {rel}");
    }

    #[test]
    fn dx_matches_finite_differences() {
        let mut rng = Rng::new(2);
        let layer = SpectralLinear::init(&mut rng, 8, 6, 3);
        let mut x = Matrix::randn(&mut rng, 2, 8, 1.0);
        let dy = Matrix::randn(&mut rng, 2, 6, 1.0);
        let (_, cache) = layer.forward(&x);
        let (dx, _) = layer.backward(&x, &dy, &cache);
        let eval = |x: &Matrix| -> f32 {
            let (y, _) = layer.forward(x);
            y.data.iter().zip(&dy.data).map(|(a, b)| a * b).sum()
        };
        let eps = 1e-3;
        let base00 = x[(0, 0)];
        x[(0, 0)] = base00 + eps;
        let fp = eval(&x);
        x[(0, 0)] = base00 - eps;
        let fm = eval(&x);
        x[(0, 0)] = base00;
        let fd = (fp - fm) / (2.0 * eps);
        assert!((fd - dx[(0, 0)]).abs() / dx[(0, 0)].abs().max(1e-3) < 2e-2);
    }

    #[test]
    fn grad_shapes_are_compact() {
        // The paper's claim: gradient shapes are (m,k), (k), (n,k) — never (m,n).
        let mut rng = Rng::new(3);
        let layer = SpectralLinear::init(&mut rng, 32, 20, 5);
        let x = Matrix::randn(&mut rng, 4, 32, 1.0);
        let dy = Matrix::randn(&mut rng, 4, 20, 1.0);
        let (_, cache) = layer.forward(&x);
        let (_, g) = layer.backward(&x, &dy, &cache);
        assert_eq!((g.du.rows, g.du.cols), (32, 5));
        assert_eq!(g.ds.len(), 5);
        assert_eq!((g.dv.rows, g.dv.cols), (20, 5));
    }

    #[test]
    fn training_step_reduces_loss_and_keeps_manifold() {
        let mut rng = Rng::new(4);
        let layer = SpectralLinear::init(&mut rng, 16, 12, 4);
        let mut trainer = LayerTrainer::new(layer, 5e-3);
        let x = Matrix::randn(&mut rng, 8, 16, 1.0);
        let target = Matrix::randn(&mut rng, 8, 12, 0.5);
        let (first, _) = trainer.step(&x, &target);
        let mut last = first;
        for _ in 0..40 {
            let (l, _) = trainer.step(&x, &target);
            last = l;
        }
        assert!(last < first * 0.8, "loss {first} -> {last}");
        assert!(trainer.layer.ortho_error() < 2e-6);
    }

    #[test]
    fn init_variance_matches_glorot() {
        let mut rng = Rng::new(5);
        let layer = SpectralLinear::init(&mut rng, 48, 80, 8);
        let w = layer.to_dense();
        let fro2 = w.data.iter().map(|x| x * x).sum::<f32>();
        let target = 48.0 * 80.0 * 2.0 / (48.0 + 80.0);
        assert!((fro2 - target).abs() / target < 1e-3);
    }
}
