//! Dense row-major f32 matrices — the minimal linear-algebra substrate for
//! the native SCT implementation (QR retraction, truncated SVD, AdamW).
//!
//! Deliberately not a general BLAS: only what the spectral math needs, with
//! cache-blocked SIMD matmuls for the hot paths (the 70B-shape retraction
//! benches run through this code).
//!
//! # Kernel structure and the determinism contract
//!
//! The inner loops live in [`super::microkernel`]: register-tiled GEBP
//! kernels over packed k-panels, with AVX2+FMA paths behind runtime feature
//! detection and bit-identical fused-scalar fallbacks. Each matmul realizes
//! one of the two **canonical accumulation orders** defined there:
//!
//! * `matmul` / `t_matmul` — the broadcast-FMA fold: every output element is
//!   `acc = fma(a_ik, b_kj, acc)` over the shared dimension ascending. The
//!   fold depends on nothing but the shared-dimension length — not on the
//!   output shape, the MR×NR tiling, the packed-vs-stream path choice, or
//!   the `par_rows` shard decomposition — so results are bit-identical at
//!   any thread count *and* across the fused/per-position prefill split in
//!   serve (same per-element bits whether a row is computed in an m=19
//!   batch or an m=1 decode step).
//! * `matmul_t` / `matmul_t_prefix` — the 8-lane fused [`dot`], whose
//!   structure depends only on the dotted length `k_eff`; see
//!   [`Matrix::matmul_t_prefix`] for why that carries the rank-grow
//!   invariant.
//!
//! Above a work threshold ([`pool::par_threshold`], tunable via
//! `SCT_PAR_THRESHOLD` / `[runtime] par_threshold`) the **output rows**
//! shard across the scoped worker pool; B-operand panels are packed once
//! before the dispatch so both arms run the identical blocked kernel
//! against shared panels (see the pool module docs for the contract).
//! Outputs thinner than [`microkernel::MIN_PACK_ROWS`] rows — the decode
//! hot path — take an unpacked stream kernel with the same per-element
//! fold.
//!
//! The inner loops are branch-free on purpose: a zero test per FLOP costs
//! more than it saves on dense data and makes timing data-dependent; the
//! one place exact zeros systematically occur — trailing zero singular
//! values after a rank-grow — goes through the dedicated
//! [`Matrix::matmul_t_prefix`] path instead.

use super::microkernel;
use crate::obs::prof;
use crate::util::pool;
use crate::util::rng::Rng;

pub use super::microkernel::{axpy, dot};

/// Row-major matrix of f32.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Matrix {
        assert_eq!(data.len(), rows * cols, "data length mismatch");
        Matrix { rows, cols, data }
    }

    pub fn eye(n: usize) -> Matrix {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Gaussian N(0, sigma^2) entries.
    pub fn randn(rng: &mut Rng, rows: usize, cols: usize, sigma: f32) -> Matrix {
        let data = (0..rows * cols).map(|_| rng.normal() as f32 * sigma).collect();
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Column `c` as a fresh Vec (rows are contiguous, columns are not).
    pub fn col(&self, c: usize) -> Vec<f32> {
        let mut buf = Vec::new();
        self.col_into(c, &mut buf);
        buf
    }

    /// Copy column `c` into `buf`, clearing it first and reusing its
    /// capacity — the allocation-free twin of [`Matrix::col`] for hot loops
    /// (the CGS2 retraction refills one column buffer per panel column).
    /// One strided pass over `data` — no per-element bounds-checked `Index`.
    pub fn col_into(&self, c: usize, buf: &mut Vec<f32>) {
        debug_assert!(c < self.cols);
        buf.clear();
        if self.rows == 0 {
            return;
        }
        buf.reserve(self.rows);
        buf.extend(self.data[c..].iter().step_by(self.cols).copied());
    }

    /// Cache-blocked transpose: walk 32×32 tiles so both the read and the
    /// write side stay within a few cache lines per tile (the naive strided
    /// loop thrashes on the tall factor matrices in checkpoint load and
    /// SVD shrink).
    pub fn transpose(&self) -> Matrix {
        const TB: usize = 32;
        let (rows, cols) = (self.rows, self.cols);
        let mut t = Matrix::zeros(cols, rows);
        let mut r0 = 0;
        while r0 < rows {
            let rmax = (r0 + TB).min(rows);
            let mut c0 = 0;
            while c0 < cols {
                let cmax = (c0 + TB).min(cols);
                for r in r0..rmax {
                    let src = &self.data[r * cols..r * cols + cmax];
                    for c in c0..cmax {
                        t.data[c * rows + r] = src[c];
                    }
                }
                c0 += TB;
            }
            r0 += TB;
        }
        t
    }

    /// `self @ other` through the blocked GEBP microkernel: B is packed
    /// once into k-major NR-column panels, A row tiles are packed per MR
    /// rows, and `microkernel::gebp_tile` computes MR×NR register tiles.
    /// Output rows shard across the worker pool above the work threshold;
    /// both dispatch arms run the identical kernel against the shared
    /// packed panels, so results are bit-identical at any thread count.
    /// Outputs under `MIN_PACK_ROWS` rows (the decode path) take the
    /// unpacked row-stream kernel — same per-element fold, same bits.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, kdim, n) = (self.rows, self.cols, other.cols);
        let packed = m >= microkernel::MIN_PACK_ROWS;
        let _prof = prof::kernel("matmul", || {
            if packed {
                prof::matmul_packed_work(m, kdim, n)
            } else {
                prof::matmul_work(m, kdim, n)
            }
        });
        let mut out = Matrix::zeros(m, n);
        if self.data.is_empty() || other.data.is_empty() {
            return out;
        }
        if packed {
            let bpanels = microkernel::pack_b_panels(&other.data, kdim, n);
            if pool::parallel_worthwhile(m * kdim * n, pool::par_threshold()) {
                pool::par_rows(&mut out.data, n, |r0, block| {
                    self.matmul_block(&bpanels, n, r0, block)
                });
            } else {
                self.matmul_block(&bpanels, n, 0, &mut out.data);
            }
        } else {
            self.matmul_stream(other, &mut out.data);
        }
        out
    }

    /// Rows `r0..r0 + block.len()/n` of `self @ other` into `block` — the
    /// GEBP kernel shared by both matmul dispatch arms. `bpanels` is the
    /// packed B operand (`microkernel::pack_b_panels`), shared read-only
    /// across shards.
    fn matmul_block(&self, bpanels: &[f32], n: usize, r0: usize, block: &mut [f32]) {
        let kdim = self.cols;
        let mb = block.len() / n;
        let mut apanel: Vec<f32> = Vec::new();
        let mut ib = 0;
        while ib < mb {
            let mr = (mb - ib).min(microkernel::MR);
            microkernel::pack_a_rows(&self.data, kdim, r0 + ib, mr, &mut apanel);
            let mut j0 = 0;
            while j0 < n {
                let nr = (n - j0).min(microkernel::NR);
                let panel_len = kdim * microkernel::NR;
                let bpanel = &bpanels[(j0 / microkernel::NR) * panel_len..][..panel_len];
                microkernel::gebp_tile(
                    &apanel,
                    mr,
                    bpanel,
                    kdim,
                    nr,
                    &mut block[ib * n + j0..],
                    n,
                );
                j0 += microkernel::NR;
            }
            ib += mr;
        }
    }

    /// Thin-output `self @ other` (fewer than `MIN_PACK_ROWS` rows): fused
    /// row-axpy stream over `other`'s rows, no packing. Per-element this is
    /// the same broadcast-FMA fold over k ascending as the GEBP path — the
    /// decode step (m = 1) produces bit-identical logits to the same row
    /// computed inside a fused prefill batch.
    fn matmul_stream(&self, other: &Matrix, out: &mut [f32]) {
        let n = other.cols;
        for (bi, out_row) in out.chunks_mut(n).enumerate() {
            let a_row = self.row(bi);
            for (k, &a_ik) in a_row.iter().enumerate() {
                axpy(a_ik, other.row(k), out_row);
            }
        }
    }

    /// `self^T @ other` without materializing the transpose, through the
    /// same GEBP microkernel as [`Matrix::matmul`]: the shared dimension is
    /// `self.rows`, A "row tiles" are column slivers of `self` packed by
    /// `microkernel::pack_a_cols` (contiguous reads per source row), B
    /// packs exactly as in matmul. Output rows (columns of `self`) shard
    /// across the pool; the per-element fold over the shared dimension is
    /// shard-independent, so results are bit-identical at any thread count.
    pub fn t_matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.rows, other.rows, "t_matmul shape mismatch");
        let (rdim, m, n) = (self.rows, self.cols, other.cols);
        let packed = m >= microkernel::MIN_PACK_ROWS;
        let _prof = prof::kernel("t_matmul", || {
            if packed {
                prof::matmul_packed_work(m, rdim, n)
            } else {
                prof::matmul_work(m, rdim, n)
            }
        });
        let mut out = Matrix::zeros(m, n);
        if self.data.is_empty() || other.data.is_empty() {
            return out;
        }
        if packed {
            let bpanels = microkernel::pack_b_panels(&other.data, rdim, n);
            if pool::parallel_worthwhile(rdim * m * n, pool::par_threshold()) {
                pool::par_rows(&mut out.data, n, |i0, block| {
                    self.t_matmul_block(&bpanels, n, i0, block)
                });
            } else {
                self.t_matmul_block(&bpanels, n, 0, &mut out.data);
            }
        } else {
            self.t_matmul_stream(other, &mut out.data);
        }
        out
    }

    /// Output rows `i0..i0 + block.len()/n` of `self^T @ other` into
    /// `block` via GEBP over packed panels (shared dimension `self.rows`).
    fn t_matmul_block(&self, bpanels: &[f32], n: usize, i0: usize, block: &mut [f32]) {
        let rdim = self.rows;
        let mb = block.len() / n;
        let mut apanel: Vec<f32> = Vec::new();
        let mut ib = 0;
        while ib < mb {
            let mr = (mb - ib).min(microkernel::MR);
            microkernel::pack_a_cols(&self.data, self.cols, rdim, i0 + ib, mr, &mut apanel);
            let mut j0 = 0;
            while j0 < n {
                let nr = (n - j0).min(microkernel::NR);
                let panel_len = rdim * microkernel::NR;
                let bpanel = &bpanels[(j0 / microkernel::NR) * panel_len..][..panel_len];
                microkernel::gebp_tile(
                    &apanel,
                    mr,
                    bpanel,
                    rdim,
                    nr,
                    &mut block[ib * n + j0..],
                    n,
                );
                j0 += microkernel::NR;
            }
            ib += mr;
        }
    }

    /// Thin-output `self^T @ other`: stream over the shared `r` dimension
    /// in order with fused axpy — the same per-element fold as the GEBP
    /// path.
    fn t_matmul_stream(&self, other: &Matrix, out: &mut [f32]) {
        let n = other.cols;
        for r in 0..self.rows {
            let a_row = self.row(r);
            let b_row = other.row(r);
            for (i, out_row) in out.chunks_mut(n).enumerate() {
                axpy(a_row[i], b_row, out_row);
            }
        }
    }

    /// `self @ other^T` without materializing the transpose.
    pub fn matmul_t(&self, other: &Matrix) -> Matrix {
        self.matmul_t_prefix(other, self.cols)
    }

    /// `self[:, ..k_eff] @ other[:, ..k_eff]^T` — the sparse-aware matmul_t.
    ///
    /// The rank subsystem's grow appends columns with **exactly zero**
    /// singular values, which makes the trailing columns of `x U diag(s)`
    /// exactly zero until the optimizer moves them; `SpectralLinear::forward`
    /// skips that block here instead of burning FLOPs on it (and instead of
    /// a per-element zero branch inside the dense kernels). With
    /// `k_eff == cols` this IS `matmul_t`. Every output element is the
    /// canonical 8-lane fused [`dot`] of length `k_eff` — its lane grouping
    /// depends only on that length, so a grown layer's forward stays
    /// bit-identical to its pre-grow forward.
    pub fn matmul_t_prefix(&self, other: &Matrix, k_eff: usize) -> Matrix {
        assert_eq!(self.cols, other.cols, "matmul_t shape mismatch");
        assert!(k_eff <= self.cols, "prefix {k_eff} beyond inner dim {}", self.cols);
        let (m, n) = (self.rows, other.rows);
        let _prof = prof::kernel("matmul_t", || prof::matmul_work(m, k_eff, n));
        let mut out = Matrix::zeros(m, n);
        if m == 0 || n == 0 || k_eff == 0 {
            return out;
        }
        if m > 1 && pool::parallel_worthwhile(m * k_eff * n, pool::par_threshold()) {
            pool::par_rows(&mut out.data, n, |r0, block| {
                self.matmul_t_block(other, k_eff, r0, block)
            });
        } else {
            self.matmul_t_block(other, k_eff, 0, &mut out.data);
        }
        out
    }

    /// Rows `r0..` of `self @ other^T` (inner dimension truncated to
    /// `k_eff`) into `block`. Columns are tiled by NR so each 8-row tile of
    /// `other` stays cache-hot across the whole row block
    /// (`microkernel::dot8_rows` — eight canonical dots sharing the A-row
    /// loads); remainder columns fall back to single [`dot`] calls with
    /// identical per-element bits.
    fn matmul_t_block(&self, other: &Matrix, k_eff: usize, r0: usize, block: &mut [f32]) {
        let n = other.rows;
        let mb = block.len() / n;
        let mut j0 = 0;
        while j0 + microkernel::NR <= n {
            for bi in 0..mb {
                let a_row = &self.row(r0 + bi)[..k_eff];
                let o = bi * n + j0;
                microkernel::dot8_rows(
                    a_row,
                    &other.data,
                    other.cols,
                    j0,
                    &mut block[o..o + microkernel::NR],
                );
            }
            j0 += microkernel::NR;
        }
        for j in j0..n {
            let b_row = &other.row(j)[..k_eff];
            for bi in 0..mb {
                block[bi * n + j] = dot(&self.row(r0 + bi)[..k_eff], b_row);
            }
        }
    }

    /// Scale column `c` by `f` in place.
    pub fn scale_col(&mut self, c: usize, f: f32) {
        for r in 0..self.rows {
            self[(r, c)] *= f;
        }
    }

    pub fn frob_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// max |Q^T Q - I| — factor orthonormality error (paper: < 2e-6).
    /// Accumulates in f64: at the 70B factor shapes (m ~ 3e4) an f32 Gram
    /// accumulation alone contributes ~1e-5 of *measurement* noise, swamping
    /// the threshold being verified.
    pub fn ortho_error(&self) -> f32 {
        let k = self.cols;
        let mut err = 0.0f64;
        for i in 0..k {
            for j in i..k {
                let mut acc = 0.0f64;
                for r in 0..self.rows {
                    acc += self[(r, i)] as f64 * self[(r, j)] as f64;
                }
                let target = if i == j { 1.0 } else { 0.0 };
                err = err.max((acc - target).abs());
            }
        }
        err as f32
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f32;
    #[inline]
    fn index(&self, (r, c): (usize, usize)) -> &f32 {
        &self.data[r * self.cols + c]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small_known() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(0);
        let a = Matrix::randn(&mut rng, 5, 7, 1.0);
        assert_eq!(a.transpose().transpose(), a);
        // shapes straddling the 32-tile boundary
        let b = Matrix::randn(&mut rng, 33, 65, 1.0);
        let bt = b.transpose();
        assert_eq!(bt.transpose(), b);
        for r in 0..b.rows {
            for c in 0..b.cols {
                assert_eq!(bt[(c, r)], b[(r, c)]);
            }
        }
    }

    #[test]
    fn t_matmul_matches_explicit_transpose() {
        let mut rng = Rng::new(1);
        let a = Matrix::randn(&mut rng, 6, 4, 1.0);
        let b = Matrix::randn(&mut rng, 6, 5, 1.0);
        let fast = a.t_matmul(&b);
        let slow = a.transpose().matmul(&b);
        assert!(fast.max_abs_diff(&slow) < 1e-5);
    }

    #[test]
    fn matmul_t_matches_explicit_transpose() {
        let mut rng = Rng::new(2);
        let a = Matrix::randn(&mut rng, 6, 4, 1.0);
        let b = Matrix::randn(&mut rng, 5, 4, 1.0);
        let fast = a.matmul_t(&b);
        let slow = a.matmul(&b.transpose());
        assert!(fast.max_abs_diff(&slow) < 1e-5);
    }

    #[test]
    fn matmul_t_prefix_skips_trailing_zero_columns_bit_exactly() {
        // The rank-grow invariant: appending zero-s columns and dotting the
        // prefix must reproduce the pre-grow product bit-for-bit.
        let mut rng = Rng::new(7);
        let a_small = Matrix::randn(&mut rng, 6, 4, 1.0);
        let b_small = Matrix::randn(&mut rng, 5, 4, 1.0);
        let full = a_small.matmul_t(&b_small);
        // widen both with garbage columns, then ask for the 4-col prefix
        let widen = |m: &Matrix, extra: usize| {
            let mut w = Matrix::randn(&mut rng, m.rows, m.cols + extra, 1.0);
            for r in 0..m.rows {
                w.row_mut(r)[..m.cols].copy_from_slice(m.row(r));
            }
            w
        };
        let a_wide = widen(&a_small, 3);
        let b_wide = widen(&b_small, 3);
        let pref = a_wide.matmul_t_prefix(&b_wide, 4);
        assert_eq!(pref.data, full.data, "prefix product must be bit-identical");
        // k_eff == cols is plain matmul_t
        assert_eq!(a_small.matmul_t_prefix(&b_small, 4).data, full.data);
        // k_eff == 0 is the zero matrix
        assert_eq!(a_small.matmul_t_prefix(&b_small, 0).data, vec![0.0; 6 * 5]);
    }

    #[test]
    fn packed_and_streamed_matmul_bit_identical() {
        // The fused-vs-per-position prefill invariant at the kernel level:
        // rows of a packed-GEBP matmul (m >= MIN_PACK_ROWS) must equal the
        // same rows computed by the thin-output stream kernel (m = 1)
        // bit-for-bit — path selection is a data-movement decision, never a
        // numerics fork.
        let mut rng = Rng::new(11);
        let a = Matrix::randn(&mut rng, 12, 19, 1.0); // 19: ragged k
        let b = Matrix::randn(&mut rng, 19, 23, 1.0); // 23: ragged n
        let fused = a.matmul(&b);
        for r in 0..a.rows {
            let row = Matrix::from_vec(1, a.cols, a.row(r).to_vec());
            let single = row.matmul(&b);
            assert_eq!(
                single.data, fused.data[r * b.cols..(r + 1) * b.cols],
                "row {r}: packed GEBP and stream kernels diverged"
            );
        }

        // Same invariant for t_matmul: a 3-column slice (stream path)
        // against the matching columns of the full product (packed path).
        let c = Matrix::randn(&mut rng, 19, 9, 1.0);
        let full = c.t_matmul(&b);
        let mut thin = Matrix::zeros(c.rows, 3);
        for r in 0..c.rows {
            thin.row_mut(r).copy_from_slice(&c.row(r)[4..7]);
        }
        let part = thin.t_matmul(&b);
        for i in 0..3 {
            assert_eq!(
                part.row(i),
                full.row(4 + i),
                "t_matmul col {i}: packed and stream kernels diverged"
            );
        }
    }

    #[test]
    fn col_into_reuses_buffer_and_matches_col() {
        let mut rng = Rng::new(8);
        let a = Matrix::randn(&mut rng, 9, 4, 1.0);
        let mut buf = vec![99.0f32; 3]; // stale contents + wrong length
        a.col_into(2, &mut buf);
        assert_eq!(buf, a.col(2));
        assert_eq!(buf.len(), 9);
        a.col_into(0, &mut buf); // reuse for another column
        assert_eq!(buf, a.col(0));
        a.col_into(3, &mut buf); // last column: strided walk must not overrun
        assert_eq!(buf, a.col(3));
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Rng::new(3);
        let a = Matrix::randn(&mut rng, 4, 4, 1.0);
        assert!(a.matmul(&Matrix::eye(4)).max_abs_diff(&a) < 1e-6);
        assert!(Matrix::eye(4).matmul(&a).max_abs_diff(&a) < 1e-6);
    }

    #[test]
    fn ortho_error_of_identity_is_zero() {
        assert_eq!(Matrix::eye(8).ortho_error(), 0.0);
    }

    #[test]
    fn dot_matches_naive() {
        let x: Vec<f32> = (0..37).map(|i| i as f32 * 0.5).collect();
        let y: Vec<f32> = (0..37).map(|i| 1.0 - i as f32 * 0.1).collect();
        let naive: f32 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        assert!((dot(&x, &y) - naive).abs() < 1e-3);
    }
}
