//! Dense row-major f32 matrices — the minimal linear-algebra substrate for
//! the native SCT implementation (QR retraction, truncated SVD, AdamW).
//!
//! Deliberately not a general BLAS: only what the spectral math needs, with
//! a cache-blocked `matmul` for the hot paths (the 70B-shape retraction
//! benches run through this code).
//!
//! The three matmuls dispatch through `util::pool`: above a work threshold
//! the **output rows** are sharded across the scoped worker pool, each row
//! computed by the same serial kernel in the same accumulation order — so
//! results are bit-identical at any thread count (see the pool module docs
//! for the determinism contract). Small shapes take the serial kernel
//! directly. The inner loops are branch-free on purpose: a zero test per
//! FLOP costs more than it saves on dense data and makes timing
//! data-dependent; the one place exact zeros systematically occur —
//! trailing zero singular values after a rank-grow — goes through the
//! dedicated [`Matrix::matmul_t_prefix`] path instead.

use crate::obs::prof;
use crate::util::pool;
use crate::util::rng::Rng;

/// Inner-loop multiply-accumulate count below which the matmuls stay
/// serial (scoped-spawn overhead dominates under ~10^5 FLOPs).
const PAR_FLOPS: usize = 1 << 17;

/// Row-major matrix of f32.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Matrix {
        assert_eq!(data.len(), rows * cols, "data length mismatch");
        Matrix { rows, cols, data }
    }

    pub fn eye(n: usize) -> Matrix {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Gaussian N(0, sigma^2) entries.
    pub fn randn(rng: &mut Rng, rows: usize, cols: usize, sigma: f32) -> Matrix {
        let data = (0..rows * cols).map(|_| rng.normal() as f32 * sigma).collect();
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Column `c` as a fresh Vec (rows are contiguous, columns are not).
    pub fn col(&self, c: usize) -> Vec<f32> {
        let mut buf = Vec::new();
        self.col_into(c, &mut buf);
        buf
    }

    /// Copy column `c` into `buf`, clearing it first and reusing its
    /// capacity — the allocation-free twin of [`Matrix::col`] for hot loops
    /// (the CGS2 retraction refills one column buffer per panel column).
    pub fn col_into(&self, c: usize, buf: &mut Vec<f32>) {
        debug_assert!(c < self.cols);
        buf.clear();
        buf.reserve(self.rows);
        for r in 0..self.rows {
            buf.push(self[(r, c)]);
        }
    }

    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                t[(c, r)] = self[(r, c)];
            }
        }
        t
    }

    /// `self @ other`, cache-blocked (i,k,j loop order keeps the inner loop
    /// streaming over contiguous rows of both output and `other`). Output
    /// rows are sharded across the worker pool above the work threshold;
    /// each row runs the identical serial kernel, so results are
    /// bit-identical at any thread count.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, kdim, n) = (self.rows, self.cols, other.cols);
        let _prof = prof::kernel("matmul", || prof::matmul_work(m, kdim, n));
        let mut out = Matrix::zeros(m, n);
        if self.data.is_empty() || other.data.is_empty() {
            return out;
        }
        if m > 1 && pool::parallel_worthwhile(m * kdim * n, PAR_FLOPS) {
            pool::par_rows(&mut out.data, n, |r0, block| self.matmul_block(other, r0, block));
        } else {
            self.matmul_block(other, 0, &mut out.data);
        }
        out
    }

    /// Rows `r0..r0 + block.len()/n` of `self @ other` into `block` — the
    /// shared serial kernel of both matmul dispatch arms.
    fn matmul_block(&self, other: &Matrix, r0: usize, block: &mut [f32]) {
        let n = other.cols;
        for (bi, out_row) in block.chunks_mut(n).enumerate() {
            let a_row = self.row(r0 + bi);
            for (k, &a_ik) in a_row.iter().enumerate() {
                axpy(a_ik, other.row(k), out_row);
            }
        }
    }

    /// `self^T @ other` without materializing the transpose. Output rows
    /// (columns of `self`) shard across the pool; within each output row
    /// the accumulation order over the shared dimension is the serial
    /// kernel's, so results are bit-identical at any thread count.
    pub fn t_matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.rows, other.rows, "t_matmul shape mismatch");
        let (m, n) = (self.cols, other.cols);
        let _prof = prof::kernel("t_matmul", || prof::matmul_work(m, self.rows, n));
        let mut out = Matrix::zeros(m, n);
        if self.data.is_empty() || other.data.is_empty() {
            return out;
        }
        if m > 1 && pool::parallel_worthwhile(self.rows * m * n, PAR_FLOPS) {
            pool::par_rows(&mut out.data, n, |i0, block| self.t_matmul_block(other, i0, block));
        } else {
            self.t_matmul_block(other, 0, &mut out.data);
        }
        out
    }

    /// Output rows `i0..i0 + block.len()/n` of `self^T @ other` into
    /// `block`, streaming over the shared `r` dimension in order.
    fn t_matmul_block(&self, other: &Matrix, i0: usize, block: &mut [f32]) {
        let n = other.cols;
        for r in 0..self.rows {
            let a_row = self.row(r);
            let b_row = other.row(r);
            for (bi, out_row) in block.chunks_mut(n).enumerate() {
                axpy(a_row[i0 + bi], b_row, out_row);
            }
        }
    }

    /// `self @ other^T` without materializing the transpose.
    pub fn matmul_t(&self, other: &Matrix) -> Matrix {
        self.matmul_t_prefix(other, self.cols)
    }

    /// `self[:, ..k_eff] @ other[:, ..k_eff]^T` — the sparse-aware matmul_t.
    ///
    /// The rank subsystem's grow appends columns with **exactly zero**
    /// singular values, which makes the trailing columns of `x U diag(s)`
    /// exactly zero until the optimizer moves them; `SpectralLinear::forward`
    /// skips that block here instead of burning FLOPs on it (and instead of
    /// a per-element zero branch inside the dense kernels). With
    /// `k_eff == cols` this IS `matmul_t`. The prefix dot uses the same
    /// lane grouping as the pre-grow full dot, so a grown layer's forward
    /// stays bit-identical to its pre-grow forward.
    pub fn matmul_t_prefix(&self, other: &Matrix, k_eff: usize) -> Matrix {
        assert_eq!(self.cols, other.cols, "matmul_t shape mismatch");
        assert!(k_eff <= self.cols, "prefix {k_eff} beyond inner dim {}", self.cols);
        let (m, n) = (self.rows, other.rows);
        let _prof = prof::kernel("matmul_t", || prof::matmul_work(m, k_eff, n));
        let mut out = Matrix::zeros(m, n);
        if m == 0 || n == 0 || k_eff == 0 {
            return out;
        }
        if m > 1 && pool::parallel_worthwhile(m * k_eff * n, PAR_FLOPS) {
            pool::par_rows(&mut out.data, n, |r0, block| {
                self.matmul_t_block(other, k_eff, r0, block)
            });
        } else {
            self.matmul_t_block(other, k_eff, 0, &mut out.data);
        }
        out
    }

    /// Rows `r0..` of `self @ other^T` (inner dimension truncated to
    /// `k_eff`) into `block`.
    fn matmul_t_block(&self, other: &Matrix, k_eff: usize, r0: usize, block: &mut [f32]) {
        let n = other.rows;
        for (bi, out_row) in block.chunks_mut(n).enumerate() {
            let a_row = &self.row(r0 + bi)[..k_eff];
            for (j, o) in out_row.iter_mut().enumerate() {
                *o = dot(a_row, &other.row(j)[..k_eff]);
            }
        }
    }

    /// Scale column `c` by `f` in place.
    pub fn scale_col(&mut self, c: usize, f: f32) {
        for r in 0..self.rows {
            self[(r, c)] *= f;
        }
    }

    pub fn frob_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// max |Q^T Q - I| — factor orthonormality error (paper: < 2e-6).
    /// Accumulates in f64: at the 70B factor shapes (m ~ 3e4) an f32 Gram
    /// accumulation alone contributes ~1e-5 of *measurement* noise, swamping
    /// the threshold being verified.
    pub fn ortho_error(&self) -> f32 {
        let k = self.cols;
        let mut err = 0.0f64;
        for i in 0..k {
            for j in i..k {
                let mut acc = 0.0f64;
                for r in 0..self.rows {
                    acc += self[(r, i)] as f64 * self[(r, j)] as f64;
                }
                let target = if i == j { 1.0 } else { 0.0 };
                err = err.max((acc - target).abs());
            }
        }
        err as f32
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f32;
    #[inline]
    fn index(&self, (r, c): (usize, usize)) -> &f32 {
        &self.data[r * self.cols + c]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }
}

#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // 4-lane unrolling; LLVM vectorizes this reliably.
    let mut acc = [0.0f32; 4];
    let chunks = a.len() / 4;
    for i in 0..chunks {
        for l in 0..4 {
            acc[l] += a[i * 4 + l] * b[i * 4 + l];
        }
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..a.len() {
        s += a[i] * b[i];
    }
    s
}

#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small_known() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(0);
        let a = Matrix::randn(&mut rng, 5, 7, 1.0);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn t_matmul_matches_explicit_transpose() {
        let mut rng = Rng::new(1);
        let a = Matrix::randn(&mut rng, 6, 4, 1.0);
        let b = Matrix::randn(&mut rng, 6, 5, 1.0);
        let fast = a.t_matmul(&b);
        let slow = a.transpose().matmul(&b);
        assert!(fast.max_abs_diff(&slow) < 1e-5);
    }

    #[test]
    fn matmul_t_matches_explicit_transpose() {
        let mut rng = Rng::new(2);
        let a = Matrix::randn(&mut rng, 6, 4, 1.0);
        let b = Matrix::randn(&mut rng, 5, 4, 1.0);
        let fast = a.matmul_t(&b);
        let slow = a.matmul(&b.transpose());
        assert!(fast.max_abs_diff(&slow) < 1e-5);
    }

    #[test]
    fn matmul_t_prefix_skips_trailing_zero_columns_bit_exactly() {
        // The rank-grow invariant: appending zero-s columns and dotting the
        // prefix must reproduce the pre-grow product bit-for-bit.
        let mut rng = Rng::new(7);
        let a_small = Matrix::randn(&mut rng, 6, 4, 1.0);
        let b_small = Matrix::randn(&mut rng, 5, 4, 1.0);
        let full = a_small.matmul_t(&b_small);
        // widen both with garbage columns, then ask for the 4-col prefix
        let widen = |m: &Matrix, extra: usize| {
            let mut w = Matrix::randn(&mut rng, m.rows, m.cols + extra, 1.0);
            for r in 0..m.rows {
                w.row_mut(r)[..m.cols].copy_from_slice(m.row(r));
            }
            w
        };
        let a_wide = widen(&a_small, 3);
        let b_wide = widen(&b_small, 3);
        let pref = a_wide.matmul_t_prefix(&b_wide, 4);
        assert_eq!(pref.data, full.data, "prefix product must be bit-identical");
        // k_eff == cols is plain matmul_t
        assert_eq!(a_small.matmul_t_prefix(&b_small, 4).data, full.data);
        // k_eff == 0 is the zero matrix
        assert_eq!(a_small.matmul_t_prefix(&b_small, 0).data, vec![0.0; 6 * 5]);
    }

    #[test]
    fn col_into_reuses_buffer_and_matches_col() {
        let mut rng = Rng::new(8);
        let a = Matrix::randn(&mut rng, 9, 4, 1.0);
        let mut buf = vec![99.0f32; 3]; // stale contents + wrong length
        a.col_into(2, &mut buf);
        assert_eq!(buf, a.col(2));
        assert_eq!(buf.len(), 9);
        a.col_into(0, &mut buf); // reuse for another column
        assert_eq!(buf, a.col(0));
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Rng::new(3);
        let a = Matrix::randn(&mut rng, 4, 4, 1.0);
        assert!(a.matmul(&Matrix::eye(4)).max_abs_diff(&a) < 1e-6);
        assert!(Matrix::eye(4).matmul(&a).max_abs_diff(&a) < 1e-6);
    }

    #[test]
    fn ortho_error_of_identity_is_zero() {
        assert_eq!(Matrix::eye(8).ortho_error(), 0.0);
    }

    #[test]
    fn dot_matches_naive() {
        let x: Vec<f32> = (0..37).map(|i| i as f32 * 0.5).collect();
        let y: Vec<f32> = (0..37).map(|i| 1.0 - i as f32 * 0.1).collect();
        let naive: f32 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        assert!((dot(&x, &y) - naive).abs() < 1e-3);
    }
}
