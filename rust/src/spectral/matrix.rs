//! Dense row-major f32 matrices — the minimal linear-algebra substrate for
//! the native SCT implementation (QR retraction, truncated SVD, AdamW).
//!
//! Deliberately not a general BLAS: only what the spectral math needs, with
//! a cache-blocked `matmul` for the hot paths (the 70B-shape retraction
//! benches run through this code).

use crate::util::rng::Rng;

/// Row-major matrix of f32.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Matrix {
        assert_eq!(data.len(), rows * cols, "data length mismatch");
        Matrix { rows, cols, data }
    }

    pub fn eye(n: usize) -> Matrix {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Gaussian N(0, sigma^2) entries.
    pub fn randn(rng: &mut Rng, rows: usize, cols: usize, sigma: f32) -> Matrix {
        let data = (0..rows * cols).map(|_| rng.normal() as f32 * sigma).collect();
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Column `c` as a fresh Vec (rows are contiguous, columns are not).
    pub fn col(&self, c: usize) -> Vec<f32> {
        (0..self.rows).map(|r| self[(r, c)]).collect()
    }

    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                t[(c, r)] = self[(r, c)];
            }
        }
        t
    }

    /// `self @ other`, cache-blocked (i,k,j loop order keeps the inner loop
    /// streaming over contiguous rows of both output and `other`).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, kdim, n) = (self.rows, self.cols, other.cols);
        let mut out = Matrix::zeros(m, n);
        for i in 0..m {
            let a_row = self.row(i);
            let out_row = out.row_mut(i);
            for (k, &a_ik) in a_row.iter().enumerate().take(kdim) {
                if a_ik == 0.0 {
                    continue;
                }
                let b_row = other.row(k);
                for j in 0..n {
                    out_row[j] += a_ik * b_row[j];
                }
            }
        }
        out
    }

    /// `self^T @ other` without materializing the transpose.
    pub fn t_matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.rows, other.rows, "t_matmul shape mismatch");
        let (m, n) = (self.cols, other.cols);
        let mut out = Matrix::zeros(m, n);
        for r in 0..self.rows {
            let a_row = self.row(r);
            let b_row = other.row(r);
            for (i, &a_ri) in a_row.iter().enumerate() {
                if a_ri == 0.0 {
                    continue;
                }
                let out_row = out.row_mut(i);
                for (j, &b_rj) in b_row.iter().enumerate() {
                    out_row[j] += a_ri * b_rj;
                }
            }
        }
        out
    }

    /// `self @ other^T` without materializing the transpose.
    pub fn matmul_t(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols, "matmul_t shape mismatch");
        let (m, n) = (self.rows, other.rows);
        let mut out = Matrix::zeros(m, n);
        for i in 0..m {
            let a_row = self.row(i);
            let out_row = out.row_mut(i);
            for j in 0..n {
                out_row[j] = dot(a_row, other.row(j));
            }
        }
        out
    }

    /// Scale column `c` by `f` in place.
    pub fn scale_col(&mut self, c: usize, f: f32) {
        for r in 0..self.rows {
            self[(r, c)] *= f;
        }
    }

    pub fn frob_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// max |Q^T Q - I| — factor orthonormality error (paper: < 2e-6).
    /// Accumulates in f64: at the 70B factor shapes (m ~ 3e4) an f32 Gram
    /// accumulation alone contributes ~1e-5 of *measurement* noise, swamping
    /// the threshold being verified.
    pub fn ortho_error(&self) -> f32 {
        let k = self.cols;
        let mut err = 0.0f64;
        for i in 0..k {
            for j in i..k {
                let mut acc = 0.0f64;
                for r in 0..self.rows {
                    acc += self[(r, i)] as f64 * self[(r, j)] as f64;
                }
                let target = if i == j { 1.0 } else { 0.0 };
                err = err.max((acc - target).abs());
            }
        }
        err as f32
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f32;
    #[inline]
    fn index(&self, (r, c): (usize, usize)) -> &f32 {
        &self.data[r * self.cols + c]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }
}

#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // 4-lane unrolling; LLVM vectorizes this reliably.
    let mut acc = [0.0f32; 4];
    let chunks = a.len() / 4;
    for i in 0..chunks {
        for l in 0..4 {
            acc[l] += a[i * 4 + l] * b[i * 4 + l];
        }
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..a.len() {
        s += a[i] * b[i];
    }
    s
}

#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small_known() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(0);
        let a = Matrix::randn(&mut rng, 5, 7, 1.0);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn t_matmul_matches_explicit_transpose() {
        let mut rng = Rng::new(1);
        let a = Matrix::randn(&mut rng, 6, 4, 1.0);
        let b = Matrix::randn(&mut rng, 6, 5, 1.0);
        let fast = a.t_matmul(&b);
        let slow = a.transpose().matmul(&b);
        assert!(fast.max_abs_diff(&slow) < 1e-5);
    }

    #[test]
    fn matmul_t_matches_explicit_transpose() {
        let mut rng = Rng::new(2);
        let a = Matrix::randn(&mut rng, 6, 4, 1.0);
        let b = Matrix::randn(&mut rng, 5, 4, 1.0);
        let fast = a.matmul_t(&b);
        let slow = a.matmul(&b.transpose());
        assert!(fast.max_abs_diff(&slow) < 1e-5);
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Rng::new(3);
        let a = Matrix::randn(&mut rng, 4, 4, 1.0);
        assert!(a.matmul(&Matrix::eye(4)).max_abs_diff(&a) < 1e-6);
        assert!(Matrix::eye(4).matmul(&a).max_abs_diff(&a) < 1e-6);
    }

    #[test]
    fn ortho_error_of_identity_is_zero() {
        assert_eq!(Matrix::eye(8).ortho_error(), 0.0);
    }

    #[test]
    fn dot_matches_naive() {
        let x: Vec<f32> = (0..37).map(|i| i as f32 * 0.5).collect();
        let y: Vec<f32> = (0..37).map(|i| 1.0 - i as f32 * 0.1).collect();
        let naive: f32 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        assert!((dot(&x, &y) - naive).abs() < 1e-3);
    }
}
