//! Native AdamW — used by the true-shape 70B phase benchmark (Table 2's
//! "Optimizer Step" row runs the real update at the real factor shapes) and
//! as an independent oracle for the exported optimizer graph.

/// Decoupled-weight-decay Adam over a flat f32 tensor.
#[derive(Debug, Clone)]
pub struct AdamW {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    pub t: u64,
    m: Vec<f32>,
    v: Vec<f32>,
}

impl AdamW {
    pub fn new(n: usize, lr: f32) -> AdamW {
        AdamW {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 0.0,
            t: 0,
            m: vec![0.0; n],
            v: vec![0.0; n],
        }
    }

    /// One update step: `params -= lr * (m_hat / (sqrt(v_hat) + eps) + wd*p)`.
    pub fn step(&mut self, params: &mut [f32], grads: &[f32]) {
        assert_eq!(params.len(), grads.len());
        assert_eq!(params.len(), self.m.len());
        self.t += 1;
        let bc1 = 1.0 - self.beta1.powi(self.t as i32);
        let bc2 = 1.0 - self.beta2.powi(self.t as i32);
        let (b1, b2) = (self.beta1, self.beta2);
        for i in 0..params.len() {
            let g = grads[i];
            self.m[i] = b1 * self.m[i] + (1.0 - b1) * g;
            self.v[i] = b2 * self.v[i] + (1.0 - b2) * g * g;
            let m_hat = self.m[i] / bc1;
            let v_hat = self.v[i] / bc2;
            let mut upd = m_hat / (v_hat.sqrt() + self.eps);
            if self.weight_decay != 0.0 {
                upd += self.weight_decay * params[i];
            }
            params[i] -= self.lr * upd;
        }
    }

    /// Memory the optimizer state occupies (the 2x factor in the paper's
    /// "four copies" analysis).
    pub fn state_bytes(&self) -> usize {
        (self.m.len() + self.v.len()) * std::mem::size_of::<f32>()
    }

    /// The first/second moments — checkpoint serialization.
    pub fn moments(&self) -> (&[f32], &[f32]) {
        (&self.m, &self.v)
    }

    /// Restore serialized state (checkpoint resume). Lengths must match the
    /// optimizer's tensor.
    pub fn restore(&mut self, m: Vec<f32>, v: Vec<f32>, t: u64) {
        assert_eq!(m.len(), self.m.len(), "moment length mismatch");
        assert_eq!(v.len(), self.v.len(), "moment length mismatch");
        self.m = m;
        self.v = v;
        self.t = t;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_step_is_signed_lr() {
        // With bias correction, step 1 moves by ~lr*sign(g) regardless of |g|.
        let mut opt = AdamW::new(3, 0.01);
        let mut p = vec![1.0f32, -2.0, 0.5];
        opt.step(&mut p, &[0.3, -7.0, 1e-4]);
        assert!((p[0] - (1.0 - 0.01)).abs() < 1e-4);
        assert!((p[1] - (-2.0 + 0.01)).abs() < 1e-4);
        assert!((p[2] - (0.5 - 0.01)).abs() < 1e-3); // tiny grad still ~lr
    }

    #[test]
    fn converges_on_quadratic() {
        // minimize f(x) = 0.5*(x - 3)^2; grad = x - 3.
        let mut opt = AdamW::new(1, 0.1);
        let mut p = vec![0.0f32];
        for _ in 0..500 {
            let g = p[0] - 3.0;
            opt.step(&mut p, &[g]);
        }
        assert!((p[0] - 3.0).abs() < 0.05, "got {}", p[0]);
    }

    #[test]
    fn weight_decay_decoupled() {
        let mut opt = AdamW::new(1, 0.01);
        opt.weight_decay = 0.5;
        let mut p = vec![2.0f32];
        opt.step(&mut p, &[0.0]);
        // zero grad: only decay acts -> p -= lr*wd*p
        assert!((p[0] - (2.0 - 0.01 * 0.5 * 2.0)).abs() < 1e-6);
    }

    #[test]
    fn restore_resumes_identically() {
        let mut a = AdamW::new(3, 0.05);
        let mut pa = vec![1.0f32, -1.0, 0.5];
        for i in 0..7 {
            let g: Vec<f32> = (0..3).map(|j| ((i * 3 + j) as f32).cos()).collect();
            a.step(&mut pa, &g);
        }
        let (m, v) = a.moments();
        let mut b = AdamW::new(3, 0.05);
        b.restore(m.to_vec(), v.to_vec(), a.t);
        let mut pb = pa.clone();
        let g = [0.3f32, -0.2, 0.9];
        a.step(&mut pa, &g);
        b.step(&mut pb, &g);
        assert_eq!(pa, pb, "restored optimizer must continue bit-for-bit");
    }

    #[test]
    fn deterministic() {
        let mut a = AdamW::new(4, 0.01);
        let mut b = AdamW::new(4, 0.01);
        let mut pa = vec![1.0, 2.0, 3.0, 4.0];
        let mut pb = pa.clone();
        for i in 0..20 {
            let g: Vec<f32> = (0..4).map(|j| ((i * 4 + j) as f32).sin()).collect();
            a.step(&mut pa, &g);
            b.step(&mut pb, &g);
        }
        assert_eq!(pa, pb);
    }
}
