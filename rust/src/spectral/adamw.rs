//! Native AdamW — used by the true-shape 70B phase benchmark (Table 2's
//! "Optimizer Step" row runs the real update at the real factor shapes) and
//! as an independent oracle for the exported optimizer graph.
//!
//! The update is elementwise, so large tensors shard across the
//! `util::pool` workers in aligned chunks — every element is updated by
//! the same scalar kernel, making the parallel step bit-identical to the
//! serial one at any thread count.

use crate::obs::prof;
use crate::util::pool;

/// Elements below which the update stays serial (the elementwise kernel is
/// memory-bound; small tensors can't amortize the scoped spawn).
const PAR_MIN_ELEMS: usize = 1 << 16;

/// Per-step scalar coefficients, captured once so worker chunks share the
/// exact values the serial loop would use.
#[derive(Clone, Copy)]
struct StepCoeffs {
    b1: f32,
    b2: f32,
    bc1: f32,
    bc2: f32,
    eps: f32,
    lr: f32,
    wd: f32,
}

/// The serial update kernel over one aligned chunk of (params, grads, m, v).
fn update_chunk(p: &mut [f32], g: &[f32], m: &mut [f32], v: &mut [f32], c: StepCoeffs) {
    for i in 0..p.len() {
        let gi = g[i];
        m[i] = c.b1 * m[i] + (1.0 - c.b1) * gi;
        v[i] = c.b2 * v[i] + (1.0 - c.b2) * gi * gi;
        let m_hat = m[i] / c.bc1;
        let v_hat = v[i] / c.bc2;
        let mut upd = m_hat / (v_hat.sqrt() + c.eps);
        if c.wd != 0.0 {
            upd += c.wd * p[i];
        }
        p[i] -= c.lr * upd;
    }
}

/// Decoupled-weight-decay Adam over a flat f32 tensor.
#[derive(Debug, Clone)]
pub struct AdamW {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    pub t: u64,
    m: Vec<f32>,
    v: Vec<f32>,
}

impl AdamW {
    pub fn new(n: usize, lr: f32) -> AdamW {
        AdamW {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 0.0,
            t: 0,
            m: vec![0.0; n],
            v: vec![0.0; n],
        }
    }

    /// One update step: `params -= lr * (m_hat / (sqrt(v_hat) + eps) + wd*p)`.
    /// Large tensors shard across the worker pool (elementwise update —
    /// bit-identical to the serial loop at any thread count).
    pub fn step(&mut self, params: &mut [f32], grads: &[f32]) {
        assert_eq!(params.len(), grads.len());
        assert_eq!(params.len(), self.m.len());
        self.t += 1;
        // Work model: ~10 FLOPs/element (two EMAs, bias corrections,
        // sqrt + divide, decay + update) over 4 f32 streams read and
        // 3 written => 28 bytes/element. Memory-bound by design.
        let n_elems = params.len();
        let _prof = prof::kernel("adamw", || (10.0 * n_elems as f64, 28.0 * n_elems as f64));
        let c = StepCoeffs {
            b1: self.beta1,
            b2: self.beta2,
            bc1: 1.0 - self.beta1.powi(self.t as i32),
            bc2: 1.0 - self.beta2.powi(self.t as i32),
            eps: self.eps,
            lr: self.lr,
            wd: self.weight_decay,
        };
        let n = params.len();
        if n > 1 && pool::parallel_worthwhile(n, PAR_MIN_ELEMS) {
            let chunk = pool::chunk_len(n);
            let (m, v) = (&mut self.m, &mut self.v);
            let prof_ctx = prof::fork_ctx();
            std::thread::scope(|s| {
                for (((p, g), mm), vv) in params
                    .chunks_mut(chunk)
                    .zip(grads.chunks(chunk))
                    .zip(m.chunks_mut(chunk))
                    .zip(v.chunks_mut(chunk))
                {
                    let prof_ctx = &prof_ctx;
                    s.spawn(move || {
                        let _prof = prof::attach(prof_ctx);
                        update_chunk(p, g, mm, vv, c)
                    });
                }
            });
        } else {
            update_chunk(params, grads, &mut self.m, &mut self.v, c);
        }
    }

    /// Memory the optimizer state occupies (the 2x factor in the paper's
    /// "four copies" analysis).
    pub fn state_bytes(&self) -> usize {
        (self.m.len() + self.v.len()) * std::mem::size_of::<f32>()
    }

    /// The first/second moments — checkpoint serialization.
    pub fn moments(&self) -> (&[f32], &[f32]) {
        (&self.m, &self.v)
    }

    /// Restore serialized state (checkpoint resume). Lengths must match the
    /// optimizer's tensor.
    pub fn restore(&mut self, m: Vec<f32>, v: Vec<f32>, t: u64) {
        assert_eq!(m.len(), self.m.len(), "moment length mismatch");
        assert_eq!(v.len(), self.v.len(), "moment length mismatch");
        self.m = m;
        self.v = v;
        self.t = t;
    }

    // -- rank-transition support (the `rank` subsystem) ---------------------
    //
    // A spectral factor is a row-major (rows x cols) tensor whose column
    // count is the rank k; when the rank changes mid-run the moment tensors
    // must be resized the same way the parameter was, or every subsequent
    // update would be misaligned. The singular-value vector s is the
    // (1 x k) case of the same layout. `t` is deliberately kept: bias
    // correction stays shared per-tensor, so fresh columns (zero moments)
    // get full-strength first updates — exactly what new capacity wants.

    /// Grow the moments of a row-major `(rows x old_cols)` tensor to
    /// `new_cols` columns: surviving entries keep their position within
    /// each row, appended columns start with zero moments.
    pub fn grow_cols(&mut self, rows: usize, old_cols: usize, new_cols: usize) {
        assert_eq!(self.m.len(), rows * old_cols, "moment shape mismatch");
        assert!(new_cols >= old_cols, "grow_cols cannot shrink ({old_cols} -> {new_cols})");
        let resize = |buf: &[f32]| -> Vec<f32> {
            let mut out = vec![0.0f32; rows * new_cols];
            for r in 0..rows {
                out[r * new_cols..r * new_cols + old_cols]
                    .copy_from_slice(&buf[r * old_cols..(r + 1) * old_cols]);
            }
            out
        };
        self.m = resize(&self.m);
        self.v = resize(&self.v);
    }

    /// Keep only the columns in `keep` (ascending indices into the old
    /// layout) of a row-major `(rows x old_cols)` tensor's moments — the
    /// shrink twin of [`AdamW::grow_cols`], matching
    /// `rank::resize::RankResize::Shrunk`'s kept-column set.
    pub fn select_cols(&mut self, rows: usize, old_cols: usize, keep: &[usize]) {
        assert_eq!(self.m.len(), rows * old_cols, "moment shape mismatch");
        assert!(keep.iter().all(|&j| j < old_cols), "kept column out of range");
        let resize = |buf: &[f32]| -> Vec<f32> {
            let mut out = Vec::with_capacity(rows * keep.len());
            for r in 0..rows {
                let row = &buf[r * old_cols..(r + 1) * old_cols];
                out.extend(keep.iter().map(|&j| row[j]));
            }
            out
        };
        self.m = resize(&self.m);
        self.v = resize(&self.v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_step_is_signed_lr() {
        // With bias correction, step 1 moves by ~lr*sign(g) regardless of |g|.
        let mut opt = AdamW::new(3, 0.01);
        let mut p = vec![1.0f32, -2.0, 0.5];
        opt.step(&mut p, &[0.3, -7.0, 1e-4]);
        assert!((p[0] - (1.0 - 0.01)).abs() < 1e-4);
        assert!((p[1] - (-2.0 + 0.01)).abs() < 1e-4);
        assert!((p[2] - (0.5 - 0.01)).abs() < 1e-3); // tiny grad still ~lr
    }

    #[test]
    fn converges_on_quadratic() {
        // minimize f(x) = 0.5*(x - 3)^2; grad = x - 3.
        let mut opt = AdamW::new(1, 0.1);
        let mut p = vec![0.0f32];
        for _ in 0..500 {
            let g = p[0] - 3.0;
            opt.step(&mut p, &[g]);
        }
        assert!((p[0] - 3.0).abs() < 0.05, "got {}", p[0]);
    }

    #[test]
    fn weight_decay_decoupled() {
        let mut opt = AdamW::new(1, 0.01);
        opt.weight_decay = 0.5;
        let mut p = vec![2.0f32];
        opt.step(&mut p, &[0.0]);
        // zero grad: only decay acts -> p -= lr*wd*p
        assert!((p[0] - (2.0 - 0.01 * 0.5 * 2.0)).abs() < 1e-6);
    }

    #[test]
    fn restore_resumes_identically() {
        let mut a = AdamW::new(3, 0.05);
        let mut pa = vec![1.0f32, -1.0, 0.5];
        for i in 0..7 {
            let g: Vec<f32> = (0..3).map(|j| ((i * 3 + j) as f32).cos()).collect();
            a.step(&mut pa, &g);
        }
        let (m, v) = a.moments();
        let mut b = AdamW::new(3, 0.05);
        b.restore(m.to_vec(), v.to_vec(), a.t);
        let mut pb = pa.clone();
        let g = [0.3f32, -0.2, 0.9];
        a.step(&mut pa, &g);
        b.step(&mut pb, &g);
        assert_eq!(pa, pb, "restored optimizer must continue bit-for-bit");
    }

    #[test]
    fn grow_cols_keeps_old_moments_in_place() {
        // 2 x 2 tensor -> 2 x 4: each row's moments stay aligned with its
        // surviving entries; new columns start cold.
        let mut opt = AdamW::new(4, 0.1);
        let mut p = vec![1.0f32, 2.0, 3.0, 4.0];
        opt.step(&mut p, &[0.1, 0.2, 0.3, 0.4]);
        let (m0, v0) = (opt.moments().0.to_vec(), opt.moments().1.to_vec());
        opt.grow_cols(2, 2, 4);
        let (m, v) = opt.moments();
        assert_eq!(m.len(), 8);
        assert_eq!(&[m[0], m[1]], &[m0[0], m0[1]]);
        assert_eq!(&[m[4], m[5]], &[m0[2], m0[3]]);
        assert_eq!(&[m[2], m[3], m[6], m[7]], &[0.0, 0.0, 0.0, 0.0]);
        assert_eq!(&[v[0], v[1], v[4], v[5]], &[v0[0], v0[1], v0[2], v0[3]]);
        // the grown optimizer steps a matching parameter tensor
        let mut p2 = vec![1.0f32; 8];
        opt.step(&mut p2, &[0.0; 8]);
    }

    #[test]
    fn select_cols_matches_a_shrunk_tensor() {
        // 3 x 4 tensor, keep columns 0 and 2 of every row.
        let mut opt = AdamW::new(12, 0.1);
        let g: Vec<f32> = (0..12).map(|i| i as f32 * 0.1).collect();
        let mut p = vec![0.0f32; 12];
        opt.step(&mut p, &g);
        let m0 = opt.moments().0.to_vec();
        opt.select_cols(3, 4, &[0, 2]);
        let (m, v) = opt.moments();
        assert_eq!(m.len(), 6);
        for r in 0..3 {
            assert_eq!(m[r * 2], m0[r * 4]);
            assert_eq!(m[r * 2 + 1], m0[r * 4 + 2]);
        }
        assert_eq!(v.len(), 6);
    }

    #[test]
    fn grown_column_update_matches_a_fresh_tensor_at_same_t() {
        // After growing, an update on a new column must equal what a fresh
        // optimizer fast-forwarded to the same t would do: zero moments +
        // shared bias correction.
        let mut grown = AdamW::new(2, 0.05);
        let mut pg = vec![1.0f32, -1.0];
        for i in 0..5 {
            grown.step(&mut pg, &[0.3 * i as f32, -0.1]);
        }
        grown.grow_cols(1, 2, 3);
        let mut fresh = AdamW::new(3, 0.05);
        fresh.restore(vec![0.0; 3], vec![0.0; 3], grown.t);
        let mut pf = vec![9.0f32, 9.0, 5.0];
        let mut pg2 = vec![9.0f32, 9.0, 5.0];
        let g = [0.0f32, 0.0, 0.7];
        grown.step(&mut pg2, &g);
        fresh.step(&mut pf, &g);
        assert_eq!(pg2[2], pf[2], "new-column update must match a cold tensor at the same t");
    }

    #[test]
    fn deterministic() {
        let mut a = AdamW::new(4, 0.01);
        let mut b = AdamW::new(4, 0.01);
        let mut pa = vec![1.0, 2.0, 3.0, 4.0];
        let mut pb = pa.clone();
        for i in 0..20 {
            let g: Vec<f32> = (0..4).map(|j| ((i * 4 + j) as f32).sin()).collect();
            a.step(&mut pa, &g);
            b.step(&mut pb, &g);
        }
        assert_eq!(pa, pb);
    }
}
