//! Truncated SVD via one-sided Jacobi — the dense->spectral conversion path.
//!
//! The paper converts pretrained dense MLP weights to spectral form by
//! truncated SVD (§4.2), at a 95% energy threshold in the fine-tune
//! experiment (§4.4). The runtime has no LAPACK (the xla_extension rejects
//! LAPACK custom calls and we are offline), so this is a from-scratch
//! one-sided Jacobi SVD: numerically robust, embarrassingly simple, and fast
//! enough for the layer sizes the fine-tune driver converts (<= ~512x2048).

use super::matrix::Matrix;
use super::qr::qr_retract;
use crate::util::rng::Rng;

/// Result of a (possibly truncated) SVD: `A ≈ U diag(s) V^T` with
/// orthonormal `U` (m x k), `V` (n x k), and `s` sorted descending.
#[derive(Debug, Clone)]
pub struct Svd {
    pub u: Matrix,
    pub s: Vec<f32>,
    pub v: Matrix,
}

impl Svd {
    pub fn reconstruct(&self) -> Matrix {
        let k = self.s.len();
        let mut us = self.u.clone();
        for j in 0..k {
            us.scale_col(j, self.s[j]);
        }
        us.matmul_t(&self.v)
    }

    /// Total spectral energy sum(s^2).
    pub fn energy(&self) -> f32 {
        self.s.iter().map(|x| x * x).sum()
    }

    /// Smallest k capturing `threshold` of the energy (paper §4.4: 0.95).
    pub fn energy_rank(&self, threshold: f32) -> usize {
        let total = self.energy();
        if total <= 0.0 {
            return 1;
        }
        let mut acc = 0.0;
        for (i, s) in self.s.iter().enumerate() {
            acc += s * s;
            if acc >= threshold * total {
                return i + 1;
            }
        }
        self.s.len()
    }

    /// Truncate to rank k (keeping the largest singular values).
    pub fn truncate(&self, k: usize) -> Svd {
        let k = k.min(self.s.len());
        let mut u = Matrix::zeros(self.u.rows, k);
        let mut v = Matrix::zeros(self.v.rows, k);
        for j in 0..k {
            for r in 0..u.rows {
                u[(r, j)] = self.u[(r, j)];
            }
            for r in 0..v.rows {
                v[(r, j)] = self.v[(r, j)];
            }
        }
        Svd { u, s: self.s[..k].to_vec(), v }
    }

    /// Zero-pad to rank k > current, orthonormally completing U and V so the
    /// reconstruction is unchanged — how an energy-rank conversion feeds a
    /// fixed-k artifact (mirrors python `spectral.pad_rank`).
    pub fn pad_to(&self, k: usize, rng: &mut Rng) -> Svd {
        let r = self.s.len();
        if k <= r {
            return self.truncate(k);
        }
        let complete = |q: &Matrix, rng: &mut Rng| -> Matrix {
            let extra = k - r;
            let mut g = Matrix::randn(rng, q.rows, extra, 1.0);
            // project off existing basis, twice (CGS2)
            for _ in 0..2 {
                let c = q.t_matmul(&g); // r x extra
                let qc = q.matmul(&c);
                for i in 0..g.data.len() {
                    g.data[i] -= qc.data[i];
                }
            }
            let gq = qr_retract(&g);
            let mut out = Matrix::zeros(q.rows, k);
            for j in 0..r {
                for row in 0..q.rows {
                    out[(row, j)] = q[(row, j)];
                }
            }
            for j in 0..extra {
                for row in 0..q.rows {
                    out[(row, r + j)] = gq[(row, j)];
                }
            }
            out
        };
        let mut s = self.s.clone();
        s.resize(k, 0.0);
        Svd { u: complete(&self.u, rng), s, v: complete(&self.v, rng) }
    }
}

/// Full (thin) SVD of `a` via one-sided Jacobi on the side with fewer
/// columns. Singular values sorted descending; signs fixed so the first
/// nonzero entry of each U column is positive (determinism for tests).
pub fn svd(a: &Matrix) -> Svd {
    // One-sided Jacobi orthogonalizes the columns of W; work on the
    // orientation with fewer columns for O(min(m,n)^2 max(m,n)) sweeps.
    if a.cols <= a.rows {
        svd_tall(a)
    } else {
        // A = U S V^T  =>  A^T = V S U^T.
        let t = svd_tall(&a.transpose());
        Svd { u: t.v, s: t.s, v: t.u }
    }
}

/// Truncated SVD: thin SVD then keep the top k triples.
pub fn svd_truncated(a: &Matrix, k: usize) -> Svd {
    svd(a).truncate(k)
}

fn svd_tall(a: &Matrix) -> Svd {
    let (m, n) = (a.rows, a.cols);
    debug_assert!(n <= m);
    // Work on B = A (m x n), rotating columns until pairwise orthogonal.
    let mut b = a.clone();
    // Column-major access pattern: keep B as column vectors.
    let mut cols: Vec<Vec<f32>> = (0..n).map(|j| b.col(j)).collect();

    let max_sweeps = 60;
    let eps = 1e-10f64;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                let (cp, cq) = pair_mut(&mut cols, p, q);
                let app: f64 = cp.iter().map(|x| (*x as f64) * (*x as f64)).sum();
                let aqq: f64 = cq.iter().map(|x| (*x as f64) * (*x as f64)).sum();
                let apq: f64 = cp.iter().zip(cq.iter()).map(|(x, y)| *x as f64 * *y as f64).sum();
                if apq.abs() <= eps * (app * aqq).sqrt() {
                    continue;
                }
                off += apq.abs();
                // Jacobi rotation zeroing the (p,q) off-diagonal of B^T B.
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..m {
                    let bp = cp[i] as f64;
                    let bq = cq[i] as f64;
                    cp[i] = (c * bp - s * bq) as f32;
                    cq[i] = (s * bp + c * bq) as f32;
                }
            }
        }
        if off < 1e-12 {
            break;
        }
    }
    for (j, cj) in cols.iter().enumerate() {
        for (i, &v) in cj.iter().enumerate() {
            b[(i, j)] = v;
        }
    }

    // Singular values are the column norms; U = normalized columns.
    let mut order: Vec<usize> = (0..n).collect();
    let norms: Vec<f32> = cols.iter().map(|c| c.iter().map(|x| x * x).sum::<f32>().sqrt()).collect();
    order.sort_by(|&i, &j| norms[j].partial_cmp(&norms[i]).unwrap());

    let mut u = Matrix::zeros(m, n);
    let mut s = vec![0.0f32; n];
    for (jj, &src) in order.iter().enumerate() {
        s[jj] = norms[src];
        let inv = if norms[src] > 1e-30 { 1.0 / norms[src] } else { 0.0 };
        for i in 0..m {
            u[(i, jj)] = cols[src][i] * inv;
        }
    }
    // V from the rotations is implicit; recover it as V = A^T U diag(1/s)
    // (exact since A = U S V^T and U has orthonormal columns).
    let mut v = a.t_matmul(&u); // n x n = A^T U
    for j in 0..n {
        let inv = if s[j] > 1e-30 { 1.0 / s[j] } else { 0.0 };
        v.scale_col(j, inv);
    }
    // Deterministic signs: first significant entry of each U column >= 0.
    for j in 0..n {
        let mut lead = 0.0f32;
        for i in 0..m {
            if u[(i, j)].abs() > 1e-6 {
                lead = u[(i, j)];
                break;
            }
        }
        if lead < 0.0 {
            u.scale_col(j, -1.0);
            v.scale_col(j, -1.0);
        }
    }
    Svd { u, s, v }
}

fn pair_mut<T>(v: &mut [Vec<T>], p: usize, q: usize) -> (&mut Vec<T>, &mut Vec<T>) {
    debug_assert!(p < q);
    let (lo, hi) = v.split_at_mut(q);
    (&mut lo[p], &mut hi[0])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rand_matrix(seed: u64, m: usize, n: usize) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::randn(&mut rng, m, n, 1.0)
    }

    #[test]
    fn reconstructs_full_rank() {
        for &(m, n) in &[(8, 5), (5, 8), (12, 12)] {
            let a = rand_matrix(0, m, n);
            let d = svd(&a);
            let err = d.reconstruct().max_abs_diff(&a);
            assert!(err < 1e-4, "{m}x{n}: recon err {err}");
            assert!(d.u.ortho_error() < 1e-5);
            assert!(d.v.ortho_error() < 1e-5);
        }
    }

    #[test]
    fn singular_values_sorted_descending_nonnegative() {
        let d = svd(&rand_matrix(1, 20, 10));
        for w in d.s.windows(2) {
            assert!(w[0] >= w[1]);
        }
        assert!(d.s.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn known_rank_one() {
        // A = 3 * u v^T with unit u, v -> single singular value 3.
        let m = 6;
        let n = 4;
        let mut a = Matrix::zeros(m, n);
        let u: Vec<f32> = (0..m).map(|i| ((i + 1) as f32).sin()).collect();
        let un = (u.iter().map(|x| x * x).sum::<f32>()).sqrt();
        let v: Vec<f32> = (0..n).map(|i| ((i + 2) as f32).cos()).collect();
        let vn = (v.iter().map(|x| x * x).sum::<f32>()).sqrt();
        for i in 0..m {
            for j in 0..n {
                a[(i, j)] = 3.0 * (u[i] / un) * (v[j] / vn);
            }
        }
        let d = svd(&a);
        assert!((d.s[0] - 3.0).abs() < 1e-4, "s0={}", d.s[0]);
        assert!(d.s[1..].iter().all(|&x| x < 1e-4));
    }

    #[test]
    fn truncation_error_decreases_with_rank() {
        // Eckart-Young is a Frobenius-norm statement (not element-wise max).
        let a = rand_matrix(2, 24, 16);
        let full = svd(&a);
        let mut errs = Vec::new();
        for k in [1, 2, 4, 8, 16] {
            let mut diff = full.truncate(k).reconstruct();
            for (d, x) in diff.data.iter_mut().zip(&a.data) {
                *d -= x;
            }
            errs.push((diff.frob_norm(), k));
        }
        for w in errs.windows(2) {
            assert!(w[0].0 >= w[1].0 - 1e-5, "err(k={}) < err(k={})", w[0].1, w[1].1);
        }
    }

    #[test]
    fn energy_rank_behaviour() {
        let a = rand_matrix(3, 16, 16);
        let d = svd(&a);
        let r50 = d.energy_rank(0.5);
        let r95 = d.energy_rank(0.95);
        assert!(1 <= r50 && r50 <= r95 && r95 <= 16);
        // an exactly rank-2 matrix needs 2 at 99.99%
        let lowrank = d.truncate(2).reconstruct();
        let d2 = svd(&lowrank);
        assert_eq!(d2.energy_rank(0.9999), 2);
    }

    #[test]
    fn pad_to_preserves_reconstruction_and_ortho() {
        let a = rand_matrix(4, 20, 12);
        let d = svd_truncated(&a, 4);
        let w = d.reconstruct();
        let mut rng = Rng::new(9);
        let padded = d.pad_to(9, &mut rng);
        assert_eq!(padded.s.len(), 9);
        assert!(padded.reconstruct().max_abs_diff(&w) < 1e-4);
        assert!(padded.u.ortho_error() < 1e-5);
        assert!(padded.v.ortho_error() < 1e-5);
        assert!(padded.s[4..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn wide_matrix_via_transpose() {
        let a = rand_matrix(5, 6, 18);
        let d = svd(&a);
        assert_eq!(d.u.rows, 6);
        assert_eq!(d.v.rows, 18);
        assert!(d.reconstruct().max_abs_diff(&a) < 1e-4);
    }
}
