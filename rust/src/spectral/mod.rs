//! Pure-Rust spectral linear algebra substrate.
//!
//! Everything SCT needs, implemented from scratch (the runtime has no BLAS /
//! LAPACK and the image is offline): dense row-major matrices, CGS2 +
//! Householder QR, one-sided Jacobi truncated SVD, AdamW, and a native
//! SpectralLinear layer with manual backprop through the factors.
//!
//! Roles in the reproduction:
//! * Table 2's phase timings (forward/backward/optimizer/retraction) are
//!   measured here at the paper's REAL 70B factor shapes — possible on this
//!   machine only because the factors are k(m+n+1) floats.
//! * The fine-tune driver's dense->spectral conversion (95% energy, §4.4)
//!   runs [`svd::svd_truncated`] + [`svd::Svd::pad_to`].
//! * Property tests cross-check the Pallas/JAX kernels' algorithm.

pub mod adamw;
pub mod layer;
pub mod matrix;
pub mod microkernel;
pub mod qr;
pub mod svd;

pub use adamw::AdamW;
pub use layer::{LayerTrainer, SpectralGrads, SpectralLinear};
pub use matrix::Matrix;
pub use qr::{polar_retract, qr_householder, qr_retract, qr_retract_parallel, qr_retract_serial};
pub use svd::{svd, svd_truncated, Svd};
