//! Per-sequence KV cache arena with slot reuse.
//!
//! The serving engine decodes incrementally — one token per step — so every
//! active sequence needs its attention keys/values from previous positions.
//! This arena preallocates `slots` fixed-capacity cache lines (one per
//! concurrent sequence) in two flat buffers and recycles them: when a
//! sequence finishes, its slot returns to the free list and the next admitted
//! request reuses the same memory with its length reset. No allocation
//! happens on the decode path.
//!
//! Layout: `k`/`v` are `[slot][layer][pos][d_model]` row-major, so one
//! layer's cached rows for one sequence are a single contiguous slice — the
//! shape the per-head attention loop streams over.

/// Identifier of one cache line (index into the arena).
pub type SlotId = usize;

/// Fixed-capacity KV arena for `slots` concurrent sequences.
pub struct KvCache {
    pub slots: usize,
    pub n_layers: usize,
    pub max_seq: usize,
    pub d_model: usize,
    k: Vec<f32>,
    v: Vec<f32>,
    lens: Vec<usize>,
    free: Vec<SlotId>,
}

impl KvCache {
    pub fn new(slots: usize, n_layers: usize, max_seq: usize, d_model: usize) -> KvCache {
        assert!(slots > 0 && n_layers > 0 && max_seq > 0 && d_model > 0);
        let total = slots * n_layers * max_seq * d_model;
        KvCache {
            slots,
            n_layers,
            max_seq,
            d_model,
            k: vec![0.0; total],
            v: vec![0.0; total],
            lens: vec![0; slots],
            // pop() takes from the back; reverse so slot 0 is handed out first.
            free: (0..slots).rev().collect(),
        }
    }

    /// Claim a free slot with length reset to 0; `None` when the arena is full.
    pub fn alloc(&mut self) -> Option<SlotId> {
        let slot = self.free.pop()?;
        self.lens[slot] = 0;
        Some(slot)
    }

    /// Return a slot to the free list (its contents become garbage).
    pub fn release(&mut self, slot: SlotId) {
        debug_assert!(!self.free.contains(&slot), "double release of slot {slot}");
        self.lens[slot] = 0;
        self.free.push(slot);
    }

    /// Cached length (number of positions written) of a slot.
    pub fn len(&self, slot: SlotId) -> usize {
        self.lens[slot]
    }

    pub fn is_empty(&self, slot: SlotId) -> bool {
        self.lens[slot] == 0
    }

    /// Positions still free in a slot's cache line — the scheduler evicts a
    /// sequence when this hits 0 (its context window is exhausted).
    pub fn remaining(&self, slot: SlotId) -> usize {
        self.max_seq - self.lens[slot]
    }

    /// Slots currently checked out.
    pub fn in_use(&self) -> usize {
        self.slots - self.free.len()
    }

    /// Arena footprint in bytes (the serving analogue of `state_bytes`).
    pub fn bytes(&self) -> usize {
        2 * self.k.len() * std::mem::size_of::<f32>()
    }

    #[inline]
    fn base(&self, slot: SlotId, layer: usize) -> usize {
        debug_assert!(slot < self.slots && layer < self.n_layers);
        (slot * self.n_layers + layer) * self.max_seq * self.d_model
    }

    /// Write the K/V rows for one position of one layer. Positions must be
    /// written in order; the engine advances the slot length only after all
    /// layers of a step are written (see [`KvCache::advance`]).
    pub fn write(&mut self, slot: SlotId, layer: usize, pos: usize, krow: &[f32], vrow: &[f32]) {
        assert!(pos < self.max_seq, "position {pos} beyond cache capacity {}", self.max_seq);
        debug_assert_eq!(krow.len(), self.d_model);
        debug_assert_eq!(vrow.len(), self.d_model);
        let at = self.base(slot, layer) + pos * self.d_model;
        self.k[at..at + self.d_model].copy_from_slice(krow);
        self.v[at..at + self.d_model].copy_from_slice(vrow);
    }

    /// First `n` cached K rows of one layer as one contiguous slice.
    pub fn k_rows(&self, slot: SlotId, layer: usize, n: usize) -> &[f32] {
        let at = self.base(slot, layer);
        &self.k[at..at + n * self.d_model]
    }

    /// First `n` cached V rows of one layer as one contiguous slice.
    pub fn v_rows(&self, slot: SlotId, layer: usize, n: usize) -> &[f32] {
        let at = self.base(slot, layer);
        &self.v[at..at + n * self.d_model]
    }

    /// Bump a slot's length after a full decode step wrote all its layers.
    pub fn advance(&mut self, slot: SlotId) {
        debug_assert!(self.lens[slot] < self.max_seq);
        self.lens[slot] += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_release_recycles_with_reset_len() {
        let mut kv = KvCache::new(2, 1, 4, 3);
        let a = kv.alloc().unwrap();
        let b = kv.alloc().unwrap();
        assert_ne!(a, b);
        assert!(kv.alloc().is_none(), "arena exhausted");
        kv.write(a, 0, 0, &[1.0; 3], &[2.0; 3]);
        kv.advance(a);
        assert_eq!(kv.len(a), 1);
        kv.release(a);
        let c = kv.alloc().unwrap();
        assert_eq!(c, a, "freed slot is reused");
        assert_eq!(kv.len(c), 0, "recycled slot starts empty");
        assert_eq!(kv.in_use(), 2);
    }

    #[test]
    fn slots_are_isolated() {
        let mut kv = KvCache::new(2, 2, 4, 2);
        let a = kv.alloc().unwrap();
        let b = kv.alloc().unwrap();
        kv.write(a, 0, 0, &[1.0, 2.0], &[3.0, 4.0]);
        kv.write(b, 0, 0, &[9.0, 9.0], &[9.0, 9.0]);
        kv.write(a, 1, 0, &[5.0, 6.0], &[7.0, 8.0]);
        kv.advance(a);
        kv.advance(b);
        assert_eq!(kv.k_rows(a, 0, 1), &[1.0, 2.0]);
        assert_eq!(kv.v_rows(a, 1, 1), &[7.0, 8.0]);
        assert_eq!(kv.k_rows(b, 0, 1), &[9.0, 9.0]);
    }

    #[test]
    fn rows_accumulate_in_position_order() {
        let mut kv = KvCache::new(1, 1, 3, 2);
        let s = kv.alloc().unwrap();
        for pos in 0..3 {
            let x = pos as f32;
            kv.write(s, 0, pos, &[x, x], &[-x, -x]);
            kv.advance(s);
        }
        assert_eq!(kv.len(s), 3);
        assert_eq!(kv.k_rows(s, 0, 3), &[0.0, 0.0, 1.0, 1.0, 2.0, 2.0]);
        assert_eq!(kv.v_rows(s, 0, 2), &[0.0, 0.0, -1.0, -1.0]);
    }

    #[test]
    #[should_panic(expected = "beyond cache capacity")]
    fn write_past_capacity_panics() {
        let mut kv = KvCache::new(1, 1, 2, 2);
        let s = kv.alloc().unwrap();
        kv.write(s, 0, 2, &[0.0; 2], &[0.0; 2]);
    }

    #[test]
    fn remaining_counts_down_to_zero() {
        let mut kv = KvCache::new(1, 1, 3, 2);
        let s = kv.alloc().unwrap();
        assert_eq!(kv.remaining(s), 3);
        for pos in 0..3 {
            kv.write(s, 0, pos, &[0.0; 2], &[0.0; 2]);
            kv.advance(s);
        }
        assert_eq!(kv.remaining(s), 0);
        kv.release(s);
        let s = kv.alloc().unwrap();
        assert_eq!(kv.remaining(s), 3, "recycled slot has full capacity again");
    }

    #[test]
    fn bytes_accounting() {
        let kv = KvCache::new(4, 2, 8, 16);
        assert_eq!(kv.bytes(), 2 * 4 * 2 * 8 * 16 * 4);
    }
}
