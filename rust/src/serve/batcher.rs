//! Continuous-batching scheduler: bounded admission queue, slot-based
//! admission, batched decode, eviction of finished sequences.
//!
//! One scheduler thread owns the [`Engine`] and the [`KvCache`] arena.
//! Clients submit [`Request`]s through a bounded `sync_channel` (the same
//! backpressure idiom as `data::loader` — a full queue blocks the submitter
//! instead of buffering unboundedly). The scheduler loop:
//!
//! 1. **admit** — while free slots exist, pull queued requests (blocking
//!    when idle, opportunistic `try_recv` otherwise), claim a KV slot, and
//!    prefill the prompt;
//! 2. **batch** — decode ONE token for every active sequence in a single
//!    [`Engine::step_batch`] call, so all sequences share the weight-matrix
//!    traffic of the projections and the logits head;
//! 3. **evict** — sequences that hit their token budget or fill their KV
//!    line release the slot (recycled by the next admission) and their
//!    [`Completion`] is delivered on the per-request channel.
//!
//! Sequences join and leave the batch at token granularity — a long request
//! never blocks a short one behind it (continuous batching), though a
//! prompt's prefill currently runs inline in the admission step (chunked
//! prefill is a ROADMAP item).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::engine::{sample_logits, Engine, SampleOpts};
use super::kv::SlotId;
use crate::util::rng::Rng;

/// One generation request (token ids in, token ids out).
#[derive(Debug, Clone)]
pub struct Request {
    pub prompt: Vec<i32>,
    pub max_new: usize,
    pub opts: SampleOpts,
}

/// Result of a finished request, with queue/decode timing for the latency
/// accounting the throughput bench reports.
#[derive(Debug, Clone)]
pub struct Completion {
    pub tokens: Vec<i32>,
    pub prompt_len: usize,
    /// Time spent waiting for a slot (admission latency).
    pub queue_ms: f64,
    /// Prefill + decode wall time.
    pub decode_ms: f64,
}

/// Shared scheduler counters (read via [`Batcher::stats`]).
#[derive(Debug, Default)]
pub struct BatchStats {
    pub admitted: AtomicU64,
    pub completed: AtomicU64,
    pub tokens_out: AtomicU64,
    pub peak_active: AtomicU64,
}

impl BatchStats {
    pub fn snapshot(&self) -> (u64, u64, u64, u64) {
        (
            self.admitted.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.tokens_out.load(Ordering::Relaxed),
            self.peak_active.load(Ordering::Relaxed),
        )
    }
}

struct Job {
    req: Request,
    done: SyncSender<Completion>,
    enqueued: Instant,
}

/// An admitted sequence holding a KV slot.
struct ActiveSeq {
    slot: SlotId,
    cur: i32,
    produced: Vec<i32>,
    max_new: usize,
    rng: Rng,
    opts: SampleOpts,
    prompt_len: usize,
    done: SyncSender<Completion>,
    queue_ms: f64,
    admitted_at: Instant,
}

/// Handle to the scheduler thread. Dropping it closes the queue and joins
/// the thread after in-flight sequences finish.
pub struct Batcher {
    // Mutex<Option<..>> rather than a bare SyncSender so `&Batcher` can be
    // shared across connection-handler threads on any rustc the image ships
    // (SyncSender: Sync is a recent guarantee); submitters clone the sender
    // out and send OUTSIDE the lock so backpressure never holds the mutex.
    tx: Mutex<Option<SyncSender<Job>>>,
    handle: Option<JoinHandle<()>>,
    stats: Arc<BatchStats>,
    pub slots: usize,
    pub queue_depth: usize,
}

impl Batcher {
    /// Spawn the scheduler with `slots` concurrent sequences and a bounded
    /// queue of `queue_depth` waiting requests.
    pub fn spawn(engine: Engine, slots: usize, queue_depth: usize) -> Batcher {
        assert!(slots > 0, "need at least one decode slot");
        let (tx, rx) = mpsc::sync_channel::<Job>(queue_depth.max(1));
        let stats = Arc::new(BatchStats::default());
        let stats_worker = stats.clone();
        let handle = std::thread::Builder::new()
            .name("sct-batcher".into())
            .spawn(move || scheduler_loop(engine, slots, rx, stats_worker))
            .expect("spawn batcher thread");
        Batcher { tx: Mutex::new(Some(tx)), handle: Some(handle), stats, slots, queue_depth }
    }

    /// Enqueue a request; blocks when the admission queue is full
    /// (backpressure). Returns the channel the completion arrives on.
    pub fn submit(&self, req: Request) -> Result<Receiver<Completion>> {
        let tx = self
            .tx
            .lock()
            .unwrap()
            .as_ref()
            .cloned()
            .ok_or_else(|| anyhow!("batcher is shut down"))?;
        let (done, done_rx) = mpsc::sync_channel(1);
        tx.send(Job { req, done, enqueued: Instant::now() })
            .map_err(|_| anyhow!("batcher thread died"))?;
        Ok(done_rx)
    }

    /// Non-blocking submit: errors immediately when the queue is full
    /// instead of applying backpressure (load-shedding for the server).
    pub fn try_submit(&self, req: Request) -> Result<Receiver<Completion>> {
        let tx = self
            .tx
            .lock()
            .unwrap()
            .as_ref()
            .cloned()
            .ok_or_else(|| anyhow!("batcher is shut down"))?;
        let (done, done_rx) = mpsc::sync_channel(1);
        match tx.try_send(Job { req, done, enqueued: Instant::now() }) {
            Ok(()) => Ok(done_rx),
            Err(TrySendError::Full(_)) => Err(anyhow!("admission queue full")),
            Err(TrySendError::Disconnected(_)) => Err(anyhow!("batcher thread died")),
        }
    }

    /// Submit and block until the completion arrives.
    pub fn generate(&self, req: Request) -> Result<Completion> {
        let rx = self.submit(req)?;
        rx.recv().map_err(|_| anyhow!("batcher dropped the request"))
    }

    pub fn stats(&self) -> &BatchStats {
        &self.stats
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        // Close the queue first so the scheduler drains and exits, then join.
        drop(self.tx.lock().unwrap().take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn scheduler_loop(engine: Engine, slots: usize, rx: Receiver<Job>, stats: Arc<BatchStats>) {
    let cfg = *engine.cfg();
    let mut kv = engine.new_kv(slots);
    let mut active: Vec<ActiveSeq> = Vec::with_capacity(slots);
    loop {
        // -- admit -----------------------------------------------------------
        while active.len() < slots {
            let job = if active.is_empty() {
                // idle: block for work; a closed queue means shutdown
                match rx.recv() {
                    Ok(j) => j,
                    Err(_) => return,
                }
            } else {
                // decoding: only take what is already waiting
                match rx.try_recv() {
                    Ok(j) => j,
                    Err(_) => break,
                }
            };
            let queue_ms = job.enqueued.elapsed().as_secs_f64() * 1e3;
            let slot = kv.alloc().expect("active < slots implies a free slot");
            let admitted_at = Instant::now();

            // budget the context window: cap the generation length, keep the
            // prompt tail that fits in front of it (absolute RoPE positions,
            // so a long prompt is truncated, not slid).
            let max_new = job.req.max_new.clamp(1, cfg.max_seq - 1);
            let keep = (cfg.max_seq - max_new).max(1);
            let prompt: &[i32] = if job.req.prompt.is_empty() {
                &[0] // BOS-less model: decode from token 0
            } else if job.req.prompt.len() > keep {
                &job.req.prompt[job.req.prompt.len() - keep..]
            } else {
                &job.req.prompt
            };

            // prefill all but the last prompt token (no logits computed)
            engine.prefill(&prompt[..prompt.len() - 1], slot, &mut kv);
            active.push(ActiveSeq {
                slot,
                cur: prompt[prompt.len() - 1],
                produced: Vec::with_capacity(max_new),
                max_new,
                rng: Rng::new(job.req.opts.seed),
                opts: job.req.opts.clone(),
                prompt_len: prompt.len(),
                done: job.done,
                queue_ms,
                admitted_at,
            });
            stats.admitted.fetch_add(1, Ordering::Relaxed);
            stats.peak_active.fetch_max(active.len() as u64, Ordering::Relaxed);
        }
        if active.is_empty() {
            // try_recv saw a closed, drained queue
            return;
        }

        // -- batch: one token for every active sequence ----------------------
        let tokens: Vec<i32> = active.iter().map(|s| s.cur).collect();
        let seq_slots: Vec<SlotId> = active.iter().map(|s| s.slot).collect();
        let logits = engine.step_batch(&tokens, &seq_slots, &mut kv);
        for (i, seq) in active.iter_mut().enumerate() {
            let next =
                sample_logits(logits.row(i), seq.opts.temperature, seq.opts.top_k, &mut seq.rng);
            seq.produced.push(next);
            seq.cur = next;
        }
        stats.tokens_out.fetch_add(active.len() as u64, Ordering::Relaxed);

        // -- evict finished sequences ----------------------------------------
        let mut i = 0;
        while i < active.len() {
            let full = kv.len(active[i].slot) >= cfg.max_seq;
            if active[i].produced.len() >= active[i].max_new || full {
                let seq = active.swap_remove(i);
                kv.release(seq.slot);
                stats.completed.fetch_add(1, Ordering::Relaxed);
                // Receiver may have given up; completion is best-effort.
                let _ = seq.done.try_send(Completion {
                    tokens: seq.produced,
                    prompt_len: seq.prompt_len,
                    queue_ms: seq.queue_ms,
                    decode_ms: seq.admitted_at.elapsed().as_secs_f64() * 1e3,
                });
            } else {
                i += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::engine::{EngineConfig, SpectralModel};

    fn tiny_batcher(slots: usize, depth: usize) -> Batcher {
        let cfg = EngineConfig {
            vocab: 50,
            d_model: 32,
            n_layers: 2,
            n_heads: 4,
            d_ffn: 48,
            rank: 4,
            max_seq: 32,
        };
        Batcher::spawn(Engine::new(SpectralModel::init(cfg, 0)), slots, depth)
    }

    fn greedy(prompt: Vec<i32>, n: usize) -> Request {
        Request { prompt, max_new: n, opts: SampleOpts { temperature: 0.0, top_k: 0, seed: 0 } }
    }

    #[test]
    fn single_request_round_trip() {
        let b = tiny_batcher(2, 4);
        let c = b.generate(greedy(vec![1, 2, 3], 5)).unwrap();
        assert_eq!(c.tokens.len(), 5);
        assert_eq!(c.prompt_len, 3);
        assert!(c.decode_ms >= 0.0 && c.queue_ms >= 0.0);
        let (adm, done, toks, _) = b.stats().snapshot();
        assert_eq!((adm, done), (1, 1));
        assert_eq!(toks, 5);
    }

    #[test]
    fn concurrent_requests_all_complete_and_match_solo_decode() {
        // 8 concurrent clients on 4 slots: everything completes, and batched
        // decode gives each request exactly what a solo engine produces.
        let b = std::sync::Arc::new(tiny_batcher(4, 8));
        let prompts: Vec<Vec<i32>> = (0..8).map(|i| vec![i + 1, 2 * i + 3, 7]).collect();
        let mut handles = Vec::new();
        for p in prompts.clone() {
            let b = b.clone();
            handles.push(std::thread::spawn(move || b.generate(greedy(p, 6)).unwrap()));
        }
        let results: Vec<Completion> = handles.into_iter().map(|h| h.join().unwrap()).collect();

        let cfg = EngineConfig {
            vocab: 50,
            d_model: 32,
            n_layers: 2,
            n_heads: 4,
            d_ffn: 48,
            rank: 4,
            max_seq: 32,
        };
        let solo = Engine::new(SpectralModel::init(cfg, 0));
        let opts = SampleOpts { temperature: 0.0, top_k: 0, seed: 0 };
        for (p, c) in prompts.iter().zip(&results) {
            assert_eq!(c.tokens, solo.generate_reencode(p, 6, &opts), "prompt {p:?}");
        }
        let (adm, done, toks, peak) = b.stats().snapshot();
        assert_eq!((adm, done), (8, 8));
        assert_eq!(toks, 8 * 6);
        assert!(peak >= 2, "batched decode should overlap sequences (peak {peak})");
    }

    #[test]
    fn long_prompt_is_trimmed_to_context_budget() {
        let b = tiny_batcher(1, 2);
        // max_seq 32: a 100-token prompt must be trimmed, not panic.
        let c = b.generate(greedy((0..100).collect(), 4)).unwrap();
        assert_eq!(c.tokens.len(), 4);
        assert!(c.prompt_len <= 28);
    }

    #[test]
    fn empty_prompt_and_oversized_budget_are_clamped() {
        let b = tiny_batcher(1, 2);
        let c = b.generate(greedy(vec![], 10_000)).unwrap();
        assert!(!c.tokens.is_empty());
        assert!(c.tokens.len() <= 31, "max_new clamped to max_seq-1");
    }

    #[test]
    fn try_submit_sheds_load_when_queue_full() {
        // One slot + depth-1 queue, and a slow first request: eventually a
        // try_submit must observe a full queue.
        let b = tiny_batcher(1, 1);
        let mut pending = Vec::new();
        let mut shed = false;
        for i in 0..50 {
            match b.try_submit(greedy(vec![i], 20)) {
                Ok(rx) => pending.push(rx),
                Err(_) => {
                    shed = true;
                    break;
                }
            }
        }
        assert!(shed, "bounded queue must refuse work eventually");
        for rx in pending {
            assert!(rx.recv().is_ok());
        }
    }

    #[test]
    fn drop_with_queued_work_completes_in_flight() {
        let b = tiny_batcher(2, 4);
        let rx = b.submit(greedy(vec![5, 6], 4)).unwrap();
        drop(b); // closes the queue, scheduler drains, thread joins
        let c = rx.recv().expect("in-flight request still completes");
        assert_eq!(c.tokens.len(), 4);
    }
}
