//! Continuous-batching scheduler: bounded admission queue, slot-based
//! admission, chunked prefill, batched decode, per-token streaming, eviction
//! of finished sequences.
//!
//! One scheduler thread owns the [`Engine`] and the [`KvCache`] arena.
//! Clients submit [`Request`]s through a bounded `sync_channel` (the same
//! backpressure idiom as `data::loader` — a full queue blocks the submitter
//! instead of buffering unboundedly). The scheduler loop:
//!
//! 1. **admit** — while free slots exist, pull queued requests (blocking
//!    when idle, opportunistic `try_recv` otherwise) and claim a KV slot.
//!    Admission is O(1): the prompt is *not* prefilled inline — the sequence
//!    enters the batch in the `Prefilling` state;
//! 2. **prefill** — spend at most `prefill_chunk` prompt tokens advancing
//!    `Prefilling` sequences (budget dealt round-robin across them, then all
//!    granted tokens absorbed as **fused multi-token runs** through ONE
//!    [`Engine::prefill_batch`] call: each projection is a single batched
//!    matmul over every granted position, not one matmul per position).
//!    This is the fairness budget: a 512-token prompt costs many scheduler
//!    steps instead of stalling one, so active decodes keep making progress
//!    while it is absorbed. A sequence whose prompt is fully cached
//!    transitions to `Decoding`;
//! 3. **decode** — ONE token for every `Decoding` sequence in a single
//!    [`Engine::step_batch`] call, so all sequences share the weight-matrix
//!    traffic of the projections and the logits head. Each sampled token is
//!    pushed down the per-sequence stream channel immediately (when the
//!    request was submitted via [`Batcher::submit_streaming`]); a stream
//!    whose receiver hung up cancels the sequence, freeing its slot. Stop
//!    sequences ([`Request::stop`]) are checked as each token lands: a match
//!    ends the sequence with `finish_reason = "stop"` and trims the matched
//!    tokens; tokens that could still become a match are **held back** from
//!    the stream until decided, so streamed tokens always concatenate to the
//!    final trimmed output;
//! 4. **evict** — sequences that matched a stop sequence, hit their token
//!    budget, fill their KV line, or were cancelled release the slot
//!    (recycled by the next admission) and their [`Completion`] is
//!    delivered with its [`FinishReason`].
//!
//! Sequences join and leave the batch at token granularity — a long request
//! never blocks a short one behind it (continuous batching), and since
//! prefill is chunked, a long *prompt* no longer stalls the decode batch
//! during admission either.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, Sender, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::engine::{sample_logits, Engine, SampleOpts};
use super::kv::SlotId;
use crate::obs::{self, prof, trace, Counter, Gauge, Histogram};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// One generation request (token ids in, token ids out).
#[derive(Debug, Clone)]
pub struct Request {
    pub prompt: Vec<i32>,
    pub max_new: usize,
    pub opts: SampleOpts,
    /// Stop sequences as token-id sequences: generation ends the moment the
    /// produced tokens end with any of them, and the matched sequence is
    /// trimmed from the output (so a single-entry sequence is exactly EOS
    /// handling). Empty sequences are ignored; at most
    /// [`MAX_STOP_SEQUENCES`] are honored.
    pub stop: Vec<Vec<i32>>,
}

/// Why a sequence finished.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// A stop sequence (or EOS token) matched.
    Stop,
    /// Token budget or KV capacity exhausted.
    Length,
    /// The stream receiver hung up.
    Cancelled,
}

impl FinishReason {
    pub fn as_str(&self) -> &'static str {
        match self {
            FinishReason::Stop => "stop",
            FinishReason::Length => "length",
            FinishReason::Cancelled => "cancelled",
        }
    }
}

/// Stop sequences honored per request (the rest are ignored).
pub const MAX_STOP_SEQUENCES: usize = 8;

/// Result of a finished request, with queue/decode timing for the latency
/// accounting the throughput bench reports.
#[derive(Debug, Clone)]
pub struct Completion {
    /// Process-unique request id (see [`crate::obs::trace`]): the same id
    /// appears in `/v1/generate` responses, SSE frames, and the request's
    /// span record in `traces.jsonl`.
    pub request_id: u64,
    pub tokens: Vec<i32>,
    pub prompt_len: usize,
    /// Time spent waiting for a slot (admission latency).
    pub queue_ms: f64,
    /// Enqueue → first generated token (the user-facing latency metric).
    /// `None` when the request finished without sampling a token, so
    /// zero-token completions cannot poison latency percentiles.
    pub ttft_ms: Option<f64>,
    /// Prefill + decode wall time.
    pub decode_ms: f64,
    pub finish_reason: FinishReason,
}

/// One event on a streaming request's channel (see
/// [`Batcher::submit_streaming`]): every sampled token as soon as the decode
/// step produces it, then a terminal [`Completion`]. Concatenating the
/// `Token` payloads yields exactly `Completion::tokens`.
#[derive(Debug, Clone)]
pub enum StreamEvent {
    Token(i32),
    Done(Completion),
}

/// Scheduler sizing: slot count, admission queue depth, and the chunked
/// prefill fairness budget.
#[derive(Debug, Clone, Copy)]
pub struct BatchConfig {
    /// Concurrent sequences (KV arena size).
    pub slots: usize,
    /// Bounded admission queue depth.
    pub queue_depth: usize,
    /// Max prompt tokens prefilled per scheduler step, shared across all
    /// `Prefilling` sequences. Bounds how long one decode step can be
    /// delayed by prompt admission. `0` disables chunking (a prompt is
    /// absorbed in one step — the pre-chunking stall behavior, kept for
    /// A/B measurement in the throughput bench).
    pub prefill_chunk: usize,
    /// Gateway worker index this scheduler runs as. Only observability
    /// reads it: every `sct_serve_*` series the scheduler records carries a
    /// `worker="<index>"` label, so a multi-worker gateway's metrics stay
    /// separable per scheduler. A standalone batcher is worker 0.
    pub worker: usize,
}

impl Default for BatchConfig {
    fn default() -> BatchConfig {
        BatchConfig { slots: 8, queue_depth: 32, prefill_chunk: 64, worker: 0 }
    }
}

/// Why a non-blocking submit was refused (typed, so the HTTP layer can map
/// load shedding to 503 and a dead scheduler to 500 without string-matching).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// The bounded admission queue is at capacity (load shed; retry later).
    QueueFull,
    /// The scheduler thread is gone (shutdown or crash).
    Shutdown,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull => write!(f, "admission queue full"),
            SubmitError::Shutdown => write!(f, "batcher is shut down"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Shared scheduler counters (read via [`Batcher::stats`]).
#[derive(Debug, Default)]
pub struct BatchStats {
    pub admitted: AtomicU64,
    pub completed: AtomicU64,
    pub tokens_out: AtomicU64,
    pub peak_active: AtomicU64,
    /// Prompt tokens absorbed through chunked prefill.
    pub prefill_tokens: AtomicU64,
    /// Sequences cancelled because their stream receiver hung up.
    pub cancelled: AtomicU64,
    /// Sequences that terminated on a stop sequence / EOS match.
    pub stopped: AtomicU64,
    /// Requests currently waiting in the admission queue (live gauge:
    /// incremented on enqueue, decremented when the scheduler admits).
    pub queue_depth: AtomicU64,
    /// Sequences currently holding a KV slot (live gauge, written by the
    /// scheduler after every admit/evict pass).
    pub active_slots: AtomicU64,
}

/// Point-in-time copy of every [`BatchStats`] counter and gauge (the
/// `/v1/stats` payload).
#[derive(Debug, Clone, Copy, Default)]
pub struct StatsSnapshot {
    pub admitted: u64,
    pub completed: u64,
    pub tokens_out: u64,
    pub peak_active: u64,
    pub prefill_tokens: u64,
    pub cancelled: u64,
    pub stopped: u64,
    /// Requests waiting in the admission queue right now.
    pub queue_depth: u64,
    /// Sequences holding a KV slot right now.
    pub active_slots: u64,
}

impl BatchStats {
    pub fn snapshot(&self) -> StatsSnapshot {
        StatsSnapshot {
            admitted: self.admitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            tokens_out: self.tokens_out.load(Ordering::Relaxed),
            peak_active: self.peak_active.load(Ordering::Relaxed),
            prefill_tokens: self.prefill_tokens.load(Ordering::Relaxed),
            cancelled: self.cancelled.load(Ordering::Relaxed),
            stopped: self.stopped.load(Ordering::Relaxed),
            queue_depth: self.queue_depth.load(Ordering::Relaxed),
            active_slots: self.active_slots.load(Ordering::Relaxed),
        }
    }

    pub fn prefill_tokens(&self) -> u64 {
        self.prefill_tokens.load(Ordering::Relaxed)
    }

    pub fn cancelled(&self) -> u64 {
        self.cancelled.load(Ordering::Relaxed)
    }

    pub fn stopped(&self) -> u64 {
        self.stopped.load(Ordering::Relaxed)
    }
}

/// Registry handles for one scheduler's serve-layer series, registered at
/// spawn and cached (recording is then wait-free — see
/// [`crate::obs::metrics`]). Every series carries a `worker="<index>"` label
/// so a multi-worker gateway's schedulers stay separable on `/metrics`;
/// the registry dedups by (name, labels), so two batchers sharing a worker
/// index (tests, standalone use) share handles and accumulate jointly,
/// exactly like the former process-global set.
struct ServeMetrics {
    requests: Counter,
    completions: Counter,
    tokens_out: Counter,
    prefill_tokens: Counter,
    cancelled: Counter,
    stopped: Counter,
    queue_depth: Gauge,
    active_slots: Gauge,
    queue_wait_ms: Histogram,
    ttft_ms: Histogram,
    decode_step_ms: Histogram,
    prefill_chunk_ms: Histogram,
}

impl ServeMetrics {
    fn register(worker: usize) -> ServeMetrics {
        let r = obs::registry();
        let w = worker.to_string();
        let l: &[(&str, &str)] = &[("worker", w.as_str())];
        ServeMetrics {
            requests: r.counter_with(
                "sct_serve_requests_total",
                l,
                "Requests enqueued for admission",
            ),
            completions: r.counter_with(
                "sct_serve_completions_total",
                l,
                "Requests finished (any reason)",
            ),
            tokens_out: r.counter_with(
                "sct_serve_tokens_out_total",
                l,
                "Tokens sampled by batched decode",
            ),
            prefill_tokens: r.counter_with(
                "sct_serve_prefill_tokens_total",
                l,
                "Prompt tokens absorbed via prefill",
            ),
            cancelled: r.counter_with(
                "sct_serve_cancelled_total",
                l,
                "Sequences cancelled by hung-up streams",
            ),
            stopped: r.counter_with(
                "sct_serve_stopped_total",
                l,
                "Sequences ended by a stop-sequence match",
            ),
            queue_depth: r.gauge_with(
                "sct_serve_queue_depth",
                l,
                "Requests waiting in the admission queue",
            ),
            active_slots: r.gauge_with(
                "sct_serve_active_slots",
                l,
                "Sequences currently holding a KV slot",
            ),
            queue_wait_ms: r.histogram_with(
                "sct_serve_queue_wait_ms",
                l,
                "Enqueue-to-admission wait per request (ms)",
            ),
            ttft_ms: r.histogram_with(
                "sct_serve_ttft_ms",
                l,
                "Enqueue to first sampled token (ms)",
            ),
            decode_step_ms: r.histogram_with(
                "sct_serve_decode_step_ms",
                l,
                "Wall time of one batched decode step (ms) — the inter-token latency floor",
            ),
            prefill_chunk_ms: r.histogram_with(
                "sct_serve_prefill_chunk_ms",
                l,
                "Wall time of one fused prefill batch (ms)",
            ),
        }
    }
}

/// Where a sequence's output goes: a one-shot completion channel or a
/// per-token stream.
enum Sink {
    Oneshot(SyncSender<Completion>),
    Stream(Sender<StreamEvent>),
}

impl Sink {
    /// Push one sampled token; `false` means the receiver hung up and the
    /// sequence should be cancelled (one-shot sinks never cancel mid-flight).
    /// std `mpsc` only reveals a dropped receiver on `send`, so a hangup
    /// during a long prefill is detected at the first decode token — the
    /// abandoned prompt's prefill work is spent, but the slot is reclaimed
    /// before any decode steps are wasted on it.
    fn push_token(&self, t: i32) -> bool {
        match self {
            Sink::Oneshot(_) => true,
            Sink::Stream(tx) => tx.send(StreamEvent::Token(t)).is_ok(),
        }
    }

    /// Deliver the terminal completion (best-effort: the receiver may be gone).
    fn finish(self, c: Completion) {
        match self {
            Sink::Oneshot(tx) => {
                let _ = tx.try_send(c);
            }
            Sink::Stream(tx) => {
                let _ = tx.send(StreamEvent::Done(c));
            }
        }
    }
}

struct Job {
    req: Request,
    req_id: u64,
    sink: Sink,
    enqueued: Instant,
}

/// Per-slot scheduler state: absorbing the prompt vs emitting tokens.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum SeqState {
    /// `done` of `total` prompt tokens fed into the KV cache so far.
    Prefilling { done: usize, total: usize },
    /// Prompt absorbed; one token per batched decode step.
    Decoding,
}

/// An admitted sequence holding a KV slot.
struct ActiveSeq {
    slot: SlotId,
    /// Request id (see [`crate::obs::trace`]); keys this request's span
    /// record and appears in its completion.
    req_id: u64,
    /// Span id of this request's worker-side `kind:"request"` span; the
    /// queue_wait/prefill_chunk/decode child spans parent to it.
    span_id: u64,
    /// Fused prefill batches this sequence took part in (span field).
    prefill_chunks: u64,
    /// Batched decode steps that sampled a token for this sequence — unlike
    /// `produced.len()` this is not reduced by stop-sequence trimming.
    decode_steps: u64,
    /// Context-trimmed prompt. `prompt[..prompt.len()-1]` is prefilled; the
    /// last token seeds decoding (its logits come from the first decode step).
    prompt: Vec<i32>,
    state: SeqState,
    cur: i32,
    produced: Vec<i32>,
    max_new: usize,
    rng: Rng,
    opts: SampleOpts,
    /// Stop sequences (non-empty, capped — see [`MAX_STOP_SEQUENCES`]).
    stop: Vec<Vec<i32>>,
    /// Tokens already pushed down the stream. Lags `produced.len()` by the
    /// stop-sequence holdback: a token that could be the prefix of a stop
    /// match is withheld until the match is decided, so the stream never
    /// emits tokens the final completion trims away.
    streamed: usize,
    sink: Option<Sink>,
    queue_ms: f64,
    enqueued: Instant,
    admitted_at: Instant,
    first_token_ms: Option<f64>,
    cancelled: bool,
    stopped: bool,
}

/// Length of the LONGEST stop sequence `produced` ends with. Longest wins so
/// an overlapping shorter stop (e.g. `"\n"` vs `"###\n"`) cannot pre-empt a
/// longer one and leave part of its text untrimmed in the output.
fn stop_match(produced: &[i32], stops: &[Vec<i32>]) -> Option<usize> {
    stops.iter().filter(|s| produced.ends_with(s)).map(|s| s.len()).max()
}

/// How many trailing tokens of `produced` could still become a stop match —
/// the longest proper prefix of any stop sequence that `produced` currently
/// ends with. These tokens must not be streamed yet.
fn stop_holdback(produced: &[i32], stops: &[Vec<i32>]) -> usize {
    let mut hold = 0usize;
    for s in stops {
        for l in (hold + 1)..s.len() {
            if l <= produced.len() && produced[produced.len() - l..] == s[..l] {
                hold = hold.max(l);
            }
        }
    }
    hold
}

/// Handle to the scheduler thread. Dropping it closes the queue and joins
/// the thread after in-flight sequences finish.
pub struct Batcher {
    // Mutex<Option<..>> rather than a bare SyncSender so `&Batcher` can be
    // shared across connection-handler threads on any rustc the image ships
    // (SyncSender: Sync is a recent guarantee); submitters clone the sender
    // out and send OUTSIDE the lock so backpressure never holds the mutex.
    tx: Mutex<Option<SyncSender<Job>>>,
    handle: Option<JoinHandle<()>>,
    stats: Arc<BatchStats>,
    /// Worker-labeled metric handles (see [`ServeMetrics`]), shared with the
    /// scheduler thread.
    m: Arc<ServeMetrics>,
    pub slots: usize,
    pub queue_depth: usize,
    pub prefill_chunk: usize,
    /// Gateway worker index (label value on this scheduler's series).
    pub worker: usize,
}

impl Batcher {
    /// Spawn the scheduler with `slots` concurrent sequences, a bounded
    /// queue of `queue_depth` waiting requests, and the default chunked
    /// prefill budget (see [`BatchConfig`]).
    pub fn spawn(engine: Engine, slots: usize, queue_depth: usize) -> Batcher {
        Batcher::spawn_with(engine, BatchConfig { slots, queue_depth, ..BatchConfig::default() })
    }

    /// Spawn the scheduler with explicit sizing.
    pub fn spawn_with(engine: Engine, cfg: BatchConfig) -> Batcher {
        assert!(cfg.slots > 0, "need at least one decode slot");
        let (tx, rx) = mpsc::sync_channel::<Job>(cfg.queue_depth.max(1));
        let stats = Arc::new(BatchStats::default());
        let m = Arc::new(ServeMetrics::register(cfg.worker));
        let stats_worker = stats.clone();
        let m_worker = m.clone();
        let handle = std::thread::Builder::new()
            .name(format!("sct-batcher-{}", cfg.worker))
            .spawn(move || scheduler_loop(engine, cfg, rx, stats_worker, m_worker))
            .expect("spawn batcher thread");
        Batcher {
            tx: Mutex::new(Some(tx)),
            handle: Some(handle),
            stats,
            m,
            slots: cfg.slots,
            queue_depth: cfg.queue_depth,
            prefill_chunk: cfg.prefill_chunk,
            worker: cfg.worker,
        }
    }

    fn sender(&self) -> Result<SyncSender<Job>> {
        self.tx
            .lock()
            .unwrap()
            .as_ref()
            .cloned()
            .ok_or_else(|| anyhow!("batcher is shut down"))
    }

    /// Claim a queue-depth slot BEFORE the job can reach the scheduler, so
    /// the scheduler's decrement at admission never observes a count the
    /// enqueue hasn't added yet. Rolled back via [`Batcher::enqueue_failed`]
    /// when the send errors.
    fn enqueue_started(&self) {
        self.stats.queue_depth.fetch_add(1, Ordering::Relaxed);
        self.m.queue_depth.set(self.stats.queue_depth.load(Ordering::Relaxed) as f64);
    }

    fn enqueue_failed(&self) {
        self.stats.queue_depth.fetch_sub(1, Ordering::Relaxed);
        self.m.queue_depth.set(self.stats.queue_depth.load(Ordering::Relaxed) as f64);
    }

    /// Enqueue a request; blocks when the admission queue is full
    /// (backpressure). Returns the channel the completion arrives on.
    pub fn submit(&self, req: Request) -> Result<Receiver<Completion>> {
        Ok(self.submit_with_id(req)?.1)
    }

    /// [`Batcher::submit`], also returning the request id assigned to the
    /// job (the id the completion, span record, and HTTP responses carry).
    pub fn submit_with_id(&self, req: Request) -> Result<(u64, Receiver<Completion>)> {
        let tx = self.sender()?;
        let req_id = trace::next_request_id();
        let (done, done_rx) = mpsc::sync_channel(1);
        self.enqueue_started();
        if tx
            .send(Job { req, req_id, sink: Sink::Oneshot(done), enqueued: Instant::now() })
            .is_err()
        {
            self.enqueue_failed();
            return Err(anyhow!("batcher thread died"));
        }
        self.m.requests.inc();
        Ok((req_id, done_rx))
    }

    /// Non-blocking submit: errors immediately when the queue is full
    /// instead of applying backpressure (load-shedding for the server).
    pub fn try_submit(&self, req: Request) -> Result<Receiver<Completion>, SubmitError> {
        Ok(self.try_submit_with_id(req)?.1)
    }

    /// Non-blocking [`Batcher::submit_with_id`] (load-shedding).
    pub fn try_submit_with_id(
        &self,
        req: Request,
    ) -> Result<(u64, Receiver<Completion>), SubmitError> {
        let Some(tx) = self.tx.lock().unwrap().as_ref().cloned() else {
            return Err(SubmitError::Shutdown);
        };
        let req_id = trace::next_request_id();
        let (done, done_rx) = mpsc::sync_channel(1);
        self.enqueue_started();
        match tx.try_send(Job { req, req_id, sink: Sink::Oneshot(done), enqueued: Instant::now() })
        {
            Ok(()) => {
                self.m.requests.inc();
                Ok((req_id, done_rx))
            }
            Err(TrySendError::Full(_)) => {
                self.enqueue_failed();
                Err(SubmitError::QueueFull)
            }
            Err(TrySendError::Disconnected(_)) => {
                self.enqueue_failed();
                Err(SubmitError::Shutdown)
            }
        }
    }

    /// Enqueue a streaming request (backpressure as [`Batcher::submit`]).
    /// Every sampled token arrives as [`StreamEvent::Token`] the step it is
    /// produced; the terminal [`StreamEvent::Done`] carries the completion.
    /// Dropping the receiver cancels the sequence at its next token, freeing
    /// the slot.
    pub fn submit_streaming(&self, req: Request) -> Result<Receiver<StreamEvent>> {
        Ok(self.submit_streaming_with_id(req)?.1)
    }

    /// [`Batcher::submit_streaming`], also returning the request id (stamped
    /// on every SSE frame by the server).
    pub fn submit_streaming_with_id(&self, req: Request) -> Result<(u64, Receiver<StreamEvent>)> {
        let tx = self.sender()?;
        let req_id = trace::next_request_id();
        let (ev_tx, ev_rx) = mpsc::channel();
        self.enqueue_started();
        if tx
            .send(Job { req, req_id, sink: Sink::Stream(ev_tx), enqueued: Instant::now() })
            .is_err()
        {
            self.enqueue_failed();
            return Err(anyhow!("batcher thread died"));
        }
        self.m.requests.inc();
        Ok((req_id, ev_rx))
    }

    /// Non-blocking [`Batcher::submit_streaming`] (load-shedding).
    pub fn try_submit_streaming(&self, req: Request) -> Result<Receiver<StreamEvent>, SubmitError> {
        Ok(self.try_submit_streaming_with_id(req)?.1)
    }

    /// Non-blocking [`Batcher::submit_streaming_with_id`] (load-shedding).
    pub fn try_submit_streaming_with_id(
        &self,
        req: Request,
    ) -> Result<(u64, Receiver<StreamEvent>), SubmitError> {
        let Some(tx) = self.tx.lock().unwrap().as_ref().cloned() else {
            return Err(SubmitError::Shutdown);
        };
        let req_id = trace::next_request_id();
        let (ev_tx, ev_rx) = mpsc::channel();
        self.enqueue_started();
        match tx.try_send(Job { req, req_id, sink: Sink::Stream(ev_tx), enqueued: Instant::now() })
        {
            Ok(()) => {
                self.m.requests.inc();
                Ok((req_id, ev_rx))
            }
            Err(TrySendError::Full(_)) => {
                self.enqueue_failed();
                Err(SubmitError::QueueFull)
            }
            Err(TrySendError::Disconnected(_)) => {
                self.enqueue_failed();
                Err(SubmitError::Shutdown)
            }
        }
    }

    /// Submit and block until the completion arrives.
    pub fn generate(&self, req: Request) -> Result<Completion> {
        let rx = self.submit(req)?;
        rx.recv().map_err(|_| anyhow!("batcher dropped the request"))
    }

    pub fn stats(&self) -> &BatchStats {
        &self.stats
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        // Close the queue first so the scheduler drains and exits, then join.
        drop(self.tx.lock().unwrap().take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn scheduler_loop(
    engine: Engine,
    bcfg: BatchConfig,
    rx: Receiver<Job>,
    stats: Arc<BatchStats>,
    m: Arc<ServeMetrics>,
) {
    // Frames this thread records (prefill/decode scopes and the kernels
    // under them) root under a per-worker label, so `/v1/profile` attributes
    // scheduler time to the right worker.
    prof::set_thread_label(prof::worker_label(bcfg.worker));
    let cfg = *engine.cfg();
    let mut kv = engine.new_kv(bcfg.slots);
    let mut active: Vec<ActiveSeq> = Vec::with_capacity(bcfg.slots);
    let mut step: usize = 0; // rotates the prefill round-robin start
    loop {
        // -- admit (O(1) per request: no inline prefill) ---------------------
        while active.len() < bcfg.slots {
            let job = if active.is_empty() {
                // idle: block for work; a closed queue means shutdown
                match rx.recv() {
                    Ok(j) => j,
                    Err(_) => return,
                }
            } else {
                // decoding: only take what is already waiting
                match rx.try_recv() {
                    Ok(j) => j,
                    Err(_) => break,
                }
            };
            stats.queue_depth.fetch_sub(1, Ordering::Relaxed);
            m.queue_depth.set(stats.queue_depth.load(Ordering::Relaxed) as f64);
            let queue_ms = job.enqueued.elapsed().as_secs_f64() * 1e3;
            m.queue_wait_ms.record(queue_ms);
            // Worker-side span id for this request: child spans (queue_wait,
            // prefill_chunk, decode) parent to it, and it parents to the
            // gateway root span, whose id IS the request id.
            let span_id = trace::next_span_id();
            if trace::enabled() {
                trace::emit(&crate::json_obj![
                    ("kind", "queue_wait"),
                    ("span_id", trace::next_span_id() as i64),
                    ("parent_id", span_id as i64),
                    ("request_id", job.req_id as i64),
                    ("queue_ms", queue_ms),
                ]);
            }
            let slot = kv.alloc().expect("active < slots implies a free slot");

            // budget the context window: cap the generation length, keep the
            // prompt tail that fits in front of it (absolute RoPE positions,
            // so a long prompt is truncated, not slid).
            let max_new = job.req.max_new.clamp(1, cfg.max_seq - 1);
            let keep = (cfg.max_seq - max_new).max(1);
            let prompt: Vec<i32> = if job.req.prompt.is_empty() {
                vec![0] // BOS-less model: decode from token 0
            } else if job.req.prompt.len() > keep {
                job.req.prompt[job.req.prompt.len() - keep..].to_vec()
            } else {
                job.req.prompt
            };

            // all but the last prompt token go through chunked prefill (no
            // logits computed); the last token seeds the first decode step.
            let total = prompt.len() - 1;
            let state = if total == 0 {
                SeqState::Decoding
            } else {
                SeqState::Prefilling { done: 0, total }
            };
            let stop: Vec<Vec<i32>> = job
                .req
                .stop
                .into_iter()
                .filter(|s| !s.is_empty())
                .take(MAX_STOP_SEQUENCES)
                .collect();
            active.push(ActiveSeq {
                slot,
                req_id: job.req_id,
                span_id,
                prefill_chunks: 0,
                decode_steps: 0,
                cur: prompt[total],
                prompt,
                state,
                produced: Vec::with_capacity(max_new),
                max_new,
                rng: Rng::new(job.req.opts.seed),
                opts: job.req.opts.clone(),
                stop,
                streamed: 0,
                sink: Some(job.sink),
                queue_ms,
                enqueued: job.enqueued,
                admitted_at: Instant::now(),
                first_token_ms: None,
                cancelled: false,
                stopped: false,
            });
            stats.admitted.fetch_add(1, Ordering::Relaxed);
            stats.peak_active.fetch_max(active.len() as u64, Ordering::Relaxed);
        }
        stats.active_slots.store(active.len() as u64, Ordering::Relaxed);
        m.active_slots.set(active.len() as f64);
        if active.is_empty() {
            // try_recv saw a closed, drained queue
            return;
        }
        step = step.wrapping_add(1);

        // -- chunked prefill: spend the fairness budget in one fused batch ---
        // The budget is dealt round-robin (one token per Prefilling sequence
        // per pass, start rotated by `step`) so a small budget cannot starve
        // later slots — the same fairness as the old one-token-per-call loop
        // — but every granted token is absorbed through a SINGLE
        // Engine::prefill_batch call: multi-token runs per sequence (slots
        // repeated, tokens in order), so each projection runs once as a
        // batched matmul over all granted positions instead of once per
        // position. Token-identical to per-position prefill (engine tests).
        let n = active.len();
        let mut order: Vec<usize> = Vec::new(); // Prefilling seqs, rotated
        let mut remaining: Vec<usize> = Vec::new();
        for j in 0..n {
            let i = (step + j) % n;
            if let SeqState::Prefilling { done, total } = active[i].state {
                order.push(i);
                remaining.push(total - done);
            }
        }
        if !order.is_empty() {
            let budget = if bcfg.prefill_chunk == 0 { usize::MAX } else { bcfg.prefill_chunk };
            let mut takes = vec![0usize; order.len()];
            let mut granted = 0usize;
            'grant: loop {
                let mut progressed = false;
                for (take, &rem) in takes.iter_mut().zip(&remaining) {
                    if granted >= budget {
                        break 'grant;
                    }
                    if *take < rem {
                        *take += 1;
                        granted += 1;
                        progressed = true;
                    }
                }
                if !progressed {
                    break;
                }
            }
            let mut toks: Vec<i32> = Vec::with_capacity(granted);
            let mut seq_slots: Vec<SlotId> = Vec::with_capacity(granted);
            for (&i, &take) in order.iter().zip(&takes) {
                if take == 0 {
                    continue;
                }
                if let SeqState::Prefilling { done, total } = active[i].state {
                    toks.extend_from_slice(&active[i].prompt[done..done + take]);
                    seq_slots.resize(seq_slots.len() + take, active[i].slot);
                    active[i].prefill_chunks += 1;
                    active[i].state = if done + take == total {
                        SeqState::Decoding
                    } else {
                        SeqState::Prefilling { done: done + take, total }
                    };
                }
            }
            if !toks.is_empty() {
                let t0 = Instant::now();
                {
                    let _p = prof::scope("prefill_chunk");
                    engine.prefill_batch(&toks, &seq_slots, &mut kv);
                }
                let chunk_ms = t0.elapsed().as_secs_f64() * 1e3;
                m.prefill_chunk_ms.record(chunk_ms);
                stats.prefill_tokens.fetch_add(toks.len() as u64, Ordering::Relaxed);
                m.prefill_tokens.add(toks.len() as u64);
                if trace::enabled() {
                    // One span per sequence that took tokens in this fused
                    // chunk; chunk_ms is the fused batch's wall time (shared).
                    for (&i, &take) in order.iter().zip(&takes) {
                        if take == 0 {
                            continue;
                        }
                        trace::emit(&crate::json_obj![
                            ("kind", "prefill_chunk"),
                            ("span_id", trace::next_span_id() as i64),
                            ("parent_id", active[i].span_id as i64),
                            ("request_id", active[i].req_id as i64),
                            ("tokens", take),
                            ("chunk_ms", chunk_ms),
                        ]);
                    }
                }
            }
        }

        // -- decode: one token for every Decoding sequence -------------------
        let decode_idx: Vec<usize> = active
            .iter()
            .enumerate()
            .filter(|(_, s)| s.state == SeqState::Decoding)
            .map(|(i, _)| i)
            .collect();
        if !decode_idx.is_empty() {
            let _p = prof::scope("decode_step");
            // ONE timestamp pair per batched step (not per token) keeps the
            // ITL histogram off the per-token hot path.
            let t_step = Instant::now();
            let tokens: Vec<i32> = decode_idx.iter().map(|&i| active[i].cur).collect();
            let seq_slots: Vec<SlotId> = decode_idx.iter().map(|&i| active[i].slot).collect();
            let logits = engine.step_batch(&tokens, &seq_slots, &mut kv);
            for (row, &i) in decode_idx.iter().enumerate() {
                let seq = &mut active[i];
                let (temp, top_k) = (seq.opts.temperature, seq.opts.top_k);
                let next = sample_logits(logits.row(row), temp, top_k, &mut seq.rng);
                seq.produced.push(next);
                seq.cur = next;
                seq.decode_steps += 1;
                if seq.first_token_ms.is_none() {
                    let ttft = seq.enqueued.elapsed().as_secs_f64() * 1e3;
                    seq.first_token_ms = Some(ttft);
                    m.ttft_ms.record(ttft);
                }
                // Stop sequences: a match ends the sequence and trims the
                // matched tokens from the output. Tokens that might still
                // become a match are withheld from the stream (holdback), so
                // streamed tokens always concatenate to the final output.
                let hold = if seq.stop.is_empty() {
                    0
                } else if let Some(matched) = stop_match(&seq.produced, &seq.stop) {
                    seq.produced.truncate(seq.produced.len() - matched);
                    seq.stopped = true;
                    stats.stopped.fetch_add(1, Ordering::Relaxed);
                    m.stopped.inc();
                    0
                } else {
                    stop_holdback(&seq.produced, &seq.stop)
                };
                let releasable = seq.produced.len() - hold.min(seq.produced.len());
                while seq.streamed < releasable && !seq.cancelled {
                    let t = seq.produced[seq.streamed];
                    match &seq.sink {
                        Some(sink) if !sink.push_token(t) => {
                            seq.cancelled = true;
                            stats.cancelled.fetch_add(1, Ordering::Relaxed);
                            m.cancelled.inc();
                        }
                        _ => seq.streamed += 1,
                    }
                }
            }
            stats.tokens_out.fetch_add(decode_idx.len() as u64, Ordering::Relaxed);
            m.tokens_out.add(decode_idx.len() as u64);
            m.decode_step_ms.record(t_step.elapsed().as_secs_f64() * 1e3);
        }

        // -- evict finished sequences ----------------------------------------
        let mut i = 0;
        while i < active.len() {
            let s = &active[i];
            let finished = s.cancelled
                || s.stopped
                || (s.state == SeqState::Decoding
                    && (s.produced.len() >= s.max_new || kv.remaining(s.slot) == 0));
            if finished {
                let mut seq = active.swap_remove(i);
                kv.release(seq.slot);
                stats.completed.fetch_add(1, Ordering::Relaxed);
                m.completions.inc();
                // A length-finish may still hold tokens back (they were a
                // possible stop prefix); the match is now decided, flush them.
                if !seq.cancelled {
                    for j in seq.streamed..seq.produced.len() {
                        if let Some(sink) = &seq.sink {
                            if !sink.push_token(seq.produced[j]) {
                                break;
                            }
                        }
                    }
                }
                let finish_reason = if seq.cancelled {
                    FinishReason::Cancelled
                } else if seq.stopped {
                    FinishReason::Stop
                } else {
                    FinishReason::Length
                };
                let decode_ms = seq.admitted_at.elapsed().as_secs_f64() * 1e3;
                // One complete span per request, emitted exactly once, at
                // eviction (no-op unless a trace sink is installed).
                if trace::enabled() {
                    if seq.decode_steps > 0 {
                        trace::emit(&crate::json_obj![
                            ("kind", "decode"),
                            ("span_id", trace::next_span_id() as i64),
                            ("parent_id", seq.span_id as i64),
                            ("request_id", seq.req_id as i64),
                            ("decode_steps", seq.decode_steps as i64),
                            ("tokens_out", seq.produced.len()),
                            ("decode_ms", decode_ms),
                        ]);
                    }
                    let mut span = crate::json_obj![
                        ("kind", "request"),
                        ("span_id", seq.span_id as i64),
                        ("parent_id", seq.req_id as i64),
                        ("request_id", seq.req_id as i64),
                        ("prompt_tokens", seq.prompt.len()),
                        ("queue_ms", seq.queue_ms),
                        ("prefill_chunks", seq.prefill_chunks as i64),
                        ("prefill_tokens", seq.prompt.len() - 1),
                        ("decode_steps", seq.decode_steps as i64),
                        ("tokens_out", seq.produced.len()),
                        ("decode_ms", decode_ms),
                        ("finish_reason", finish_reason.as_str()),
                    ];
                    if let (Json::Obj(fields), Some(t)) = (&mut span, seq.first_token_ms) {
                        fields.push(("ttft_ms".to_string(), t.into()));
                    }
                    trace::emit(&span);
                }
                // Receiver may have given up; completion is best-effort.
                if let Some(sink) = seq.sink.take() {
                    sink.finish(Completion {
                        request_id: seq.req_id,
                        tokens: seq.produced,
                        prompt_len: seq.prompt.len(),
                        queue_ms: seq.queue_ms,
                        ttft_ms: seq.first_token_ms,
                        decode_ms,
                        finish_reason,
                    });
                }
            } else {
                i += 1;
            }
        }
        stats.active_slots.store(active.len() as u64, Ordering::Relaxed);
        m.active_slots.set(active.len() as f64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::engine::{EngineConfig, SpectralModel};

    fn tiny_cfg() -> EngineConfig {
        EngineConfig {
            vocab: 50,
            d_model: 32,
            n_layers: 2,
            n_heads: 4,
            d_ffn: 48,
            rank: 4,
            max_seq: 32,
            tied: true,
        }
    }

    fn tiny_batcher(slots: usize, depth: usize) -> Batcher {
        Batcher::spawn(Engine::new(SpectralModel::init(tiny_cfg(), 0)), slots, depth)
    }

    fn greedy(prompt: Vec<i32>, n: usize) -> Request {
        Request {
            prompt,
            max_new: n,
            opts: SampleOpts { temperature: 0.0, top_k: 0, seed: 0 },
            stop: vec![],
        }
    }

    fn greedy_stop(prompt: Vec<i32>, n: usize, stop: Vec<Vec<i32>>) -> Request {
        Request { stop, ..greedy(prompt, n) }
    }

    #[test]
    fn single_request_round_trip() {
        let b = tiny_batcher(2, 4);
        let c = b.generate(greedy(vec![1, 2, 3], 5)).unwrap();
        assert_eq!(c.tokens.len(), 5);
        assert_eq!(c.prompt_len, 3);
        assert!(c.decode_ms >= 0.0 && c.queue_ms >= 0.0);
        let ttft = c.ttft_ms.expect("a completion with tokens has a TTFT");
        assert!(ttft > 0.0 && ttft <= c.queue_ms + c.decode_ms + 1.0);
        assert!(c.request_id > 0);
        let s = b.stats().snapshot();
        assert_eq!((s.admitted, s.completed), (1, 1));
        assert_eq!(s.tokens_out, 5);
        assert_eq!(s.queue_depth, 0, "drained queue");
        assert_eq!(s.active_slots, 0, "no sequence left in the batch");
        assert_eq!(b.stats().prefill_tokens(), 2, "prompt[..len-1] goes through prefill");
    }

    #[test]
    fn concurrent_requests_all_complete_and_match_solo_decode() {
        // 8 concurrent clients on 4 slots: everything completes, and batched
        // decode gives each request exactly what a solo engine produces.
        let b = std::sync::Arc::new(tiny_batcher(4, 8));
        let prompts: Vec<Vec<i32>> = (0..8).map(|i| vec![i + 1, 2 * i + 3, 7]).collect();
        let mut handles = Vec::new();
        for p in prompts.clone() {
            let b = b.clone();
            handles.push(std::thread::spawn(move || b.generate(greedy(p, 6)).unwrap()));
        }
        let results: Vec<Completion> = handles.into_iter().map(|h| h.join().unwrap()).collect();

        let solo = Engine::new(SpectralModel::init(tiny_cfg(), 0));
        let opts = SampleOpts { temperature: 0.0, top_k: 0, seed: 0 };
        for (p, c) in prompts.iter().zip(&results) {
            assert_eq!(c.tokens, solo.generate_reencode(p, 6, &opts), "prompt {p:?}");
        }
        let s = b.stats().snapshot();
        assert_eq!((s.admitted, s.completed), (8, 8));
        assert_eq!(s.tokens_out, 8 * 6);
        let peak = s.peak_active;
        assert!(peak >= 2, "batched decode should overlap sequences (peak {peak})");
        let ids: Vec<u64> = results.iter().map(|c| c.request_id).collect();
        let mut dedup = ids.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), ids.len(), "request ids must be unique");
    }

    #[test]
    fn long_prompt_is_trimmed_to_context_budget() {
        let b = tiny_batcher(1, 2);
        // max_seq 32: a 100-token prompt must be trimmed, not panic.
        let c = b.generate(greedy((0..100).collect(), 4)).unwrap();
        assert_eq!(c.tokens.len(), 4);
        assert!(c.prompt_len <= 28);
    }

    #[test]
    fn empty_prompt_and_oversized_budget_are_clamped() {
        let b = tiny_batcher(1, 2);
        let c = b.generate(greedy(vec![], 10_000)).unwrap();
        assert!(!c.tokens.is_empty());
        assert!(c.tokens.len() <= 31, "max_new clamped to max_seq-1");
    }

    #[test]
    fn try_submit_sheds_load_when_queue_full() {
        // One slot + depth-1 queue, and a slow first request: eventually a
        // try_submit must observe a full queue.
        let b = tiny_batcher(1, 1);
        let mut pending = Vec::new();
        let mut shed = false;
        for i in 0..50 {
            match b.try_submit(greedy(vec![i], 20)) {
                Ok(rx) => pending.push(rx),
                Err(_) => {
                    shed = true;
                    break;
                }
            }
        }
        assert!(shed, "bounded queue must refuse work eventually");
        for rx in pending {
            assert!(rx.recv().is_ok());
        }
    }

    #[test]
    fn drop_with_queued_work_completes_in_flight() {
        let b = tiny_batcher(2, 4);
        let rx = b.submit(greedy(vec![5, 6], 4)).unwrap();
        drop(b); // closes the queue, scheduler drains, thread joins
        let c = rx.recv().expect("in-flight request still completes");
        assert_eq!(c.tokens.len(), 4);
    }

    #[test]
    fn streamed_tokens_concatenate_to_the_oneshot_completion() {
        let b = tiny_batcher(2, 4);
        let oneshot = b.generate(greedy(vec![3, 9, 27], 7)).unwrap();

        let rx = b.submit_streaming(greedy(vec![3, 9, 27], 7)).unwrap();
        let mut streamed = Vec::new();
        let mut done = None;
        for ev in rx {
            match ev {
                StreamEvent::Token(t) => streamed.push(t),
                StreamEvent::Done(c) => done = Some(c),
            }
        }
        let done = done.expect("terminal Done event");
        assert_eq!(streamed, done.tokens, "Token frames must concatenate to the completion");
        assert_eq!(streamed, oneshot.tokens, "streaming must not change greedy decode");
        assert!(done.ttft_ms.unwrap() > 0.0);
    }

    #[test]
    fn stop_on_first_token_reports_ttft_of_the_trimmed_token() {
        // A stop that matches the very first sampled token leaves zero output
        // tokens; a token WAS sampled, so ttft_ms stays Some (the old
        // unwrap_or(0.0) encoding reported a fake 0 ms here).
        let b = tiny_batcher(1, 2);
        let baseline = b.generate(greedy(vec![1, 2, 3], 4)).unwrap();
        let first = baseline.tokens[0];
        let c = b.generate(greedy_stop(vec![1, 2, 3], 4, vec![vec![first]])).unwrap();
        assert!(c.tokens.is_empty());
        assert_eq!(c.finish_reason, FinishReason::Stop);
        assert!(c.ttft_ms.unwrap() > 0.0);
    }

    #[test]
    fn chunked_prefill_is_token_identical_to_inline() {
        // A long prompt absorbed 4 tokens per step must decode exactly what
        // the unchunked engine produces.
        let cfg = EngineConfig { max_seq: 128, ..tiny_cfg() };
        let prompt: Vec<i32> = (0..90).map(|i| (i * 7 + 3) % 50).collect();
        let solo = Engine::new(SpectralModel::init(cfg, 0));
        let opts = SampleOpts { temperature: 0.0, top_k: 0, seed: 0 };
        let baseline = solo.generate_reencode(&prompt, 6, &opts);

        let b = Batcher::spawn_with(
            Engine::new(SpectralModel::init(cfg, 0)),
            BatchConfig { slots: 2, queue_depth: 4, prefill_chunk: 4, ..BatchConfig::default() },
        );
        let c = b.generate(greedy(prompt, 6)).unwrap();
        assert_eq!(c.tokens, baseline, "chunked prefill must not change the decode");
        assert!(b.stats().prefill_tokens() >= 89);
    }

    #[test]
    fn stop_sequence_truncates_output_and_reports_stop() {
        let b = tiny_batcher(2, 4);
        let baseline = b.generate(greedy(vec![1, 2, 3], 12)).unwrap();
        assert_eq!(baseline.finish_reason, FinishReason::Length);
        assert_eq!(baseline.tokens.len(), 12);

        // single-token stop (EOS semantics): cut at its first occurrence
        let eos = baseline.tokens[4];
        let first = baseline.tokens.iter().position(|&t| t == eos).unwrap();
        let c = b.generate(greedy_stop(vec![1, 2, 3], 12, vec![vec![eos]])).unwrap();
        assert_eq!(c.tokens, baseline.tokens[..first], "output truncated before EOS");
        assert_eq!(c.finish_reason, FinishReason::Stop);

        // two-token stop sequence: cut at its first window match
        let pair = vec![baseline.tokens[5], baseline.tokens[6]];
        let at = baseline.tokens.windows(2).position(|w| w == pair[..]).unwrap();
        let c = b.generate(greedy_stop(vec![1, 2, 3], 12, vec![pair])).unwrap();
        assert_eq!(c.tokens, baseline.tokens[..at]);
        assert_eq!(c.finish_reason, FinishReason::Stop);
        assert!(b.stats().stopped() >= 2);
    }

    #[test]
    fn unmatched_stop_runs_to_length() {
        let b = tiny_batcher(1, 2);
        // token -5 is never sampled, so the stop can never match
        let c = b.generate(greedy_stop(vec![4, 2], 6, vec![vec![-5], vec![-5, -5]])).unwrap();
        assert_eq!(c.tokens.len(), 6);
        assert_eq!(c.finish_reason, FinishReason::Length);
    }

    #[test]
    fn streamed_tokens_with_stop_match_the_trimmed_completion() {
        // Holdback: even with a multi-token stop sequence, the stream must
        // emit exactly the tokens the final (trimmed) completion contains.
        let b = tiny_batcher(2, 4);
        let baseline = b.generate(greedy(vec![7, 1], 10)).unwrap();
        let pair = vec![baseline.tokens[3], baseline.tokens[4]];

        let rx = b.submit_streaming(greedy_stop(vec![7, 1], 10, vec![pair])).unwrap();
        let mut streamed = Vec::new();
        let mut done = None;
        for ev in rx {
            match ev {
                StreamEvent::Token(t) => streamed.push(t),
                StreamEvent::Done(c) => done = Some(c),
            }
        }
        let done = done.expect("terminal Done event");
        assert_eq!(done.finish_reason, FinishReason::Stop);
        assert_eq!(streamed, done.tokens, "stream must never emit trimmed stop tokens");

        // a stop list on a streaming request that finishes by length still
        // flushes the held-back tail
        let rx = b.submit_streaming(greedy_stop(vec![7, 1], 5, vec![vec![-5, -5]])).unwrap();
        let mut streamed = Vec::new();
        let mut done = None;
        for ev in rx {
            match ev {
                StreamEvent::Token(t) => streamed.push(t),
                StreamEvent::Done(c) => done = Some(c),
            }
        }
        let done = done.unwrap();
        assert_eq!(done.finish_reason, FinishReason::Length);
        assert_eq!(streamed, done.tokens);
        assert_eq!(streamed.len(), 5);
    }

    #[test]
    fn stop_holdback_prefix_logic() {
        let stops = vec![vec![1, 2, 3], vec![9, 9]];
        assert_eq!(stop_holdback(&[5, 1], &stops), 1, "trailing 1 could start 1,2,3");
        assert_eq!(stop_holdback(&[5, 1, 2], &stops), 2);
        assert_eq!(stop_holdback(&[5, 9], &stops), 1);
        assert_eq!(stop_holdback(&[5, 4], &stops), 0);
        assert_eq!(stop_match(&[5, 1, 2, 3], &stops), Some(3));
        assert_eq!(stop_match(&[5, 9, 9], &stops), Some(2));
        assert_eq!(stop_match(&[5, 1, 2], &stops), None);
        // overlapping stops: the LONGEST match wins, so "###\n"-style stops
        // are trimmed whole even when "\n" alone is also a stop
        let overlapping = vec![vec![10], vec![35, 35, 35, 10]];
        assert_eq!(stop_match(&[7, 35, 35, 35, 10], &overlapping), Some(4));
        assert_eq!(stop_match(&[7, 10], &overlapping), Some(1));
    }

    #[test]
    fn dropped_stream_receiver_frees_the_slot() {
        // One slot: cancel the first (long) stream by dropping its receiver;
        // a second request must still get the slot and complete.
        let b = tiny_batcher(1, 2);
        let rx = b.submit_streaming(greedy(vec![4, 2], 30)).unwrap();
        let first = rx.recv();
        assert!(matches!(first, Ok(StreamEvent::Token(_))));
        drop(rx);
        let c = b.generate(greedy(vec![8, 1], 3)).unwrap();
        assert_eq!(c.tokens.len(), 3, "cancelled stream must release its slot");
        assert!(b.stats().cancelled() >= 1);
    }
}
