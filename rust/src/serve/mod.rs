//! `serve` — the pure-Rust spectral **inference engine**: KV-cached
//! incremental decoding, a continuous-batching scheduler with chunked
//! prefill, and a streaming HTTP/1.1 server, all built directly on the
//! `spectral` substrate.
//!
//! The paper's storage claim — the dense `(m, n)` matrix never exists —
//! holds on the serving path too: every MLP projection runs as
//! `x → (xU) ⊙ s → (·)Vᵀ` through [`crate::spectral::SpectralLinear`].
//! Where `coordinator::generate` re-encodes the whole window per token
//! through the AOT artifact (and needs PJRT), this subsystem decodes **one
//! token per step** against a per-sequence KV cache and needs nothing but
//! the standard library, so a checkpointed (or random-init) model serves on
//! any machine the crate builds on.
//!
//! Pieces:
//! * [`engine`] — the factored decoder: incremental KV path + the
//!   full-re-encode baseline, cross-sequence batched prefill, model
//!   checkpointing, and the sampler shared with `coordinator::generate`.
//!   The decoder math itself (RMSNorm, RoPE attention, spectral SwiGLU)
//!   lives in the **shared decoder blocks** of [`crate::train::blocks`],
//!   and `Engine::forward_full` *is* the training forward
//!   (`crate::train::decoder::decoder_fwd`) — one implementation, so the
//!   serving and training paths cannot drift and the KV-equivalence tests
//!   transitively pin training numerics.
//! * [`kv`] — fixed-capacity KV cache arena with slot reuse; no allocation
//!   on the decode path.
//! * [`batcher`] — continuous batching: bounded admission queue
//!   (`sync_channel` backpressure, as in `data::loader`), O(1) slot-based
//!   admission, **chunked prefill** (a long prompt is absorbed
//!   `prefill_chunk` tokens per step, interleaved with decode steps, so it
//!   cannot stall active sequences), one batched decode step per token
//!   across all active sequences, per-token streaming channels, EOS /
//!   stop-sequence termination (matched stops are trimmed; possible stop
//!   prefixes are held back from streams until decided), eviction of
//!   finished, stopped or cancelled sequences with a [`FinishReason`].
//! * [`server`] — `std::net` HTTP front-end (`POST /v1/generate`,
//!   `GET /healthz`, `GET /v1/stats`, `GET /metrics`) using `util::json`,
//!   with HTTP/1.1 keep-alive, a connection read deadline, and SSE
//!   streaming. `/metrics` renders the process-global [`crate::obs`]
//!   registry in Prometheus text exposition format.
//!
//! # Checkpoints
//!
//! [`SpectralModel`] saves/loads the `.sct` container in the
//! `params/layers/...` layout shared with the native trainer (the full
//! contract is documented in [`crate::train`]): a checkpoint written by
//! `sct train --backend native` — or mid-run by its checkpoint manager —
//! loads directly via `SpectralModel::load` / `sct serve --ckpt`, closing
//! the train → checkpoint → serve loop.
//!
//! # Streaming wire format (SSE)
//!
//! `POST /v1/generate` with `"stream": true` answers with
//! `Content-Type: text/event-stream` over chunked transfer encoding. Each
//! sampled token is flushed immediately as one Server-Sent-Events frame
//! (one HTTP chunk per frame):
//!
//! ```text
//! data: {"request_id": 7, "token": 104, "index": 0, "text": "h"}
//!
//! data: {"request_id": 7, "token": 105, "index": 1, "text": "i"}
//!
//! data: {"request_id": 7, "done": true, "completion": "hi",
//!        "prompt_tokens": 8, "finish_reason": "length", "queue_ms": 0.1,
//!        "decode_ms": 14.2, "tok_per_s": 140.8, "ttft_ms": 1.9}
//! ```
//!
//! The final frame carries `"done": true` plus the same usage stats a
//! non-streaming response returns, followed by the zero-length terminating
//! chunk. Every frame of a stream (and every non-streaming response) is
//! stamped with the same `request_id` — the process-unique id assigned at
//! admission, which also keys the request's span record in `traces.jsonl`
//! when tracing is on (see [`crate::obs::trace`]). `ttft_ms` is **omitted**
//! when the request produced no tokens (e.g. a stop sequence matched the
//! first sampled token), never reported as `0`. Concatenating the `token`
//! fields reproduces the non-streaming `tokens` array exactly (verified at
//! temperature 0 in the integration tests); per-frame `text` is a lossy
//! single-token decode, the final `completion` is the authoritative text.
//! Without `"stream": true` the response is a single JSON document with the
//! same usage fields.
//!
//! Requests may carry `"stop": [...]` — strings (tokenized stop sequences)
//! or integer token ids (EOS). A match ends generation, the matched tokens
//! are trimmed and never emitted as `data:` frames (tokens that could still
//! begin a match are held back until decided), and `finish_reason` is
//! `"stop"` instead of `"length"`. At most 8 stop sequences are honored per
//! request ([`batcher::MAX_STOP_SEQUENCES`]; extras are ignored), and an
//! out-of-vocab token id can never match, so it is dropped.
//!
//! # Streaming/serving config keys
//!
//! `[serve]` TOML section and `sct serve` flags (see [`ServeConfig`]):
//! `addr`, `slots`, `queue_depth`, `max_new` — as before;
//! `prefill_chunk` — prompt tokens absorbed per scheduler step (the
//! chunked-prefill fairness budget; 0 = unchunked); `keep_alive_ms` — the
//! connection read deadline / keep-alive idle window (0 = no deadline).
//!
//! # Observability
//!
//! `GET /v1/stats` reports lifetime counters (`admitted`, `completed`,
//! `tokens_out`, `peak_active`, `prefill_tokens`, `cancelled`, `stopped`)
//! plus the **live** gauges `queue_depth` (requests accepted but not yet
//! admitted to a slot) and `active_slots` (sequences currently decoding) —
//! a [`batcher::StatsSnapshot`]. `GET /metrics` exposes the same signals as
//! Prometheus series (`sct_serve_*`, `sct_http_requests_total{route=...}`)
//! with queue-wait / TTFT / decode-step / prefill-chunk latency histograms;
//! `sct serve --trace-out traces.jsonl` additionally records one span per
//! request. See [`crate::obs`] for the registry and exposition format.
//!
//! Correctness anchors: at temperature 0 the KV-cached path is
//! token-identical to the full re-encode baseline (tested in [`engine`]),
//! chunked prefill is token-identical to inline prefill (tested in
//! [`batcher`]), and SSE frames concatenate to the non-streaming output
//! (integration tests). Throughput, time-to-first-token, and inter-token
//! latency are measured by `benches/serve_throughput.rs`, which emits
//! `BENCH_serve.json` for the CI trajectory.

pub mod batcher;
pub mod engine;
pub mod kv;
pub mod server;

pub use batcher::{
    BatchConfig, Batcher, Completion, FinishReason, Request, StatsSnapshot, StreamEvent,
};
pub use engine::{sample_logits, Engine, EngineConfig, SampleOpts, SpectralModel};
pub use kv::KvCache;
pub use server::{
    http_exchange, http_get_json, http_get_text, http_post_json, http_post_sse, http_roundtrip,
    ServeConfig, Server, SseFrame,
};
