//! `serve` — the pure-Rust spectral **inference engine**: KV-cached
//! incremental decoding, a continuous-batching scheduler, and a minimal
//! HTTP/1.1 server, all built directly on the `spectral` substrate.
//!
//! The paper's storage claim — the dense `(m, n)` matrix never exists —
//! holds on the serving path too: every MLP projection runs as
//! `x → (xU) ⊙ s → (·)Vᵀ` through [`crate::spectral::SpectralLinear`].
//! Where `coordinator::generate` re-encodes the whole window per token
//! through the AOT artifact (and needs PJRT), this subsystem decodes **one
//! token per step** against a per-sequence KV cache and needs nothing but
//! the standard library, so a checkpointed (or random-init) model serves on
//! any machine the crate builds on.
//!
//! Pieces:
//! * [`engine`] — the factored decoder forward (RMSNorm, RoPE attention,
//!   spectral SwiGLU), incremental + full-re-encode paths, model
//!   checkpointing, and the sampler shared with `coordinator::generate`.
//! * [`kv`] — fixed-capacity KV cache arena with slot reuse; no allocation
//!   on the decode path.
//! * [`batcher`] — continuous batching: bounded admission queue
//!   (`sync_channel` backpressure, as in `data::loader`), slot-based
//!   admission, one batched decode step per token across all active
//!   sequences, eviction of finished ones.
//! * [`server`] — `std::net` HTTP front-end (`POST /v1/generate`,
//!   `GET /healthz`, `GET /v1/stats`) using `util::json`.
//!
//! Correctness anchor: at temperature 0 the KV-cached path is
//! token-identical to the full re-encode baseline (tested in [`engine`]);
//! throughput of batched vs sequential serving is measured by
//! `benches/serve_throughput.rs`.

pub mod batcher;
pub mod engine;
pub mod kv;
pub mod server;

pub use batcher::{Batcher, Completion, Request};
pub use engine::{sample_logits, Engine, EngineConfig, SampleOpts, SpectralModel};
pub use kv::KvCache;
pub use server::{http_get_json, http_post_json, http_roundtrip, ServeConfig, Server};
