//! `serve` — the pure-Rust spectral **inference engine**: KV-cached
//! incremental decoding, continuous-batching schedulers with chunked
//! prefill — sharded across N engine-clone workers behind a load-aware
//! gateway — and a streaming HTTP/1.1 server with a typed, versioned wire
//! API, all built directly on the `spectral` substrate.
//!
//! The paper's storage claim — the dense `(m, n)` matrix never exists —
//! holds on the serving path too: every MLP projection runs as
//! `x → (xU) ⊙ s → (·)Vᵀ` through [`crate::spectral::SpectralLinear`].
//! Where `coordinator::generate` re-encodes the whole window per token
//! through the AOT artifact (and needs PJRT), this subsystem decodes **one
//! token per step** against a per-sequence KV cache and needs nothing but
//! the standard library, so a checkpointed (or random-init) model serves on
//! any machine the crate builds on.
//!
//! Pieces:
//! * [`engine`] — the factored decoder: incremental KV path + the
//!   full-re-encode baseline, cross-sequence batched prefill, model
//!   checkpointing, and the sampler shared with `coordinator::generate`.
//!   The decoder math itself (RMSNorm, RoPE attention, spectral SwiGLU)
//!   lives in the **shared decoder blocks** of [`crate::train::blocks`],
//!   and `Engine::forward_full` *is* the training forward
//!   (`crate::train::decoder::decoder_fwd`) — one implementation, so the
//!   serving and training paths cannot drift and the KV-equivalence tests
//!   transitively pin training numerics.
//! * [`kv`] — fixed-capacity KV cache arena with slot reuse; no allocation
//!   on the decode path.
//! * [`batcher`] — continuous batching: bounded admission queue
//!   (`sync_channel` backpressure, as in `data::loader`), O(1) slot-based
//!   admission, **chunked prefill** (a long prompt is absorbed
//!   `prefill_chunk` tokens per step, interleaved with decode steps, so it
//!   cannot stall active sequences), one batched decode step per token
//!   across all active sequences, per-token streaming channels, EOS /
//!   stop-sequence termination (matched stops are trimmed; possible stop
//!   prefixes are held back from streams until decided), eviction of
//!   finished, stopped or cancelled sequences with a [`FinishReason`].
//! * [`gateway`] — **sharded multi-engine serving**: N independent worker
//!   schedulers (one [`Batcher`] + KV arena + [`Engine`] clone each) behind
//!   least-outstanding-tokens placement with a queue-depth tiebreak. A
//!   request is shed with 503 only when EVERY worker's bounded queue is
//!   full, and placement never changes T=0 output (every worker runs the
//!   same bit-deterministic kernels on the same weights).
//! * [`api`] — the typed wire surface: [`api::GenerateRequest`] /
//!   [`api::GenerateResponse`] / [`api::ErrorEnvelope`] / the versioned
//!   stats document ([`api::stats_json`]). Parsing and rendering live here;
//!   the server only moves bytes.
//! * [`server`] — `std::net` HTTP front-end (`POST /v1/generate`,
//!   `GET /healthz`, `GET /v1/stats`, `GET /metrics`) using `util::json`,
//!   with HTTP/1.1 keep-alive, a connection read deadline, and SSE
//!   streaming. `/metrics` renders the process-global [`crate::obs`]
//!   registry in Prometheus text exposition format.
//!
//! # Checkpoints
//!
//! [`SpectralModel`] saves/loads the `.sct` container in the
//! `params/layers/...` layout shared with the native trainer (the full
//! contract is documented in [`crate::train`]): a checkpoint written by
//! `sct train --backend native` — or mid-run by its checkpoint manager —
//! loads directly via `SpectralModel::load` / `sct serve --ckpt`, closing
//! the train → checkpoint → serve loop.
//!
//! # Wire API (v1)
//!
//! Every body on the wire maps to a type in [`api`]. A one-shot generation:
//!
//! ```text
//! POST /v1/generate
//! {"prompt": "hi", "tokens": 4, "temperature": 0, "top_k": 0, "seed": 0,
//!  "stop": ["\n", 0], "stream": false}
//!
//! 200 OK  (api::GenerateResponse)
//! {"request_id": 7, "worker": 1, "completion": "...", "tokens": [104, ...],
//!  "prompt_tokens": 2, "finish_reason": "length", "queue_ms": 0.1,
//!  "decode_ms": 14.2, "tok_per_s": 140.8, "ttft_ms": 1.9}
//! ```
//!
//! `prompt_ids` (an integer array) may replace `prompt`; all other request
//! fields are optional (`temperature` 0.8, `top_k` 40, `seed` 0, `tokens`
//! from the server's `max_new` default). `worker` is the gateway worker
//! index that served the request — informational only, since placement
//! cannot change T=0 output.
//!
//! **Errors.** Every non-2xx response — malformed bodies (400), unknown
//! routes (404), wrong verbs (405), oversize bodies (413), fleet-wide load
//! shed (503) — is one [`api::ErrorEnvelope`] with
//! `Content-Type: application/json`:
//!
//! ```text
//! 503 Service Unavailable
//! {"code": "queue_full",
//!  "message": "admission queue full on every worker (load shed)",
//!  "request_id": 12}
//! ```
//!
//! `code` is a stable machine-readable string (`bad_request`, `not_found`,
//! `method_not_allowed`, `payload_too_large`, `queue_full`, `internal`);
//! the HTTP status is derived from it. `request_id` is stamped on errors
//! too, so failed requests correlate with server logs and spans.
//!
//! **Stats (versioned).** `GET /v1/stats` keeps the flat single-scheduler
//! fields bit-compatible for old clients — now the aggregate across
//! workers — and adds a `workers: [...]` array of per-worker snapshots:
//!
//! ```text
//! {"admitted": 9, "completed": 9, "tokens_out": 72, "peak_active": 3,
//!  "prefill_tokens": 41, "cancelled": 0, "stopped": 1, "queue_depth": 0,
//!  "active_slots": 0,
//!  "workers": [
//!    {"worker": 0, "admitted": 5, "completed": 5, ...},
//!    {"worker": 1, "admitted": 4, "completed": 4, ...}]}
//! ```
//!
//! Counters and live gauges sum across workers; `peak_active` is the sum of
//! per-worker peaks (an upper bound on simultaneously active sequences,
//! exact when `workers = 1`).
//!
//! # Streaming wire format (SSE)
//!
//! `POST /v1/generate` with `"stream": true` answers with
//! `Content-Type: text/event-stream` over chunked transfer encoding. Each
//! sampled token is flushed immediately as one Server-Sent-Events frame
//! (one HTTP chunk per frame):
//!
//! ```text
//! data: {"request_id": 7, "token": 104, "index": 0, "text": "h"}
//!
//! data: {"request_id": 7, "token": 105, "index": 1, "text": "i"}
//!
//! data: {"request_id": 7, "done": true, "completion": "hi",
//!        "prompt_tokens": 8, "finish_reason": "length", "queue_ms": 0.1,
//!        "decode_ms": 14.2, "tok_per_s": 140.8, "ttft_ms": 1.9}
//! ```
//!
//! The final frame carries `"done": true` plus the same usage stats a
//! non-streaming response returns, followed by the zero-length terminating
//! chunk. Every frame of a stream (and every non-streaming response) is
//! stamped with the same `request_id` — the process-unique id assigned at
//! admission, which also keys the request's span record in `traces.jsonl`
//! when tracing is on (see [`crate::obs::trace`]). `ttft_ms` is **omitted**
//! when the request produced no tokens (e.g. a stop sequence matched the
//! first sampled token), never reported as `0`. Concatenating the `token`
//! fields reproduces the non-streaming `tokens` array exactly (verified at
//! temperature 0 in the integration tests); per-frame `text` is a lossy
//! single-token decode, the final `completion` is the authoritative text.
//! Without `"stream": true` the response is a single JSON document with the
//! same usage fields.
//!
//! Requests may carry `"stop": [...]` — strings (tokenized stop sequences)
//! or integer token ids (EOS). A match ends generation, the matched tokens
//! are trimmed and never emitted as `data:` frames (tokens that could still
//! begin a match are held back until decided), and `finish_reason` is
//! `"stop"` instead of `"length"`. At most 8 stop sequences are honored per
//! request ([`batcher::MAX_STOP_SEQUENCES`]; extras are ignored), and an
//! out-of-vocab token id can never match, so it is dropped.
//!
//! # Streaming/serving config keys
//!
//! `[serve]` TOML section and `sct serve` flags (see [`ServeConfig`]):
//! `addr`; `workers` — worker schedulers behind the gateway, one engine
//! clone + KV arena each (`--workers` flag > `[serve] workers` TOML >
//! `SCT_WORKERS` env > 1); `slots` and `queue_depth` — **per worker**;
//! `max_new` — default token budget; `prefill_chunk` — prompt tokens
//! absorbed per scheduler step (the chunked-prefill fairness budget;
//! 0 = unchunked); `keep_alive_ms` — the connection read deadline /
//! keep-alive idle window (0 = no deadline).
//!
//! # Observability
//!
//! `GET /v1/stats` reports lifetime counters (`admitted`, `completed`,
//! `tokens_out`, `peak_active`, `prefill_tokens`, `cancelled`, `stopped`)
//! plus the **live** gauges `queue_depth` (requests accepted but not yet
//! admitted to a slot) and `active_slots` (sequences currently decoding) —
//! a [`batcher::StatsSnapshot`] per worker plus the aggregate (schema
//! above). `GET /metrics` exposes the same signals as Prometheus series —
//! every `sct_serve_*` series carries a `worker="i"` label matching the
//! `workers` array index, plus `sct_http_requests_total{route=...}` — with
//! queue-wait / TTFT / decode-step / prefill-chunk latency histograms;
//! `sct serve --trace-out traces.jsonl` additionally records one span per
//! request. See [`crate::obs`] for the registry and exposition format.
//!
//! Correctness anchors: at temperature 0 the KV-cached path is
//! token-identical to the full re-encode baseline (tested in [`engine`]),
//! chunked prefill is token-identical to inline prefill (tested in
//! [`batcher`]), and SSE frames concatenate to the non-streaming output
//! (integration tests). Throughput, time-to-first-token, and inter-token
//! latency are measured by `benches/serve_throughput.rs`, which emits
//! `BENCH_serve.json` for the CI trajectory.

pub mod api;
pub mod batcher;
pub mod engine;
pub mod gateway;
pub mod kv;
pub mod server;

pub use api::{ErrorCode, ErrorEnvelope, GenerateRequest, GenerateResponse};
pub use batcher::{
    BatchConfig, Batcher, Completion, FinishReason, Request, StatsSnapshot, StreamEvent,
    SubmitError,
};
pub use engine::{sample_logits, Engine, EngineConfig, SampleOpts, SpectralModel};
pub use gateway::{Gateway, GatewayConfig, Placed};
pub use kv::KvCache;
pub use server::{
    http_exchange, http_get_json, http_get_text, http_post_json, http_post_sse, http_roundtrip,
    ServeConfig, Server, SseFrame,
};
