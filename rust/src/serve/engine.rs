//! The spectral inference engine: a pure-Rust transformer decoder whose MLP
//! projections are [`SpectralLinear`] triples — every MLP matmul computes
//! `x → (xU) ⊙ s → (·)Vᵀ`, so no `(m, n)` weight ever exists, during
//! serving exactly as during training (paper §3's "never materialized"
//! claim, now on the deployment path).
//!
//! The decoder math itself — RMSNorm, RoPE, SiLU, causal attention — lives
//! in [`crate::train::blocks`], shared with the native trainer so the two
//! paths cannot drift. Two forward paths:
//! * [`Engine::step_batch`] — incremental decode: one token per sequence per
//!   call, attending over that sequence's [`KvCache`] line. This is the
//!   serving hot path; a step over B admitted sequences shares every weight
//!   matrix across the batch rows (the projections and the logits matmul run
//!   as one (B, d) GEMM), which is where continuous batching earns its
//!   throughput on a memory-bound CPU decode.
//! * [`Engine::forward_full`] — whole-sequence re-encode with an explicit
//!   causal mask, which IS the training forward
//!   ([`crate::train::decoder::decoder_fwd`]). The correctness baseline:
//!   the KV path must produce token-identical greedy output (tested below),
//!   and by the same tests the KV path matches what training computes.
//!
//! The sampler ([`SampleOpts`], [`sample_logits`]) lives here and is shared
//! with `coordinator::generate`, so the baseline and the server sample
//! identically for a given seed.

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::kv::{KvCache, SlotId};
use crate::checkpoint::format::{read_checkpoint, write_checkpoint, NamedTensor};
use crate::spectral::{Matrix, SpectralLinear};
use crate::train::blocks::{
    add_into, attend_head_row, attend_row, rmsnorm, silu, Rope, ATTN_PAR_WORK,
};
use crate::train::decoder::decoder_fwd;
use crate::util::pool;
use crate::util::rng::Rng;

// ---------------------------------------------------------------------------
// sampling (shared with coordinator::generate)
// ---------------------------------------------------------------------------

/// Sampling configuration.
#[derive(Debug, Clone)]
pub struct SampleOpts {
    /// 0.0 => greedy argmax.
    pub temperature: f32,
    /// keep only the top-k logits before sampling (0 = all).
    pub top_k: usize,
    pub seed: u64,
}

impl Default for SampleOpts {
    fn default() -> SampleOpts {
        SampleOpts { temperature: 0.8, top_k: 40, seed: 0 }
    }
}

/// Sample one token id from a logits row. `temperature <= 0` is greedy
/// argmax; `top_k == 0` (or >= vocab) keeps the full distribution.
pub fn sample_logits(logits: &[f32], temperature: f32, top_k: usize, rng: &mut Rng) -> i32 {
    if temperature <= 0.0 {
        return argmax(logits) as i32;
    }
    // top-k filter
    let k = if top_k == 0 { logits.len() } else { top_k.min(logits.len()) };
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    idx.sort_unstable_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap());
    let kept = &idx[..k];
    // softmax over kept at temperature
    let mx = logits[kept[0]];
    let weights: Vec<f64> =
        kept.iter().map(|&i| (((logits[i] - mx) / temperature) as f64).exp()).collect();
    let total: f64 = weights.iter().sum();
    let mut u = rng.f64() * total;
    for (w, &i) in weights.iter().zip(kept) {
        u -= w;
        if u <= 0.0 {
            return i as i32;
        }
    }
    kept[k - 1] as i32
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

// ---------------------------------------------------------------------------
// model
// ---------------------------------------------------------------------------

/// Architecture of a spectral decoder (mirrors the training `ModelSpec`
/// family: RMSNorm, RoPE attention, SwiGLU MLP with spectral gate/up/down).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EngineConfig {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ffn: usize,
    /// Spectral rank k of the MLP triples.
    pub rank: usize,
    /// KV cache capacity per sequence (absolute RoPE positions).
    pub max_seq: usize,
    /// Tied LM head (`logits = x Eᵀ`) vs a separate `(d_model, vocab)` head.
    pub tied: bool,
}

impl Default for EngineConfig {
    /// The `tiny_r8` testbed shape — small enough that tests and the demo
    /// decode in milliseconds.
    fn default() -> EngineConfig {
        EngineConfig {
            vocab: 256,
            d_model: 64,
            n_layers: 2,
            n_heads: 4,
            d_ffn: 192,
            rank: 8,
            max_seq: 128,
            tied: true,
        }
    }
}

impl EngineConfig {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    fn validate(&self) {
        assert!(self.vocab > 0 && self.n_layers > 0 && self.max_seq >= 2);
        assert!(
            self.n_heads > 0 && self.d_model > 0,
            "need at least one head and a positive width"
        );
        assert!(self.d_model % self.n_heads == 0, "d_model must divide into heads");
        assert!(self.head_dim() % 2 == 0, "RoPE needs an even head_dim");
        assert!(
            self.rank >= 1 && self.rank <= self.d_model.min(self.d_ffn),
            "rank {} out of range for ({}, {})",
            self.rank,
            self.d_model,
            self.d_ffn
        );
    }
}

/// One decoder block's weights. Attention stays dense (the paper leaves it
/// dense, §4.2); the SwiGLU MLP is spectral.
#[derive(Clone)]
pub struct LayerWeights {
    pub wq: Matrix,
    pub wk: Matrix,
    pub wv: Matrix,
    pub wo: Matrix,
    pub ln1: Vec<f32>,
    pub ln2: Vec<f32>,
    pub gate: SpectralLinear,
    pub up: SpectralLinear,
    pub down: SpectralLinear,
}

/// Full model: embeddings, per-layer weights, final norm, and an optional
/// untied head (`None` = tied, `logits = x Eᵀ`).
#[derive(Clone)]
pub struct SpectralModel {
    pub cfg: EngineConfig,
    pub embed: Matrix,
    pub layers: Vec<LayerWeights>,
    pub ln_f: Vec<f32>,
    pub head: Option<Matrix>,
}

impl SpectralModel {
    /// Random init matching the training-side recipe: Glorot-normal dense
    /// weights, variance-matched orthonormal spectral triples, 0.02-σ embed.
    pub fn init(cfg: EngineConfig, seed: u64) -> SpectralModel {
        cfg.validate();
        let mut rng = Rng::new(seed);
        let (d, f, k) = (cfg.d_model, cfg.d_ffn, cfg.rank);
        let glorot = |rng: &mut Rng, m: usize, n: usize| {
            Matrix::randn(rng, m, n, (2.0 / (m + n) as f32).sqrt())
        };
        let layers = (0..cfg.n_layers)
            .map(|_| LayerWeights {
                wq: glorot(&mut rng, d, d),
                wk: glorot(&mut rng, d, d),
                wv: glorot(&mut rng, d, d),
                wo: glorot(&mut rng, d, d),
                ln1: vec![1.0; d],
                ln2: vec![1.0; d],
                gate: SpectralLinear::init(&mut rng, d, f, k),
                up: SpectralLinear::init(&mut rng, d, f, k),
                down: SpectralLinear::init(&mut rng, f, d, k),
            })
            .collect();
        let embed = Matrix::randn(&mut rng, cfg.vocab, d, 0.02);
        let head = if cfg.tied { None } else { Some(glorot(&mut rng, d, cfg.vocab)) };
        SpectralModel { cfg, embed, layers, ln_f: vec![1.0; d], head }
    }

    /// Parameter count — compact factors only, k(m+n+1) per projection,
    /// summed per layer (layers may carry different ranks after a
    /// `rank`-subsystem transition).
    pub fn param_count(&self) -> usize {
        let d = self.cfg.d_model;
        let spectral: usize = self
            .layers
            .iter()
            .map(|l| l.gate.param_count() + l.up.param_count() + l.down.param_count())
            .sum();
        self.cfg.vocab * d
            + self.cfg.n_layers * (4 * d * d + 2 * d)
            + spectral
            + d
            + self.head.as_ref().map_or(0, |h| h.rows * h.cols)
    }

    /// Rank of each layer's MLP triples. Uniform after [`SpectralModel::init`];
    /// heterogeneous after per-layer transitions by the `rank` subsystem
    /// (the gate/up/down triples of one layer always share a rank).
    pub fn layer_ranks(&self) -> Vec<usize> {
        self.layers.iter().map(|l| l.gate.k()).collect()
    }

    /// Project final hidden states to logits through the tied or untied head.
    pub fn logits(&self, hf: &Matrix) -> Matrix {
        match &self.head {
            Some(head) => hf.matmul(head),
            None => hf.matmul_t(&self.embed),
        }
    }

    // -- checkpoint I/O (the `.sct` params layout; see `train` module docs) --

    /// The model as named tensors in the shared `params/layers/...` layout
    /// (plus a `model/meta` architecture tensor, so loading is
    /// self-contained). The trainer appends its `opt/...` tensors to this
    /// same list — a serve checkpoint is a strict subset of a training one.
    pub fn to_tensors(&self) -> Vec<NamedTensor> {
        let c = &self.cfg;
        // 8 header entries + one rank per layer: heterogeneous per-layer
        // ranks are part of the checkpoint contract (see `crate::train`
        // module docs). Readers accept the legacy 8-entry form too.
        let mut meta: Vec<i32> = vec![
            c.vocab as i32,
            c.d_model as i32,
            c.n_layers as i32,
            c.n_heads as i32,
            c.d_ffn as i32,
            c.rank as i32,
            c.max_seq as i32,
            c.tied as i32,
        ];
        meta.extend(self.layer_ranks().iter().map(|&k| k as i32));
        let mut tensors = vec![
            NamedTensor::i32("model/meta", vec![meta.len()], &meta),
            NamedTensor::f32("params/embed", vec![c.vocab, c.d_model], &self.embed.data),
        ];
        for (i, l) in self.layers.iter().enumerate() {
            let mat = |name: &str, m: &Matrix| {
                NamedTensor::f32(&format!("params/layers/{i}/{name}"), vec![m.rows, m.cols], &m.data)
            };
            let vec1 = |name: &str, v: &[f32]| {
                NamedTensor::f32(&format!("params/layers/{i}/{name}"), vec![v.len()], v)
            };
            tensors.extend([
                mat("attn/wq", &l.wq),
                mat("attn/wk", &l.wk),
                mat("attn/wv", &l.wv),
                mat("attn/wo", &l.wo),
                vec1("ln1", &l.ln1),
                vec1("ln2", &l.ln2),
            ]);
            for (nm, sl) in [("gate", &l.gate), ("up", &l.up), ("down", &l.down)] {
                tensors.extend([
                    mat(&format!("mlp/{nm}/u"), &sl.u),
                    vec1(&format!("mlp/{nm}/s"), &sl.s),
                    mat(&format!("mlp/{nm}/v"), &sl.v),
                ]);
            }
        }
        tensors.push(NamedTensor::f32("params/ln_f", vec![c.d_model], &self.ln_f));
        if let Some(h) = &self.head {
            tensors.push(NamedTensor::f32("params/head", vec![h.rows, h.cols], &h.data));
        }
        tensors
    }

    /// Rebuild a model from `model/meta` + `params/...` tensors. Extra
    /// tensors (the trainer's `opt/...` moments) are ignored, so a
    /// mid-training checkpoint loads directly.
    pub fn from_tensors(tensors: &[NamedTensor]) -> Result<SpectralModel> {
        fn find<'a>(tensors: &'a [NamedTensor], name: &str) -> Result<&'a NamedTensor> {
            tensors
                .iter()
                .find(|t| t.name == name)
                .with_context(|| format!("checkpoint missing tensor {name:?}"))
        }
        let matrix = |name: String| -> Result<Matrix> {
            let t = find(tensors, &name)?;
            if t.shape.len() != 2 {
                bail!("{}: expected 2-D shape, got {:?}", t.name, t.shape);
            }
            Ok(Matrix::from_vec(t.shape[0], t.shape[1], t.as_f32()?))
        };
        let vector = |name: String| -> Result<Vec<f32>> { find(tensors, &name)?.as_f32() };

        let meta = find(tensors, "model/meta")?.as_i32()?;
        if meta.len() < 8 {
            bail!("model/meta has {} entries, expected at least 8", meta.len());
        }
        let cfg = EngineConfig {
            vocab: meta[0] as usize,
            d_model: meta[1] as usize,
            n_layers: meta[2] as usize,
            n_heads: meta[3] as usize,
            d_ffn: meta[4] as usize,
            rank: meta[5] as usize,
            max_seq: meta[6] as usize,
            tied: meta[7] != 0,
        };
        cfg.validate();
        // Per-layer ranks: present in checkpoints written since the rank
        // subsystem landed; a legacy 8-entry meta means the uniform
        // `cfg.rank` applies everywhere.
        let meta_ranks: Vec<usize> = if meta.len() == 8 {
            vec![cfg.rank; cfg.n_layers]
        } else if meta.len() == 8 + cfg.n_layers {
            meta[8..].iter().map(|&r| r as usize).collect()
        } else {
            bail!(
                "model/meta has {} entries, expected 8 or 8 + n_layers ({})",
                meta.len(),
                8 + cfg.n_layers
            );
        };
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for (i, &want_k) in meta_ranks.iter().enumerate() {
            let triple = |nm: &str| -> Result<SpectralLinear> {
                Ok(SpectralLinear {
                    u: matrix(format!("params/layers/{i}/mlp/{nm}/u"))?,
                    s: vector(format!("params/layers/{i}/mlp/{nm}/s"))?,
                    v: matrix(format!("params/layers/{i}/mlp/{nm}/v"))?,
                })
            };
            let layer = LayerWeights {
                wq: matrix(format!("params/layers/{i}/attn/wq"))?,
                wk: matrix(format!("params/layers/{i}/attn/wk"))?,
                wv: matrix(format!("params/layers/{i}/attn/wv"))?,
                wo: matrix(format!("params/layers/{i}/attn/wo"))?,
                ln1: vector(format!("params/layers/{i}/ln1"))?,
                ln2: vector(format!("params/layers/{i}/ln2"))?,
                gate: triple("gate")?,
                up: triple("up")?,
                down: triple("down")?,
            };
            // Shape consistency: a mismatched factor would fail silently
            // deep in a matmul, so check here with names attached.
            if !(1..=cfg.d_model.min(cfg.d_ffn)).contains(&want_k) {
                bail!("layer {i}: rank {want_k} out of range for ({}, {})", cfg.d_model, cfg.d_ffn);
            }
            for (nm, sl, m_rows, n_rows) in [
                ("gate", &layer.gate, cfg.d_model, cfg.d_ffn),
                ("up", &layer.up, cfg.d_model, cfg.d_ffn),
                ("down", &layer.down, cfg.d_ffn, cfg.d_model),
            ] {
                if sl.u.rows != m_rows
                    || sl.v.rows != n_rows
                    || sl.u.cols != want_k
                    || sl.v.cols != want_k
                    || sl.s.len() != want_k
                {
                    bail!(
                        "layer {i} mlp/{nm}: factor shapes u {}x{}, s {}, v {}x{} \
                         inconsistent with rank {want_k} for a ({m_rows}, {n_rows}) projection",
                        sl.u.rows,
                        sl.u.cols,
                        sl.s.len(),
                        sl.v.rows,
                        sl.v.cols,
                    );
                }
            }
            layers.push(layer);
        }
        let head = if cfg.tied { None } else { Some(matrix("params/head".into())?) };
        Ok(SpectralModel {
            cfg,
            embed: matrix("params/embed".into())?,
            layers,
            ln_f: vector("params/ln_f".into())?,
            head,
        })
    }

    /// Save as a `.sct` checkpoint (see [`SpectralModel::to_tensors`]).
    pub fn save(&self, path: &Path) -> Result<()> {
        write_checkpoint(path, 0, &self.to_tensors())
    }

    /// Load a `.sct` checkpoint written by [`SpectralModel::save`] or by
    /// `train::NativeTrainer::save` — the layouts are the same.
    pub fn load(path: &Path) -> Result<SpectralModel> {
        let (_step, tensors) = read_checkpoint(path)?;
        SpectralModel::from_tensors(&tensors)
    }
}

// ---------------------------------------------------------------------------
// engine
// ---------------------------------------------------------------------------

/// Model + precomputed RoPE tables, ready to decode. `Clone` replicates the
/// model (compact factors — cheap) for the gateway's per-worker engines.
#[derive(Clone)]
pub struct Engine {
    pub model: SpectralModel,
    rope: Rope,
}

impl Engine {
    pub fn new(model: SpectralModel) -> Engine {
        let rope = Rope::new(model.cfg.max_seq, model.cfg.head_dim());
        Engine { model, rope }
    }

    pub fn cfg(&self) -> &EngineConfig {
        &self.model.cfg
    }

    /// Fresh KV arena sized for this model.
    pub fn new_kv(&self, slots: usize) -> KvCache {
        let c = &self.model.cfg;
        KvCache::new(slots, c.n_layers, c.max_seq, c.d_model)
    }

    /// One incremental decode step for a batch of sequences: `tokens[i]` is
    /// appended to the sequence in `slots[i]` at its next position, and the
    /// returned `(B, vocab)` matrix holds the next-token logits per row.
    /// All per-row math is independent, so decoding B sequences in one call
    /// is exactly equivalent to B single-row calls — the batch exists to
    /// share the weight-matrix traffic.
    pub fn step_batch(&self, tokens: &[i32], slots: &[SlotId], kv: &mut KvCache) -> Matrix {
        let x = self.advance_batch(tokens, slots, kv);
        let xf = rmsnorm(&x, &self.model.ln_f);
        self.model.logits(&xf) // (B, vocab)
    }

    /// Feed a prompt's tokens into `slot` without computing logits — the
    /// admission-path fast prefill (the logits head is the single largest
    /// matmul per step and its output would be discarded).
    ///
    /// **Fused**: the whole prompt advances in ONE batched pass — every
    /// projection runs as a single `(T, d) @ (d, ·)` matmul over all
    /// positions instead of one `(1, d)` matmul per position — and is
    /// bit-identical to per-position prefill (rows are independent through
    /// every op, and attention row `i` sees exactly the KV prefix
    /// `0..=pos_i`; pinned by the determinism tests).
    pub fn prefill(&self, tokens: &[i32], slot: SlotId, kv: &mut KvCache) {
        if tokens.is_empty() {
            return;
        }
        let slots = vec![slot; tokens.len()];
        self.prefill_batch(tokens, &slots, kv);
    }

    /// One chunked-prefill step: append `tokens[i]` (the next prompt token
    /// of the sequence in `slots[i]`) to its KV line, skipping the logits
    /// head. Rows may come from *different* sequences at *different*
    /// positions — the batcher uses this to absorb several prompts at once
    /// while sharing the projection weight traffic, exactly like a decode
    /// batch. A slot may appear on **multiple rows** with its prompt tokens
    /// in order (a fused multi-token run): row `j` of a slot's run lands at
    /// position `len + j`, and its attention sees the run's earlier rows
    /// through the KV cache — so one call absorbs a whole chunk per
    /// sequence with one batched matmul per projection.
    pub fn prefill_batch(&self, tokens: &[i32], slots: &[SlotId], kv: &mut KvCache) {
        self.advance_batch(tokens, slots, kv);
    }

    /// Shared body of [`Engine::step_batch`]/[`Engine::prefill`]: run the
    /// layer stack, populate the KV cache, return the final hidden states.
    /// Attention runs head-parallel across the pool — task `(row, head)`
    /// writes the disjoint stripe `y[row, hb..hb+hd]` with the same
    /// [`attend_head_row`] kernel the serial path uses, so decode is
    /// bit-identical at any thread count.
    fn advance_batch(&self, tokens: &[i32], slots: &[SlotId], kv: &mut KvCache) -> Matrix {
        let c = &self.model.cfg;
        let bsz = tokens.len();
        assert_eq!(bsz, slots.len(), "one slot per token");
        let d = c.d_model;
        // A slot may appear several times with consecutive tokens (fused
        // multi-token prefill): row j of its run lands at len + j. One
        // O(B + slots) pass with a per-slot running counter — B can be a
        // whole prompt (Engine::prefill fuses the full prompt in one call).
        let mut seen = vec![0usize; kv.slots];
        let positions: Vec<usize> = slots
            .iter()
            .map(|&s| {
                let p = kv.len(s) + seen[s];
                seen[s] += 1;
                p
            })
            .collect();
        for &p in &positions {
            assert!(p < c.max_seq, "KV cache full (max_seq {})", c.max_seq);
        }

        // embed current tokens
        let mut x = Matrix::zeros(bsz, d);
        for (i, &t) in tokens.iter().enumerate() {
            let t = (t.max(0) as usize) % c.vocab;
            x.row_mut(i).copy_from_slice(self.model.embed.row(t));
        }

        let n_heads = c.n_heads;
        let hd = d / n_heads;
        let scale = 1.0 / (hd as f32).sqrt();
        // score+value multiply-accumulates this step — gates the fan-out
        let attn_work: usize = positions.iter().map(|&p| (p + 1) * d).sum();

        for (l, layer) in self.model.layers.iter().enumerate() {
            // attention
            let h = rmsnorm(&x, &layer.ln1);
            let mut q = h.matmul(&layer.wq);
            let mut k = h.matmul(&layer.wk);
            let v = h.matmul(&layer.wv);
            for i in 0..bsz {
                self.rope.apply_row(q.row_mut(i), positions[i]);
                self.rope.apply_row(k.row_mut(i), positions[i]);
                kv.write(slots[i], l, positions[i], k.row(i), v.row(i));
            }
            // All K/V rows of this layer (including this call's own rows)
            // are written; attend each (row, head) over its causal prefix.
            let mut y = Matrix::zeros(bsz, d);
            let tasks = bsz * n_heads;
            if tasks > 1 && pool::parallel_worthwhile(attn_work, ATTN_PAR_WORK) {
                // head-parallel: task (row, head) writes its disjoint stripe
                // (per-task scores scratch is noise at shapes above the
                // work threshold)
                let kvr: &KvCache = kv;
                let y_ptr = pool::SendPtr::new(&mut y.data);
                pool::par_tasks(tasks, |task| {
                    let (i, hh) = (task / n_heads, task % n_heads);
                    let hb = hh * hd;
                    let n_ctx = positions[i] + 1;
                    let krows = kvr.k_rows(slots[i], l, n_ctx);
                    let vrows = kvr.v_rows(slots[i], l, n_ctx);
                    let qh = &q.row(i)[hb..hb + hd];
                    let mut scores = vec![0.0f32; n_ctx];
                    // SAFETY: stripe (row i, cols hb..hb+hd) of y belongs to
                    // this (row, head) task alone.
                    let oh = unsafe {
                        std::slice::from_raw_parts_mut(y_ptr.0.add(i * d + hb), hd)
                    };
                    attend_head_row(qh, krows, vrows, hb, hd, d, n_ctx, scale, &mut scores, oh);
                });
            } else {
                // serial: one scores buffer reused across all heads of a row
                // (attend_row == per-head attend_head_row calls, so this arm
                // is bit-identical to the parallel one)
                for i in 0..bsz {
                    let n_ctx = positions[i] + 1;
                    let krows = kv.k_rows(slots[i], l, n_ctx);
                    let vrows = kv.v_rows(slots[i], l, n_ctx);
                    attend_row(q.row(i), krows, vrows, n_ctx, n_heads, d, y.row_mut(i));
                }
            }
            add_into(&mut x, &y.matmul(&layer.wo));

            // spectral SwiGLU MLP
            let m = self.mlp(layer, &x);
            add_into(&mut x, &m);
        }

        for &s in slots {
            kv.advance(s);
        }
        x
    }

    /// Whole-sequence re-encode: logits for every position of `tokens`
    /// (shape `(T, vocab)`), causal mask, no KV cache. This IS the training
    /// forward — one shared implementation in `train::decoder` — so the
    /// KV-vs-full equivalence tests below also pin serving against
    /// training. The call builds (and drops) the training activation cache;
    /// that overhead is deliberate — this is the correctness baseline, the
    /// serving hot path is [`Engine::step_batch`], and a second cacheless
    /// forward would reintroduce exactly the drift this refactor removed.
    pub fn forward_full(&self, tokens: &[i32]) -> Matrix {
        decoder_fwd(&self.model, &self.rope, tokens, 1, tokens.len()).0
    }

    /// Greedy decode via full re-encode — the `generate.rs`-style baseline.
    pub fn generate_reencode(&self, prompt: &[i32], n_new: usize, opts: &SampleOpts) -> Vec<i32> {
        let mut rng = Rng::new(opts.seed);
        let mut ctx = prompt.to_vec();
        let mut out = Vec::with_capacity(n_new);
        for _ in 0..n_new {
            // A context of exactly max_seq tokens still yields one more
            // sample (forward_full accepts T == max_seq) — the same budget
            // as the KV path, whose last step writes position max_seq-1.
            if ctx.len() > self.model.cfg.max_seq {
                break;
            }
            let logits = self.forward_full(&ctx);
            let row = logits.row(ctx.len() - 1);
            let next = sample_logits(row, opts.temperature, opts.top_k, &mut rng);
            out.push(next);
            ctx.push(next);
        }
        out
    }

    /// Greedy decode via the KV cache — one token per step after prefill.
    pub fn generate_kv(
        &self,
        prompt: &[i32],
        n_new: usize,
        opts: &SampleOpts,
        kv: &mut KvCache,
        slot: SlotId,
    ) -> Vec<i32> {
        assert!(!prompt.is_empty(), "prompt must not be empty");
        let mut rng = Rng::new(opts.seed);
        // prefill all but the last prompt token (their logits are unused)
        self.prefill(&prompt[..prompt.len() - 1], slot, kv);
        let mut cur = prompt[prompt.len() - 1];
        let mut out = Vec::with_capacity(n_new);
        for _ in 0..n_new {
            if kv.len(slot) >= self.model.cfg.max_seq {
                break;
            }
            let logits = self.step_batch(&[cur], &[slot], kv);
            let next = sample_logits(logits.row(0), opts.temperature, opts.top_k, &mut rng);
            out.push(next);
            cur = next;
        }
        out
    }

    // -- internals ---------------------------------------------------------

    /// SwiGLU through the spectral triples: silu(x·gate) ⊙ (x·up) → down.
    fn mlp(&self, layer: &LayerWeights, x: &Matrix) -> Matrix {
        let h = rmsnorm(x, &layer.ln2);
        let (mut g, _) = layer.gate.forward(&h);
        let (u, _) = layer.up.forward(&h);
        for (gi, &ui) in g.data.iter_mut().zip(&u.data) {
            *gi = silu(*gi) * ui;
        }
        layer.down.forward(&g).0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_engine(seed: u64) -> Engine {
        let cfg = EngineConfig {
            vocab: 50,
            d_model: 32,
            n_layers: 2,
            n_heads: 4,
            d_ffn: 48,
            rank: 4,
            max_seq: 32,
            tied: true,
        };
        Engine::new(SpectralModel::init(cfg, seed))
    }

    #[test]
    fn kv_decode_is_token_identical_to_reencode() {
        let e = tiny_engine(0);
        let opts = SampleOpts { temperature: 0.0, top_k: 0, seed: 0 };
        let prompt = [3i32, 17, 5, 9];
        let baseline = e.generate_reencode(&prompt, 12, &opts);
        let mut kv = e.new_kv(1);
        let slot = kv.alloc().unwrap();
        let cached = e.generate_kv(&prompt, 12, &opts, &mut kv, slot);
        assert_eq!(baseline, cached, "KV path must match the re-encode baseline at T=0");
        assert_eq!(cached.len(), 12);
    }

    #[test]
    fn kv_logits_match_full_forward() {
        let e = tiny_engine(1);
        let tokens = [1i32, 2, 3, 4, 5, 6];
        let full = e.forward_full(&tokens);
        let mut kv = e.new_kv(1);
        let slot = kv.alloc().unwrap();
        for (i, &t) in tokens.iter().enumerate() {
            let step = e.step_batch(&[t], &[slot], &mut kv);
            let mut max_diff = 0.0f32;
            for (a, b) in step.row(0).iter().zip(full.row(i)) {
                max_diff = max_diff.max((a - b).abs());
            }
            assert!(max_diff < 1e-4, "position {i}: max logit diff {max_diff}");
        }
    }

    #[test]
    fn untied_head_decodes_and_roundtrips() {
        let cfg = EngineConfig {
            vocab: 40,
            d_model: 32,
            n_layers: 2,
            n_heads: 4,
            d_ffn: 48,
            rank: 4,
            max_seq: 32,
            tied: false,
        };
        let e = Engine::new(SpectralModel::init(cfg, 5));
        assert!(e.model.head.is_some());
        let opts = SampleOpts { temperature: 0.0, top_k: 0, seed: 0 };
        let prompt = [2i32, 7, 11];
        // KV and re-encode agree with an untied head too
        let baseline = e.generate_reencode(&prompt, 8, &opts);
        let mut kv = e.new_kv(1);
        let slot = kv.alloc().unwrap();
        assert_eq!(baseline, e.generate_kv(&prompt, 8, &opts, &mut kv, slot));
        // and the head survives a checkpoint roundtrip
        let dir = std::env::temp_dir().join(format!("sct_untied_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("untied.sct");
        e.model.save(&path).unwrap();
        let restored = SpectralModel::load(&path).unwrap();
        assert!(!restored.cfg.tied && restored.head.is_some());
        assert_eq!(
            baseline,
            Engine::new(restored).generate_reencode(&prompt, 8, &opts)
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn batched_rows_are_independent() {
        // Decoding two sequences interleaved in one batch must equal
        // decoding each alone — slot isolation + row independence.
        let e = tiny_engine(2);
        let opts = SampleOpts { temperature: 0.0, top_k: 0, seed: 0 };
        let pa = [7i32, 3];
        let pb = [11i32, 2, 30];
        let mut kv_solo = e.new_kv(1);
        let sa = kv_solo.alloc().unwrap();
        let alone_a = e.generate_kv(&pa, 8, &opts, &mut kv_solo, sa);
        kv_solo.release(sa);
        let sb = kv_solo.alloc().unwrap();
        let alone_b = e.generate_kv(&pb, 8, &opts, &mut kv_solo, sb);

        let mut kv = e.new_kv(2);
        let (a, b) = (kv.alloc().unwrap(), kv.alloc().unwrap());
        for &t in &pa[..pa.len() - 1] {
            e.step_batch(&[t], &[a], &mut kv);
        }
        for &t in &pb[..pb.len() - 1] {
            e.step_batch(&[t], &[b], &mut kv);
        }
        let (mut ca, mut cb) = (*pa.last().unwrap(), *pb.last().unwrap());
        let (mut out_a, mut out_b) = (Vec::new(), Vec::new());
        for _ in 0..8 {
            let logits = e.step_batch(&[ca, cb], &[a, b], &mut kv);
            ca = argmax(logits.row(0)) as i32;
            cb = argmax(logits.row(1)) as i32;
            out_a.push(ca);
            out_b.push(cb);
        }
        assert_eq!(out_a, alone_a);
        assert_eq!(out_b, alone_b);
    }

    #[test]
    fn chunked_prefill_batch_matches_inline_prefill() {
        // Two sequences absorbed together through prefill_batch (one token
        // each per call, different prompts) must yield the same next-token
        // logits as a solo inline prefill of each.
        let e = tiny_engine(6);
        let pa = [1i32, 2, 3, 4, 5, 6];
        let pb = [9i32, 8, 7, 6, 5, 4];

        let mut kv_solo = e.new_kv(1);
        let s = kv_solo.alloc().unwrap();
        e.prefill(&pa[..5], s, &mut kv_solo);
        let la = e.step_batch(&[pa[5]], &[s], &mut kv_solo);
        kv_solo.release(s);
        let s = kv_solo.alloc().unwrap();
        e.prefill(&pb[..5], s, &mut kv_solo);
        let lb = e.step_batch(&[pb[5]], &[s], &mut kv_solo);

        let mut kv = e.new_kv(2);
        let (a, b) = (kv.alloc().unwrap(), kv.alloc().unwrap());
        for (&ta, &tb) in pa[..5].iter().zip(&pb[..5]) {
            e.prefill_batch(&[ta, tb], &[a, b], &mut kv);
        }
        let l = e.step_batch(&[pa[5], pb[5]], &[a, b], &mut kv);
        for (x, y) in l.row(0).iter().zip(la.row(0)) {
            assert!((x - y).abs() < 1e-5, "row a diverged: {x} vs {y}");
        }
        for (x, y) in l.row(1).iter().zip(lb.row(0)) {
            assert!((x - y).abs() < 1e-5, "row b diverged: {x} vs {y}");
        }
    }

    #[test]
    fn fused_multi_token_prefill_is_bit_identical_to_per_position() {
        // One prefill_batch call carrying multi-token runs for two sequences
        // (slots repeated, tokens in order) must leave the KV caches — and
        // therefore the next-step logits — bit-identical to one-token-per-
        // call prefill. This is the fused-prefill contract the batcher
        // relies on.
        let e = tiny_engine(7);
        let pa = [3i32, 1, 4, 1, 5];
        let pb = [2i32, 7, 1, 8];

        let mut kv_ref = e.new_kv(2);
        let (ra, rb) = (kv_ref.alloc().unwrap(), kv_ref.alloc().unwrap());
        for &t in &pa {
            e.prefill_batch(&[t], &[ra], &mut kv_ref);
        }
        for &t in &pb {
            e.prefill_batch(&[t], &[rb], &mut kv_ref);
        }
        let l_ref = e.step_batch(&[9, 9], &[ra, rb], &mut kv_ref);

        let mut kv = e.new_kv(2);
        let (fa, fb) = (kv.alloc().unwrap(), kv.alloc().unwrap());
        // both runs in ONE fused call: [a0 a1 a2 | b0 b1], then the tails
        e.prefill_batch(&[pa[0], pa[1], pa[2], pb[0], pb[1]], &[fa, fa, fa, fb, fb], &mut kv);
        assert_eq!(kv.len(fa), 3);
        assert_eq!(kv.len(fb), 2);
        e.prefill_batch(&[pa[3], pa[4], pb[2], pb[3]], &[fa, fa, fb, fb], &mut kv);
        let l_fused = e.step_batch(&[9, 9], &[fa, fb], &mut kv);
        assert_eq!(
            l_fused.data, l_ref.data,
            "fused runs must be bit-identical to per-position prefill"
        );

        // Engine::prefill itself is the whole-prompt fused pass
        let mut kv2 = e.new_kv(1);
        let s = kv2.alloc().unwrap();
        e.prefill(&pa, s, &mut kv2);
        assert_eq!(kv2.len(s), pa.len());
        let l_one = e.step_batch(&[9], &[s], &mut kv2);
        assert_eq!(l_one.row(0), l_ref.row(0));
    }

    #[test]
    fn heterogeneous_rank_checkpoint_roundtrips_and_decodes() {
        // Grow one layer's triples so the model carries per-layer ranks,
        // save, reload, and decode — the rank subsystem's checkpoint
        // contract (`model/meta` records one rank per layer).
        let mut rng = crate::util::rng::Rng::new(99);
        let mut model = SpectralModel::init(
            EngineConfig {
                vocab: 50,
                d_model: 32,
                n_layers: 2,
                n_heads: 4,
                d_ffn: 48,
                rank: 4,
                max_seq: 32,
                tied: true,
            },
            8,
        );
        let l0 = &mut model.layers[0];
        for sl in [&mut l0.gate, &mut l0.up, &mut l0.down] {
            crate::rank::resize::grow_triple(sl, 10, &mut rng);
        }
        model.cfg.rank = 10; // cfg.rank tracks the max layer rank
        assert_eq!(model.layer_ranks(), vec![10, 4]);

        let e = Engine::new(model);
        let opts = SampleOpts { temperature: 0.0, top_k: 0, seed: 0 };
        let prompt = [5i32, 9, 13];
        let baseline = e.generate_reencode(&prompt, 8, &opts);
        // KV path handles per-layer ranks identically
        let mut kv = e.new_kv(1);
        let slot = kv.alloc().unwrap();
        assert_eq!(baseline, e.generate_kv(&prompt, 8, &opts, &mut kv, slot));

        let dir = std::env::temp_dir().join(format!("sct_hetero_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("hetero.sct");
        e.model.save(&path).unwrap();
        let restored = SpectralModel::load(&path).unwrap();
        assert_eq!(restored.layer_ranks(), vec![10, 4]);
        assert_eq!(restored.param_count(), e.model.param_count());
        assert_eq!(
            baseline,
            Engine::new(restored).generate_reencode(&prompt, 8, &opts),
            "heterogeneous-rank checkpoint must serve token-identically"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn checkpoint_roundtrip_preserves_decode() {
        let e = tiny_engine(3);
        let dir = std::env::temp_dir().join(format!("sct_serve_ckpt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.sct");
        e.model.save(&path).unwrap();
        let restored = Engine::new(SpectralModel::load(&path).unwrap());
        assert_eq!(restored.model.cfg, e.model.cfg);
        let opts = SampleOpts { temperature: 0.0, top_k: 0, seed: 0 };
        let prompt = [4i32, 8, 15];
        assert_eq!(
            e.generate_reencode(&prompt, 6, &opts),
            restored.generate_reencode(&prompt, 6, &opts)
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn logits_shapes() {
        let e = tiny_engine(4);
        let full = e.forward_full(&[1, 2, 3]);
        assert_eq!((full.rows, full.cols), (3, 50));
        let mut kv = e.new_kv(2);
        let (a, b) = (kv.alloc().unwrap(), kv.alloc().unwrap());
        let step = e.step_batch(&[1, 2], &[a, b], &mut kv);
        assert_eq!((step.rows, step.cols), (2, 50));
        assert_eq!(kv.len(a), 1);
    }

    // -- sampler edge cases (shared with coordinator::generate) -------------

    #[test]
    fn temperature_zero_is_greedy_argmax() {
        let logits = [0.1f32, 2.5, -1.0, 2.4];
        let mut rng = Rng::new(0);
        for _ in 0..10 {
            assert_eq!(sample_logits(&logits, 0.0, 40, &mut rng), 1);
        }
        // negative temperature degrades to greedy too
        assert_eq!(sample_logits(&logits, -1.0, 0, &mut rng), 1);
    }

    #[test]
    fn top_k_zero_samples_full_distribution() {
        // With uniform logits and top_k=0 every index must eventually appear.
        let logits = [0.0f32; 8];
        let mut rng = Rng::new(7);
        let mut seen = [false; 8];
        for _ in 0..500 {
            let t = sample_logits(&logits, 1.0, 0, &mut rng);
            seen[t as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "top_k=0 must reach the whole vocab: {seen:?}");
    }

    #[test]
    fn top_k_clamps_to_vocab() {
        let logits = [1.0f32, 0.5, 0.25];
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            let t = sample_logits(&logits, 0.7, 1000, &mut rng);
            assert!((0..3).contains(&t));
        }
        // top_k = 1 is greedy regardless of temperature
        for _ in 0..20 {
            assert_eq!(sample_logits(&logits, 5.0, 1, &mut rng), 0);
        }
    }

    #[test]
    fn sampling_is_deterministic_per_seed() {
        let logits: Vec<f32> = (0..16).map(|i| (i as f32 * 0.3).sin()).collect();
        let draw = |seed: u64| -> Vec<i32> {
            let mut rng = Rng::new(seed);
            (0..20).map(|_| sample_logits(&logits, 0.8, 8, &mut rng)).collect()
        };
        assert_eq!(draw(5), draw(5));
        assert_ne!(draw(5), draw(6));
    }
}
