//! Minimal HTTP/1.1 front-end for the batching engine.
//!
//! The image is offline — no tokio, no hyper, no serde — so this is a
//! `std::net::TcpListener` accept loop with one handler thread per
//! connection and `util::json` for the bodies. Connections are HTTP/1.1
//! **keep-alive**: a handler serves requests in a loop until the client
//! sends `Connection: close`, hangs up, goes idle past the read deadline
//! (`keep_alive_ms`, also the stalled-client guard — a socket that never
//! sends a request cannot hold a server thread forever), or exhausts the
//! per-connection request cap (which bounds thread lifetime against
//! slow-drip clients). Pipelining is not supported: send one request, read
//! its full response, then the next.
//!
//! Requests are not handled by one scheduler anymore: the server fronts a
//! [`Gateway`] of `workers` independent worker schedulers (one engine clone
//! + KV arena each) and every `/v1/generate` is placed on the least-loaded
//! worker (see [`crate::serve::gateway`]). The wire types themselves —
//! request/response documents, the uniform [`ErrorEnvelope`], the versioned
//! stats schema — live in [`crate::serve::api`]; this module is only the
//! socket plumbing that moves them.
//!
//! Routes:
//! * `POST /v1/generate` — body parsed as an [`api::GenerateRequest`]
//!   (`prompt` or `prompt_ids`, optional `tokens`/`temperature`/`top_k`/
//!   `seed`/`stop`/`stream`). `stop` entries are strings (tokenized stop
//!   sequences) or raw token ids (EOS); generation ends when the output
//!   ends with any of them, the match is trimmed, and `finish_reason`
//!   reports `"stop"` vs `"length"`. At most 8 stop sequences are honored
//!   (extras ignored); out-of-vocab ids can never match and are dropped.
//!   Without `stream`, responds with one [`api::GenerateResponse`]
//!   document: the completion text, token ids, finish reason, the serving
//!   `worker` index, `request_id` (the same id that keys the request's span
//!   record in `traces.jsonl`), and queue/TTFT/decode latency (`ttft_ms` is
//!   omitted when no token was sampled). With `"stream": true`, responds
//!   with Server-Sent Events over chunked transfer encoding, every frame
//!   stamped with `request_id` — see [`crate::serve`] module docs for the
//!   exact wire format.
//! * `GET /healthz` — liveness + uptime + worker count + per-worker sizing.
//! * `GET /v1/health` — the readiness report `/healthz` only hints at:
//!   model identity (checkpoint path, params, per-layer ranks), worker
//!   liveness, KV-slot pressure (active slots vs total arena capacity),
//!   and the training watchdog's last-anomaly state
//!   ([`crate::obs::health::report_json`]). `status` is `"degraded"` once
//!   any anomaly has been recorded in this process, else `"ok"`.
//! * `GET /v1/stats` — versioned stats document ([`api::stats_json`]): flat
//!   aggregate counters (bit-compatible with the pre-gateway schema) plus a
//!   `workers: [...]` array of per-worker snapshots, `uptime_seconds`, and
//!   the served model's identity under `model`.
//! * `GET /metrics` — Prometheus text exposition of the process-global
//!   [`crate::obs`] registry (serve, pool, train, and rank series; the
//!   `sct_serve_*` series carry a `worker="i"` label).
//! * `GET /v1/profile` — point-in-time snapshot of the [`crate::obs::prof`]
//!   phase tree as JSON: per-worker roots (`worker0 → prefill_chunk →
//!   matmul`, ...), per-kernel roofline rows (achieved GFLOP/s, arithmetic
//!   intensity, fraction of calibrated peak), and whether profiling is
//!   currently enabled (`sct serve --profile-out` / `[obs] profile_out`
//!   enables it; the endpoint answers either way — disabled and empty is a
//!   valid snapshot).
//! * `GET /v1/version` — crate name + version, compiled features, kernel
//!   pool thread count, gateway worker count ([`api::version_json`]).
//!   `/v1/profile` and `/v1/version` answer `POST` with a 405 envelope.
//!
//! Every non-2xx response — 400 parse failures, 404/405 route misses, 413
//! oversize bodies, 503 load sheds — is one [`ErrorEnvelope`] JSON body
//! written through one [`write_error`] path: correct `Content-Type`,
//! status derived from the error code, `request_id` stamped. A full
//! admission queue on EVERY worker answers `503` (load shedding) rather
//! than holding the connection on the backpressured submit path.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, OnceLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use super::api::{self, ErrorCode, ErrorEnvelope, GenerateRequest, GenerateResponse};
use super::batcher::{BatchConfig, StatsSnapshot, StreamEvent};
use super::engine::Engine;
use super::gateway::{Gateway, GatewayConfig, Placed};
use crate::coordinator::config::TomlDoc;
use crate::data::Tokenizer;
use crate::json_obj;
use crate::obs::{self, prof, Counter};
use crate::util::json::Json;

/// Per-route request counters (registered once, cached for the accept path).
struct HttpMetrics {
    generate: Counter,
    healthz: Counter,
    health: Counter,
    stats: Counter,
    metrics: Counter,
    profile: Counter,
    version: Counter,
    other: Counter,
}

fn http_metrics() -> &'static HttpMetrics {
    static M: OnceLock<HttpMetrics> = OnceLock::new();
    M.get_or_init(|| {
        let r = obs::registry();
        const HELP: &str = "HTTP requests served, by route";
        HttpMetrics {
            generate: r.counter_with("sct_http_requests_total", &[("route", "/v1/generate")], HELP),
            healthz: r.counter_with("sct_http_requests_total", &[("route", "/healthz")], HELP),
            health: r.counter_with("sct_http_requests_total", &[("route", "/v1/health")], HELP),
            stats: r.counter_with("sct_http_requests_total", &[("route", "/v1/stats")], HELP),
            metrics: r.counter_with("sct_http_requests_total", &[("route", "/metrics")], HELP),
            profile: r.counter_with("sct_http_requests_total", &[("route", "/v1/profile")], HELP),
            version: r.counter_with("sct_http_requests_total", &[("route", "/v1/version")], HELP),
            other: r.counter_with("sct_http_requests_total", &[("route", "other")], HELP),
        }
    })
}

/// Server + scheduler sizing. CLI flags and the `[serve]` TOML section both
/// land here.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub addr: String,
    /// Independent worker schedulers behind the gateway (one engine clone +
    /// KV arena each). Default from `SCT_WORKERS`, else 1.
    pub workers: usize,
    /// Concurrent decode slots (KV arena size) — per worker.
    pub slots: usize,
    /// Bounded admission queue depth — per worker.
    pub queue_depth: usize,
    /// Tokens per request when the body does not say.
    pub max_new_default: usize,
    /// Prompt tokens prefilled per scheduler step (chunked prefill fairness
    /// budget; 0 = absorb each prompt in one step).
    pub prefill_chunk: usize,
    /// Read deadline on accepted connections, which doubles as the
    /// keep-alive idle window (0 = no deadline).
    pub keep_alive_ms: u64,
    /// Checkpoint path the served model was restored from (`None` for a
    /// random-init model). Surfaced as model identity in `GET /v1/stats`
    /// and `GET /v1/health`.
    pub ckpt: Option<String>,
}

/// Worker-count default: the `SCT_WORKERS` env var when set to a positive
/// integer, else a single worker (the pre-gateway behavior). Mirrors how
/// `SCT_THREADS` sizes the kernel pool.
fn default_workers() -> usize {
    std::env::var("SCT_WORKERS")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or(1)
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            addr: "127.0.0.1:8077".into(),
            workers: default_workers(),
            slots: 8,
            queue_depth: 32,
            max_new_default: 48,
            prefill_chunk: 64,
            keep_alive_ms: 15_000,
            ckpt: None,
        }
    }
}

impl ServeConfig {
    /// Apply a `[serve]` section from a parsed TOML document.
    pub fn apply_toml(&mut self, doc: &TomlDoc) -> Result<()> {
        let Some(s) = doc.get("serve") else {
            return Ok(());
        };
        if let Some(v) = s.get("addr") {
            self.addr = v.as_str()?.to_string();
        }
        if let Some(v) = s.get("workers") {
            self.workers = v.as_usize()?;
        }
        if let Some(v) = s.get("slots") {
            self.slots = v.as_usize()?;
        }
        if let Some(v) = s.get("queue_depth") {
            self.queue_depth = v.as_usize()?;
        }
        if let Some(v) = s.get("max_new") {
            self.max_new_default = v.as_usize()?;
        }
        if let Some(v) = s.get("prefill_chunk") {
            self.prefill_chunk = v.as_usize()?;
        }
        if let Some(v) = s.get("keep_alive_ms") {
            self.keep_alive_ms = v.as_usize()? as u64;
        }
        if let Some(v) = s.get("ckpt") {
            self.ckpt = Some(v.as_str()?.to_string());
        }
        Ok(())
    }
}

struct ServerState {
    gateway: Gateway,
    tokenizer: Tokenizer,
    vocab: usize,
    cfg: ServeConfig,
    started: Instant,
    /// Identity of the served model (checkpoint path, params, per-layer
    /// ranks, dims), captured at startup before the gateway consumes the
    /// engine. Served verbatim in `/v1/stats` and `/v1/health`.
    model_info: Json,
}

/// A running server: accept loop + batcher, stoppable for tests.
pub struct Server {
    pub addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    state: Arc<ServerState>,
}

impl Server {
    /// Bind `cfg.addr` (port 0 picks a free port) and start serving.
    pub fn start(cfg: &ServeConfig, engine: Engine, tokenizer: Tokenizer) -> Result<Server> {
        let vocab = engine.cfg().vocab;
        // Capture model identity and publish the spectral-health baseline
        // before the gateway consumes the engine: the `sct_spectral_*`
        // gauges and zero-valued `sct_health_*` counters are then part of
        // every /metrics scrape from the first request on.
        let model_info = {
            let m = &engine.model;
            let ranks: Vec<Json> =
                m.layer_ranks().iter().map(|&r| Json::Num(r as f64)).collect();
            json_obj![
                ("checkpoint", cfg.ckpt.clone().map(Json::Str).unwrap_or(Json::Null)),
                ("params", m.param_count()),
                ("d_model", m.cfg.d_model),
                ("n_layers", m.cfg.n_layers),
                ("vocab", m.cfg.vocab),
                ("max_seq", m.cfg.max_seq),
                ("layer_ranks", Json::Arr(ranks)),
            ]
        };
        obs::health::register_metrics();
        crate::rank::spectra::publish(&crate::rank::model_spectra(&engine.model, 0.25));
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding {}", cfg.addr))?;
        let addr = listener.local_addr()?;
        let state = Arc::new(ServerState {
            gateway: Gateway::start(
                engine,
                &GatewayConfig {
                    workers: cfg.workers,
                    batch: BatchConfig {
                        slots: cfg.slots,
                        queue_depth: cfg.queue_depth,
                        prefill_chunk: cfg.prefill_chunk,
                        worker: 0, // overridden per worker by the gateway
                    },
                },
            ),
            tokenizer,
            vocab,
            cfg: cfg.clone(),
            started: Instant::now(),
            model_info,
        });
        let shutdown = Arc::new(AtomicBool::new(false));
        let accept = {
            let state = state.clone();
            let shutdown = shutdown.clone();
            std::thread::Builder::new()
                .name("sct-serve-accept".into())
                .spawn(move || {
                    for stream in listener.incoming() {
                        if shutdown.load(Ordering::SeqCst) {
                            break;
                        }
                        let Ok(stream) = stream else { continue };
                        let state = state.clone();
                        // Handlers live as long as their connection (keep-
                        // alive); the read deadline bounds idle lifetime and
                        // the batcher's bounded queue is the real
                        // concurrency limit.
                        std::thread::spawn(move || {
                            let _ = handle_connection(stream, &state);
                        });
                    }
                })
                .expect("spawn accept thread")
        };
        Ok(Server { addr, shutdown, accept: Some(accept), state })
    }

    /// Point-in-time scheduler counters and gauges, aggregated across all
    /// workers (bit-compatible with the single-scheduler snapshot when
    /// `workers == 1`).
    pub fn stats(&self) -> StatsSnapshot {
        self.state.gateway.stats()
    }

    /// Per-worker snapshots, by worker index.
    pub fn worker_stats(&self) -> Vec<StatsSnapshot> {
        self.state.gateway.worker_stats()
    }

    /// Worker scheduler count behind the gateway.
    pub fn workers(&self) -> usize {
        self.state.gateway.workers()
    }

    /// Block until the accept loop exits (it only exits via [`Server::stop`]
    /// or process death) — what `sct serve` does after printing the banner.
    pub fn join(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }

    /// Stop accepting, join the accept thread, shut the batcher down.
    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // Unblock the accept() with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        // `state` (and the Gateway's workers in it) drops with self once
        // handlers end.
    }
}

// ---------------------------------------------------------------------------
// minimal blocking client (demos, benches, tests)
// ---------------------------------------------------------------------------

/// Send one raw HTTP/1.1 request and parse the `Connection: close` response:
/// returns (status code, JSON body). This is the client half the serve demo,
/// the integration tests, and external smoke checks share. The raw request
/// should carry `Connection: close` — this helper reads to EOF. A request
/// that forgets the header gets a keep-alive response; the bounded read
/// timeout below turns that from a hang into a short stall (the buffered
/// response still parses).
pub fn http_roundtrip(addr: SocketAddr, raw: &str) -> Result<(u16, Json)> {
    let mut s = TcpStream::connect(addr).context("connecting to serve endpoint")?;
    s.set_read_timeout(Some(Duration::from_secs(30))).ok();
    s.write_all(raw.as_bytes())?;
    let mut buf = Vec::new();
    match s.read_to_end(&mut buf) {
        Ok(_) => {}
        // Timed out on a kept-alive socket: whatever arrived is the response.
        Err(e)
            if !buf.is_empty()
                && matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) => {}
        Err(e) => return Err(e).context("reading response"),
    }
    let text = String::from_utf8_lossy(&buf);
    let status: u16 = text
        .split_whitespace()
        .nth(1)
        .ok_or_else(|| anyhow!("malformed response: {text:?}"))?
        .parse()
        .context("non-numeric status code")?;
    let payload = text.split("\r\n\r\n").nth(1).unwrap_or("{}");
    Ok((status, Json::parse(payload)?))
}

/// `POST path` with a JSON body via [`http_roundtrip`] (one-shot connection).
pub fn http_post_json(addr: SocketAddr, path: &str, body: &str) -> Result<(u16, Json)> {
    http_roundtrip(
        addr,
        &format!(
            "POST {path} HTTP/1.1\r\nHost: sct\r\nConnection: close\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        ),
    )
}

/// `GET path` via [`http_roundtrip`] (one-shot connection).
pub fn http_get_json(addr: SocketAddr, path: &str) -> Result<(u16, Json)> {
    http_roundtrip(addr, &format!("GET {path} HTTP/1.1\r\nHost: sct\r\nConnection: close\r\n\r\n"))
}

/// `GET path` returning the raw response body as text — the scrape client
/// for `GET /metrics` (Prometheus exposition is not JSON).
pub fn http_get_text(addr: SocketAddr, path: &str) -> Result<(u16, String)> {
    let mut s = TcpStream::connect(addr).context("connecting to serve endpoint")?;
    s.set_read_timeout(Some(Duration::from_secs(30))).ok();
    s.write_all(
        format!("GET {path} HTTP/1.1\r\nHost: sct\r\nConnection: close\r\n\r\n").as_bytes(),
    )?;
    let mut buf = Vec::new();
    s.read_to_end(&mut buf).context("reading response")?;
    let text = String::from_utf8_lossy(&buf);
    let status: u16 = text
        .split_whitespace()
        .nth(1)
        .ok_or_else(|| anyhow!("malformed response: {text:?}"))?
        .parse()
        .context("non-numeric status code")?;
    let body = text.split_once("\r\n\r\n").map(|(_, b)| b.to_string()).unwrap_or_default();
    Ok((status, body))
}

/// One request/response exchange over an already-open connection — the
/// keep-alive client half. Writes `raw` (which should NOT ask for
/// `Connection: close`), reads exactly one `Content-Length`-framed response,
/// and leaves the connection open for the next exchange.
pub fn http_exchange(stream: &mut TcpStream, raw: &str) -> Result<(u16, Json)> {
    stream.write_all(raw.as_bytes())?;
    stream.flush()?;
    let mut reader = BufReader::new(&mut *stream);
    let (status, headers) = read_response_head(&mut reader)?;
    let len: usize = find_header(&headers, "content-length")
        .ok_or_else(|| anyhow!("keep-alive response carries no Content-Length"))?
        .parse()
        .context("bad Content-Length")?;
    let mut body = vec![0u8; len];
    reader.read_exact(&mut body).context("reading response body")?;
    Ok((status, Json::parse(std::str::from_utf8(&body)?)?))
}

/// One parsed SSE `data:` frame, stamped with its client-side arrival time
/// (seconds since the request was sent). TTFT is `frames[0].at_s`; the gaps
/// between consecutive frames are the inter-token latencies.
#[derive(Debug, Clone)]
pub struct SseFrame {
    pub at_s: f64,
    pub data: Json,
}

/// `POST path` with `"stream": true` semantics: reads the chunked
/// `text/event-stream` response incrementally and returns every `data:`
/// frame with its arrival time. Non-200 responses come back as one frame
/// holding the error body.
pub fn http_post_sse(addr: SocketAddr, path: &str, body: &str) -> Result<(u16, Vec<SseFrame>)> {
    let mut s = TcpStream::connect(addr).context("connecting to serve endpoint")?;
    let t0 = Instant::now();
    let raw = format!(
        "POST {path} HTTP/1.1\r\nHost: sct\r\nConnection: close\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    s.write_all(raw.as_bytes())?;
    let mut reader = BufReader::new(s);
    let (status, headers) = read_response_head(&mut reader)?;
    if status != 200 {
        // load-shed / bad-request errors are plain JSON bodies
        let mut text = String::new();
        reader.read_to_string(&mut text)?;
        let data = Json::parse(if text.is_empty() { "{}" } else { &text })?;
        return Ok((status, vec![SseFrame { at_s: t0.elapsed().as_secs_f64(), data }]));
    }
    let chunked = matches!(
        find_header(&headers, "transfer-encoding"), Some(v) if v.eq_ignore_ascii_case("chunked")
    );
    if !chunked {
        bail!("streaming response must use chunked transfer encoding");
    }
    let mut pending = String::new();
    let mut frames = Vec::new();
    loop {
        let mut szline = String::new();
        if reader.read_line(&mut szline)? == 0 {
            bail!("connection closed mid-stream");
        }
        let sz = usize::from_str_radix(szline.trim(), 16)
            .with_context(|| format!("bad chunk size line {szline:?}"))?;
        let mut chunk = vec![0u8; sz + 2]; // chunk payload + trailing CRLF
        reader.read_exact(&mut chunk).context("reading chunk")?;
        if sz == 0 {
            break;
        }
        chunk.truncate(sz);
        pending.push_str(std::str::from_utf8(&chunk).context("SSE frame is not UTF-8")?);
        let at_s = t0.elapsed().as_secs_f64();
        while let Some(p) = pending.find("\n\n") {
            let event: String = pending.drain(..p + 2).collect();
            if let Some(data) = event.trim_end().strip_prefix("data: ") {
                frames.push(SseFrame { at_s, data: Json::parse(data)? });
            }
        }
    }
    Ok((200, frames))
}

/// Parse an HTTP response status line + headers (keys lower-cased).
fn read_response_head<R: BufRead>(reader: &mut R) -> Result<(u16, Vec<(String, String)>)> {
    let mut line = String::new();
    reader.read_line(&mut line).context("reading status line")?;
    let status: u16 = line
        .split_whitespace()
        .nth(1)
        .ok_or_else(|| anyhow!("malformed status line {line:?}"))?
        .parse()
        .context("non-numeric status code")?;
    let mut headers = Vec::new();
    loop {
        let mut h = String::new();
        let n = reader.read_line(&mut h)?;
        if n == 0 || h.trim().is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string()));
        }
    }
    Ok((status, headers))
}

fn find_header<'a>(headers: &'a [(String, String)], key: &str) -> Option<&'a str> {
    headers.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
}

// ---------------------------------------------------------------------------
// HTTP plumbing
// ---------------------------------------------------------------------------

struct HttpRequest {
    method: String,
    path: String,
    keep_alive: bool,
    body: Vec<u8>,
}

/// Generation requests are small JSON documents; anything bigger is abuse.
const MAX_BODY_BYTES: usize = 1 << 20;
/// Hard cap on bytes read per request (request line + headers + body), so
/// a newline-less flood cannot grow `read_line` without bound.
const MAX_REQUEST_BYTES: u64 = 2 << 20;
const MAX_HEADERS: usize = 64;
/// Requests served per keep-alive connection before the server closes it.
/// Bounds the handler-thread lifetime: without it, a client trickling cheap
/// requests just under the read deadline pins a thread indefinitely.
const KEEP_ALIVE_MAX_REQUESTS: usize = 1000;

/// Declared `Content-Length` beyond [`MAX_BODY_BYTES`].
#[derive(Debug)]
struct RequestTooLarge {
    bytes: usize,
}

impl std::fmt::Display for RequestTooLarge {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "body too large ({} bytes > {} cap)", self.bytes, MAX_BODY_BYTES)
    }
}

impl std::error::Error for RequestTooLarge {}

/// Read one request off a (possibly reused) connection. `Ok(None)` is a
/// clean end of the connection: the client closed it, reset it, or went
/// idle past the read deadline without starting a request. Errors are
/// malformed or abusive requests and deserve a `400`.
fn read_request(reader: &mut BufReader<TcpStream>) -> Result<Option<HttpRequest>> {
    let mut limited = reader.by_ref().take(MAX_REQUEST_BYTES);
    let mut line = String::new();
    match limited.read_line(&mut line) {
        Ok(0) => return Ok(None),
        Ok(_) => {}
        Err(e) => {
            if line.is_empty()
                && matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                        | std::io::ErrorKind::ConnectionReset
                )
            {
                return Ok(None);
            }
            return Err(e).context("reading request line");
        }
    }
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or_default().to_string();
    let path = parts.next().unwrap_or_default().to_string();
    let version = parts.next().unwrap_or("HTTP/1.1").to_string();
    if method.is_empty() || path.is_empty() {
        bail!("malformed request line {line:?}");
    }
    let mut content_length = 0usize;
    // HTTP/1.1 defaults to keep-alive; `Connection: close` opts out, and
    // HTTP/1.0 must opt in explicitly.
    let mut keep_alive = version != "HTTP/1.0";
    for n_headers in 0.. {
        if n_headers >= MAX_HEADERS {
            bail!("too many headers");
        }
        let mut header = String::new();
        let n = limited.read_line(&mut header)?;
        if n == 0 {
            // EOF before the blank line: the client closed mid-request, or
            // the size cap truncated it. Never dispatch a half-parsed
            // request (under keep-alive its tail would be misread as the
            // next request).
            bail!("connection closed mid-headers (or request exceeds the size cap)");
        }
        if header.trim().is_empty() {
            break;
        }
        if let Some((k, v)) = header.split_once(':') {
            let (k, v) = (k.trim(), v.trim());
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.parse().context("bad Content-Length")?;
            } else if k.eq_ignore_ascii_case("connection") {
                keep_alive = !v.eq_ignore_ascii_case("close")
                    && (keep_alive || v.eq_ignore_ascii_case("keep-alive"));
            }
        }
    }
    if content_length > MAX_BODY_BYTES {
        // Typed (not a bail! string) so the handler can answer 413 instead
        // of folding it into the generic 400 read-error path.
        return Err(anyhow::Error::new(RequestTooLarge { bytes: content_length }));
    }
    let mut body = vec![0u8; content_length];
    limited.read_exact(&mut body).context("reading body")?;
    Ok(Some(HttpRequest { method, path, keep_alive, body }))
}

fn write_raw_response(
    stream: &mut TcpStream,
    status: u16,
    reason: &str,
    content_type: &str,
    payload: &str,
    keep_alive: bool,
) -> Result<()> {
    let head = format!(
        "HTTP/1.1 {status} {reason}\r\n\
         Content-Type: {content_type}\r\n\
         Content-Length: {}\r\n\
         Connection: {}\r\n\r\n",
        payload.len(),
        if keep_alive { "keep-alive" } else { "close" },
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(payload.as_bytes())?;
    stream.flush()?;
    Ok(())
}

fn write_response(
    stream: &mut TcpStream,
    status: u16,
    reason: &str,
    body: &Json,
    keep_alive: bool,
) -> Result<()> {
    write_raw_response(stream, status, reason, "application/json", &body.to_string(), keep_alive)
}

/// Write one SSE frame as its own HTTP chunk and flush, so the client sees
/// it the moment the token is sampled.
fn write_sse_frame(stream: &mut TcpStream, data: &Json) -> Result<()> {
    let json = data.to_string();
    let payload = format!("data: {json}\n\n");
    stream.write_all(format!("{:x}\r\n", payload.len()).as_bytes())?;
    stream.write_all(payload.as_bytes())?;
    stream.write_all(b"\r\n")?;
    stream.flush()?;
    Ok(())
}

/// THE error write path: every non-2xx response is an [`ErrorEnvelope`]
/// rendered as `application/json`, with the status line and reason phrase
/// derived from its [`ErrorCode`] (no free-floating status/body pairs), and
/// the connection's keep-alive state honored — an envelope is an answer,
/// not an excuse to drop the connection.
fn write_error(stream: &mut TcpStream, e: &ErrorEnvelope, keep_alive: bool) -> Result<()> {
    write_raw_response(
        stream,
        e.code.http_status(),
        e.code.reason(),
        "application/json",
        &e.to_json().to_string(),
        keep_alive,
    )
}

fn handle_connection(mut stream: TcpStream, state: &ServerState) -> Result<()> {
    // The read deadline is both the keep-alive idle window and the
    // stalled-client guard: a socket that opens and never sends a request
    // can no longer hold this thread forever.
    let deadline = match state.cfg.keep_alive_ms {
        0 => None,
        ms => Some(Duration::from_millis(ms)),
    };
    stream.set_read_timeout(deadline).ok();
    // Symmetric write deadline: a client that stops *reading* (full TCP send
    // buffer) must not hold the handler thread in write_all forever either.
    stream.set_write_timeout(deadline).ok();
    let mut reader = BufReader::new(stream.try_clone().context("cloning connection stream")?);
    for served in 0..KEEP_ALIVE_MAX_REQUESTS {
        let req = match read_request(&mut reader) {
            Ok(Some(r)) => r,
            Ok(None) => return Ok(()), // client closed / idle deadline
            Err(e) => {
                // After a read failure the request framing is unknown, so
                // the connection must close either way; the envelope still
                // goes out first so the client sees a typed error.
                let code = if e.downcast_ref::<RequestTooLarge>().is_some() {
                    ErrorCode::PayloadTooLarge
                } else {
                    ErrorCode::BadRequest
                };
                let _ = write_error(&mut stream, &ErrorEnvelope::new(code, e.to_string()), false);
                return Ok(());
            }
        };
        // advertise `Connection: close` on the connection's last allowed
        // request so well-behaved clients reconnect instead of erroring
        let keep = req.keep_alive && served + 1 < KEEP_ALIVE_MAX_REQUESTS;
        match (req.method.as_str(), req.path.as_str()) {
            ("POST", "/v1/generate") => {
                http_metrics().generate.inc();
                handle_generate(&mut stream, &req.body, state, keep)?
            }
            ("GET", "/healthz") => {
                http_metrics().healthz.inc();
                let body = json_obj![
                    ("status", "ok"),
                    ("uptime_s", state.started.elapsed().as_secs_f64()),
                    ("workers", state.gateway.workers()),
                    ("slots", state.cfg.slots),
                    ("queue_depth", state.cfg.queue_depth),
                    ("prefill_chunk", state.cfg.prefill_chunk),
                    ("keep_alive_ms", state.cfg.keep_alive_ms as i64),
                ];
                write_response(&mut stream, 200, "OK", &body, keep)?;
            }
            ("GET", "/v1/health") => {
                http_metrics().health.inc();
                let per_worker = state.gateway.worker_stats();
                let workers_n = state.gateway.workers();
                let slots_total = state.cfg.slots.saturating_mul(workers_n);
                let active: u64 = per_worker.iter().map(|w| w.active_slots).sum();
                let queued: u64 = per_worker.iter().map(|w| w.queue_depth).sum();
                let anomalous = obs::health::last_anomaly().is_some();
                let body = json_obj![
                    ("status", if anomalous { "degraded" } else { "ok" }),
                    ("uptime_seconds", state.started.elapsed().as_secs_f64()),
                    ("model", state.model_info.clone()),
                    (
                        "workers",
                        json_obj![("count", workers_n), ("live", per_worker.len())]
                    ),
                    (
                        "kv",
                        json_obj![
                            ("slots_total", slots_total),
                            ("slots_active", active as usize),
                            ("queued", queued as usize),
                            (
                                "pressure",
                                if slots_total > 0 {
                                    active as f64 / slots_total as f64
                                } else {
                                    0.0
                                }
                            ),
                        ]
                    ),
                    ("watchdog", obs::health::report_json()),
                ];
                write_response(&mut stream, 200, "OK", &body, keep)?;
            }
            ("GET", "/v1/stats") => {
                http_metrics().stats.inc();
                let per_worker = state.gateway.worker_stats();
                let aggregate = state.gateway.stats();
                let body = api::stats_json(
                    &aggregate,
                    &per_worker,
                    state.started.elapsed().as_secs_f64(),
                    &state.model_info,
                );
                write_response(&mut stream, 200, "OK", &body, keep)?;
            }
            ("GET", "/metrics") => {
                http_metrics().metrics.inc();
                let text = obs::registry().render_prometheus();
                write_raw_response(
                    &mut stream,
                    200,
                    "OK",
                    "text/plain; version=0.0.4",
                    &text,
                    keep,
                )?;
            }
            ("GET", "/v1/profile") => {
                http_metrics().profile.inc();
                // Snapshot of whatever has been collected so far; when
                // profiling is off the tree is simply empty (enabled: false
                // tells the client why).
                write_response(&mut stream, 200, "OK", &prof::snapshot().to_json(), keep)?;
            }
            ("GET", "/v1/version") => {
                http_metrics().version.inc();
                let body = api::version_json(state.gateway.workers());
                write_response(&mut stream, 200, "OK", &body, keep)?;
            }
            // Read-only introspection routes reject writes with a typed 405
            // (not the 404 the generic POST fallback would give).
            ("POST", "/v1/profile" | "/v1/version" | "/v1/health") => {
                http_metrics().other.inc();
                let e = ErrorEnvelope::new(
                    ErrorCode::MethodNotAllowed,
                    format!("{} only supports GET", req.path),
                );
                write_error(&mut stream, &e, keep)?;
            }
            ("POST", _) | ("GET", _) => {
                http_metrics().other.inc();
                let e = ErrorEnvelope::new(
                    ErrorCode::NotFound,
                    format!("no such route: {} {}", req.method, req.path),
                );
                write_error(&mut stream, &e, keep)?;
            }
            _ => {
                http_metrics().other.inc();
                let e = ErrorEnvelope::new(
                    ErrorCode::MethodNotAllowed,
                    format!("method {} not allowed (use GET/POST)", req.method),
                );
                write_error(&mut stream, &e, keep)?;
            }
        }
        if !keep {
            return Ok(());
        }
    }
    Ok(())
}

fn handle_generate(
    stream: &mut TcpStream,
    body: &[u8],
    state: &ServerState,
    keep: bool,
) -> Result<()> {
    // Parse (shape) then resolve (bind to the model) — both failure modes
    // are the client's, both answer 400 envelopes.
    let parsed = match GenerateRequest::parse(body) {
        Ok(g) => g,
        Err(e) => {
            return write_error(
                stream,
                &ErrorEnvelope::new(ErrorCode::BadRequest, e.to_string()),
                keep,
            )
        }
    };
    let req = match parsed.resolve(&state.tokenizer, state.vocab, state.cfg.max_new_default) {
        Ok(r) => r,
        Err(e) => {
            return write_error(
                stream,
                &ErrorEnvelope::new(ErrorCode::BadRequest, e.to_string()),
                keep,
            )
        }
    };
    if parsed.stream {
        match state.gateway.try_submit_streaming(req) {
            Ok(placed) => stream_sse(stream, placed, state, keep),
            Err(e) => write_error(stream, &ErrorEnvelope::from_submit(e), keep),
        }
    } else {
        match state.gateway.try_submit(req) {
            Ok(placed) => match placed.rx.recv() {
                Ok(c) => {
                    let doc = GenerateResponse::new(&c, &state.tokenizer, placed.worker);
                    write_response(stream, 200, "OK", &doc.to_json(), keep)
                }
                Err(_) => write_error(
                    stream,
                    &ErrorEnvelope::new(ErrorCode::Internal, "scheduler dropped the request"),
                    keep,
                ),
            },
            Err(e) => write_error(stream, &ErrorEnvelope::from_submit(e), keep),
        }
    }
}

/// Relay a streaming generation as Server-Sent Events: one `data:` frame per
/// token as it is sampled, a terminal frame with the usage stats, then the
/// zero-length chunk. Every frame carries the `request_id` so clients can
/// correlate a stream with its span record. A write failure (client hung up)
/// drops the event receiver, which cancels the sequence in the batcher at
/// its next token.
fn stream_sse(
    stream: &mut TcpStream,
    placed: Placed<StreamEvent>,
    state: &ServerState,
    keep: bool,
) -> Result<()> {
    let head = format!(
        "HTTP/1.1 200 OK\r\n\
         Content-Type: text/event-stream\r\n\
         Cache-Control: no-cache\r\n\
         Transfer-Encoding: chunked\r\n\
         Connection: {}\r\n\r\n",
        if keep { "keep-alive" } else { "close" },
    );
    stream.write_all(head.as_bytes())?;
    stream.flush()?;
    let mut index = 0usize;
    let mut finished = false;
    let worker = placed.worker;
    let req_id = placed.request_id;
    for ev in &placed.rx {
        match ev {
            StreamEvent::Token(t) => {
                // Per-token text is a best-effort lossy decode (a token that
                // splits a multi-byte character renders as U+FFFD); the
                // terminal frame carries the full, correctly-decoded text.
                let frame = api::sse_token_json(req_id, t, index, &state.tokenizer.decode(&[t]));
                write_sse_frame(stream, &frame)?;
                index += 1;
            }
            StreamEvent::Done(c) => {
                let doc = GenerateResponse::new(&c, &state.tokenizer, worker);
                write_sse_frame(stream, &doc.to_sse_done_json())?;
                finished = true;
                break;
            }
        }
    }
    if !finished {
        // The batcher died mid-stream. Do NOT write the clean terminating
        // chunk: dropping the connection makes the truncation visible to the
        // client as a transport error instead of a short-but-valid stream.
        bail!("stream ended without a completion event");
    }
    stream.write_all(b"0\r\n\r\n")?;
    stream.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::engine::{EngineConfig, SpectralModel};

    fn test_server_cfg(slots: usize, queue: usize, keep_alive_ms: u64) -> Server {
        let cfg = EngineConfig { max_seq: 64, ..EngineConfig::default() };
        let engine = Engine::new(SpectralModel::init(cfg, 0));
        let serve_cfg = ServeConfig {
            addr: "127.0.0.1:0".into(),
            workers: 1,
            slots,
            queue_depth: queue,
            max_new_default: 8,
            prefill_chunk: 4,
            keep_alive_ms,
            ckpt: None,
        };
        Server::start(&serve_cfg, engine, Tokenizer::byte_level()).unwrap()
    }

    fn test_server(slots: usize, queue: usize) -> Server {
        test_server_cfg(slots, queue, 15_000)
    }

    #[test]
    fn healthz_and_stats_respond() {
        let srv = test_server(2, 4);
        let (code, body) = http_get_json(srv.addr, "/healthz").unwrap();
        assert_eq!(code, 200);
        assert_eq!(body.get("status").unwrap().as_str().unwrap(), "ok");
        assert_eq!(body.get("workers").unwrap().as_usize().unwrap(), 1);
        assert_eq!(body.get("prefill_chunk").unwrap().as_usize().unwrap(), 4);
        let (code, body) = http_get_json(srv.addr, "/v1/stats").unwrap();
        assert_eq!(code, 200);
        // flat aggregate fields: the pre-gateway schema, still present
        assert_eq!(body.get("admitted").unwrap().as_i64().unwrap(), 0);
        assert_eq!(body.get("prefill_tokens").unwrap().as_i64().unwrap(), 0);
        assert_eq!(body.get("queue_depth").unwrap().as_i64().unwrap(), 0);
        assert_eq!(body.get("active_slots").unwrap().as_i64().unwrap(), 0);
        // versioned addition: one snapshot per worker
        let workers = body.get("workers").unwrap().as_arr().unwrap();
        assert_eq!(workers.len(), 1);
        assert_eq!(workers[0].get("worker").unwrap().as_i64().unwrap(), 0);
        assert_eq!(workers[0].get("admitted").unwrap().as_i64().unwrap(), 0);
        srv.stop();
    }

    #[test]
    fn v1_health_reports_readiness_and_model_identity() {
        let srv = test_server(2, 4);
        let (code, body) = http_get_json(srv.addr, "/v1/health").unwrap();
        assert_eq!(code, 200);
        // status reflects process-lifetime watchdog state; another test in
        // the same binary may have recorded an anomaly on purpose.
        assert!(matches!(
            body.get("status").unwrap().as_str().unwrap(),
            "ok" | "degraded"
        ));
        assert!(body.get("uptime_seconds").unwrap().as_f64().unwrap() >= 0.0);
        let model = body.get("model").unwrap();
        assert_eq!(model.get("checkpoint").unwrap(), &Json::Null, "random-init model");
        assert!(model.get("params").unwrap().as_usize().unwrap() > 0);
        let ranks = model.get("layer_ranks").unwrap().as_arr().unwrap();
        assert_eq!(ranks.len(), EngineConfig::default().n_layers);
        let workers = body.get("workers").unwrap();
        assert_eq!(workers.get("count").unwrap().as_usize().unwrap(), 1);
        assert_eq!(workers.get("live").unwrap().as_usize().unwrap(), 1);
        let kv = body.get("kv").unwrap();
        assert_eq!(kv.get("slots_total").unwrap().as_usize().unwrap(), 2);
        assert_eq!(kv.get("slots_active").unwrap().as_usize().unwrap(), 0);
        assert_eq!(kv.get("pressure").unwrap().as_f64().unwrap(), 0.0);
        let wd = body.get("watchdog").unwrap();
        assert!(wd.get("enabled").unwrap().as_bool().is_ok());

        // read-only: POST answers a typed 405
        let (code, body) = http_post_json(srv.addr, "/v1/health", "{}").unwrap();
        assert_eq!(code, 405);
        assert_envelope(&body, "method_not_allowed");
        srv.stop();
    }

    #[test]
    fn stats_carry_uptime_and_model_identity() {
        let srv = test_server(2, 4);
        let (code, body) = http_get_json(srv.addr, "/v1/stats").unwrap();
        assert_eq!(code, 200);
        assert!(body.get("uptime_seconds").unwrap().as_f64().unwrap() >= 0.0);
        let model = body.get("model").unwrap();
        assert!(model.get("params").unwrap().as_usize().unwrap() > 0);
        assert_eq!(
            model.get("layer_ranks").unwrap().as_arr().unwrap().len(),
            EngineConfig::default().n_layers
        );
        srv.stop();
    }

    #[test]
    fn metrics_expose_spectral_and_health_series_from_startup() {
        let srv = test_server(2, 4);
        let (code, text) = http_get_text(srv.addr, "/metrics").unwrap();
        assert_eq!(code, 200);
        for series in [
            "sct_spectral_energy",
            "sct_spectral_tail_share",
            "sct_spectral_effective_rank",
            "sct_health_anomalies_total",
            "sct_health_skipped_steps_total",
        ] {
            assert!(text.contains(series), "missing series {series} in:\n{text}");
        }
        srv.stop();
    }

    #[test]
    fn metrics_exposition_responds_with_serve_series() {
        let srv = test_server(2, 4);
        let req = r#"{"prompt": "observe me", "tokens": 3, "temperature": 0}"#;
        let (code, _) = http_post_json(srv.addr, "/v1/generate", req).unwrap();
        assert_eq!(code, 200);
        let (code, text) = http_get_text(srv.addr, "/metrics").unwrap();
        assert_eq!(code, 200);
        for series in [
            "sct_serve_requests_total",
            "sct_serve_completions_total",
            "sct_serve_queue_depth",
            "sct_serve_active_slots",
            "sct_serve_ttft_ms_bucket",
            "sct_serve_decode_step_ms_count",
            "sct_http_requests_total{route=\"/v1/generate\"}",
        ] {
            assert!(text.contains(series), "missing series {series} in:\n{text}");
        }
        srv.stop();
    }

    #[test]
    fn generate_roundtrip_is_deterministic_at_t0() {
        let srv = test_server(2, 4);
        let req = r#"{"prompt": "spectral", "tokens": 6, "temperature": 0}"#;
        let (code, a) = http_post_json(srv.addr, "/v1/generate", req).unwrap();
        assert_eq!(code, 200, "body: {a:?}");
        assert_eq!(a.get("tokens").unwrap().as_arr().unwrap().len(), 6);
        assert_eq!(a.get("prompt_tokens").unwrap().as_usize().unwrap(), 8);
        assert!(a.get("ttft_ms").unwrap().as_f64().unwrap() > 0.0);
        assert!(a.get("request_id").unwrap().as_i64().unwrap() > 0);
        assert_eq!(a.get("worker").unwrap().as_i64().unwrap(), 0, "single-worker gateway");
        let (_, b) = http_post_json(srv.addr, "/v1/generate", req).unwrap();
        assert_eq!(
            a.get("tokens").unwrap(),
            b.get("tokens").unwrap(),
            "greedy decode must be reproducible across requests"
        );
        srv.stop();
    }

    #[test]
    fn stop_sequence_ends_generation_early() {
        let srv = test_server(2, 4);
        let base = r#"{"prompt": "spectral", "tokens": 10, "temperature": 0}"#;
        let (code, full) = http_post_json(srv.addr, "/v1/generate", base).unwrap();
        assert_eq!(code, 200, "body: {full:?}");
        assert_eq!(full.get("finish_reason").unwrap().as_str().unwrap(), "length");
        let toks = full.get("tokens").unwrap().as_arr().unwrap();
        let eos = toks[3].as_i64().unwrap();
        let first = toks.iter().position(|t| t.as_i64().unwrap() == eos).unwrap();

        // raw token-id stop (EOS semantics)
        let req = format!(
            r#"{{"prompt": "spectral", "tokens": 10, "temperature": 0, "stop": [{eos}]}}"#
        );
        let (code, body) = http_post_json(srv.addr, "/v1/generate", &req).unwrap();
        assert_eq!(code, 200, "body: {body:?}");
        assert_eq!(body.get("finish_reason").unwrap().as_str().unwrap(), "stop");
        assert_eq!(body.get("tokens").unwrap().as_arr().unwrap().len(), first);

        // string stop: the byte-level decode of that token must cut the same
        let text = String::from_utf8_lossy(&[eos as u8]).to_string();
        if !text.contains('"') && !text.contains('\\') && eos >= 0x20 && eos < 0x7f {
            let req = format!(
                r#"{{"prompt": "spectral", "tokens": 10, "temperature": 0, "stop": ["{text}"]}}"#
            );
            let (code, body) = http_post_json(srv.addr, "/v1/generate", &req).unwrap();
            assert_eq!(code, 200);
            assert_eq!(body.get("finish_reason").unwrap().as_str().unwrap(), "stop");
            assert_eq!(body.get("tokens").unwrap().as_arr().unwrap().len(), first);
        }
        srv.stop();
    }

    #[test]
    fn profile_and_version_endpoints_respond() {
        let srv = test_server(2, 4);
        let (code, body) = http_get_json(srv.addr, "/v1/version").unwrap();
        assert_eq!(code, 200);
        assert_eq!(body.get("name").unwrap().as_str().unwrap(), "sct");
        assert_eq!(body.get("version").unwrap().as_str().unwrap(), env!("CARGO_PKG_VERSION"));
        assert_eq!(body.get("workers").unwrap().as_usize().unwrap(), 1);
        assert!(body.get("threads").unwrap().as_usize().unwrap() >= 1);

        let (code, body) = http_get_json(srv.addr, "/v1/profile").unwrap();
        assert_eq!(code, 200);
        // Profiling may or may not be enabled by a concurrent test; the
        // snapshot document is well-formed either way.
        assert!(body.get("enabled").is_some());
        assert!(body.get("tree").unwrap().as_arr().is_ok());
        assert!(body.get("kernels").unwrap().as_arr().is_ok());

        // Read-only routes answer POST with a typed 405, not a 404.
        let (code, body) = http_post_json(srv.addr, "/v1/version", "{}").unwrap();
        assert_eq!(code, 405);
        assert_envelope(&body, "method_not_allowed");
        let (code, body) = http_post_json(srv.addr, "/v1/profile", "{}").unwrap();
        assert_eq!(code, 405);
        assert_envelope(&body, "method_not_allowed");
        srv.stop();
    }

    #[test]
    fn stats_carry_latency_quantiles_after_traffic() {
        let srv = test_server(2, 4);
        let req = r#"{"prompt": "quantile me", "tokens": 3, "temperature": 0}"#;
        let (code, _) = http_post_json(srv.addr, "/v1/generate", req).unwrap();
        assert_eq!(code, 200);
        let (code, body) = http_get_json(srv.addr, "/v1/stats").unwrap();
        assert_eq!(code, 200);
        let latency = body.get("latency").expect("latency summary present");
        let ttft = latency.get("ttft_ms").expect("ttft histogram summarized after a request");
        assert!(ttft.get("p50").unwrap().as_f64().unwrap() > 0.0);
        assert!(
            ttft.get("p99").unwrap().as_f64().unwrap()
                >= ttft.get("p50").unwrap().as_f64().unwrap()
        );
        srv.stop();
    }

    /// Assert a response body is a well-formed [`ErrorEnvelope`] document.
    fn assert_envelope(body: &Json, code: &str) {
        assert_eq!(body.get("code").unwrap().as_str().unwrap(), code, "body: {body:?}");
        assert!(!body.get("message").unwrap().as_str().unwrap().is_empty());
        assert!(body.get("request_id").unwrap().as_i64().unwrap() > 0);
    }

    #[test]
    fn bad_requests_get_enveloped_4xx() {
        let srv = test_server(1, 2);
        let (code, body) = http_post_json(srv.addr, "/v1/generate", "{not json").unwrap();
        assert_eq!(code, 400);
        assert_envelope(&body, "bad_request");
        let (code, body) = http_post_json(srv.addr, "/v1/generate", r#"{"tokens": 4}"#).unwrap();
        assert_eq!(code, 400, "missing prompt");
        assert_envelope(&body, "bad_request");
        let (code, body) = http_get_json(srv.addr, "/nope").unwrap();
        assert_eq!(code, 404);
        assert_envelope(&body, "not_found");
        let (code, body) = http_roundtrip(
            srv.addr,
            "DELETE /v1/generate HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n",
        )
        .unwrap();
        assert_eq!(code, 405);
        assert_envelope(&body, "method_not_allowed");
        srv.stop();
    }

    #[test]
    fn oversized_body_is_rejected_with_413() {
        let srv = test_server(1, 2);
        // Declared Content-Length beyond the cap: refused before allocation.
        let raw = format!(
            "POST /v1/generate HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n",
            MAX_BODY_BYTES + 1
        );
        let (code, body) = http_roundtrip(srv.addr, &raw).unwrap();
        assert_eq!(code, 413);
        assert_envelope(&body, "payload_too_large");
        srv.stop();
    }

    #[test]
    fn keep_alive_serves_multiple_requests_on_one_connection() {
        let srv = test_server(2, 4);
        let mut conn = TcpStream::connect(srv.addr).unwrap();
        conn.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let body = r#"{"prompt": "hold the line", "tokens": 4, "temperature": 0}"#;
        let raw = format!(
            "POST /v1/generate HTTP/1.1\r\nHost: sct\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        let (code_a, a) = http_exchange(&mut conn, &raw).unwrap();
        let (code_b, b) = http_exchange(&mut conn, &raw).unwrap();
        let (code_h, h) = http_exchange(&mut conn, "GET /healthz HTTP/1.1\r\nHost: sct\r\n\r\n")
            .unwrap();
        assert_eq!((code_a, code_b, code_h), (200, 200, 200));
        assert_eq!(a.get("tokens").unwrap(), b.get("tokens").unwrap());
        assert_eq!(h.get("status").unwrap().as_str().unwrap(), "ok");
        srv.stop();
    }

    #[test]
    fn stalled_connection_is_closed_by_the_read_deadline() {
        // A client that opens a socket and never sends a request must not
        // hold the handler thread past the deadline: the server closes, and
        // our subsequent read sees EOF.
        let srv = test_server_cfg(1, 2, 200);
        let mut conn = TcpStream::connect(srv.addr).unwrap();
        conn.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let t0 = Instant::now();
        let mut buf = [0u8; 16];
        let n = conn.read(&mut buf).unwrap();
        assert_eq!(n, 0, "server must close the idle connection (got {n} bytes)");
        assert!(
            t0.elapsed() < Duration::from_secs(8),
            "close should come from the 200ms deadline, not the client timeout"
        );
        srv.stop();
    }

    #[test]
    fn sse_stream_smoke() {
        let srv = test_server(2, 4);
        let (code, frames) = http_post_sse(
            srv.addr,
            "/v1/generate",
            r#"{"prompt": "stream me", "tokens": 5, "temperature": 0, "stream": true}"#,
        )
        .unwrap();
        assert_eq!(code, 200);
        assert_eq!(frames.len(), 6, "5 token frames + 1 usage frame: {frames:?}");
        let last = frames.last().unwrap();
        assert!(last.data.get("done").unwrap().as_bool().unwrap());
        assert!(last.data.get("ttft_ms").unwrap().as_f64().unwrap() > 0.0);
        let id = last.data.get("request_id").unwrap().as_i64().unwrap();
        assert!(id > 0);
        for f in &frames {
            assert_eq!(
                f.data.get("request_id").unwrap().as_i64().unwrap(),
                id,
                "every frame of a stream carries the same request id"
            );
        }
        srv.stop();
    }
}
