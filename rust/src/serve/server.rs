//! Minimal HTTP/1.1 front-end for the batching engine.
//!
//! The image is offline — no tokio, no hyper, no serde — so this is a
//! `std::net::TcpListener` accept loop with one short-lived handler thread
//! per connection and `util::json` for the bodies. Connections are
//! `Connection: close` (one request per connection), which keeps the parser
//! to request-line + headers + `Content-Length` body.
//!
//! Routes:
//! * `POST /v1/generate` — body `{"prompt": "...", "tokens": N,
//!   "temperature": T, "top_k": K, "seed": S}` (all but `prompt` optional;
//!   `prompt_ids` may replace `prompt`). Responds with the completion text,
//!   token ids, and queue/decode latency.
//! * `GET /healthz` — liveness + uptime.
//! * `GET /v1/stats` — scheduler counters (admitted/completed/tokens/peak).
//!
//! A full admission queue answers `503` (load shedding) rather than holding
//! the connection on the backpressured submit path.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use super::batcher::{Batcher, Request};
use super::engine::{Engine, SampleOpts};
use crate::coordinator::config::TomlDoc;
use crate::data::Tokenizer;
use crate::json_obj;
use crate::util::json::Json;

/// Server + scheduler sizing. CLI flags and the `[serve]` TOML section both
/// land here.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub addr: String,
    /// Concurrent decode slots (KV arena size).
    pub slots: usize,
    /// Bounded admission queue depth.
    pub queue_depth: usize,
    /// Tokens per request when the body does not say.
    pub max_new_default: usize,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            addr: "127.0.0.1:8077".into(),
            slots: 8,
            queue_depth: 32,
            max_new_default: 48,
        }
    }
}

impl ServeConfig {
    /// Apply a `[serve]` section from a parsed TOML document.
    pub fn apply_toml(&mut self, doc: &TomlDoc) -> Result<()> {
        let Some(s) = doc.get("serve") else {
            return Ok(());
        };
        if let Some(v) = s.get("addr") {
            self.addr = v.as_str()?.to_string();
        }
        if let Some(v) = s.get("slots") {
            self.slots = v.as_usize()?;
        }
        if let Some(v) = s.get("queue_depth") {
            self.queue_depth = v.as_usize()?;
        }
        if let Some(v) = s.get("max_new") {
            self.max_new_default = v.as_usize()?;
        }
        Ok(())
    }
}

struct ServerState {
    batcher: Batcher,
    tokenizer: Tokenizer,
    vocab: usize,
    max_new_default: usize,
    started: Instant,
}

/// A running server: accept loop + batcher, stoppable for tests.
pub struct Server {
    pub addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    state: Arc<ServerState>,
}

impl Server {
    /// Bind `cfg.addr` (port 0 picks a free port) and start serving.
    pub fn start(cfg: &ServeConfig, engine: Engine, tokenizer: Tokenizer) -> Result<Server> {
        let vocab = engine.cfg().vocab;
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding {}", cfg.addr))?;
        let addr = listener.local_addr()?;
        let state = Arc::new(ServerState {
            batcher: Batcher::spawn(engine, cfg.slots, cfg.queue_depth),
            tokenizer,
            vocab,
            max_new_default: cfg.max_new_default,
            started: Instant::now(),
        });
        let shutdown = Arc::new(AtomicBool::new(false));
        let accept = {
            let state = state.clone();
            let shutdown = shutdown.clone();
            std::thread::Builder::new()
                .name("sct-serve-accept".into())
                .spawn(move || {
                    for stream in listener.incoming() {
                        if shutdown.load(Ordering::SeqCst) {
                            break;
                        }
                        let Ok(stream) = stream else { continue };
                        let state = state.clone();
                        // Handlers are short-lived (one request, connection
                        // close); the batcher's bounded queue is the real
                        // concurrency limit.
                        std::thread::spawn(move || {
                            let _ = handle_connection(stream, &state);
                        });
                    }
                })
                .expect("spawn accept thread")
        };
        Ok(Server { addr, shutdown, accept: Some(accept), state })
    }

    /// Scheduler counters: (admitted, completed, tokens_out, peak_active).
    pub fn stats(&self) -> (u64, u64, u64, u64) {
        self.state.batcher.stats().snapshot()
    }

    /// Block until the accept loop exits (it only exits via [`Server::stop`]
    /// or process death) — what `sct serve` does after printing the banner.
    pub fn join(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }

    /// Stop accepting, join the accept thread, shut the batcher down.
    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // Unblock the accept() with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        // `state` (and the Batcher in it) drops with self once handlers end.
    }
}

// ---------------------------------------------------------------------------
// minimal blocking client (demos, benches, tests)
// ---------------------------------------------------------------------------

/// Send one raw HTTP/1.1 request and parse the `Connection: close` response:
/// returns (status code, JSON body). This is the client half the serve demo,
/// the integration tests, and external smoke checks share.
pub fn http_roundtrip(addr: SocketAddr, raw: &str) -> Result<(u16, Json)> {
    let mut s = TcpStream::connect(addr).context("connecting to serve endpoint")?;
    s.write_all(raw.as_bytes())?;
    let mut buf = Vec::new();
    s.read_to_end(&mut buf)?;
    let text = String::from_utf8_lossy(&buf);
    let status: u16 = text
        .split_whitespace()
        .nth(1)
        .ok_or_else(|| anyhow!("malformed response: {text:?}"))?
        .parse()
        .context("non-numeric status code")?;
    let payload = text.split("\r\n\r\n").nth(1).unwrap_or("{}");
    Ok((status, Json::parse(payload)?))
}

/// `POST path` with a JSON body via [`http_roundtrip`].
pub fn http_post_json(addr: SocketAddr, path: &str, body: &str) -> Result<(u16, Json)> {
    http_roundtrip(
        addr,
        &format!(
            "POST {path} HTTP/1.1\r\nHost: sct\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        ),
    )
}

/// `GET path` via [`http_roundtrip`].
pub fn http_get_json(addr: SocketAddr, path: &str) -> Result<(u16, Json)> {
    http_roundtrip(addr, &format!("GET {path} HTTP/1.1\r\nHost: sct\r\n\r\n"))
}

// ---------------------------------------------------------------------------
// HTTP plumbing
// ---------------------------------------------------------------------------

struct HttpRequest {
    method: String,
    path: String,
    body: Vec<u8>,
}

/// Generation requests are small JSON documents; anything bigger is abuse.
const MAX_BODY_BYTES: usize = 1 << 20;
/// Hard cap on bytes read per connection (request line + headers + body), so
/// a newline-less flood cannot grow `read_line` without bound.
const MAX_REQUEST_BYTES: u64 = 2 << 20;
const MAX_HEADERS: usize = 64;

fn read_request(stream: &mut TcpStream) -> Result<HttpRequest> {
    stream.set_read_timeout(Some(Duration::from_secs(30))).ok();
    let mut reader = BufReader::new((&mut *stream).take(MAX_REQUEST_BYTES));
    let mut line = String::new();
    reader.read_line(&mut line).context("reading request line")?;
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or_default().to_string();
    let path = parts.next().unwrap_or_default().to_string();
    if method.is_empty() || path.is_empty() {
        bail!("malformed request line {line:?}");
    }
    let mut content_length = 0usize;
    for n_headers in 0.. {
        if n_headers >= MAX_HEADERS {
            bail!("too many headers");
        }
        let mut header = String::new();
        let n = reader.read_line(&mut header)?;
        if n == 0 || header.trim().is_empty() {
            break;
        }
        if let Some((k, v)) = header.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().context("bad Content-Length")?;
            }
        }
    }
    if content_length > MAX_BODY_BYTES {
        bail!("body too large ({content_length} bytes)");
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body).context("reading body")?;
    Ok(HttpRequest { method, path, body })
}

fn write_response(stream: &mut TcpStream, status: u16, reason: &str, body: &Json) -> Result<()> {
    let payload = body.to_string();
    let head = format!(
        "HTTP/1.1 {status} {reason}\r\n\
         Content-Type: application/json\r\n\
         Content-Length: {}\r\n\
         Connection: close\r\n\r\n",
        payload.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(payload.as_bytes())?;
    stream.flush()?;
    Ok(())
}

fn error_json(msg: &str) -> Json {
    json_obj![("error", msg)]
}

fn handle_connection(mut stream: TcpStream, state: &ServerState) -> Result<()> {
    let req = match read_request(&mut stream) {
        Ok(r) => r,
        Err(e) => {
            let _ = write_response(&mut stream, 400, "Bad Request", &error_json(&e.to_string()));
            return Ok(());
        }
    };
    match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/v1/generate") => match handle_generate(&req.body, state) {
            Ok(body) => write_response(&mut stream, 200, "OK", &body),
            Err(e) => {
                let msg = e.to_string();
                if msg.contains("admission queue full") {
                    write_response(&mut stream, 503, "Service Unavailable", &error_json(&msg))
                } else {
                    write_response(&mut stream, 400, "Bad Request", &error_json(&msg))
                }
            }
        },
        ("GET", "/healthz") => {
            let body = json_obj![
                ("status", "ok"),
                ("uptime_s", state.started.elapsed().as_secs_f64()),
                ("slots", state.batcher.slots),
                ("queue_depth", state.batcher.queue_depth),
            ];
            write_response(&mut stream, 200, "OK", &body)
        }
        ("GET", "/v1/stats") => {
            let (admitted, completed, tokens_out, peak_active) =
                state.batcher.stats().snapshot();
            let body = json_obj![
                ("admitted", admitted as i64),
                ("completed", completed as i64),
                ("tokens_out", tokens_out as i64),
                ("peak_active", peak_active as i64),
            ];
            write_response(&mut stream, 200, "OK", &body)
        }
        ("POST", _) | ("GET", _) => {
            write_response(&mut stream, 404, "Not Found", &error_json("no such route"))
        }
        _ => write_response(&mut stream, 405, "Method Not Allowed", &error_json("use GET/POST")),
    }
}

fn handle_generate(body: &[u8], state: &ServerState) -> Result<Json> {
    let j = Json::parse(std::str::from_utf8(body).context("body is not UTF-8")?)
        .context("body is not valid JSON")?;

    // prompt: either text (tokenized here) or explicit ids
    let prompt_ids: Vec<i32> = if let Some(ids) = j.get("prompt_ids") {
        ids.as_arr()?
            .iter()
            .map(|v| Ok(v.as_i64()? as i32))
            .collect::<Result<_>>()?
    } else {
        let text = j
            .get("prompt")
            .ok_or_else(|| anyhow!("missing \"prompt\" (or \"prompt_ids\")"))?
            .as_str()?;
        if text.is_empty() {
            bail!("empty prompt");
        }
        state.tokenizer.encode(text)
    };
    let cap = state.vocab as i32;
    let prompt_ids: Vec<i32> = prompt_ids.into_iter().map(|t| t.rem_euclid(cap)).collect();

    let max_new = match j.get("tokens") {
        Some(v) => v.as_usize()?,
        None => state.max_new_default,
    };
    let opts = SampleOpts {
        temperature: j.get("temperature").map(|v| v.as_f64()).transpose()? .unwrap_or(0.8) as f32,
        top_k: j.get("top_k").map(|v| v.as_usize()).transpose()?.unwrap_or(40),
        seed: j.get("seed").map(|v| v.as_i64()).transpose()?.unwrap_or(0) as u64,
    };

    let prompt_len = prompt_ids.len();
    let completion = state
        .batcher
        .try_submit(Request { prompt: prompt_ids, max_new, opts })?
        .recv()
        .map_err(|_| anyhow!("batcher dropped the request"))?;

    let text = state.tokenizer.decode(&completion.tokens);
    let n = completion.tokens.len();
    let tok_per_s = if completion.decode_ms > 0.0 { n as f64 / (completion.decode_ms / 1e3) } else { 0.0 };
    Ok(json_obj![
        ("completion", text),
        ("tokens", completion.tokens.iter().map(|&t| Json::from(t as i64)).collect::<Vec<_>>()),
        ("prompt_tokens", prompt_len),
        ("queue_ms", completion.queue_ms),
        ("decode_ms", completion.decode_ms),
        ("tok_per_s", tok_per_s),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::engine::{EngineConfig, SpectralModel};

    fn test_server(slots: usize, queue: usize) -> Server {
        let cfg = EngineConfig { max_seq: 64, ..EngineConfig::default() };
        let engine = Engine::new(SpectralModel::init(cfg, 0));
        let serve_cfg = ServeConfig {
            addr: "127.0.0.1:0".into(),
            slots,
            queue_depth: queue,
            max_new_default: 8,
        };
        Server::start(&serve_cfg, engine, Tokenizer::byte_level()).unwrap()
    }

    #[test]
    fn healthz_and_stats_respond() {
        let srv = test_server(2, 4);
        let (code, body) = http_get_json(srv.addr, "/healthz").unwrap();
        assert_eq!(code, 200);
        assert_eq!(body.get("status").unwrap().as_str().unwrap(), "ok");
        let (code, body) = http_get_json(srv.addr, "/v1/stats").unwrap();
        assert_eq!(code, 200);
        assert_eq!(body.get("admitted").unwrap().as_i64().unwrap(), 0);
        srv.stop();
    }

    #[test]
    fn generate_roundtrip_is_deterministic_at_t0() {
        let srv = test_server(2, 4);
        let req = r#"{"prompt": "spectral", "tokens": 6, "temperature": 0}"#;
        let (code, a) = http_post_json(srv.addr, "/v1/generate", req).unwrap();
        assert_eq!(code, 200, "body: {a:?}");
        assert_eq!(a.get("tokens").unwrap().as_arr().unwrap().len(), 6);
        assert_eq!(a.get("prompt_tokens").unwrap().as_usize().unwrap(), 8);
        let (_, b) = http_post_json(srv.addr, "/v1/generate", req).unwrap();
        assert_eq!(
            a.get("tokens").unwrap(),
            b.get("tokens").unwrap(),
            "greedy decode must be reproducible across requests"
        );
        srv.stop();
    }

    #[test]
    fn bad_requests_get_4xx() {
        let srv = test_server(1, 2);
        let (code, _) = http_post_json(srv.addr, "/v1/generate", "{not json").unwrap();
        assert_eq!(code, 400);
        let (code, _) = http_post_json(srv.addr, "/v1/generate", r#"{"tokens": 4}"#).unwrap();
        assert_eq!(code, 400, "missing prompt");
        let (code, _) = http_get_json(srv.addr, "/nope").unwrap();
        assert_eq!(code, 404);
        srv.stop();
    }

    #[test]
    fn oversized_body_is_rejected() {
        let srv = test_server(1, 2);
        // Declared Content-Length beyond the cap: refused before allocation.
        let raw = format!(
            "POST /v1/generate HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n",
            MAX_BODY_BYTES + 1
        );
        let (code, _) = http_roundtrip(srv.addr, &raw).unwrap();
        assert_eq!(code, 400);
        srv.stop();
    }
}
