//! `serve::api` — the typed, versioned wire surface of the HTTP front-end.
//!
//! Everything the server reads off or writes onto a socket body is defined
//! here as a plain Rust type with an explicit JSON mapping, instead of
//! ad-hoc `json_obj!` construction scattered through `server.rs`:
//!
//! * [`GenerateRequest`] — the parsed `POST /v1/generate` body. Parsing is
//!   split in two: [`GenerateRequest::parse`] validates JSON shape (types,
//!   required fields), [`GenerateRequest::resolve`] binds it to a concrete
//!   model (tokenizer, vocab size, default token budget) and produces the
//!   scheduler-level [`Request`]. Both failure modes are client errors.
//! * [`GenerateResponse`] — the non-streaming response document and the
//!   terminal SSE usage frame, built from a scheduler [`Completion`] plus
//!   the id of the worker that served it.
//! * [`ErrorEnvelope`] — the ONE error shape every route returns, including
//!   404/405/413/503: `{"code": "...", "message": "...", "request_id": N}`.
//!   `code` is a stable machine-readable string from [`ErrorCode`] (the
//!   HTTP status is derived from it, never free-floating), and
//!   `request_id` is stamped at construction from the process-wide trace
//!   counter so failed requests are log-correlatable too.
//! * [`stats_json`] — the versioned `GET /v1/stats` document: the flat
//!   aggregate fields are bit-compatible with the pre-gateway (workers=1)
//!   schema, a `workers: [...]` array adds one [`StatsSnapshot`] per
//!   worker scheduler, a `latency: {...}` object summarizes the merged
//!   serve histograms as p50/p95/p99 (same buckets `/metrics` exposes),
//!   `uptime_seconds` counts from server start, and `model: {...}` is the
//!   served model's identity (checkpoint path, params, per-layer ranks).
//!   Old clients keep reading the flat fields; new clients read per-worker
//!   placement out of the array.
//! * [`version_json`] — the `GET /v1/version` document: crate identity plus
//!   build/runtime shape (compiled features, kernel-pool threads, gateway
//!   worker count).
//!
//! The exact wire examples live in the [`crate::serve`] module docs.

use anyhow::{anyhow, bail, Context, Result};

use super::batcher::{Completion, Request, StatsSnapshot, SubmitError};
use super::engine::SampleOpts;
use crate::data::Tokenizer;
use crate::json_obj;
use crate::obs::trace;
use crate::util::json::Json;

// ---------------------------------------------------------------------------
// error envelope
// ---------------------------------------------------------------------------

/// Machine-readable error class. The HTTP status code and reason phrase are
/// derived from this — there is no way to send an envelope whose `code`
/// disagrees with its status line.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorCode {
    /// Malformed body, unknown fields of the wrong type, empty prompt.
    BadRequest,
    /// No such route.
    NotFound,
    /// Route exists, verb is wrong.
    MethodNotAllowed,
    /// Declared `Content-Length` beyond the request body cap.
    PayloadTooLarge,
    /// Every worker's bounded admission queue is full (load shed).
    QueueFull,
    /// Scheduler died or another server-side invariant broke.
    Internal,
}

impl ErrorCode {
    /// Stable wire identifier (the `"code"` field).
    pub fn as_str(&self) -> &'static str {
        match self {
            ErrorCode::BadRequest => "bad_request",
            ErrorCode::NotFound => "not_found",
            ErrorCode::MethodNotAllowed => "method_not_allowed",
            ErrorCode::PayloadTooLarge => "payload_too_large",
            ErrorCode::QueueFull => "queue_full",
            ErrorCode::Internal => "internal",
        }
    }

    pub fn http_status(&self) -> u16 {
        match self {
            ErrorCode::BadRequest => 400,
            ErrorCode::NotFound => 404,
            ErrorCode::MethodNotAllowed => 405,
            ErrorCode::PayloadTooLarge => 413,
            ErrorCode::QueueFull => 503,
            ErrorCode::Internal => 500,
        }
    }

    pub fn reason(&self) -> &'static str {
        match self {
            ErrorCode::BadRequest => "Bad Request",
            ErrorCode::NotFound => "Not Found",
            ErrorCode::MethodNotAllowed => "Method Not Allowed",
            ErrorCode::PayloadTooLarge => "Payload Too Large",
            ErrorCode::QueueFull => "Service Unavailable",
            ErrorCode::Internal => "Internal Server Error",
        }
    }
}

/// The uniform error body every route returns (including 404s on unknown
/// paths): `{"code": "...", "message": "...", "request_id": N}`.
#[derive(Debug, Clone)]
pub struct ErrorEnvelope {
    pub code: ErrorCode,
    pub message: String,
    /// Process-unique id (see [`crate::obs::trace`]). Errors that never
    /// reached a scheduler still get one, so a client-reported failure can
    /// be matched against server logs.
    pub request_id: u64,
}

impl ErrorEnvelope {
    /// Build an envelope, stamping a fresh request id.
    pub fn new(code: ErrorCode, message: impl Into<String>) -> ErrorEnvelope {
        ErrorEnvelope { code, message: message.into(), request_id: trace::next_request_id() }
    }

    /// Map a gateway/batcher submit failure onto the wire.
    pub fn from_submit(e: SubmitError) -> ErrorEnvelope {
        match e {
            SubmitError::QueueFull => ErrorEnvelope::new(
                ErrorCode::QueueFull,
                "admission queue full on every worker (load shed)",
            ),
            SubmitError::Shutdown => {
                ErrorEnvelope::new(ErrorCode::Internal, "scheduler is shut down")
            }
        }
    }

    pub fn to_json(&self) -> Json {
        json_obj![
            ("code", self.code.as_str()),
            ("message", self.message.as_str()),
            ("request_id", self.request_id as i64),
        ]
    }
}

// ---------------------------------------------------------------------------
// generate request
// ---------------------------------------------------------------------------

/// One `"stop"` entry as it appears on the wire: a string (tokenized stop
/// sequence) or a raw token id (EOS semantics).
#[derive(Debug, Clone, PartialEq)]
pub enum StopSpec {
    Text(String),
    Id(i64),
}

/// The parsed `POST /v1/generate` body, model-independent: token budgets are
/// still optional and stop strings untokenized until
/// [`GenerateRequest::resolve`] binds them to an engine's tokenizer/vocab.
#[derive(Debug, Clone)]
pub struct GenerateRequest {
    /// Prompt text (tokenized at resolve time). Exactly one of `prompt` /
    /// `prompt_ids` must be present.
    pub prompt: Option<String>,
    /// Explicit prompt token ids (wrapped into the vocab at resolve time).
    pub prompt_ids: Option<Vec<i64>>,
    /// Requested new tokens; `None` falls back to the server default.
    pub tokens: Option<usize>,
    pub temperature: f32,
    pub top_k: usize,
    pub seed: u64,
    pub stop: Vec<StopSpec>,
    /// `true` selects the SSE streaming response.
    pub stream: bool,
}

impl GenerateRequest {
    /// Parse and shape-validate a request body. Everything that can be
    /// checked without a model is checked here; any error is a 400.
    pub fn parse(body: &[u8]) -> Result<GenerateRequest> {
        let j = Json::parse(std::str::from_utf8(body).context("body is not UTF-8")?)
            .context("body is not valid JSON")?;

        let prompt = match j.get("prompt") {
            Some(v) => Some(v.as_str()?.to_string()),
            None => None,
        };
        let prompt_ids: Option<Vec<i64>> = match j.get("prompt_ids") {
            Some(ids) => {
                Some(ids.as_arr()?.iter().map(|v| v.as_i64()).collect::<Result<_>>()?)
            }
            None => None,
        };
        if prompt.is_none() && prompt_ids.is_none() {
            bail!("missing \"prompt\" (or \"prompt_ids\")");
        }

        let mut stop = Vec::new();
        if let Some(list) = j.get("stop") {
            for entry in list.as_arr().context("\"stop\" must be an array")? {
                stop.push(match entry.as_str() {
                    Ok(text) => StopSpec::Text(text.to_string()),
                    Err(_) => StopSpec::Id(
                        entry.as_i64().context("stop entries are strings or token ids")?,
                    ),
                });
            }
        }

        Ok(GenerateRequest {
            prompt,
            prompt_ids,
            tokens: j.get("tokens").map(|v| v.as_usize()).transpose()?,
            temperature: j.get("temperature").map(|v| v.as_f64()).transpose()?.unwrap_or(0.8)
                as f32,
            top_k: j.get("top_k").map(|v| v.as_usize()).transpose()?.unwrap_or(40),
            seed: j.get("seed").map(|v| v.as_i64()).transpose()?.unwrap_or(0) as u64,
            stop,
            stream: j.get("stream").map(|v| v.as_bool()).transpose()?.unwrap_or(false),
        })
    }

    /// Bind the request to a concrete model, producing the scheduler-level
    /// [`Request`]: the prompt is tokenized (or the explicit ids wrapped
    /// into the vocab), stop strings are tokenized, and out-of-vocab stop
    /// ids are dropped — an id the sampler can never produce must never
    /// match, and wrapping it would silently turn a foreign tokenizer's EOS
    /// into a real, spuriously-matching token.
    pub fn resolve(
        &self,
        tokenizer: &Tokenizer,
        vocab: usize,
        max_new_default: usize,
    ) -> Result<Request> {
        let cap = vocab as i32;
        let prompt: Vec<i32> = if let Some(ids) = &self.prompt_ids {
            ids.iter().map(|&t| (t as i32).rem_euclid(cap)).collect()
        } else {
            let text = self.prompt.as_deref().ok_or_else(|| anyhow!("missing \"prompt\""))?;
            if text.is_empty() {
                bail!("empty prompt");
            }
            tokenizer.encode(text)
        };
        let mut stop: Vec<Vec<i32>> = Vec::new();
        for spec in &self.stop {
            let ids: Vec<i32> = match spec {
                StopSpec::Text(text) => tokenizer.encode(text),
                StopSpec::Id(id) => {
                    if (0..cap as i64).contains(id) {
                        vec![*id as i32]
                    } else {
                        vec![]
                    }
                }
            };
            if !ids.is_empty() {
                stop.push(ids);
            }
        }
        Ok(Request {
            prompt,
            max_new: self.tokens.unwrap_or(max_new_default),
            opts: SampleOpts { temperature: self.temperature, top_k: self.top_k, seed: self.seed },
            stop,
        })
    }
}

// ---------------------------------------------------------------------------
// generate response
// ---------------------------------------------------------------------------

/// The `POST /v1/generate` response document (and, minus the token array,
/// the terminal SSE usage frame).
#[derive(Debug, Clone)]
pub struct GenerateResponse {
    pub request_id: u64,
    /// Gateway worker that ran the request (informational; at temperature 0
    /// the output is token-identical regardless of placement).
    pub worker: usize,
    pub completion: String,
    pub tokens: Vec<i32>,
    pub prompt_tokens: usize,
    pub finish_reason: &'static str,
    pub queue_ms: f64,
    pub decode_ms: f64,
    pub tok_per_s: f64,
    /// Omitted from the wire (not 0, not null) when no token was sampled,
    /// so latency aggregators never absorb a fake zero.
    pub ttft_ms: Option<f64>,
}

impl GenerateResponse {
    pub fn new(c: &Completion, tokenizer: &Tokenizer, worker: usize) -> GenerateResponse {
        let n = c.tokens.len();
        GenerateResponse {
            request_id: c.request_id,
            worker,
            completion: tokenizer.decode(&c.tokens),
            tokens: c.tokens.clone(),
            prompt_tokens: c.prompt_len,
            finish_reason: c.finish_reason.as_str(),
            queue_ms: c.queue_ms,
            decode_ms: c.decode_ms,
            tok_per_s: if c.decode_ms > 0.0 { n as f64 / (c.decode_ms / 1e3) } else { 0.0 },
            ttft_ms: c.ttft_ms,
        }
    }

    /// Usage fields shared by the one-shot document and the SSE done frame.
    fn usage_fields(&self, body: &mut Json) {
        if let (Json::Obj(fields), Some(t)) = (body, self.ttft_ms) {
            fields.push(("ttft_ms".to_string(), t.into()));
        }
    }

    /// The one-shot (non-streaming) response document.
    pub fn to_json(&self) -> Json {
        let mut body = json_obj![
            ("request_id", self.request_id as i64),
            ("worker", self.worker),
            ("completion", self.completion.as_str()),
            ("tokens", self.tokens.iter().map(|&t| Json::from(t as i64)).collect::<Vec<_>>()),
            ("prompt_tokens", self.prompt_tokens),
            ("finish_reason", self.finish_reason),
            ("queue_ms", self.queue_ms),
            ("decode_ms", self.decode_ms),
            ("tok_per_s", self.tok_per_s),
        ];
        self.usage_fields(&mut body);
        body
    }

    /// The terminal SSE frame: `done: true` plus the usage stats (the token
    /// ids already went out one frame at a time, so no `tokens` array).
    pub fn to_sse_done_json(&self) -> Json {
        let mut body = json_obj![
            ("request_id", self.request_id as i64),
            ("done", true),
            ("worker", self.worker),
            ("completion", self.completion.as_str()),
            ("prompt_tokens", self.prompt_tokens),
            ("finish_reason", self.finish_reason),
            ("queue_ms", self.queue_ms),
            ("decode_ms", self.decode_ms),
            ("tok_per_s", self.tok_per_s),
        ];
        self.usage_fields(&mut body);
        body
    }
}

/// One per-token SSE frame.
pub fn sse_token_json(request_id: u64, token: i32, index: usize, text: &str) -> Json {
    json_obj![
        ("request_id", request_id as i64),
        ("token", token as i64),
        ("index", index),
        ("text", text),
    ]
}

// ---------------------------------------------------------------------------
// versioned stats document
// ---------------------------------------------------------------------------

/// The nine counter/gauge fields of one [`StatsSnapshot`], in schema order.
fn snapshot_fields(s: &StatsSnapshot) -> Vec<(&'static str, i64)> {
    vec![
        ("admitted", s.admitted as i64),
        ("completed", s.completed as i64),
        ("tokens_out", s.tokens_out as i64),
        ("peak_active", s.peak_active as i64),
        ("prefill_tokens", s.prefill_tokens as i64),
        ("cancelled", s.cancelled as i64),
        ("stopped", s.stopped as i64),
        ("queue_depth", s.queue_depth as i64),
        ("active_slots", s.active_slots as i64),
    ]
}

/// Render the `GET /v1/stats` document. The flat top-level fields are the
/// aggregate across workers — bit-compatible with the single-scheduler
/// schema old clients parse — and `workers: [...]` carries one snapshot per
/// worker (each tagged with its `worker` index, matching the `worker="i"`
/// label on the `sct_serve_*` Prometheus series). `uptime_seconds` and the
/// `model` identity document (checkpoint path, params, per-layer ranks)
/// tie a scrape to the process and weights that produced it.
pub fn stats_json(
    aggregate: &StatsSnapshot,
    workers: &[StatsSnapshot],
    uptime_seconds: f64,
    model: &Json,
) -> Json {
    let mut fields: Vec<(String, Json)> = snapshot_fields(aggregate)
        .into_iter()
        .map(|(k, v)| (k.to_string(), Json::from(v)))
        .collect();
    let worker_docs: Vec<Json> = workers
        .iter()
        .enumerate()
        .map(|(i, s)| {
            let mut w: Vec<(String, Json)> = vec![("worker".to_string(), Json::from(i as i64))];
            w.extend(snapshot_fields(s).into_iter().map(|(k, v)| (k.to_string(), Json::from(v))));
            Json::Obj(w)
        })
        .collect();
    fields.push(("workers".to_string(), Json::from(worker_docs)));
    fields.push(("latency".to_string(), latency_json()));
    fields.push(("uptime_seconds".to_string(), Json::Num(uptime_seconds)));
    fields.push(("model".to_string(), model.clone()));
    Json::Obj(fields)
}

/// p50/p95/p99 of the serve latency histograms, merged across every worker
/// label set — the machine summary of the same log2 buckets `/metrics`
/// exposes raw. Histograms not yet registered (no request served) are
/// simply absent from the object.
pub fn latency_json() -> Json {
    let reg = crate::obs::registry();
    let mut out: Vec<(String, Json)> = Vec::new();
    for name in [
        "sct_serve_queue_wait_ms",
        "sct_serve_ttft_ms",
        "sct_serve_prefill_chunk_ms",
        "sct_serve_decode_step_ms",
    ] {
        if let Some(q) = reg.histogram_quantiles(name, &[0.5, 0.95, 0.99]) {
            let key = name.trim_start_matches("sct_serve_").to_string();
            out.push((key, json_obj![("p50", q[0]), ("p95", q[1]), ("p99", q[2])]));
        }
    }
    Json::Obj(out)
}

/// Render the `GET /v1/version` document: crate identity (name, version)
/// plus the build/runtime shape a client needs to interpret benchmarks —
/// compiled cargo features, kernel-pool thread count, gateway worker count.
pub fn version_json(workers: usize) -> Json {
    let mut features: Vec<Json> = Vec::new();
    if cfg!(feature = "pjrt") {
        features.push(Json::Str("pjrt".to_string()));
    }
    json_obj![
        ("name", env!("CARGO_PKG_NAME")),
        ("version", env!("CARGO_PKG_VERSION")),
        ("features", features),
        ("threads", crate::util::pool::threads()),
        ("workers", workers),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::batcher::FinishReason;

    #[test]
    fn parse_applies_sampling_defaults() {
        let g = GenerateRequest::parse(br#"{"prompt": "hi"}"#).unwrap();
        assert_eq!(g.prompt.as_deref(), Some("hi"));
        assert_eq!(g.tokens, None);
        assert_eq!(g.temperature, 0.8);
        assert_eq!(g.top_k, 40);
        assert_eq!(g.seed, 0);
        assert!(g.stop.is_empty());
        assert!(!g.stream);
    }

    #[test]
    fn parse_rejects_malformed_bodies() {
        assert!(GenerateRequest::parse(b"{not json").is_err());
        assert!(GenerateRequest::parse(br#"{"tokens": 4}"#).is_err(), "no prompt");
        assert!(GenerateRequest::parse(br#"{"prompt": "x", "stop": 3}"#).is_err());
        assert!(GenerateRequest::parse(br#"{"prompt": "x", "stop": [true]}"#).is_err());
        assert!(GenerateRequest::parse(br#"{"prompt": "x", "tokens": "many"}"#).is_err());
    }

    #[test]
    fn resolve_binds_tokenizer_vocab_and_defaults() {
        let tok = Tokenizer::byte_level();
        let g = GenerateRequest::parse(br#"{"prompt": "ab", "stop": ["a", 300, 65, -1]}"#).unwrap();
        let r = g.resolve(&tok, 256, 48).unwrap();
        assert_eq!(r.prompt, tok.encode("ab"));
        assert_eq!(r.max_new, 48, "server default budget");
        // "a" tokenizes, 65 is in-vocab; 300 and -1 are out-of-vocab ids and
        // must be dropped, never wrapped.
        assert_eq!(r.stop, vec![tok.encode("a"), vec![65]]);

        let g = GenerateRequest::parse(br#"{"prompt_ids": [300, -1], "tokens": 3}"#).unwrap();
        let r = g.resolve(&tok, 256, 48).unwrap();
        assert_eq!(r.prompt, vec![44, 255], "prompt ids wrap into the vocab");
        assert_eq!(r.max_new, 3);

        let g = GenerateRequest::parse(br#"{"prompt": ""}"#).unwrap();
        assert!(g.resolve(&tok, 256, 48).is_err(), "empty prompt is a client error");
    }

    #[test]
    fn error_envelope_maps_codes_to_statuses() {
        for (code, status) in [
            (ErrorCode::BadRequest, 400),
            (ErrorCode::NotFound, 404),
            (ErrorCode::MethodNotAllowed, 405),
            (ErrorCode::PayloadTooLarge, 413),
            (ErrorCode::QueueFull, 503),
            (ErrorCode::Internal, 500),
        ] {
            assert_eq!(code.http_status(), status);
        }
        let e = ErrorEnvelope::new(ErrorCode::QueueFull, "shed");
        assert!(e.request_id > 0, "errors are log-correlatable too");
        let j = e.to_json();
        assert_eq!(j.get("code").unwrap().as_str().unwrap(), "queue_full");
        assert_eq!(j.get("message").unwrap().as_str().unwrap(), "shed");
        assert_eq!(j.get("request_id").unwrap().as_i64().unwrap(), e.request_id as i64);

        let shed = ErrorEnvelope::from_submit(SubmitError::QueueFull);
        assert_eq!(shed.code, ErrorCode::QueueFull);
        assert!(shed.message.contains("admission queue full"), "legacy substring preserved");
        assert_eq!(ErrorEnvelope::from_submit(SubmitError::Shutdown).code, ErrorCode::Internal);
    }

    #[test]
    fn generate_response_omits_absent_ttft() {
        let tok = Tokenizer::byte_level();
        let c = Completion {
            request_id: 9,
            tokens: vec![104, 105],
            prompt_len: 4,
            queue_ms: 0.5,
            ttft_ms: None,
            decode_ms: 2.0,
            finish_reason: FinishReason::Length,
        };
        let r = GenerateResponse::new(&c, &tok, 1);
        let j = r.to_json();
        assert!(j.get("ttft_ms").is_none(), "no fake zero TTFT");
        assert_eq!(j.get("worker").unwrap().as_usize().unwrap(), 1);
        assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(j.get("completion").unwrap().as_str().unwrap(), tok.decode(&[104, 105]));
        let done = r.to_sse_done_json();
        assert!(done.get("done").unwrap().as_bool().unwrap());
        assert!(done.get("tokens").is_none(), "SSE already streamed the ids");

        let with_ttft = GenerateResponse::new(&Completion { ttft_ms: Some(1.5), ..c }, &tok, 0);
        assert_eq!(with_ttft.to_json().get("ttft_ms").unwrap().as_f64().unwrap(), 1.5);
    }

    #[test]
    fn stats_json_keeps_flat_aggregate_and_adds_workers() {
        let w0 = StatsSnapshot { admitted: 3, completed: 2, tokens_out: 10, ..Default::default() };
        let w1 = StatsSnapshot { admitted: 1, completed: 1, tokens_out: 4, ..Default::default() };
        let agg = StatsSnapshot {
            admitted: 4,
            completed: 3,
            tokens_out: 14,
            ..Default::default()
        };
        let model = json_obj![("checkpoint", Json::Null), ("params", 1234usize)];
        let j = stats_json(&agg, &[w0, w1], 12.5, &model);
        // flat fields: the pre-gateway schema, bit-compatible
        assert_eq!(j.get("admitted").unwrap().as_i64().unwrap(), 4);
        assert_eq!(j.get("completed").unwrap().as_i64().unwrap(), 3);
        assert_eq!(j.get("tokens_out").unwrap().as_i64().unwrap(), 14);
        assert_eq!(j.get("active_slots").unwrap().as_i64().unwrap(), 0);
        // per-worker array
        let workers = j.get("workers").unwrap().as_arr().unwrap();
        assert_eq!(workers.len(), 2);
        assert_eq!(workers[0].get("worker").unwrap().as_i64().unwrap(), 0);
        assert_eq!(workers[0].get("admitted").unwrap().as_i64().unwrap(), 3);
        assert_eq!(workers[1].get("worker").unwrap().as_i64().unwrap(), 1);
        assert_eq!(workers[1].get("tokens_out").unwrap().as_i64().unwrap(), 4);
        // latency summary object is always present (possibly empty before
        // any request registered the serve histograms)
        assert!(matches!(j.get("latency"), Some(Json::Obj(_))));
        // identity fields ride along verbatim
        assert_eq!(j.get("uptime_seconds").unwrap().as_f64().unwrap(), 12.5);
        assert_eq!(j.get("model").unwrap().get("params").unwrap().as_usize().unwrap(), 1234);
    }

    #[test]
    fn latency_json_reports_quantiles_once_histograms_exist() {
        let r = crate::obs::registry();
        let h = r.histogram_with("sct_serve_ttft_ms", &[("worker", "91")], "test");
        for _ in 0..100 {
            h.record(1.5);
        }
        let j = latency_json();
        let ttft = j.get("ttft_ms").expect("registered histogram summarized");
        let p50 = ttft.get("p50").unwrap().as_f64().unwrap();
        assert!(p50 > 0.0 && p50 <= 2.1, "p50 within the recorded bucket, got {p50}");
        assert!(ttft.get("p99").unwrap().as_f64().unwrap() >= p50);
    }

    #[test]
    fn version_json_reports_crate_identity_and_runtime_shape() {
        let j = version_json(3);
        assert_eq!(j.get("name").unwrap().as_str().unwrap(), "sct");
        assert_eq!(j.get("version").unwrap().as_str().unwrap(), env!("CARGO_PKG_VERSION"));
        assert!(j.get("features").unwrap().as_arr().is_ok());
        assert!(j.get("threads").unwrap().as_usize().unwrap() >= 1);
        assert_eq!(j.get("workers").unwrap().as_usize().unwrap(), 3);
    }
}
