//! `serve::gateway` — sharded multi-engine serving: N independent worker
//! schedulers behind one load-aware placement front.
//!
//! One [`super::batcher::Batcher`] thread owns one engine and one KV arena —
//! that is the whole machine when there is one of them. The gateway holds
//! `workers` of them, each with its **own** [`Engine`] clone (the compact
//! spectral factors make a full model replica cheap — that is the paper's
//! economics applied to serving) and its own KV arena, scheduler thread,
//! and bounded admission queue. The HTTP front-end stays single: it submits
//! through [`Gateway::try_submit`] / [`Gateway::try_submit_streaming`] and
//! the gateway picks the worker.
//!
//! # Placement
//!
//! Least-outstanding-tokens, queue-depth tiebreak, worker-index final tie:
//! for each worker the gateway tracks an *outstanding token* gauge — the sum
//! of `prompt_len + max_new` over requests placed there whose client is
//! still attached — and places each request on the worker with the smallest
//! gauge; among equals, the one with the shallowest admission queue; among
//! those, the lowest index (deterministic). The gauge is charged *before*
//! the submit (so concurrent placements observe each other) and released by
//! a guard tied to the returned [`Placed`] handle — when the handler drops
//! it (response written, or client hung up), the worker's load drains even
//! if the sequence was cancelled server-side.
//!
//! A worker whose bounded queue is full is skipped and the next-least-loaded
//! one tried; [`SubmitError::QueueFull`] comes back only when EVERY worker
//! refused — the 503 load-shed surface is now the whole fleet's capacity.
//!
//! # Determinism
//!
//! Placement cannot change what a request decodes: every worker runs an
//! identical engine clone built from the same weights, and the kernels
//! underneath are bit-deterministic at any thread count (the
//! `util::pool` contract from the parallel-kernel layer). A temperature-0
//! request therefore returns token-identical output whether the gateway has
//! 1 worker or 8, and whichever worker it lands on — pinned by unit tests
//! here and over HTTP in `tests/serve_integration.rs`.
//!
//! # Observability
//!
//! Each worker's scheduler registers its `sct_serve_*` series with a
//! `worker="<index>"` label (see [`super::batcher`]); [`Gateway::stats`]
//! sums the per-worker [`StatsSnapshot`]s into the flat aggregate the
//! legacy `/v1/stats` fields report, and [`Gateway::worker_stats`] feeds
//! the versioned `workers: [...]` array ([`super::api::stats_json`]).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use super::batcher::{
    BatchConfig, Batcher, Completion, Request, StatsSnapshot, StreamEvent, SubmitError,
};
use super::engine::Engine;
use crate::obs::trace;

/// Gateway sizing: worker count plus the per-worker scheduler sizing.
#[derive(Debug, Clone, Copy)]
pub struct GatewayConfig {
    /// Independent worker schedulers (engine clone + KV arena each).
    pub workers: usize,
    /// Applied to EVERY worker (`slots` decode slots and `queue_depth`
    /// admission entries *per worker*; the `worker` field is overridden
    /// with each worker's index).
    pub batch: BatchConfig,
}

impl Default for GatewayConfig {
    fn default() -> GatewayConfig {
        GatewayConfig { workers: 1, batch: BatchConfig::default() }
    }
}

struct Worker {
    batcher: Batcher,
    /// Sum of `prompt_len + max_new` over placed requests whose client is
    /// still attached (released by [`LoadGuard`]).
    outstanding: Arc<AtomicU64>,
}

/// N worker schedulers behind least-outstanding-tokens placement. Dropping
/// the gateway closes every worker's queue and joins their threads after
/// in-flight sequences finish.
pub struct Gateway {
    workers: Vec<Worker>,
}

/// Releases a placement's token charge when dropped.
struct LoadGuard {
    outstanding: Arc<AtomicU64>,
    cost: u64,
}

impl Drop for LoadGuard {
    fn drop(&mut self) {
        self.outstanding.fetch_sub(self.cost, Ordering::Relaxed);
    }
}

/// A placed request: which worker took it, its request id, and the channel
/// its output arrives on. Holding this handle keeps the request's token
/// cost charged against the worker; drop it when done with the receiver.
pub struct Placed<T> {
    pub worker: usize,
    pub request_id: u64,
    pub rx: Receiver<T>,
    _load: LoadGuard,
}

/// Estimated token footprint of a request: prompt to prefill + budgeted
/// output. What the placement gauge charges.
fn request_cost(req: &Request) -> u64 {
    (req.prompt.len() + req.max_new).max(1) as u64
}

/// Worker indices in placement order for the observed `(outstanding_tokens,
/// queue_depth)` loads: least outstanding first, shallower queue breaking
/// ties, lower index breaking those (deterministic, and exhaustive — every
/// worker appears, so a full best choice falls through to the next).
fn placement_order(loads: &[(u64, u64)]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..loads.len()).collect();
    idx.sort_by_key(|&i| (loads[i].0, loads[i].1, i));
    idx
}

/// Sum per-worker snapshots into the fleet-wide aggregate (the flat
/// `/v1/stats` fields). Counters and live gauges add; `peak_active` is the
/// sum of per-worker peaks — an upper bound on simultaneously active
/// sequences, exact when there is one worker.
pub fn aggregate_stats(workers: &[StatsSnapshot]) -> StatsSnapshot {
    let mut a = StatsSnapshot::default();
    for s in workers {
        a.admitted += s.admitted;
        a.completed += s.completed;
        a.tokens_out += s.tokens_out;
        a.peak_active += s.peak_active;
        a.prefill_tokens += s.prefill_tokens;
        a.cancelled += s.cancelled;
        a.stopped += s.stopped;
        a.queue_depth += s.queue_depth;
        a.active_slots += s.active_slots;
    }
    a
}

impl Gateway {
    /// Spawn `cfg.workers` schedulers, each with its own clone of `engine`
    /// (the original is moved into the last worker, so a single-worker
    /// gateway clones nothing).
    pub fn start(engine: Engine, cfg: &GatewayConfig) -> Gateway {
        let n = cfg.workers.max(1);
        let mut engines = Vec::with_capacity(n);
        for _ in 0..n - 1 {
            engines.push(engine.clone());
        }
        engines.push(engine);
        let workers = engines
            .into_iter()
            .enumerate()
            .map(|(i, eng)| Worker {
                batcher: Batcher::spawn_with(eng, BatchConfig { worker: i, ..cfg.batch }),
                outstanding: Arc::new(AtomicU64::new(0)),
            })
            .collect();
        Gateway { workers }
    }

    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Point-in-time snapshot of every worker scheduler, by worker index.
    pub fn worker_stats(&self) -> Vec<StatsSnapshot> {
        self.workers.iter().map(|w| w.batcher.stats().snapshot()).collect()
    }

    /// Fleet-wide aggregate (see [`aggregate_stats`]).
    pub fn stats(&self) -> StatsSnapshot {
        aggregate_stats(&self.worker_stats())
    }

    /// Per-worker outstanding-token gauges (placement inputs; test hook).
    pub fn outstanding_tokens(&self) -> Vec<u64> {
        self.workers.iter().map(|w| w.outstanding.load(Ordering::Relaxed)).collect()
    }

    fn place<T>(
        &self,
        req: Request,
        submit: impl Fn(&Batcher, Request) -> Result<(u64, Receiver<T>), SubmitError>,
    ) -> Result<Placed<T>, SubmitError> {
        let cost = request_cost(&req);
        let loads: Vec<(u64, u64)> = self
            .workers
            .iter()
            .map(|w| {
                (w.outstanding.load(Ordering::Relaxed), w.batcher.stats().snapshot().queue_depth)
            })
            .collect();
        for i in placement_order(&loads) {
            let w = &self.workers[i];
            // Charge the gauge BEFORE submitting so a concurrent placement
            // sees this request's footprint; the guard refunds it if this
            // worker refuses (and, on success, when the client detaches).
            w.outstanding.fetch_add(cost, Ordering::Relaxed);
            let guard = LoadGuard { outstanding: w.outstanding.clone(), cost };
            match submit(&w.batcher, req.clone()) {
                Ok((request_id, rx)) => {
                    // Root span of the request's trace tree: its span_id IS
                    // the request id, so worker-side spans parent to it
                    // without any cross-thread plumbing.
                    if trace::enabled() {
                        trace::emit(&crate::json_obj![
                            ("kind", "gateway"),
                            ("span_id", request_id as i64),
                            ("request_id", request_id as i64),
                            ("worker", i as i64),
                            ("queue_depth", loads[i].1 as i64),
                            ("outstanding_tokens", loads[i].0 as i64),
                            ("cost_tokens", cost as i64),
                        ]);
                    }
                    return Ok(Placed { worker: i, request_id, rx, _load: guard })
                }
                Err(SubmitError::QueueFull) => continue, // guard refunds; try next
                Err(SubmitError::Shutdown) => return Err(SubmitError::Shutdown),
            }
        }
        // Every worker's bounded queue refused: the fleet is at capacity.
        Err(SubmitError::QueueFull)
    }

    /// Place a one-shot request on the least-loaded worker (load-shedding:
    /// errors instead of blocking when every queue is full).
    pub fn try_submit(&self, req: Request) -> Result<Placed<Completion>, SubmitError> {
        self.place(req, |b, r| b.try_submit_with_id(r))
    }

    /// Place a streaming request (see [`Gateway::try_submit`]).
    pub fn try_submit_streaming(&self, req: Request) -> Result<Placed<StreamEvent>, SubmitError> {
        self.place(req, |b, r| b.try_submit_streaming_with_id(r))
    }

    /// Place, then block for the completion: `(worker, completion)`. The
    /// demo/bench convenience path (size `queue_depth` for the burst —
    /// placement still load-sheds).
    pub fn generate(&self, req: Request) -> Result<(usize, Completion)> {
        let placed = self.try_submit(req).map_err(|e| anyhow!(e))?;
        let c = placed.rx.recv().map_err(|_| anyhow!("scheduler dropped the request"))?;
        Ok((placed.worker, c))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::engine::{EngineConfig, SampleOpts, SpectralModel};

    fn tiny_cfg() -> EngineConfig {
        EngineConfig {
            vocab: 50,
            d_model: 32,
            n_layers: 2,
            n_heads: 4,
            d_ffn: 48,
            rank: 4,
            max_seq: 32,
            tied: true,
        }
    }

    fn gateway(workers: usize, slots: usize, queue_depth: usize) -> Gateway {
        Gateway::start(
            Engine::new(SpectralModel::init(tiny_cfg(), 0)),
            &GatewayConfig {
                workers,
                batch: BatchConfig { slots, queue_depth, prefill_chunk: 4, worker: 0 },
            },
        )
    }

    fn greedy(prompt: Vec<i32>, n: usize) -> Request {
        Request {
            prompt,
            max_new: n,
            opts: SampleOpts { temperature: 0.0, top_k: 0, seed: 0 },
            stop: vec![],
        }
    }

    #[test]
    fn placement_order_prefers_least_tokens_then_queue_then_index() {
        // outstanding tokens dominate
        assert_eq!(placement_order(&[(10, 0), (3, 9), (7, 0)]), vec![1, 2, 0]);
        // queue depth breaks token ties
        assert_eq!(placement_order(&[(5, 2), (5, 0), (5, 1)]), vec![1, 2, 0]);
        // index breaks full ties (deterministic placement)
        assert_eq!(placement_order(&[(5, 1), (5, 1), (0, 0)]), vec![2, 0, 1]);
        assert_eq!(placement_order(&[]), Vec::<usize>::new());
    }

    #[test]
    fn placement_routes_around_a_loaded_worker() {
        let g = gateway(2, 1, 4);
        // First request lands on worker 0 (all loads equal, index tiebreak)
        // and we HOLD its handle, keeping the charge outstanding.
        let a = g.try_submit(greedy(vec![1, 2, 3], 8)).unwrap();
        assert_eq!(a.worker, 0);
        assert_eq!(g.outstanding_tokens()[0], 11, "prompt 3 + budget 8 charged");
        // Second request must see worker 0's load and go to worker 1.
        let b = g.try_submit(greedy(vec![4, 5, 6], 8)).unwrap();
        assert_eq!(b.worker, 1, "least-outstanding-tokens placement");

        let ca = a.rx.recv().unwrap();
        let cb = b.rx.recv().unwrap();
        assert_eq!(ca.tokens.len(), 8);
        assert_eq!(cb.tokens.len(), 8);
        drop(a);
        drop(b);
        assert_eq!(g.outstanding_tokens(), vec![0, 0], "guards drain the gauges");
        let per_worker = g.worker_stats();
        assert_eq!(per_worker[0].admitted, 1);
        assert_eq!(per_worker[1].admitted, 1);
        let agg = g.stats();
        assert_eq!((agg.admitted, agg.completed), (2, 2));
        assert_eq!(agg.tokens_out, 16);
    }

    #[test]
    fn full_fleet_sheds_with_queue_full() {
        // 2 workers x (1 slot + depth-1 queue) and slow requests: a burst
        // larger than fleet capacity must eventually shed, and the error is
        // QueueFull only (never a false Shutdown).
        let g = gateway(2, 1, 1);
        let mut pending = Vec::new();
        let mut shed = None;
        for i in 0..40 {
            match g.try_submit(greedy(vec![i % 50], 20)) {
                Ok(p) => pending.push(p),
                Err(e) => {
                    shed = Some(e);
                    break;
                }
            }
        }
        assert_eq!(shed, Some(SubmitError::QueueFull), "full fleet sheds load");
        assert!(pending.len() >= 2, "both workers took work before the shed");
        let workers: Vec<usize> = pending.iter().map(|p| p.worker).collect();
        assert!(workers.contains(&0) && workers.contains(&1), "placement spread: {workers:?}");
        for p in pending {
            assert!(p.rx.recv().is_ok(), "shed requests never cancel admitted ones");
        }
    }

    #[test]
    fn t0_output_is_identical_at_any_worker_count_and_placement() {
        let solo = gateway(1, 1, 8);
        let (w, base) = solo.generate(greedy(vec![7, 3, 1], 6)).unwrap();
        assert_eq!(w, 0);

        let sharded = gateway(2, 1, 8);
        // Hold the first placement so the second lands on the other worker:
        // the same prompt now decodes on BOTH workers.
        let a = sharded.try_submit(greedy(vec![7, 3, 1], 6)).unwrap();
        let b = sharded.try_submit(greedy(vec![7, 3, 1], 6)).unwrap();
        assert_ne!(a.worker, b.worker, "both workers exercised");
        let ca = a.rx.recv().unwrap();
        let cb = b.rx.recv().unwrap();
        assert_eq!(ca.tokens, base.tokens, "worker count must not change T=0 output");
        assert_eq!(cb.tokens, base.tokens, "placement must not change T=0 output");
    }

    #[test]
    fn refused_placement_refunds_the_load_charge() {
        // Saturate a 1-worker gateway, then get refused: the failed
        // placement must not leave a phantom charge on the gauge.
        let g = gateway(1, 1, 1);
        let mut pending = Vec::new();
        loop {
            match g.try_submit(greedy(vec![9], 20)) {
                Ok(p) => pending.push(p),
                Err(SubmitError::QueueFull) => break,
                Err(e) => panic!("unexpected {e:?}"),
            }
        }
        let charged: u64 = pending.iter().map(|_| 21u64).sum();
        assert_eq!(g.outstanding_tokens()[0], charged, "only live placements stay charged");
        for p in pending {
            assert!(p.rx.recv().is_ok());
        }
    }
}
