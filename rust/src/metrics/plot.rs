//! ASCII plots — Figure 2 (loss curves) and Figure 3 (Pareto scatter)
//! renderers for terminal + EXPERIMENTS.md output.

/// Multi-series line plot (Figure 2 style). Each series is (label, ys);
/// x is the step index. Rows x cols fixed character grid, shared y-range.
pub fn line_plot(series: &[(String, Vec<f32>)], rows: usize, cols: usize) -> String {
    let finite = |v: &f32| v.is_finite();
    let all: Vec<f32> = series.iter().flat_map(|(_, ys)| ys.iter().cloned()).filter(finite).collect();
    if all.is_empty() {
        return "(no data)\n".into();
    }
    let ymin = all.iter().cloned().fold(f32::INFINITY, f32::min);
    let ymax = all.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let span = (ymax - ymin).max(1e-6);
    let marks = ['*', '+', 'o', 'x', '#', '@', '%', '&'];

    let mut grid = vec![vec![' '; cols]; rows];
    let max_len = series.iter().map(|(_, ys)| ys.len()).max().unwrap_or(1).max(2);
    for (si, (_, ys)) in series.iter().enumerate() {
        let mark = marks[si % marks.len()];
        for (i, &y) in ys.iter().enumerate() {
            if !y.is_finite() {
                continue;
            }
            let c = i * (cols - 1) / (max_len - 1).max(1);
            let r = ((ymax - y) / span * (rows - 1) as f32).round() as usize;
            let r = r.min(rows - 1);
            grid[r][c] = mark;
        }
    }

    let mut out = String::new();
    for (r, row) in grid.iter().enumerate() {
        let label = if r == 0 {
            format!("{ymax:8.3} ")
        } else if r == rows - 1 {
            format!("{ymin:8.3} ")
        } else {
            " ".repeat(9)
        };
        out.push_str(&label);
        out.push('|');
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str(&" ".repeat(9));
    out.push('+');
    out.push_str(&"-".repeat(cols));
    out.push('\n');
    out.push_str(&" ".repeat(10));
    out.push_str(&format!("steps 0..{max_len}\n"));
    for (si, (label, _)) in series.iter().enumerate() {
        out.push_str(&format!("  {} {label}\n", marks[si % marks.len()]));
    }
    out
}

/// Scatter plot with labelled points (Figure 3 Pareto style).
/// points: (label, x, y). Axes annotated with min/max.
pub fn scatter_plot(points: &[(String, f64, f64)], rows: usize, cols: usize) -> String {
    if points.is_empty() {
        return "(no data)\n".into();
    }
    let xs: Vec<f64> = points.iter().map(|p| p.1).collect();
    let ys: Vec<f64> = points.iter().map(|p| p.2).collect();
    let (xmin, xmax) = (xs.iter().cloned().fold(f64::INFINITY, f64::min), xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max));
    let (ymin, ymax) = (ys.iter().cloned().fold(f64::INFINITY, f64::min), ys.iter().cloned().fold(f64::NEG_INFINITY, f64::max));
    let xspan = (xmax - xmin).max(1e-9);
    let yspan = (ymax - ymin).max(1e-9);

    let mut grid = vec![vec![' '; cols]; rows];
    let marks = ['A', 'B', 'C', 'D', 'E', 'F', 'G', 'H'];
    for (i, (_, x, y)) in points.iter().enumerate() {
        let c = (((x - xmin) / xspan) * (cols - 1) as f64).round() as usize;
        let r = (((ymax - y) / yspan) * (rows - 1) as f64).round() as usize;
        grid[r.min(rows - 1)][c.min(cols - 1)] = marks[i % marks.len()];
    }
    let mut out = String::new();
    for (r, row) in grid.iter().enumerate() {
        let label = if r == 0 {
            format!("{ymax:9.2} ")
        } else if r == rows - 1 {
            format!("{ymin:9.2} ")
        } else {
            " ".repeat(10)
        };
        out.push_str(&label);
        out.push('|');
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str(&" ".repeat(10));
    out.push('+');
    out.push_str(&"-".repeat(cols));
    out.push('\n');
    out.push_str(&format!("{}x: {xmin:.2} .. {xmax:.2}\n", " ".repeat(10)));
    for (i, (label, x, y)) in points.iter().enumerate() {
        out.push_str(&format!("  {} {label} ({x:.2}, {y:.2})\n", marks[i % marks.len()]));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn line_plot_renders_all_series() {
        let s = vec![
            ("dense".to_string(), vec![4.0, 3.0, 2.0, 1.0]),
            ("sct_r8".to_string(), vec![5.0, 4.5, 4.2, 4.2]),
        ];
        let p = line_plot(&s, 10, 40);
        assert!(p.contains('*') && p.contains('+'));
        assert!(p.contains("dense") && p.contains("sct_r8"));
        assert!(p.contains("5.000")); // ymax label
    }

    #[test]
    fn scatter_labels_points() {
        let pts = vec![
            ("r32".to_string(), 46.9, 86.9),
            ("r128".to_string(), 11.7, 65.6),
        ];
        let p = scatter_plot(&pts, 8, 30);
        assert!(p.contains('A') && p.contains('B'));
        assert!(p.contains("r128"));
    }

    #[test]
    fn degenerate_inputs_do_not_panic() {
        assert!(line_plot(&[], 5, 10).contains("no data"));
        assert!(scatter_plot(&[], 5, 10).contains("no data"));
        let one = vec![("x".to_string(), vec![1.0])];
        let _ = line_plot(&one, 5, 10);
        let flat = vec![("f".to_string(), vec![2.0, 2.0, 2.0])];
        let _ = line_plot(&flat, 5, 10);
    }
}
