//! CSV/JSON export of run metrics — the experiment drivers write these next
//! to EXPERIMENTS.md so every table row is regenerable and diffable.

use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

use super::tracker::Tracker;
use crate::json_obj;
use crate::util::json::Json;

/// Write the raw + smoothed loss series as CSV (step,loss,smoothed,step_s).
pub fn write_loss_csv(tracker: &Tracker, path: &Path) -> Result<()> {
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    writeln!(f, "step,loss,smoothed,step_seconds")?;
    let smoothed = tracker.smoothed_series();
    for i in 0..tracker.losses.len() {
        writeln!(
            f,
            "{},{},{},{}",
            i, tracker.losses[i], smoothed[i], tracker.step_seconds[i]
        )?;
    }
    Ok(())
}

/// One summary row (Table 3 style) as a JSON object.
pub fn summary_json(
    label: &str,
    params: usize,
    compression: f64,
    tracker: &Tracker,
    state_bytes: usize,
) -> Json {
    json_obj![
        ("label", label),
        ("params", params),
        ("mlp_compression", compression),
        ("steps", tracker.steps()),
        ("loss_smoothed", tracker.smoothed_loss() as f64),
        ("ppl", tracker.ppl() as f64),
        ("state_bytes", state_bytes),
        ("mean_step_seconds", tracker.mean_step_s()),
    ]
}

/// Append rows to a JSON-lines file (one run summary per line).
pub fn append_jsonl(path: &Path, row: &Json) -> Result<()> {
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    writeln!(f, "{}", row.to_string())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join("sct_test_csv");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("loss.csv");
        let mut t = Tracker::new(2);
        t.record(3.0, 0.1);
        t.record(1.0, 0.2);
        write_loss_csv(&t, &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].starts_with("step,"));
        assert!(lines[2].starts_with("1,1,2")); // smoothed mean(3,1)=2
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn summary_and_jsonl() {
        let mut t = Tracker::new(50);
        t.record(2.0, 0.5);
        let row = summary_json("sct_r8", 1000, 12.5, &t, 4096);
        let s = row.to_string();
        assert!(s.contains("\"sct_r8\"") && s.contains("12.5"));
        let dir = std::env::temp_dir().join("sct_test_jsonl");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("runs.jsonl");
        std::fs::remove_file(&path).ok();
        append_jsonl(&path, &row).unwrap();
        append_jsonl(&path, &row).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2);
        for line in text.lines() {
            Json::parse(line).unwrap();
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
