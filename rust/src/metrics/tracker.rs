//! Loss/PPL tracking with the paper's smoothing.
//!
//! Table 3's loss and PPL columns are "smoothed (window=50)"; Figure 2 plots
//! the smoothed loss curves. This tracker records the raw series and exposes
//! windowed means, PPL (= exp(smoothed loss)), throughput, and step-time
//! stats.

use std::time::Instant;

/// Rolling training metrics for one run.
#[derive(Debug)]
pub struct Tracker {
    pub losses: Vec<f32>,
    pub step_seconds: Vec<f64>,
    pub window: usize,
    started: Instant,
}

impl Tracker {
    /// `window == 0` is clamped to 1 (a zero window would divide by zero in
    /// the smoothing; callers passing a config value stay safe).
    pub fn new(window: usize) -> Tracker {
        Tracker {
            losses: Vec::new(),
            step_seconds: Vec::new(),
            window: window.max(1),
            started: Instant::now(),
        }
    }

    /// Paper configuration: window = 50.
    pub fn paper() -> Tracker {
        Tracker::new(50)
    }

    pub fn record(&mut self, loss: f32, step_time_s: f64) {
        self.losses.push(loss);
        self.step_seconds.push(step_time_s);
    }

    pub fn record_losses(&mut self, losses: &[f32], total_time_s: f64) {
        let per = total_time_s / losses.len().max(1) as f64;
        for &l in losses {
            self.record(l, per);
        }
    }

    pub fn steps(&self) -> usize {
        self.losses.len()
    }

    /// Mean of the trailing `window` losses (or all, early on).
    pub fn smoothed_loss(&self) -> f32 {
        smooth_tail(&self.losses, self.window)
    }

    /// exp(smoothed loss) — the paper's PPL column.
    pub fn ppl(&self) -> f32 {
        self.smoothed_loss().exp()
    }

    /// Full smoothed series (trailing-window mean at every step) — the
    /// Figure 2 curves.
    pub fn smoothed_series(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.losses.len());
        let mut acc = 0.0f64;
        for i in 0..self.losses.len() {
            acc += self.losses[i] as f64;
            if i >= self.window {
                acc -= self.losses[i - self.window] as f64;
            }
            let n = (i + 1).min(self.window);
            out.push((acc / n as f64) as f32);
        }
        out
    }

    /// Mean step time over the run (paper Table 3 "Step Time").
    pub fn mean_step_s(&self) -> f64 {
        if self.step_seconds.is_empty() {
            return 0.0;
        }
        self.step_seconds.iter().sum::<f64>() / self.step_seconds.len() as f64
    }

    pub fn wallclock_s(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// (min, final smoothed) losses — convergence-floor reporting (§4.3).
    /// Empty series: `(NaN, NaN)` rather than `(inf, NaN)` so exporters can
    /// treat "no data" uniformly.
    pub fn loss_floor(&self) -> (f32, f32) {
        if self.losses.is_empty() {
            return (f32::NAN, f32::NAN);
        }
        let min = self.losses.iter().cloned().fold(f32::INFINITY, f32::min);
        (min, self.smoothed_loss())
    }
}

/// Mean of the trailing `window` values; empty input gives NaN, a series
/// shorter than the window averages what exists (the paper's warmup rule).
fn smooth_tail(xs: &[f32], window: usize) -> f32 {
    if xs.is_empty() {
        return f32::NAN;
    }
    let n = xs.len().min(window.max(1));
    xs[xs.len() - n..].iter().sum::<f32>() / n as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoothing_matches_manual_mean() {
        let mut t = Tracker::new(3);
        for l in [4.0, 3.0, 2.0, 1.0] {
            t.record(l, 0.1);
        }
        assert!((t.smoothed_loss() - 2.0).abs() < 1e-6); // mean(3,2,1)
        assert!((t.ppl() - 2.0f32.exp()).abs() < 1e-4);
    }

    #[test]
    fn smoothed_series_length_and_warmup() {
        let mut t = Tracker::new(4);
        for i in 0..10 {
            t.record(i as f32, 0.0);
        }
        let s = t.smoothed_series();
        assert_eq!(s.len(), 10);
        assert_eq!(s[0], 0.0); // first value = itself
        assert!((s[1] - 0.5).abs() < 1e-6); // mean(0,1)
        assert!((s[9] - 7.5).abs() < 1e-6); // mean(6,7,8,9)
    }

    #[test]
    fn step_time_mean() {
        let mut t = Tracker::new(2);
        t.record(1.0, 0.5);
        t.record(1.0, 1.5);
        assert!((t.mean_step_s() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn record_losses_spreads_time() {
        let mut t = Tracker::new(50);
        t.record_losses(&[1.0, 2.0, 3.0, 4.0], 2.0);
        assert_eq!(t.steps(), 4);
        assert!((t.mean_step_s() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn empty_tracker_is_sane() {
        let t = Tracker::paper();
        assert!(t.smoothed_loss().is_nan());
        assert!(t.ppl().is_nan());
        assert_eq!(t.mean_step_s(), 0.0);
        assert!(t.smoothed_series().is_empty());
        let (min, fin) = t.loss_floor();
        assert!(min.is_nan() && fin.is_nan());
    }

    #[test]
    fn window_one_is_the_raw_series() {
        let mut t = Tracker::new(1);
        for l in [3.0, 1.0, 4.0] {
            t.record(l, 0.0);
        }
        assert_eq!(t.smoothed_loss(), 4.0);
        assert_eq!(t.smoothed_series(), vec![3.0, 1.0, 4.0]);
        assert!((t.ppl() - 4.0f32.exp()).abs() < 1e-3);
    }

    #[test]
    fn window_zero_is_clamped_to_one() {
        let mut t = Tracker::new(0);
        t.record(2.0, 0.0);
        t.record(6.0, 0.0);
        assert_eq!(t.window, 1);
        assert_eq!(t.smoothed_loss(), 6.0);
        assert_eq!(t.smoothed_series(), vec![2.0, 6.0]);
    }

    #[test]
    fn paper_window_on_shorter_series_averages_what_exists() {
        // window=50 with only 4 points: mean of all 4, not a panic or NaN.
        let mut t = Tracker::new(50);
        for l in [2.0, 4.0, 6.0, 8.0] {
            t.record(l, 0.1);
        }
        assert!((t.smoothed_loss() - 5.0).abs() < 1e-6);
        assert!((t.ppl() - 5.0f32.exp()).abs() < 1e-2);
        let (min, fin) = t.loss_floor();
        assert_eq!(min, 2.0);
        assert!((fin - 5.0).abs() < 1e-6);
    }
}
