//! Metrics: loss/PPL tracking with the paper's window-50 smoothing
//! ([`tracker`]), ASCII figure renderers ([`plot`]) and CSV/JSONL export
//! ([`export`]).

pub mod export;
pub mod plot;
pub mod tracker;

pub use tracker::Tracker;
