//! In-tree property-testing mini-framework (proptest is not vendored in
//! this offline image).
//!
//! Deterministic, seed-driven case generation with failure shrinking by
//! re-running on "smaller" seeds of the same case shape. Usage:
//!
//! ```ignore
//! use sct::testkit::Prop;
//! Prop::new("qr is orthonormal").cases(200).run(|g| {
//!     let m = g.usize(2, 64);
//!     let k = g.usize(1, m.min(16));
//!     let a = g.matrix(m, k, 1.0);
//!     let q = qr_retract(&a);
//!     g.check(q.ortho_error() < 2e-6, "ortho error");
//! });
//! ```

use crate::spectral::Matrix;
use crate::util::rng::Rng;

/// Case-level generator + assertion collector.
pub struct Gen {
    rng: Rng,
    pub seed: u64,
    failure: Option<String>,
    log: Vec<String>,
}

impl Gen {
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        let v = self.rng.range(lo, hi);
        self.log.push(format!("usize({lo},{hi})={v}"));
        v
    }

    pub fn f32(&mut self, lo: f32, hi: f32) -> f32 {
        let v = lo + (hi - lo) * self.rng.f32();
        self.log.push(format!("f32({lo},{hi})={v}"));
        v
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Gaussian vector.
    pub fn vec_f32(&mut self, n: usize, sigma: f32) -> Vec<f32> {
        (0..n).map(|_| self.rng.normal() as f32 * sigma).collect()
    }

    pub fn matrix(&mut self, rows: usize, cols: usize, sigma: f32) -> Matrix {
        Matrix::randn(&mut self.rng, rows, cols, sigma)
    }

    /// Record a failed expectation (keeps the first).
    pub fn check(&mut self, cond: bool, what: &str) {
        if !cond && self.failure.is_none() {
            self.failure = Some(what.to_string());
        }
    }

    pub fn check_close(&mut self, a: f64, b: f64, tol: f64, what: &str) {
        let ok = (a - b).abs() <= tol * b.abs().max(1.0);
        if !ok && self.failure.is_none() {
            self.failure = Some(format!("{what}: {a} !~ {b} (tol {tol})"));
        }
    }
}

/// A named property run over N seeded cases.
pub struct Prop {
    name: &'static str,
    n_cases: usize,
    base_seed: u64,
}

impl Prop {
    pub fn new(name: &'static str) -> Prop {
        // Seed derives from the property name so different properties explore
        // different streams but every run is reproducible.
        let mut h = 0xcbf29ce484222325u64;
        for b in name.bytes() {
            h = (h ^ b as u64).wrapping_mul(0x100000001b3);
        }
        Prop { name, n_cases: 100, base_seed: h }
    }

    pub fn cases(mut self, n: usize) -> Prop {
        self.n_cases = n;
        self
    }

    pub fn seed(mut self, s: u64) -> Prop {
        self.base_seed = s;
        self
    }

    /// Run the property; panics with the failing seed + generation log.
    pub fn run<F: FnMut(&mut Gen)>(&self, mut f: F) {
        for case in 0..self.n_cases {
            let seed = self.base_seed.wrapping_add(case as u64);
            let mut g = Gen { rng: Rng::new(seed), seed, failure: None, log: Vec::new() };
            f(&mut g);
            if let Some(failure) = g.failure {
                panic!(
                    "property {:?} failed on case {case} (seed {seed:#x}): {failure}\n  gen log: {}",
                    self.name,
                    g.log.join(", ")
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        Prop::new("trivially true").cases(25).run(|g| {
            let a = g.usize(1, 10);
            g.check(a >= 1 && a <= 10, "in range");
            count += 1;
        });
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "property \"always fails\"")]
    fn failing_property_panics_with_seed() {
        Prop::new("always fails").cases(5).run(|g| {
            g.check(false, "nope");
        });
    }

    #[test]
    fn deterministic_streams() {
        let mut first: Vec<usize> = Vec::new();
        Prop::new("stream").cases(10).run(|g| first.push(g.usize(0, 1000)));
        let mut second: Vec<usize> = Vec::new();
        Prop::new("stream").cases(10).run(|g| second.push(g.usize(0, 1000)));
        assert_eq!(first, second);
    }

    #[test]
    fn check_close_tolerances() {
        Prop::new("close").cases(1).run(|g| {
            g.check_close(1.0001, 1.0, 1e-3, "near");
        });
    }
}
