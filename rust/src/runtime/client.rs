//! PJRT client management.
//!
//! The `xla` crate's `PjRtClient` is reference-counted with `Rc`, i.e. not
//! `Send`/`Sync`, so the client is cached **per thread**. The coordinator
//! keeps all XLA execution on one dedicated device thread (data generation /
//! metrics run elsewhere); tests and examples just use whatever thread they
//! run on. Creating a second client on another thread is legal for the CPU
//! plugin — it only costs its thread-pool setup.

use std::cell::OnceCell;
use std::path::Path;

use anyhow::{Context, Result};

thread_local! {
    static CLIENT: OnceCell<xla::PjRtClient> = const { OnceCell::new() };
}

/// The per-thread CPU PJRT client (created on first use).
pub fn cpu() -> Result<xla::PjRtClient> {
    CLIENT.with(|cell| {
        if cell.get().is_none() {
            let c = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            let _ = cell.set(c);
        }
        Ok(cell.get().unwrap().clone())
    })
}

/// Load an HLO-text artifact and compile it for this thread's client.
///
/// HLO *text* is the interchange format: jax >= 0.5 emits HloModuleProtos
/// with 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
/// parser reassigns ids (see DESIGN.md §6 and /opt/xla-example/README.md).
pub fn compile_hlo_file(path: &Path) -> Result<xla::PjRtLoadedExecutable> {
    let client = cpu()?;
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str().context("non-UTF8 artifact path")?,
    )
    .with_context(|| format!("parsing HLO text {}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .with_context(|| format!("compiling {}", path.display()))
}
