//! The training session: device execution of the AOT step functions with
//! state threading.
//!
//! ## Execution model
//!
//! The artifacts are lowered with `return_tuple=True` and the PJRT shim in
//! this image does **not** untuple results (`ExecuteOptions` default), so a
//! step returns one tuple literal. The session therefore keeps the training
//! state (parameters + AdamW moments, `n_state` tensors) as host literals,
//! passes them positionally, and splits the output tuple after each call.
//!
//! The host round-trip costs two state copies per dispatch. Two mitigations,
//! both measured in EXPERIMENTS.md §Perf:
//! * [`Session::train_chunk`] executes the `train_chunk` artifact — a
//!   `lax.scan` over K training steps fused into one HLO — amortizing the
//!   round-trip and dispatch overhead by K (the default driver path).
//! * Only the loss scalar is *parsed* per step; state tensors are moved,
//!   never decoded.

use std::collections::BTreeMap;
use std::path::Path;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::artifact::{ArtifactSpec, Manifest, PresetManifest};
use super::client;
use super::tensor;

/// A compiled, stateful training session for one preset.
pub struct Session {
    pub preset: PresetManifest,
    exes: BTreeMap<String, xla::PjRtLoadedExecutable>,
    /// Training state: params then optimizer tensors, in manifest order.
    state: Vec<xla::Literal>,
    /// Wall-clock compile seconds per artifact (perf accounting).
    pub compile_times: BTreeMap<String, f64>,
    pub steps_done: u64,
}

impl Session {
    /// Load the manifest at `root` and prepare a session for `preset`.
    /// Artifacts compile lazily on first use.
    pub fn open(root: impl AsRef<Path>, preset: &str) -> Result<Session> {
        let manifest = Manifest::load(root)?;
        let preset = manifest.preset(preset)?.clone();
        Ok(Session {
            preset,
            exes: BTreeMap::new(),
            state: Vec::new(),
            compile_times: BTreeMap::new(),
            steps_done: 0,
        })
    }

    /// Compile `name` if not yet compiled.
    fn prepare(&mut self, name: &str) -> Result<()> {
        if !self.exes.contains_key(name) {
            let spec = self.preset.artifact(name)?;
            let t0 = Instant::now();
            let exe = client::compile_hlo_file(&spec.file)?;
            self.compile_times.insert(name.to_string(), t0.elapsed().as_secs_f64());
            self.exes.insert(name.to_string(), exe);
        }
        Ok(())
    }

    /// Immutable access to a prepared artifact.
    fn get(&self, name: &str) -> Result<(&xla::PjRtLoadedExecutable, &ArtifactSpec)> {
        let exe = self.exes.get(name).with_context(|| format!("{name} not prepared"))?;
        Ok((exe, self.preset.artifact(name)?))
    }

    /// Pre-compile a set of artifacts (so timing loops exclude compilation).
    pub fn warmup(&mut self, names: &[&str]) -> Result<()> {
        for n in names {
            if self.preset.artifacts.contains_key(*n) {
                self.prepare(n)?;
            }
        }
        Ok(())
    }

    pub fn has_artifact(&self, name: &str) -> bool {
        self.preset.artifacts.contains_key(name)
    }

    fn run(
        exe: &xla::PjRtLoadedExecutable,
        spec: &ArtifactSpec,
        inputs: &[&xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        if inputs.len() != spec.inputs.len() {
            bail!(
                "artifact {:?}: got {} inputs, expected {}",
                spec.file,
                inputs.len(),
                spec.inputs.len()
            );
        }
        let result = exe.execute::<&xla::Literal>(inputs)?;
        let root = result[0][0].to_literal_sync()?;
        let outs = root.to_tuple()?;
        if outs.len() != spec.outputs.len() {
            bail!(
                "artifact {:?}: got {} outputs, expected {}",
                spec.file,
                outs.len(),
                spec.outputs.len()
            );
        }
        Ok(outs)
    }

    // ---------------------------------------------------------------------
    // state lifecycle
    // ---------------------------------------------------------------------

    /// Run the `init` artifact: fresh params + optimizer state from a seed.
    pub fn init(&mut self, seed: i32) -> Result<()> {
        self.prepare("init")?;
        let (exe, spec) = self.get("init")?;
        let seed_lit = tensor::scalar_i32(seed);
        let outs = Self::run(exe, spec, &[&seed_lit])?;
        if outs.len() != self.preset.n_state {
            bail!("init returned {} tensors, n_state={}", outs.len(), self.preset.n_state);
        }
        self.state = outs;
        self.steps_done = 0;
        Ok(())
    }

    /// Replace the state wholesale (checkpoint restore).
    pub fn set_state(&mut self, state: Vec<xla::Literal>) -> Result<()> {
        if state.len() != self.preset.n_state {
            bail!("state has {} tensors, expected {}", state.len(), self.preset.n_state);
        }
        self.state = state;
        Ok(())
    }

    pub fn state(&self) -> &[xla::Literal] {
        &self.state
    }

    /// Overwrite one state tensor by manifest name (e.g.
    /// `params/layers/0/mlp/gate/u`) — used by the dense->spectral
    /// conversion in the fine-tune driver.
    pub fn set_tensor(&mut self, name: &str, shape: &[usize], data: &[f32]) -> Result<()> {
        let idx = self.preset.state_index(name)?;
        let spec = &self.preset.state[idx];
        if spec.shape != shape {
            bail!("{name:?}: shape {shape:?} != manifest {:?}", spec.shape);
        }
        self.state[idx] = tensor::literal_f32(shape, data)?;
        Ok(())
    }

    /// Read one state tensor back as f32 values (returns shape + data).
    pub fn tensor_f32(&self, name: &str) -> Result<(Vec<usize>, Vec<f32>)> {
        let idx = self.preset.state_index(name)?;
        let spec = &self.preset.state[idx];
        Ok((spec.shape.clone(), tensor::to_f32_vec(&self.state[idx])?))
    }

    /// Names + specs of all state tensors, in order.
    pub fn state_specs(&self) -> &[super::TensorSpec] {
        &self.preset.state
    }

    fn check_ready(&self) -> Result<()> {
        if self.state.is_empty() {
            bail!("session has no state; call init() or restore a checkpoint first");
        }
        Ok(())
    }

    // ---------------------------------------------------------------------
    // steps
    // ---------------------------------------------------------------------

    /// One training step (paper Alg. 1 as one XLA call). Returns the loss.
    pub fn train_step(&mut self, tokens: &[i32], lr_dense: f32, lr_spectral: f32) -> Result<f32> {
        self.check_ready()?;
        self.prepare("train_step")?;
        let (exe, spec) = self.get("train_step")?;
        let idx = spec.input_index("tokens")?;
        let tok = tensor::literal_i32(&spec.inputs[idx].shape, tokens)?;
        let ld = tensor::scalar_f32(lr_dense);
        let ls = tensor::scalar_f32(lr_spectral);

        let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(self.preset.n_state + 3);
        inputs.extend(self.state.iter());
        inputs.push(&tok);
        inputs.push(&ld);
        inputs.push(&ls);
        let mut outs = Self::run(exe, spec, &inputs)?;

        let loss = outs.pop().context("train_step returned no loss")?.to_vec::<f32>()?[0];
        self.state = outs;
        self.steps_done += 1;
        Ok(loss)
    }

    /// K fused training steps (`train_chunk` artifact: lax.scan over the
    /// leading axis of `tokens` inside one HLO). Returns the K losses.
    /// `tokens` is K * batch * (seq+1) i32 values.
    pub fn train_chunk(
        &mut self,
        tokens: &[i32],
        lr_dense: f32,
        lr_spectral: f32,
    ) -> Result<Vec<f32>> {
        self.check_ready()?;
        self.prepare("train_chunk")?;
        let (exe, spec) = self.get("train_chunk")?;
        let idx = spec.input_index("tokens")?;
        let tok_spec = &spec.inputs[idx];
        let k = tok_spec.shape[0];
        let tok = tensor::literal_i32(&tok_spec.shape, tokens)?;
        let ld = tensor::scalar_f32(lr_dense);
        let ls = tensor::scalar_f32(lr_spectral);

        let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(self.preset.n_state + 3);
        inputs.extend(self.state.iter());
        inputs.push(&tok);
        inputs.push(&ld);
        inputs.push(&ls);
        let mut outs = Self::run(exe, spec, &inputs)?;

        let losses = outs.pop().context("train_chunk returned no losses")?.to_vec::<f32>()?;
        self.state = outs;
        self.steps_done += k as u64;
        Ok(losses)
    }

    /// Chunk length K of the exported `train_chunk` artifact (if present).
    pub fn chunk_len(&self) -> Option<usize> {
        let spec = self.preset.artifacts.get("train_chunk")?;
        let idx = spec.input_index("tokens").ok()?;
        Some(spec.inputs[idx].shape[0])
    }

    /// Evaluation loss on one batch (no state update).
    pub fn eval_step(&mut self, tokens: &[i32]) -> Result<f32> {
        self.check_ready()?;
        self.prepare("eval_step")?;
        let (exe, spec) = self.get("eval_step")?;
        let idx = spec.input_index("tokens")?;
        let tok = tensor::literal_i32(&spec.inputs[idx].shape, tokens)?;
        let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(self.preset.n_params + 1);
        inputs.extend(self.state.iter().take(self.preset.n_params));
        inputs.push(&tok);
        let outs = Self::run(exe, spec, &inputs)?;
        Ok(outs[0].to_vec::<f32>()?[0])
    }

    /// Forward-pass logits for a batch: returns (shape, data).
    pub fn forward(&mut self, tokens: &[i32]) -> Result<(Vec<usize>, Vec<f32>)> {
        self.check_ready()?;
        self.prepare("forward")?;
        let (exe, spec) = self.get("forward")?;
        let idx = spec.input_index("tokens")?;
        let tok = tensor::literal_i32(&spec.inputs[idx].shape, tokens)?;
        let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(self.preset.n_params + 1);
        inputs.extend(self.state.iter().take(self.preset.n_params));
        inputs.push(&tok);
        let outs = Self::run(exe, spec, &inputs)?;
        let shape = spec.outputs[0].shape.clone();
        Ok((shape, outs[0].to_vec::<f32>()?))
    }

    /// Re-retract every spectral factor (standalone `retract` artifact; used
    /// for the retraction-cadence ablation and after checkpoint restores).
    pub fn retract(&mut self) -> Result<()> {
        self.check_ready()?;
        self.prepare("retract")?;
        let (exe, spec) = self.get("retract")?;
        let inputs: Vec<&xla::Literal> = self.state.iter().take(self.preset.n_params).collect();
        let outs = Self::run(exe, spec, &inputs)?;
        for (i, lit) in outs.into_iter().enumerate() {
            self.state[i] = lit;
        }
        Ok(())
    }

    /// Max ||Q^T Q - I||_inf over all spectral factors (paper Table 2 row
    /// "Ortho. Error"; must stay < 2e-6 throughout training).
    pub fn ortho_check(&mut self) -> Result<f32> {
        self.check_ready()?;
        self.prepare("ortho_check")?;
        let (exe, spec) = self.get("ortho_check")?;
        let inputs: Vec<&xla::Literal> = self.state.iter().take(self.preset.n_params).collect();
        let outs = Self::run(exe, spec, &inputs)?;
        Ok(outs[0].to_vec::<f32>()?[0])
    }
}
