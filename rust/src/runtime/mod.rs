//! L3 runtime — PJRT execution of the AOT artifacts.
//!
//! `python/compile/aot.py` lowers every step function (init, train_step,
//! eval_step, forward, retract, ortho_check) to HLO **text** once at build
//! time; this module loads those files, compiles them on the PJRT CPU
//! client, and executes them with device-resident state. Python never runs
//! on the training path.
//!
//! Key pieces:
//! * [`client`] — process-wide `PjRtClient` (CPU), plus compile helpers.
//! * [`artifact`] — `artifacts/manifest.json` parsing: per-preset model
//!   config and the positional tensor-spec contract for every artifact.
//! * [`tensor`] — dtype plumbing between manifest specs, host `Vec`s and
//!   `xla::Literal`s.
//! * [`session`] — the training session: owns compiled executables and the
//!   state buffers (params + optimizer moments), feeds step outputs back as
//!   next-step inputs, syncing only the loss scalar to the host.
//!
//! Execution ([`client`], [`session`]) requires the `pjrt` feature — the
//! offline default build keeps only the manifest/dtype layer, which the
//! checkpoint format and the pure-Rust `serve` engine use.

pub mod artifact;
#[cfg(feature = "pjrt")]
pub mod client;
#[cfg(feature = "pjrt")]
pub mod session;
pub mod tensor;

pub use artifact::{ArtifactSpec, Manifest, ModelSpec, PresetManifest, TensorSpec};
#[cfg(feature = "pjrt")]
pub use session::Session;
pub use tensor::DType;
