//! `artifacts/manifest.json` — the positional contract between the Python
//! AOT exporter and the rust runtime.
//!
//! For every preset the manifest records the model config and, for each
//! artifact, the ordered flat list of input and output tensor specs (name,
//! dtype, shape) in jax tree-flatten order — exactly the order of XLA
//! parameters and output-tuple elements. For `train_step` the first
//! `n_state` inputs and outputs are the same tensors in the same order, so
//! the session can recycle output buffers as next-step inputs.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::tensor::DType;
use crate::util::json::Json;

/// One tensor on an artifact boundary.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn bytes(&self) -> usize {
        self.elements() * self.dtype.size_bytes()
    }

    fn parse(j: &Json) -> Result<TensorSpec> {
        Ok(TensorSpec {
            name: j.req("name")?.as_str()?.to_string(),
            dtype: DType::parse(j.req("dtype")?.as_str()?)?,
            shape: j
                .req("shape")?
                .as_arr()?
                .iter()
                .map(|d| d.as_usize())
                .collect::<Result<_>>()?,
        })
    }
}

/// One lowered HLO module + its I/O contract.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

impl ArtifactSpec {
    fn parse(j: &Json, dir: &Path) -> Result<ArtifactSpec> {
        let parse_list = |key: &str| -> Result<Vec<TensorSpec>> {
            j.req(key)?.as_arr()?.iter().map(TensorSpec::parse).collect()
        };
        Ok(ArtifactSpec {
            file: dir.join(j.req("file")?.as_str()?),
            inputs: parse_list("inputs")?,
            outputs: parse_list("outputs")?,
        })
    }

    pub fn input_index(&self, name: &str) -> Result<usize> {
        self.inputs
            .iter()
            .position(|t| t.name == name)
            .with_context(|| format!("no input named {name:?}"))
    }
}

/// Model architecture of a preset (mirrors python `ModelConfig`).
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ffn: usize,
    pub seq_len: usize,
    pub batch: usize,
    /// None => dense MLP baseline.
    pub rank: Option<usize>,
    pub use_pallas: bool,
    pub param_count: usize,
}

/// Everything exported for one preset.
#[derive(Debug, Clone)]
pub struct PresetManifest {
    pub model: ModelSpec,
    /// Number of leading state tensors in train_step I/O (params + opt).
    pub n_state: usize,
    /// Number of parameter tensors (prefix of the state).
    pub n_params: usize,
    /// Canonical state layout: params then optimizer tensors, in flatten
    /// order (what `init` returns and the train_step state prefix means).
    pub state: Vec<TensorSpec>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl PresetManifest {
    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .with_context(|| format!("preset {} has no artifact {name:?}", self.model.name))
    }

    /// Index of a state tensor by manifest name.
    pub fn state_index(&self, name: &str) -> Result<usize> {
        self.state
            .iter()
            .position(|t| t.name == name)
            .with_context(|| format!("no state tensor named {name:?}"))
    }

    /// Tokens-tensor spec of the training step: (batch, seq_len + 1) i32.
    pub fn tokens_spec(&self) -> Result<&TensorSpec> {
        let ts = self.artifact("train_step")?;
        Ok(&ts.inputs[ts.input_index("tokens")?])
    }

    /// Total state bytes (params + optimizer moments) — the SCT-side term of
    /// the paper's memory comparison.
    pub fn state_bytes(&self) -> usize {
        self.state.iter().map(|t| t.bytes()).sum()
    }
}

/// The parsed manifest for an artifact root directory.
#[derive(Debug)]
pub struct Manifest {
    pub root: PathBuf,
    pub presets: BTreeMap<String, PresetManifest>,
}

impl Manifest {
    pub fn load(root: impl AsRef<Path>) -> Result<Manifest> {
        let root = root.as_ref().to_path_buf();
        let path = root.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} — run `make artifacts` first", path.display()))?;
        Self::parse(&text, &root)
    }

    pub fn parse(text: &str, root: &Path) -> Result<Manifest> {
        let j = Json::parse(text).context("parsing manifest.json")?;
        let format = j.req("format")?.as_i64()?;
        if format != 1 {
            bail!("unsupported manifest format {format}");
        }
        let mut presets = BTreeMap::new();
        for (name, pj) in j.req("presets")?.as_obj()? {
            let dir = root.join(name);
            let m = pj.req("model")?;
            let rank = match m.req("rank")? {
                Json::Null => None,
                v => Some(v.as_usize()?),
            };
            let model = ModelSpec {
                name: m.req("name")?.as_str()?.to_string(),
                vocab: m.req("vocab")?.as_usize()?,
                d_model: m.req("d_model")?.as_usize()?,
                n_layers: m.req("n_layers")?.as_usize()?,
                n_heads: m.req("n_heads")?.as_usize()?,
                d_ffn: m.req("d_ffn")?.as_usize()?,
                seq_len: m.req("seq_len")?.as_usize()?,
                batch: m.req("batch")?.as_usize()?,
                rank,
                use_pallas: m.req("use_pallas")?.as_bool()?,
                param_count: pj.req("param_count")?.as_usize()?,
            };
            let mut artifacts = BTreeMap::new();
            for (aname, aj) in pj.req("artifacts")?.as_obj()? {
                artifacts.insert(aname.clone(), ArtifactSpec::parse(aj, &dir)?);
            }
            let state = pj
                .req("state")?
                .as_arr()?
                .iter()
                .map(TensorSpec::parse)
                .collect::<Result<Vec<_>>>()?;
            let n_state = pj.req("n_state")?.as_usize()?;
            if state.len() != n_state {
                bail!("preset {name}: state list has {} entries, n_state={n_state}", state.len());
            }
            presets.insert(
                name.clone(),
                PresetManifest {
                    model,
                    n_state,
                    n_params: pj.req("n_params")?.as_usize()?,
                    state,
                    artifacts,
                },
            );
        }
        Ok(Manifest { root: root.to_path_buf(), presets })
    }

    pub fn preset(&self, name: &str) -> Result<&PresetManifest> {
        self.presets.get(name).with_context(|| {
            format!("no preset {name:?} in manifest; have {:?}", self.presets.keys().collect::<Vec<_>>())
        })
    }

    /// Conventional artifact root: $SCT_ARTIFACTS or ./artifacts.
    pub fn default_root() -> PathBuf {
        std::env::var("SCT_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": 1,
      "presets": {
        "tiny_r8": {
          "model": {"name": "tiny_r8", "vocab": 256, "d_model": 64,
                    "n_layers": 2, "n_heads": 4, "d_ffn": 192, "seq_len": 64,
                    "batch": 4, "rank": 8, "use_pallas": false,
                    "tie_embeddings": true},
          "param_count": 61808,
          "n_state": 2, "n_params": 1,
          "state": [
            {"name": "params/embed", "dtype": "float32", "shape": [256, 64]},
            {"name": "opt/t", "dtype": "int32", "shape": []}
          ],
          "artifacts": {
            "train_step": {
              "file": "train_step.hlo.txt",
              "inputs": [
                {"name": "params/embed", "dtype": "float32", "shape": [256, 64]},
                {"name": "tokens", "dtype": "int32", "shape": [4, 65]}
              ],
              "outputs": [
                {"name": "out/0/embed", "dtype": "float32", "shape": [256, 64]}
              ],
              "bytes": 1
            }
          }
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        let p = m.preset("tiny_r8").unwrap();
        assert_eq!(p.model.rank, Some(8));
        assert_eq!(p.n_state, 2);
        assert_eq!(p.state_index("opt/t").unwrap(), 1);
        assert!(p.state_index("nope").is_err());
        assert_eq!(p.state_bytes(), 256 * 64 * 4 + 4);
        let ts = p.artifact("train_step").unwrap();
        assert_eq!(ts.inputs.len(), 2);
        assert_eq!(ts.inputs[1].dtype, DType::I32);
        assert_eq!(ts.inputs[0].elements(), 256 * 64);
        assert_eq!(ts.file, Path::new("/tmp/a/tiny_r8/train_step.hlo.txt"));
        assert!(p.artifact("nope").is_err());
        assert!(m.preset("nope").is_err());
    }

    #[test]
    fn dense_rank_is_null() {
        let text = SAMPLE.replace("\"rank\": 8", "\"rank\": null");
        let m = Manifest::parse(&text, Path::new("/tmp")).unwrap();
        assert_eq!(m.preset("tiny_r8").unwrap().model.rank, None);
    }

    #[test]
    fn rejects_bad_format() {
        let text = SAMPLE.replace("\"format\": 1", "\"format\": 99");
        assert!(Manifest::parse(&text, Path::new("/tmp")).is_err());
    }
}
