//! Dtype plumbing between manifest specs, host buffers and `xla::Literal`s.
//!
//! [`DType`] and its parsing are always available (the checkpoint format and
//! the `serve` engine depend on them); the literal constructors/readers need
//! the `pjrt` feature.

use anyhow::{bail, Result};

/// The dtypes the SCT artifacts use on the wire.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    U32,
}

impl DType {
    pub fn parse(name: &str) -> Result<DType> {
        Ok(match name {
            "float32" | "f32" => DType::F32,
            "int32" | "i32" => DType::I32,
            "uint32" | "u32" => DType::U32,
            other => bail!("unsupported dtype {other:?} in manifest"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            DType::F32 => "float32",
            DType::I32 => "int32",
            DType::U32 => "uint32",
        }
    }

    #[cfg(feature = "pjrt")]
    pub fn element_type(&self) -> xla::ElementType {
        match self {
            DType::F32 => xla::ElementType::F32,
            DType::I32 => xla::ElementType::S32,
            DType::U32 => xla::ElementType::U32,
        }
    }

    pub fn size_bytes(&self) -> usize {
        4
    }
}

/// Build a literal from raw little-endian bytes + spec.
#[cfg(feature = "pjrt")]
pub fn literal_from_bytes(dtype: DType, shape: &[usize], bytes: &[u8]) -> Result<xla::Literal> {
    let expected = shape.iter().product::<usize>() * dtype.size_bytes();
    if bytes.len() != expected {
        bail!("byte length {} != expected {} for shape {:?}", bytes.len(), expected, shape);
    }
    Ok(xla::Literal::create_from_shape_and_untyped_data(dtype.element_type(), shape, bytes)?)
}

#[cfg(feature = "pjrt")]
pub fn literal_f32(shape: &[usize], data: &[f32]) -> Result<xla::Literal> {
    let bytes: &[u8] =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    literal_from_bytes(DType::F32, shape, bytes)
}

#[cfg(feature = "pjrt")]
pub fn literal_i32(shape: &[usize], data: &[i32]) -> Result<xla::Literal> {
    let bytes: &[u8] =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    literal_from_bytes(DType::I32, shape, bytes)
}

#[cfg(feature = "pjrt")]
pub fn scalar_f32(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

#[cfg(feature = "pjrt")]
pub fn scalar_i32(v: i32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Read a literal back to an f32 vec (checks the element type).
#[cfg(feature = "pjrt")]
pub fn to_f32_vec(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

#[cfg(feature = "pjrt")]
pub fn to_i32_vec(lit: &xla::Literal) -> Result<Vec<i32>> {
    Ok(lit.to_vec::<i32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_parse_roundtrip() {
        for name in ["float32", "int32", "uint32"] {
            assert_eq!(DType::parse(name).unwrap().name(), name);
        }
        assert!(DType::parse("bfloat16").is_err());
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn literal_shape_mismatch_errors() {
        let bytes = vec![0u8; 12];
        assert!(literal_from_bytes(DType::F32, &[2, 2], &bytes).is_err());
        assert!(literal_from_bytes(DType::F32, &[3], &bytes).is_ok());
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn literal_f32_roundtrip() {
        let data = vec![1.0f32, -2.5, 3.25, 0.0, 5.5, -6.0];
        let lit = literal_f32(&[2, 3], &data).unwrap();
        assert_eq!(to_f32_vec(&lit).unwrap(), data);
        assert_eq!(lit.element_count(), 6);
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn literal_i32_roundtrip() {
        let data = vec![1i32, -2, 3, 4];
        let lit = literal_i32(&[4], &data).unwrap();
        assert_eq!(to_i32_vec(&lit).unwrap(), data);
    }
}
