//! Lock-free metric handles and the process-global registry.
//!
//! Three instrument kinds, all backed by relaxed atomics so the record path
//! never locks or allocates:
//!
//! * [`Counter`] — monotonically increasing `u64` (`fetch_add`).
//! * [`Gauge`] — an `f64` stored as bits in an `AtomicU64` (`store`).
//! * [`Histogram`] — a fixed array of [`HIST_BUCKETS`] log2-spaced bucket
//!   counters plus a sample count and a sum held in integer microunits, so
//!   `record` is three `fetch_add`s. Bucket upper bounds are
//!   `HIST_BASE * 2^i`; the last bucket is `+Inf` (overflow). With
//!   `HIST_BASE = 1e-3` a millisecond-valued histogram resolves 1 µs to
//!   ~9 minutes, which covers every latency this runtime produces.
//!
//! Handles are cheap `Arc` clones. Instrumented code registers once through
//! [`registry`] (the only mutex in the subsystem, taken at registration and
//! render time) and caches the handle — typically in a per-subsystem
//! `OnceLock` struct — so steady-state recording is wait-free.
//!
//! The registry renders two formats: [`Registry::render_prometheus`] (the
//! text exposition behind `GET /metrics`, cumulative `_bucket{le=...}` /
//! `_sum` / `_count` for histograms) and [`Registry::render_json`] (a flat
//! object for `sct train --metrics-out` JSONL snapshots).

use crate::util::json::Json;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Bucket count per histogram; index `HIST_BUCKETS - 1` is the `+Inf`
/// overflow bucket.
pub const HIST_BUCKETS: usize = 32;

/// Upper bound of the first histogram bucket; bucket `i` spans
/// `(HIST_BASE * 2^(i-1), HIST_BASE * 2^i]`.
pub const HIST_BASE: f64 = 1e-3;

/// Monotonic event counter. `inc`/`add` are single relaxed `fetch_add`s.
#[derive(Clone)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins instantaneous value (`f64` bits in an `AtomicU64`).
#[derive(Clone)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

struct HistogramInner {
    buckets: [AtomicU64; HIST_BUCKETS],
    count: AtomicU64,
    /// Sum of samples in 1e-6 units — an integer `fetch_add` keeps the
    /// record path wait-free (no CAS loop on f64 bits).
    sum_micros: AtomicU64,
}

/// Fixed log2-bucketed histogram. Recording a sample is three relaxed
/// `fetch_add`s — no allocation, no lock, no resize.
#[derive(Clone)]
pub struct Histogram(Arc<HistogramInner>);

/// Upper bound of bucket `i` (`+Inf` for the last bucket).
pub fn bucket_upper_bound(i: usize) -> f64 {
    if i >= HIST_BUCKETS - 1 {
        f64::INFINITY
    } else {
        HIST_BASE * (1u64 << i) as f64
    }
}

fn bucket_index(v: f64) -> usize {
    if !(v > HIST_BASE) {
        // v <= HIST_BASE, or NaN: both land in the first bucket.
        return 0;
    }
    let idx = (v / HIST_BASE).log2().ceil() as usize;
    idx.min(HIST_BUCKETS - 1)
}

impl Histogram {
    fn new() -> Histogram {
        Histogram(Arc::new(HistogramInner {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_micros: AtomicU64::new(0),
        }))
    }

    pub fn record(&self, v: f64) {
        self.0.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.0.count.fetch_add(1, Ordering::Relaxed);
        self.0.sum_micros.fetch_add((v.max(0.0) * 1e6) as u64, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.0.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> f64 {
        self.0.sum_micros.load(Ordering::Relaxed) as f64 / 1e6
    }

    /// Per-bucket (non-cumulative) counts, index-aligned with
    /// [`bucket_upper_bound`].
    pub fn bucket_counts(&self) -> [u64; HIST_BUCKETS] {
        std::array::from_fn(|i| self.0.buckets[i].load(Ordering::Relaxed))
    }

    /// Estimated `q`-quantile (`0.0..=1.0`) interpolated from the log-spaced
    /// buckets (see [`quantile_from_buckets`]). `0.0` with no samples.
    pub fn quantile(&self, q: f64) -> f64 {
        quantile_from_buckets(&self.bucket_counts(), q)
    }
}

/// Estimate the `q`-quantile of a sample set from its log2-bucketed counts:
/// find the bucket holding the target rank and interpolate linearly inside
/// it. Resolution is therefore the bucket width (a factor of 2); the `+Inf`
/// overflow bucket reports its lower bound. Returns `0.0` for an empty set.
pub fn quantile_from_buckets(counts: &[u64; HIST_BUCKETS], q: f64) -> f64 {
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).clamp(1, total);
    let mut cum = 0u64;
    for (i, &c) in counts.iter().enumerate() {
        if c == 0 {
            continue;
        }
        if cum + c >= target {
            let lo = if i == 0 { 0.0 } else { bucket_upper_bound(i - 1) };
            let hi = bucket_upper_bound(i);
            if hi.is_infinite() {
                return lo;
            }
            let frac = (target - cum) as f64 / c as f64;
            return lo + (hi - lo) * frac;
        }
        cum += c;
    }
    0.0
}

#[derive(Clone)]
enum Instrument {
    Counter(Counter),
    Gauge(Gauge),
    Histogram(Histogram),
}

impl Instrument {
    fn kind(&self) -> &'static str {
        match self {
            Instrument::Counter(_) => "counter",
            Instrument::Gauge(_) => "gauge",
            Instrument::Histogram(_) => "histogram",
        }
    }
}

struct Entry {
    name: String,
    labels: Vec<(String, String)>,
    help: &'static str,
    instrument: Instrument,
}

/// The process-global metric table. The mutex guards registration and
/// rendering only — recording through a handle never touches it.
pub struct Registry {
    entries: Mutex<Vec<Entry>>,
}

/// The global registry (`GET /metrics` and `--metrics-out` both render it).
pub fn registry() -> &'static Registry {
    static REGISTRY: OnceLock<Registry> = OnceLock::new();
    REGISTRY.get_or_init(|| Registry { entries: Mutex::new(Vec::new()) })
}

impl Registry {
    /// Get-or-register: same `(name, labels)` returns a handle to the same
    /// underlying instrument. Panics if the name is already registered with
    /// a different instrument kind (a programming error, not a runtime
    /// condition).
    fn get_or_insert(
        &self,
        name: &str,
        labels: &[(&str, &str)],
        help: &'static str,
        make: impl FnOnce() -> Instrument,
    ) -> Instrument {
        let mut entries = self.entries.lock().unwrap();
        if let Some(e) = entries
            .iter()
            .find(|e| e.name == name && labels_eq(&e.labels, labels))
        {
            return e.instrument.clone();
        }
        let instrument = make();
        if let Some(e) = entries.iter().find(|e| e.name == name) {
            assert_eq!(
                e.instrument.kind(),
                instrument.kind(),
                "metric {name} re-registered with a different kind"
            );
        }
        entries.push(Entry {
            name: name.to_string(),
            labels: labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect(),
            help,
            instrument: instrument.clone(),
        });
        instrument
    }

    pub fn counter(&self, name: &str, help: &'static str) -> Counter {
        self.counter_with(name, &[], help)
    }

    pub fn counter_with(&self, name: &str, labels: &[(&str, &str)], help: &'static str) -> Counter {
        match self.get_or_insert(name, labels, help, || {
            Instrument::Counter(Counter(Arc::new(AtomicU64::new(0))))
        }) {
            Instrument::Counter(c) => c,
            _ => unreachable!("kind checked in get_or_insert"),
        }
    }

    pub fn gauge(&self, name: &str, help: &'static str) -> Gauge {
        self.gauge_with(name, &[], help)
    }

    pub fn gauge_with(&self, name: &str, labels: &[(&str, &str)], help: &'static str) -> Gauge {
        match self.get_or_insert(name, labels, help, || {
            Instrument::Gauge(Gauge(Arc::new(AtomicU64::new(0.0f64.to_bits()))))
        }) {
            Instrument::Gauge(g) => g,
            _ => unreachable!("kind checked in get_or_insert"),
        }
    }

    pub fn histogram(&self, name: &str, help: &'static str) -> Histogram {
        self.histogram_with(name, &[], help)
    }

    pub fn histogram_with(
        &self,
        name: &str,
        labels: &[(&str, &str)],
        help: &'static str,
    ) -> Histogram {
        match self.get_or_insert(name, labels, help, || Instrument::Histogram(Histogram::new())) {
            Instrument::Histogram(h) => h,
            _ => unreachable!("kind checked in get_or_insert"),
        }
    }

    /// Prometheus text exposition (format version 0.0.4). `# HELP` / `# TYPE`
    /// are emitted once per metric name; histogram buckets are cumulative
    /// with an explicit `+Inf` bound, followed by `_sum` and `_count`.
    pub fn render_prometheus(&self) -> String {
        let entries = self.entries.lock().unwrap();
        let mut out = String::new();
        let mut seen_header: Vec<&str> = Vec::new();
        for e in entries.iter() {
            if !seen_header.contains(&e.name.as_str()) {
                seen_header.push(&e.name);
                out.push_str(&format!("# HELP {} {}\n", e.name, e.help));
                out.push_str(&format!("# TYPE {} {}\n", e.name, e.instrument.kind()));
            }
            match &e.instrument {
                Instrument::Counter(c) => {
                    out.push_str(&format!("{}{} {}\n", e.name, fmt_labels(&e.labels, None), c.get()));
                }
                Instrument::Gauge(g) => {
                    out.push_str(&format!("{}{} {}\n", e.name, fmt_labels(&e.labels, None), fmt_f64(g.get())));
                }
                Instrument::Histogram(h) => {
                    let counts = h.bucket_counts();
                    let mut cum = 0u64;
                    for (i, n) in counts.iter().enumerate() {
                        cum += n;
                        let le = if i == HIST_BUCKETS - 1 {
                            "+Inf".to_string()
                        } else {
                            fmt_f64(bucket_upper_bound(i))
                        };
                        out.push_str(&format!(
                            "{}_bucket{} {}\n",
                            e.name,
                            fmt_labels(&e.labels, Some(&le)),
                            cum
                        ));
                    }
                    out.push_str(&format!(
                        "{}_sum{} {}\n",
                        e.name,
                        fmt_labels(&e.labels, None),
                        fmt_f64(h.sum())
                    ));
                    out.push_str(&format!(
                        "{}_count{} {}\n",
                        e.name,
                        fmt_labels(&e.labels, None),
                        h.count()
                    ));
                }
            }
        }
        out
    }

    /// Flat JSON snapshot: counters and gauges as numbers, histograms as
    /// `{count, sum, p50, p95, p99, buckets}` objects. Keys carry labels
    /// inline (`name{k="v"}`, values escaped), matching the exposition
    /// identity — escaping also keeps keys collision-free when a label
    /// value contains the `","` separator or a quote.
    pub fn render_json(&self) -> Json {
        let entries = self.entries.lock().unwrap();
        let mut obj: Vec<(String, Json)> = Vec::with_capacity(entries.len());
        for e in entries.iter() {
            let key = format!("{}{}", e.name, fmt_labels(&e.labels, None));
            let val = match &e.instrument {
                Instrument::Counter(c) => Json::Num(c.get() as f64),
                Instrument::Gauge(g) => Json::Num(g.get()),
                Instrument::Histogram(h) => {
                    let counts = h.bucket_counts();
                    Json::Obj(vec![
                        ("count".to_string(), Json::Num(h.count() as f64)),
                        ("sum".to_string(), Json::Num(h.sum())),
                        ("p50".to_string(), Json::Num(quantile_from_buckets(&counts, 0.50))),
                        ("p95".to_string(), Json::Num(quantile_from_buckets(&counts, 0.95))),
                        ("p99".to_string(), Json::Num(quantile_from_buckets(&counts, 0.99))),
                        (
                            "buckets".to_string(),
                            Json::Arr(counts.iter().map(|&n| Json::Num(n as f64)).collect()),
                        ),
                    ])
                }
            };
            obj.push((key, val));
        }
        Json::Obj(obj)
    }

    /// Merge the bucket counts of every label set registered under `name`
    /// (e.g. one histogram per gateway worker) into one distribution and
    /// return the requested quantiles. `None` when no histogram with that
    /// name exists; all-zero estimates when none has samples.
    pub fn histogram_quantiles(&self, name: &str, qs: &[f64]) -> Option<Vec<f64>> {
        let entries = self.entries.lock().unwrap();
        let mut merged = [0u64; HIST_BUCKETS];
        let mut found = false;
        for e in entries.iter().filter(|e| e.name == name) {
            if let Instrument::Histogram(h) = &e.instrument {
                found = true;
                for (m, c) in merged.iter_mut().zip(h.bucket_counts()) {
                    *m += c;
                }
            }
        }
        if !found {
            return None;
        }
        Some(qs.iter().map(|&q| quantile_from_buckets(&merged, q)).collect())
    }
}

fn labels_eq(have: &[(String, String)], want: &[(&str, &str)]) -> bool {
    have.len() == want.len()
        && have.iter().zip(want).all(|((hk, hv), (wk, wv))| hk == wk && hv == wv)
}

/// Escape a label value per the Prometheus exposition format: backslash,
/// double-quote, and line-feed. Also what keeps the rendered `name{k="v"}`
/// identity collision-free — an unescaped value containing `","` or `"`
/// could otherwise render identically to a different label set.
fn escape_label_value(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

fn fmt_labels(labels: &[(String, String)], le: Option<&str>) -> String {
    if labels.is_empty() && le.is_none() {
        return String::new();
    }
    let mut parts: Vec<String> =
        labels.iter().map(|(k, v)| format!("{k}=\"{}\"", escape_label_value(v))).collect();
    if let Some(le) = le {
        parts.push(format!("le=\"{le}\""));
    }
    format!("{{{}}}", parts.join(","))
}

fn fmt_f64(v: f64) -> String {
    if v.is_infinite() {
        return if v > 0.0 { "+Inf".to_string() } else { "-Inf".to_string() };
    }
    // Shortest clean form: integers without a trailing ".0", floats as-is.
    if v == v.trunc() && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_empty_renders_zeroes() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.sum(), 0.0);
        assert!(h.bucket_counts().iter().all(|&n| n == 0));
    }

    #[test]
    fn histogram_single_sample_lands_in_one_bucket() {
        let h = Histogram::new();
        h.record(5.0); // 5 ms -> bound 8e-3*... in base units: bucket with bound >= 5.0
        assert_eq!(h.count(), 1);
        assert!((h.sum() - 5.0).abs() < 1e-6);
        let counts = h.bucket_counts();
        assert_eq!(counts.iter().sum::<u64>(), 1);
        let idx = counts.iter().position(|&n| n == 1).unwrap();
        assert!(bucket_upper_bound(idx) >= 5.0, "bound {} < sample", bucket_upper_bound(idx));
        assert!(idx == 0 || bucket_upper_bound(idx - 1) < 5.0, "sample fits a tighter bucket");
    }

    #[test]
    fn histogram_overflow_goes_to_inf_bucket() {
        let h = Histogram::new();
        h.record(1e12);
        let counts = h.bucket_counts();
        assert_eq!(counts[HIST_BUCKETS - 1], 1);
        assert_eq!(counts[..HIST_BUCKETS - 1].iter().sum::<u64>(), 0);
        assert!(bucket_upper_bound(HIST_BUCKETS - 1).is_infinite());
    }

    #[test]
    fn histogram_tiny_and_negative_go_to_first_bucket() {
        let h = Histogram::new();
        h.record(0.0);
        h.record(-3.0);
        h.record(HIST_BASE / 2.0);
        assert_eq!(h.bucket_counts()[0], 3);
        // Negative samples clamp to 0 in the sum.
        assert!((h.sum() - HIST_BASE / 2.0).abs() < 1e-6);
    }

    #[test]
    fn registry_get_or_register_returns_same_instrument() {
        let r = registry();
        let a = r.counter("sct_test_dedup_total", "test");
        let b = r.counter("sct_test_dedup_total", "test");
        let before = a.get();
        b.inc();
        assert_eq!(a.get(), before + 1);
    }

    #[test]
    fn labeled_series_are_distinct() {
        let r = registry();
        let a = r.counter_with("sct_test_labeled_total", &[("k", "a")], "test");
        let b = r.counter_with("sct_test_labeled_total", &[("k", "b")], "test");
        let (a0, b0) = (a.get(), b.get());
        a.add(2);
        b.add(5);
        assert_eq!(a.get(), a0 + 2);
        assert_eq!(b.get(), b0 + 5);
        let text = r.render_prometheus();
        assert!(text.contains("sct_test_labeled_total{k=\"a\"}"));
        assert!(text.contains("sct_test_labeled_total{k=\"b\"}"));
    }

    #[test]
    fn prometheus_histogram_buckets_are_cumulative_and_monotone() {
        let r = registry();
        let h = r.histogram("sct_test_expo_ms", "test");
        for v in [0.5, 1.0, 2.0, 4.0, 1e9] {
            h.record(v);
        }
        let text = r.render_prometheus();
        let mut last = 0u64;
        let mut bucket_lines = 0;
        for line in text.lines() {
            if let Some(rest) = line.strip_prefix("sct_test_expo_ms_bucket{") {
                let val: u64 = rest.rsplit(' ').next().unwrap().parse().unwrap();
                assert!(val >= last, "bucket counts must be cumulative: {line}");
                last = val;
                bucket_lines += 1;
            }
        }
        assert_eq!(bucket_lines, HIST_BUCKETS);
        assert!(text.contains("le=\"+Inf\""));
        assert!(text.contains("sct_test_expo_ms_count"));
        assert!(text.contains("sct_test_expo_ms_sum"));
        assert_eq!(last, h.count(), "+Inf bucket must equal total count");
    }

    #[test]
    fn quantiles_interpolate_within_buckets() {
        let h = Histogram::new();
        assert_eq!(h.quantile(0.5), 0.0, "empty histogram reports 0");
        // 100 samples at ~1.5 (bucket (1.024, 2.048]), 10 at ~100 (bucket
        // (65.5, 131.1]): p50 lands in the low bucket, p99 in the high one.
        for _ in 0..100 {
            h.record(1.5);
        }
        for _ in 0..10 {
            h.record(100.0);
        }
        let p50 = h.quantile(0.50);
        assert!((1.024..=2.048).contains(&p50), "p50 {p50} outside its bucket");
        let p99 = h.quantile(0.99);
        assert!((65.0..=132.0).contains(&p99), "p99 {p99} outside its bucket");
        assert!(h.quantile(0.0) > 0.0, "q=0 clamps to the first sample's bucket");
        // Overflow samples report the +Inf bucket's lower bound, not inf.
        let o = Histogram::new();
        o.record(1e12);
        assert!(o.quantile(0.99).is_finite());
    }

    #[test]
    fn label_values_are_escaped_and_collision_free() {
        let r = registry();
        // Raw formatting of these two would render the identical series
        // `...{k="a",b="c"}`; escaping must keep them distinct and the
        // exposition parseable.
        let tricky = r.counter_with("sct_test_escape_total", &[("k", "a\",b=\"c")], "test");
        let plain = r.counter_with("sct_test_escape_total", &[("k", "a"), ("b", "c")], "test");
        tricky.add(1);
        plain.add(2);
        let text = r.render_prometheus();
        assert!(text.contains(r#"sct_test_escape_total{k="a\",b=\"c"}"#), "escaped quote");
        assert!(text.contains(r#"sct_test_escape_total{k="a",b="c"}"#), "plain series intact");
        let esc = r.counter_with("sct_test_escape2_total", &[("k", "a\\b\nc")], "test");
        esc.inc();
        let text = r.render_prometheus();
        assert!(text.contains(r#"{k="a\\b\nc"}"#), "backslash and newline escaped: {text}");
        // The JSON snapshot uses the same identity: both keys present.
        let json = registry().render_json();
        if let Json::Obj(kv) = &json {
            let keys: Vec<&str> =
                kv.iter().map(|(k, _)| k.as_str()).filter(|k| k.contains("escape_total")).collect();
            assert_eq!(keys.len(), 2, "escaped keys must not collide: {keys:?}");
        } else {
            panic!("render_json must be an object");
        }
    }

    #[test]
    fn histogram_quantiles_aggregate_across_label_sets() {
        let r = registry();
        let a = r.histogram_with("sct_test_agg_ms", &[("worker", "0")], "test");
        let b = r.histogram_with("sct_test_agg_ms", &[("worker", "1")], "test");
        for _ in 0..50 {
            a.record(1.0);
            b.record(64.0);
        }
        let qs = r.histogram_quantiles("sct_test_agg_ms", &[0.25, 0.9]).unwrap();
        assert!(qs[0] <= 2.1, "p25 from worker 0's samples, got {}", qs[0]);
        assert!(qs[1] >= 30.0, "p90 from worker 1's samples, got {}", qs[1]);
        assert!(r.histogram_quantiles("sct_test_absent_ms", &[0.5]).is_none());
    }

    #[test]
    fn render_json_surfaces_histogram_quantiles() {
        let r = registry();
        let h = r.histogram("sct_test_json_quant_ms", "test");
        for _ in 0..10 {
            h.record(2.0);
        }
        let json = r.render_json();
        let doc = json.get("sct_test_json_quant_ms").unwrap();
        for key in ["p50", "p95", "p99"] {
            let v = doc.get(key).unwrap().as_f64().unwrap();
            assert!(v > 0.0 && v <= 4.1, "{key} = {v} for 2.0-valued samples");
        }
    }

    #[test]
    fn render_json_is_flat_and_parses_back() {
        let r = registry();
        let c = r.counter("sct_test_json_total", "test");
        c.inc();
        let g = r.gauge("sct_test_json_gauge", "test");
        g.set(1.5);
        let json = r.render_json();
        let text = json.to_string();
        let back = Json::parse(&text).expect("snapshot must round-trip");
        assert!(back.get("sct_test_json_total").unwrap().as_f64().unwrap() >= 1.0);
        assert_eq!(back.get("sct_test_json_gauge").unwrap().as_f64().unwrap(), 1.5);
    }
}
