//! `obs` — the dependency-free observability layer: a process-global metrics
//! registry, Prometheus-style text exposition, per-request span tracing, and
//! a leveled structured logger. Everything here is std-only (the image is
//! offline), and everything on a hot path is lock-free: counters and gauges
//! are relaxed atomics, histograms are fixed log-bucketed atomic arrays, so
//! recording a sample never allocates and never takes a lock.
//!
//! Pieces:
//! * [`metrics`] — [`metrics::Counter`] / [`metrics::Gauge`] /
//!   [`metrics::Histogram`] handles registered in the global
//!   [`metrics::registry`]. Handles are `Clone` + `Send` + `Sync` and cache
//!   the underlying atomics, so instrumented code registers once (a mutex
//!   hit) and records forever after with one `fetch_add`. The registry
//!   renders the Prometheus text format (`GET /metrics` in
//!   `serve::server`) and a flat JSON snapshot (`sct train --metrics-out`
//!   JSONL cadence).
//! * [`trace`] — monotonically increasing request ids and a process-global
//!   span sink. The batcher emits one span record per request
//!   (queue → prefill chunks → decode steps → finish) as a JSON line; the
//!   sink is a file (`traces.jsonl`, `sct serve --trace-out`) or an
//!   in-memory buffer for tests. When no sink is installed, emission is a
//!   single relaxed load — tracing costs nothing unless asked for.
//! * [`log`] — the leveled logger behind the `sct_error!` / `sct_warn!` /
//!   `sct_info!` / `sct_debug!` macros. Level resolves as `--log-level`
//!   flag > `[obs] log_level` TOML > `SCT_LOG` env > `info`. Log lines go
//!   to **stderr** so `--log-level quiet` leaves stdout machine-clean for
//!   scripting (tables, generated text and JSON outputs stay on stdout).
//! * [`prof`] — the performance-attribution profiler: scoped hierarchical
//!   phase/kernel tree with declared FLOP + byte work models, roofline
//!   accounting against a calibrated machine peak, flamegraph `.folded`
//!   and JSON renders. Off by default; a disabled scope is one relaxed
//!   atomic load.
//! * [`health`] — the training watchdog and process health state:
//!   NaN/Inf, loss-spike, gradient-explosion and dead-spectrum checks at
//!   step granularity with `warn`/`skip`/`halt` policies, the
//!   `sct_health_*` anomaly counters, and the last-anomaly record behind
//!   `GET /v1/health`. Disarmed checks are one relaxed atomic load.
//!
//! Instrumented layers (all registered under the `sct_` prefix):
//! serve (`sct_serve_*`: queue depth, active slots, admission wait,
//! TTFT/ITL histograms, request/token counters), the worker pool
//! (`sct_pool_*`: parallel-vs-serial decisions, fan-outs, shard sizes,
//! per-worker busy time), the native trainer (`sct_train_*`: per-phase
//! step-time histograms, grad norm, clip events), and the rank subsystem
//! (`sct_rank_*`: per-layer rank and tail-energy gauges, transition
//! counters, ortho error).
//!
//! # Observability — worked examples
//!
//! **Metrics (scrape).** Every subsystem registers on the process-global
//! [`metrics::registry`]; `sct serve` exposes it at `GET /metrics` in the
//! Prometheus text format:
//!
//! ```text
//! $ curl -s localhost:8077/metrics | grep sct_serve_ttft
//! sct_serve_ttft_ms_bucket{worker="0",le="0.004"} 2
//! sct_serve_ttft_ms_sum{worker="0"} 0.0061
//! sct_serve_ttft_ms_count{worker="0"} 2
//! ```
//!
//! Histogram JSON snapshots (`sct train --metrics-out`, `GET /v1/stats`)
//! additionally carry `p50`/`p95`/`p99` estimates interpolated from the 32
//! log-spaced buckets ([`metrics::Histogram::quantile`]).
//!
//! **Tracing (follow one request).** `sct serve --trace-out traces.jsonl`
//! emits hierarchical spans linked by `span_id`/`parent_id`, all stamped
//! with the `request_id` the client saw on the wire: the gateway placement
//! span is the root (its `span_id` *is* the request id), the worker-side
//! request span points at it, and queue-wait / per-chunk prefill / decode
//! spans point at the request span:
//!
//! ```text
//! $ grep '"request_id":7' traces.jsonl | python3 -c 'import json,sys
//! for l in sys.stdin: s=json.loads(l); print(s["kind"], s["span_id"], s.get("parent_id"))'
//! gateway 7 None
//! queue_wait 31 9
//! prefill_chunk 32 9
//! decode 33 9
//! request 9 7
//! ```
//!
//! **Profiling (read a flamegraph).** `sct train --backend native
//! --profile-out prof.json` writes the phase tree as JSON plus collapsed
//! stacks at `prof.folded` — one `path;to;frame <self-µs>` line each, so
//! `flamegraph.pl prof.folded > prof.svg` (or speedscope) renders it
//! directly. Frame width is self time: a wide `train_step;forward;matmul`
//! box says the forward matmuls dominate the step; the JSON `kernels` rows
//! give the same kernels as achieved GFLOP/s and FLOPs/byte against the
//! calibrated machine peak (how far each kernel sits from roofline). The
//! server surface is `GET /v1/profile` (per-worker attribution under
//! `worker0..N` roots when `sct serve --profile-out` enabled it).
//!
//! **Spectral health (watch the factors themselves).** `sct train
//! --backend native --spectra-out spectra.jsonl --spectra-every 25`
//! samples per-layer diagnostics from the live factors — the full
//! singular spectrum, tail-energy curve, effective rank (spectral
//! entropy), condition number, factor ortho error, and principal-angle
//! drift vs the previous sample — as one JSON line per sample, and
//! publishes the same numbers as `sct_spectral_*{layer=...}` gauges:
//!
//! ```text
//! $ tail -1 spectra.jsonl | python3 -c 'import json,sys
//! r=json.load(sys.stdin); t=r["layers"][0]["triples"][0]
//! print(r["step"], t["name"], t["effective_rank"], t["drift_u"])'
//! 50 gate 7.82 0.031
//! ```
//!
//! `sct doctor ckpt.sct` runs the same diagnostics offline over any
//! checkpoint (per-layer table on stdout, `--json report.json` for the
//! full record) — rank-sweep artifacts become comparable post hoc.
//!
//! **Watchdog (react to anomalies).** `sct train --backend native
//! --watchdog skip` arms the [`health`] checks: NaN/Inf loss or
//! gradients, loss spikes vs a rolling window (`--watchdog-spike-factor`),
//! gradient-norm explosions (`--watchdog-grad-max`), and collapsed
//! spectra. Policy `warn` logs + counts
//! (`sct_health_anomalies_total{kind="nan_loss"|...}`), `skip` also drops
//! the anomalous optimizer update (the factors and Adam moments stay at
//! their pre-step values), `halt` stops the run: non-zero exit, final
//! diagnostic dump, no checkpoint written from the poisoned state. The
//! serve-side readiness report `GET /v1/health` carries the last-anomaly
//! record, worker liveness and KV-slot pressure.

pub mod health;
pub mod log;
pub mod metrics;
pub mod prof;
pub mod trace;

pub use metrics::{registry, Counter, Gauge, Histogram, Registry};
pub use trace::{next_request_id, next_span_id};
