//! `obs` — the dependency-free observability layer: a process-global metrics
//! registry, Prometheus-style text exposition, per-request span tracing, and
//! a leveled structured logger. Everything here is std-only (the image is
//! offline), and everything on a hot path is lock-free: counters and gauges
//! are relaxed atomics, histograms are fixed log-bucketed atomic arrays, so
//! recording a sample never allocates and never takes a lock.
//!
//! Pieces:
//! * [`metrics`] — [`metrics::Counter`] / [`metrics::Gauge`] /
//!   [`metrics::Histogram`] handles registered in the global
//!   [`metrics::registry`]. Handles are `Clone` + `Send` + `Sync` and cache
//!   the underlying atomics, so instrumented code registers once (a mutex
//!   hit) and records forever after with one `fetch_add`. The registry
//!   renders the Prometheus text format (`GET /metrics` in
//!   `serve::server`) and a flat JSON snapshot (`sct train --metrics-out`
//!   JSONL cadence).
//! * [`trace`] — monotonically increasing request ids and a process-global
//!   span sink. The batcher emits one span record per request
//!   (queue → prefill chunks → decode steps → finish) as a JSON line; the
//!   sink is a file (`traces.jsonl`, `sct serve --trace-out`) or an
//!   in-memory buffer for tests. When no sink is installed, emission is a
//!   single relaxed load — tracing costs nothing unless asked for.
//! * [`log`] — the leveled logger behind the `sct_error!` / `sct_warn!` /
//!   `sct_info!` / `sct_debug!` macros. Level resolves as `--log-level`
//!   flag > `[obs] log_level` TOML > `SCT_LOG` env > `info`. Log lines go
//!   to **stderr** so `--log-level quiet` leaves stdout machine-clean for
//!   scripting (tables, generated text and JSON outputs stay on stdout).
//!
//! Instrumented layers (all registered under the `sct_` prefix):
//! serve (`sct_serve_*`: queue depth, active slots, admission wait,
//! TTFT/ITL histograms, request/token counters), the worker pool
//! (`sct_pool_*`: parallel-vs-serial decisions, fan-outs, shard sizes,
//! per-worker busy time), the native trainer (`sct_train_*`: per-phase
//! step-time histograms, grad norm, clip events), and the rank subsystem
//! (`sct_rank_*`: per-layer rank and tail-energy gauges, transition
//! counters, ortho error).

pub mod log;
pub mod metrics;
pub mod trace;

pub use metrics::{registry, Counter, Gauge, Histogram, Registry};
pub use trace::next_request_id;
