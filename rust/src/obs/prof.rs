//! `obs::prof` — the performance-attribution profiler: a scoped,
//! hierarchical phase/kernel tree with per-frame work models (FLOPs + bytes
//! moved) and roofline accounting against a once-calibrated machine peak.
//!
//! # Design
//!
//! Always compiled, **off by default**. The fast path mirrors
//! [`super::trace`]: [`enabled`] is one relaxed atomic load, and a
//! [`scope`]/[`kernel`] call while disabled allocates nothing, takes no
//! lock, reads no clock, and returns a disarmed guard — a handful of
//! nanoseconds, cheap enough to leave in the innermost batched kernels
//! (`benches/kernel_scaling.rs` shapes are asserted unaffected in the
//! overhead test below).
//!
//! When enabled, each thread accumulates **completed frames into a
//! thread-local buffer** (no cross-thread synchronization on the record
//! path) keyed by the frame's full path — e.g.
//! `train_step → forward → matmul` or `worker0 → decode_step → matmul` —
//! and merges that buffer into the process-global collector whenever its
//! scope stack unwinds to empty (once per train step / scheduler phase).
//! A frame records wall time, call count, and the work model its kernel
//! declared: FLOPs and bytes moved, evaluated lazily so the disabled path
//! never computes them.
//!
//! Pool workers are part of the tree: `util::pool::par_rows`/`par_tasks`
//! (and the scoped spawns in `AdamW::step` / `retract_model`) capture the
//! spawning thread's path with [`fork_ctx`] and install it in each worker
//! via [`attach`], so frames recorded inside a fan-out attach under the
//! scope that spawned it instead of forming disconnected roots.
//!
//! # Outputs
//!
//! [`snapshot`] folds the collector into a [`Report`] tree, rendered four
//! ways:
//! * [`Report::render_table`] — self/total-time phase tree + a top-k kernel
//!   table with achieved GFLOP/s, arithmetic intensity (FLOPs/byte), and
//!   the fraction of the calibrated machine peak ([`machine_peak_gflops`],
//!   a once-per-process multi-accumulator FMA micro-benchmark) — the
//!   roofline position of every instrumented kernel.
//! * [`Report::render_folded`] — collapsed-stack `.folded` lines
//!   (`a;b;c <self-µs>`) consumable by standard flamegraph tools
//!   (`flamegraph.pl`, speedscope, inferno).
//! * [`Report::to_json`] — the machine-readable document behind
//!   `GET /v1/profile` and `--profile-out` (`sct train`/`serve`/`sweep`,
//!   `[obs] profile_out` in TOML), tree plus flat per-kernel roofline rows.
//! * [`write_report`] — JSON to the given path plus a sibling `.folded`.

use crate::util::json::Json;
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

// ---------------------------------------------------------------------------
// enable flag + global collector
// ---------------------------------------------------------------------------

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Is profiling on? One relaxed load — the whole cost of a disarmed scope.
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn the profiler on. Frames recorded before the flag was set are kept
/// (call [`reset`] first for a clean window).
pub fn enable() {
    ENABLED.store(true, Ordering::Release);
}

/// Turn the profiler off. Already-collected frames survive for
/// [`snapshot`]; new scopes become free no-ops again.
pub fn disable() {
    ENABLED.store(false, Ordering::Release);
}

/// Wall time, call count and declared work of one aggregated tree path.
#[derive(Clone, Copy, Default)]
struct Stat {
    wall_ns: u64,
    calls: u64,
    flops: f64,
    bytes: f64,
}

type PathMap = HashMap<Vec<&'static str>, Stat>;

fn collector() -> &'static Mutex<PathMap> {
    static COLLECTOR: OnceLock<Mutex<PathMap>> = OnceLock::new();
    COLLECTOR.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Drop every collected frame (the calling thread's local buffer included).
/// Frames still buffered on *other* live threads merge on their next flush.
pub fn reset() {
    TLS.with(|t| t.borrow_mut().local.clear());
    collector().lock().unwrap().clear();
}

// ---------------------------------------------------------------------------
// per-thread frame buffer
// ---------------------------------------------------------------------------

struct ThreadProf {
    /// Path inherited from a spawning thread ([`attach`]) or a permanent
    /// per-thread root ([`set_thread_label`], serve scheduler threads).
    prefix: Vec<&'static str>,
    /// Open frames on this thread, innermost last.
    stack: Vec<(&'static str, Instant)>,
    /// Completed frames, aggregated by full path; merged into the global
    /// collector when the stack unwinds to empty.
    local: PathMap,
}

impl ThreadProf {
    fn flush(&mut self) {
        if self.local.is_empty() {
            return;
        }
        let mut global = collector().lock().unwrap();
        for (path, s) in self.local.drain() {
            let e = global.entry(path).or_default();
            e.wall_ns += s.wall_ns;
            e.calls += s.calls;
            e.flops += s.flops;
            e.bytes += s.bytes;
        }
    }
}

impl Drop for ThreadProf {
    fn drop(&mut self) {
        self.flush();
    }
}

thread_local! {
    static TLS: RefCell<ThreadProf> = RefCell::new(ThreadProf {
        prefix: Vec::new(),
        stack: Vec::new(),
        local: HashMap::new(),
    });
}

/// An open profiler frame; closing (dropping) it records the completed
/// frame. Disarmed (free) when profiling is disabled at open time.
pub struct Scope {
    armed: bool,
    flops: f64,
    bytes: f64,
}

/// Open a plain phase frame (no work model): train phases, serve phases.
#[inline]
pub fn scope(name: &'static str) -> Scope {
    kernel(name, || (0.0, 0.0))
}

/// Open a kernel frame with a declared work model. `work` returns
/// `(flops, bytes_moved)` and is evaluated **only when profiling is
/// enabled** — the disabled path is one relaxed load.
#[inline]
pub fn kernel(name: &'static str, work: impl FnOnce() -> (f64, f64)) -> Scope {
    if !enabled() {
        return Scope { armed: false, flops: 0.0, bytes: 0.0 };
    }
    let (flops, bytes) = work();
    TLS.with(|t| t.borrow_mut().stack.push((name, Instant::now())));
    Scope { armed: true, flops, bytes }
}

impl Drop for Scope {
    fn drop(&mut self) {
        if !self.armed {
            return;
        }
        TLS.with(|t| {
            let mut t = t.borrow_mut();
            let Some((name, t0)) = t.stack.pop() else { return };
            let wall = t0.elapsed().as_nanos() as u64;
            let mut path: Vec<&'static str> =
                Vec::with_capacity(t.prefix.len() + t.stack.len() + 1);
            path.extend_from_slice(&t.prefix);
            path.extend(t.stack.iter().map(|(n, _)| *n));
            path.push(name);
            let e = t.local.entry(path).or_default();
            e.wall_ns += wall;
            e.calls += 1;
            e.flops += self.flops;
            e.bytes += self.bytes;
            if t.stack.is_empty() {
                t.flush();
            }
        });
    }
}

// ---------------------------------------------------------------------------
// fan-out attachment (pool workers join the spawning scope)
// ---------------------------------------------------------------------------

/// The spawning thread's full path at fan-out time, for [`attach`]ing pool
/// workers under the scope that spawned them.
#[derive(Clone)]
pub struct ForkCtx(Vec<&'static str>);

/// Capture the calling thread's current path (`None` when profiling is
/// off — attachment then costs nothing in the workers either).
pub fn fork_ctx() -> Option<ForkCtx> {
    if !enabled() {
        return None;
    }
    TLS.with(|t| {
        let t = t.borrow();
        let mut p = t.prefix.clone();
        p.extend(t.stack.iter().map(|(n, _)| *n));
        Some(ForkCtx(p))
    })
}

/// Restores the worker thread's previous prefix (and flushes its frames)
/// when the fan-out body returns.
pub struct AttachGuard {
    armed: bool,
    prev: Vec<&'static str>,
}

/// Install a captured [`ForkCtx`] as this thread's path prefix, so frames
/// recorded here attach under the spawning scope. No-op for `None`.
pub fn attach(ctx: &Option<ForkCtx>) -> AttachGuard {
    match ctx {
        None => AttachGuard { armed: false, prev: Vec::new() },
        Some(c) => TLS.with(|t| {
            let mut t = t.borrow_mut();
            let prev = std::mem::replace(&mut t.prefix, c.0.clone());
            AttachGuard { armed: true, prev }
        }),
    }
}

impl Drop for AttachGuard {
    fn drop(&mut self) {
        if !self.armed {
            return;
        }
        TLS.with(|t| {
            let mut t = t.borrow_mut();
            t.prefix = std::mem::take(&mut self.prev);
            t.flush();
        });
    }
}

/// Give the calling thread a permanent root label — serve scheduler threads
/// call this once with [`worker_label`], so every frame they record (and
/// every fan-out they spawn) attributes to `workerN → ...` in the tree.
pub fn set_thread_label(label: &'static str) {
    TLS.with(|t| t.borrow_mut().prefix = vec![label]);
}

const WORKER_LABELS: [&str; 16] = [
    "worker0", "worker1", "worker2", "worker3", "worker4", "worker5", "worker6", "worker7",
    "worker8", "worker9", "worker10", "worker11", "worker12", "worker13", "worker14", "worker15",
];

/// Static per-worker root label (`worker0`..`worker15`; larger fleets share
/// one overflow label — attribution, not identity, is the contract there).
pub fn worker_label(i: usize) -> &'static str {
    WORKER_LABELS.get(i).copied().unwrap_or("worker16plus")
}

// ---------------------------------------------------------------------------
// machine-peak calibration
// ---------------------------------------------------------------------------

/// Calibrated single-core peak, GFLOP/s. Measured once per process by a
/// multi-accumulator mul+add micro-benchmark (best of three reps) and
/// cached — the roofline reference every kernel's achieved GFLOP/s is
/// reported against.
pub fn machine_peak_gflops() -> f64 {
    static PEAK: OnceLock<f64> = OnceLock::new();
    *PEAK.get_or_init(calibrate_peak)
}

fn calibrate_peak() -> f64 {
    let xs: Vec<f32> = (0..1024).map(|i| 1.0 + (i % 7) as f32 * 1e-7).collect();
    let mut best = 0.0f64;
    for _ in 0..3 {
        let mut acc = [1.0f32, 1.1, 1.2, 1.3, 1.4, 1.5, 1.6, 1.7];
        let passes = 20_000usize;
        let t0 = Instant::now();
        for _ in 0..passes {
            for lane in xs.chunks_exact(8) {
                for (a, &x) in acc.iter_mut().zip(lane) {
                    // mul + add: 2 flops per lane element, 8 independent
                    // chains so the dependency height doesn't serialize.
                    *a = *a * x + 1e-9;
                }
            }
            acc = std::hint::black_box(acc);
        }
        let secs = t0.elapsed().as_secs_f64();
        let flops = (passes * xs.len() * 2) as f64;
        if secs > 0.0 {
            best = best.max(flops / secs / 1e9);
        }
    }
    best.max(1e-9)
}

// ---------------------------------------------------------------------------
// report tree
// ---------------------------------------------------------------------------

/// One node of the aggregated phase tree. `wall_ns` is inclusive (the frame
/// open-to-close time); [`Node::self_ns`] subtracts profiled children.
#[derive(Debug, Clone)]
pub struct Node {
    pub name: &'static str,
    pub wall_ns: u64,
    pub calls: u64,
    pub flops: f64,
    pub bytes: f64,
    pub children: Vec<Node>,
}

impl Node {
    /// Wall time not attributed to a profiled child frame.
    pub fn self_ns(&self) -> u64 {
        let kids: u64 = self.children.iter().map(|c| c.wall_ns).sum();
        self.wall_ns.saturating_sub(kids)
    }

    /// First direct child with this name.
    pub fn child(&self, name: &str) -> Option<&Node> {
        self.children.iter().find(|c| c.name == name)
    }
}

/// Aggregated per-kernel roofline row (self time across every path the
/// kernel appears on).
#[derive(Debug, Clone)]
pub struct KernelStat {
    pub name: &'static str,
    pub calls: u64,
    pub self_ns: u64,
    pub flops: f64,
    pub bytes: f64,
}

impl KernelStat {
    /// Achieved throughput over the kernel's own (self) wall time.
    pub fn gflops(&self) -> f64 {
        let secs = self.self_ns as f64 / 1e9;
        if secs > 0.0 {
            self.flops / secs / 1e9
        } else {
            0.0
        }
    }

    /// Arithmetic intensity, FLOPs per byte moved (roofline x-axis).
    pub fn intensity(&self) -> f64 {
        if self.bytes > 0.0 {
            self.flops / self.bytes
        } else {
            0.0
        }
    }
}

/// A point-in-time fold of the collector: the phase tree plus flat kernel
/// aggregation.
#[derive(Debug, Clone, Default)]
pub struct Report {
    pub roots: Vec<Node>,
}

/// Flush the calling thread's buffer and fold the global collector into a
/// [`Report`]. Frames still open (or buffered on other threads whose stack
/// has not unwound) are not yet visible — staleness is bounded by one train
/// step / scheduler phase.
pub fn snapshot() -> Report {
    TLS.with(|t| t.borrow_mut().flush());
    let global = collector().lock().unwrap();
    build_report(&global)
}

fn build_report(map: &PathMap) -> Report {
    let mut roots: Vec<Node> = Vec::new();
    let mut paths: Vec<(&Vec<&'static str>, &Stat)> = map.iter().collect();
    // Deterministic insertion: parents (shorter paths) first, then lexical.
    paths.sort_by(|a, b| (a.0.len(), a.0).cmp(&(b.0.len(), b.0)));
    for (path, stat) in paths {
        insert_path(&mut roots, path, stat);
    }
    fill_synthetic_walls(&mut roots);
    sort_nodes(&mut roots);
    Report { roots }
}

fn insert_path(level: &mut Vec<Node>, path: &[&'static str], stat: &Stat) {
    let Some((&head, rest)) = path.split_first() else { return };
    let idx = match level.iter().position(|n| n.name == head) {
        Some(i) => i,
        None => {
            level.push(Node {
                name: head,
                wall_ns: 0,
                calls: 0,
                flops: 0.0,
                bytes: 0.0,
                children: Vec::new(),
            });
            level.len() - 1
        }
    };
    let node = &mut level[idx];
    if rest.is_empty() {
        node.wall_ns += stat.wall_ns;
        node.calls += stat.calls;
        node.flops += stat.flops;
        node.bytes += stat.bytes;
    } else {
        insert_path(&mut node.children, rest, stat);
    }
}

/// Synthetic nodes (path segments never directly scoped, e.g. a worker
/// label prefix) get the sum of their children as wall time, so self time
/// stays zero and totals roll up sensibly.
fn fill_synthetic_walls(nodes: &mut [Node]) {
    for n in nodes {
        fill_synthetic_walls(&mut n.children);
        if n.calls == 0 {
            n.wall_ns = n.children.iter().map(|c| c.wall_ns).sum();
        }
    }
}

fn sort_nodes(nodes: &mut [Node]) {
    nodes.sort_by(|a, b| b.wall_ns.cmp(&a.wall_ns).then(a.name.cmp(b.name)));
    for n in nodes.iter_mut() {
        sort_nodes(&mut n.children);
    }
}

impl Report {
    pub fn is_empty(&self) -> bool {
        self.roots.is_empty()
    }

    /// First root with this name (test/driver convenience).
    pub fn root(&self, name: &str) -> Option<&Node> {
        self.roots.iter().find(|n| n.name == name)
    }

    /// Self-time kernel aggregation across the whole tree, kernels with a
    /// declared work model only (`flops > 0`), sorted by self time.
    pub fn kernel_stats(&self) -> Vec<KernelStat> {
        let mut by_name: HashMap<&'static str, KernelStat> = HashMap::new();
        fn walk(nodes: &[Node], by_name: &mut HashMap<&'static str, KernelStat>) {
            for n in nodes {
                if n.flops > 0.0 {
                    let e = by_name.entry(n.name).or_insert(KernelStat {
                        name: n.name,
                        calls: 0,
                        self_ns: 0,
                        flops: 0.0,
                        bytes: 0.0,
                    });
                    e.calls += n.calls;
                    e.self_ns += n.self_ns();
                    e.flops += n.flops;
                    e.bytes += n.bytes;
                }
                walk(&n.children, by_name);
            }
        }
        walk(&self.roots, &mut by_name);
        let mut out: Vec<KernelStat> = by_name.into_values().collect();
        out.sort_by(|a, b| b.self_ns.cmp(&a.self_ns).then(a.name.cmp(b.name)));
        out
    }

    /// Human-readable report: indented self/total phase tree, then the
    /// top-`top_k` kernel roofline table against the calibrated peak.
    pub fn render_table(&self, top_k: usize) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:<40} {:>10} {:>10} {:>9}\n",
            "phase", "total ms", "self ms", "calls"
        ));
        fn walk(nodes: &[Node], depth: usize, out: &mut String) {
            for n in nodes {
                let indent = "  ".repeat(depth);
                out.push_str(&format!(
                    "{:<40} {:>10.3} {:>10.3} {:>9}\n",
                    format!("{indent}{}", n.name),
                    n.wall_ns as f64 / 1e6,
                    n.self_ns() as f64 / 1e6,
                    n.calls,
                ));
                walk(&n.children, depth + 1, out);
            }
        }
        walk(&self.roots, 0, &mut out);

        let kernels = self.kernel_stats();
        if !kernels.is_empty() {
            let peak = machine_peak_gflops();
            out.push_str(&format!(
                "\nkernel roofline (machine peak {peak:.2} GFLOP/s):\n\
                 {:<18} {:>9} {:>10} {:>10} {:>10} {:>8}\n",
                "kernel", "calls", "self ms", "GFLOP/s", "FLOP/byte", "% peak"
            ));
            for k in kernels.iter().take(top_k) {
                out.push_str(&format!(
                    "{:<18} {:>9} {:>10.3} {:>10.2} {:>10.3} {:>8.2}\n",
                    k.name,
                    k.calls,
                    k.self_ns as f64 / 1e6,
                    k.gflops(),
                    k.intensity(),
                    100.0 * k.gflops() / peak,
                ));
            }
        }
        out
    }

    /// Collapsed-stack flamegraph lines: one `a;b;c <self-µs>` line per
    /// node with recorded calls, root-to-leaf order, standard-tool ready.
    pub fn render_folded(&self) -> String {
        let mut out = String::new();
        fn walk(nodes: &[Node], prefix: &str, out: &mut String) {
            for n in nodes {
                let path = if prefix.is_empty() {
                    n.name.to_string()
                } else {
                    format!("{prefix};{}", n.name)
                };
                if n.calls > 0 {
                    out.push_str(&format!("{path} {}\n", n.self_ns() / 1_000));
                }
                walk(&n.children, &path, out);
            }
        }
        walk(&self.roots, "", &mut out);
        out
    }

    /// The machine-readable document (`GET /v1/profile`, `--profile-out`):
    /// `{enabled, machine_peak_gflops, kernels: [...], tree: [...]}`.
    pub fn to_json(&self) -> Json {
        fn node_json(n: &Node) -> Json {
            let mut fields: Vec<(String, Json)> = vec![
                ("name".to_string(), Json::Str(n.name.to_string())),
                ("calls".to_string(), Json::Num(n.calls as f64)),
                ("total_ms".to_string(), Json::Num(n.wall_ns as f64 / 1e6)),
                ("self_ms".to_string(), Json::Num(n.self_ns() as f64 / 1e6)),
            ];
            if n.flops > 0.0 {
                fields.push(("flops".to_string(), Json::Num(n.flops)));
                fields.push(("bytes".to_string(), Json::Num(n.bytes)));
            }
            if !n.children.is_empty() {
                fields.push((
                    "children".to_string(),
                    Json::Arr(n.children.iter().map(node_json).collect()),
                ));
            }
            Json::Obj(fields)
        }
        let kernels = self.kernel_stats();
        let peak = if kernels.is_empty() { 0.0 } else { machine_peak_gflops() };
        let kernel_rows: Vec<Json> = kernels
            .iter()
            .map(|k| {
                Json::Obj(vec![
                    ("kernel".to_string(), Json::Str(k.name.to_string())),
                    ("calls".to_string(), Json::Num(k.calls as f64)),
                    ("self_ms".to_string(), Json::Num(k.self_ns as f64 / 1e6)),
                    ("flops".to_string(), Json::Num(k.flops)),
                    ("bytes".to_string(), Json::Num(k.bytes)),
                    ("gflops".to_string(), Json::Num(k.gflops())),
                    ("intensity".to_string(), Json::Num(k.intensity())),
                    (
                        "peak_fraction".to_string(),
                        Json::Num(if peak > 0.0 { k.gflops() / peak } else { 0.0 }),
                    ),
                ])
            })
            .collect();
        Json::Obj(vec![
            ("enabled".to_string(), Json::Bool(enabled())),
            ("machine_peak_gflops".to_string(), Json::Num(peak)),
            ("kernels".to_string(), Json::Arr(kernel_rows)),
            ("tree".to_string(), Json::Arr(self.roots.iter().map(node_json).collect())),
        ])
    }
}

/// Snapshot and persist: JSON at `path`, collapsed stacks at the sibling
/// `<path>.folded` (extension replaced) — one flag feeds both standard
/// consumers. Returns the report for callers that also want to log it.
pub fn write_report(path: &Path) -> std::io::Result<Report> {
    let report = snapshot();
    std::fs::write(path, report.to_json().to_string())?;
    std::fs::write(path.with_extension("folded"), report.render_folded())?;
    Ok(report)
}

// ---------------------------------------------------------------------------
// shared work models
// ---------------------------------------------------------------------------

/// `(flops, bytes)` of an `(m×k)·(k×n)` f32 matmul: 2 FLOPs per MAC, each
/// operand + the output streamed once.
pub fn matmul_work(m: usize, k: usize, n: usize) -> (f64, f64) {
    (2.0 * m as f64 * k as f64 * n as f64, 4.0 * (m * k + k * n + m * n) as f64)
}

/// `(flops, bytes)` of the packed-GEBP matmul path
/// (`spectral::microkernel`): same FLOPs as [`matmul_work`], plus the panel
/// packing traffic — both operands are rewritten into packed panels (one
/// write) and the kernel reads the packed copies instead of re-streaming
/// the originals per tile, so A and B each cost one extra write + read:
/// `+ 8*(m*k + k*n)` bytes.
pub fn matmul_packed_work(m: usize, k: usize, n: usize) -> (f64, f64) {
    let (flops, bytes) = matmul_work(m, k, n);
    (flops, bytes + 8.0 * (m * k + k * n) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Profiler state is process-global and lib tests run concurrently:
    /// serialize every test that flips ENABLED or reads the collector.
    fn lock() -> std::sync::MutexGuard<'static, ()> {
        static LOCK: Mutex<()> = Mutex::new(());
        LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn disabled_scope_records_nothing_and_is_cheap() {
        let _g = lock();
        disable();
        reset();
        // Correctness: nothing recorded, work closure never evaluated.
        let mut evaluated = false;
        {
            let _s = kernel("test_prof_disabled", || {
                evaluated = true;
                (1.0, 1.0)
            });
        }
        assert!(!evaluated, "work model must not run while disabled");
        assert!(snapshot().root("test_prof_disabled").is_none());

        // Overhead bound: the disabled path is one relaxed load + a branch.
        // 2M scopes in well under a second leaves a generous margin over the
        // <5ns target on any CI host (500ns/scope here) while still failing
        // loudly if someone adds allocation, a lock, or a clock read.
        let n = 2_000_000u64;
        let t0 = Instant::now();
        for _ in 0..n {
            let _s = kernel("test_prof_overhead", || (1.0, 1.0));
        }
        let per_call = t0.elapsed().as_secs_f64() / n as f64;
        assert!(
            per_call < 500e-9,
            "disabled scope costs {:.1}ns, expected nanoseconds",
            per_call * 1e9
        );
        assert!(snapshot().root("test_prof_overhead").is_none());
    }

    #[test]
    fn tree_nests_scopes_and_aggregates_calls() {
        let _g = lock();
        reset();
        enable();
        for _ in 0..3 {
            let _outer = scope("test_prof_step");
            {
                let _fwd = scope("test_prof_fwd");
                let _k = kernel("test_prof_matmul", || matmul_work(4, 8, 4));
            }
            let _bwd = scope("test_prof_bwd");
        }
        disable();
        let report = snapshot();
        let step = report.root("test_prof_step").expect("root present");
        assert_eq!(step.calls, 3);
        let fwd = step.child("test_prof_fwd").expect("fwd nested under step");
        assert_eq!(fwd.calls, 3);
        let mm = fwd.child("test_prof_matmul").expect("kernel nested under fwd");
        assert_eq!(mm.calls, 3);
        let (flops1, bytes1) = matmul_work(4, 8, 4);
        assert_eq!(mm.flops, 3.0 * flops1);
        assert_eq!(mm.bytes, 3.0 * bytes1);
        assert!(step.child("test_prof_bwd").is_some());
        // Inclusive wall: parent >= sum of children.
        assert!(step.wall_ns >= fwd.wall_ns + step.child("test_prof_bwd").unwrap().wall_ns);
        reset();
    }

    #[test]
    fn pool_fanout_frames_attach_to_the_spawning_scope() {
        let _g = lock();
        reset();
        enable();
        let threads_before = crate::util::pool::threads();
        crate::util::pool::set_threads(4);
        {
            let _outer = scope("test_prof_fanout");
            let mut out = vec![0.0f32; 64 * 4];
            crate::util::pool::par_rows(&mut out, 4, |_r0, block| {
                let _inner = kernel("test_prof_shard", || (block.len() as f64, 0.0));
                for v in block.iter_mut() {
                    *v += 1.0;
                }
            });
        }
        crate::util::pool::set_threads(threads_before);
        disable();
        let report = snapshot();
        let outer = report.root("test_prof_fanout").expect("spawning scope present");
        let shard = outer
            .child("test_prof_shard")
            .expect("worker frames must attach under the spawning scope");
        assert!(shard.calls >= 2, "expected one frame per pool shard, got {}", shard.calls);
        assert_eq!(shard.flops, 64.0 * 4.0, "each element counted once across shards");
        assert!(
            report.root("test_prof_shard").is_none(),
            "worker frames must not form disconnected roots"
        );
        reset();
    }

    #[test]
    fn folded_and_json_renders_match_the_tree() {
        let _g = lock();
        reset();
        enable();
        {
            let _a = scope("test_prof_ra");
            let _b = kernel("test_prof_rb", || (1000.0, 500.0));
        }
        disable();
        let report = snapshot();

        let folded = report.render_folded();
        assert!(folded.lines().any(|l| {
            l.starts_with("test_prof_ra ") && l.split(' ').nth(1).unwrap().parse::<u64>().is_ok()
        }));
        assert!(folded.lines().any(|l| l.starts_with("test_prof_ra;test_prof_rb ")));

        let j = report.to_json();
        let parsed = Json::parse(&j.to_string()).expect("profile JSON must round-trip");
        let tree = parsed.get("tree").unwrap().as_arr().unwrap();
        let ra = tree
            .iter()
            .find(|n| n.get("name").unwrap().as_str().unwrap() == "test_prof_ra")
            .expect("root in JSON tree");
        let kids = ra.get("children").unwrap().as_arr().unwrap();
        assert_eq!(kids[0].get("name").unwrap().as_str().unwrap(), "test_prof_rb");
        assert_eq!(kids[0].get("flops").unwrap().as_f64().unwrap(), 1000.0);
        let kernels = parsed.get("kernels").unwrap().as_arr().unwrap();
        let rb = kernels
            .iter()
            .find(|k| k.get("kernel").unwrap().as_str().unwrap() == "test_prof_rb")
            .expect("kernel row present");
        assert!(rb.get("gflops").unwrap().as_f64().unwrap() >= 0.0);
        assert_eq!(rb.get("intensity").unwrap().as_f64().unwrap(), 2.0);
        assert!(parsed.get("machine_peak_gflops").unwrap().as_f64().unwrap() > 0.0);
        let table = report.render_table(10);
        assert!(table.contains("test_prof_rb") && table.contains("GFLOP/s"));
        reset();
    }

    #[test]
    fn worker_labels_are_stable_and_machine_peak_is_positive() {
        assert_eq!(worker_label(0), "worker0");
        assert_eq!(worker_label(15), "worker15");
        assert_eq!(worker_label(99), "worker16plus");
        assert!(machine_peak_gflops() > 0.0);
    }
}
