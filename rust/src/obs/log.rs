//! Leveled structured logger.
//!
//! Levels: `quiet < error < warn < info < debug`. The active level resolves
//! lazily as: [`set_level`] (the `--log-level` flag or `[obs] log_level`
//! TOML key, applied by the coordinator CLI) > the `SCT_LOG` env var >
//! `info`. Lines are written to **stderr** as
//! `[LEVEL module::path] message`, so a `quiet` run leaves stdout clean for
//! machine consumers (tables, generated text, JSON summaries stay on
//! stdout by design).
//!
//! Use through the macros: `sct_error!`, `sct_warn!`, `sct_info!`,
//! `sct_debug!` — each captures `module_path!()` as the target and is a
//! single relaxed load when the level filters it out.

use std::sync::atomic::{AtomicUsize, Ordering};

#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub enum Level {
    Quiet = 0,
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
}

impl Level {
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Quiet => "quiet",
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }
}

/// Parse a level name (`quiet|error|warn|info|debug`, case-insensitive).
pub fn parse_level(s: &str) -> Option<Level> {
    match s.trim().to_ascii_lowercase().as_str() {
        "quiet" | "off" | "none" => Some(Level::Quiet),
        "error" => Some(Level::Error),
        "warn" | "warning" => Some(Level::Warn),
        "info" => Some(Level::Info),
        "debug" | "trace" => Some(Level::Debug),
        _ => None,
    }
}

/// Sentinel meaning "not yet resolved from SCT_LOG".
const UNRESOLVED: usize = usize::MAX;

static LEVEL: AtomicUsize = AtomicUsize::new(UNRESOLVED);

fn from_usize(n: usize) -> Level {
    match n {
        0 => Level::Quiet,
        1 => Level::Error,
        2 => Level::Warn,
        4 => Level::Debug,
        _ => Level::Info,
    }
}

/// The active log level. First call resolves `SCT_LOG` (default `info`).
pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        UNRESOLVED => {
            let l = std::env::var("SCT_LOG")
                .ok()
                .and_then(|s| parse_level(&s))
                .unwrap_or(Level::Info);
            // Benign race: concurrent first readers resolve the same value
            // unless set_level landed in between, which then wins.
            let _ = LEVEL.compare_exchange(
                UNRESOLVED,
                l as usize,
                Ordering::Relaxed,
                Ordering::Relaxed,
            );
            from_usize(LEVEL.load(Ordering::Relaxed))
        }
        n => from_usize(n),
    }
}

/// Override the level (CLI `--log-level` / `[obs] log_level`). Takes
/// precedence over `SCT_LOG` from this point on.
pub fn set_level(l: Level) {
    LEVEL.store(l as usize, Ordering::Relaxed);
}

/// Would a message at `l` be emitted right now?
pub fn enabled(l: Level) -> bool {
    l <= level() && l != Level::Quiet
}

/// Emit one log line to stderr (no-op when filtered). Prefer the macros.
pub fn log(l: Level, target: &str, args: std::fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    // Short target: the crate prefix carries no information in a binary
    // that only has one crate.
    let target = target.strip_prefix("sct::").unwrap_or(target);
    eprintln!("[{} {}] {}", l.as_str(), target, args);
}

#[macro_export]
macro_rules! sct_error {
    ($($a:tt)*) => {
        $crate::obs::log::log($crate::obs::log::Level::Error, module_path!(), format_args!($($a)*))
    };
}

#[macro_export]
macro_rules! sct_warn {
    ($($a:tt)*) => {
        $crate::obs::log::log($crate::obs::log::Level::Warn, module_path!(), format_args!($($a)*))
    };
}

#[macro_export]
macro_rules! sct_info {
    ($($a:tt)*) => {
        $crate::obs::log::log($crate::obs::log::Level::Info, module_path!(), format_args!($($a)*))
    };
}

#[macro_export]
macro_rules! sct_debug {
    ($($a:tt)*) => {
        $crate::obs::log::log($crate::obs::log::Level::Debug, module_path!(), format_args!($($a)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_level_accepts_aliases() {
        assert_eq!(parse_level("QUIET"), Some(Level::Quiet));
        assert_eq!(parse_level("warning"), Some(Level::Warn));
        assert_eq!(parse_level("debug"), Some(Level::Debug));
        assert_eq!(parse_level("bogus"), None);
    }

    #[test]
    fn levels_order_as_expected() {
        assert!(Level::Quiet < Level::Error);
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
    }
}
