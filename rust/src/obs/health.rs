//! Training watchdog + process health state — the reaction half of the
//! spectral-health subsystem (`rank::spectra` is the measurement half).
//!
//! Native low-rank pretraining fails in characteristic numerical ways that
//! the loss curve alone hides: NaN/Inf leaking from an overflowed forward,
//! a gradient-norm explosion one step before the loss shows it, singular
//! values collapsing to zero (a dead subspace that QR retraction happily
//! keeps orthonormal). The watchdog checks for each of these at step
//! granularity and reacts per the configured [`Policy`]:
//!
//! * `warn`  — log + count, keep training;
//! * `skip`  — additionally drop the optimizer update for the anomalous
//!   step (the model is left exactly as it was before the step, so a NaN
//!   gradient can never poison the factors or the Adam moments);
//! * `halt`  — additionally stop the run: the trainer returns an error, the
//!   CLI exits non-zero after a final diagnostic dump, and no checkpoint is
//!   written from the anomalous state.
//!
//! Every anomaly increments `sct_health_anomalies_total{kind=...}`, emits a
//! leveled log line and a trace event, and is kept as the process-wide
//! "last anomaly" surfaced by `GET /v1/health` and the halt dump.
//!
//! Disabled cost: each check is one relaxed atomic load (the same contract
//! as `obs::prof` — see the overhead test). The lazy-closure form
//! [`check_params`] never evaluates its closure while disabled, so a full
//! parameter scan can sit on the step path unguarded.

use crate::util::json::Json;
use crate::{json_obj, obs, sct_error, sct_warn};
use std::collections::VecDeque;
use std::str::FromStr;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

/// What to do when an anomaly fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Policy {
    /// Log + count only.
    #[default]
    Warn,
    /// Also skip the optimizer update for the anomalous step.
    Skip,
    /// Also stop the run with a non-zero exit and a diagnostic dump.
    Halt,
}

impl Policy {
    pub fn as_str(self) -> &'static str {
        match self {
            Policy::Warn => "warn",
            Policy::Skip => "skip",
            Policy::Halt => "halt",
        }
    }
}

impl FromStr for Policy {
    type Err = String;

    fn from_str(s: &str) -> Result<Policy, String> {
        match s {
            "warn" => Ok(Policy::Warn),
            "skip" => Ok(Policy::Skip),
            "halt" => Ok(Policy::Halt),
            other => Err(format!("unknown watchdog policy '{other}' (use warn|skip|halt)")),
        }
    }
}

/// Watchdog thresholds. `Default` matches the CLI/TOML defaults.
#[derive(Debug, Clone)]
pub struct WatchdogConfig {
    pub policy: Policy,
    /// A finite loss counts as a spike when it exceeds `spike_factor` times
    /// the rolling-window mean (once the window holds [`MIN_WINDOW`] steps).
    pub spike_factor: f32,
    /// Rolling loss-window length, in steps.
    pub window: usize,
    /// Gradient global norm above this is an explosion.
    pub grad_max: f64,
    /// A triple whose largest |s| is at or below this is a dead spectrum.
    pub dead_eps: f32,
}

impl Default for WatchdogConfig {
    fn default() -> WatchdogConfig {
        WatchdogConfig {
            policy: Policy::Warn,
            spike_factor: 3.0,
            window: 50,
            grad_max: 1e3,
            dead_eps: 1e-8,
        }
    }
}

/// Steps the rolling window must hold before loss-spike detection arms
/// (early training is noisy by construction).
pub const MIN_WINDOW: usize = 10;

/// Outcome of a check, already resolved against the policy. Ordered by
/// severity so a step can fold multiple checks with `max`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
pub enum Verdict {
    #[default]
    Ok,
    /// Anomaly recorded; keep going.
    Warn,
    /// Skip this step's optimizer update.
    Skip,
    /// Stop the run.
    Halt,
}

impl Verdict {
    /// Should the optimizer update be dropped? (True for halt too — a
    /// halting run must not apply the poisoned update first.)
    pub fn skips_update(self) -> bool {
        self >= Verdict::Skip
    }

    pub fn halts(self) -> bool {
        self == Verdict::Halt
    }
}

/// The anomaly taxonomy. `name()` is the metric label value.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AnomalyKind {
    NanLoss,
    LossSpike,
    NanGrad,
    GradExplosion,
    NanParam,
    DeadSpectrum,
}

/// Every kind, for zero-state metric pre-registration (so the series exist
/// in a scrape before anything went wrong).
pub const ANOMALY_KINDS: [AnomalyKind; 6] = [
    AnomalyKind::NanLoss,
    AnomalyKind::LossSpike,
    AnomalyKind::NanGrad,
    AnomalyKind::GradExplosion,
    AnomalyKind::NanParam,
    AnomalyKind::DeadSpectrum,
];

impl AnomalyKind {
    pub fn name(self) -> &'static str {
        match self {
            AnomalyKind::NanLoss => "nan_loss",
            AnomalyKind::LossSpike => "loss_spike",
            AnomalyKind::NanGrad => "nan_grad",
            AnomalyKind::GradExplosion => "grad_explosion",
            AnomalyKind::NanParam => "nan_param",
            AnomalyKind::DeadSpectrum => "dead_spectrum",
        }
    }
}

/// One recorded anomaly — the `/v1/health` "last anomaly" payload.
#[derive(Debug, Clone)]
pub struct Anomaly {
    pub step: u64,
    pub kind: AnomalyKind,
    pub detail: String,
}

struct State {
    cfg: WatchdogConfig,
    window: VecDeque<f64>,
    window_sum: f64,
    last: Option<Anomaly>,
    anomalies: u64,
    skipped: u64,
}

impl Default for State {
    fn default() -> State {
        State {
            cfg: WatchdogConfig::default(),
            window: VecDeque::new(),
            window_sum: 0.0,
            last: None,
            anomalies: 0,
            skipped: 0,
        }
    }
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static STATE: Mutex<Option<State>> = Mutex::new(None);

fn with_state<R>(f: impl FnOnce(&mut State) -> R) -> R {
    let mut guard = STATE.lock().unwrap_or_else(|e| e.into_inner());
    f(guard.get_or_insert_with(State::default))
}

/// Is the watchdog armed? One relaxed load — the whole cost of a disarmed
/// check.
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Arm the watchdog with the given thresholds, resetting the rolling
/// window (anomaly counters and the last-anomaly record persist — they are
/// process-lifetime health state).
pub fn configure(cfg: WatchdogConfig) {
    with_state(|s| {
        s.cfg = cfg;
        s.window.clear();
        s.window_sum = 0.0;
    });
    register_metrics();
    ENABLED.store(true, Ordering::Release);
}

/// Disarm the watchdog. Already-recorded health state survives for
/// reporting.
pub fn disable() {
    ENABLED.store(false, Ordering::Release);
}

/// The armed policy ([`Policy::Warn`] when disarmed).
pub fn policy() -> Policy {
    with_state(|s| s.cfg.policy)
}

fn metrics_counter(kind: &str) -> obs::Counter {
    obs::registry().counter_with(
        "sct_health_anomalies_total",
        &[("kind", kind)],
        "Training anomalies detected by the watchdog, by kind",
    )
}

fn skipped_counter() -> obs::Counter {
    obs::registry().counter_with(
        "sct_health_skipped_steps_total",
        &[],
        "Optimizer updates dropped by the watchdog skip/halt policies",
    )
}

/// Pre-register every `sct_health_*` series at zero so scrapes (and the CI
/// metrics gate) see them before any anomaly fires. Called by `configure`
/// and at serve startup.
pub fn register_metrics() {
    for kind in ANOMALY_KINDS {
        metrics_counter(kind.name());
    }
    skipped_counter();
}

fn record(s: &mut State, step: u64, kind: AnomalyKind, detail: String) -> Verdict {
    let verdict = match s.cfg.policy {
        Policy::Warn => Verdict::Warn,
        Policy::Skip => Verdict::Skip,
        Policy::Halt => Verdict::Halt,
    };
    metrics_counter(kind.name()).inc();
    s.anomalies += 1;
    obs::trace::emit(&json_obj![
        ("kind", "anomaly"),
        ("anomaly", kind.name()),
        ("step", step as usize),
        ("policy", s.cfg.policy.as_str()),
        ("detail", detail.as_str()),
    ]);
    if verdict.halts() {
        sct_error!("watchdog: {} at step {step}: {detail} (policy halt)", kind.name());
    } else {
        sct_warn!(
            "watchdog: {} at step {step}: {detail} (policy {})",
            kind.name(),
            s.cfg.policy.as_str()
        );
    }
    s.last = Some(Anomaly { step, kind, detail });
    verdict
}

/// Check a step's training loss: NaN/Inf, then spike vs the rolling-window
/// mean. Finite losses (spiking or not) enter the window.
pub fn check_loss(step: u64, loss: f32) -> Verdict {
    if !enabled() {
        return Verdict::Ok;
    }
    with_state(|s| {
        if !loss.is_finite() {
            return record(s, step, AnomalyKind::NanLoss, format!("loss = {loss}"));
        }
        let mut verdict = Verdict::Ok;
        if s.window.len() >= MIN_WINDOW {
            let mean = s.window_sum / s.window.len() as f64;
            if mean > 0.0 && loss as f64 > mean * s.cfg.spike_factor as f64 {
                verdict = record(
                    s,
                    step,
                    AnomalyKind::LossSpike,
                    format!("loss {loss:.4} > {:.1}x window mean {mean:.4}", s.cfg.spike_factor),
                );
            }
        }
        s.window.push_back(loss as f64);
        s.window_sum += loss as f64;
        while s.window.len() > s.cfg.window.max(1) {
            if let Some(old) = s.window.pop_front() {
                s.window_sum -= old;
            }
        }
        verdict
    })
}

/// Check the gradient global norm: NaN/Inf, then explosion threshold.
pub fn check_grad_norm(step: u64, norm: f64) -> Verdict {
    if !enabled() {
        return Verdict::Ok;
    }
    with_state(|s| {
        if !norm.is_finite() {
            return record(s, step, AnomalyKind::NanGrad, format!("grad norm = {norm}"));
        }
        if norm > s.cfg.grad_max {
            return record(
                s,
                step,
                AnomalyKind::GradExplosion,
                format!("grad norm {norm:.3e} > max {:.3e}", s.cfg.grad_max),
            );
        }
        Verdict::Ok
    })
}

/// Check one triple's singular values: NaN poisons, all-(near-)zero is a
/// collapsed/dead spectrum.
pub fn check_spectrum(step: u64, layer: usize, name: &str, s_vals: &[f32]) -> Verdict {
    if !enabled() {
        return Verdict::Ok;
    }
    with_state(|s| {
        if s_vals.iter().any(|v| !v.is_finite()) {
            return record(
                s,
                step,
                AnomalyKind::NanParam,
                format!("non-finite singular value in layer {layer} {name}"),
            );
        }
        let s_max = s_vals.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        if s_max <= s.cfg.dead_eps {
            return record(
                s,
                step,
                AnomalyKind::DeadSpectrum,
                format!("layer {layer} {name}: max |s| = {s_max:.3e} (collapsed spectrum)"),
            );
        }
        Verdict::Ok
    })
}

/// Lazy full-parameter scan: `scan` runs only while the watchdog is armed
/// (never while disabled — see the overhead test) and returns a detail
/// string when it finds a non-finite parameter.
pub fn check_params<F: FnOnce() -> Option<String>>(step: u64, scan: F) -> Verdict {
    if !enabled() {
        return Verdict::Ok;
    }
    match scan() {
        Some(detail) => with_state(|s| record(s, step, AnomalyKind::NanParam, detail)),
        None => Verdict::Ok,
    }
}

/// Count an optimizer update dropped by the skip/halt policies.
pub fn note_skipped_step() {
    skipped_counter().inc();
    with_state(|s| s.skipped += 1);
}

/// The most recent anomaly (process-lifetime), for `/v1/health` and the
/// halt dump.
pub fn last_anomaly() -> Option<Anomaly> {
    with_state(|s| s.last.clone())
}

/// Total anomalies recorded over the process lifetime.
pub fn anomaly_total() -> u64 {
    with_state(|s| s.anomalies)
}

/// Health report object: watchdog arming, policy, counts and the last
/// anomaly — embedded in `/v1/health` and the halt-time diagnostic dump.
pub fn report_json() -> Json {
    with_state(|s| {
        let last = match &s.last {
            Some(a) => json_obj![
                ("step", a.step as usize),
                ("kind", a.kind.name()),
                ("detail", a.detail.as_str()),
            ],
            None => Json::Null,
        };
        json_obj![
            ("enabled", enabled()),
            ("policy", s.cfg.policy.as_str()),
            ("anomalies_total", s.anomalies as usize),
            ("skipped_steps", s.skipped as usize),
            ("last_anomaly", last),
        ]
    })
}

/// Serialize tests (and any test arming the global watchdog elsewhere in
/// the crate) against each other.
#[doc(hidden)]
pub fn test_guard() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arm(policy: Policy) {
        configure(WatchdogConfig { policy, ..WatchdogConfig::default() });
    }

    #[test]
    fn disarmed_checks_are_noops_and_cheap() {
        let _g = test_guard();
        disable();
        let before = anomaly_total();
        let mut evaluated = false;
        let v = check_params(1, || {
            evaluated = true;
            Some("never".to_string())
        });
        assert_eq!(v, Verdict::Ok);
        assert!(!evaluated, "disarmed check_params must not run the scan");
        assert_eq!(check_loss(1, f32::NAN), Verdict::Ok);
        assert_eq!(check_grad_norm(1, f64::INFINITY), Verdict::Ok);
        assert_eq!(check_spectrum(1, 0, "gate", &[f32::NAN]), Verdict::Ok);
        assert_eq!(anomaly_total(), before, "disarmed checks must not record");

        // The disarmed fast path is one relaxed load — same budget as the
        // profiler's overhead test.
        let n = 2_000_000u64;
        let t0 = std::time::Instant::now();
        for i in 0..n {
            let _ = check_loss(i, 1.0);
        }
        let per_call = t0.elapsed().as_secs_f64() / n as f64;
        assert!(per_call < 500e-9, "disarmed watchdog check cost {per_call:.3e}s per call");
    }

    #[test]
    fn policy_resolves_verdicts() {
        let _g = test_guard();
        arm(Policy::Warn);
        assert_eq!(check_loss(5, f32::NAN), Verdict::Warn);
        arm(Policy::Skip);
        assert_eq!(check_loss(6, f32::NAN), Verdict::Skip);
        arm(Policy::Halt);
        let v = check_loss(7, f32::INFINITY);
        assert_eq!(v, Verdict::Halt);
        assert!(v.halts() && v.skips_update());
        let last = last_anomaly().unwrap();
        assert_eq!(last.step, 7);
        assert_eq!(last.kind, AnomalyKind::NanLoss);
        disable();
    }

    #[test]
    fn loss_spike_arms_after_min_window() {
        let _g = test_guard();
        arm(Policy::Warn);
        // Below MIN_WINDOW: even a huge loss is not a spike yet.
        assert_eq!(check_loss(0, 100.0), Verdict::Ok);
        for step in 1..=(MIN_WINDOW as u64) {
            assert_eq!(check_loss(step, 2.0), Verdict::Ok);
        }
        // Window mean is ~ 10.9 (one 100 + ten 2.0); 4x mean is a spike at
        // the default factor 3.0 only if > 3*mean — use a clear spike.
        let v = check_loss(99, 1000.0);
        assert_eq!(v, Verdict::Warn);
        assert_eq!(last_anomaly().unwrap().kind, AnomalyKind::LossSpike);
        // The spike entered the window; a normal loss right after is fine.
        assert_eq!(check_loss(100, 2.0), Verdict::Ok);
        disable();
    }

    #[test]
    fn grad_and_spectrum_checks_fire() {
        let _g = test_guard();
        configure(WatchdogConfig { policy: Policy::Skip, grad_max: 10.0, ..Default::default() });
        assert_eq!(check_grad_norm(3, 5.0), Verdict::Ok);
        assert_eq!(check_grad_norm(3, 50.0), Verdict::Skip);
        assert_eq!(last_anomaly().unwrap().kind, AnomalyKind::GradExplosion);
        assert_eq!(check_grad_norm(4, f64::NAN), Verdict::Skip);
        assert_eq!(last_anomaly().unwrap().kind, AnomalyKind::NanGrad);

        assert_eq!(check_spectrum(5, 1, "up", &[0.5, 0.1]), Verdict::Ok);
        assert_eq!(check_spectrum(5, 1, "up", &[0.0, 0.0]), Verdict::Skip);
        assert_eq!(last_anomaly().unwrap().kind, AnomalyKind::DeadSpectrum);
        assert_eq!(check_spectrum(6, 2, "down", &[1.0, f32::NAN]), Verdict::Skip);
        assert_eq!(last_anomaly().unwrap().kind, AnomalyKind::NanParam);

        let mut ran = false;
        let v = check_params(7, || {
            ran = true;
            None
        });
        assert!(ran, "armed check_params must run the scan");
        assert_eq!(v, Verdict::Ok);
        disable();
    }

    #[test]
    fn report_and_metrics_surface() {
        let _g = test_guard();
        arm(Policy::Halt);
        let _ = check_loss(42, f32::NAN);
        note_skipped_step();
        let report = report_json();
        assert_eq!(report.get("policy").unwrap(), &Json::Str("halt".into()));
        let last = report.get("last_anomaly").unwrap();
        assert_eq!(last.get("kind").unwrap(), &Json::Str("nan_loss".into()));
        assert!(report.get("anomalies_total").unwrap().as_f64().unwrap() >= 1.0);
        assert!(report.get("skipped_steps").unwrap().as_f64().unwrap() >= 1.0);

        let text = obs::registry().render_prometheus();
        assert!(text.contains("sct_health_anomalies_total{kind=\"nan_loss\"}"));
        // Pre-registered at zero even though this kind never fired here.
        assert!(text.contains("sct_health_anomalies_total{kind=\"grad_explosion\"}"));
        assert!(text.contains("sct_health_skipped_steps_total"));
        disable();
    }

    #[test]
    fn policy_parses() {
        assert_eq!("warn".parse::<Policy>().unwrap(), Policy::Warn);
        assert_eq!("skip".parse::<Policy>().unwrap(), Policy::Skip);
        assert_eq!("halt".parse::<Policy>().unwrap(), Policy::Halt);
        assert!("loud".parse::<Policy>().is_err());
    }
}
