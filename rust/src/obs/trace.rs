//! Hierarchical per-request span tracing.
//!
//! Every request admitted through the batcher gets a process-unique id from
//! [`next_request_id`]; the id flows into `/v1/generate` responses and SSE
//! frames. When a sink is installed, a served request produces a **span
//! tree** linked by `span_id`/`parent_id`, every record also stamped with
//! the request id that went out on the wire:
//!
//! * `kind:"gateway"` — the placement decision (root; its `span_id` **is**
//!   the request id, so children link to it without cross-thread plumbing)
//! * `kind:"request"` — the worker-side summary emitted at eviction time
//!   (`parent_id` = the gateway span), carrying the flat fields the
//!   pre-hierarchical schema had:
//!
//! ```json
//! {"kind":"request","span_id":9,"parent_id":7,"request_id":7,"worker":0,
//!  "prompt_tokens":12,"queue_ms":0.4,"prefill_chunks":1,
//!  "prefill_tokens":11,"decode_steps":16,"tokens_out":16,"ttft_ms":3.1,
//!  "decode_ms":12.8,"finish_reason":"length"}
//! ```
//!
//! * `kind:"queue_wait"` / `kind:"prefill_chunk"` / `kind:"decode"` —
//!   admission wait, one span per fused prefill chunk, and the decode
//!   phase, each with `parent_id` pointing at the request span.
//!
//! (`ttft_ms` is omitted when the request produced no tokens.)
//!
//! Sinks: [`install_file`] appends JSON lines to `traces.jsonl`
//! (`sct serve --trace-out`, bench `--trace-out`); [`install_memory`] keeps
//! spans in a buffer for tests. With no sink installed, [`emit`] is a
//! single relaxed atomic load — tracing is free unless requested.

use crate::util::json::Json;
use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

static NEXT_ID: AtomicU64 = AtomicU64::new(1);

/// Allocate the next request id (monotonic, process-wide, starts at 1).
pub fn next_request_id() -> u64 {
    NEXT_ID.fetch_add(1, Ordering::Relaxed)
}

/// Allocate a span id for a non-root span. Drawn from the same process-wide
/// sequence as request ids, so a request id doubles as its gateway (root)
/// span id without ever colliding with a child span's id.
pub fn next_span_id() -> u64 {
    NEXT_ID.fetch_add(1, Ordering::Relaxed)
}

enum Sink {
    File(Mutex<File>),
    Memory(Arc<Mutex<Vec<Json>>>),
}

/// Fast-path flag mirroring "SINK is Some" so [`emit`] skips the mutex when
/// tracing is off (the common case).
static ENABLED: AtomicBool = AtomicBool::new(false);

fn sink_slot() -> &'static Mutex<Option<Sink>> {
    static SINK: Mutex<Option<Sink>> = Mutex::new(None);
    &SINK
}

/// Install a JSONL file sink (append mode; each span is one line, flushed
/// immediately so a crash loses at most the in-flight span).
pub fn install_file(path: &Path) -> std::io::Result<()> {
    let f = OpenOptions::new().create(true).append(true).open(path)?;
    *sink_slot().lock().unwrap() = Some(Sink::File(Mutex::new(f)));
    ENABLED.store(true, Ordering::Release);
    Ok(())
}

/// Install an in-memory sink (tests) and return the shared span buffer.
pub fn install_memory() -> Arc<Mutex<Vec<Json>>> {
    let buf: Arc<Mutex<Vec<Json>>> = Arc::new(Mutex::new(Vec::new()));
    *sink_slot().lock().unwrap() = Some(Sink::Memory(buf.clone()));
    ENABLED.store(true, Ordering::Release);
    buf
}

/// Remove the sink; subsequent [`emit`]s are no-ops again.
pub fn uninstall() {
    ENABLED.store(false, Ordering::Release);
    *sink_slot().lock().unwrap() = None;
}

/// Is a sink installed? One relaxed load — callers may skip building the
/// span object entirely when this is false.
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Acquire)
}

/// Record one span. No-op without a sink.
pub fn emit(span: &Json) {
    if !enabled() {
        return;
    }
    let slot = sink_slot().lock().unwrap();
    match &*slot {
        Some(Sink::File(f)) => {
            let mut f = f.lock().unwrap();
            let _ = writeln!(f, "{}", span.to_string());
            let _ = f.flush();
        }
        Some(Sink::Memory(buf)) => buf.lock().unwrap().push(span.clone()),
        None => {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_are_unique_and_increasing() {
        let a = next_request_id();
        let b = next_request_id();
        assert!(b > a);
    }

    #[test]
    fn emit_without_sink_is_noop() {
        // Must not panic or block; nothing observable to assert beyond that.
        emit(&Json::Num(1.0));
    }
}
