//! Checkpointing: the `.sct` binary format + a rotating manager.
//!
//! A checkpoint stores the full session state (params + AdamW moments) as
//! named tensors, so training resumes bit-exactly. The format is
//! self-describing (names/dtypes/shapes in a JSON header) and versioned.

pub mod format;
pub mod manager;

pub use format::{read_checkpoint, write_checkpoint, NamedTensor};
pub use manager::CheckpointManager;
