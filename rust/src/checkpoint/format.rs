//! The `.sct` checkpoint file format.
//!
//! Layout:
//! ```text
//!   magic   "SCTCKPT1"                       (8 bytes)
//!   hdr_len u64 little-endian                (8 bytes)
//!   header  JSON: {"step": N, "tensors": [{name, dtype, shape, bytes}...]}
//!   payload concatenated raw little-endian tensor data, in header order
//! ```
//! Integrity: total payload length is validated against the header; each
//! tensor's byte count must equal prod(shape) * sizeof(dtype).

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::json_obj;
use crate::runtime::DType;
use crate::util::json::Json;

const MAGIC: &[u8; 8] = b"SCTCKPT1";

/// A named tensor with raw little-endian payload.
#[derive(Debug, Clone, PartialEq)]
pub struct NamedTensor {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
    pub data: Vec<u8>,
}

impl NamedTensor {
    pub fn f32(name: &str, shape: Vec<usize>, values: &[f32]) -> NamedTensor {
        let mut data = Vec::with_capacity(values.len() * 4);
        for v in values {
            data.extend_from_slice(&v.to_le_bytes());
        }
        NamedTensor { name: name.to_string(), dtype: DType::F32, shape, data }
    }

    pub fn i32(name: &str, shape: Vec<usize>, values: &[i32]) -> NamedTensor {
        let mut data = Vec::with_capacity(values.len() * 4);
        for v in values {
            data.extend_from_slice(&v.to_le_bytes());
        }
        NamedTensor { name: name.to_string(), dtype: DType::I32, shape, data }
    }

    pub fn as_f32(&self) -> Result<Vec<f32>> {
        if self.dtype != DType::F32 {
            bail!("{} is {:?}, not f32", self.name, self.dtype);
        }
        Ok(self.data.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    pub fn as_i32(&self) -> Result<Vec<i32>> {
        if self.dtype != DType::I32 {
            bail!("{} is {:?}, not i32", self.name, self.dtype);
        }
        Ok(self.data.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn validate(&self) -> Result<()> {
        let expect = self.shape.iter().product::<usize>() * self.dtype.size_bytes();
        if self.data.len() != expect {
            bail!(
                "tensor {}: {} bytes, expected {} for shape {:?}",
                self.name,
                self.data.len(),
                expect,
                self.shape
            );
        }
        Ok(())
    }
}

/// Write a checkpoint atomically (tmp file + rename).
pub fn write_checkpoint(path: &Path, step: u64, tensors: &[NamedTensor]) -> Result<()> {
    for t in tensors {
        t.validate()?;
    }
    let header = json_obj![
        ("step", step as i64),
        (
            "tensors",
            Json::Arr(
                tensors
                    .iter()
                    .map(|t| json_obj![
                        ("name", t.name.as_str()),
                        ("dtype", t.dtype.name()),
                        ("shape", t.shape.clone().into_iter().map(Json::from).collect::<Vec<_>>()),
                        ("bytes", t.data.len()),
                    ])
                    .collect()
            )
        ),
    ];
    let header_bytes = header.to_string().into_bytes();

    let tmp = path.with_extension("sct.tmp");
    {
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(MAGIC)?;
        f.write_all(&(header_bytes.len() as u64).to_le_bytes())?;
        f.write_all(&header_bytes)?;
        for t in tensors {
            f.write_all(&t.data)?;
        }
        f.flush()?;
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming into {}", path.display()))?;
    Ok(())
}

/// Read a checkpoint: returns (step, tensors).
pub fn read_checkpoint(path: &Path) -> Result<(u64, Vec<NamedTensor>)> {
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{} is not an SCT checkpoint (bad magic)", path.display());
    }
    let mut len8 = [0u8; 8];
    f.read_exact(&mut len8)?;
    let hdr_len = u64::from_le_bytes(len8) as usize;
    if hdr_len > 64 << 20 {
        bail!("unreasonable header length {hdr_len}");
    }
    let mut hdr = vec![0u8; hdr_len];
    f.read_exact(&mut hdr)?;
    let header = Json::parse(std::str::from_utf8(&hdr)?)?;
    let step = header.req("step")?.as_i64()? as u64;

    let mut tensors = Vec::new();
    for tj in header.req("tensors")?.as_arr()? {
        let name = tj.req("name")?.as_str()?.to_string();
        let dtype = DType::parse(tj.req("dtype")?.as_str()?)?;
        let shape: Vec<usize> =
            tj.req("shape")?.as_arr()?.iter().map(|d| d.as_usize()).collect::<Result<_>>()?;
        let nbytes = tj.req("bytes")?.as_usize()?;
        let mut data = vec![0u8; nbytes];
        f.read_exact(&mut data)
            .with_context(|| format!("reading payload of {name}"))?;
        let t = NamedTensor { name, dtype, shape, data };
        t.validate()?;
        tensors.push(t);
    }
    // no trailing garbage
    let mut rest = Vec::new();
    f.read_to_end(&mut rest)?;
    if !rest.is_empty() {
        bail!("{} trailing bytes after payload", rest.len());
    }
    Ok((step, tensors))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir() -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("sct_ckpt_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn roundtrip() {
        let dir = tmpdir();
        let path = dir.join("a.sct");
        let tensors = vec![
            NamedTensor::f32("params/embed", vec![2, 3], &[1.0, -2.0, 3.5, 0.0, 1e-9, 7.0]),
            NamedTensor::i32("opt/t", vec![], &[42]),
        ];
        write_checkpoint(&path, 17, &tensors).unwrap();
        let (step, back) = read_checkpoint(&path).unwrap();
        assert_eq!(step, 17);
        assert_eq!(back, tensors);
        assert_eq!(back[0].as_f32().unwrap()[2], 3.5);
        assert_eq!(back[1].as_i32().unwrap(), vec![42]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = tmpdir();
        let path = dir.join("bad.sct");
        std::fs::write(&path, b"NOTSCT00aaaaaaaa").unwrap();
        assert!(read_checkpoint(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_truncated_payload() {
        let dir = tmpdir();
        let path = dir.join("trunc.sct");
        let tensors = vec![NamedTensor::f32("x", vec![4], &[1.0, 2.0, 3.0, 4.0])];
        write_checkpoint(&path, 1, &tensors).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 4]).unwrap();
        assert!(read_checkpoint(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_shape_mismatch() {
        let t = NamedTensor { name: "x".into(), dtype: DType::F32, shape: vec![3], data: vec![0; 8] };
        assert!(t.validate().is_err());
    }

    #[test]
    fn dtype_mismatch_on_read_accessor() {
        let t = NamedTensor::f32("x", vec![1], &[1.0]);
        assert!(t.as_i32().is_err());
    }
}
