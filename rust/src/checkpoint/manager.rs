//! Rotating checkpoint manager + session save/restore glue.
//!
//! The manager itself (directory layout, listing, pruning) and the
//! tensor-level [`CheckpointManager::save_tensors`] path (used by the
//! native trainer) are pure filesystem code; the [`Session`] save/restore
//! glue needs the `pjrt` feature because session state lives in device
//! literals.

use std::path::PathBuf;

use anyhow::{Context, Result};

#[cfg(feature = "pjrt")]
use std::path::Path;

#[cfg(feature = "pjrt")]
use anyhow::bail;

use super::format::{write_checkpoint, NamedTensor};

#[cfg(feature = "pjrt")]
use super::format::read_checkpoint;
#[cfg(feature = "pjrt")]
use crate::runtime::{DType, Session};

/// Saves `step_NNNNNN.sct` files in a directory, keeping the newest `keep`.
pub struct CheckpointManager {
    pub dir: PathBuf,
    pub keep: usize,
}

impl CheckpointManager {
    pub fn new(dir: impl Into<PathBuf>, keep: usize) -> Result<CheckpointManager> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating checkpoint dir {}", dir.display()))?;
        Ok(CheckpointManager { dir, keep: keep.max(1) })
    }

    fn path_for(&self, step: u64) -> PathBuf {
        self.dir.join(format!("step_{step:08}.sct"))
    }

    /// All checkpoints, sorted by step ascending.
    pub fn list(&self) -> Result<Vec<(u64, PathBuf)>> {
        let mut out = Vec::new();
        for entry in std::fs::read_dir(&self.dir)? {
            let path = entry?.path();
            let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
            if let Some(num) = name.strip_prefix("step_").and_then(|s| s.strip_suffix(".sct")) {
                if let Ok(step) = num.parse::<u64>() {
                    out.push((step, path));
                }
            }
        }
        out.sort();
        Ok(out)
    }

    /// Newest checkpoint, if any.
    pub fn latest(&self) -> Result<Option<(u64, PathBuf)>> {
        Ok(self.list()?.pop())
    }

    /// Save pre-built tensors as `step_NNNNNN.sct` and prune beyond `keep`
    /// — the backend-agnostic path the native trainer uses.
    pub fn save_tensors(&self, step: u64, tensors: &[NamedTensor]) -> Result<PathBuf> {
        let path = self.path_for(step);
        write_checkpoint(&path, step, tensors)?;
        self.prune()?;
        Ok(path)
    }

    /// Save the full session state; prune old checkpoints beyond `keep`.
    #[cfg(feature = "pjrt")]
    pub fn save(&self, session: &Session) -> Result<PathBuf> {
        let specs = session.state_specs().to_vec();
        let state = session.state();
        if state.len() != specs.len() {
            bail!("session state not initialized");
        }
        let mut tensors = Vec::with_capacity(specs.len());
        for (spec, lit) in specs.iter().zip(state) {
            let data = match spec.dtype {
                DType::F32 => NamedTensor::f32(&spec.name, spec.shape.clone(), &lit.to_vec::<f32>()?),
                DType::I32 => NamedTensor::i32(&spec.name, spec.shape.clone(), &lit.to_vec::<i32>()?),
                DType::U32 => {
                    let v = lit.to_vec::<u32>()?;
                    let as_i: Vec<i32> = v.iter().map(|&x| x as i32).collect();
                    let mut t = NamedTensor::i32(&spec.name, spec.shape.clone(), &as_i);
                    t.dtype = DType::U32;
                    t
                }
            };
            tensors.push(data);
        }
        self.save_tensors(session.steps_done, &tensors)
    }

    /// Restore the latest checkpoint into the session (names must match the
    /// manifest state layout exactly). Returns the restored step.
    #[cfg(feature = "pjrt")]
    pub fn restore_latest(&self, session: &mut Session) -> Result<u64> {
        let list = self.list()?;
        let Some((_, path)) = list.last() else {
            bail!("no checkpoints in {}", self.dir.display());
        };
        self.restore(session, path)
    }

    #[cfg(feature = "pjrt")]
    pub fn restore(&self, session: &mut Session, path: &Path) -> Result<u64> {
        let (step, tensors) = read_checkpoint(path)?;
        let specs = session.state_specs().to_vec();
        if tensors.len() != specs.len() {
            bail!(
                "checkpoint has {} tensors, manifest expects {}",
                tensors.len(),
                specs.len()
            );
        }
        let mut state = Vec::with_capacity(specs.len());
        for (spec, t) in specs.iter().zip(&tensors) {
            if t.name != spec.name || t.shape != spec.shape {
                bail!(
                    "checkpoint tensor {:?} {:?} does not match manifest {:?} {:?}",
                    t.name,
                    t.shape,
                    spec.name,
                    spec.shape
                );
            }
            state.push(crate::runtime::tensor::literal_from_bytes(
                spec.dtype,
                &spec.shape,
                &t.data,
            )?);
        }
        session.set_state(state)?;
        session.steps_done = step;
        Ok(step)
    }

    fn prune(&self) -> Result<()> {
        let list = self.list()?;
        if list.len() > self.keep {
            for (_, path) in &list[..list.len() - self.keep] {
                std::fs::remove_file(path).ok();
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::format::{write_checkpoint, NamedTensor};

    #[test]
    fn list_and_prune_ordering() {
        let dir = std::env::temp_dir().join(format!("sct_mgr_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let mgr = CheckpointManager::new(&dir, 2).unwrap();
        for step in [5u64, 1, 9] {
            let t = vec![NamedTensor::f32("x", vec![1], &[step as f32])];
            write_checkpoint(&mgr.path_for(step), step, &t).unwrap();
        }
        let steps: Vec<u64> = mgr.list().unwrap().into_iter().map(|(s, _)| s).collect();
        assert_eq!(steps, vec![1, 5, 9]);
        mgr.prune().unwrap();
        let steps: Vec<u64> = mgr.list().unwrap().into_iter().map(|(s, _)| s).collect();
        assert_eq!(steps, vec![5, 9], "keep=2 prunes the oldest");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn save_tensors_rotates_and_reports_latest() {
        let dir = std::env::temp_dir().join(format!("sct_mgr3_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let mgr = CheckpointManager::new(&dir, 2).unwrap();
        assert!(mgr.latest().unwrap().is_none());
        for step in [3u64, 8, 21] {
            let t = vec![NamedTensor::f32("params/x", vec![1], &[step as f32])];
            mgr.save_tensors(step, &t).unwrap();
        }
        let steps: Vec<u64> = mgr.list().unwrap().into_iter().map(|(s, _)| s).collect();
        assert_eq!(steps, vec![8, 21], "save_tensors must prune to keep=2");
        let (latest, path) = mgr.latest().unwrap().unwrap();
        assert_eq!(latest, 21);
        assert!(path.ends_with("step_00000021.sct"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn ignores_foreign_files() {
        let dir = std::env::temp_dir().join(format!("sct_mgr2_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let mgr = CheckpointManager::new(&dir, 3).unwrap();
        std::fs::write(dir.join("README.txt"), "not a checkpoint").unwrap();
        std::fs::write(dir.join("step_x.sct"), "bad name").unwrap();
        assert!(mgr.list().unwrap().is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }
}
