//! Small in-tree substrates that would normally be crates (serde_json, clap,
//! rand, criterion) — the build environment is offline, so they are built
//! from scratch here.

pub mod args;
pub mod bench;
pub mod json;
pub mod pool;
pub mod rng;
