//! Tiny CLI argument parser (clap is not vendored in this offline image).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional arguments;
//! generates usage text from declared options. Just enough structure for the
//! `sct` launcher and the example binaries to share one implementation.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// Declarative option spec: used for usage text and validation.
#[derive(Debug, Clone)]
pub struct Opt {
    pub name: &'static str,
    pub help: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
}

/// Parsed arguments for one (sub)command.
#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn req(&self, key: &str) -> Result<&str> {
        self.get(key).ok_or_else(|| anyhow!("missing required option --{key}"))
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    pub fn parse_num<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s.parse::<T>().map_err(|e| anyhow!("--{key} {s:?}: {e}")),
        }
    }
}

/// A subcommand with declared options.
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<Opt>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Command { name, about, opts: Vec::new() }
    }

    pub fn opt(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt { name, help, takes_value: true, default: None });
        self
    }

    pub fn opt_default(mut self, name: &'static str, help: &'static str, default: &'static str) -> Self {
        self.opts.push(Opt { name, help, takes_value: true, default: Some(default) });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt { name, help, takes_value: false, default: None });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\noptions:\n", self.name, self.about);
        for o in &self.opts {
            let val = if o.takes_value { " <value>" } else { "" };
            let def = o.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
            s.push_str(&format!("  --{}{}\n      {}{}\n", o.name, val, o.help, def));
        }
        s
    }

    /// Parse raw argv (without the program/subcommand names).
    pub fn parse(&self, argv: &[String]) -> Result<Args> {
        let mut args = Args::default();
        for o in &self.opts {
            if let Some(d) = o.default {
                args.values.insert(o.name.to_string(), d.to_string());
            }
        }
        let mut it = argv.iter().peekable();
        while let Some(raw) = it.next() {
            if raw == "--help" || raw == "-h" {
                bail!("{}", self.usage());
            }
            if let Some(body) = raw.strip_prefix("--") {
                let (key, inline_val) = match body.split_once('=') {
                    Some((k, v)) => (k, Some(v.to_string())),
                    None => (body, None),
                };
                let opt = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| anyhow!("unknown option --{key}\n\n{}", self.usage()))?;
                if opt.takes_value {
                    let val = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| anyhow!("--{key} requires a value"))?
                            .clone(),
                    };
                    args.values.insert(key.to_string(), val);
                } else {
                    if inline_val.is_some() {
                        bail!("--{key} does not take a value");
                    }
                    args.flags.push(key.to_string());
                }
            } else {
                args.positional.push(raw.clone());
            }
        }
        Ok(args)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("t", "test")
            .opt("name", "a name")
            .opt_default("steps", "step count", "100")
            .flag("verbose", "talk more")
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_values_flags_positional() {
        let a = cmd().parse(&sv(&["--name", "x", "--verbose", "pos1", "--steps=7"])).unwrap();
        assert_eq!(a.get("name"), Some("x"));
        assert_eq!(a.parse_num::<u32>("steps", 0).unwrap(), 7);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn defaults_apply() {
        let a = cmd().parse(&sv(&[])).unwrap();
        assert_eq!(a.get("steps"), Some("100"));
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn unknown_option_errors() {
        assert!(cmd().parse(&sv(&["--nope"])).is_err());
    }

    #[test]
    fn missing_value_errors() {
        assert!(cmd().parse(&sv(&["--name"])).is_err());
    }

    #[test]
    fn req_and_parse_num_errors() {
        let a = cmd().parse(&sv(&[])).unwrap();
        assert!(a.req("name").is_err());
        let b = cmd().parse(&sv(&["--steps", "abc"])).unwrap();
        assert!(b.parse_num::<u32>("steps", 0).is_err());
    }
}
