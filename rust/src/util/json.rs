//! Minimal JSON parser + writer.
//!
//! This build environment is fully offline and `serde_json` is not among the
//! vendored crates, so the manifest/metrics interchange uses this ~400-line
//! implementation instead. It supports the complete JSON grammar (objects,
//! arrays, strings with escapes incl. `\uXXXX`, numbers, booleans, null);
//! object key order is preserved (the artifact manifest is order-sensitive).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value. Objects keep insertion order via a Vec of pairs
/// (the manifest's `inputs`/`outputs` arrays are positional contracts).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing characters at offset {}", p.i);
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// `get` that errors with the key name — manifest parsing wants loud
    /// failures, not silent defaults.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_i64(&self) -> Result<i64> {
        let n = self.as_f64()?;
        if n.fract() != 0.0 {
            bail!("expected integer, got {n}");
        }
        Ok(n as i64)
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_i64()?;
        if n < 0 {
            bail!("expected non-negative integer, got {n}");
        }
        Ok(n as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&[(String, Json)]> {
        match self {
            Json::Obj(o) => Ok(o),
            _ => bail!("expected object, got {self:?}"),
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// Object as a map for random access (keys assumed unique).
    pub fn to_map(&self) -> Result<BTreeMap<&str, &Json>> {
        Ok(self.as_obj()?.iter().map(|(k, v)| (k.as_str(), v)).collect())
    }

    // -- writer --------------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let (nl, pad, pad_in) = match indent {
            Some(w) => ("\n", " ".repeat(w * depth), " ".repeat(w * (depth + 1))),
            None => ("", String::new(), String::new()),
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_number(out, *n),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    v.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push(']');
            }
            Json::Obj(o) => {
                if o.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push('}');
            }
        }
    }
}

/// Convenience constructors for building values to serialize.
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Self {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// `obj![("k", v), ...]` helper.
#[macro_export]
macro_rules! json_obj {
    ($(($k:expr, $v:expr)),* $(,)?) => {
        $crate::util::json::Json::Obj(vec![ $(($k.to_string(), $v.into())),* ])
    };
}

fn write_number(out: &mut String, n: f64) {
    if n.fract() == 0.0 && n.abs() < 9e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------------------------------------------------------------------

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at offset {}, got {:?}", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at offset {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected character {:?} at offset {}", c as char, self.i),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut kv = Vec::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(kv));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            kv.push((k, v));
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(kv));
                }
                c => bail!("expected ',' or '}}' at offset {}, got {:?}", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                c => bail!("expected ',' or ']' at offset {}, got {:?}", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            // surrogate pair handling
                            if (0xD800..0xDC00).contains(&cp) {
                                self.eat(b'\\')?;
                                self.eat(b'u')?;
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    bail!("invalid low surrogate");
                                }
                                let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                s.push(char::from_u32(c).ok_or_else(|| anyhow!("bad codepoint"))?);
                            } else {
                                s.push(char::from_u32(cp).ok_or_else(|| anyhow!("bad codepoint"))?);
                            }
                        }
                        e => bail!("invalid escape \\{:?}", e as char),
                    }
                }
                c if c < 0x20 => bail!("raw control character in string"),
                c => {
                    // Re-decode UTF-8 multibyte sequences from the raw bytes.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = utf8_len(c)?;
                        let end = start + len;
                        if end > self.b.len() {
                            bail!("truncated UTF-8 sequence");
                        }
                        let chunk = std::str::from_utf8(&self.b[start..end])
                            .map_err(|e| anyhow!("invalid UTF-8 in string: {e}"))?;
                        s.push_str(chunk);
                        self.i = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        if self.i + 4 > self.b.len() {
            bail!("truncated \\u escape");
        }
        let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
        self.i += 4;
        u32::from_str_radix(hex, 16).map_err(|e| anyhow!("bad \\u escape: {e}"))
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|e| anyhow!("bad number {text:?}: {e}"))?))
    }
}

fn utf8_len(first: u8) -> Result<usize> {
    match first {
        0xC0..=0xDF => Ok(2),
        0xE0..=0xEF => Ok(3),
        0xF0..=0xF7 => Ok(4),
        _ => bail!("invalid UTF-8 lead byte {first:#x}"),
    }
}

// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": null, "d": true}, "s": "x\ny"}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("b").unwrap().get("d").unwrap().as_bool().unwrap(), true);
    }

    #[test]
    fn preserves_key_order() {
        let v = Json::parse(r#"{"z": 1, "a": 2, "m": 3}"#).unwrap();
        let keys: Vec<_> = v.as_obj().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é😀");
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse("\"héllo — 😀\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo — 😀");
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("01x").is_err());
        assert!(Json::parse("\"abc").is_err());
        assert!(Json::parse("{\"a\":1} x").is_err());
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("42").unwrap().as_i64().unwrap(), 42);
        assert_eq!(Json::parse("-1.5e3").unwrap().as_f64().unwrap(), -1500.0);
        assert!(Json::parse("1.5").unwrap().as_i64().is_err());
        assert!(Json::parse("-1").unwrap().as_usize().is_err());
    }

    #[test]
    fn pretty_print_parses_back() {
        let v = json_obj![("name", "x"), ("dims", vec![1usize, 2, 3])];
        let pretty = v.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }
}
