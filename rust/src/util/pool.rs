//! Scoped worker pool for the parallel kernel layer.
//!
//! Every hot loop in the native stack — the `spectral::matrix` matmuls, the
//! head-parallel attention kernels in `train::blocks`, the AdamW update, the
//! per-factor QR retraction fan-out, and the serving engine's batched
//! decode/prefill — parallelizes through the two primitives here. The pool
//! is `std::thread::scope`-based: no persistent worker threads, no channels,
//! no work stealing — a call sites fans out, joins, and returns, so the
//! borrow checker sees every shard end before the caller continues.
//!
//! # Determinism contract
//!
//! Work is sharded by **disjoint output rows** ([`par_rows`]) or disjoint
//! task indices ([`par_tasks`]): every output element is produced by exactly
//! one worker running the *same serial kernel over the same inputs in the
//! same order* as the single-threaded path. No partial sums are combined
//! across workers, so results are **bit-identical at any thread count** —
//! `--threads 1` vs `--threads 64` produce the same f32s, training runs
//! resume bit-for-bit regardless of the machine, and the determinism tests
//! in `tests/parallel_determinism.rs` pin this invariant.
//!
//! The canonical serial kernel under this contract is the cache-blocked
//! SIMD microkernel layer (`spectral::microkernel`): each output element's
//! accumulation order is fixed by the shared-dimension length alone —
//! register tiling, panel packing, shard boundaries and the AVX2-vs-scalar
//! dispatch all preserve it, because the fused-multiply-add lane ops are
//! exactly specified by IEEE-754 on both paths. A shard starting at any
//! `first_row` therefore reproduces the exact bits of the full serial run's
//! rows, which is what makes the row-sharding here sufficient for the
//! contract (no constraint on *how many* rows land in a shard).
//!
//! # Sizing
//!
//! Thread count resolves as: [`set_threads`] (the `--threads` flag /
//! `[runtime] threads` TOML key) > the `SCT_THREADS` env var > all available
//! cores. Callers gate fan-out on [`parallel_worthwhile`] with a
//! per-kernel work threshold, falling back to the serial kernel for small
//! shapes where scoped-spawn overhead (tens of µs) would dominate. The
//! matmul threshold is itself a tunable ([`par_threshold`]:
//! [`set_par_threshold`] / `[runtime] par_threshold` TOML key >
//! `SCT_PAR_THRESHOLD` env var > [`DEFAULT_PAR_THRESHOLD`]) — the blocked
//! microkernels retire FLOPs ~4× faster than the old scalar loops, moving
//! the break-even shape upward; like the thread count it is purely a
//! throughput knob, never a numerics one.
//!
//! # Observability
//!
//! The pool publishes `sct_pool_*` series through [`crate::obs`]: the
//! resolved `sct_pool_threads` gauge, parallel-vs-serial gate decisions
//! (`sct_pool_decide_parallel_total` / `sct_pool_decide_serial_total`),
//! fan-outs and spawned shards (`sct_pool_fanouts_total` /
//! `sct_pool_tasks_total`), shard sizes (`sct_pool_shard_rows`), and
//! per-worker busy time (`sct_pool_worker_busy_ms`). The serial fast paths
//! record nothing beyond the gate counter, so single-threaded kernels stay
//! uninstrumented.

use crate::obs::{self, prof, Counter, Gauge, Histogram};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

struct PoolMetrics {
    decide_parallel: Counter,
    decide_serial: Counter,
    fanouts: Counter,
    tasks: Counter,
    shard_rows: Histogram,
    worker_busy_ms: Histogram,
    threads: Gauge,
}

fn pool_metrics() -> &'static PoolMetrics {
    static METRICS: OnceLock<PoolMetrics> = OnceLock::new();
    METRICS.get_or_init(|| {
        let r = obs::registry();
        PoolMetrics {
            decide_parallel: r.counter(
                "sct_pool_decide_parallel_total",
                "Kernel fan-out gate decisions that took the parallel path",
            ),
            decide_serial: r.counter(
                "sct_pool_decide_serial_total",
                "Kernel fan-out gate decisions that stayed serial",
            ),
            fanouts: r.counter(
                "sct_pool_fanouts_total",
                "Scoped fan-outs (one spawn/join cycle across the pool)",
            ),
            tasks: r.counter(
                "sct_pool_tasks_total",
                "Worker shards spawned across all fan-outs",
            ),
            shard_rows: r.histogram(
                "sct_pool_shard_rows",
                "Work items per spawned shard (output rows for par_rows, task indices for par_tasks)",
            ),
            worker_busy_ms: r.histogram(
                "sct_pool_worker_busy_ms",
                "Per-worker busy time inside a fan-out, milliseconds",
            ),
            threads: r.gauge("sct_pool_threads", "Resolved worker pool size"),
        }
    })
}

/// Upper bound on the pool size (fan-out beyond this stops paying on any
/// hardware this targets).
pub const MAX_THREADS: usize = 64;

/// 0 = unresolved; first reader resolves env/cores and caches the result.
static THREADS: AtomicUsize = AtomicUsize::new(0);

/// Default matmul fan-out threshold (inner-loop multiply-accumulates).
/// Re-calibrated for the blocked SIMD microkernels: the old scalar loops
/// broke even near 2^17 MACs, but the GEBP kernels retire FLOPs ~4× faster,
/// so scoped-spawn overhead (tens of µs) isn't amortized until ~2^19.
pub const DEFAULT_PAR_THRESHOLD: usize = 1 << 19;

/// 0 = unresolved; first reader resolves override/env and caches.
static PAR_THRESHOLD: AtomicUsize = AtomicUsize::new(0);

/// Test hook: when set, [`parallel_worthwhile`] ignores work thresholds so
/// determinism tests exercise the parallel kernels on tiny shapes.
static FORCE_PARALLEL: AtomicBool = AtomicBool::new(false);

fn resolve_default() -> usize {
    if let Ok(s) = std::env::var("SCT_THREADS") {
        if let Ok(n) = s.trim().parse::<usize>() {
            if n >= 1 {
                return n.min(MAX_THREADS);
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(MAX_THREADS)
}

/// The pool's thread count. Resolution order: [`set_threads`] override >
/// `SCT_THREADS` env var > available parallelism. Always >= 1.
pub fn threads() -> usize {
    let t = THREADS.load(Ordering::Relaxed);
    if t != 0 {
        return t;
    }
    let n = resolve_default();
    // Benign race: concurrent first readers resolve the same value.
    let _ = THREADS.compare_exchange(0, n, Ordering::Relaxed, Ordering::Relaxed);
    let t = THREADS.load(Ordering::Relaxed);
    pool_metrics().threads.set(t as f64);
    t
}

/// Override the pool size (CLI `--threads` / `[runtime] threads`). Clamped
/// to `1..=MAX_THREADS`. Safe to change at any time: results are
/// bit-identical at every setting, so this is purely a throughput knob.
pub fn set_threads(n: usize) {
    let n = n.clamp(1, MAX_THREADS);
    THREADS.store(n, Ordering::Relaxed);
    pool_metrics().threads.set(n as f64);
}

/// Test hook (see `tests/parallel_determinism.rs`): bypass the work
/// thresholds so tiny shapes take the parallel code paths.
pub fn set_force_parallel(on: bool) {
    FORCE_PARALLEL.store(on, Ordering::Relaxed);
}

/// The matmul fan-out threshold (inner-loop multiply-accumulates below
/// which the matmuls stay serial). Resolution order: [`set_par_threshold`]
/// override (`[runtime] par_threshold`) > `SCT_PAR_THRESHOLD` env var >
/// [`DEFAULT_PAR_THRESHOLD`]. Always >= 1.
pub fn par_threshold() -> usize {
    let t = PAR_THRESHOLD.load(Ordering::Relaxed);
    if t != 0 {
        return t;
    }
    let n = resolve_par_threshold_default();
    // Benign race: concurrent first readers resolve the same value.
    let _ = PAR_THRESHOLD.compare_exchange(0, n, Ordering::Relaxed, Ordering::Relaxed);
    PAR_THRESHOLD.load(Ordering::Relaxed)
}

fn resolve_par_threshold_default() -> usize {
    if let Ok(s) = std::env::var("SCT_PAR_THRESHOLD") {
        if let Ok(n) = s.trim().parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    DEFAULT_PAR_THRESHOLD
}

/// Override the matmul fan-out threshold (`[runtime] par_threshold`).
/// Clamped to >= 1. Purely a throughput knob: results are bit-identical
/// whichever dispatch arm a shape lands on.
pub fn set_par_threshold(n: usize) {
    PAR_THRESHOLD.store(n.max(1), Ordering::Relaxed);
}

/// Should a kernel with `work` inner-loop operations fan out? False when the
/// pool has one thread or the shape is too small to amortize scoped-spawn
/// overhead (unless the test hook forces it).
pub fn parallel_worthwhile(work: usize, threshold: usize) -> bool {
    let go = threads() > 1 && (work >= threshold || FORCE_PARALLEL.load(Ordering::Relaxed));
    let m = pool_metrics();
    if go {
        m.decide_parallel.inc();
    } else {
        m.decide_serial.inc();
    }
    go
}

/// Chunk length that deals `n` work items evenly across the pool — the
/// shared sizing policy for kernels that shard their buffers themselves
/// (the AdamW four-slice update, the trainer's per-factor retraction
/// fan-out). Always >= 1 so `chunks_mut(chunk_len(n))` is well-formed.
pub fn chunk_len(n: usize) -> usize {
    n.div_ceil(threads().min(n).max(1)).max(1)
}

/// Shard a `(rows x row_len)` row-major buffer into contiguous row blocks,
/// one per worker, and run `body(first_row, block)` on each. Each output row
/// lives in exactly one block, and `body` is the same kernel the serial path
/// runs, so results are bit-identical at any thread count.
pub fn par_rows<F>(out: &mut [f32], row_len: usize, body: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    assert!(row_len > 0 && out.len() % row_len == 0, "out must be rows x row_len");
    let rows = out.len() / row_len;
    let t = threads().min(rows).max(1);
    if t <= 1 {
        body(0, out);
        return;
    }
    let chunk_rows = rows.div_ceil(t);
    let m = pool_metrics();
    m.fanouts.inc();
    // Workers inherit the spawning thread's profiler path, so frames they
    // record attach under the scope that fanned out (obs::prof contract).
    let prof_ctx = prof::fork_ctx();
    std::thread::scope(|s| {
        for (ti, block) in out.chunks_mut(chunk_rows * row_len).enumerate() {
            m.tasks.inc();
            m.shard_rows.record((block.len() / row_len) as f64);
            let body = &body;
            let prof_ctx = &prof_ctx;
            s.spawn(move || {
                let _prof = prof::attach(prof_ctx);
                let t0 = Instant::now();
                body(ti * chunk_rows, block);
                m.worker_busy_ms.record(t0.elapsed().as_secs_f64() * 1e3);
            });
        }
    });
}

/// Run `body(i)` for every `i in 0..n_tasks`, tasks dealt to workers in
/// contiguous index ranges. For kernels whose disjoint writes are strided
/// rather than row-contiguous (per-head attention stripes), pair with
/// [`SendPtr`]; the caller guarantees tasks write disjoint memory.
pub fn par_tasks<F>(n_tasks: usize, body: F)
where
    F: Fn(usize) + Sync,
{
    let t = threads().min(n_tasks).max(1);
    if t <= 1 {
        for i in 0..n_tasks {
            body(i);
        }
        return;
    }
    let chunk = n_tasks.div_ceil(t);
    let m = pool_metrics();
    m.fanouts.inc();
    let prof_ctx = prof::fork_ctx();
    std::thread::scope(|s| {
        for ti in 0..t {
            let (lo, hi) = (ti * chunk, ((ti + 1) * chunk).min(n_tasks));
            if lo >= hi {
                break;
            }
            m.tasks.inc();
            m.shard_rows.record((hi - lo) as f64);
            let body = &body;
            let prof_ctx = &prof_ctx;
            s.spawn(move || {
                let _prof = prof::attach(prof_ctx);
                let t0 = Instant::now();
                for i in lo..hi {
                    body(i);
                }
                m.worker_busy_ms.record(t0.elapsed().as_secs_f64() * 1e3);
            });
        }
    });
}

/// Raw-pointer Send/Sync shim for provably disjoint writes from
/// [`par_tasks`] workers (the same idiom as `spectral::qr`'s row-sharded
/// panels). Callers create short-lived `&mut` sub-slices with
/// `std::slice::from_raw_parts_mut(ptr.0.add(offset), len)`; soundness rests
/// on every concurrent task touching a distinct `offset..offset+len` range
/// within the original borrow.
pub struct SendPtr(pub *mut f32);

unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

impl SendPtr {
    pub fn new(data: &mut [f32]) -> SendPtr {
        SendPtr(data.as_mut_ptr())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn threads_is_at_least_one() {
        assert!(threads() >= 1);
    }

    #[test]
    fn par_rows_covers_every_row_once() {
        // 37 rows of length 5, written with the row index: every element
        // must be visited exactly once regardless of sharding.
        let mut out = vec![0.0f32; 37 * 5];
        par_rows(&mut out, 5, |r0, block| {
            for (bi, row) in block.chunks_mut(5).enumerate() {
                for v in row.iter_mut() {
                    *v += (r0 + bi) as f32 + 1.0;
                }
            }
        });
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, (i / 5) as f32 + 1.0, "element {i}");
        }
    }

    #[test]
    fn par_tasks_runs_each_task_once() {
        use std::sync::atomic::AtomicU32;
        let counts: Vec<AtomicU32> = (0..23).map(|_| AtomicU32::new(0)).collect();
        par_tasks(23, |i| {
            counts[i].fetch_add(1, Ordering::Relaxed);
        });
        for (i, c) in counts.iter().enumerate() {
            assert_eq!(c.load(Ordering::Relaxed), 1, "task {i}");
        }
    }

    #[test]
    fn par_rows_handles_fewer_rows_than_threads() {
        let mut out = vec![0.0f32; 2 * 3];
        par_rows(&mut out, 3, |r0, block| {
            for (bi, row) in block.chunks_mut(3).enumerate() {
                row.fill((r0 + bi) as f32);
            }
        });
        assert_eq!(out, vec![0.0, 0.0, 0.0, 1.0, 1.0, 1.0]);
    }

    #[test]
    fn set_threads_clamps() {
        let before = threads();
        set_threads(0);
        assert_eq!(threads(), 1);
        set_threads(1_000_000);
        assert_eq!(threads(), MAX_THREADS);
        set_threads(before);
    }

    #[test]
    fn par_threshold_resolves_and_overrides() {
        let before = par_threshold();
        assert!(before >= 1);
        set_par_threshold(12345);
        assert_eq!(par_threshold(), 12345);
        set_par_threshold(0); // clamped up, never disables the gate entirely
        assert_eq!(par_threshold(), 1);
        set_par_threshold(before);
    }
}
