//! Deterministic PRNG (SplitMix64 + xoshiro256**) and sampling helpers.
//!
//! Everything stochastic in the rust layer — synthetic corpus generation,
//! property-test case generation, shuffling — draws from this generator so
//! runs are reproducible from a single seed (the paper's experiments are
//! seed-controlled; ours are too).

/// xoshiro256** seeded via SplitMix64. Passes BigCrush; more than enough for
/// data generation and property tests.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the xoshiro state.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        // Lemire's nearly-divisionless bounded sampling.
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform in [lo, hi] inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Zipf(alpha) over {0, .., n-1} by inverse-CDF on precomputed weights is
    /// overkill here; rejection-free approximation via the power of f64()
    /// suffices for corpus shaping.
    pub fn zipf(&mut self, n: usize, alpha: f64) -> usize {
        // Draw continuous Pareto-ish value and clamp; produces the heavy
        // head/long tail we want for a natural-language-like token histogram.
        let u = self.f64().max(1e-12);
        let x = u.powf(-1.0 / alpha) - 1.0;
        let idx = x * n as f64 / (n as f64).powf(1.0 / alpha);
        (idx as usize).min(n - 1)
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Independent child generator (for parallel shards).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets should be hit");
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.normal();
            sum += v;
            sq += v * v;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn zipf_is_head_heavy() {
        let mut r = Rng::new(11);
        let mut counts = vec![0usize; 100];
        for _ in 0..10_000 {
            counts[r.zipf(100, 1.2)] += 1;
        }
        let head: usize = counts[..10].iter().sum();
        let tail: usize = counts[90..].iter().sum();
        assert!(head > 5 * tail.max(1), "head {head} tail {tail}");
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>(), "astronomically unlikely");
    }
}
