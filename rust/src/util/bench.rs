//! Minimal benchmarking harness (criterion is not vendored; `harness = false`
//! bench targets link this instead).
//!
//! Method: warmup runs, then timed iterations until both a minimum iteration
//! count and a minimum wall-time are reached; reports min/median/mean/p95 and
//! a robust MAD-based spread, criterion-style. All bench binaries print a
//! shared table format so EXPERIMENTS.md can quote them directly.

use std::time::{Duration, Instant};

/// One measured sample set, in nanoseconds.
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub samples_ns: Vec<f64>,
}

impl Stats {
    fn sorted(&self) -> Vec<f64> {
        let mut s = self.samples_ns.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        s
    }

    pub fn min(&self) -> f64 {
        self.sorted()[0]
    }

    pub fn median(&self) -> f64 {
        let s = self.sorted();
        let n = s.len();
        if n % 2 == 1 {
            s[n / 2]
        } else {
            0.5 * (s[n / 2 - 1] + s[n / 2])
        }
    }

    pub fn mean(&self) -> f64 {
        self.samples_ns.iter().sum::<f64>() / self.samples_ns.len() as f64
    }

    pub fn p95(&self) -> f64 {
        let s = self.sorted();
        s[((s.len() as f64 - 1.0) * 0.95).round() as usize]
    }

    /// Median absolute deviation — robust spread estimate.
    pub fn mad(&self) -> f64 {
        let med = self.median();
        let mut dev: Vec<f64> = self.samples_ns.iter().map(|x| (x - med).abs()).collect();
        dev.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = dev.len();
        if n % 2 == 1 {
            dev[n / 2]
        } else {
            0.5 * (dev[n / 2 - 1] + dev[n / 2])
        }
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Bench runner with a uniform output format.
pub struct Bench {
    pub min_iters: usize,
    pub max_iters: usize,
    pub min_time: Duration,
    pub warmup: usize,
    results: Vec<Stats>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            min_iters: 10,
            max_iters: 1000,
            min_time: Duration::from_millis(300),
            warmup: 3,
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn new() -> Self {
        Self::default()
    }

    /// Quick-mode knobs for expensive end-to-end cases.
    pub fn heavy() -> Self {
        Bench { min_iters: 5, max_iters: 30, min_time: Duration::from_millis(200), warmup: 1, ..Self::default() }
    }

    /// Time `f`, which performs ONE iteration of the measured operation.
    pub fn run<F: FnMut()>(&mut self, name: &str, mut f: F) -> &Stats {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Vec::new();
        let start = Instant::now();
        while samples.len() < self.min_iters
            || (start.elapsed() < self.min_time && samples.len() < self.max_iters)
        {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_nanos() as f64);
        }
        let stats = Stats { name: name.to_string(), samples_ns: samples };
        println!(
            "{:<44} {:>12} med {:>12} mean {:>12} p95 (±{} , n={})",
            stats.name,
            fmt_ns(stats.median()),
            fmt_ns(stats.mean()),
            fmt_ns(stats.p95()),
            fmt_ns(stats.mad()),
            stats.samples_ns.len(),
        );
        self.results.push(stats);
        self.results.last().unwrap()
    }

    /// Record an externally measured sample set (e.g. per-phase timers inside
    /// a training step) under the same reporting format.
    pub fn record(&mut self, name: &str, samples_ns: Vec<f64>) -> &Stats {
        let stats = Stats { name: name.to_string(), samples_ns };
        println!(
            "{:<44} {:>12} med (n={})",
            stats.name,
            fmt_ns(stats.median()),
            stats.samples_ns.len()
        );
        self.results.push(stats);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[Stats] {
        &self.results
    }
}

/// Print a markdown table header used by the table-reproduction benches.
pub fn table_header(title: &str, cols: &[&str]) {
    println!("\n### {title}\n");
    println!("| {} |", cols.join(" | "));
    println!("|{}|", cols.iter().map(|_| "---").collect::<Vec<_>>().join("|"));
}

pub fn table_row(cells: &[String]) {
    println!("| {} |", cells.join(" | "));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basics() {
        let s = Stats { name: "t".into(), samples_ns: vec![1.0, 2.0, 3.0, 4.0, 100.0] };
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.median(), 3.0);
        assert!(s.mean() > 3.0);
        assert_eq!(s.p95(), 100.0);
        assert_eq!(s.mad(), 1.0);
    }

    #[test]
    fn runner_runs_minimum_iterations() {
        let mut b = Bench { min_iters: 7, max_iters: 8, min_time: Duration::ZERO, warmup: 0, ..Bench::default() };
        let mut count = 0;
        b.run("noop", || count += 1);
        assert!(count >= 7);
    }

    #[test]
    fn fmt_scales() {
        assert!(fmt_ns(5.0).contains("ns"));
        assert!(fmt_ns(5.0e3).contains("µs"));
        assert!(fmt_ns(5.0e6).contains("ms"));
        assert!(fmt_ns(5.0e9).contains(" s"));
    }
}
