//! `sct` — launcher CLI for Spectral Compact Training.
//!
//! Subcommands map one-to-one onto the paper's experiments; see DESIGN.md §3.

fn main() -> anyhow::Result<()> {
    sct::coordinator::cli::run()
}
