//! Shared decoder blocks: the forward primitives the serving engine and the
//! native trainer both execute, plus their reverse-mode adjoints.
//!
//! Every op the decoder is made of lives here exactly once — RMSNorm, the
//! RoPE rotation tables, SiLU, causal softmax attention, cross-entropy —
//! so the training forward and the serving forward cannot drift: `serve`
//! calls the forward halves on its KV-cached hot path, `train::decoder`
//! calls the same functions plus the `*_bwd` adjoints defined next to them.
//! Each adjoint is finite-difference checked in the tests below.
//!
//! Numerics note: [`causal_attention_fwd`] mirrors [`attend_row`]'s exact
//! arithmetic (same `dot`, same running max, same `w * inv` weights, same
//! accumulation order), so a full-sequence training forward is bit-identical
//! to the incremental KV decode the serve tests pin against it. The `dot` /
//! `axpy` primitives are `spectral::microkernel`'s canonical fused SIMD
//! kernels (AVX2+FMA with a bit-identical fused-scalar fallback), so
//! [`attend_head_row`]'s score and value loops run on the same microkernel
//! layer as the matmuls — one set of canonical accumulation orders across
//! the whole stack.

use crate::obs::prof;
use crate::spectral::matrix::{axpy, dot, Matrix};
use crate::util::pool;

pub const RMS_EPS: f32 = 1e-6;

/// Attention work (score/value multiply-accumulates, roughly
/// `bsz * n_heads * ctx * head_dim` summed over rows) below which the
/// batched kernels stay serial. Shared with `serve::engine`'s incremental
/// decode so train and serve cross over at the same shapes.
pub(crate) const ATTN_PAR_WORK: usize = 1 << 15;

// ---------------------------------------------------------------------------
// RMSNorm
// ---------------------------------------------------------------------------

/// Per-row `1/sqrt(mean(x^2) + eps)` factors cached by [`rmsnorm_fwd`] for
/// the backward pass.
pub struct RmsCache {
    pub inv: Vec<f32>,
}

/// Row-wise RMSNorm with gain: `y = x * inv_rms(x) * gain`, plus the cache
/// the adjoint needs.
pub fn rmsnorm_fwd(x: &Matrix, gain: &[f32]) -> (Matrix, RmsCache) {
    debug_assert_eq!(x.cols, gain.len());
    let mut out = Matrix::zeros(x.rows, x.cols);
    let mut invs = Vec::with_capacity(x.rows);
    for r in 0..x.rows {
        let row = x.row(r);
        let ms = row.iter().map(|v| v * v).sum::<f32>() / row.len() as f32;
        let inv = 1.0 / (ms + RMS_EPS).sqrt();
        invs.push(inv);
        for (o, (&v, &g)) in out.row_mut(r).iter_mut().zip(row.iter().zip(gain)) {
            *o = v * inv * g;
        }
    }
    (out, RmsCache { inv: invs })
}

/// Forward-only RMSNorm — the serving path (cache discarded).
pub fn rmsnorm(x: &Matrix, gain: &[f32]) -> Matrix {
    rmsnorm_fwd(x, gain).0
}

/// Adjoint of [`rmsnorm_fwd`]: given dL/dy, produce (dL/dx, dL/dgain).
///
/// With `inv = (mean(x^2) + eps)^(-1/2)`:
/// `dx_j = dy_j g_j inv - x_j inv^3 / d * sum_i(dy_i g_i x_i)`,
/// `dg_i = sum_rows dy_i x_i inv`.
pub fn rmsnorm_bwd(x: &Matrix, gain: &[f32], cache: &RmsCache, dy: &Matrix) -> (Matrix, Vec<f32>) {
    debug_assert_eq!((x.rows, x.cols), (dy.rows, dy.cols));
    let d = x.cols as f32;
    let mut dx = Matrix::zeros(x.rows, x.cols);
    let mut dgain = vec![0.0f32; gain.len()];
    for r in 0..x.rows {
        let inv = cache.inv[r];
        let xr = x.row(r);
        let dyr = dy.row(r);
        let mut s = 0.0f32;
        for ((&dyi, &gi), &xi) in dyr.iter().zip(gain).zip(xr) {
            s += dyi * gi * xi;
        }
        let coef = inv * inv * inv * s / d;
        for (j, dxj) in dx.row_mut(r).iter_mut().enumerate() {
            *dxj = dyr[j] * gain[j] * inv - xr[j] * coef;
            dgain[j] += dyr[j] * xr[j] * inv;
        }
    }
    (dx, dgain)
}

// ---------------------------------------------------------------------------
// SiLU
// ---------------------------------------------------------------------------

#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// d silu / dx = sigma(x) * (1 + x * (1 - sigma(x))).
#[inline]
pub fn dsilu(x: f32) -> f32 {
    let sig = 1.0 / (1.0 + (-x).exp());
    sig * (1.0 + x * (1.0 - sig))
}

// ---------------------------------------------------------------------------
// RoPE
// ---------------------------------------------------------------------------

/// Precomputed rotary-position tables, applied head-major: within each head
/// the pair `(row[j], row[j + head_dim/2])` rotates by the position's angle.
#[derive(Clone)]
pub struct Rope {
    cos: Matrix,
    sin: Matrix,
    head_dim: usize,
}

impl Rope {
    pub fn new(max_seq: usize, head_dim: usize) -> Rope {
        assert!(head_dim % 2 == 0, "RoPE needs an even head_dim");
        let half = head_dim / 2;
        let mut cos = Matrix::zeros(max_seq, half);
        let mut sin = Matrix::zeros(max_seq, half);
        for pos in 0..max_seq {
            for j in 0..half {
                let inv = 1.0f64 / 10000f64.powf(j as f64 / half as f64);
                let ang = pos as f64 * inv;
                cos[(pos, j)] = ang.cos() as f32;
                sin[(pos, j)] = ang.sin() as f32;
            }
        }
        Rope { cos, sin, head_dim }
    }

    pub fn max_seq(&self) -> usize {
        self.cos.rows
    }

    /// Rotate a (head-major) Q/K row in place with the tables at `pos`.
    pub fn apply_row(&self, row: &mut [f32], pos: usize) {
        let hd = self.head_dim;
        let half = hd / 2;
        debug_assert_eq!(row.len() % hd, 0);
        let cos = self.cos.row(pos);
        let sin = self.sin.row(pos);
        for h in 0..row.len() / hd {
            let base = h * hd;
            for j in 0..half {
                let a = row[base + j];
                let b = row[base + half + j];
                row[base + j] = a * cos[j] - b * sin[j];
                row[base + half + j] = a * sin[j] + b * cos[j];
            }
        }
    }

    /// Inverse rotation (angle negated). The rotation is orthogonal, so this
    /// is also its transpose — i.e. the adjoint the backward pass applies to
    /// gradients flowing through [`Rope::apply_row`].
    pub fn apply_row_inv(&self, row: &mut [f32], pos: usize) {
        let hd = self.head_dim;
        let half = hd / 2;
        debug_assert_eq!(row.len() % hd, 0);
        let cos = self.cos.row(pos);
        let sin = self.sin.row(pos);
        for h in 0..row.len() / hd {
            let base = h * hd;
            for j in 0..half {
                let a = row[base + j];
                let b = row[base + half + j];
                row[base + j] = a * cos[j] + b * sin[j];
                row[base + half + j] = b * cos[j] - a * sin[j];
            }
        }
    }
}

// ---------------------------------------------------------------------------
// causal softmax attention
// ---------------------------------------------------------------------------

/// One head's attention for ONE query row over `n_ctx` context rows stored
/// `[pos][d_model]`-major: scores via the SIMD [`dot`], running max,
/// exp-normalize, then `w * (1/denom)`-weighted value accumulation through
/// the fused [`axpy`] — THE attention arithmetic, shared by [`attend_row`]
/// (serving decode), [`causal_attention_fwd`] (training) and the
/// head-parallel batched variants, so every path is bit-identical by
/// construction. `scores`
/// (length >= n_ctx) receives the normalized softmax weights; `oh`
/// (head_dim, zero-initialized) accumulates the head's output.
#[allow(clippy::too_many_arguments)]
#[inline]
pub fn attend_head_row(
    qh: &[f32],
    krows: &[f32],
    vrows: &[f32],
    hb: usize,
    hd: usize,
    d_model: usize,
    n_ctx: usize,
    scale: f32,
    scores: &mut [f32],
    oh: &mut [f32],
) {
    let scores = &mut scores[..n_ctx];
    let mut mx = f32::NEG_INFINITY;
    for (t, sc) in scores.iter_mut().enumerate() {
        *sc = dot(qh, &krows[t * d_model + hb..t * d_model + hb + hd]) * scale;
        mx = mx.max(*sc);
    }
    let mut denom = 0.0f32;
    for sc in scores.iter_mut() {
        *sc = (*sc - mx).exp();
        denom += *sc;
    }
    let inv = 1.0 / denom;
    for (t, sc) in scores.iter_mut().enumerate() {
        *sc *= inv;
        axpy(*sc, &vrows[t * d_model + hb..t * d_model + hb + hd], oh);
    }
}

/// Causal softmax attention for one query row over `n_ctx` cached K/V rows
/// (contiguous `[pos][d_model]` layout), writing the concatenated head
/// outputs into `out` (d_model, zero-initialized). The serving engine's
/// incremental decode step — one query against the KV cache (which runs
/// the heads through [`attend_head_row`] in parallel; this serial wrapper
/// is the reference and the small-shape path).
pub fn attend_row(
    qrow: &[f32],
    krows: &[f32],
    vrows: &[f32],
    n_ctx: usize,
    n_heads: usize,
    d_model: usize,
    out: &mut [f32],
) {
    let hd = d_model / n_heads;
    let scale = 1.0 / (hd as f32).sqrt();
    let mut scores = vec![0.0f32; n_ctx];
    for h in 0..n_heads {
        let hb = h * hd;
        attend_head_row(
            &qrow[hb..hb + hd],
            krows,
            vrows,
            hb,
            hd,
            d_model,
            n_ctx,
            scale,
            &mut scores,
            &mut out[hb..hb + hd],
        );
    }
}

/// One (sequence, head) pair of the full-sequence causal forward: row `i`
/// attends over rows `0..=i` through [`attend_head_row`]; the normalized
/// softmax weights land in `probs_head` (`t_len * t_len`, `[i][j]`).
///
/// `out` is the raw base pointer of this sequence's `t_len * d_model`
/// output region: the head writes only its `hb..hb+hd` stripe of each row,
/// so concurrent heads of the same sequence never touch the same memory.
#[allow(clippy::too_many_arguments)]
fn attention_head_seq_fwd(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    hb: usize,
    hd: usize,
    d_model: usize,
    t_len: usize,
    scale: f32,
    probs_head: &mut [f32],
    out: &pool::SendPtr,
) {
    for i in 0..t_len {
        let n_ctx = i + 1;
        let qh = &q[i * d_model + hb..i * d_model + hb + hd];
        let prow = &mut probs_head[i * t_len..i * t_len + n_ctx];
        // SAFETY: stripe (row i, cols hb..hb+hd) is written by exactly this
        // (sequence, head) task — see causal_attention_fwd_batched.
        let oh = unsafe { std::slice::from_raw_parts_mut(out.0.add(i * d_model + hb), hd) };
        attend_head_row(qh, k, v, hb, hd, d_model, n_ctx, scale, prow, oh);
    }
}

/// Full-sequence causal attention for one sequence: `q`, `k`, `v` are
/// `t_len * d_model` slices of post-RoPE projections; row `i` attends over
/// rows `0..=i`. `out` (same size, zero-initialized) receives the head
/// outputs; `probs` (`n_heads * t_len * t_len`, `[h][i][j]`) caches the
/// softmax weights for [`causal_attention_bwd`].
///
/// The per-row arithmetic is exactly [`attend_row`]'s (both call
/// [`attend_head_row`]), so the training forward matches the KV decode
/// bit-for-bit. This is the `bsz == 1` case of
/// [`causal_attention_fwd_batched`], head-parallelism included.
pub fn causal_attention_fwd(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    t_len: usize,
    n_heads: usize,
    d_model: usize,
    out: &mut [f32],
    probs: &mut [f32],
) {
    causal_attention_fwd_batched(q, k, v, 1, t_len, n_heads, d_model, out, probs);
}

/// Head-parallel causal attention over `bsz` packed sequences (`q`/`k`/`v`:
/// `bsz * t_len * d_model`, sequences contiguous; `probs`:
/// `bsz * n_heads * t_len * t_len`, `[b][h][i][j]`). One pool task per
/// (sequence, head); a task owns the disjoint output stripes
/// `out[b*t_len*d + i*d + hb .. +hd]` and its contiguous `probs` block, and
/// runs the identical serial head kernel — so results are bit-identical at
/// any thread count. Small shapes run the same tasks inline.
#[allow(clippy::too_many_arguments)]
pub fn causal_attention_fwd_batched(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    bsz: usize,
    t_len: usize,
    n_heads: usize,
    d_model: usize,
    out: &mut [f32],
    probs: &mut [f32],
) {
    debug_assert_eq!(out.len(), bsz * t_len * d_model);
    debug_assert_eq!(probs.len(), bsz * n_heads * t_len * t_len);
    let hd = d_model / n_heads;
    let scale = 1.0 / (hd as f32).sqrt();
    let tasks = bsz * n_heads;
    let out_ptr = pool::SendPtr::new(out);
    let probs_ptr = pool::SendPtr::new(probs);
    let run = |task: usize| {
        let (b, h) = (task / n_heads, task % n_heads);
        let seq = b * t_len * d_model;
        let qs = &q[seq..seq + t_len * d_model];
        let ks = &k[seq..seq + t_len * d_model];
        let vs = &v[seq..seq + t_len * d_model];
        // SAFETY: the probs block of task (b, h) is contiguous and owned by
        // this task alone; out stripes are disjoint per head (see
        // attention_head_seq_fwd).
        let probs_head = unsafe {
            std::slice::from_raw_parts_mut(probs_ptr.0.add(task * t_len * t_len), t_len * t_len)
        };
        let out_seq = pool::SendPtr(unsafe { out_ptr.0.add(seq) });
        attention_head_seq_fwd(qs, ks, vs, h * hd, hd, d_model, t_len, scale, probs_head, &out_seq);
    };
    let work = bsz * n_heads * t_len * t_len * hd;
    // Causal triangle: ~work/2 (i, j) context pairs, each a score dot plus a
    // value axpy over hd lanes (2 FLOPs/lane each) => 2*work FLOPs, with a
    // K and a V stripe (8 bytes/lane) streamed per pair => 4*work bytes.
    let _prof = prof::kernel("attention_fwd", || (2.0 * work as f64, 4.0 * work as f64));
    if tasks > 1 && pool::parallel_worthwhile(work, ATTN_PAR_WORK) {
        pool::par_tasks(tasks, run);
    } else {
        for task in 0..tasks {
            run(task);
        }
    }
}

/// Adjoint of [`causal_attention_fwd`]: accumulates into `dq`, `dk`, `dv`
/// (each `t_len * d_model`, zero-initialized by the caller) from the cached
/// softmax `probs` and the output gradient `dout`. The `bsz == 1` case of
/// [`causal_attention_bwd_batched`].
#[allow(clippy::too_many_arguments)]
pub fn causal_attention_bwd(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    probs: &[f32],
    dout: &[f32],
    t_len: usize,
    n_heads: usize,
    d_model: usize,
    dq: &mut [f32],
    dk: &mut [f32],
    dv: &mut [f32],
) {
    causal_attention_bwd_batched(q, k, v, probs, dout, 1, t_len, n_heads, d_model, dq, dk, dv);
}

/// Head-parallel adjoint over `bsz` packed sequences (layouts as in
/// [`causal_attention_fwd_batched`]). One pool task per (sequence, head):
/// a task's writes into `dq`/`dk`/`dv` all land in its sequence's rows at
/// its own `hb..hb+hd` stripe — disjoint across tasks, serial within —
/// so gradients are bit-identical at any thread count.
#[allow(clippy::too_many_arguments)]
pub fn causal_attention_bwd_batched(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    probs: &[f32],
    dout: &[f32],
    bsz: usize,
    t_len: usize,
    n_heads: usize,
    d_model: usize,
    dq: &mut [f32],
    dk: &mut [f32],
    dv: &mut [f32],
) {
    debug_assert_eq!(probs.len(), bsz * n_heads * t_len * t_len);
    let hd = d_model / n_heads;
    let scale = 1.0 / (hd as f32).sqrt();
    let tasks = bsz * n_heads;
    let dq_ptr = pool::SendPtr::new(dq);
    let dk_ptr = pool::SendPtr::new(dk);
    let dv_ptr = pool::SendPtr::new(dv);
    let run = |task: usize| {
        let (b, h) = (task / n_heads, task % n_heads);
        let hb = h * hd;
        let seq = b * t_len * d_model;
        let probs_head = &probs[task * t_len * t_len..(task + 1) * t_len * t_len];
        let mut dp = vec![0.0f32; t_len];
        for i in 0..t_len {
            let n_ctx = i + 1;
            let prow = &probs_head[i * t_len..i * t_len + n_ctx];
            let doh = &dout[seq + i * d_model + hb..seq + i * d_model + hb + hd];
            // dp_j = dout_i . v_j ; softmax adjoint needs sum_j p_j dp_j.
            let mut pdp = 0.0f32;
            for (j, dpj) in dp[..n_ctx].iter_mut().enumerate() {
                *dpj = dot(doh, &v[seq + j * d_model + hb..seq + j * d_model + hb + hd]);
                pdp += *dpj * prow[j];
            }
            // SAFETY: rows of sequence b at stripe hb..hb+hd are written by
            // exactly this (sequence, head) task; dq/dk/dv are distinct
            // buffers, so the three sub-slices never alias.
            let dq_i =
                unsafe { std::slice::from_raw_parts_mut(dq_ptr.0.add(seq + i * d_model + hb), hd) };
            for (j, &pj) in prow.iter().enumerate() {
                let ds = pj * (dp[j] - pdp) * scale;
                axpy(ds, &k[seq + j * d_model + hb..seq + j * d_model + hb + hd], dq_i);
                let dk_j = unsafe {
                    std::slice::from_raw_parts_mut(dk_ptr.0.add(seq + j * d_model + hb), hd)
                };
                axpy(ds, &q[seq + i * d_model + hb..seq + i * d_model + hb + hd], dk_j);
                let dv_j = unsafe {
                    std::slice::from_raw_parts_mut(dv_ptr.0.add(seq + j * d_model + hb), hd)
                };
                axpy(pj, doh, dv_j);
            }
        }
    };
    let work = bsz * n_heads * t_len * t_len * hd;
    // Per context pair: a dp dot plus three axpys into dq/dk/dv over hd
    // lanes => ~4*work FLOPs; six stripes (q/k/v/dout reads, dq/dk/dv
    // read-modify-writes) stream ~12*work bytes.
    let _prof = prof::kernel("attention_bwd", || (4.0 * work as f64, 12.0 * work as f64));
    if tasks > 1 && pool::parallel_worthwhile(work, ATTN_PAR_WORK) {
        pool::par_tasks(tasks, run);
    } else {
        for task in 0..tasks {
            run(task);
        }
    }
}

// ---------------------------------------------------------------------------
// cross-entropy
// ---------------------------------------------------------------------------

/// Mean token-level cross-entropy over a `(N, vocab)` logits matrix, plus
/// its gradient `dlogits = (softmax - onehot) / N`. Target ids are clamped
/// into the vocab the same way the embedding lookup clamps them.
pub fn cross_entropy(logits: &Matrix, targets: &[i32]) -> (f32, Matrix) {
    assert_eq!(logits.rows, targets.len(), "one target per logits row");
    let n = logits.rows;
    let vocab = logits.cols;
    let mut dlogits = Matrix::zeros(n, vocab);
    let mut loss = 0.0f64;
    let inv_n = 1.0 / n as f32;
    for r in 0..n {
        let row = logits.row(r);
        let t = (targets[r].max(0) as usize) % vocab;
        let mut mx = f32::NEG_INFINITY;
        for &l in row {
            mx = mx.max(l);
        }
        let mut z = 0.0f64;
        for &l in row {
            z += ((l - mx) as f64).exp();
        }
        loss -= (row[t] - mx) as f64 - z.ln();
        let drow = dlogits.row_mut(r);
        for (j, (&l, dj)) in row.iter().zip(drow.iter_mut()).enumerate() {
            let p = (((l - mx) as f64).exp() / z) as f32;
            *dj = (p - if j == t { 1.0 } else { 0.0 }) * inv_n;
        }
    }
    ((loss / n as f64) as f32, dlogits)
}

// ---------------------------------------------------------------------------
// small helpers
// ---------------------------------------------------------------------------

/// `x += delta`, elementwise (the residual-stream add).
pub fn add_into(x: &mut Matrix, delta: &Matrix) {
    debug_assert_eq!((x.rows, x.cols), (delta.rows, delta.cols));
    for (a, &b) in x.data.iter_mut().zip(&delta.data) {
        *a += b;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn rmsnorm_bwd_matches_finite_differences() {
        let mut rng = Rng::new(0);
        let x = Matrix::randn(&mut rng, 3, 8, 1.0);
        let gain: Vec<f32> = (0..8).map(|i| 1.0 + 0.1 * i as f32).collect();
        let r = Matrix::randn(&mut rng, 3, 8, 1.0); // linear functional weights
        // f64 accumulation + a fat eps keep the FD quotient well above f32
        // rounding noise.
        let eval = |x: &Matrix, gain: &[f32]| -> f32 {
            let (y, _) = rmsnorm_fwd(x, gain);
            y.data.iter().zip(&r.data).map(|(a, b)| (a * b) as f64).sum::<f64>() as f32
        };
        let (_, cache) = rmsnorm_fwd(&x, &gain);
        let (dx, dgain) = rmsnorm_bwd(&x, &gain, &cache, &r);
        let eps = 1e-2f32;
        for &(rr, cc) in &[(0usize, 0usize), (1, 3), (2, 7)] {
            let mut xp = x.clone();
            xp[(rr, cc)] += eps;
            let mut xm = x.clone();
            xm[(rr, cc)] -= eps;
            let fd = (eval(&xp, &gain) - eval(&xm, &gain)) / (2.0 * eps);
            let an = dx[(rr, cc)];
            assert!(
                (fd - an).abs() / an.abs().max(1e-2) < 2e-2,
                "dx[{rr},{cc}]: fd {fd} vs an {an}"
            );
        }
        for &j in &[0usize, 4] {
            let mut gp = gain.clone();
            gp[j] += eps;
            let mut gm = gain.clone();
            gm[j] -= eps;
            let fd = (eval(&x, &gp) - eval(&x, &gm)) / (2.0 * eps);
            assert!(
                (fd - dgain[j]).abs() / dgain[j].abs().max(1e-2) < 2e-2,
                "dgain[{j}]: fd {fd} vs an {}",
                dgain[j]
            );
        }
    }

    #[test]
    fn rope_inverse_undoes_forward_and_is_the_transpose() {
        let rope = Rope::new(16, 8);
        let mut rng = Rng::new(1);
        for pos in [0usize, 3, 15] {
            let orig: Vec<f32> = (0..24).map(|_| rng.normal() as f32).collect();
            let mut row = orig.clone();
            rope.apply_row(&mut row, pos);
            rope.apply_row_inv(&mut row, pos);
            for (a, b) in row.iter().zip(&orig) {
                assert!((a - b).abs() < 1e-5, "inverse must undo the rotation");
            }
            // <R x, y> == <x, R^T y>: the inverse is the adjoint.
            let x: Vec<f32> = (0..24).map(|_| rng.normal() as f32).collect();
            let y: Vec<f32> = (0..24).map(|_| rng.normal() as f32).collect();
            let mut rx = x.clone();
            rope.apply_row(&mut rx, pos);
            let mut rty = y.clone();
            rope.apply_row_inv(&mut rty, pos);
            let lhs: f32 = rx.iter().zip(&y).map(|(a, b)| a * b).sum();
            let rhs: f32 = x.iter().zip(&rty).map(|(a, b)| a * b).sum();
            assert!((lhs - rhs).abs() < 1e-4, "pos {pos}: {lhs} vs {rhs}");
        }
    }

    #[test]
    fn causal_attention_fwd_matches_attend_row() {
        // The training forward and the serving decode step must agree
        // bit-for-bit on the same context.
        let (t_len, heads, d) = (6usize, 2usize, 8usize);
        let mut rng = Rng::new(2);
        let q: Vec<f32> = (0..t_len * d).map(|_| rng.normal() as f32).collect();
        let k: Vec<f32> = (0..t_len * d).map(|_| rng.normal() as f32).collect();
        let v: Vec<f32> = (0..t_len * d).map(|_| rng.normal() as f32).collect();
        let mut out = vec![0.0f32; t_len * d];
        let mut probs = vec![0.0f32; heads * t_len * t_len];
        causal_attention_fwd(&q, &k, &v, t_len, heads, d, &mut out, &mut probs);
        for i in 0..t_len {
            let mut row = vec![0.0f32; d];
            attend_row(&q[i * d..(i + 1) * d], &k[..(i + 1) * d], &v[..(i + 1) * d], i + 1, heads, d, &mut row);
            for (a, b) in row.iter().zip(&out[i * d..(i + 1) * d]) {
                assert_eq!(a, b, "row {i} must be bit-identical to attend_row");
            }
        }
    }

    #[test]
    fn batched_attention_matches_per_sequence_calls_bit_exactly() {
        // The head-parallel batched kernels over packed sequences must be
        // bit-identical to one serial call per sequence, forward AND
        // backward (the determinism-by-disjoint-stripes invariant).
        let (bsz, t_len, heads, d) = (3usize, 5usize, 2usize, 8usize);
        let n = bsz * t_len * d;
        let mut rng = Rng::new(9);
        let q: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let k: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let v: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let r: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();

        let mut out_b = vec![0.0f32; n];
        let mut probs_b = vec![0.0f32; bsz * heads * t_len * t_len];
        causal_attention_fwd_batched(&q, &k, &v, bsz, t_len, heads, d, &mut out_b, &mut probs_b);
        let (mut dq_b, mut dk_b, mut dv_b) = (vec![0.0f32; n], vec![0.0f32; n], vec![0.0f32; n]);
        causal_attention_bwd_batched(
            &q, &k, &v, &probs_b, &r, bsz, t_len, heads, d, &mut dq_b, &mut dk_b, &mut dv_b,
        );

        for b in 0..bsz {
            let rows = b * t_len * d..(b + 1) * t_len * d;
            let pb = b * heads * t_len * t_len..(b + 1) * heads * t_len * t_len;
            let mut out_s = vec![0.0f32; t_len * d];
            let mut probs_s = vec![0.0f32; heads * t_len * t_len];
            causal_attention_fwd(
                &q[rows.clone()],
                &k[rows.clone()],
                &v[rows.clone()],
                t_len,
                heads,
                d,
                &mut out_s,
                &mut probs_s,
            );
            assert_eq!(out_s, out_b[rows.clone()], "sequence {b} forward diverged");
            assert_eq!(probs_s, probs_b[pb], "sequence {b} probs diverged");
            let (mut dq_s, mut dk_s, mut dv_s) =
                (vec![0.0f32; t_len * d], vec![0.0f32; t_len * d], vec![0.0f32; t_len * d]);
            causal_attention_bwd(
                &q[rows.clone()],
                &k[rows.clone()],
                &v[rows.clone()],
                &probs_s,
                &r[rows.clone()],
                t_len,
                heads,
                d,
                &mut dq_s,
                &mut dk_s,
                &mut dv_s,
            );
            assert_eq!(dq_s, dq_b[rows.clone()], "sequence {b} dq diverged");
            assert_eq!(dk_s, dk_b[rows.clone()], "sequence {b} dk diverged");
            assert_eq!(dv_s, dv_b[rows.clone()], "sequence {b} dv diverged");
        }
    }

    #[test]
    fn causal_attention_bwd_matches_finite_differences() {
        let (t_len, heads, d) = (5usize, 2usize, 8usize);
        let mut rng = Rng::new(3);
        let q: Vec<f32> = (0..t_len * d).map(|_| rng.normal() as f32).collect();
        let k: Vec<f32> = (0..t_len * d).map(|_| rng.normal() as f32).collect();
        let v: Vec<f32> = (0..t_len * d).map(|_| rng.normal() as f32).collect();
        let r: Vec<f32> = (0..t_len * d).map(|_| rng.normal() as f32).collect();
        let eval = |q: &[f32], k: &[f32], v: &[f32]| -> f32 {
            let mut out = vec![0.0f32; t_len * d];
            let mut probs = vec![0.0f32; heads * t_len * t_len];
            causal_attention_fwd(q, k, v, t_len, heads, d, &mut out, &mut probs);
            out.iter().zip(&r).map(|(a, b)| (a * b) as f64).sum::<f64>() as f32
        };
        let mut out = vec![0.0f32; t_len * d];
        let mut probs = vec![0.0f32; heads * t_len * t_len];
        causal_attention_fwd(&q, &k, &v, t_len, heads, d, &mut out, &mut probs);
        let (mut dq, mut dk, mut dv) = (vec![0.0f32; t_len * d], vec![0.0f32; t_len * d], vec![0.0f32; t_len * d]);
        causal_attention_bwd(&q, &k, &v, &probs, &r, t_len, heads, d, &mut dq, &mut dk, &mut dv);
        let eps = 1e-2f32;
        let probes = [3usize, 11, 27, 38];
        for &i in &probes {
            for (xs, grads, name) in [(&q, &dq, "dq"), (&k, &dk, "dk"), (&v, &dv, "dv")] {
                let mut p = xs.clone();
                p[i] += eps;
                let mut m = xs.clone();
                m[i] -= eps;
                let (fp, fm) = match name {
                    "dq" => (eval(&p, &k, &v), eval(&m, &k, &v)),
                    "dk" => (eval(&q, &p, &v), eval(&q, &m, &v)),
                    _ => (eval(&q, &k, &p), eval(&q, &k, &m)),
                };
                let fd = (fp - fm) / (2.0 * eps);
                let an = grads[i];
                assert!(
                    (fd - an).abs() / an.abs().max(1e-2) < 3e-2,
                    "{name}[{i}]: fd {fd} vs an {an}"
                );
            }
        }
    }

    #[test]
    fn cross_entropy_grad_matches_finite_differences() {
        let mut rng = Rng::new(4);
        let logits = Matrix::randn(&mut rng, 4, 9, 1.5);
        let targets = [2i32, 0, 8, 5];
        let (loss, dlogits) = cross_entropy(&logits, &targets);
        assert!(loss > 0.0);
        let eps = 1e-2f32;
        for &(r, c) in &[(0usize, 2usize), (0, 4), (2, 8), (3, 0)] {
            let mut lp = logits.clone();
            lp[(r, c)] += eps;
            let mut lm = logits.clone();
            lm[(r, c)] -= eps;
            let fd = (cross_entropy(&lp, &targets).0 - cross_entropy(&lm, &targets).0) / (2.0 * eps);
            let an = dlogits[(r, c)];
            assert!(
                (fd - an).abs() / an.abs().max(1e-2) < 2e-2,
                "dlogits[{r},{c}]: fd {fd} vs an {an}"
            );
        }
    }

    #[test]
    fn cross_entropy_of_uniform_logits_is_log_vocab() {
        let logits = Matrix::zeros(3, 32);
        let (loss, d) = cross_entropy(&logits, &[0, 1, 2]);
        assert!((loss - (32.0f32).ln()).abs() < 1e-5);
        // gradient rows sum to zero (softmax minus onehot)
        for r in 0..3 {
            let s: f32 = d.row(r).iter().sum();
            assert!(s.abs() < 1e-6);
        }
    }

    #[test]
    fn silu_derivative_matches_finite_differences() {
        for &x in &[-3.0f32, -0.5, 0.0, 0.7, 4.0] {
            let eps = 1e-3;
            let fd = (silu(x + eps) - silu(x - eps)) / (2.0 * eps);
            assert!((fd - dsilu(x)).abs() < 1e-3, "x={x}: fd {fd} vs {}", dsilu(x));
        }
    }
}
