//! The shared full-sequence decoder: ONE implementation of the transformer
//! forward (embedding → [RMSNorm → RoPE attention → spectral SwiGLU] × L →
//! final norm → tied/untied LM head) used by BOTH `serve::Engine::forward_full`
//! (the correctness baseline every KV-cache test pins against) and the
//! native trainer — plus its full reverse-mode backward.
//!
//! Because serving and training literally execute this function, the two
//! paths cannot drift: the serve tests that assert KV decode ≡ full forward
//! transitively assert KV decode ≡ training forward.
//!
//! The backward produces [`ModelGrads`] — compact factor gradients
//! `(m,k)/(k)/(n,k)` for every spectral triple (paper §3: no `(m, n)`
//! gradient ever exists) and dense gradients for embeddings, attention and
//! norms. Gradients are finite-difference checked in the tests below.

use crate::serve::engine::SpectralModel;
use crate::spectral::layer::SpectralCache;
use crate::spectral::matrix::axpy;
use crate::spectral::{Matrix, SpectralGrads};

use super::blocks::{
    add_into, causal_attention_bwd_batched, causal_attention_fwd_batched, dsilu, rmsnorm_bwd,
    rmsnorm_fwd, silu, RmsCache, Rope,
};

// ---------------------------------------------------------------------------
// caches
// ---------------------------------------------------------------------------

/// Per-layer activations the backward pass needs (all `(B*T, ·)` matrices;
/// `probs` is `B * n_heads * T * T` softmax weights).
pub struct LayerFwdCache {
    x_in: Matrix,
    h1: Matrix,
    r1: RmsCache,
    q: Matrix,
    k: Matrix,
    v: Matrix,
    probs: Vec<f32>,
    att: Matrix,
    x_mid: Matrix,
    h2: Matrix,
    r2: RmsCache,
    g: Matrix,
    u: Matrix,
    a: Matrix,
    gate_c: SpectralCache,
    up_c: SpectralCache,
    down_c: SpectralCache,
}

/// Everything [`decoder_bwd`] needs from a [`decoder_fwd`] call.
pub struct FwdCache {
    layers: Vec<LayerFwdCache>,
    x_f: Matrix,
    hf: Matrix,
    rf: RmsCache,
}

// ---------------------------------------------------------------------------
// gradients
// ---------------------------------------------------------------------------

/// Gradients for one decoder block — dense attention matrices, norm gains,
/// and the three compact spectral triples.
pub struct LayerGrads {
    pub wq: Matrix,
    pub wk: Matrix,
    pub wv: Matrix,
    pub wo: Matrix,
    pub ln1: Vec<f32>,
    pub ln2: Vec<f32>,
    pub gate: SpectralGrads,
    pub up: SpectralGrads,
    pub down: SpectralGrads,
}

/// Full-model gradients, shaped exactly like the parameters.
pub struct ModelGrads {
    pub embed: Matrix,
    pub layers: Vec<LayerGrads>,
    pub ln_f: Vec<f32>,
    pub head: Option<Matrix>,
}

impl ModelGrads {
    /// Flat gradient slices in the canonical parameter order (see
    /// `train::trainer::param_kinds` — embed, then per layer
    /// wq/wk/wv/wo/ln1/ln2/gate(u,s,v)/up(…)/down(…), then ln_f, then head).
    pub fn slices(&self) -> Vec<&[f32]> {
        let mut out: Vec<&[f32]> = vec![&self.embed.data];
        for l in &self.layers {
            out.push(&l.wq.data);
            out.push(&l.wk.data);
            out.push(&l.wv.data);
            out.push(&l.wo.data);
            out.push(&l.ln1);
            out.push(&l.ln2);
            for g in [&l.gate, &l.up, &l.down] {
                out.push(&g.du.data);
                out.push(&g.ds);
                out.push(&g.dv.data);
            }
        }
        out.push(&self.ln_f);
        if let Some(h) = &self.head {
            out.push(&h.data);
        }
        out
    }

    fn slices_mut(&mut self) -> Vec<&mut [f32]> {
        let mut out: Vec<&mut [f32]> = vec![&mut self.embed.data];
        for l in &mut self.layers {
            out.push(&mut l.wq.data);
            out.push(&mut l.wk.data);
            out.push(&mut l.wv.data);
            out.push(&mut l.wo.data);
            out.push(&mut l.ln1);
            out.push(&mut l.ln2);
            for g in [&mut l.gate, &mut l.up, &mut l.down] {
                out.push(&mut g.du.data);
                out.push(&mut g.ds);
                out.push(&mut g.dv.data);
            }
        }
        out.push(&mut self.ln_f);
        if let Some(h) = &mut self.head {
            out.push(&mut h.data);
        }
        out
    }

    /// Global L2 norm over every gradient entry (f64 accumulation).
    pub fn global_norm(&self) -> f32 {
        let mut acc = 0.0f64;
        for s in self.slices() {
            for &v in s {
                acc += v as f64 * v as f64;
            }
        }
        acc.sqrt() as f32
    }

    /// Scale every gradient by `f` (gradient clipping).
    pub fn scale(&mut self, f: f32) {
        for s in self.slices_mut() {
            for v in s.iter_mut() {
                *v *= f;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// forward
// ---------------------------------------------------------------------------

/// Full-sequence forward over `bsz` packed sequences of `t_len` tokens
/// (`tokens.len() == bsz * t_len`, row-major). Returns `(B*T, vocab)`
/// logits (position `b*T + i` holds the next-token logits after token `i`
/// of sequence `b`) and the activation cache for [`decoder_bwd`].
///
/// Rows of different sequences never attend to each other; within a
/// sequence, position `i` attends causally over `0..=i`.
pub fn decoder_fwd(
    model: &SpectralModel,
    rope: &Rope,
    tokens: &[i32],
    bsz: usize,
    t_len: usize,
) -> (Matrix, FwdCache) {
    let c = &model.cfg;
    assert_eq!(tokens.len(), bsz * t_len, "tokens must be bsz x t_len");
    assert!(t_len >= 1 && t_len <= rope.max_seq(), "sequence length {t_len} out of range");
    let d = c.d_model;
    let n = bsz * t_len;

    let mut x = Matrix::zeros(n, d);
    for (i, &t) in tokens.iter().enumerate() {
        let t = (t.max(0) as usize) % c.vocab;
        x.row_mut(i).copy_from_slice(model.embed.row(t));
    }

    let mut layers = Vec::with_capacity(c.n_layers);
    for layer in &model.layers {
        let x_in = x.clone();
        // attention
        let (h1, r1) = rmsnorm_fwd(&x, &layer.ln1);
        let mut q = h1.matmul(&layer.wq);
        let mut k = h1.matmul(&layer.wk);
        let v = h1.matmul(&layer.wv);
        for i in 0..n {
            let pos = i % t_len;
            rope.apply_row(q.row_mut(i), pos);
            rope.apply_row(k.row_mut(i), pos);
        }
        // One head-parallel call over every (sequence, head) pair — the
        // pool shards tasks, results bit-identical at any thread count.
        let mut att = Matrix::zeros(n, d);
        let mut probs = vec![0.0f32; bsz * c.n_heads * t_len * t_len];
        causal_attention_fwd_batched(
            &q.data,
            &k.data,
            &v.data,
            bsz,
            t_len,
            c.n_heads,
            d,
            &mut att.data,
            &mut probs,
        );
        add_into(&mut x, &att.matmul(&layer.wo));
        let x_mid = x.clone();

        // spectral SwiGLU MLP
        let (h2, r2) = rmsnorm_fwd(&x, &layer.ln2);
        let (g, gate_c) = layer.gate.forward(&h2);
        let (u, up_c) = layer.up.forward(&h2);
        let mut a = g.clone();
        for (ai, &ui) in a.data.iter_mut().zip(&u.data) {
            *ai = silu(*ai) * ui;
        }
        let (m, down_c) = layer.down.forward(&a);
        add_into(&mut x, &m);

        layers.push(LayerFwdCache {
            x_in,
            h1,
            r1,
            q,
            k,
            v,
            probs,
            att,
            x_mid,
            h2,
            r2,
            g,
            u,
            a,
            gate_c,
            up_c,
            down_c,
        });
    }

    let x_f = x;
    let (hf, rf) = rmsnorm_fwd(&x_f, &model.ln_f);
    let logits = model.logits(&hf);
    (logits, FwdCache { layers, x_f, hf, rf })
}

// ---------------------------------------------------------------------------
// backward
// ---------------------------------------------------------------------------

/// Reverse-mode backward through the whole decoder: given `dL/dlogits`,
/// produce gradients for every parameter. `tokens`, `bsz`, `t_len` and
/// `cache` must come from the matching [`decoder_fwd`] call.
pub fn decoder_bwd(
    model: &SpectralModel,
    rope: &Rope,
    tokens: &[i32],
    bsz: usize,
    t_len: usize,
    cache: &FwdCache,
    dlogits: &Matrix,
) -> ModelGrads {
    let c = &model.cfg;
    let d = c.d_model;
    let n = bsz * t_len;
    assert_eq!((dlogits.rows, dlogits.cols), (n, c.vocab));

    let mut embed_grad = Matrix::zeros(c.vocab, d);
    // LM head: tied shares the embedding matrix, untied has its own.
    let (dhf, head_grad) = match &model.head {
        Some(head) => {
            // logits = hf @ head ; head is (d, vocab).
            let dh = cache.hf.t_matmul(dlogits); // (d, vocab)
            (dlogits.matmul_t(head), Some(dh)) // (N, d)
        }
        None => {
            // logits = hf @ embed^T.
            add_into(&mut embed_grad, &dlogits.t_matmul(&cache.hf)); // (vocab, d)
            (dlogits.matmul(&model.embed), None) // (N, d)
        }
    };
    let (dx_f, ln_f_grad) = rmsnorm_bwd(&cache.x_f, &model.ln_f, &cache.rf, &dhf);
    let mut dres = dx_f;

    let mut layer_grads_rev: Vec<LayerGrads> = Vec::with_capacity(c.n_layers);
    for (layer, lc) in model.layers.iter().zip(&cache.layers).rev() {
        // -- MLP branch (its output was added onto x_mid) --------------------
        let (da, down_g) = layer.down.backward(&lc.a, &dres, &lc.down_c);
        // a = silu(g) ⊙ u
        let mut du = da.clone();
        let mut dg = da;
        for i in 0..du.data.len() {
            let gi = lc.g.data[i];
            du.data[i] *= silu(gi);
            dg.data[i] *= lc.u.data[i] * dsilu(gi);
        }
        let (dh2_u, up_g) = layer.up.backward(&lc.h2, &du, &lc.up_c);
        let (mut dh2, gate_g) = layer.gate.backward(&lc.h2, &dg, &lc.gate_c);
        add_into(&mut dh2, &dh2_u);
        let (dx_mid, ln2_grad) = rmsnorm_bwd(&lc.x_mid, &layer.ln2, &lc.r2, &dh2);
        add_into(&mut dres, &dx_mid);

        // -- attention branch (its output was added onto x_in) ---------------
        let datt = dres.matmul_t(&layer.wo); // (N, d)
        let wo_grad = lc.att.t_matmul(&dres); // (d, d)
        let mut dq = Matrix::zeros(n, d);
        let mut dk = Matrix::zeros(n, d);
        let mut dv = Matrix::zeros(n, d);
        causal_attention_bwd_batched(
            &lc.q.data,
            &lc.k.data,
            &lc.v.data,
            &lc.probs,
            &datt.data,
            bsz,
            t_len,
            c.n_heads,
            d,
            &mut dq.data,
            &mut dk.data,
            &mut dv.data,
        );
        // RoPE adjoint: rotate the q/k gradients back.
        for i in 0..n {
            let pos = i % t_len;
            rope.apply_row_inv(dq.row_mut(i), pos);
            rope.apply_row_inv(dk.row_mut(i), pos);
        }
        let wq_grad = lc.h1.t_matmul(&dq);
        let wk_grad = lc.h1.t_matmul(&dk);
        let wv_grad = lc.h1.t_matmul(&dv);
        let mut dh1 = dq.matmul_t(&layer.wq);
        add_into(&mut dh1, &dk.matmul_t(&layer.wk));
        add_into(&mut dh1, &dv.matmul_t(&layer.wv));
        let (dx_in, ln1_grad) = rmsnorm_bwd(&lc.x_in, &layer.ln1, &lc.r1, &dh1);
        add_into(&mut dres, &dx_in);

        layer_grads_rev.push(LayerGrads {
            wq: wq_grad,
            wk: wk_grad,
            wv: wv_grad,
            wo: wo_grad,
            ln1: ln1_grad,
            ln2: ln2_grad,
            gate: gate_g,
            up: up_g,
            down: down_g,
        });
    }
    layer_grads_rev.reverse();

    // embedding gather adjoint: scatter-add the residual gradient rows.
    for (i, &t) in tokens.iter().enumerate() {
        let t = (t.max(0) as usize) % c.vocab;
        axpy(1.0, dres.row(i), embed_grad.row_mut(t));
    }

    ModelGrads {
        embed: embed_grad,
        layers: layer_grads_rev,
        ln_f: ln_f_grad,
        head: head_grad,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::engine::EngineConfig;
    use crate::util::rng::Rng;

    fn tiny_cfg(tied: bool) -> EngineConfig {
        EngineConfig {
            vocab: 24,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ffn: 20,
            rank: 3,
            max_seq: 16,
            tied,
        }
    }

    fn tiny_inputs(rng: &mut Rng, vocab: usize, n: usize) -> Vec<i32> {
        (0..n).map(|_| (rng.next_u64() % vocab as u64) as i32).collect()
    }

    /// L(theta) = sum(logits ⊙ R) — a linear functional of the forward, so
    /// dL/dlogits = R exactly and finite differences probe only the network.
    /// f64 accumulation keeps the FD quotient above f32 rounding noise.
    fn eval(model: &SpectralModel, rope: &Rope, tokens: &[i32], b: usize, t: usize, r: &Matrix) -> f32 {
        let (logits, _) = decoder_fwd(model, rope, tokens, b, t);
        logits.data.iter().zip(&r.data).map(|(a, w)| (a * w) as f64).sum::<f64>() as f32
    }

    fn check_probe(
        model: &SpectralModel,
        rope: &Rope,
        tokens: &[i32],
        b: usize,
        t: usize,
        r: &Matrix,
        analytic: f32,
        name: &str,
        perturb: impl Fn(&mut SpectralModel, f32),
    ) {
        let eps = 1e-2f32;
        let mut mp = clone_model(model);
        perturb(&mut mp, eps);
        let mut mm = clone_model(model);
        perturb(&mut mm, -eps);
        let fd = (eval(&mp, rope, tokens, b, t, r) - eval(&mm, rope, tokens, b, t, r)) / (2.0 * eps);
        // the 0.05 floor keeps near-zero-gradient probes from comparing FD
        // noise against itself; real backward bugs show up as O(|grad|)
        // mismatches on the well-conditioned probes
        let denom = analytic.abs().max(fd.abs()).max(0.05);
        assert!(
            (fd - analytic).abs() / denom < 5e-2,
            "{name}: fd {fd} vs analytic {analytic}"
        );
    }

    fn clone_model(m: &SpectralModel) -> SpectralModel {
        SpectralModel::from_tensors(&m.to_tensors()).unwrap()
    }

    #[test]
    fn model_gradients_match_finite_differences_tied() {
        let mut rng = Rng::new(7);
        let model = SpectralModel::init(tiny_cfg(true), 7);
        let rope = Rope::new(model.cfg.max_seq, model.cfg.head_dim());
        let (b, t) = (2usize, 6usize);
        let tokens = tiny_inputs(&mut rng, model.cfg.vocab, b * t);
        let r = Matrix::randn(&mut rng, b * t, model.cfg.vocab, 1.0);

        let (_, cache) = decoder_fwd(&model, &rope, &tokens, b, t);
        let grads = decoder_bwd(&model, &rope, &tokens, b, t, &cache, &r);

        let used_tok = (tokens[0].max(0) as usize) % model.cfg.vocab;
        let probes: Vec<(&str, f32, Box<dyn Fn(&mut SpectralModel, f32)>)> = vec![
            ("embed", grads.embed[(used_tok, 1)], Box::new(move |m, e| m.embed[(used_tok, 1)] += e)),
            ("wq", grads.layers[0].wq[(0, 1)], Box::new(|m, e| m.layers[0].wq[(0, 1)] += e)),
            ("wk", grads.layers[1].wk[(1, 0)], Box::new(|m, e| m.layers[1].wk[(1, 0)] += e)),
            ("wv", grads.layers[0].wv[(2, 2)], Box::new(|m, e| m.layers[0].wv[(2, 2)] += e)),
            ("wo", grads.layers[1].wo[(3, 0)], Box::new(|m, e| m.layers[1].wo[(3, 0)] += e)),
            ("ln1", grads.layers[0].ln1[0], Box::new(|m, e| m.layers[0].ln1[0] += e)),
            ("ln2", grads.layers[1].ln2[2], Box::new(|m, e| m.layers[1].ln2[2] += e)),
            ("gate.u", grads.layers[0].gate.du[(0, 0)], Box::new(|m, e| m.layers[0].gate.u[(0, 0)] += e)),
            ("gate.s", grads.layers[0].gate.ds[0], Box::new(|m, e| m.layers[0].gate.s[0] += e)),
            ("up.v", grads.layers[1].up.dv[(1, 1)], Box::new(|m, e| m.layers[1].up.v[(1, 1)] += e)),
            ("down.u", grads.layers[0].down.du[(2, 1)], Box::new(|m, e| m.layers[0].down.u[(2, 1)] += e)),
            ("ln_f", grads.ln_f[3], Box::new(|m, e| m.ln_f[3] += e)),
        ];
        for (name, analytic, perturb) in probes {
            check_probe(&model, &rope, &tokens, b, t, &r, analytic, name, perturb);
        }
    }

    #[test]
    fn model_gradients_match_finite_differences_untied() {
        let mut rng = Rng::new(9);
        let model = SpectralModel::init(tiny_cfg(false), 9);
        assert!(model.head.is_some(), "untied config must materialize a head");
        let rope = Rope::new(model.cfg.max_seq, model.cfg.head_dim());
        let (b, t) = (1usize, 5usize);
        let tokens = tiny_inputs(&mut rng, model.cfg.vocab, b * t);
        let r = Matrix::randn(&mut rng, b * t, model.cfg.vocab, 1.0);
        let (_, cache) = decoder_fwd(&model, &rope, &tokens, b, t);
        let grads = decoder_bwd(&model, &rope, &tokens, b, t, &cache, &r);
        let head_grad = grads.head.as_ref().expect("untied backward must emit a head gradient");
        let used_tok = (tokens[2].max(0) as usize) % model.cfg.vocab;
        let probes: Vec<(&str, f32, Box<dyn Fn(&mut SpectralModel, f32)>)> = vec![
            ("head", head_grad[(0, 1)], Box::new(|m, e| {
                if let Some(h) = &mut m.head {
                    h[(0, 1)] += e;
                }
            })),
            ("embed", grads.embed[(used_tok, 0)], Box::new(move |m, e| m.embed[(used_tok, 0)] += e)),
        ];
        for (name, analytic, perturb) in probes {
            check_probe(&model, &rope, &tokens, b, t, &r, analytic, name, perturb);
        }
    }

    #[test]
    fn batched_forward_equals_per_sequence_forward() {
        // Sequences in one packed batch must not see each other.
        let mut rng = Rng::new(11);
        let model = SpectralModel::init(tiny_cfg(true), 3);
        let rope = Rope::new(model.cfg.max_seq, model.cfg.head_dim());
        let t = 7usize;
        let ta = tiny_inputs(&mut rng, model.cfg.vocab, t);
        let tb = tiny_inputs(&mut rng, model.cfg.vocab, t);
        let mut packed = ta.clone();
        packed.extend_from_slice(&tb);
        let (batched, _) = decoder_fwd(&model, &rope, &packed, 2, t);
        let (la, _) = decoder_fwd(&model, &rope, &ta, 1, t);
        let (lb, _) = decoder_fwd(&model, &rope, &tb, 1, t);
        for i in 0..t {
            for j in 0..model.cfg.vocab {
                assert_eq!(batched[(i, j)], la[(i, j)], "row {i} of sequence a diverged");
                assert_eq!(batched[(t + i, j)], lb[(i, j)], "row {i} of sequence b diverged");
            }
        }
    }

    #[test]
    fn grad_shapes_are_compact_and_clip_scales_the_norm() {
        let mut rng = Rng::new(13);
        let model = SpectralModel::init(tiny_cfg(true), 1);
        let rope = Rope::new(model.cfg.max_seq, model.cfg.head_dim());
        let (b, t) = (2usize, 4usize);
        let tokens = tiny_inputs(&mut rng, model.cfg.vocab, b * t);
        let r = Matrix::randn(&mut rng, b * t, model.cfg.vocab, 1.0);
        let (_, cache) = decoder_fwd(&model, &rope, &tokens, b, t);
        let mut grads = decoder_bwd(&model, &rope, &tokens, b, t, &cache, &r);
        // spectral grads are (m,k)/(k)/(n,k) — never (d_model, d_ffn)
        let g = &grads.layers[0].gate;
        assert_eq!((g.du.rows, g.du.cols), (16, 3));
        assert_eq!(g.ds.len(), 3);
        assert_eq!((g.dv.rows, g.dv.cols), (20, 3));
        let norm = grads.global_norm();
        assert!(norm > 0.0);
        grads.scale(0.5 / norm);
        assert!((grads.global_norm() - 0.5).abs() < 1e-3);
    }
}
