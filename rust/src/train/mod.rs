//! `train` — the pure-Rust end-to-end training engine: paper Algorithm 1
//! (forward through compact factors, backprop into (U, s, V), AdamW, Stiefel
//! QR retraction) with no PJRT, no artifacts, nothing beyond the standard
//! library — the training half of the `serve` story. A model trained here
//! checkpoints to `.sct` and serves directly through [`crate::serve`].
//!
//! Pieces:
//! * [`blocks`] — the **shared decoder blocks**: RMSNorm, RoPE, SiLU,
//!   causal softmax attention and cross-entropy, each forward next to its
//!   reverse-mode adjoint. The serving engine executes the same forward
//!   functions on its KV-cached hot path, so train and serve cannot drift;
//!   every adjoint is finite-difference checked.
//! * [`decoder`] — ONE full-sequence decoder forward (used verbatim by
//!   `serve::Engine::forward_full`, the baseline all KV tests pin against)
//!   plus the whole-model backward producing compact [`decoder::ModelGrads`]
//!   — gradient shapes `(m,k)/(k)/(n,k)`; no `(m, n)` tensor exists
//!   anywhere in training, the paper's core storage claim.
//! * [`trainer`] — [`NativeTrainer`]: per-tensor AdamW with the dense /
//!   spectral LR split, global gradient-norm clipping, QR retraction every
//!   `retract_every` steps, per-phase step timing (Table 2's
//!   fwd/bwd/opt/retract decomposition), and checkpoint save/restore with
//!   optimizer moments.
//!
//! # The `.sct` params layout contract
//!
//! Training checkpoints and serve checkpoints share one tensor namespace
//! (mirroring the AOT session state layout the JAX side exports):
//!
//! ```text
//! model/meta                        i32[8 + n_layers]: vocab, d_model,
//!                                   n_layers, n_heads, d_ffn, rank,
//!                                   max_seq, tied, then one rank per
//!                                   layer (heterogeneous after `rank`-
//!                                   subsystem transitions; the header
//!                                   `rank` field records the max).
//!                                   Legacy i32[8] checkpoints load with
//!                                   the uniform header rank.
//! params/embed                      f32[vocab, d_model]
//! params/layers/{i}/attn/wq|wk|wv|wo f32[d_model, d_model]
//! params/layers/{i}/ln1|ln2         f32[d_model]
//! params/layers/{i}/mlp/{p}/u       f32[m, k_i]   p in {gate, up, down}
//! params/layers/{i}/mlp/{p}/s       f32[k_i]
//! params/layers/{i}/mlp/{p}/v       f32[n, k_i]
//! params/ln_f                       f32[d_model]
//! params/head                       f32[d_model, vocab]  (untied only)
//! opt/t                             i32[1]              (trainer only)
//! opt/{m,v}/params/...              f32[flat]           (trainer only)
//! ```
//!
//! `serve::SpectralModel::load` reads `model/meta` + `params/...` and
//! ignores `opt/...`, so a mid-training checkpoint serves as-is; the
//! trainer additionally restores the AdamW moments so a resumed run
//! continues bit-for-bit — including runs whose layers carry different
//! ranks (`k_i` above): the optimizer slots derive their lengths from the
//! model tensors, and [`NativeTrainer::set_layer_rank`] keeps moments and
//! parameters aligned through every live transition. The canonical tensor
//! order (and the optimizer slot order) is defined once, in
//! [`trainer::param_kinds`].

pub mod blocks;
pub mod decoder;
pub mod trainer;

pub use blocks::Rope;
pub use decoder::{decoder_bwd, decoder_fwd, ModelGrads};
pub use trainer::{mlp_compression, NativeTrainConfig, NativeTrainer, ParamKind};
